//! End-to-end driver across all three layers on a real (synthetic-image)
//! workload — the repo's full-stack validation (DESIGN.md §5, recorded in
//! EXPERIMENTS.md §E2E):
//!
//!   L1/L2 (build time): `make artifacts` trained Pallas-kernel score
//!   nets on blobs8/gmm2d and exported HLO text.
//!   L3 (this binary):   loads the nets through PJRT, replays the
//!   manifest probes (cross-layer numerics), then runs gDDIM with the
//!   *learned* score at several NFE and reports FD vs the oracle runs.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_blobs
//! ```

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Process, TimeGrid};
use gddim::math::rng::Rng;
use gddim::metrics::frechet::frechet_to_spec;
use gddim::runtime::{Manifest, NetScore};
use gddim::samplers::gddim::sample_deterministic;
use gddim::score::model::ScoreModel;
use gddim::score::oracle::GmmOracle;
use gddim::util::bench::Table;
use gddim::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let dir = Manifest::default_dir();
    let manifest = match Manifest::load(&dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("no artifacts at {dir:?} ({e}); run `make artifacts` first");
            std::process::exit(1);
        }
    };
    let client = xla::PjRtClient::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {} ({} devices)", client.platform_name(), client.device_count());

    // Cross-layer probe check for every exported model.
    println!("\n== manifest probes (jax-recorded vs PJRT-executed) ==");
    let mut nets = Vec::new();
    for entry in &manifest.models {
        let net = NetScore::load(&client, entry).expect("load model");
        let err = net.probe_error().expect("probe");
        println!(
            "{:<16} dim={:<4} loss={:<8} probe max|Δ| = {err:.2e}  {}",
            entry.name,
            entry.dim_u,
            entry.final_loss.map(|l| format!("{l:.4}")).unwrap_or("cached".into()),
            if err < 1e-4 { "OK" } else { "MISMATCH" }
        );
        assert!(err < 1e-3, "cross-layer probe mismatch for {}", entry.name);
        nets.push(net);
    }

    // Learned-score sampling vs oracle-score sampling.
    let n = args.get_usize("n", 1000);
    let mut t = Table::new(
        "E2E: gDDIM with learned (PJRT) vs exact score — FD",
        &["model", "NFE", "FD (net)", "FD (oracle)"],
    );
    for net in &nets {
        let entry = &net.entry;
        let info = presets::info(&entry.dataset).unwrap();
        let spec = info.build();
        let proc = gddim::diffusion::process_for(&entry.process, info).unwrap();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), entry.kt);
        for nfe in [20usize, 50] {
            let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), nfe);
            let plan = SamplerPlan::build(
                proc.as_ref(),
                &grid,
                &PlanConfig::deterministic(2, entry.kt),
            );
            let mut rng = Rng::seed_from(5);
            let out_net = sample_deterministic(
                proc.as_ref(),
                &plan,
                net as &dyn ScoreModel,
                n,
                &mut rng,
                false,
            );
            let mut rng = Rng::seed_from(5);
            let out_oracle =
                sample_deterministic(proc.as_ref(), &plan, &oracle, n, &mut rng, false);
            t.row(vec![
                entry.name.clone(),
                nfe.to_string(),
                format!("{:.3}", frechet_to_spec(&out_net.xs, &spec)),
                format!("{:.3}", frechet_to_spec(&out_oracle.xs, &spec)),
            ]);
        }
    }
    t.emit("e2e_blobs");
    println!("python was used only at build time; this binary ran the nets via PJRT.");
}
