//! Quickstart: build a diffusion process, prepare a gDDIM plan (Stage I),
//! sample with 20 NFE (Stage II), and score the result — the 60-second
//! tour of the public API.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use std::sync::Arc;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Cld, Process, TimeGrid};
use gddim::math::rng::Rng;
use gddim::metrics::coverage::coverage;
use gddim::metrics::frechet::frechet_to_spec;
use gddim::samplers::gddim::sample_deterministic;
use gddim::score::oracle::GmmOracle;

fn main() {
    // 1. A diffusion model: critically-damped Langevin dynamics over 2-D data.
    let proc = Arc::new(Cld::standard(2));

    // 2. Data + its exact score (swap in a PJRT-backed net via
    //    `gddim::runtime::NetScore` once `make artifacts` has run).
    let spec = presets::gmm2d();
    let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);

    // 3. Stage I — offline: 20-step grid, multistep order 3, K_t = R_t.
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 20);
    let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(3, KtKind::R));
    println!("Stage I done in {:.1} ms", plan.build_seconds * 1e3);

    // 4. Stage II — online: 4096 samples in 20 score evaluations.
    let mut rng = Rng::seed_from(0);
    let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 4096, &mut rng, false);

    // 5. Quality report.
    let fd = frechet_to_spec(&out.xs, &spec);
    let cov = coverage(&out.xs, &spec);
    println!(
        "gDDIM on CLD: NFE={}  FD={fd:.4}  modes covered {}/{}  outliers {:.2}%",
        out.nfe,
        spec.n_modes() - cov.missing,
        spec.n_modes(),
        100.0 * cov.outliers
    );
    assert!(fd < 0.5, "quickstart quality regression");
    println!("first samples: {:?}", &out.xs[..8.min(out.xs.len())]);
}
