//! The batched sampling service under a Poisson workload — the serving
//! deliverable's demo (`gddim serve` wraps the same code).
//!
//! ```sh
//! cargo run --release --example serve_demo -- --requests 64 --rate 200
//! ```

use gddim::server::demo;
use gddim::util::cli::Args;

fn main() {
    demo::run(&Args::from_env());
}
