//! The paper's toy-data story, end to end (Figs. 2, 4, 5):
//! on a hard 2-D mixture with the *exact* score, compare Euler, the
//! exponential integrator with the wrong parameterization (K=L), and
//! gDDIM (K=R) at low NFE; then show what λ does.
//!
//! ```sh
//! cargo run --release --example toy2d -- --nfe 20
//! ```

use gddim::diffusion::process::KtKind;
use gddim::exp::helpers::{run_em, run_gddim, run_gddim_sde, setup};
use gddim::metrics::coverage::coverage;
use gddim::metrics::frechet::frechet_to_spec;
use gddim::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let nfe = args.get_usize("nfe", 20);
    let n = args.get_usize("n", 4000);
    let s = setup("cld", "hard2d");

    println!("hard 2-D mixture (25 tight modes), CLD, exact score, NFE={nfe}\n");
    let cases: Vec<(&str, gddim::samplers::common::SampleOutput)> = vec![
        ("Euler (prob-flow)", run_em(&s, 0.0, nfe, n, 1)),
        ("EM (SDE, λ=1)", run_em(&s, 1.0, nfe, n, 1)),
        ("EI, K=L_t", run_gddim(&s, KtKind::L, 1, nfe, false, n, 1)),
        ("EI, K=R_t (gDDIM)", run_gddim(&s, KtKind::R, 1, nfe, false, n, 1)),
        ("gDDIM multistep q=2", run_gddim(&s, KtKind::R, 3, nfe, false, n, 1)),
        ("stochastic gDDIM λ=0.5", run_gddim_sde(&s, 0.5, nfe, n, 1)),
    ];
    println!("{:<26} {:>8} {:>14} {:>9}", "sampler", "FD", "modes", "outliers");
    for (name, out) in cases {
        let fd = frechet_to_spec(&out.xs, &s.spec);
        let c = coverage(&out.xs, &s.spec);
        println!(
            "{name:<26} {fd:>8.4} {:>10}/{} {:>8.3}",
            s.spec.n_modes() - c.missing,
            s.spec.n_modes(),
            c.outliers
        );
    }
    println!("\n(the paper's Fig. 4 ordering: Euler ≪ EI(L) < EI(R); multistep helps further)");
}
