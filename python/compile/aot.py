"""AOT export: train (or load cached) score nets, lower to HLO **text**,
write `artifacts/*.hlo.txt` + `artifacts/manifest.json`.

HLO text — NOT `.serialize()` — is the interchange format: jax ≥ 0.5
emits HloModuleProto with 64-bit instruction ids which xla_extension
0.5.1 (the version the published `xla` crate binds) rejects; the text
parser reassigns ids (see /opt/xla-example/README.md).

Each variant exports **two** serving artifacts: the HLO text (for the
feature-gated PJRT executor) and a `.gdw` raw-weight file (see
:mod:`compile.weights`) that the pure-Rust ``score::net::ScoreNet``
loads with zero native deps.

The manifest records, per model: files, dims, batch, K_t kind, process,
dataset, network config, final training loss, and a **probe** (frozen
input → expected ε output) that the rust loaders replay to pin the
cross-layer numerics. The probe's `eps_row0` is the *float64 reference*
forward of the exported f32 weights (``weights.score_eps_f64``), which
the Rust float64 forward reproduces to ~1e-12 — so the rust gate is a
strict 1e-6. jax's float32 forward is asserted within 2e-4 of it here.

Exported function signature: `eps = f(u: f32[B, D], t: f32[]) → f32[B, D]`.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import score_eps
from .train import train_model
from .weights import probe_block, write_gdw

# (name, process, dataset, kt, hidden, blocks, steps)
VARIANTS = [
    ("vpsde_gmm2d", "vpsde", "gmm2d", "R", 128, 3, None),
    ("cld_gmm2d_R", "cld", "gmm2d", "R", 128, 3, None),
    ("cld_gmm2d_L", "cld", "gmm2d", "L", 128, 3, None),
    ("vpsde_blobs8", "vpsde", "blobs8", "R", 256, 3, None),
    ("bdm_blobs8", "bdm", "blobs8", "R", 256, 3, None),
    ("cld_blobs8_R", "cld", "blobs8", "R", 256, 3, None),
]

BATCH = 256


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True: the default printer elides big literals
    # as `constant({...})`, which the old XLA text parser then silently
    # fills with zeros — i.e. it would strip the trained weights out of
    # the artifact. (Found the hard way; pinned by the probe check below
    # and the rust integration test.)
    text = comp.as_hlo_text(True)
    assert "{...}" not in text, "HLO text still contains elided constants"
    return text


def export_variant(out_dir, name, process, dataset, kt, hidden, blocks, steps):
    params_path = os.path.join(out_dir, f"params_{name}.npz")
    cfg = None
    if os.path.exists(params_path):
        print(f"[{name}] loading cached params")
        blob = np.load(params_path, allow_pickle=True)
        params = {k: jnp.asarray(blob[k]) for k in blob.files if k != "__cfg__"}
        cfg_arr = blob["__cfg__"]
        from .model import ScoreNetConfig

        cfg = ScoreNetConfig(*[int(x) for x in cfg_arr])
        losses = []
    else:
        print(f"[{name}] training ({steps} steps)…")
        params, cfg, losses = train_model(
            process, dataset, kt=kt, hidden=hidden, blocks=blocks, steps=steps
        )
        np.savez(
            params_path,
            __cfg__=np.asarray(list(cfg), dtype=np.int64),
            **{k: np.asarray(v) for k, v in params.items()},
        )

    d = cfg.dim

    # Export with the jnp reference ops (see model._IMPLS for why), after
    # asserting pallas↔ref equivalence on a random batch.
    rng0 = np.random.default_rng(99)
    u_chk = jnp.asarray(rng0.standard_normal((32, d)).astype(np.float32))
    a = np.asarray(score_eps(params, cfg, u_chk, jnp.float32(0.37), impl="pallas"))
    b = np.asarray(score_eps(params, cfg, u_chk, jnp.float32(0.37), impl="ref"))
    np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)

    def fn(u, t):
        return (score_eps(params, cfg, u, t, impl="ref"),)

    spec_u = jax.ShapeDtypeStruct((BATCH, d), jnp.float32)
    spec_t = jax.ShapeDtypeStruct((), jnp.float32)
    lowered = jax.jit(fn).lower(spec_u, spec_t)
    hlo = to_hlo_text(lowered)
    hlo_file = f"{name}.hlo.txt"
    with open(os.path.join(out_dir, hlo_file), "w") as f:
        f.write(hlo)

    # Raw weights for the pure-Rust ScoreNet (deterministic bytes).
    gdw_file = f"{name}.gdw"
    write_gdw(os.path.join(out_dir, gdw_file), params, cfg)

    # Probe: deterministic input; the recorded row is the float64
    # reference forward, with jax's f32 evaluation asserted against it.
    probe, u_probe, eps_ref = probe_block(params, cfg, BATCH)
    eps_jax = np.asarray(fn(jnp.asarray(u_probe), jnp.asarray(np.float32(probe["t"])))[0])
    np.testing.assert_allclose(eps_jax, eps_ref, rtol=2e-4, atol=2e-4)

    entry = {
        "file": hlo_file,
        "weights": gdw_file,
        "process": process,
        "dataset": dataset,
        "kt": kt,
        "dim_u": d,
        "batch": BATCH,
        "hidden": cfg.hidden,
        "blocks": cfg.blocks,
        "emb_half": cfg.emb_half,
        "final_loss": float(np.mean(losses[-50:])) if losses else None,
        "probe": probe,
    }
    print(f"[{name}] exported {hlo_file} ({len(hlo)} chars) + {gdw_file}")
    return entry


def export_pallas_probe(out_dir):
    """A single-Pallas-kernel artifact proving the pallas→HLO-text→PJRT
    path end to end (xla_extension 0.5.1 handles exactly one interpret-
    mode kernel per module — see model._IMPLS). The rust integration test
    executes it and checks `silu(x@w+b)` numerically."""
    from .kernels.fused_linear import fused_linear

    w = jnp.asarray(np.linspace(-0.5, 0.5, 8 * 4, dtype=np.float32).reshape(8, 4))
    b = jnp.asarray(np.linspace(0.0, 0.3, 4, dtype=np.float32))

    def fn(x):
        return (fused_linear(x, w, b, activation="silu"),)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4, 8), jnp.float32))
    with open(os.path.join(out_dir, "pallas_probe.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))
    x = np.arange(32, dtype=np.float32).reshape(4, 8) * 0.1
    y = np.asarray(fn(jnp.asarray(x))[0])
    np.save(os.path.join(out_dir, "pallas_probe_expected.npy"), y)
    with open(os.path.join(out_dir, "pallas_probe_expected.json"), "w") as f:
        json.dump({"x_scale": 0.1, "y": y.reshape(-1).tolist()}, f)
    print(f"exported pallas_probe.hlo.txt")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("AOT_STEPS", "2000")))
    ap.add_argument("--only", default=None, help="comma-separated variant names")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    only = set(args.only.split(",")) if args.only else None
    export_pallas_probe(args.out_dir)
    manifest = {"models": {}, "batch": BATCH}
    for name, process, dataset, kt, hidden, blocks, steps in VARIANTS:
        if only and name not in only:
            continue
        manifest["models"][name] = export_variant(
            args.out_dir, name, process, dataset, kt, hidden, blocks, steps or args.steps
        )
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json with {len(manifest['models'])} models")


if __name__ == "__main__":
    main()
