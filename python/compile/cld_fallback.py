"""Fallback generator for ``configs/cld_tables.json``.

`gddim gen-configs` (the rust binary) is the **authoritative** producer
of the CLD Stage-I tables; :class:`compile.processes.Cld` only ever
interpolates them. This module exists for environments with no rust
toolchain (CI's python job, the fixture exporter): it replays the same
closed forms as ``rust/src/diffusion/cld.rs`` — Ψ(t,0), Σ_t and its
Cholesky L_t are exact exponential-polynomial expressions, and R_t uses
the polar trick ``R_t = L_t·Rot(φ_t)`` with the scalar angle φ
integrated by RK4 from the closed-form skew generator rate.

Fidelity notes: because Rot(φ) is orthogonal, ``R_tR_tᵀ = Σ_t`` holds to
machine precision for *any* φ, so the only approximation here is the
angle itself (RK4 on the same geometric grid the rust engine uses).
Training-data quality is insensitive to that at the tolerances involved;
anything downstream that pins numerics (manifest probes) is recorded
from the trained weights, not from these tables.
"""

import json
import math
import os

import numpy as np

# Mirrors rust `CldConfig::default()`.
BETA = 4.0
MASS = 0.25
GAMMA0 = 0.04
T_MAX = 1.0
T_MIN = 1e-3
TABLE_LEN = 4096
SUBSTEPS = 8

_OMEGA = 1.0 / math.sqrt(MASS)
_GAMMA = 2.0 * math.sqrt(MASS)  # critical damping Γ = 2√M
# Drift structure A with F_t = β·A, as ((a, b), (c, d)).
_A = (0.0, 1.0 / MASS, -1.0, -_GAMMA / MASS)


def _mul2(x, y):
    return (
        x[0] * y[0] + x[1] * y[2],
        x[0] * y[1] + x[1] * y[3],
        x[2] * y[0] + x[3] * y[2],
        x[2] * y[1] + x[3] * y[3],
    )


def _sigma(t):
    """Closed-form Σ_t as (xx, xv, vv) — port of `Cld::sigma_mat`."""
    w = _OMEGA
    tb = BETA * max(t, 0.0)
    e = math.exp(-2.0 * w * tb)
    g0 = GAMMA0 * MASS
    p = w * w * tb
    q = 1.0 - w * tb
    aa = 2.0 * w
    at = aa * tb
    if at < 1e-4:
        i0 = tb - aa * tb * tb / 2.0 + aa * aa * tb**3 / 6.0
        i1 = tb * tb / 2.0 - aa * tb**3 / 3.0
        i2 = tb**3 / 3.0 - aa * tb**4 / 4.0
    else:
        i0 = (1.0 - e) / aa
        i1 = (1.0 - e * (1.0 + at)) / (aa * aa)
        i2 = (2.0 - e * (2.0 + 2.0 * at + at * at)) / (aa * aa * aa)
    c = 2.0 * _GAMMA
    sxx = g0 * e * p * p + c * w**4 * i2
    sxv = g0 * e * p * q + c * w * w * (i1 - w * i2)
    svv = g0 * e * q * q + c * (i0 - 2.0 * w * i1 + w * w * i2)
    return sxx, sxv, svv


def _sigma_dot(t):
    """Lyapunov RHS F S + S Fᵀ + GGᵀ as (xx, xv, vv)."""
    sxx, sxv, svv = _sigma(t)
    fa, fb, fc, fd = (BETA * v for v in _A)
    dxx = 2.0 * (fa * sxx + fb * sxv)
    dxv = fa * sxv + fb * svv + sxx * fc + sxv * fd
    dvv = 2.0 * (fc * sxv + fd * svv) + 2.0 * _GAMMA * BETA
    return dxx, dxv, dvv


def _chol_and_dot(t):
    """Closed-form L_t and L'_t (lower triangular, as (l11, l21, l22))."""
    sxx, sxv, svv = _sigma(t)
    dxx, dxv, dvv = _sigma_dot(t)
    l11 = math.sqrt(max(sxx, 0.0))
    l21 = sxv / l11
    l22 = math.sqrt(max(svv - l21 * l21, 0.0))
    d11 = dxx / (2.0 * l11)
    d21 = (dxv - l21 * d11) / l11
    d22 = (dvv - 2.0 * l21 * d21) / (2.0 * l22)
    return (l11, l21, l22), (d11, d21, d22)


def _phi_rate(t):
    """φ' = [L⁻¹FL + ½L⁻¹GGᵀL⁻ᵀ − L⁻¹L']₍₂,₁₎ — port of `Cld::phi_rate`."""
    (l11, l21, l22), (d11, d21, d22) = _chol_and_dot(t)
    l = (l11, 0.0, l21, l22)
    ld = (d11, 0.0, d21, d22)
    li = (1.0 / l11, 0.0, -l21 / (l11 * l22), 1.0 / l22)
    f = tuple(BETA * v for v in _A)
    ggt_half = (0.0, 0.0, 0.0, _GAMMA * BETA)
    li_t = (li[0], li[2], li[1], li[3])
    m = _mul2(_mul2(li, f), l)
    n = _mul2(_mul2(li, ggt_half), li_t)
    p = _mul2(li, ld)
    return (m[2] + n[2] - p[2])


def _phi_table():
    """Integrate φ on the geometric grid rust uses; returns (ts, φs)."""
    r_start = T_MIN * 1e-2
    # φ(r_start): Rot(φ₀) = L⁻¹·sqrtm(Σ), with the SPD 2×2 closed form
    # sqrtm(S) = (S + √det·I)/√(tr + 2√det).
    sxx, sxv, svv = _sigma(r_start)
    sdet = math.sqrt(max(sxx * svv - sxv * sxv, 0.0))
    norm = math.sqrt(sxx + svv + 2.0 * sdet)
    sq = ((sxx + sdet) / norm, sxv / norm, sxv / norm, (svv + sdet) / norm)
    l11, l21, l22 = _chol_and_dot(r_start)[0]
    li = (1.0 / l11, 0.0, -l21 / (l11 * l22), 1.0 / l22)
    w0 = _mul2(li, sq)
    phi = math.atan2(w0[2], w0[0])

    ratio = math.log(T_MAX / r_start)
    ts = [r_start]
    phis = [phi]
    for i in range(TABLE_LEN):
        t_lo = r_start * math.exp(ratio * i / TABLE_LEN)
        t_hi = r_start * math.exp(ratio * (i + 1) / TABLE_LEN)
        h = (t_hi - t_lo) / SUBSTEPS
        for k in range(SUBSTEPS):
            t0 = t_lo + k * h
            k1 = _phi_rate(t0)
            k2 = _phi_rate(t0 + 0.5 * h)
            k3 = k2  # scalar autonomous-in-y RHS: k2 == k3 exactly
            k4 = _phi_rate(t0 + h)
            phi += h * (k1 + 2.0 * k2 + 2.0 * k3 + k4) / 6.0
        ts.append(t_hi)
        phis.append(phi)
    return np.asarray(ts), np.asarray(phis)


def ensure_cld_tables(config_dir):
    """Write a fallback ``configs/cld_tables.json`` when absent, with the
    same schema `gddim gen-configs` emits (2001 uniform rows of
    ``[t, Ψ(a,b,c,d), Σ(xx,xv,vv), R(a,b,c,d), L(l11,l21,l22)]``)."""
    path = os.path.join(config_dir, "cld_tables.json")
    if os.path.exists(path):
        return
    ts_phi, phis = _phi_table()
    log_ts = np.log(ts_phi)
    r_start = float(ts_phi[0])
    n = 2000
    rows = []
    for i in range(n + 1):
        t = T_MIN * 0.1 + (T_MAX - T_MIN * 0.1) * i / n
        w = _OMEGA
        tau = BETA * t
        sc = math.exp(-w * tau)
        nil = (_A[0] + w, _A[1], _A[2], _A[3] + w)  # A + ωI (nilpotent)
        psi = tuple(sc * ((1.0 if j in (0, 3) else 0.0) + tau * nil[j]) for j in range(4))
        sxx, sxv, svv = _sigma(t)
        tc = min(max(t, r_start), T_MAX)
        (l11, l21, l22), _ = _chol_and_dot(tc)
        phi = float(np.interp(math.log(tc), log_ts, phis))
        cphi, sphi = math.cos(phi), math.sin(phi)
        r = (l11 * cphi, -l11 * sphi, l21 * cphi + l22 * sphi, -l21 * sphi + l22 * cphi)
        # R Rᵀ = Σ holds for any φ (Rot is orthogonal) — cheap sanity net.
        assert abs(r[0] * r[0] + r[1] * r[1] - sxx) < 1e-9 * (1.0 + sxx)
        rows.append([t, *psi, sxx, sxv, svv, *r, l11, l21, l22])
    tab = {
        "columns": "t, psi(a,b,c,d), sigma(xx,xv,vv), R(a,b,c,d), L(l11,l21,l22)",
        "beta": BETA,
        "mass": MASS,
        "gamma0": GAMMA0,
        "rows": rows,
    }
    os.makedirs(config_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(tab, f)
    print(f"wrote fallback {path} (`gddim gen-configs` is authoritative)")
