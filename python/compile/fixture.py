"""Train + export the tiny learned-score fixture the rust tests pin.

``python -m compile.fixture`` (from `python/`) trains two deliberately
tiny nets (blocks=1, hidden=16, emb_half=8, fixed seed, ~200 steps —
seconds on CPU) and writes a weights-only artifacts directory:

    manifest.json
    tiny_vpsde_gmm2d.gdw      (vpsde on gmm2d, D=2)
    tiny_cld_gmm2d.gdw        (cld   on gmm2d, D=4 — position+velocity)

The output is committed under ``rust/tests/fixtures/learned/`` so the
rust probe-parity and serving tests stay hermetic when JAX is absent;
CI's python job re-runs this exporter on every PR (into a scratch dir)
to prove the pipeline still trains and exports end to end.

Unlike `aot.py` these entries carry **no** HLO file — the fixture only
feeds the pure-Rust ``score::net`` path, and the manifest schema allows
either artifact (`file` for PJRT, `weights` for native) per entry.
"""

import argparse
import json
import math
import os

import numpy as np

from .cld_fallback import ensure_cld_tables
from .processes import CONFIG_DIR
from .train import train_model
from .weights import probe_block, write_gdw


def ensure_gmm2d_config():
    """Write a minimal ``configs/datasets.json`` when the repo copy is
    absent (CI's python job has no rust binary to run `gddim
    gen-configs`). The spec mirrors ``data::presets::gmm2d`` exactly: 8
    modes on a radius-4 circle, shared variance 0.05, uniform weights."""
    path = os.path.join(CONFIG_DIR, "datasets.json")
    if os.path.exists(path):
        return
    means = [
        [4.0 * math.cos(math.tau * i / 8.0), 4.0 * math.sin(math.tau * i / 8.0)]
        for i in range(8)
    ]
    spec = {"name": "gmm2d", "d": 2, "var": 0.05, "weights": [1.0 / 8.0] * 8, "means": means}
    os.makedirs(CONFIG_DIR, exist_ok=True)
    with open(path, "w") as f:
        json.dump({"gmm2d": spec}, f, indent=2)
    print(f"wrote fallback {path} (gmm2d only; `gddim gen-configs` is authoritative)")

# (name, process, dataset, kt, hidden, blocks, emb_half)
FIXTURE_VARIANTS = [
    ("tiny_vpsde_gmm2d", "vpsde", "gmm2d", "R", 16, 1, 8),
    ("tiny_cld_gmm2d", "cld", "gmm2d", "R", 16, 1, 8),
]

BATCH = 64


def export_fixture(out_dir, steps=200, seed=0):
    ensure_gmm2d_config()
    ensure_cld_tables(CONFIG_DIR)
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"models": {}, "batch": BATCH}
    for name, process, dataset, kt, hidden, blocks, emb_half in FIXTURE_VARIANTS:
        print(f"[{name}] training tiny net ({steps} steps)…")
        params, cfg, losses = train_model(
            process,
            dataset,
            kt=kt,
            hidden=hidden,
            blocks=blocks,
            emb_half=emb_half,
            steps=steps,
            batch=128,
            seed=seed,
            log_every=0,
        )
        gdw_file = f"{name}.gdw"
        write_gdw(os.path.join(out_dir, gdw_file), params, cfg)
        probe, u_probe, eps_ref = probe_block(params, cfg, BATCH)

        # Cross-check: jax's f32 forward must agree with the recorded
        # float64 reference to f32 rounding — same gate as aot.py.
        import jax.numpy as jnp

        from .model import score_eps

        eps_jax = np.asarray(
            score_eps(params, cfg, jnp.asarray(u_probe), jnp.float32(probe["t"]), impl="ref")
        )
        np.testing.assert_allclose(eps_jax, eps_ref, rtol=2e-4, atol=2e-4)

        manifest["models"][name] = {
            "weights": gdw_file,
            "process": process,
            "dataset": dataset,
            "kt": kt,
            "dim_u": cfg.dim,
            "batch": BATCH,
            "hidden": cfg.hidden,
            "blocks": cfg.blocks,
            "emb_half": cfg.emb_half,
            "final_loss": float(np.mean(losses[-50:])),
            "probe": probe,
        }
        print(f"[{name}] exported {gdw_file} (final loss {manifest['models'][name]['final_loss']:.4f})")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {out_dir}/manifest.json with {len(manifest['models'])} models")
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../rust/tests/fixtures/learned")
    ap.add_argument("--steps", type=int, default=int(os.environ.get("FIXTURE_STEPS", "200")))
    args = ap.parse_args()
    export_fixture(args.out_dir, steps=args.steps)


if __name__ == "__main__":
    main()
