"""L1 Pallas kernel: fused tiled matmul + bias + SiLU.

The score network's hot spot is a chain of dense layers; on TPU the right
shape is an MXU-tiled matmul whose epilogue fuses the bias add and the
SiLU activation so the activation tensor never round-trips to HBM
(DESIGN.md §4 — this is the TPU rethink of the paper's cuBLAS+pointwise
GPU chain).

BlockSpec schedule: grid over (M/bm, N/bn, K/bk); A tiles (bm×bk) and
B tiles (bk×bn) stream HBM→VMEM; the output block's index map ignores the
K axis, so Pallas keeps it resident in VMEM across the K-reduction
(accumulator), and the epilogue fuses bias + SiLU on the last K step.

Everything is lowered with ``interpret=True``: the CPU PJRT client cannot
execute Mosaic custom-calls; real-TPU numbers are *estimated* from the
block shapes in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-friendly defaults (128×128 systolic array; fp32 here).
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _kernel(a_ref, b_ref, bias_ref, o_ref, *, n_k: int, activation: str):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(a_ref[...], b_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == n_k - 1)
    def _epilogue():
        out = o_ref[...] + bias_ref[...]
        if activation == "silu":
            out = out * jax.nn.sigmoid(out)
        elif activation == "tanh":
            out = jnp.tanh(out)
        o_ref[...] = out


def _tile(x: int, cap: int) -> int:
    """Smallest power-of-two ≥ min(x, cap), at least 8."""
    t = 8
    while t < x and t < cap:
        t *= 2
    return min(t, cap)


def _pad_to(x: int, b: int) -> int:
    return ((x + b - 1) // b) * b


def fused_linear(x, w, b, activation: str = "silu", bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK):
    """y = act(x @ w + b) with an MXU-tiled Pallas kernel.

    x: (M, K), w: (K, N), b: (N,). Shapes need not be tile multiples;
    inputs are zero-padded up and the result sliced back.

    Differentiable: the forward pass is the Pallas kernel; the backward
    pass is an analytic jnp VJP (`pl.program_id` has no JVP rule, and on
    TPU one would hand-write the backward kernels anyway).
    """
    return _fused_linear_vjp(x, w, b, activation, bm, bn, bk)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _fused_linear_vjp(x, w, b, activation, bm, bn, bk):
    return _forward_pallas(x, w, b, activation, bm, bn, bk)


def _fused_linear_fwd(x, w, b, activation, bm, bn, bk):
    return _forward_pallas(x, w, b, activation, bm, bn, bk), (x, w, b)


def _fused_linear_bwd(activation, bm, bn, bk, res, g):
    x, w, b = res
    z = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if activation == "silu":
        sig = jax.nn.sigmoid(z)
        dz = g * sig * (1.0 + z * (1.0 - sig))
    elif activation == "tanh":
        dz = g * (1.0 - jnp.tanh(z) ** 2)
    else:
        dz = g
    return dz @ w.T, x.T @ dz, dz.sum(axis=0)


_fused_linear_vjp.defvjp(_fused_linear_fwd, _fused_linear_bwd)


def _forward_pallas(x, w, b, activation, bm, bn, bk):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims {k} vs {k2}"
    assert b.shape == (n,)

    bm_, bn_, bk_ = _tile(m, bm), _tile(n, bn), _tile(k, bk)
    mp, np_, kp = _pad_to(m, bm_), _pad_to(n, bn_), _pad_to(k, bk_)
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w.astype(jnp.float32), ((0, kp - k), (0, np_ - n)))
    bp = jnp.pad(b.astype(jnp.float32), (0, np_ - n))[None, :]

    n_k = kp // bk_
    grid = (mp // bm_, np_ // bn_, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn_), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, wp, bp)
    return out[:m, :n]


def vmem_bytes(bm=DEFAULT_BM, bn=DEFAULT_BN, bk=DEFAULT_BK, dtype_bytes=4) -> int:
    """VMEM working-set estimate for one grid step (DESIGN.md §Perf):
    A tile + B tile + out tile + bias row."""
    return dtype_bytes * (bm * bk + bk * bn + bm * bn + bn)
