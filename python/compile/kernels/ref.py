"""Pure-jnp oracles for every Pallas kernel — the CORE correctness signal
(pytest asserts kernel == ref across a shape/activation sweep)."""

import jax
import jax.numpy as jnp

from .time_embed import frequencies


def fused_linear_ref(x, w, b, activation: str = "silu"):
    y = x.astype(jnp.float32) @ w.astype(jnp.float32) + b.astype(jnp.float32)
    if activation == "silu":
        return y * jax.nn.sigmoid(y)
    if activation == "tanh":
        return jnp.tanh(y)
    return y


def time_embed_ref(t, half: int = 16):
    f = frequencies(half)[None, :]
    phase = t.astype(jnp.float32)[:, None] * f
    return jnp.concatenate([jnp.sin(phase), jnp.cos(phase)], axis=-1)


def scale_shift_ref(h, scale, shift):
    return h.astype(jnp.float32) * (1.0 + scale.astype(jnp.float32)) + shift.astype(jnp.float32)
