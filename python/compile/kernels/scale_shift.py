"""L1 Pallas kernel: fused FiLM modulation `h·(1+scale) + shift`.

Used for the time-conditioning of every residual block. One fused
elementwise VMEM pass instead of three HBM round-trips.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(h_ref, s_ref, b_ref, o_ref):
    o_ref[...] = h_ref[...] * (1.0 + s_ref[...]) + b_ref[...]


def scale_shift(h, scale, shift):
    """h: (B, C); scale, shift: (B, C) → h·(1+scale)+shift.

    Pallas forward, analytic VJP (interpret-mode pallas_call has no
    reverse-mode rule)."""
    assert h.shape == scale.shape == shift.shape
    return _scale_shift_vjp(h, scale, shift)


@jax.custom_vjp
def _scale_shift_vjp(h, scale, shift):
    return pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct(h.shape, jnp.float32),
        interpret=True,
    )(h.astype(jnp.float32), scale.astype(jnp.float32), shift.astype(jnp.float32))


def _fwd(h, scale, shift):
    return _scale_shift_vjp(h, scale, shift), (h, scale)


def _bwd(res, g):
    h, scale = res
    return g * (1.0 + scale), g * h, g


_scale_shift_vjp.defvjp(_fwd, _bwd)
