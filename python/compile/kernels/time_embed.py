"""L1 Pallas kernel: Fourier time embedding.

`emb(t) = [sin(t·f₀…), cos(t·f₀…)]` with log-spaced frequencies — the
standard diffusion time-conditioning features, fused into one elementwise
VMEM pass (the tensor is tiny; the point is that it lowers into the same
HLO module as the rest of the network).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def frequencies(half: int, max_period: float = 100.0):
    """Log-spaced angular frequencies, shape (half,)."""
    exps = jnp.arange(half, dtype=jnp.float32) / max(half - 1, 1)
    return (2.0 * jnp.pi) / (max_period ** exps)


def _kernel(t_ref, f_ref, o_ref, *, half: int):
    t = t_ref[...]  # (B, 1)
    f = f_ref[...]  # (1, half)
    phase = t * f
    o_ref[...] = jnp.concatenate([jnp.sin(phase), jnp.cos(phase)], axis=-1)


@functools.partial(jax.jit, static_argnames=("half",))
def time_embed(t, half: int = 16):
    """t: (B,) → (B, 2·half) Fourier features."""
    b = t.shape[0]
    f = frequencies(half)[None, :]
    return pl.pallas_call(
        functools.partial(_kernel, half=half),
        out_shape=jax.ShapeDtypeStruct((b, 2 * half), jnp.float32),
        interpret=True,
    )(t.astype(jnp.float32)[:, None], f)
