"""Forward-process kernels for training (build-time only).

Each process provides `perturb(x0, t, key, kt)` → `(u_t, eps)` with
`u_t = Ψ(t,0)·lift(x0) + K_t ε`, matching the rust Stage-I definitions:

* VPSDE: closed form (same β₀/β₁/T as `rust/src/diffusion/vpsde.rs`).
* CLD: Ψ/Σ/R/L read from `configs/cld_tables.json` (exported by
  `gddim gen-configs` — the rust coefficient engine is the single source
  of truth; python only interpolates).
* BDM: closed-form cosine + blur schedule (same formulas as
  `rust/src/diffusion/bdm.rs`).
"""

import json
import os

import numpy as np

CONFIG_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "configs")


# ---------------------------------------------------------------- VPSDE
class Vpsde:
    name = "vpsde"

    def __init__(self, d, beta0=0.1, beta1=20.0, t_max=1.0, t_min=1e-3):
        self.d = d
        self.dim_u = d
        self.beta0, self.beta1 = beta0, beta1
        self.t_max, self.t_min = t_max, t_min

    def alpha(self, t):
        return np.exp(-(self.beta0 * t + 0.5 * (self.beta1 - self.beta0) * t * t))

    def perturb(self, x0, t, rng, kt="R"):
        # K_t = sqrt(1-α) I for every kind (isotropic).
        a = self.alpha(t)[:, None]
        eps = rng.standard_normal(x0.shape).astype(np.float32)
        u_t = np.sqrt(a) * x0 + np.sqrt(1.0 - a) * eps
        return u_t.astype(np.float32), eps


# ------------------------------------------------------------------ CLD
class Cld:
    name = "cld"

    def __init__(self, d):
        self.d = d
        self.dim_u = 2 * d
        path = os.path.join(CONFIG_DIR, "cld_tables.json")
        with open(path) as f:
            tab = json.load(f)
        rows = np.asarray(tab["rows"], dtype=np.float64)
        self.ts = rows[:, 0]
        self.psi = rows[:, 1:5]      # (a,b,c,d)
        self.sigma = rows[:, 5:8]    # (xx,xv,vv)
        self.r = rows[:, 8:12]       # (a,b,c,d)
        self.l = rows[:, 12:15]      # (l11,l21,l22)
        self.gamma0 = tab["gamma0"]
        self.mass = tab["mass"]
        self.t_max, self.t_min = float(self.ts[-1]), 1e-3

    def _interp(self, table, t):
        out = np.empty((len(t), table.shape[1]))
        for j in range(table.shape[1]):
            out[:, j] = np.interp(t, self.ts, table[:, j])
        return out

    def perturb(self, x0, t, rng, kt="R"):
        b, d = x0.shape
        psi = self._interp(self.psi, t)  # (B,4)
        # mean = Ψ(t,0) [x0; 0] → x-channel a·x0, v-channel c·x0
        mean_x = psi[:, 0:1] * x0
        mean_v = psi[:, 2:3] * x0
        if kt == "R":
            k = self._interp(self.r, t)  # (a,b,c,d)
            ka, kb, kc, kd = k[:, 0:1], k[:, 1:2], k[:, 2:3], k[:, 3:4]
        else:  # L (lower triangular)
            k = self._interp(self.l, t)
            ka, kb, kc, kd = k[:, 0:1], np.zeros((b, 1)), k[:, 1:2], k[:, 2:3]
        ex = rng.standard_normal((b, d)).astype(np.float32)
        ev = rng.standard_normal((b, d)).astype(np.float32)
        u_x = mean_x + ka * ex + kb * ev
        u_v = mean_v + kc * ex + kd * ev
        u = np.concatenate([u_x, u_v], axis=1).astype(np.float32)
        eps = np.concatenate([ex, ev], axis=1)
        return u, eps


# ------------------------------------------------------------------ BDM
class Bdm:
    name = "bdm"

    def __init__(self, h, w, tau_max=0.5, cosine_s=0.008, t_max=1.0, t_min=1e-3):
        self.h, self.w = h, w
        self.d = h * w
        self.dim_u = h * w
        self.tau_max, self.cosine_s = tau_max, cosine_s
        self.t_max, self.t_min = t_max, t_min
        # Orthonormal DCT-II matrices (same as rust/src/math/dct.rs).
        self.ch = _dct_matrix(h)
        self.cw = _dct_matrix(w)
        fh = (np.pi * np.arange(h) / h) ** 2
        fw = (np.pi * np.arange(w) / w) ** 2
        self.lam = (fh[:, None] + fw[None, :]).reshape(-1)

    def _theta(self, t):
        s = self.cosine_s
        raw = 0.5 * np.pi * (t / self.t_max + s) / (1.0 + s)
        return np.minimum(raw, 0.5 * np.pi - 1e-2)

    def alphabar(self, t):
        th0 = self._theta(np.zeros_like(t))
        return (np.cos(self._theta(t)) / np.cos(th0)) ** 2

    def tau(self, t):
        return self.tau_max * np.sin(0.5 * np.pi * t / self.t_max) ** 2

    def to_freq(self, x):
        img = x.reshape(-1, self.h, self.w)
        return np.einsum("ij,bjk,lk->bil", self.ch, img, self.cw).reshape(-1, self.d)

    def perturb(self, x0, t, rng, kt="R"):
        # State = DCT spectrum; α_{t,k} = √ᾱ·exp(−λ_k τ), σ² = 1−ᾱ.
        y0 = self.to_freq(x0)
        ab = self.alphabar(t)[:, None]
        tau = self.tau(t)[:, None]
        alpha = np.sqrt(ab) * np.exp(-self.lam[None, :] * tau)
        eps = rng.standard_normal(y0.shape).astype(np.float32)
        u_t = alpha * y0 + np.sqrt(1.0 - ab) * eps
        return u_t.astype(np.float32), eps


def _dct_matrix(n):
    c = np.zeros((n, n))
    for k in range(n):
        s = np.sqrt(1.0 / n) if k == 0 else np.sqrt(2.0 / n)
        c[k] = s * np.cos(np.pi * (np.arange(n) + 0.5) * k / n)
    return c


# -------------------------------------------------------------- Dataset
class GmmData:
    """Sampler for the shared `configs/datasets.json` specs."""

    def __init__(self, name):
        path = os.path.join(CONFIG_DIR, "datasets.json")
        with open(path) as f:
            specs = json.load(f)
        spec = specs[name]
        self.name = name
        self.means = np.asarray(spec["means"], dtype=np.float32)
        self.weights = np.asarray(spec["weights"], dtype=np.float64)
        self.var = float(spec["var"])
        self.d = self.means.shape[1]

    def sample(self, n, rng):
        idx = rng.choice(len(self.means), size=n, p=self.weights / self.weights.sum())
        x = self.means[idx] + np.sqrt(self.var) * rng.standard_normal(
            (n, self.d)
        ).astype(np.float32)
        return x.astype(np.float32)


def build_process(name, d):
    if name == "vpsde":
        return Vpsde(d)
    if name == "cld":
        return Cld(d)
    if name == "bdm":
        side = int(round(d ** 0.5))
        assert side * side == d
        return Bdm(side, side)
    raise ValueError(name)
