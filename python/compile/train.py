"""Build-time training of the score networks (DSM/HSM, paper Eq. 5/77).

Small MLPs on synthetic mixtures — minutes on CPU. Python never runs at
request time; `aot.py` calls `train_model` once per exported variant and
caches parameters under `artifacts/params_<name>.npz`.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from .model import ScoreNetConfig, dsm_loss, init_params, score_eps
from .processes import GmmData, build_process


def adam_update(params, grads, m, v, step, lr, b1=0.9, b2=0.999, eps=1e-8):
    new_params, new_m, new_v = {}, {}, {}
    for k in params:
        new_m[k] = b1 * m[k] + (1 - b1) * grads[k]
        new_v[k] = b2 * v[k] + (1 - b2) * grads[k] ** 2
        mhat = new_m[k] / (1 - b1 ** step)
        vhat = new_v[k] / (1 - b2 ** step)
        new_params[k] = params[k] - lr * mhat / (jnp.sqrt(vhat) + eps)
    return new_params, new_m, new_v


def train_model(
    process_name: str,
    dataset_name: str,
    kt: str = "R",
    hidden: int = 128,
    blocks: int = 3,
    emb_half: int = 16,
    steps: int = 2000,
    batch: int = 512,
    lr: float = 2e-3,
    seed: int = 0,
    log_every: int = 500,
):
    """Train ε_θ for (process, dataset, K_t); returns (params, cfg, losses)."""
    data = GmmData(dataset_name)
    proc = build_process(process_name, data.d)
    cfg = ScoreNetConfig(dim=proc.dim_u, hidden=hidden, blocks=blocks, emb_half=emb_half)
    key = jax.random.PRNGKey(seed)
    params = init_params(key, cfg)
    m = {k: jnp.zeros_like(p) for k, p in params.items()}
    v = {k: jnp.zeros_like(p) for k, p in params.items()}
    rng = np.random.default_rng(seed + 1)

    loss_grad = jax.jit(jax.value_and_grad(functools.partial(dsm_loss, cfg=cfg)))
    losses = []
    for step in range(1, steps + 1):
        x0 = data.sample(batch, rng)
        t = rng.uniform(proc.t_min, proc.t_max, size=batch).astype(np.float32)
        u_t, eps = proc.perturb(x0, t, rng, kt=kt)
        loss, grads = loss_grad(params, batch=(jnp.asarray(u_t), jnp.asarray(t), jnp.asarray(eps)))
        # Cosine LR decay.
        cur_lr = lr * 0.5 * (1.0 + np.cos(np.pi * step / steps))
        params, m, v = adam_update(params, grads, m, v, step, cur_lr)
        losses.append(float(loss))
        if log_every and step % log_every == 0:
            print(f"  [{process_name}/{dataset_name}/K={kt}] step {step}/{steps} loss {loss:.4f}")
    return params, cfg, losses


def eval_eps(params, cfg, u, t):
    """Convenience wrapper used by aot.py's probe recording."""
    return np.asarray(score_eps(params, cfg, jnp.asarray(u), jnp.asarray(t)))
