"""Deterministic weight export (`.gdw`) + the float64 reference forward.

The `.gdw` file is the serving contract between this training layer and
the pure-Rust ``score::net::ScoreNet``: one line of compact JSON header
followed by raw little-endian float32 tensor data, concatenated in
header order (row-major / C layout, weight matrices stored
``(fan_in, fan_out)`` exactly as trained). The header pins everything
the loader needs to validate the blob without trusting its length::

    {"magic":"gddim-weights","version":1,"dtype":"f32","order":"row-major",
     "dim":2,"hidden":16,"blocks":1,"emb_half":8,
     "tensors":[{"name":"emb0_w","shape":[16,16]}, ...]}\n
    <raw f32 bytes>

Tensor order is the fixed canonical sequence of :func:`tensor_names` —
byte output is a pure function of the parameters, so re-exporting
unchanged weights produces an identical file (no timestamps, no dict
ordering hazards).

:func:`score_eps_f64` replays :func:`compile.model.score_eps` in float64
from the *stored f32* weights, mirroring the Rust forward's op order.
Manifest probes record its output: the Rust loader reproduces it to
~1e-12 (same ops, same promotion), so the probe-parity gate can be a
strict 1e-6 while jax's float32 forward is only asserted to ~2e-4 of it
(float32 rounding, checked at export time).
"""

import json

import numpy as np

GDW_MAGIC = "gddim-weights"
GDW_VERSION = 1


def tensor_names(blocks: int):
    """Canonical tensor order: embed MLP, stem, FiLM+residual per block
    (ascending), head — `_w` then `_b` for each layer."""
    layers = ["emb0", "emb1", "stem"]
    for i in range(blocks):
        layers += [f"film{i}", f"block{i}"]
    layers.append("head")
    names = []
    for layer in layers:
        names += [f"{layer}_w", f"{layer}_b"]
    return names


def write_gdw(path, params, cfg):
    """Write `params` (a name → array dict from :func:`compile.model.init_params`)
    for `cfg` (a ``ScoreNetConfig``) as a `.gdw` file."""
    tensors = []
    blobs = []
    for name in tensor_names(cfg.blocks):
        arr = np.ascontiguousarray(np.asarray(params[name], dtype=np.float32))
        tensors.append({"name": name, "shape": [int(s) for s in arr.shape]})
        blobs.append(arr.tobytes())
    header = {
        "magic": GDW_MAGIC,
        "version": GDW_VERSION,
        "dtype": "f32",
        "order": "row-major",
        "dim": int(cfg.dim),
        "hidden": int(cfg.hidden),
        "blocks": int(cfg.blocks),
        "emb_half": int(cfg.emb_half),
        "tensors": tensors,
    }
    with open(path, "wb") as f:
        f.write(json.dumps(header, sort_keys=True, separators=(",", ":")).encode("utf-8"))
        f.write(b"\n")
        for blob in blobs:
            f.write(blob)


def read_gdw(path):
    """Read a `.gdw` file back → (header dict, name → f32 array dict).
    The inverse of :func:`write_gdw`; pytest round-trips through it."""
    with open(path, "rb") as f:
        raw = f.read()
    nl = raw.index(b"\n")
    header = json.loads(raw[:nl].decode("utf-8"))
    assert header["magic"] == GDW_MAGIC and header["version"] == GDW_VERSION
    assert header["dtype"] == "f32" and header["order"] == "row-major"
    tensors = {}
    off = nl + 1
    for spec in header["tensors"]:
        count = int(np.prod(spec["shape"], dtype=np.int64)) if spec["shape"] else 1
        end = off + 4 * count
        tensors[spec["name"]] = np.frombuffer(raw[off:end], dtype="<f4").reshape(spec["shape"])
        off = end
    assert off == len(raw), "trailing bytes after the last declared tensor"
    return header, tensors


def _silu(y):
    return y * (1.0 / (1.0 + np.exp(-y)))


def score_eps_f64(params, cfg, u, t):
    """Float64 replay of ``score_eps`` from the stored-f32 weights.

    Op order mirrors the Rust ``ScoreNet`` forward: the time embedding
    and the per-block FiLM (scale, shift) pair are computed once (they
    depend only on `t`), then every row runs stem → blocks → head
    independently. `u` is (B, D) float64, `t` a python float; returns
    (B, D) float64.
    """
    p = {k: np.asarray(v, dtype=np.float32).astype(np.float64) for k, v in params.items()}
    u = np.atleast_2d(np.asarray(u, dtype=np.float64))
    t = float(t)

    half = cfg.emb_half
    exps = np.arange(half, dtype=np.float64) / max(half - 1, 1)
    freqs = (2.0 * np.pi) / (100.0 ** exps)
    phase = t * freqs
    emb = np.concatenate([np.sin(phase), np.cos(phase)])
    emb = _silu(emb @ p["emb0_w"] + p["emb0_b"])
    emb = _silu(emb @ p["emb1_w"] + p["emb1_b"])

    films = []
    for i in range(cfg.blocks):
        ss = emb @ p[f"film{i}_w"] + p[f"film{i}_b"]
        films.append((ss[: cfg.hidden], ss[cfg.hidden :]))

    out = np.empty_like(u)
    for r in range(u.shape[0]):
        h = _silu(u[r] @ p["stem_w"] + p["stem_b"])
        for i, (scale, shift) in enumerate(films):
            g = h * (1.0 + scale) + shift
            h = h + _silu(g @ p[f"block{i}_w"] + p[f"block{i}_b"])
        out[r] = h @ p["head_w"] + p["head_b"]
    return out


def probe_block(params, cfg, batch, seed=1234, t=0.5):
    """The manifest's frozen probe: `batch` standard-normal rows from
    ``default_rng(seed)`` at time `t`, with row 0's input and float64
    reference output recorded."""
    rng = np.random.default_rng(seed)
    u_probe = rng.standard_normal((batch, cfg.dim)).astype(np.float32)
    eps_ref = score_eps_f64(params, cfg, u_probe.astype(np.float64), t)
    probe = {
        "t": float(t),
        "u_row0": [float(x) for x in u_probe[0]],
        "eps_row0": [float(x) for x in eps_ref[0]],
        "seed": int(seed),
    }
    return probe, u_probe, eps_ref
