"""Test-session guards for offline / JAX-less runners.

The kernel, model, and train/AOT suites all import JAX at module scope;
on a runner without JAX (or with a broken CUDA/Pallas install) that is a
collection *error*, not a skip. Ignore those files up front so CI reports
a green "skipped" python job instead of a red import crash, and force the
CPU platform so Pallas kernels run in interpret mode everywhere.
"""

import importlib.util
import os

# Deterministic, device-free CI: run JAX on CPU (Pallas falls back to
# interpret mode there) unless the caller explicitly overrides it.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

collect_ignore_glob = []
if importlib.util.find_spec("jax") is None:
    collect_ignore_glob = ["test_*.py"]
