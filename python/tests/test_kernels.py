"""Kernel-vs-reference sweeps — the core L1 correctness signal."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels.fused_linear import fused_linear, vmem_bytes
from compile.kernels.ref import fused_linear_ref, scale_shift_ref, time_embed_ref
from compile.kernels.scale_shift import scale_shift
from compile.kernels.time_embed import time_embed

RNG = np.random.default_rng(0)


def rand(*shape):
    return RNG.standard_normal(shape).astype(np.float32)


# hypothesis-style sweep (the hypothesis package is not installed; the
# grid covers the same boundary cases: non-tile-multiples, tiny dims,
# tall/wide, every activation).
SHAPES = [
    (1, 1, 1),
    (2, 3, 5),
    (8, 8, 8),
    (16, 32, 8),
    (7, 130, 33),
    (256, 64, 128),
    (130, 20, 257),
]


@pytest.mark.parametrize("m,k,n", SHAPES)
@pytest.mark.parametrize("act", ["silu", "none", "tanh"])
def test_fused_linear_matches_ref(m, k, n, act):
    x, w, b = rand(m, k), rand(k, n), rand(n)
    got = np.asarray(fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), activation=act))
    want = np.asarray(fused_linear_ref(x, w, b, activation=act))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_fused_linear_large_k_accumulation():
    # K spans many tiles: the in-VMEM accumulator must not lose terms.
    x, w, b = rand(4, 1024), rand(1024, 8), rand(8)
    got = np.asarray(fused_linear(jnp.asarray(x), jnp.asarray(w), jnp.asarray(b), activation="none"))
    want = x @ w + b
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("b,half", [(1, 4), (7, 16), (64, 16), (256, 32)])
def test_time_embed_matches_ref(b, half):
    t = RNG.uniform(0.0, 1.0, size=b).astype(np.float32)
    got = np.asarray(time_embed(jnp.asarray(t), half=half))
    want = np.asarray(time_embed_ref(t, half=half))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert got.shape == (b, 2 * half)


def test_time_embed_distinguishes_times():
    t = np.asarray([0.0, 0.5, 1.0], dtype=np.float32)
    e = np.asarray(time_embed(jnp.asarray(t)))
    assert np.linalg.norm(e[0] - e[1]) > 0.1
    assert np.linalg.norm(e[1] - e[2]) > 0.1


@pytest.mark.parametrize("shape", [(1, 1), (5, 7), (64, 128)])
def test_scale_shift_matches_ref(shape):
    h, s, b = rand(*shape), rand(*shape), rand(*shape)
    got = np.asarray(scale_shift(jnp.asarray(h), jnp.asarray(s), jnp.asarray(b)))
    want = np.asarray(scale_shift_ref(h, s, b))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)


def test_vmem_estimate_fits_tpu_budget():
    # DESIGN.md §Perf: default tiles must fit a ~16 MiB VMEM comfortably.
    assert vmem_bytes() < 4 * 1024 * 1024
