"""Score-network shape/behaviour tests (L2)."""

import jax
import jax.numpy as jnp
import numpy as np

from compile.model import ScoreNetConfig, dsm_loss, init_params, score_eps


def make(dim=4, hidden=32, blocks=2):
    cfg = ScoreNetConfig(dim=dim, hidden=hidden, blocks=blocks)
    params = init_params(jax.random.PRNGKey(0), cfg)
    return params, cfg


def test_output_shape():
    params, cfg = make(dim=4)
    u = jnp.zeros((8, 4))
    out = score_eps(params, cfg, u, jnp.float32(0.3))
    assert out.shape == (8, 4)


def test_deterministic():
    params, cfg = make()
    u = jax.random.normal(jax.random.PRNGKey(1), (5, 4))
    a = score_eps(params, cfg, u, jnp.float32(0.7))
    b = score_eps(params, cfg, u, jnp.float32(0.7))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_time_conditioning_matters():
    params, cfg = make()
    # Need a trained-ish net? No: FiLM layers are randomly initialized, so
    # different t must change the output through the embedding path.
    u = jax.random.normal(jax.random.PRNGKey(2), (5, 4))
    a = np.asarray(score_eps(params, cfg, u, jnp.float32(0.1)))
    b = np.asarray(score_eps(params, cfg, u, jnp.float32(0.9)))
    assert np.abs(a - b).max() > 1e-7


def test_head_starts_near_zero():
    params, cfg = make()
    u = 3.0 * jax.random.normal(jax.random.PRNGKey(3), (16, 4))
    out = np.asarray(score_eps(params, cfg, u, jnp.float32(0.5)))
    assert np.abs(out).max() < 0.5, "near-zero init head"


def test_loss_differentiable_and_finite():
    params, cfg = make()
    u = jax.random.normal(jax.random.PRNGKey(4), (8, 4))
    t = jnp.full((8,), 0.4)
    eps = jax.random.normal(jax.random.PRNGKey(5), (8, 4))
    loss, grads = jax.value_and_grad(lambda p: dsm_loss(p, cfg, (u, t, eps)))(params)
    assert np.isfinite(float(loss))
    assert all(np.isfinite(np.asarray(g)).all() for g in grads.values())
    assert any(np.abs(np.asarray(g)).max() > 0 for g in grads.values())
