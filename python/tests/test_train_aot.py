"""Training + AOT export smoke tests (fast settings)."""

import os
import subprocess
import sys

import numpy as np
import pytest

CONFIGS = os.path.join(os.path.dirname(__file__), "..", "..", "configs")

needs_configs = pytest.mark.skipif(
    not os.path.exists(os.path.join(CONFIGS, "datasets.json")),
    reason="run `gddim gen-configs` first",
)


@needs_configs
def test_short_training_reduces_loss():
    from compile.train import train_model

    _params, _cfg, losses = train_model(
        "vpsde", "gmm2d", steps=150, batch=256, hidden=64, blocks=2, log_every=0
    )
    first = np.mean(losses[:20])
    last = np.mean(losses[-20:])
    assert last < 0.8 * first, f"loss did not drop: {first} -> {last}"


@needs_configs
def test_cld_tables_consistent():
    # R Rᵀ must equal Σ in the exported tables (rust guarantees it by
    # construction; this guards the JSON plumbing).
    from compile.processes import Cld

    p = Cld(2)
    for i in [0, 500, 1000, 2000]:
        r = p.r[i]
        rrt = np.array(
            [
                r[0] * r[0] + r[1] * r[1],
                r[0] * r[2] + r[1] * r[3],
                r[2] * r[2] + r[3] * r[3],
            ]
        )
        np.testing.assert_allclose(rrt, p.sigma[i], rtol=1e-6, atol=1e-9)


@needs_configs
def test_perturb_statistics_vpsde():
    # E[u_t] = √α x0, Var = 1−α.
    from compile.processes import Vpsde

    p = Vpsde(1)
    rng = np.random.default_rng(0)
    x0 = np.full((20000, 1), 2.0, dtype=np.float32)
    t = np.full(20000, 0.5, dtype=np.float32)
    u, _eps = p.perturb(x0, t, rng)
    a = p.alpha(np.array([0.5]))[0]
    assert abs(u.mean() - np.sqrt(a) * 2.0) < 0.02
    assert abs(u.var() - (1 - a)) < 0.02


@needs_configs
def test_perturb_statistics_cld_matches_sigma():
    from compile.processes import Cld

    p = Cld(1)
    rng = np.random.default_rng(1)
    x0 = np.zeros((40000, 1), dtype=np.float32)
    t = np.full(40000, 0.3, dtype=np.float32)
    for kt in ["R", "L"]:
        u, _ = p.perturb(x0, t, rng, kt=kt)
        sig = p._interp(p.sigma, np.array([0.3]))[0]
        cov_xx = np.var(u[:, 0])
        cov_vv = np.var(u[:, 1])
        cov_xv = np.mean(u[:, 0] * u[:, 1])
        assert abs(cov_xx - sig[0]) < 0.01, kt
        assert abs(cov_xv - sig[1]) < 0.01, kt
        assert abs(cov_vv - sig[2]) < 0.01, kt


@needs_configs
def test_aot_exports_loadable_hlo(tmp_path):
    # Fast end-to-end: train tiny, export, re-parse HLO text with jax's
    # own XlaComputation parser (round-trip sanity).
    env = dict(os.environ, AOT_STEPS="30")
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out-dir",
            str(tmp_path),
            "--only",
            "vpsde_gmm2d",
            "--steps",
            "30",
        ],
        cwd=os.path.join(os.path.dirname(__file__), ".."),
        env=env,
        check=True,
    )
    hlo = (tmp_path / "vpsde_gmm2d.hlo.txt").read_text()
    assert "HloModule" in hlo
    manifest = (tmp_path / "manifest.json").read_text()
    assert "probe" in manifest
