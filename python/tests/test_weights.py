"""Contract tests for the `.gdw` weight export and the float64 probe
reference (`compile.weights`) — the serving handshake with the rust
``score::net::ScoreNet`` loader."""

import os

import jax
import numpy as np
import pytest

from compile.model import ScoreNetConfig, init_params, score_eps
from compile.weights import probe_block, read_gdw, score_eps_f64, tensor_names, write_gdw


@pytest.fixture(scope="module")
def tiny():
    cfg = ScoreNetConfig(dim=3, hidden=8, blocks=2, emb_half=4)
    params = init_params(jax.random.PRNGKey(7), cfg)
    return params, cfg


def test_tensor_names_are_canonical():
    assert tensor_names(1) == [
        "emb0_w", "emb0_b", "emb1_w", "emb1_b", "stem_w", "stem_b",
        "film0_w", "film0_b", "block0_w", "block0_b", "head_w", "head_b",
    ]
    # Blocks interleave film/block ascending; every layer is _w then _b.
    names = tensor_names(3)
    assert names.index("film2_w") < names.index("block2_w") < names.index("head_w")


def test_gdw_round_trip_is_exact_and_deterministic(tiny, tmp_path):
    params, cfg = tiny
    p1, p2 = tmp_path / "a.gdw", tmp_path / "b.gdw"
    write_gdw(p1, params, cfg)
    write_gdw(p2, params, cfg)
    assert p1.read_bytes() == p2.read_bytes(), "export must be byte-deterministic"
    header, tensors = read_gdw(p1)
    assert header["dim"] == cfg.dim and header["blocks"] == cfg.blocks
    assert [t["name"] for t in header["tensors"]] == tensor_names(cfg.blocks)
    for name in tensor_names(cfg.blocks):
        np.testing.assert_array_equal(tensors[name], np.asarray(params[name], dtype=np.float32))


def test_f64_reference_matches_jax_forward(tiny):
    params, cfg = tiny
    rng = np.random.default_rng(3)
    u = rng.standard_normal((5, cfg.dim)).astype(np.float32)
    ref = score_eps_f64(params, cfg, u.astype(np.float64), 0.37)
    via_jax = np.asarray(score_eps(params, cfg, u, np.float32(0.37), impl="ref"))
    np.testing.assert_allclose(via_jax, ref, rtol=2e-4, atol=2e-4)


def test_probe_block_is_reproducible(tiny):
    params, cfg = tiny
    probe1, u1, eps1 = probe_block(params, cfg, 16)
    probe2, _, _ = probe_block(params, cfg, 16)
    assert probe1 == probe2
    assert u1.shape == (16, cfg.dim)
    np.testing.assert_array_equal(np.asarray(probe1["eps_row0"]), eps1[0])


def test_fixture_probe_replays_from_committed_gdw():
    """The committed fixture's probe must be regenerable from its own
    .gdw bytes — exactly what the rust loader does at registry load."""
    import json

    fix = os.path.join(os.path.dirname(__file__), "..", "..", "rust", "tests", "fixtures", "learned")
    if not os.path.exists(os.path.join(fix, "manifest.json")):
        pytest.skip("committed fixture not present")
    with open(os.path.join(fix, "manifest.json")) as f:
        manifest = json.load(f)
    for name, entry in manifest["models"].items():
        header, tensors = read_gdw(os.path.join(fix, entry["weights"]))
        cfg = ScoreNetConfig(
            dim=header["dim"], hidden=header["hidden"],
            blocks=header["blocks"], emb_half=header["emb_half"],
        )
        probe = entry["probe"]
        u = np.asarray(probe["u_row0"], dtype=np.float64)[None, :]
        eps = score_eps_f64(tensors, cfg, u, probe["t"])
        np.testing.assert_allclose(
            eps[0], np.asarray(probe["eps_row0"]), rtol=1e-12, atol=1e-12, err_msg=name
        )
