//! `cargo bench --bench fig4` — regenerates the paper's fig4 (see
//! DESIGN.md §5 and EXPERIMENTS.md). Pass --full for paper-scale sample
//! counts; the default uses --fast sizes so the whole battery runs in CI
//! time. Full-scale runs: `gddim exp fig4`.
use gddim::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !args.has("full") {
        args.flags.insert("fast".into(), "true".into());
    }
    gddim::exp::run("fig4", &args);
}
