//! `cargo bench --bench fig5` — regenerates the paper's fig5 (see
//! DESIGN.md §5 and EXPERIMENTS.md). Pass --full for paper-scale sample
//! counts; the default uses --fast sizes so the whole battery runs in CI
//! time. Full-scale runs: `gddim exp fig5`.
use gddim::util::cli::Args;

fn main() {
    let mut args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    if !args.has("full") {
        args.flags.insert("fast".into(), "true".into());
    }
    gddim::exp::run("fig5", &args);
}
