//! `cargo bench --bench microbench` — hot-path microbenchmarks feeding
//! the §Perf pass: Stage-I plan build time (paper App. C.3: "within
//! 1 min"), per-step sampler cost with score calls excluded (coordinator
//! overhead), oracle score throughput, Fréchet metric cost.

use std::sync::Arc;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Bdm, Cld, Process, TimeGrid, Vpsde};
use gddim::math::rng::Rng;
use gddim::score::model::ScoreModel;
use gddim::score::oracle::GmmOracle;
use gddim::util::bench::{time_until, Table};

fn main() {
    let mut t = Table::new(
        "Microbench (per-iteration wall time)",
        &["what", "mean", "p50", "p99"],
    );

    // Stage-I plan construction (the paper's "within 1 min" budget).
    for (name, proc) in [
        ("plan vpsde N=50 q=3", Arc::new(Vpsde::standard(2)) as Arc<dyn Process>),
        ("plan cld   N=50 q=3", Arc::new(Cld::standard(2))),
        ("plan bdm   N=50 q=3", Arc::new(Bdm::standard(8, 8))),
    ] {
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 50);
        let s = time_until(0.5, 50, || {
            let _ =
                SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(3, KtKind::R));
        });
        t.row(vec![name.into(), fmt(s.mean), fmt(s.p50), fmt(s.p99)]);
    }

    // Stochastic plan (adds the Ψ̂/P ODE solves).
    {
        let proc = Cld::standard(2);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 50);
        let s = time_until(0.5, 20, || {
            let _ = SamplerPlan::build(&proc, &grid, &PlanConfig::stochastic(1.0));
        });
        t.row(vec!["plan cld stochastic λ=1 N=50".into(), fmt(s.mean), fmt(s.p50), fmt(s.p99)]);
    }

    // Oracle score throughput (batch 1024, 8 modes, 2-D CLD).
    {
        let proc = Arc::new(Cld::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(1);
        let us: Vec<f64> = (0..1024 * 4).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; us.len()];
        let s = time_until(0.5, 10_000, || oracle.eps_batch(0.5, &us, &mut out));
        t.row(vec!["oracle eps (1024×4, 8 modes)".into(), fmt(s.mean), fmt(s.p50), fmt(s.p99)]);
    }

    // Coordinator overhead: gDDIM step arithmetic with a free score.
    {
        struct ZeroScore(usize);
        impl ScoreModel for ZeroScore {
            fn dim_u(&self) -> usize {
                self.0
            }
            fn kt_kind(&self) -> KtKind {
                KtKind::R
            }
            fn eps_batch(&self, _t: f64, _us: &[f64], out: &mut [f64]) {
                out.iter_mut().for_each(|x| *x = 0.0);
            }
        }
        let proc = Cld::standard(2);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 50);
        let plan = SamplerPlan::build(&proc, &grid, &PlanConfig::deterministic(3, KtKind::R));
        let model = ZeroScore(4);
        let s = time_until(0.5, 1000, || {
            let mut rng = Rng::seed_from(2);
            let _ = gddim::samplers::gddim::sample_deterministic(
                &proc, &plan, &model, 1024, &mut rng, false,
            );
        });
        t.row(vec![
            "gDDIM 50 steps × 1024 samples (zero score) — L3 overhead".into(),
            fmt(s.mean),
            fmt(s.p50),
            fmt(s.p99),
        ]);
    }

    // Fréchet metric on 64-dim data.
    {
        let spec = presets::blobs8();
        let mut rng = Rng::seed_from(3);
        let xs = spec.sample(2000, &mut rng);
        let s = time_until(0.5, 200, || {
            let _ = gddim::metrics::frechet::frechet_to_spec(&xs, &spec);
        });
        t.row(vec!["frechet (2000×64)".into(), fmt(s.mean), fmt(s.p50), fmt(s.p99)]);
    }

    // Engine scaling: the same sharded job with 1 vs 4 workers.
    {
        use gddim::engine::{Engine, Job};
        use gddim::samplers::GddimDet;
        let proc = Arc::new(Cld::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 20);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let sampler = GddimDet { plan: &plan };
        for workers in [1usize, 4] {
            let engine = Engine::new(workers);
            let s = time_until(0.5, 50, || {
                let _ = engine.run(&Job {
                    proc: proc.as_ref(),
                    model: &oracle,
                    sampler: &sampler,
                    n: 4096,
                    seed: 5,
                });
            });
            t.row(vec![
                format!("engine gDDIM 20×4096, {workers} worker(s)"),
                fmt(s.mean),
                fmt(s.p50),
                fmt(s.p99),
            ]);
        }
    }

    t.emit("microbench");
}

fn fmt(secs: f64) -> String {
    if secs < 1e-3 {
        format!("{:.1}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{secs:.3}s")
    }
}
