//! `cargo bench --bench serving` — batched sampling service throughput &
//! latency under a Poisson workload (the L3 deliverable's headline bench).
//! Reports batch occupancy, samples/s, and latency percentiles at several
//! arrival rates, plus a batching on/off comparison.

use std::sync::Arc;
use std::time::Duration;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Cld, Process, TimeGrid};
use gddim::engine::{Engine, EngineConfig, Job};
use gddim::samplers::GddimDet;
use gddim::score::oracle::GmmOracle;
use gddim::server::batcher::BatcherConfig;
use gddim::server::request::{GenRequest, PlanKey};
use gddim::server::router::{learned_factory, oracle_factory, Router, RouterConfig};
use gddim::util::bench::Table;
use gddim::util::cli::Args;
use gddim::server::net::NetConfig;
use gddim::workload::bench_report::{BenchReport, BenchScenario};
use gddim::workload::{
    engine_throughput, max_rate_under_slo, open_loop_probe, open_loop_probe_with,
    open_loop_tcp_probe, ClosedLoop, WorkloadSpec,
};

/// `GDDIM_BENCH_QUICK=1` shrinks every sweep to CI-probe size (same
/// scenario set, smaller request counts) — the mode the `perf-probe` CI
/// job runs on every PR. Any nonempty value other than "0" counts.
fn quick_mode() -> bool {
    std::env::var("GDDIM_BENCH_QUICK").map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}

fn run_once(
    rate: f64,
    max_wait_ms: u64,
    n_requests: usize,
    samples: usize,
) -> (f64, f64, f64, f64) {
    let router = Router::new(
        4,
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(max_wait_ms) },
        oracle_factory(),
    );
    let spec = WorkloadSpec {
        n_requests,
        samples_per_request: samples,
        rate_per_sec: rate,
        keys: vec![PlanKey::gddim("cld", "gmm2d", 20, 2)],
        seed: 7,
    };
    let _ = ClosedLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
        id,
        n,
        key: key.clone(),
        seed,
    });
    let report = router.metrics().report();
    let lat = report.latency.as_ref().unwrap();
    let out = (report.samples_per_sec, lat.p50, lat.p99, report.mean_batch_requests);
    router.shutdown();
    out
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let quick = quick_mode();
    let n_requests = args.get_usize("requests", if quick { 12 } else { 48 });
    let samples = args.get_usize("samples", if quick { 16 } else { 64 });
    let mut t = Table::new(
        "Serving: Poisson workload on the batched sampler (gDDIM CLD NFE=20)",
        &["rate(req/s)", "batching", "samples/s", "p50(s)", "p99(s)", "mean batch"],
    );
    for rate in [100.0, 400.0, f64::INFINITY] {
        for (label, wait) in [("off (1µs)", 0u64), ("on (5ms)", 5)] {
            let (tput, p50, p99, mb) = run_once(rate, wait, n_requests, samples);
            t.row(vec![
                if rate.is_finite() { format!("{rate:.0}") } else { "burst".into() },
                label.into(),
                format!("{tput:.0}"),
                format!("{p50:.4}"),
                format!("{p99:.4}"),
                format!("{mb:.1}"),
            ]);
        }
    }
    t.emit("serving");

    engine_scaling(&args, quick);
    let mut scenarios = dimension_scaling(&args, quick);
    open_loop_slo(&args, quick);
    scenarios.extend(score_batching(&args, quick));
    scenarios.extend(tcp_edge(&args, quick));
    scenarios.extend(learned_models(&args, quick));

    // --json PATH: persist the scenario set as a schema-versioned
    // snapshot (the perf trajectory; see workload::bench_report).
    if let Some(path) = args.get("json") {
        let source = std::env::var("GDDIM_BENCH_SOURCE").unwrap_or_else(|_| "local".to_string());
        let mut report = BenchReport::new(quick, &source);
        report.scenarios = scenarios;
        report.validate().expect("bench report must pass its own schema check");
        report.write(path).expect("bench report write");
        println!("wrote {path} ({} scenarios, quick={quick})", report.scenarios.len());
    }
}

/// Dimension scale sweep (the perf trajectory's resolution axis): one
/// fixed gDDIM job per image preset (8/16/32) on both BDM and VPSDE,
/// sharded under the engine's default byte budget. Reports the derived
/// rows/shard next to samples/s so shard-memory policy and throughput
/// move together in the record.
fn dimension_scaling(args: &Args, quick: bool) -> Vec<BenchScenario> {
    let n = args.get_usize("scale-batch", if quick { 128 } else { 512 });
    let nfe = args.get_usize("scale-nfe", 10);
    let workers = args.get_usize("scale-workers", 4);
    let mut t = Table::new(
        "Dimension scaling: gDDIM q=2 batch throughput by image resolution (default shard budget)",
        &["dataset", "d", "process", "rows/shard", "samples/s"],
    );
    let mut scenarios = Vec::new();
    for name in ["blobs8", "blobs16", "blobs32"] {
        let info = presets::info(name).expect("image preset in registry");
        let spec = info.build();
        for proc_name in ["bdm", "vpsde"] {
            let proc = gddim::diffusion::process_for(proc_name, info).unwrap();
            let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
            let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), nfe);
            let plan =
                SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
            let cfg = EngineConfig { workers, ..EngineConfig::default() };
            let rows = cfg.rows_per_shard(proc.dim_u());
            let engine = Engine::with_config(cfg);
            let sampler = GddimDet { plan: &plan };
            let job = Job { proc: proc.as_ref(), model: &oracle, sampler: &sampler, n, seed: 23 };
            let tput = engine_throughput(&engine, &job, 3);
            t.row(vec![
                name.to_string(),
                info.d.to_string(),
                proc_name.to_string(),
                rows.to_string(),
                format!("{tput:.0}"),
            ]);
            // Closed batch throughput scenario: issued = completed = the
            // batch size; no latency split (no queueing in this driver).
            let mut s = BenchScenario::named(&format!("dim_{name}_{proc_name}"));
            s.issued = n as u64;
            s.completed = n as u64;
            s.samples_per_sec = Some(tput);
            scenarios.push(s);
        }
    }
    t.emit("serving_scale");
    scenarios
}

/// Cross-key score batching on a heterogeneous key mix: four sampler
/// configurations (gDDIM orders 1–3 + Euler) share one `(process,
/// dataset, K_t)` oracle, so with the scheduler on their same-`t` score
/// requests pool into shared `eps_batch` calls. The table compares the
/// scheduler off/on on the same open-loop workload and reports the
/// realized batch fill (`rows/call`) and cross-key coalescing counters
/// straight from the engine stats.
fn score_batching(args: &Args, quick: bool) -> Vec<BenchScenario> {
    let n_requests = args.get_usize("open-requests", if quick { 12 } else { 40 });
    let samples = args.get_usize("hetero-samples", if quick { 8 } else { 16 });
    let rate = args.get_f64("hetero-rate", 400.0);
    let keys = vec![
        PlanKey::gddim("cld", "gmm2d", 20, 1),
        PlanKey::gddim("cld", "gmm2d", 20, 2),
        PlanKey::gddim("cld", "gmm2d", 20, 3),
        PlanKey::new(
            "cld",
            "gmm2d",
            gddim::samplers::SamplerSpec::Em { lambda: gddim::samplers::OrderedF64::new(0.0) },
            20,
        ),
    ];
    let mut t = Table::new(
        "Cross-key score batching: heterogeneous 4-key mix (CLD NFE=20), scheduler off vs on",
        &["score-batch", "done", "p50(s)", "p99(s)", "score calls", "rows/call", "cross-job"],
    );
    let mut scenarios = Vec::new();
    for score_batch in [0usize, 4096] {
        let (report, metrics) = open_loop_probe(
            RouterConfig { dispatchers: 4, ..RouterConfig::default() },
            EngineConfig {
                workers: 4,
                score_batch,
                score_wait: std::time::Duration::from_micros(200),
                ..EngineConfig::default()
            },
            BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(2) },
            WorkloadSpec {
                n_requests,
                samples_per_request: samples,
                rate_per_sec: rate,
                keys: keys.clone(),
                seed: 17,
            },
            true,
        );
        let engine = metrics.engine.expect("router report carries engine stats");
        let cell = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.4}"));
        t.row(vec![
            if score_batch == 0 { "off".into() } else { score_batch.to_string() },
            format!("{}/{}", report.completed, report.issued),
            cell(report.total.as_ref().map(|s| s.p50)),
            cell(report.total.as_ref().map(|s| s.p99)),
            if score_batch == 0 { "-".into() } else { engine.score_calls.to_string() },
            if score_batch == 0 { "-".into() } else { format!("{:.1}", engine.rows_per_call()) },
            if score_batch == 0 { "-".into() } else { engine.coalesced_keys.to_string() },
        ]);
        let name = if score_batch == 0 { "hetero4_sched_off" } else { "hetero4_sched_on" };
        scenarios.push(BenchScenario::from_probe(name, &report, samples, Some(&engine)));
    }
    t.emit("serving_score_batching");
    scenarios
}

/// Loopback-TCP edge scenario: the same heterogeneous 4-key mix as
/// [`score_batching`] (scheduler on), but driven through a real
/// `NetServer` over loopback sockets — wire parsing, admission control
/// and per-connection writer threads are all on the measured path, so
/// this row tracks the *edge tax* relative to `hetero4_sched_on` in the
/// committed trajectory.
fn tcp_edge(args: &Args, quick: bool) -> Vec<BenchScenario> {
    let n_requests = args.get_usize("open-requests", if quick { 12 } else { 40 });
    let samples = args.get_usize("hetero-samples", if quick { 8 } else { 16 });
    let rate = args.get_f64("hetero-rate", 400.0);
    let conns = args.get_usize("conns", 4);
    let keys = vec![
        PlanKey::gddim("cld", "gmm2d", 20, 1),
        PlanKey::gddim("cld", "gmm2d", 20, 2),
        PlanKey::gddim("cld", "gmm2d", 20, 3),
        PlanKey::new(
            "cld",
            "gmm2d",
            gddim::samplers::SamplerSpec::Em { lambda: gddim::samplers::OrderedF64::new(0.0) },
            20,
        ),
    ];
    let (report, metrics) = open_loop_tcp_probe(
        RouterConfig { dispatchers: 4, ..RouterConfig::default() },
        EngineConfig {
            workers: 4,
            score_batch: 4096,
            score_wait: std::time::Duration::from_micros(200),
            ..EngineConfig::default()
        },
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(2) },
        NetConfig::default(),
        conns,
        WorkloadSpec {
            n_requests,
            samples_per_request: samples,
            rate_per_sec: rate,
            keys,
            seed: 17,
        },
        true,
    );
    let edge = metrics.edge.as_ref().expect("edge server report carries edge counters");
    let cell = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.4}"));
    let mut t = Table::new(
        "Loopback TCP edge: heterogeneous 4-key mix (CLD NFE=20) through the wire protocol",
        &["conns", "done", "admitted", "shed", "p50(s)", "p99(s)", "samples/s"],
    );
    t.row(vec![
        conns.to_string(),
        format!("{}/{}", report.completed, report.issued),
        edge.requests_admitted.to_string(),
        edge.requests_shed.to_string(),
        cell(report.total.as_ref().map(|s| s.p50)),
        cell(report.total.as_ref().map(|s| s.p99)),
        format!("{:.0}", metrics.samples_per_sec),
    ]);
    t.emit("serving_tcp_edge");
    vec![BenchScenario::from_probe("hetero4_tcp", &report, samples, metrics.engine.as_ref())]
}

/// Learned-score serving: the same open-loop harness as
/// [`score_batching`], but routed through `learned_factory` over the
/// committed tiny-model fixture, so the measured `eps_batch` is a real
/// matmul forward ([`gddim::score::ScoreNet`]) instead of the closed-form
/// oracle — the fill-ratio and pooling numbers this row records are the
/// honest ones for network-shaped score cost. Two keys (gDDIM q=1/q=2 on
/// vpsde/gmm2d) share the one fixture model, so the scheduler's same-
/// model pooling is on the measured path. The scenario is part of the
/// committed `BENCH_serving.json` baseline, so `benchdiff` tracks it
/// like any other trajectory row.
fn learned_models(args: &Args, quick: bool) -> Vec<BenchScenario> {
    let n_requests = args.get_usize("open-requests", if quick { 12 } else { 40 });
    let samples = args.get_usize("hetero-samples", if quick { 8 } else { 16 });
    let rate = args.get_f64("hetero-rate", 400.0);
    let fixture = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/learned");
    let keys =
        vec![PlanKey::gddim("vpsde", "gmm2d", 20, 1), PlanKey::gddim("vpsde", "gmm2d", 20, 2)];
    let (report, metrics) = open_loop_probe_with(
        RouterConfig { dispatchers: 4, ..RouterConfig::default() },
        EngineConfig {
            workers: 4,
            score_batch: 4096,
            score_wait: std::time::Duration::from_micros(200),
            ..EngineConfig::default()
        },
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(2) },
        WorkloadSpec {
            n_requests,
            samples_per_request: samples,
            rate_per_sec: rate,
            keys,
            seed: 17,
        },
        true,
        learned_factory(fixture).expect("committed learned fixture loads"),
    );
    let engine = metrics.engine.expect("router report carries engine stats");
    let cell = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.4}"));
    let mut t = Table::new(
        "Learned-score serving: tiny ScoreNet fixture (vpsde/gmm2d, 2-key mix, scheduler on)",
        &["done", "p50(s)", "p99(s)", "score calls", "rows/call", "samples/s"],
    );
    t.row(vec![
        format!("{}/{}", report.completed, report.issued),
        cell(report.total.as_ref().map(|s| s.p50)),
        cell(report.total.as_ref().map(|s| s.p99)),
        engine.score_calls.to_string(),
        format!("{:.1}", engine.rows_per_call()),
        format!("{:.0}", metrics.samples_per_sec),
    ]);
    t.emit("serving_learned");
    vec![BenchScenario::from_probe("learned_vpsde_sched_on", &report, samples, Some(&engine))]
}

/// Open-loop SLO bench: inject at fixed rates regardless of completion
/// (tail latency is *not* hidden by arrival backoff, unlike the closed
/// loop above) and report queueing/service/total percentiles plus the
/// max injection rate whose total-latency p99 meets the SLO. Each rate
/// point runs `workload::open_loop_probe` — the same harness as the
/// `gddim workload` subcommand — against a 4-dispatcher, 1-worker-engine
/// router (the closed-loop bench's thread budget).
fn open_loop_slo(args: &Args, quick: bool) {
    let n_requests = args.get_usize("open-requests", if quick { 12 } else { 40 });
    let samples = args.get_usize("samples", if quick { 16 } else { 64 });
    let slo_ms = args.get_f64("slo-ms", 100.0);
    let rates: Vec<f64> = match args.get("rates") {
        Some(list) => list.split(',').map(|s| s.trim().parse().expect("bad --rates")).collect(),
        None if quick => vec![200.0],
        None => vec![50.0, 200.0, 800.0],
    };
    let mut t = Table::new(
        "Open-loop SLO: fixed-rate injection (gDDIM CLD NFE=20), latency percentiles",
        &["rate(req/s)", "done", "queue p95(s)", "service p95(s)", "p50(s)", "p99(s)", "SLO"],
    );
    let sweep = max_rate_under_slo(&rates, slo_ms / 1e3, |rate| {
        let (report, _metrics) = open_loop_probe(
            RouterConfig { dispatchers: 4, ..RouterConfig::default() },
            EngineConfig { workers: 1, ..EngineConfig::default() },
            BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(2) },
            WorkloadSpec {
                n_requests,
                samples_per_request: samples,
                rate_per_sec: rate,
                keys: vec![PlanKey::gddim("cld", "gmm2d", 20, 2)],
                seed: 13,
            },
            true,
        );
        report
    });
    // A rate point can complete zero requests (every response timed out):
    // its summaries are None, shown as "-" rather than panicking.
    let cell = |v: Option<f64>| v.map_or_else(|| "-".into(), |x| format!("{x:.4}"));
    for p in &sweep.points {
        t.row(vec![
            format!("{:.0}", p.rate),
            format!("{}/{}", p.report.completed, p.report.issued),
            cell(p.report.queueing.as_ref().map(|s| s.p95)),
            cell(p.report.service.as_ref().map(|s| s.p95)),
            cell(p.report.total.as_ref().map(|s| s.p50)),
            cell(p.report.total.as_ref().map(|s| s.p99)),
            if p.meets_slo { "ok".into() } else { "MISS".into() },
        ]);
    }
    t.emit("serving_open_loop");
    match sweep.max_rate {
        Some(r) => println!("max rate under SLO (p99 ≤ {slo_ms:.0}ms): {r:.0} req/s"),
        None => println!("no probed rate met the SLO (p99 ≤ {slo_ms:.0}ms)"),
    }
}

/// Engine worker-scaling sweep: one fixed batched job, increasing pool
/// sizes. The headline number for the sharded engine — samples/s must
/// grow from 1 worker to 4 on any multicore box.
fn engine_scaling(args: &Args, quick: bool) {
    let n = args.get_usize("engine-batch", if quick { 1024 } else { 8192 });
    let nfe = args.get_usize("nfe", 20);
    let spec = presets::gmm2d();
    let proc = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), nfe);
    let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let sampler = GddimDet { plan: &plan };
    let job = Job {
        proc: proc.as_ref(),
        model: &oracle,
        sampler: &sampler,
        n,
        seed: 11,
    };
    let mut t = Table::new(
        "Engine scaling: sharded gDDIM job (CLD NFE=20), samples/s by worker count",
        &["workers", "samples/s", "speedup vs 1"],
    );
    let mut base = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let engine = Engine::new(workers);
        let tput = engine_throughput(&engine, &job, 3);
        if workers == 1 {
            base = tput;
        }
        t.row(vec![
            workers.to_string(),
            format!("{tput:.0}"),
            format!("{:.2}x", tput / base.max(1e-9)),
        ]);
    }
    t.emit("serving_engine");
}
