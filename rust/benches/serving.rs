//! `cargo bench --bench serving` — batched sampling service throughput &
//! latency under a Poisson workload (the L3 deliverable's headline bench).
//! Reports batch occupancy, samples/s, and latency percentiles at several
//! arrival rates, plus a batching on/off comparison.

use std::sync::Arc;
use std::time::Duration;

use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Cld, Process, TimeGrid};
use gddim::engine::{Engine, Job, SamplerSpec};
use gddim::score::oracle::GmmOracle;
use gddim::server::batcher::BatcherConfig;
use gddim::server::request::{GenRequest, PlanKey};
use gddim::server::router::{oracle_factory, Router};
use gddim::util::bench::Table;
use gddim::util::cli::Args;
use gddim::workload::{engine_throughput, ClosedLoop, WorkloadSpec};

fn run_once(rate: f64, max_wait_ms: u64, n_requests: usize, samples: usize) -> (f64, f64, f64, f64) {
    let router = Router::new(
        4,
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(max_wait_ms) },
        oracle_factory(),
    );
    let spec = WorkloadSpec {
        n_requests,
        samples_per_request: samples,
        rate_per_sec: rate,
        keys: vec![PlanKey::gddim("cld", "gmm2d", 20, 2)],
        seed: 7,
    };
    let _ = ClosedLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
        id,
        n,
        key: key.clone(),
        seed,
    });
    let report = router.metrics().report();
    let lat = report.latency.as_ref().unwrap();
    let out = (report.samples_per_sec, lat.p50, lat.p99, report.mean_batch_requests);
    router.shutdown();
    out
}

fn main() {
    let args = Args::parse(std::env::args().skip(1).filter(|a| a != "--bench"));
    let n_requests = args.get_usize("requests", 48);
    let samples = args.get_usize("samples", 64);
    let mut t = Table::new(
        "Serving: Poisson workload on the batched sampler (gDDIM CLD NFE=20)",
        &["rate(req/s)", "batching", "samples/s", "p50(s)", "p99(s)", "mean batch"],
    );
    for rate in [100.0, 400.0, f64::INFINITY] {
        for (label, wait) in [("off (1µs)", 0u64), ("on (5ms)", 5)] {
            let (tput, p50, p99, mb) = run_once(rate, wait, n_requests, samples);
            t.row(vec![
                if rate.is_finite() { format!("{rate:.0}") } else { "burst".into() },
                label.into(),
                format!("{tput:.0}"),
                format!("{p50:.4}"),
                format!("{p99:.4}"),
                format!("{mb:.1}"),
            ]);
        }
    }
    t.emit("serving");

    engine_scaling(&args);
}

/// Engine worker-scaling sweep: one fixed batched job, increasing pool
/// sizes. The headline number for the sharded engine — samples/s must
/// grow from 1 worker to 4 on any multicore box.
fn engine_scaling(args: &Args) {
    let n = args.get_usize("engine-batch", 8192);
    let nfe = args.get_usize("nfe", 20);
    let spec = presets::gmm2d();
    let proc = Arc::new(Cld::standard(spec.d));
    let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), nfe);
    let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
    let job = Job {
        proc: proc.as_ref(),
        model: &oracle,
        sampler: SamplerSpec::GddimDet(&plan),
        n,
        seed: 11,
    };
    let mut t = Table::new(
        "Engine scaling: sharded gDDIM job (CLD NFE=20), samples/s by worker count",
        &["workers", "samples/s", "speedup vs 1"],
    );
    let mut base = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let engine = Engine::new(workers);
        let tput = engine_throughput(&engine, &job, 3);
        if workers == 1 {
            base = tput;
        }
        t.row(vec![
            workers.to_string(),
            format!("{tput:.0}"),
            format!("{:.2}x", tput / base.max(1e-9)),
        ]);
    }
    t.emit("serving_engine");
}
