//! Whole-crate flow analysis: symbol table, call graph, and the four
//! graph rules of catalog v3.
//!
//! [`super::scan`] gives a comment- and literal-stripped *code* channel
//! per line; this module parses `fn` items, `impl`/`trait` blocks and
//! call sites out of it — deliberately *not* a full parser — and builds
//! an intra-crate call graph with best-effort method resolution:
//!
//! - `self.m(..)` resolves inside the enclosing `impl` block first;
//! - `Type::m(..)` resolves against that type's `impl` blocks;
//! - `module::f(..)` resolves to free fns in a file named after the
//!   last module segment (`util::sync::f` → `…/sync.rs`);
//! - `x.m(..)` is receiver-type-blind: it links every method named `m`
//!   when `m` is declared by some trait (dispatch), a unique method
//!   otherwise, and lands in the explicit [`Graph::unresolved`] bucket
//!   when several unrelated types define `m` — soundness gaps stay
//!   visible instead of silently dropping edges.
//!
//! On top of the graph sit the transitive rules (`panic-reachability`,
//! `lock-order`, `blocking-in-lock`, `reassoc-taint`); each finding
//! carries a deterministic witness path (`--explain RULE` prints it).
//! Iteration order is deterministic everywhere: files are sorted by the
//! caller, functions keep file order, and worklists are index-ordered.

use std::collections::{BTreeMap, BTreeSet};

use super::rules::{self, Finding};
use super::scan::SourceLine;

/// One line of a function body, as seen by the fact extractors.
struct BodyLine {
    number: usize,
    code: String,
    /// Brace depth relative to the `fn` item at the *start* of the line
    /// (the body proper sits at depth ≥ 1); guard scopes end when the
    /// depth falls below the binding depth.
    depth: i64,
}

/// One `fn` item: identity, enclosing block context, and extracted
/// facts. `file` keeps the label the walker passed in.
pub(crate) struct FnInfo {
    pub file: String,
    pub name: String,
    /// Enclosing `impl Type { .. }` / `trait Name { .. }` type name.
    pub impl_type: Option<String>,
    /// Trait being implemented (`impl Trait for Type`) or declared.
    pub trait_name: Option<String>,
    /// 1-based line of the `fn` keyword.
    pub line: usize,
    pub in_test: bool,
    body: Vec<BodyLine>,
}

impl FnInfo {
    /// Stable display key: `file::Type::name` with the path shortened
    /// to its `src/`-relative suffix.
    pub fn key(&self) -> String {
        let file = short_path(&self.file);
        match &self.impl_type {
            Some(t) => format!("{file}::{t}::{}", self.name),
            None => format!("{file}::{}", self.name),
        }
    }
}

fn short_path(path: &str) -> &str {
    path.rfind("/src/").map_or(path, |p| &path[p + 5..])
}

/// A call site that could not be pinned to a single callee (or to a
/// trait dispatch set): several unrelated types define the method.
pub(crate) struct UnresolvedCall {
    pub file: String,
    pub line: usize,
    pub name: String,
    pub candidates: usize,
}

/// One lock acquisition inside a function body.
struct LockAcq {
    line: usize,
    /// Normalized identity: `self` replaced by the impl type, then the
    /// last two path segments (`ScoreScheduler.inner`, `slot.state`).
    id: String,
    /// Let-bound guard variable, if any. `None` means the guard is a
    /// temporary — no `let`, or the acquisition is method-chained so the
    /// binding holds the call result, not the guard — and the region is
    /// that single line (documented under-approximation for
    /// match-scrutinee temporaries).
    guard: Option<String>,
    /// Binding depth (line-start depth of the acquisition line).
    depth: i64,
}

/// The symbol table + call graph + per-function facts.
pub(crate) struct Graph {
    pub fns: Vec<FnInfo>,
    /// Resolved edges per caller: `(callee index, call-site line)`.
    pub edges: Vec<Vec<(usize, usize)>>,
    pub unresolved: Vec<UnresolvedCall>,
    /// Panic sites per fn: `(line, pattern)`; empty for test code.
    panics: Vec<Vec<(usize, &'static str)>>,
    locks: Vec<Vec<LockAcq>>,
    /// Blocking sites per fn: `(line, what)`.
    blocking: Vec<Vec<(usize, String)>>,
    /// Reassociating taint sources (fn indices).
    reassoc_sources: Vec<usize>,
}

// ---------------------------------------------------------------------------
// Tokenizing
// ---------------------------------------------------------------------------

#[derive(Clone, PartialEq)]
enum Tok {
    Id(String),
    P(char),
}

impl Tok {
    fn id(&self) -> Option<&str> {
        match self {
            Tok::Id(s) => Some(s),
            Tok::P(_) => None,
        }
    }

    fn is(&self, c: char) -> bool {
        matches!(self, Tok::P(p) if *p == c)
    }
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Drop `::<…>` turbofish spans so `f::<T>(x)` tokenizes like `f(x)`.
fn strip_turbofish(code: &str) -> String {
    let mut out = String::with_capacity(code.len());
    let b: Vec<char> = code.chars().collect();
    let mut i = 0;
    while i < b.len() {
        if b[i] == ':' && b.get(i + 1) == Some(&':') && b.get(i + 2) == Some(&'<') {
            let mut depth = 1i64;
            let mut j = i + 3;
            while j < b.len() && depth > 0 {
                match b[j] {
                    '<' => depth += 1,
                    '>' => depth -= 1,
                    _ => {}
                }
                j += 1;
            }
            i = j;
        } else {
            out.push(b[i]);
            i += 1;
        }
    }
    out
}

/// Whitespace-free token stream of one code-channel line.
fn tokenize(code: &str) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut ident = String::new();
    for c in strip_turbofish(code).chars() {
        if is_ident(c) {
            ident.push(c);
        } else {
            if !ident.is_empty() {
                out.push(Tok::Id(std::mem::take(&mut ident)));
            }
            if !c.is_whitespace() {
                out.push(Tok::P(c));
            }
        }
    }
    if !ident.is_empty() {
        out.push(Tok::Id(ident));
    }
    out
}

// ---------------------------------------------------------------------------
// Item parsing
// ---------------------------------------------------------------------------

/// Position of `word` as a standalone token in `code`.
fn word_pos(code: &str, word: &str) -> Option<usize> {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !code[..at].chars().next_back().is_some_and(is_ident);
        let after = at + word.len();
        let after_ok = !code[after..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            return Some(at);
        }
        start = after;
    }
    None
}

/// Last segment of the first `A::B::C` path at the start of `s`,
/// skipping a leading `<…>` generic parameter list.
fn first_path_last_seg(s: &str) -> Option<String> {
    let mut rest = s.trim_start();
    if let Some(r) = rest.strip_prefix('<') {
        let mut depth = 1i64;
        let mut idx = 0;
        for (i, c) in r.char_indices() {
            match c {
                '<' => depth += 1,
                '>' => depth -= 1,
                _ => {}
            }
            if depth == 0 {
                idx = i + 1;
                break;
            }
        }
        rest = r[idx..].trim_start();
    }
    let mut last = None;
    loop {
        let end = rest.find(|c: char| !is_ident(c)).unwrap_or(rest.len());
        if end == 0 {
            return last;
        }
        last = Some(rest[..end].to_string());
        rest = &rest[end..];
        match rest.strip_prefix("::") {
            Some(r) => rest = r,
            None => return last,
        }
    }
}

struct Ctx {
    type_name: String,
    trait_name: Option<String>,
    is_trait_decl: bool,
    open_depth: i64,
}

struct PendingCtx {
    type_name: String,
    trait_name: Option<String>,
    is_trait_decl: bool,
}

struct PendingFn {
    name: String,
    line: usize,
    in_test: bool,
}

/// Parse one scanned file into `fn` items with raw body lines. Also
/// records trait-*declared* method names (signature-only or defaulted)
/// into `trait_methods`.
fn parse_file(
    label: &str,
    lines: &[SourceLine],
    trait_methods: &mut BTreeSet<String>,
) -> Vec<FnInfo> {
    let mut fns: Vec<FnInfo> = Vec::new();
    let mut ctx_stack: Vec<Ctx> = Vec::new();
    let mut fn_stack: Vec<(usize, i64)> = Vec::new();
    let mut pending_ctx: Option<PendingCtx> = None;
    let mut pending_fn: Option<PendingFn> = None;
    // Byte position of the pending item's keyword on the *current* line
    // (-1 once it was declared on an earlier line), so a `{` can tell
    // which pending item it opens when both sit on one line
    // (`impl T for A { fn m(&self) {} }`).
    let mut pending_ctx_pos = -1i64;
    let mut pending_fn_pos = -1i64;
    let mut depth = 0i64;
    // Paren/bracket depth inside a pending fn signature, so the `;` in
    // `fn f(x: [u8; 4]);` is not mistaken for the decl-only terminator.
    let mut sig_nest = 0i64;

    for line in lines {
        let code = line.code.as_str();
        let line_depth_start = depth - fn_stack.last().map_or(depth, |f| f.1);
        pending_ctx_pos = -1;
        pending_fn_pos = -1;
        // Item headers are only recognized at item scope.
        if pending_fn.is_none() && fn_stack.is_empty() && pending_ctx.is_none() {
            let fp = word_pos(code, "fn");
            let ip = word_pos(code, "impl").filter(|p| fp.is_none_or(|f| *p < f));
            let tp = word_pos(code, "trait").filter(|p| fp.is_none_or(|f| *p < f));
            if let Some(p) = ip {
                let rest = &code[p + "impl".len()..];
                let (head, tail) = match rest.split_once(" for ") {
                    Some((h, t)) => (Some(h), t),
                    None => (None, rest),
                };
                if let Some(ty) = first_path_last_seg(tail) {
                    pending_ctx = Some(PendingCtx {
                        type_name: ty,
                        trait_name: head.and_then(first_path_last_seg),
                        is_trait_decl: false,
                    });
                    pending_ctx_pos = p as i64;
                }
            } else if let Some(p) = tp {
                if let Some(name) = first_path_last_seg(&code[p + "trait".len()..]) {
                    pending_ctx = Some(PendingCtx {
                        trait_name: Some(name.clone()),
                        type_name: name,
                        is_trait_decl: true,
                    });
                    pending_ctx_pos = p as i64;
                }
            }
        }
        if pending_fn.is_none() {
            if let Some(p) = word_pos(code, "fn") {
                // `fn(A) -> B` type positions yield no ident.
                if let Some(name) = first_path_last_seg(&code[p + "fn".len()..]) {
                    pending_fn = Some(PendingFn { name, line: line.number, in_test: line.in_test });
                    pending_fn_pos = p as i64;
                    sig_nest = 0;
                }
            }
        }

        // Innermost fn owning this line, surviving a same-line close.
        let mut line_owner = fn_stack.last().map(|f| f.0);
        for (ci, c) in code.char_indices() {
            match c {
                '(' | '[' if pending_fn.is_some() => sig_nest += 1,
                ')' | ']' if pending_fn.is_some() => sig_nest -= 1,
                ';' if pending_fn.is_some() && sig_nest == 0 => {
                    // Signature-only decl (trait method or extern).
                    let pf = pending_fn.take().expect("checked is_some");
                    let in_trait = fn_stack.is_empty()
                        && ctx_stack.last().is_some_and(|c| c.is_trait_decl);
                    if in_trait {
                        trait_methods.insert(pf.name);
                    }
                }
                '{' => {
                    depth += 1;
                    // When both an item header and a fn decl precede
                    // this brace, it opens the *nearer* (rightmost) one.
                    let fn_ok = pending_fn.is_some() && pending_fn_pos < ci as i64;
                    let ctx_ok = pending_ctx.is_some() && pending_ctx_pos < ci as i64;
                    if fn_ok && (!ctx_ok || pending_fn_pos > pending_ctx_pos) {
                        let pf = pending_fn.take().expect("fn_ok");
                        // Context resolves at attach time, so a block
                        // opened earlier on this same line counts.
                        let ctx = if fn_stack.is_empty() { ctx_stack.last() } else { None };
                        if ctx.is_some_and(|c| c.is_trait_decl) {
                            trait_methods.insert(pf.name.clone());
                        }
                        fns.push(FnInfo {
                            file: label.to_string(),
                            name: pf.name,
                            impl_type: ctx.map(|c| c.type_name.clone()),
                            trait_name: ctx.and_then(|c| c.trait_name.clone()),
                            line: pf.line,
                            in_test: pf.in_test,
                            body: Vec::new(),
                        });
                        fn_stack.push((fns.len() - 1, depth));
                        line_owner = Some(fns.len() - 1);
                    } else if ctx_ok {
                        let pc = pending_ctx.take().expect("ctx_ok");
                        ctx_stack.push(Ctx {
                            type_name: pc.type_name,
                            trait_name: pc.trait_name,
                            is_trait_decl: pc.is_trait_decl,
                            open_depth: depth,
                        });
                    }
                }
                '}' => {
                    depth -= 1;
                    if fn_stack.last().is_some_and(|f| f.1 > depth) {
                        fn_stack.pop();
                    }
                    if ctx_stack.last().is_some_and(|c| c.open_depth > depth) {
                        ctx_stack.pop();
                    }
                }
                _ => {}
            }
        }
        if let Some(idx) = line_owner {
            fns[idx].body.push(BodyLine {
                number: line.number,
                code: code.to_string(),
                depth: line_depth_start.max(0),
            });
        }
    }
    fns
}

// ---------------------------------------------------------------------------
// Call extraction + resolution
// ---------------------------------------------------------------------------

enum Recv {
    /// `f(..)` — plain path-less call.
    Bare,
    /// `self.m(..)`.
    SelfDot,
    /// `x.m(..)`, `).m(..)` — receiver type unknown.
    Method,
    /// `a::b::m(..)` — `qual` is the segment before the name.
    Qual(String),
}

struct CallSite {
    name: String,
    recv: Recv,
}

/// Extract call sites from one tokenized line. Declarations (`fn name(`)
/// and macros (`name!(`) are not calls.
fn calls_on_line(toks: &[Tok]) -> Vec<CallSite> {
    let mut out = Vec::new();
    for i in 0..toks.len() {
        let Some(name) = toks[i].id() else { continue };
        if toks.get(i + 1).is_none_or(|t| !t.is('(')) {
            continue;
        }
        if i > 0 && toks[i - 1].id() == Some("fn") {
            continue;
        }
        let recv = if i >= 3 && toks[i - 1].is(':') && toks[i - 2].is(':') {
            match toks[i - 3].id() {
                Some(q) => Recv::Qual(q.to_string()),
                None => continue,
            }
        } else if i >= 1 && toks[i - 1].is('.') {
            if i >= 2 && toks[i - 2].id() == Some("self") {
                Recv::SelfDot
            } else {
                Recv::Method
            }
        } else {
            Recv::Bare
        };
        out.push(CallSite { name: name.to_string(), recv });
    }
    out
}

impl Graph {
    /// Build the graph from scanned files (`(label, lines)` pairs,
    /// already in deterministic order).
    pub fn build(files: &[(String, Vec<SourceLine>)]) -> Graph {
        let mut trait_methods = BTreeSet::new();
        let mut fns = Vec::new();
        for (label, lines) in files {
            fns.extend(parse_file(label, lines, &mut trait_methods));
        }
        // Defaulted trait methods also dispatch.
        for f in &fns {
            if f.trait_name.is_some() && f.impl_type.as_deref() == f.trait_name.as_deref() {
                trait_methods.insert(f.name.clone());
            }
        }

        let mut by_name: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        let mut by_type: BTreeMap<(&str, &str), Vec<usize>> = BTreeMap::new();
        let mut frees: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
        for (i, f) in fns.iter().enumerate() {
            by_name.entry(&f.name).or_default().push(i);
            match &f.impl_type {
                Some(t) => by_type.entry((t, &f.name)).or_default().push(i),
                None => frees.entry(&f.name).or_default().push(i),
            }
        }

        let mut edges: Vec<Vec<(usize, usize)>> = vec![Vec::new(); fns.len()];
        let mut unresolved = Vec::new();
        for (i, f) in fns.iter().enumerate() {
            for bl in &f.body {
                let toks = tokenize(&bl.code);
                for call in calls_on_line(&toks) {
                    let name = call.name.as_str();
                    let methods = || -> Vec<usize> {
                        by_name.get(name).map_or(Vec::new(), |v| {
                            v.iter().copied().filter(|&j| fns[j].impl_type.is_some()).collect()
                        })
                    };
                    let targets: Vec<usize> = match call.recv {
                        Recv::SelfDot => {
                            let own = f.impl_type.as_deref().and_then(|t| by_type.get(&(t, name)));
                            match own {
                                Some(v) => v.clone(),
                                None => resolve_method(name, &methods(), &trait_methods),
                            }
                        }
                        Recv::Qual(q) => {
                            let q = if q == "Self" {
                                f.impl_type.clone().unwrap_or(q)
                            } else {
                                q
                            };
                            if q.starts_with(char::is_uppercase) {
                                by_type.get(&(q.as_str(), name)).cloned().unwrap_or_default()
                            } else {
                                // `module::f` → free fns in `…/module.rs`
                                // or `…/module/…`; no crate-wide fallback.
                                let file_rs = format!("/{q}.rs");
                                let dir = format!("/{q}/");
                                frees.get(name).map_or(Vec::new(), |v| {
                                    v.iter()
                                        .copied()
                                        .filter(|&j| {
                                            fns[j].file.ends_with(&file_rs)
                                                || fns[j].file.contains(&dir)
                                        })
                                        .collect()
                                })
                            }
                        }
                        Recv::Method => resolve_method(name, &methods(), &trait_methods),
                        Recv::Bare => {
                            let cands = frees.get(name).cloned().unwrap_or_default();
                            let same_file: Vec<usize> =
                                cands.iter().copied().filter(|&j| fns[j].file == f.file).collect();
                            if same_file.len() == 1 {
                                same_file
                            } else if cands.len() == 1 {
                                cands
                            } else if cands.len() > 1 {
                                unresolved.push(UnresolvedCall {
                                    file: f.file.clone(),
                                    line: bl.number,
                                    name: name.to_string(),
                                    candidates: cands.len(),
                                });
                                Vec::new()
                            } else {
                                Vec::new()
                            }
                        }
                    };
                    if matches!(call.recv, Recv::Method | Recv::SelfDot) && targets.is_empty() {
                        let n = methods().len();
                        if n > 1 {
                            unresolved.push(UnresolvedCall {
                                file: f.file.clone(),
                                line: bl.number,
                                name: name.to_string(),
                                candidates: n,
                            });
                        }
                    }
                    for t in targets {
                        if t != i {
                            edges[i].push((t, bl.number));
                        }
                    }
                }
            }
        }
        for e in &mut edges {
            e.sort_unstable();
            e.dedup();
        }

        let panics = fns.iter().map(collect_panics).collect();
        let locks = fns.iter().map(collect_locks).collect();
        let blocking = fns.iter().map(collect_blocking).collect();
        Graph {
            reassoc_sources: reassoc_sources(&fns, files),
            fns,
            edges,
            unresolved,
            panics,
            locks,
            blocking,
        }
    }
}

/// Method-call resolution over the impl-method candidate set: a trait
/// dispatch links every implementation, a unique method links directly,
/// and ≥ 2 unrelated candidates stay unresolved (handled by the caller).
fn resolve_method(name: &str, methods: &[usize], trait_methods: &BTreeSet<String>) -> Vec<usize> {
    if trait_methods.contains(name) || methods.len() == 1 {
        methods.to_vec()
    } else {
        Vec::new()
    }
}

// ---------------------------------------------------------------------------
// Per-function facts
// ---------------------------------------------------------------------------

const PANIC_PATS: &[&str] =
    &[".unwrap()", ".expect(", "panic!(", "unreachable!(", "todo!(", "unimplemented!("];

fn collect_panics(f: &FnInfo) -> Vec<(usize, &'static str)> {
    if f.in_test {
        return Vec::new();
    }
    let mut out = Vec::new();
    for bl in &f.body {
        for pat in PANIC_PATS {
            if let Some(p) = bl.code.find(pat) {
                // Macro patterns need a word boundary on the left so a
                // hypothetical `my_panic!(` never matches; the method
                // patterns start with `.` and are boundary-safe as-is.
                let boundary = pat.starts_with('.')
                    || p == 0
                    || !bl.code[..p].chars().next_back().is_some_and(is_ident);
                if boundary {
                    out.push((bl.number, *pat));
                }
            }
        }
    }
    out
}

/// Normalize a lock path to its identity: `self` → impl type, then the
/// last two segments.
fn lock_id(segs: &[String], impl_type: Option<&str>) -> String {
    let mut segs: Vec<&str> = segs.iter().map(String::as_str).collect();
    if segs.first() == Some(&"self") {
        if let Some(t) = impl_type {
            segs[0] = t;
        }
    }
    let n = segs.len();
    segs[n.saturating_sub(2)..].join(".")
}

/// Index just past the `)` matching the `(` at `open`.
fn after_close(toks: &[Tok], open: usize) -> Option<usize> {
    let mut depth = 0i64;
    for (k, t) in toks.iter().enumerate().skip(open) {
        if t.is('(') {
            depth += 1;
        }
        if t.is(')') {
            depth -= 1;
            if depth == 0 {
                return Some(k + 1);
            }
        }
    }
    None
}

/// Read an `ident(.ident)*` path forward from `toks[at]`.
fn path_forward(toks: &[Tok], mut at: usize) -> Vec<String> {
    let mut segs = Vec::new();
    while let Some(s) = toks.get(at).and_then(Tok::id) {
        segs.push(s.to_string());
        if toks.get(at + 1).is_some_and(|t| t.is('.')) {
            at += 2;
        } else {
            break;
        }
    }
    segs
}

fn collect_locks(f: &FnInfo) -> Vec<LockAcq> {
    // The helpers themselves acquire raw guards by design.
    if f.file.ends_with("util/sync.rs") {
        return Vec::new();
    }
    let mut out = Vec::new();
    for bl in &f.body {
        let toks = tokenize(&bl.code);
        let guard = match toks.as_slice() {
            [Tok::Id(l), Tok::Id(m), Tok::Id(g), ..] if l == "let" && m == "mut" => {
                Some(g.clone())
            }
            [Tok::Id(l), Tok::Id(g), ..] if l == "let" => Some(g.clone()),
            _ => None,
        };
        for i in 0..toks.len() {
            let Some(name) = toks[i].id() else { continue };
            let helper =
                matches!(name, "lock_unpoisoned" | "read_unpoisoned" | "write_unpoisoned");
            if helper && toks.get(i + 1).is_some_and(|t| t.is('(')) {
                // A method-chained acquisition is a temporary: the guard
                // dies at the end of the statement, not at the binding
                // (`let task = lock_unpoisoned(rx).recv()` binds the recv
                // result, never the guard).
                let chained = after_close(&toks, i + 1)
                    .is_some_and(|k| toks.get(k).is_some_and(|t| t.is('.')));
                let at = if toks.get(i + 2).is_some_and(|t| t.is('&')) { i + 3 } else { i + 2 };
                let segs = path_forward(&toks, at);
                if !segs.is_empty() {
                    out.push(LockAcq {
                        line: bl.number,
                        id: lock_id(&segs, f.impl_type.as_deref()),
                        guard: if chained { None } else { guard.clone() },
                        depth: bl.depth,
                    });
                }
            }
            // Raw `path.lock()` / `path.write()` / argless `path.read()`.
            let raw = matches!(name, "lock" | "read" | "write");
            if raw
                && i >= 2
                && toks[i - 1].is('.')
                && toks.get(i + 1).is_some_and(|t| t.is('('))
                && toks.get(i + 2).is_some_and(|t| t.is(')'))
            {
                // Walk the receiver path backwards.
                let mut segs = Vec::new();
                let mut j = i - 1;
                while j >= 1 && toks[j].is('.') {
                    match toks[j - 1].id() {
                        Some(s) => segs.push(s.to_string()),
                        None => break,
                    }
                    if j < 2 {
                        break;
                    }
                    j -= 2;
                }
                segs.reverse();
                let chained = toks.get(i + 3).is_some_and(|t| t.is('.'));
                if !segs.is_empty() {
                    out.push(LockAcq {
                        line: bl.number,
                        id: lock_id(&segs, f.impl_type.as_deref()),
                        guard: if chained { None } else { guard.clone() },
                        depth: bl.depth,
                    });
                }
            }
        }
    }
    out
}

/// Lines of `f` on which the acquisition `a` is still held.
fn lock_region(f: &FnInfo, a: &LockAcq) -> Vec<usize> {
    let mut out = Vec::new();
    let mut active = false;
    for bl in &f.body {
        if bl.number == a.line {
            active = true;
        }
        if !active {
            continue;
        }
        if bl.number > a.line {
            if a.guard.is_none() {
                break;
            }
            if bl.depth < a.depth {
                break;
            }
            if let Some(g) = &a.guard {
                let toks = tokenize(&bl.code);
                let dropped = toks.windows(4).any(|w| {
                    w[0].id() == Some("drop")
                        && w[1].is('(')
                        && w[2].id() == Some(g)
                        && w[3].is(')')
                });
                if dropped {
                    break;
                }
            }
        }
        out.push(bl.number);
    }
    out
}

fn collect_blocking(f: &FnInfo) -> Vec<(usize, String)> {
    let net_file = f.body.iter().any(|bl| bl.code.contains("TcpStream"));
    let mut out = Vec::new();
    for bl in &f.body {
        if bl.code.contains("thread::sleep") {
            out.push((bl.number, "thread::sleep".to_string()));
        }
        if bl.code.contains("eps_batch(") {
            out.push((bl.number, "eps_batch (score evaluation)".to_string()));
        }
        if net_file {
            for pat in [".write_all(", ".read_exact(", ".read(&", ".flush()"] {
                if bl.code.contains(pat) {
                    out.push((bl.number, format!("TcpStream I/O `{pat}`")));
                }
            }
        }
    }
    out
}

/// Taint sources: the documented reassociating kernel plus anything
/// pragma'd `allow(no-reassoc-on-sampler-path)` inside its body.
fn reassoc_sources(fns: &[FnInfo], files: &[(String, Vec<SourceLine>)]) -> Vec<usize> {
    let mut relocked: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for (label, lines) in files {
        for a in rules::collect_allows(lines) {
            if a.rule == "no-reassoc-on-sampler-path" {
                relocked.entry(label).or_default().push(a.covers);
            }
        }
    }
    let mut out = Vec::new();
    for (i, f) in fns.iter().enumerate() {
        let pragma_hit = relocked.get(f.file.as_str()).is_some_and(|lines| {
            lines.iter().any(|&l| f.line == l || f.body.iter().any(|bl| bl.number == l))
        });
        if f.name == "sum_sq_blocked" || pragma_hit {
            out.push(i);
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Graph rules
// ---------------------------------------------------------------------------

/// Serving-path roots for `panic-reachability` (file suffix, impl type,
/// fn name). Thread entry points are roots of their own: a panic there
/// kills a worker even though no submit() frame is on the stack.
const PANIC_ROOTS: &[(&str, Option<&str>, &str)] = &[
    ("server/router.rs", Some("Router"), "submit"),
    ("server/router.rs", None, "worker_loop"),
    ("engine/mod.rs", Some("Engine"), "run"),
    ("engine/mod.rs", Some("Engine"), "run_group"),
    ("engine/mod.rs", None, "pool_worker"),
    ("engine/scheduler.rs", Some("ScoreScheduler"), "eval"),
    ("server/net.rs", None, "accept_loop"),
    ("server/net.rs", None, "conn_worker"),
    ("server/net.rs", None, "handle_conn"),
    ("server/net.rs", None, "handle_line"),
    ("server/net.rs", None, "answer_oversized"),
    ("server/net.rs", None, "shed"),
    ("server/net.rs", None, "write_line"),
];

impl Graph {
    fn root_indices(&self) -> Vec<usize> {
        let mut out: Vec<usize> = (0..self.fns.len())
            .filter(|&i| {
                let f = &self.fns[i];
                PANIC_ROOTS.iter().any(|(file, ty, name)| {
                    f.file.ends_with(file) && f.impl_type.as_deref() == *ty && f.name == *name
                })
            })
            .collect();
        out.sort_by_key(|&i| (self.fns[i].file.clone(), self.fns[i].line));
        out
    }

    /// BFS from `roots`; returns `parent[i] = Some(caller)` for every
    /// reachable fn (roots map to themselves). Deterministic: roots in
    /// the given order, edges in per-fn sorted order.
    fn reach(&self, roots: &[usize]) -> BTreeMap<usize, usize> {
        let mut parent: BTreeMap<usize, usize> = BTreeMap::new();
        let mut queue: std::collections::VecDeque<usize> = std::collections::VecDeque::new();
        for &r in roots {
            if !parent.contains_key(&r) {
                parent.insert(r, r);
                queue.push_back(r);
            }
        }
        while let Some(i) = queue.pop_front() {
            for &(j, _) in &self.edges[i] {
                if !parent.contains_key(&j) {
                    parent.insert(j, i);
                    queue.push_back(j);
                }
            }
        }
        parent
    }

    /// Witness path root → `i` through the BFS parent map.
    fn witness(&self, parent: &BTreeMap<usize, usize>, mut i: usize) -> Vec<String> {
        let mut path = vec![self.fns[i].key()];
        while let Some(&p) = parent.get(&i) {
            if p == i {
                break;
            }
            path.push(self.fns[p].key());
            i = p;
        }
        path.reverse();
        path
    }

    pub fn panic_reachability(&self) -> Vec<Finding> {
        let roots = self.root_indices();
        let parent = self.reach(&roots);
        let mut out = Vec::new();
        for (&i, _) in &parent {
            let f = &self.fns[i];
            if f.in_test {
                continue;
            }
            for &(line, pat) in &self.panics[i] {
                let witness = self.witness(&parent, i);
                let hops = witness.len() - 1;
                out.push(Finding {
                    path: f.file.clone(),
                    line,
                    rule: "panic-reachability",
                    message: format!(
                        "`{pat}` in `{}` is reachable from serving root `{}` ({hops} call(s) \
                         deep); answer the error or justify with a pragma",
                        f.key(),
                        witness[0],
                    ),
                    witness,
                });
            }
        }
        out
    }

    /// Transitive closure of a per-fn seeded fact over call edges.
    fn transitive(&self, mut acc: Vec<BTreeSet<String>>) -> Vec<BTreeSet<String>> {
        loop {
            let mut changed = false;
            for i in 0..self.fns.len() {
                for &(j, _) in &self.edges[i] {
                    let add: Vec<String> =
                        acc[j].iter().filter(|s| !acc[i].contains(*s)).cloned().collect();
                    if !add.is_empty() {
                        acc[i].extend(add);
                        changed = true;
                    }
                }
            }
            if !changed {
                return acc;
            }
        }
    }

    pub fn lock_order(&self) -> Vec<Finding> {
        // Transitive lock sets: every lock a call into `i` may acquire.
        let seed: Vec<BTreeSet<String>> = (0..self.fns.len())
            .map(|i| self.locks[i].iter().map(|a| a.id.clone()).collect())
            .collect();
        let trans = self.transitive(seed);

        // Ordered edges `held → acquired`, first witness wins.
        let mut edges: BTreeMap<(String, String), (String, usize, String)> = BTreeMap::new();
        for (i, f) in self.fns.iter().enumerate() {
            for a in &self.locks[i] {
                let region = lock_region(f, a);
                for bl in &f.body {
                    if !region.contains(&bl.number) {
                        continue;
                    }
                    for b in &self.locks[i] {
                        if b.id != a.id && b.line == bl.number && b.line > a.line {
                            edges.entry((a.id.clone(), b.id.clone())).or_insert_with(|| {
                                (
                                    f.file.clone(),
                                    b.line,
                                    format!(
                                        "`{}` acquired at {}:{} while `{}` is held (since \
                                         line {})",
                                        b.id,
                                        short_path(&f.file),
                                        b.line,
                                        a.id,
                                        a.line
                                    ),
                                )
                            });
                        }
                    }
                    for &(j, line) in &self.edges[i] {
                        if line != bl.number {
                            continue;
                        }
                        for id in &trans[j] {
                            if *id != a.id {
                                edges.entry((a.id.clone(), id.clone())).or_insert_with(|| {
                                    (
                                        f.file.clone(),
                                        line,
                                        format!(
                                            "`{}` held in `{}` across the call to `{}` at \
                                             {}:{}, which may acquire `{id}`",
                                            a.id,
                                            f.key(),
                                            self.fns[j].key(),
                                            short_path(&f.file),
                                            line
                                        ),
                                    )
                                });
                            }
                        }
                    }
                }
            }
        }

        // Cycle detection over the lock-order digraph.
        let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
        for (a, b) in edges.keys() {
            adj.entry(a).or_default().push(b);
        }
        let mut seen_cycles: BTreeSet<Vec<String>> = BTreeSet::new();
        let mut out = Vec::new();
        let starts: Vec<&str> = adj.keys().copied().collect();
        for start in starts {
            let mut stack: Vec<&str> = vec![start];
            let mut iters: Vec<usize> = vec![0];
            while let Some(&node) = stack.last() {
                let next = adj.get(node).and_then(|v| v.get(*iters.last().expect("in step")));
                *iters.last_mut().expect("in step") += 1;
                match next {
                    None => {
                        stack.pop();
                        iters.pop();
                    }
                    Some(&n) => {
                        if let Some(pos) = stack.iter().position(|&s| s == n) {
                            let cycle: Vec<String> =
                                stack[pos..].iter().map(|s| s.to_string()).collect();
                            let mut canon = cycle.clone();
                            let min =
                                (0..canon.len()).min_by_key(|&k| &canon[k]).expect("non-empty");
                            canon.rotate_left(min);
                            if seen_cycles.insert(canon.clone()) {
                                let mut witness = Vec::new();
                                for k in 0..cycle.len() {
                                    let pair =
                                        (cycle[k].clone(), cycle[(k + 1) % cycle.len()].clone());
                                    if let Some((_, _, w)) = edges.get(&pair) {
                                        witness.push(w.clone());
                                    }
                                }
                                let (file, line, _) = edges
                                    [&(canon[0].clone(), canon[1 % canon.len()].clone())]
                                    .clone();
                                let mut ring = canon.clone();
                                ring.push(canon[0].clone());
                                out.push(Finding {
                                    path: file,
                                    line,
                                    rule: "lock-order",
                                    message: format!(
                                        "lock-order cycle `{}` — two threads interleaving \
                                         these acquisitions deadlock",
                                        ring.join(" -> ")
                                    ),
                                    witness,
                                });
                            }
                        } else if stack.len() < 32 {
                            stack.push(n);
                            iters.push(0);
                        }
                    }
                }
            }
        }
        out
    }

    pub fn blocking_in_lock(&self) -> Vec<Finding> {
        let seed: Vec<BTreeSet<String>> = (0..self.fns.len())
            .map(|i| self.blocking[i].iter().map(|(_, w)| w.clone()).collect())
            .collect();
        let trans = self.transitive(seed);
        let mut out = Vec::new();
        for (i, f) in self.fns.iter().enumerate() {
            if !rules::path_has_dir(&f.file, "engine") {
                continue;
            }
            for a in &self.locks[i] {
                let region = lock_region(f, a);
                for &(line, ref what) in &self.blocking[i] {
                    if region.contains(&line) && line >= a.line {
                        out.push(Finding {
                            path: f.file.clone(),
                            line,
                            rule: "blocking-in-lock",
                            message: format!(
                                "{what} while `{}` is held (acquired at line {}) stalls every \
                                 thread contending for the lock",
                                a.id, a.line
                            ),
                            witness: vec![f.key()],
                        });
                    }
                }
                for &(j, line) in &self.edges[i] {
                    if region.contains(&line) && !trans[j].is_empty() {
                        let what = trans[j].iter().next().expect("non-empty").clone();
                        out.push(Finding {
                            path: f.file.clone(),
                            line,
                            rule: "blocking-in-lock",
                            message: format!(
                                "call to `{}` may block ({what}) while `{}` is held (acquired \
                                 at line {})",
                                self.fns[j].key(),
                                a.id,
                                a.line
                            ),
                            witness: vec![f.key(), self.fns[j].key()],
                        });
                    }
                }
            }
        }
        out
    }

    pub fn reassoc_taint(&self) -> Vec<Finding> {
        let mut roots: Vec<usize> = (0..self.fns.len())
            .filter(|&i| {
                let f = &self.fns[i];
                let sampler_step = f.trait_name.as_deref() == Some("Sampler") && f.name == "step";
                let score_impl = f.trait_name.as_deref() == Some("ScoreModel");
                sampler_step || score_impl
            })
            .collect();
        roots.sort_by_key(|&i| (self.fns[i].file.clone(), self.fns[i].line));
        let parent = self.reach(&roots);
        let mut out = Vec::new();
        for &i in &self.reassoc_sources {
            if !parent.contains_key(&i) || roots.contains(&i) {
                continue;
            }
            let f = &self.fns[i];
            let witness = self.witness(&parent, i);
            out.push(Finding {
                path: f.file.clone(),
                line: f.line,
                rule: "reassoc-taint",
                message: format!(
                    "reassociating kernel `{}` is reachable from bit-identity root `{}` — \
                     re-lock the goldens or route through the scalar kernel",
                    f.key(),
                    witness[0]
                ),
                witness,
            });
        }
        out
    }
}

/// Run the four graph rules over a scanned file set and drop findings
/// suppressed by an allow pragma at the finding line.
pub fn check_files(files: &[(String, Vec<SourceLine>)]) -> Vec<Finding> {
    let g = Graph::build(files);
    let mut findings = Vec::new();
    findings.extend(g.panic_reachability());
    findings.extend(g.lock_order());
    findings.extend(g.blocking_in_lock());
    findings.extend(g.reassoc_taint());
    let allows: BTreeMap<&str, Vec<rules::Allow>> = files
        .iter()
        .map(|(label, lines)| (label.as_str(), rules::collect_allows(lines)))
        .collect();
    findings.retain(|f| {
        !allows.get(f.path.as_str()).is_some_and(|a| rules::allowed(a, f.rule, f.line))
    });
    findings
}

/// Render the resolver's blind spots for `--explain`: call sites where
/// several unrelated types define the method and no trait declares it,
/// so no edge was linked. Keeping these visible is the soundness
/// contract of the heuristic resolver.
pub(crate) fn unresolved_report(files: &[(String, Vec<SourceLine>)], max: usize) -> Vec<String> {
    let g = Graph::build(files);
    let mut out: Vec<String> = g
        .unresolved
        .iter()
        .take(max)
        .map(|u| {
            format!(
                "{}:{}: `{}` ({} candidates)",
                short_path(&u.file),
                u.line,
                u.name,
                u.candidates
            )
        })
        .collect();
    if g.unresolved.len() > max {
        out.push(format!("... and {} more", g.unresolved.len() - max));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn build(files: &[(&str, &str)]) -> Graph {
        let scanned: Vec<(String, Vec<SourceLine>)> =
            files.iter().map(|(l, t)| (l.to_string(), super::super::scan::scan(t))).collect();
        Graph::build(&scanned)
    }

    fn callees(g: &Graph, key: &str) -> Vec<String> {
        let i = g.fns.iter().position(|f| f.key() == key).expect("caller exists");
        g.edges[i].iter().map(|&(j, _)| g.fns[j].key()).collect()
    }

    #[test]
    fn self_calls_resolve_inside_the_enclosing_impl() {
        let a = "pub struct A;\nimpl A {\n    pub fn go(&self) {\n        self.m();\n        \
                 Self::fresh();\n    }\n    fn m(&self) {}\n    fn fresh() {}\n}\n";
        let b = "pub struct B;\nimpl B {\n    fn m(&self) {}\n}\n";
        let g = build(&[("a.rs", a), ("b.rs", b)]);
        assert_eq!(callees(&g, "a.rs::A::go"), vec!["a.rs::A::m", "a.rs::A::fresh"]);
        assert!(g.unresolved.is_empty(), "exact impl match is not ambiguous");
    }

    #[test]
    fn trait_dispatch_links_every_implementation() {
        let t = "pub trait T {\n    fn m(&self);\n}\n";
        let a = "impl T for A {\n    fn m(&self) {}\n}\n";
        let b = "impl T for B {\n    fn m(&self) {}\n}\n";
        let c = "pub fn go(x: &dyn T) {\n    x.m();\n}\n";
        let g = build(&[("a.rs", a), ("b.rs", b), ("c.rs", c), ("t.rs", t)]);
        assert_eq!(callees(&g, "c.rs::go"), vec!["a.rs::A::m", "b.rs::B::m"]);
        assert!(g.unresolved.is_empty());
    }

    #[test]
    fn ambiguous_methods_land_in_the_unresolved_bucket() {
        let a = "pub struct A;\nimpl A {\n    fn m(&self) {}\n}\n";
        let b = "pub struct B;\nimpl B {\n    fn m(&self) {}\n}\n";
        let c = "pub fn go(x: &A) {\n    x.m();\n}\n";
        let g = build(&[("a.rs", a), ("b.rs", b), ("c.rs", c)]);
        assert!(callees(&g, "c.rs::go").is_empty(), "no guessing between unrelated types");
        assert_eq!(g.unresolved.len(), 1);
        let u = &g.unresolved[0];
        assert_eq!((u.file.as_str(), u.line, u.name.as_str(), u.candidates), ("c.rs", 2, "m", 2));
    }

    #[test]
    fn declarations_and_macros_are_not_calls() {
        let src = "fn helper() {}\npub fn go() {\n    println!(\"{}\", 1);\n    helper();\n}\n";
        let g = build(&[("x.rs", src)]);
        assert_eq!(callees(&g, "x.rs::go"), vec!["x.rs::helper"]);
        assert!(callees(&g, "x.rs::helper").is_empty(), "a decl is not a self-call");
        assert!(g.unresolved.is_empty());
    }

    #[test]
    fn bare_calls_prefer_the_same_file_and_stay_unresolved_across_files() {
        let m1 = "pub fn mk() {}\npub fn use_local() {\n    mk();\n}\n";
        let m2 = "pub fn mk() {}\n";
        let m3 = "pub fn use_far() {\n    mk();\n}\n";
        let g = build(&[("m1.rs", m1), ("m2.rs", m2), ("m3.rs", m3)]);
        assert_eq!(callees(&g, "m1.rs::use_local"), vec!["m1.rs::mk"]);
        assert!(callees(&g, "m3.rs::use_far").is_empty());
        assert_eq!(g.unresolved.len(), 1, "cross-file bare call with two candidates");
        assert_eq!(g.unresolved[0].name, "mk");
    }

    #[test]
    fn module_qualified_calls_resolve_by_file_name_only() {
        let sync = "pub fn relock() {}\n";
        let eng = "pub fn go() {\n    crate::util::sync::relock();\n}\n\
                   pub fn go2() {\n    other::relock();\n}\n";
        let g = build(&[("engine/mod.rs", eng), ("util/sync.rs", sync)]);
        assert_eq!(callees(&g, "engine/mod.rs::go"), vec!["util/sync.rs::relock"]);
        assert!(callees(&g, "engine/mod.rs::go2").is_empty(), "wrong module: external, no guess");
    }

    #[test]
    fn dot_receiver_calls_never_fall_back_to_free_fns() {
        // `(-x).exp()` is a method on the float, not the free `exp`.
        let src = "pub fn exp(x: f64) -> f64 {\n    x\n}\npub fn go(x: f64) -> f64 {\n    \
                   (-x).exp()\n}\n";
        let g = build(&[("main.rs", src)]);
        assert!(callees(&g, "main.rs::go").is_empty());
        assert!(g.unresolved.is_empty(), "zero method candidates is external, not unresolved");
    }

    #[test]
    fn one_line_impl_blocks_attach_their_methods() {
        let src = "impl T for A { fn m(&self) {} }\n";
        let g = build(&[("a.rs", src)]);
        assert_eq!(g.fns.len(), 1);
        assert_eq!(g.fns[0].key(), "a.rs::A::m");
        assert_eq!(g.fns[0].trait_name.as_deref(), Some("T"));
    }
}
