//! `gddim lint` — the repo-invariant static-analysis pass.
//!
//! The serving stack holds its concurrency core to a small set of
//! mechanical invariants (poison-proof locking, SAFETY-documented
//! unsafe, no panics or exits on the serving path, bounded network
//! reads, no re-association on the bit-identical sampler path). Each is
//! cheap to state and easy to erode one edit at a time, so this module
//! enforces them as a versioned rule catalog over the source itself:
//!
//! - [`rules::CATALOG`] — the rules and their remediation plans
//!   (`--fix-plan` prints the latter);
//! - [`scan`] — the lexer-lite that makes line-level matching sound
//!   (comments, strings and `#[cfg(test)]` regions);
//! - [`graph`] — the whole-crate call graph behind the transitive rules
//!   (`lock-order`, `panic-reachability`, `blocking-in-lock`,
//!   `reassoc-taint`), on by default, disabled with `--no-graph`;
//! - [`run_cli`] — `gddim lint [PATHS] [--fix-plan] [--no-graph]
//!   [--format json] [--explain RULE]`, exit 0 clean / 1 findings /
//!   2 usage or I/O error.
//!
//! The pass runs over its own source: `cargo test` includes a self-test
//! that lints `src/` (graph rules included) and asserts zero findings,
//! and CI gates merges on the same invocation, so every exemption in
//! the tree carries a justified allow pragma (see [`rules`]).

pub mod graph;
pub mod rules;
pub mod scan;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

pub use rules::{Finding, CATALOG, CATALOG_VERSION};

use crate::util::cli::Args;
use crate::util::json::Json;
use crate::{Error, Result};

/// Run the *line* rules over one in-memory source file. `label` is the
/// path used in diagnostics and for the path-scoped rules (forward
/// slashes). The graph rules need the whole file set — see
/// [`lint_sources`].
pub fn lint_source(label: &str, text: &str) -> Vec<Finding> {
    rules::check_file(label, &scan::scan(text))
}

/// Lint a whole file set: line rules per file, then (when `graph_on`)
/// the call-graph rules across all of them. Findings come back sorted
/// by path, line, rule.
pub fn lint_sources(files: &[(String, String)], graph_on: bool) -> Vec<Finding> {
    let scanned: Vec<(String, Vec<scan::SourceLine>)> =
        files.iter().map(|(label, text)| (label.clone(), scan::scan(text))).collect();
    let mut findings = Vec::new();
    for (label, lines) in &scanned {
        findings.extend(rules::check_file(label, lines));
    }
    if graph_on {
        findings.extend(graph::check_files(&scanned));
    }
    findings.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    findings
}

/// Lint files and directories (recursively, `.rs` only).
pub fn lint_paths(paths: &[PathBuf], graph_on: bool) -> Result<Vec<Finding>> {
    Ok(lint_sources(&read_sources(paths)?, graph_on))
}

/// Collect `(label, text)` pairs for files and directories (recursively,
/// `.rs` only), labels with forward slashes, in sorted order.
fn read_sources(paths: &[PathBuf]) -> Result<Vec<(String, String)>> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut sources = Vec::new();
    for file in &files {
        let text = std::fs::read_to_string(file)
            .map_err(|e| Error::msg(format!("read {}: {e}", file.display())))?;
        let label = file.to_string_lossy().replace('\\', "/");
        sources.push((label, text));
    }
    Ok(sources)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if path.is_dir() {
        let entries = std::fs::read_dir(path)
            .map_err(|e| Error::msg(format!("read dir {}: {e}", path.display())))?;
        for entry in entries {
            let entry = entry.map_err(|e| Error::msg(format!("walk {}: {e}", path.display())))?;
            let p = entry.path();
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if p.is_dir() {
                if name == "target" || name.starts_with('.') {
                    continue;
                }
                collect_rs(&p, out)?;
            } else if name.ends_with(".rs") {
                out.push(p);
            }
        }
        Ok(())
    } else if path.is_file() {
        out.push(path.to_path_buf());
        Ok(())
    } else {
        Err(Error::msg(format!("lint: no such path {}", path.display())))
    }
}

/// One finding as a JSON object (`--format json` emits one per line,
/// which the CI problem-matcher turns into PR diff annotations).
fn finding_json(f: &Finding) -> Json {
    let mut o = BTreeMap::new();
    o.insert("rule".to_string(), Json::Str(f.rule.to_string()));
    o.insert("path".to_string(), Json::Str(f.path.clone()));
    o.insert("line".to_string(), Json::Num(f.line as f64));
    o.insert("message".to_string(), Json::Str(f.message.clone()));
    let witness = f.witness.iter().map(|w| Json::Str(w.clone())).collect();
    o.insert("witness".to_string(), Json::Arr(witness));
    Json::Obj(o)
}

/// `gddim lint [PATHS] [--fix-plan] [--no-graph] [--format json]
/// [--explain RULE]`. Returns the process exit code so `main.rs` owns
/// the actual `exit` (the no-process-exit rule applies here too).
pub fn run_cli(args: &Args) -> i32 {
    let mut paths: Vec<PathBuf> = args.positional.iter().skip(1).map(PathBuf::from).collect();
    // `--fix-plan rust/src` parses the path as the flag's value; claim
    // it back so flag order doesn't matter.
    if let Some(v) = args.get("fix-plan") {
        if v != "true" {
            paths.push(PathBuf::from(v));
        }
    }
    let explain = args.get("explain").filter(|v| *v != "true");
    if let Some(r) = explain {
        if rules::rule(r).is_none() {
            eprintln!("gddim lint: --explain {r}: no such rule in catalog v{CATALOG_VERSION}");
            return 2;
        }
    }
    let json = args.get("format").is_some_and(|v| v == "json");
    let graph_on = !args.has("no-graph");
    if paths.is_empty() {
        // From the repo root the crate lives under rust/; inside the
        // crate dir, src/ directly.
        let default = if Path::new("rust/src").is_dir() { "rust/src" } else { "src" };
        paths.push(PathBuf::from(default));
    }
    let sources = match read_sources(&paths) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("gddim lint: {e}");
            return 2;
        }
    };
    let findings = lint_sources(&sources, graph_on);
    for f in &findings {
        if json {
            println!("{}", finding_json(f).to_string_compact());
        } else {
            println!("{f}");
        }
    }
    if let Some(r) = explain {
        if let Some(rule) = rules::rule(r) {
            println!("\n[{}] {}", rule.id, rule.summary);
            println!("  fix: {}", rule.fix_plan);
            let mut any = false;
            for f in findings.iter().filter(|f| f.rule == r) {
                any = true;
                println!("  {}:{}", f.path, f.line);
                for (k, hop) in f.witness.iter().enumerate() {
                    let arrow = if k == 0 { "  " } else { "-> " };
                    println!("    {arrow}{hop}");
                }
            }
            if !any {
                println!("  no findings for this rule");
            }
            if graph_on {
                // Resolver blind spots: call sites the graph refused to
                // guess on. An empty list means full edge coverage.
                let scanned: Vec<(String, Vec<scan::SourceLine>)> =
                    sources.iter().map(|(l, t)| (l.clone(), scan::scan(t))).collect();
                let report = graph::unresolved_report(&scanned, 8);
                if report.is_empty() {
                    println!("  unresolved method calls: none (full edge coverage)");
                } else {
                    println!("  unresolved method calls (no edges linked):");
                    for entry in &report {
                        println!("    {entry}");
                    }
                }
            }
        }
    }
    if findings.is_empty() {
        if !json {
            println!("gddim lint: clean (catalog v{CATALOG_VERSION})");
        }
        return 0;
    }
    if args.has("fix-plan") && !json {
        println!("\nfix plan (catalog v{CATALOG_VERSION}):");
        let mut seen: Vec<&str> = Vec::new();
        for f in &findings {
            if seen.contains(&f.rule) {
                continue;
            }
            seen.push(f.rule);
            if let Some(r) = rules::rule(f.rule) {
                println!("  [{}] {}", r.id, r.fix_plan);
            }
        }
    }
    eprintln!("gddim lint: {} finding(s) (catalog v{CATALOG_VERSION})", findings.len());
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(label: &str, src: &str) -> Vec<&'static str> {
        lint_source(label, src).into_iter().map(|f| f.rule).collect()
    }

    /// Whole-fileset lint (graph rules on) over in-memory fixtures.
    fn lint_files(files: &[(&str, &str)]) -> Vec<Finding> {
        let owned: Vec<(String, String)> =
            files.iter().map(|(l, t)| (l.to_string(), t.to_string())).collect();
        lint_sources(&owned, true)
    }

    #[test]
    fn raw_lock_unwrap_is_flagged_and_the_helper_is_not() {
        let bad = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n";
        assert_eq!(rules_hit("util/x.rs", bad), vec!["no-raw-lock-unwrap"]);
        let bad_rw = "fn f(l: &std::sync::RwLock<u32>) { l.read().unwrap(); l.write().unwrap(); }\n";
        assert_eq!(rules_hit("util/x.rs", bad_rw).len(), 2);
        let good = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *lock_unpoisoned(m) }\n";
        assert!(rules_hit("util/x.rs", good).is_empty());
        let helper = "pub fn lock_unpoisoned(m: &Mutex<u32>) -> MutexGuard<'_, u32> {\n    \
                      m.lock().unwrap_or_else(|e| e.into_inner())\n}\n";
        assert!(rules_hit("util/sync.rs", helper).is_empty(), "unwrap_or_else is the fix");
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t(m: &M) { m.lock().unwrap(); }\n}\n";
        assert_eq!(rules_hit("util/x.rs", in_test), vec!["no-raw-lock-unwrap"], "tests too");
    }

    #[test]
    fn unsafe_needs_an_adjacent_safety_comment() {
        let bad = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_hit("engine/x.rs", bad), vec!["safety-comment"]);
        let good = "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller keeps p alive.\n    \
                    unsafe { *p }\n}\n";
        assert!(rules_hit("engine/x.rs", good).is_empty());
        // One SAFETY comment covers a run of unsafe impls, and a
        // multi-line statement whose unsafe sits below the comment.
        let run = "// SAFETY: no interior mutability.\nunsafe impl Send for X {}\n\
                   unsafe impl Sync for X {}\n";
        assert!(rules_hit("engine/x.rs", run).is_empty());
        let stmt = "// SAFETY: lifetime erasure only.\nlet m: &'static dyn M =\n    \
                    unsafe { std::mem::transmute(model) };\n";
        assert!(rules_hit("engine/x.rs", stmt).is_empty());
        let far = "// SAFETY: too far away.\nfn a() {}\nfn b() {}\nfn c() {}\n\
                   fn f(p: *const u32) -> u32 { unsafe { *p } }\n";
        assert_eq!(rules_hit("engine/x.rs", far), vec!["safety-comment"], "3 code lines between");
    }

    #[test]
    fn fma_is_fenced_off_the_sampler_path_unless_relocked() {
        let bad = "fn axpy(a: f64, x: f64, y: f64) -> f64 { a.mul_add(x, y) }\n";
        assert_eq!(rules_hit("math/simd.rs", bad), vec!["no-reassoc-on-sampler-path"]);
        assert_eq!(rules_hit("samplers/gddim.rs", bad), vec!["no-reassoc-on-sampler-path"]);
        assert!(rules_hit("server/net.rs", bad).is_empty(), "rule is path-scoped");
        let free_fn = "let z = crate::math::simd::mul_add(o, x, y);\n";
        assert!(rules_hit("math/linop.rs", free_fn).is_empty(), "free fn is elementwise, unfused");
        let relocked = "// gddim-lint: allow(no-reassoc-on-sampler-path) — golden re-lock: \
                        goldens regenerated in this PR\nlet z = a.mul_add(x, y);\n";
        assert!(rules_hit("math/simd.rs", relocked).is_empty());
    }

    #[test]
    fn process_exit_is_main_only() {
        let bad = "fn f() { std::process::exit(2); }\n";
        assert_eq!(rules_hit("server/demo.rs", bad), vec!["no-process-exit"]);
        assert!(rules_hit("main.rs", bad).is_empty(), "main.rs owns the exit");
        assert!(rules_hit("src/main.rs", bad).is_empty());
    }

    #[test]
    fn unbounded_reads_are_flagged_only_on_network_files() {
        let bad = "use std::net::TcpStream;\nfn f(r: &mut impl std::io::BufRead) {\n    \
                   let mut s = String::new();\n    r.read_line(&mut s);\n}\n";
        assert_eq!(rules_hit("server/net.rs", bad), vec!["bounded-io"]);
        let no_net = "fn f(r: &mut impl std::io::BufRead) {\n    let mut s = String::new();\n    \
                      r.read_line(&mut s);\n}\n";
        assert!(rules_hit("server/net.rs", no_net).is_empty(), "scoped to TCP-handling files");
        let lines_iter = "use std::net::TcpStream;\nfn f(r: impl std::io::BufRead) {\n    \
                          for _ in r.lines() {}\n}\n";
        assert_eq!(rules_hit("workload/mod.rs", lines_iter), vec!["bounded-io"]);
    }

    #[test]
    fn uncapped_artifact_reads_are_flagged_on_score_and_runtime_files() {
        let bad = "fn f(p: &std::path::Path) -> Vec<u8> { std::fs::read(p).unwrap() }\n";
        assert_eq!(rules_hit("score/net.rs", bad), vec!["bounded-io"]);
        let bad_str =
            "fn f(p: &std::path::Path) -> String { std::fs::read_to_string(p).unwrap() }\n";
        assert_eq!(rules_hit("runtime/manifest.rs", bad_str), vec!["bounded-io"]);
        assert!(rules_hit("workload/bench_report.rs", bad).is_empty(), "rule is path-scoped");
        let capped = "fn f(p: &std::path::Path) -> crate::Result<Vec<u8>> {\n    \
                      crate::util::io::read_capped(p, 64 << 20)\n}\n";
        assert!(rules_hit("score/net.rs", capped).is_empty(), "read_capped is the sanctioned path");
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t(p: &std::path::Path) { \
                       std::fs::read(p).unwrap(); }\n}\n";
        assert!(rules_hit("runtime/manifest.rs", in_test).is_empty(), "test code is exempt");
    }

    #[test]
    fn pragmas_require_a_justification_and_a_known_rule() {
        let naked = "// gddim-lint: allow(no-process-exit)\nstd::process::exit(2);\n";
        assert_eq!(rules_hit("server/x.rs", naked), vec!["pragma-justification"]);
        let dashed = "// gddim-lint: allow(no-process-exit) - short reason\n\
                      std::process::exit(2);\n";
        assert!(rules_hit("server/x.rs", dashed).is_empty(), "plain dash separator works");
        let unknown = "// gddim-lint: allow(no-such-rule) — reason\nlet x = 1;\n";
        assert_eq!(rules_hit("server/x.rs", unknown), vec!["pragma-justification"]);
        let wrong_rule = "// gddim-lint: allow(bounded-io) — reason\nstd::process::exit(2);\n";
        assert_eq!(
            rules_hit("server/x.rs", wrong_rule),
            vec!["no-process-exit"],
            "a pragma only suppresses its own rule"
        );
    }

    #[test]
    fn patterns_inside_strings_and_comments_never_fire() {
        let src = "fn f() {\n    // a comment mentioning .lock().unwrap() and unsafe\n    \
                   let s = \".unwrap() process::exit unsafe\";\n    let _ = s;\n}\n";
        assert!(rules_hit("server/x.rs", src).is_empty());
    }

    // -- graph-rule fixtures -------------------------------------------------

    const ROUTER_TO_HELPER: &str = "pub struct Router;\n\
                                    impl Router {\n    \
                                        pub fn submit(&self) {\n        helper();\n    }\n}\n\
                                    fn helper() {\n    grid_max();\n}\n";

    #[test]
    fn panic_reachability_fires_through_the_call_graph_with_a_witness() {
        let math = "pub fn grid_max(v: &[f64]) -> f64 {\n    *v.last().unwrap()\n}\n";
        let fs = lint_files(&[("server/router.rs", ROUTER_TO_HELPER), ("math/grid.rs", math)]);
        assert_eq!(fs.len(), 1, "{fs:?}",);
        let f = &fs[0];
        assert_eq!((f.rule, f.path.as_str(), f.line), ("panic-reachability", "math/grid.rs", 2));
        assert_eq!(
            f.witness,
            vec![
                "server/router.rs::Router::submit".to_string(),
                "server/router.rs::helper".to_string(),
                "math/grid.rs::grid_max".to_string(),
            ],
            "deterministic witness path root -> sink"
        );
    }

    #[test]
    fn panic_reachability_is_silent_without_a_path_from_a_root() {
        // Same panic site, but nothing on the serving path calls it.
        let math = "pub fn grid_max(v: &[f64]) -> f64 {\n    *v.last().unwrap()\n}\n";
        let clean = lint_files(&[("math/grid.rs", math)]);
        assert!(clean.is_empty(), "{clean:?}");
        // And a non-panicking helper under a root is clean too.
        let ok = "pub fn grid_max(v: &[f64]) -> Option<f64> {\n    v.last().copied()\n}\n";
        let fs = lint_files(&[("server/router.rs", ROUTER_TO_HELPER), ("math/grid.rs", ok)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn panic_reachability_respects_a_pragma_at_the_sink() {
        let math = "pub fn grid_max(v: &[f64]) -> f64 {\n    \
                    // gddim-lint: allow(panic-reachability) — grids are never empty by \
                    construction\n    \
                    *v.last().unwrap()\n}\n";
        let fs = lint_files(&[("server/router.rs", ROUTER_TO_HELPER), ("math/grid.rs", math)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    const LOCK_CYCLE: &str = "pub struct E {\n    \
                              a: std::sync::Mutex<u32>,\n    b: std::sync::Mutex<u32>,\n}\n\
                              impl E {\n    \
                              pub fn ab(&self) {\n        \
                              let g = lock_unpoisoned(&self.a);\n        \
                              self.with_b();\n        drop(g);\n    }\n    \
                              fn with_b(&self) {\n        \
                              let h = lock_unpoisoned(&self.b);\n        drop(h);\n    }\n    \
                              pub fn ba(&self) {\n        \
                              let h = lock_unpoisoned(&self.b);\n        \
                              self.with_a();\n        drop(h);\n    }\n    \
                              fn with_a(&self) {\n        \
                              let g = lock_unpoisoned(&self.a);\n        drop(g);\n    }\n}\n";

    #[test]
    fn lock_order_cycle_is_reported_with_both_edges_as_witness() {
        let fs = lint_files(&[("engine/locks.rs", LOCK_CYCLE)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        let f = &fs[0];
        assert_eq!(f.rule, "lock-order");
        assert!(f.message.contains("`E.a -> E.b -> E.a`"), "{}", f.message);
        assert_eq!(f.witness.len(), 2, "one witness line per cycle edge: {:?}", f.witness);
        assert!(f.witness[0].contains("E.a") && f.witness[0].contains("with_b"), "{:?}", f.witness);
    }

    #[test]
    fn lock_order_is_silent_when_acquisition_order_is_consistent() {
        // Same locks, but both paths take E.a before E.b.
        let src = LOCK_CYCLE.replace(
            "let h = lock_unpoisoned(&self.b);\n        self.with_a();",
            "let g = lock_unpoisoned(&self.a);\n        self.with_b();",
        );
        let fs = lint_files(&[("engine/locks.rs", &src)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn lock_order_respects_a_pragma_at_the_edge_site() {
        let src = LOCK_CYCLE.replace(
            "self.with_b();",
            "self.with_b(); // gddim-lint: allow(lock-order) — ordered by design: see module doc",
        );
        let fs = lint_files(&[("engine/locks.rs", &src)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn blocking_in_lock_fires_directly_and_through_a_callee() {
        let direct = "pub struct P;\nimpl P {\n    \
                      pub fn poll(&self) {\n        \
                      let g = lock_unpoisoned(&self.state);\n        \
                      std::thread::sleep(d);\n        drop(g);\n    }\n}\n";
        let fs = lint_files(&[("engine/pool.rs", direct)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!((fs[0].rule, fs[0].line), ("blocking-in-lock", 5));
        assert!(fs[0].message.contains("thread::sleep"), "{}", fs[0].message);

        let via = "pub struct P;\nimpl P {\n    \
                   pub fn poll(&self) {\n        \
                   let g = lock_unpoisoned(&self.state);\n        \
                   self.nap();\n        drop(g);\n    }\n    \
                   fn nap(&self) {\n        std::thread::sleep(d);\n    }\n}\n";
        let fs = lint_files(&[("engine/pool.rs", via)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!((fs[0].rule, fs[0].line), ("blocking-in-lock", 5));
        assert_eq!(fs[0].witness, vec!["engine/pool.rs::P::poll", "engine/pool.rs::P::nap"]);
    }

    #[test]
    fn blocking_in_lock_is_silent_once_the_guard_is_dropped_or_off_engine() {
        let after_drop = "pub struct P;\nimpl P {\n    \
                          pub fn poll(&self) {\n        \
                          let g = lock_unpoisoned(&self.state);\n        \
                          drop(g);\n        std::thread::sleep(d);\n    }\n}\n";
        assert!(lint_files(&[("engine/pool.rs", after_drop)]).is_empty());
        // Same code outside engine/ is out of scope for this rule.
        let direct = after_drop.replace("drop(g);\n        ", "");
        assert!(lint_files(&[("workload/mod.rs", &direct)]).is_empty());
        // A chained acquisition is a temporary: the binding holds the
        // recv() result, and the guard dies at the end of the statement.
        let temp = "pub struct P;\nimpl P {\n    \
                    pub fn poll(&self, rx: &M) {\n        \
                    let task = lock_unpoisoned(rx).recv();\n        \
                    std::thread::sleep(d);\n    }\n}\n";
        assert!(lint_files(&[("engine/pool.rs", temp)]).is_empty());
    }

    #[test]
    fn blocking_in_lock_respects_a_pragma() {
        let src = "pub struct P;\nimpl P {\n    \
                   pub fn poll(&self) {\n        \
                   let g = lock_unpoisoned(&self.state);\n        \
                   // gddim-lint: allow(blocking-in-lock) — bounded 1ms backoff, by design\n        \
                   std::thread::sleep(d);\n        drop(g);\n    }\n}\n";
        assert!(lint_files(&[("engine/pool.rs", src)]).is_empty());
    }

    const SAMPLER_ROOT: &str = "pub struct S;\nimpl Sampler for S {\n    \
                                fn step(&self) {\n        fast_norm();\n    }\n}\n";

    #[test]
    fn reassoc_taint_fires_from_sampler_step_to_a_relocked_kernel() {
        let simd = "pub fn fast_norm(x: f64, y: f64, z: f64) -> f64 {\n    \
                    x.mul_add(y, z) // gddim-lint: allow(no-reassoc-on-sampler-path) — golden \
                    re-lock: pinned\n}\n";
        let fs = lint_files(&[("samplers/s.rs", SAMPLER_ROOT), ("math/simd.rs", simd)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        let f = &fs[0];
        assert_eq!((f.rule, f.path.as_str(), f.line), ("reassoc-taint", "math/simd.rs", 1));
        assert_eq!(f.witness, vec!["samplers/s.rs::S::step", "math/simd.rs::fast_norm"]);
        // The blocked-sum kernel is a source by name, no pragma needed.
        let blocked = "pub fn sum_sq_blocked(v: &[f64]) -> f64 {\n    0.0\n}\n";
        let root = SAMPLER_ROOT.replace("fast_norm", "sum_sq_blocked");
        let fs = lint_files(&[("samplers/s.rs", &root), ("math/simd.rs", blocked)]);
        assert_eq!(fs.len(), 1, "{fs:?}");
        assert_eq!(fs[0].rule, "reassoc-taint");
    }

    #[test]
    fn reassoc_taint_is_silent_off_the_sampler_path_and_with_a_pragma() {
        // A clean kernel under the root: no taint.
        let clean = "pub fn fast_norm(x: f64, y: f64, z: f64) -> f64 {\n    x * y + z\n}\n";
        assert!(lint_files(&[("samplers/s.rs", SAMPLER_ROOT), ("math/simd.rs", clean)]).is_empty());
        // The relocked kernel with an explicit taint re-lock at the decl.
        let simd = "// gddim-lint: allow(reassoc-taint) — golden re-lock: sampler goldens pinned\n\
                    pub fn fast_norm(x: f64, y: f64, z: f64) -> f64 {\n    \
                    x.mul_add(y, z) // gddim-lint: allow(no-reassoc-on-sampler-path) — golden \
                    re-lock: pinned\n}\n";
        let fs = lint_files(&[("samplers/s.rs", SAMPLER_ROOT), ("math/simd.rs", simd)]);
        assert!(fs.is_empty(), "{fs:?}");
    }

    #[test]
    fn json_findings_round_trip_with_witness() {
        let math = "pub fn grid_max(v: &[f64]) -> f64 {\n    *v.last().unwrap()\n}\n";
        let fs = lint_files(&[("server/router.rs", ROUTER_TO_HELPER), ("math/grid.rs", math)]);
        let line = finding_json(&fs[0]).to_string_compact();
        assert!(!line.contains('\n'), "one object per line");
        let v = Json::parse(&line).expect("valid json");
        assert_eq!(v.get("rule").and_then(Json::as_str), Some("panic-reachability"));
        assert_eq!(v.get("path").and_then(Json::as_str), Some("math/grid.rs"));
        assert_eq!(v.get("line").and_then(Json::as_usize), Some(2));
        let witness = v.get("witness").and_then(Json::as_arr).expect("witness array");
        assert_eq!(witness.len(), 3);
        assert_eq!(witness[0].as_str(), Some("server/router.rs::Router::submit"));
    }

    #[test]
    fn catalog_is_well_formed() {
        assert_eq!(CATALOG_VERSION, 3);
        assert_eq!(CATALOG.len(), 10);
        for r in CATALOG {
            assert!(!r.id.is_empty() && !r.summary.is_empty() && !r.fix_plan.is_empty());
            assert_eq!(r.id, r.id.to_lowercase(), "rule ids are kebab-case");
        }
        let ids: std::collections::BTreeSet<&str> = CATALOG.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), CATALOG.len(), "rule ids are unique");
        for graph_rule in ["lock-order", "panic-reachability", "blocking-in-lock", "reassoc-taint"]
        {
            assert!(ids.contains(graph_rule), "catalog v3 carries the graph rules");
        }
    }

    /// The repo must lint clean against its own catalog — graph rules
    /// included: every exemption in the tree carries a justified pragma.
    /// This is the same check CI gates on (`gddim lint`), so a violation
    /// fails fast locally.
    #[test]
    fn self_test_repo_source_lints_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_paths(&[src], true).expect("walk src");
        let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(findings.is_empty(), "gddim lint must pass on its own repo:\n{rendered:?}");
    }
}
