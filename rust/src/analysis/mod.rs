//! `gddim lint` — the repo-invariant static-analysis pass.
//!
//! The serving stack holds its concurrency core to a small set of
//! mechanical invariants (poison-proof locking, SAFETY-documented
//! unsafe, no panics or exits on the serving path, bounded network
//! reads, no re-association on the bit-identical sampler path). Each is
//! cheap to state and easy to erode one edit at a time, so this module
//! enforces them as a versioned rule catalog over the source itself:
//!
//! - [`rules::CATALOG`] — the rules and their remediation plans
//!   (`--fix-plan` prints the latter);
//! - [`scan`] — the lexer-lite that makes line-level matching sound
//!   (comments, strings and `#[cfg(test)]` regions);
//! - [`run_cli`] — `gddim lint [PATHS] [--fix-plan]`, exit 0 clean /
//!   1 findings / 2 I/O error.
//!
//! The pass runs over its own source: `cargo test` includes a self-test
//! that lints `src/` and asserts zero findings, and CI gates merges on
//! the same invocation, so every exemption in the tree carries a
//! justified `gddim-lint: allow(...)` pragma (see [`rules`]).

pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

pub use rules::{Finding, CATALOG, CATALOG_VERSION};

use crate::util::cli::Args;
use crate::{Error, Result};

/// Lint one in-memory source file. `label` is the path used in
/// diagnostics and for the path-scoped rules (forward slashes).
pub fn lint_source(label: &str, text: &str) -> Vec<Finding> {
    rules::check_file(label, &scan::scan(text))
}

/// Lint files and directories (recursively, `.rs` only). Findings come
/// back sorted by path, then line.
pub fn lint_paths(paths: &[PathBuf]) -> Result<Vec<Finding>> {
    let mut files = Vec::new();
    for p in paths {
        collect_rs(p, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut findings = Vec::new();
    for file in &files {
        let text = std::fs::read_to_string(file)
            .map_err(|e| Error::msg(format!("read {}: {e}", file.display())))?;
        let label = file.to_string_lossy().replace('\\', "/");
        findings.extend(lint_source(&label, &text));
    }
    findings.sort_by(|a, b| (&a.path, a.line).cmp(&(&b.path, b.line)));
    Ok(findings)
}

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    if path.is_dir() {
        let entries = std::fs::read_dir(path)
            .map_err(|e| Error::msg(format!("read dir {}: {e}", path.display())))?;
        for entry in entries {
            let entry = entry.map_err(|e| Error::msg(format!("walk {}: {e}", path.display())))?;
            let p = entry.path();
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if p.is_dir() {
                if name == "target" || name.starts_with('.') {
                    continue;
                }
                collect_rs(&p, out)?;
            } else if name.ends_with(".rs") {
                out.push(p);
            }
        }
        Ok(())
    } else if path.is_file() {
        out.push(path.to_path_buf());
        Ok(())
    } else {
        Err(Error::msg(format!("lint: no such path {}", path.display())))
    }
}

/// `gddim lint [PATHS] [--fix-plan]`. Returns the process exit code so
/// `main.rs` owns the actual `exit` (the no-process-exit rule applies
/// here too).
pub fn run_cli(args: &Args) -> i32 {
    let mut paths: Vec<PathBuf> = args.positional.iter().skip(1).map(PathBuf::from).collect();
    // `--fix-plan rust/src` parses the path as the flag's value; claim
    // it back so flag order doesn't matter.
    if let Some(v) = args.get("fix-plan") {
        if v != "true" {
            paths.push(PathBuf::from(v));
        }
    }
    if paths.is_empty() {
        // From the repo root the crate lives under rust/; inside the
        // crate dir, src/ directly.
        let default = if Path::new("rust/src").is_dir() { "rust/src" } else { "src" };
        paths.push(PathBuf::from(default));
    }
    let findings = match lint_paths(&paths) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("gddim lint: {e}");
            return 2;
        }
    };
    for f in &findings {
        println!("{f}");
    }
    if findings.is_empty() {
        println!("gddim lint: clean (catalog v{CATALOG_VERSION})");
        return 0;
    }
    if args.has("fix-plan") {
        println!("\nfix plan (catalog v{CATALOG_VERSION}):");
        let mut seen: Vec<&str> = Vec::new();
        for f in &findings {
            if seen.contains(&f.rule) {
                continue;
            }
            seen.push(f.rule);
            if let Some(r) = rules::rule(f.rule) {
                println!("  [{}] {}", r.id, r.fix_plan);
            }
        }
    }
    eprintln!("gddim lint: {} finding(s) (catalog v{CATALOG_VERSION})", findings.len());
    1
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_hit(label: &str, src: &str) -> Vec<&'static str> {
        lint_source(label, src).into_iter().map(|f| f.rule).collect()
    }

    #[test]
    fn raw_lock_unwrap_is_flagged_and_the_helper_is_not() {
        let bad = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *m.lock().unwrap() }\n";
        assert_eq!(rules_hit("util/x.rs", bad), vec!["no-raw-lock-unwrap"]);
        let bad_rw = "fn f(l: &std::sync::RwLock<u32>) { l.read().unwrap(); l.write().unwrap(); }\n";
        assert_eq!(rules_hit("util/x.rs", bad_rw).len(), 2);
        let good = "fn f(m: &std::sync::Mutex<u32>) -> u32 { *lock_unpoisoned(m) }\n";
        assert!(rules_hit("util/x.rs", good).is_empty());
        let helper = "pub fn lock_unpoisoned(m: &Mutex<u32>) -> MutexGuard<'_, u32> {\n    \
                      m.lock().unwrap_or_else(|e| e.into_inner())\n}\n";
        assert!(rules_hit("util/sync.rs", helper).is_empty(), "unwrap_or_else is the fix");
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t(m: &M) { m.lock().unwrap(); }\n}\n";
        assert_eq!(rules_hit("util/x.rs", in_test), vec!["no-raw-lock-unwrap"], "tests too");
    }

    #[test]
    fn unsafe_needs_an_adjacent_safety_comment() {
        let bad = "fn f(p: *const u32) -> u32 {\n    unsafe { *p }\n}\n";
        assert_eq!(rules_hit("engine/x.rs", bad), vec!["safety-comment"]);
        let good = "fn f(p: *const u32) -> u32 {\n    // SAFETY: caller keeps p alive.\n    \
                    unsafe { *p }\n}\n";
        assert!(rules_hit("engine/x.rs", good).is_empty());
        // One SAFETY comment covers a run of unsafe impls, and a
        // multi-line statement whose unsafe sits below the comment.
        let run = "// SAFETY: no interior mutability.\nunsafe impl Send for X {}\n\
                   unsafe impl Sync for X {}\n";
        assert!(rules_hit("engine/x.rs", run).is_empty());
        let stmt = "// SAFETY: lifetime erasure only.\nlet m: &'static dyn M =\n    \
                    unsafe { std::mem::transmute(model) };\n";
        assert!(rules_hit("engine/x.rs", stmt).is_empty());
        let far = "// SAFETY: too far away.\nfn a() {}\nfn b() {}\nfn c() {}\n\
                   fn f(p: *const u32) -> u32 { unsafe { *p } }\n";
        assert_eq!(rules_hit("engine/x.rs", far), vec!["safety-comment"], "3 code lines between");
    }

    #[test]
    fn fma_is_fenced_off_the_sampler_path_unless_relocked() {
        let bad = "fn axpy(a: f64, x: f64, y: f64) -> f64 { a.mul_add(x, y) }\n";
        assert_eq!(rules_hit("math/simd.rs", bad), vec!["no-reassoc-on-sampler-path"]);
        assert_eq!(rules_hit("samplers/gddim.rs", bad), vec!["no-reassoc-on-sampler-path"]);
        assert!(rules_hit("server/net.rs", bad).is_empty(), "rule is path-scoped");
        let free_fn = "let z = crate::math::simd::mul_add(o, x, y);\n";
        assert!(rules_hit("math/linop.rs", free_fn).is_empty(), "free fn is elementwise, unfused");
        let relocked = "// gddim-lint: allow(no-reassoc-on-sampler-path) — golden re-lock: \
                        goldens regenerated in this PR\nlet z = a.mul_add(x, y);\n";
        assert!(rules_hit("math/simd.rs", relocked).is_empty());
    }

    #[test]
    fn unwrap_on_the_serving_path_is_flagged_outside_tests() {
        let bad = "fn f(x: Option<u32>) -> u32 { x.unwrap() }\n";
        assert_eq!(rules_hit("server/router.rs", bad), vec!["no-unwrap-in-server"]);
        assert_eq!(rules_hit("engine/mod.rs", bad), vec!["no-unwrap-in-server"]);
        assert!(rules_hit("math/simd.rs", bad).is_empty(), "rule is path-scoped");
        let expect = "fn f(x: Option<u32>) -> u32 { x.expect(\"invariant\") }\n";
        assert_eq!(rules_hit("server/router.rs", expect), vec!["no-unwrap-in-server"]);
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        assert!(rules_hit("server/router.rs", in_test).is_empty(), "test code is exempt");
        let tagged = "// gddim-lint: allow(no-unwrap-in-server) — construction-time fail-fast\n\
                      let h = spawn().expect(\"spawn\");\n";
        assert!(rules_hit("server/router.rs", tagged).is_empty());
        let trailing = "let h = spawn().expect(\"spawn\"); \
                        // gddim-lint: allow(no-unwrap-in-server) — fail-fast\n";
        assert!(rules_hit("server/router.rs", trailing).is_empty(), "trailing pragma, same line");
    }

    #[test]
    fn process_exit_is_main_only() {
        let bad = "fn f() { std::process::exit(2); }\n";
        assert_eq!(rules_hit("server/demo.rs", bad), vec!["no-process-exit"]);
        assert!(rules_hit("main.rs", bad).is_empty(), "main.rs owns the exit");
        assert!(rules_hit("src/main.rs", bad).is_empty());
    }

    #[test]
    fn unbounded_reads_are_flagged_only_on_network_files() {
        let bad = "use std::net::TcpStream;\nfn f(r: &mut impl std::io::BufRead) {\n    \
                   let mut s = String::new();\n    r.read_line(&mut s);\n}\n";
        assert_eq!(rules_hit("server/net.rs", bad), vec!["bounded-io"]);
        let no_net = "fn f(r: &mut impl std::io::BufRead) {\n    let mut s = String::new();\n    \
                      r.read_line(&mut s);\n}\n";
        assert!(rules_hit("server/net.rs", no_net).is_empty(), "scoped to TCP-handling files");
        let lines_iter = "use std::net::TcpStream;\nfn f(r: impl std::io::BufRead) {\n    \
                          for _ in r.lines() {}\n}\n";
        assert_eq!(rules_hit("workload/mod.rs", lines_iter), vec!["bounded-io"]);
    }

    #[test]
    fn uncapped_artifact_reads_are_flagged_on_score_and_runtime_files() {
        let bad = "fn f(p: &std::path::Path) -> Vec<u8> { std::fs::read(p).unwrap() }\n";
        assert_eq!(rules_hit("score/net.rs", bad), vec!["bounded-io"]);
        let bad_str =
            "fn f(p: &std::path::Path) -> String { std::fs::read_to_string(p).unwrap() }\n";
        assert_eq!(rules_hit("runtime/manifest.rs", bad_str), vec!["bounded-io"]);
        assert!(rules_hit("workload/bench_report.rs", bad).is_empty(), "rule is path-scoped");
        let capped = "fn f(p: &std::path::Path) -> crate::Result<Vec<u8>> {\n    \
                      crate::util::io::read_capped(p, 64 << 20)\n}\n";
        assert!(rules_hit("score/net.rs", capped).is_empty(), "read_capped is the sanctioned path");
        let in_test = "#[cfg(test)]\nmod tests {\n    fn t(p: &std::path::Path) { \
                       std::fs::read(p).unwrap(); }\n}\n";
        assert!(rules_hit("runtime/manifest.rs", in_test).is_empty(), "test code is exempt");
    }

    #[test]
    fn pragmas_require_a_justification_and_a_known_rule() {
        let naked = "// gddim-lint: allow(no-unwrap-in-server)\nlet x = f().unwrap();\n";
        assert_eq!(rules_hit("server/x.rs", naked), vec!["pragma-justification"]);
        let dashed = "// gddim-lint: allow(no-unwrap-in-server) - short reason\n\
                      let x = f().unwrap();\n";
        assert!(rules_hit("server/x.rs", dashed).is_empty(), "plain dash separator works");
        let unknown = "// gddim-lint: allow(no-such-rule) — reason\nlet x = 1;\n";
        assert_eq!(rules_hit("server/x.rs", unknown), vec!["pragma-justification"]);
        let wrong_rule = "// gddim-lint: allow(bounded-io) — reason\nlet x = f().unwrap();\n";
        assert_eq!(
            rules_hit("server/x.rs", wrong_rule),
            vec!["no-unwrap-in-server"],
            "a pragma only suppresses its own rule"
        );
    }

    #[test]
    fn patterns_inside_strings_and_comments_never_fire() {
        let src = "fn f() {\n    // a comment mentioning .lock().unwrap() and unsafe\n    \
                   let s = \".unwrap() process::exit unsafe\";\n    let _ = s;\n}\n";
        assert!(rules_hit("server/x.rs", src).is_empty());
    }

    #[test]
    fn catalog_is_well_formed() {
        assert_eq!(CATALOG_VERSION, 2);
        assert_eq!(CATALOG.len(), 7);
        for r in CATALOG {
            assert!(!r.id.is_empty() && !r.summary.is_empty() && !r.fix_plan.is_empty());
            assert_eq!(r.id, r.id.to_lowercase(), "rule ids are kebab-case");
        }
        let ids: std::collections::BTreeSet<&str> = CATALOG.iter().map(|r| r.id).collect();
        assert_eq!(ids.len(), CATALOG.len(), "rule ids are unique");
    }

    /// The repo must lint clean against its own catalog: every exemption
    /// in the tree carries a justified pragma. This is the same check CI
    /// gates on (`gddim lint`), so a violation fails fast locally.
    #[test]
    fn self_test_repo_source_lints_clean() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let findings = lint_paths(&[src]).expect("walk src");
        let rendered: Vec<String> = findings.iter().map(|f| f.to_string()).collect();
        assert!(findings.is_empty(), "gddim lint must pass on its own repo:\n{rendered:?}");
    }
}
