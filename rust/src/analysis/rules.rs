//! The versioned rule catalog and its line-level checkers.
//!
//! Every rule works on the channelled lines of [`super::scan`]: pattern
//! rules match the comment- and literal-stripped *code* channel, and
//! pragma / `SAFETY:` detection reads the *comment* channel, so strings
//! can never trip a rule and code can never fake an exemption.
//!
//! # Pragmas
//!
//! A finding is suppressed by a scoped allow pragma with a mandatory
//! justification:
//!
//! ```text
//! // gddim-lint: allow(panic-reachability) — why this site is sound
//! flagged_code();
//! ```
//!
//! A pragma on its own line covers the next line that carries code; a
//! trailing pragma covers its own line. The justification (anything
//! after a `—` or `-` separator) is not optional: an allow without one
//! is itself a finding (`pragma-justification`), so exemptions carry
//! their reasoning in the diff forever.

use super::scan::SourceLine;

/// Bumped whenever a rule is added, removed, or changes meaning, so a
/// CI failure can be traced to a catalog change rather than a code one.
/// v2: `bounded-io` also covers uncapped `fs::read*` on artifact-loading
/// files (`score/`, `runtime/`), where `util::io::read_capped` is the
/// sanctioned replacement.
/// v3: the call-graph rules land ([`super::graph`]): `lock-order`,
/// `panic-reachability`, `blocking-in-lock`, `reassoc-taint`. The
/// file-scoped `no-unwrap-in-server` rule is *replaced* by
/// `panic-reachability`, which follows the call graph from the serving
/// roots instead of stopping at the `server/`+`engine/` directory
/// boundary.
pub const CATALOG_VERSION: u32 = 3;

/// One catalog entry. `fix_plan` is the remediation line printed by
/// `gddim lint --fix-plan`.
pub struct Rule {
    pub id: &'static str,
    pub summary: &'static str,
    pub fix_plan: &'static str,
}

pub const CATALOG: &[Rule] = &[
    Rule {
        id: "no-raw-lock-unwrap",
        summary: "raw .lock()/.read()/.write() + .unwrap() panics every later caller once one \
                  thread poisons the lock",
        fix_plan: "route the acquisition through util::sync \
                   (lock_unpoisoned/read_unpoisoned/write_unpoisoned), which recovers the guard \
                   from a PoisonError",
    },
    Rule {
        id: "safety-comment",
        summary: "unsafe block or impl without an adjacent `// SAFETY:` comment stating the \
                  invariant it relies on",
        fix_plan: "write a `// SAFETY:` comment immediately above the unsafe site naming the \
                   invariant and who upholds it",
    },
    Rule {
        id: "no-reassoc-on-sampler-path",
        summary: "fused multiply-add on the sampler/score/math path changes bit patterns, \
                  breaking the bit-identity contract the golden tests pin",
        fix_plan: "use separate mul and add (the simd kernels are written to be bit-identical), \
                   or re-lock the goldens and tag the site with allow(no-reassoc-on-sampler-path) \
                   — golden re-lock: <evidence>",
    },
    Rule {
        id: "panic-reachability",
        summary: ".unwrap()/.expect()/panic! transitively reachable from a serving root \
                  (Router::submit, Engine::run/run_group, the server::net handlers, \
                  ScoreScheduler::eval) converts a recoverable condition into a thread panic",
        fix_plan: "return the error on the wire (WireResponse::Error) or make the helper return \
                   Result; for construction-time or invariant-backed sites, keep the panic and \
                   tag it with a justified allow pragma (`--explain panic-reachability` prints \
                   the call path)",
    },
    Rule {
        id: "lock-order",
        summary: "a cycle in the lock-order graph (lock A held while acquiring B somewhere, B \
                  held while acquiring A elsewhere) deadlocks two threads that interleave",
        fix_plan: "pick one global acquisition order and release the outer guard before taking \
                   the inner one (scope the guard in a block, or drop() it explicitly)",
    },
    Rule {
        id: "blocking-in-lock",
        summary: "TcpStream I/O, thread::sleep or an eps_batch score evaluation while an \
                  engine/scheduler lock is held stalls every thread contending for that lock",
        fix_plan: "copy what the critical section needs out of the guard, drop it, then block \
                   (see engine::scheduler::execute_pool: eval outside, publish under the lock)",
    },
    Rule {
        id: "reassoc-taint",
        summary: "a reassociating kernel (sum_sq_blocked, or anything pragma'd \
                  no-reassoc-on-sampler-path) reachable from Sampler::step or a ScoreModel \
                  implementation silently changes sampler bit patterns",
        fix_plan: "route the sampler path through the scalar kernel, or re-lock the goldens and \
                   tag the kernel with allow(reassoc-taint) — golden re-lock: <evidence>",
    },
    Rule {
        id: "no-process-exit",
        summary: "process::exit outside main.rs skips every destructor — engines, routers and \
                  sockets never drain",
        fix_plan: "bubble an error (or exit code) up to main.rs and exit there, after the stack \
                   has unwound",
    },
    Rule {
        id: "bounded-io",
        summary: "unbounded read (.read_line/.read_to_end/.read_to_string/.lines) on a file that \
                  handles network streams, or an uncapped fs::read* on an artifact-loading file \
                  (score/, runtime/), lets a peer or an oversized artifact grow a buffer without \
                  limit",
        fix_plan: "frame network reads through a bounded accumulator (see server::net's \
                   max_frame_len state machine), route artifact reads through \
                   util::io::read_capped, or tag trusted sites with a justified allow pragma",
    },
    Rule {
        id: "pragma-justification",
        summary: "gddim-lint allow pragma without a justification — exemptions must carry their \
                  reasoning",
        fix_plan: "append `— <why this site is sound>` to the pragma",
    },
];

/// Look up a catalog entry by id.
pub fn rule(id: &str) -> Option<&'static Rule> {
    CATALOG.iter().find(|r| r.id == id)
}

/// One diagnostic: `path:line: [rule] message`.
#[derive(Debug)]
pub struct Finding {
    /// Path as given to the walker (kept relative for stable output).
    pub path: String,
    /// 1-based line number.
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
    /// Call path backing a graph-rule finding (root → sink), empty for
    /// line rules. Printed by `--explain RULE` and `--format json`.
    pub witness: Vec<String>,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.path, self.line, self.rule, self.message)
    }
}

/// A parsed allow pragma, resolved to the line it covers. Shared with
/// [`super::graph`], which suppresses graph-rule findings the same way
/// (the pragma sits at the finding's sink line).
pub(crate) struct Allow {
    pub(crate) rule: String,
    /// 1-based line the pragma exempts.
    pub(crate) covers: usize,
    justified: bool,
    /// 1-based line the pragma itself sits on (for diagnostics).
    at: usize,
}

/// Extract allow pragmas from the comment channel. A pragma on a line
/// with no code covers the next line that has code; a trailing pragma
/// covers its own line.
pub(crate) fn collect_allows(lines: &[SourceLine]) -> Vec<Allow> {
    let mut out = Vec::new();
    for (idx, line) in lines.iter().enumerate() {
        let Some(pos) = line.comment.find("gddim-lint:") else { continue };
        let rest = &line.comment[pos + "gddim-lint:".len()..];
        let Some(open) = rest.find("allow(") else { continue };
        let after = &rest[open + "allow(".len()..];
        let Some(close) = after.find(')') else { continue };
        let rule = after[..close].trim().to_string();
        let tail = &after[close + 1..];
        let justified = ["—", "--", "-"]
            .iter()
            .find_map(|sep| tail.split_once(sep))
            .map(|(_, j)| !j.trim().is_empty())
            .unwrap_or(false);
        let covers = if line.code.trim().is_empty() {
            // Own-line pragma: the next line carrying code.
            lines[idx + 1..]
                .iter()
                .find(|l| !l.code.trim().is_empty())
                .map(|l| l.number)
                .unwrap_or(line.number)
        } else {
            line.number
        };
        out.push(Allow { rule, covers, justified, at: line.number });
    }
    out
}

pub(crate) fn allowed(allows: &[Allow], rule_id: &str, line: usize) -> bool {
    allows.iter().any(|a| a.covers == line && a.rule == rule_id)
}

/// Does `code` contain `word` as a standalone token (not an identifier
/// substring)?
fn has_word(code: &str, word: &str) -> bool {
    let mut start = 0;
    while let Some(pos) = code[start..].find(word) {
        let at = start + pos;
        let before_ok = at == 0 || !code[..at].chars().next_back().is_some_and(is_ident);
        let after = at + word.len();
        let after_ok = !code[after..].chars().next().is_some_and(is_ident);
        if before_ok && after_ok {
            return true;
        }
        start = after;
    }
    false
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Is the `unsafe` on `lines[idx]` covered by a `SAFETY` comment?
/// Accepts a trailing comment on the same line, or a comment block
/// above, looking through at most two interleaved code lines (a
/// multi-line statement, or a run of `unsafe impl`s sharing one
/// comment) within a 12-line window.
fn has_safety_comment(lines: &[SourceLine], idx: usize) -> bool {
    if lines[idx].comment.contains("SAFETY") {
        return true;
    }
    let mut skipped_code = 0usize;
    let mut i = idx;
    while i > 0 && idx - i < 12 {
        i -= 1;
        let l = &lines[i];
        let has_comment = !l.comment.trim().is_empty();
        let has_code = !l.code.trim().is_empty();
        if has_comment && l.comment.contains("SAFETY") {
            return true;
        }
        if has_comment && !has_code {
            continue;
        }
        if has_code {
            skipped_code += 1;
            if skipped_code > 2 {
                return false;
            }
            continue;
        }
        // Blank line: the comment block (if any) has ended.
        return false;
    }
    false
}

pub(crate) fn path_has_dir(path: &str, dir: &str) -> bool {
    path.split('/').any(|seg| seg == dir)
}

/// Push `message` as a finding unless a pragma on `line` allows it.
fn flag(
    out: &mut Vec<Finding>,
    allows: &[Allow],
    path: &str,
    rule_id: &'static str,
    line: usize,
    message: String,
) {
    if !allowed(allows, rule_id, line) {
        out.push(Finding {
            path: path.to_string(),
            line,
            rule: rule_id,
            message,
            witness: Vec::new(),
        });
    }
}

/// Run every rule over one scanned file. `path` should be the
/// repo-relative path (forward slashes) for stable diagnostics.
pub fn check_file(path: &str, lines: &[SourceLine]) -> Vec<Finding> {
    let allows = collect_allows(lines);
    let mut out = Vec::new();

    // pragma-justification: an allow without a reason is a finding at
    // the pragma's own line (and the allow still suppresses its target —
    // the justification finding is the enforcement).
    for a in &allows {
        if !a.justified {
            out.push(Finding {
                path: path.to_string(),
                line: a.at,
                rule: "pragma-justification",
                message: format!(
                    "allow({}) has no justification — append `— <why this site is sound>`",
                    a.rule
                ),
                witness: Vec::new(),
            });
        }
        if rule(&a.rule).is_none() {
            out.push(Finding {
                path: path.to_string(),
                line: a.at,
                rule: "pragma-justification",
                message: format!("allow({}) names no rule in catalog v{CATALOG_VERSION}", a.rule),
                witness: Vec::new(),
            });
        }
    }

    let is_main = path == "main.rs" || path.ends_with("/main.rs");
    let sampler_path =
        path_has_dir(path, "math") || path_has_dir(path, "score") || path_has_dir(path, "samplers");
    let net_file = lines
        .iter()
        .any(|l| l.code.contains("TcpStream") || l.code.contains("TcpListener"));
    // Artifact loaders (learned-score weights, manifests, HLO text) read
    // on-disk files whose size the server does not control; they must go
    // through the size-capped helpers in `util::io`.
    let artifact_file = path_has_dir(path, "score") || path_has_dir(path, "runtime");

    for (idx, line) in lines.iter().enumerate() {
        let code = line.code.as_str();
        let n = line.number;

        for pat in [".lock().unwrap()", ".read().unwrap()", ".write().unwrap()"] {
            if code.contains(pat) {
                let msg = format!("`{pat}` panics on a poisoned lock; use util::sync helpers");
                flag(&mut out, &allows, path, "no-raw-lock-unwrap", n, msg);
            }
        }

        if has_word(code, "unsafe") && !has_safety_comment(lines, idx) {
            let msg = "unsafe site without an adjacent `// SAFETY:` comment".to_string();
            flag(&mut out, &allows, path, "safety-comment", n, msg);
        }

        if sampler_path {
            for pat in [".mul_add(", "fmaf32", "fmaf64", "fmadd"] {
                if code.contains(pat) {
                    let msg =
                        format!("`{pat}` fuses the rounding step and breaks bit-identity goldens");
                    flag(&mut out, &allows, path, "no-reassoc-on-sampler-path", n, msg);
                }
            }
        }

        if !is_main && code.contains("process::exit") {
            let msg = "process::exit outside main.rs skips destructors".to_string();
            flag(&mut out, &allows, path, "no-process-exit", n, msg);
        }

        if net_file && !line.in_test {
            for pat in [".read_line(", ".read_to_end(", ".read_to_string(", ".lines()"] {
                if code.contains(pat) {
                    let msg = format!(
                        "`{pat}` is unbounded on a network-handling file; frame with a byte cap"
                    );
                    flag(&mut out, &allows, path, "bounded-io", n, msg);
                }
            }
        }

        if artifact_file && !line.in_test {
            for pat in ["fs::read(", "fs::read_to_string("] {
                if code.contains(pat) {
                    let msg = format!(
                        "`{pat}` is uncapped on an artifact-loading file; use \
                         util::io::read_capped"
                    );
                    flag(&mut out, &allows, path, "bounded-io", n, msg);
                }
            }
        }
    }
    out
}
