//! Lexer-lite for the lint pass: a per-line view of a Rust source file
//! with comment text and literal contents separated out of the *code*
//! channel, plus `#[cfg(test)]` region tracking.
//!
//! This is deliberately not a parser. The line rules in [`super::rules`]
//! and the item/call-site parser in [`super::graph`] only need three
//! things to be reliable — where comments are, where string/char
//! literals are, and which lines sit inside test-gated items — and a
//! hand-rolled character state machine gets exactly those right:
//!
//! - nested block comments (`/* /* */ */`), line comments, doc comments;
//! - string, byte-string, raw-string (`r#"…"#`) and char literals, with
//!   the `'a` lifetime vs `'a'` char-literal ambiguity resolved by
//!   lookahead;
//! - `#[cfg(test)]` attributes gate the following brace region (module
//!   or fn), tracked by brace counting over the already-stripped code
//!   channel so braces inside strings or comments cannot desync it.
//!
//! Pattern rules match against [`SourceLine::code`], so `".unwrap()"`
//! inside a string (say, a lint fixture) can never produce a finding,
//! and pragma/SAFETY detection reads [`SourceLine::comment`], so code
//! can never fake a comment.

/// One physical source line, split into channels.
pub struct SourceLine {
    /// 1-based line number.
    pub number: usize,
    /// Code with comments removed and literal contents blanked (the
    /// delimiting quotes survive, so token adjacency is preserved).
    pub code: String,
    /// Concatenated comment text appearing on this line (line comments
    /// and the per-line slices of block comments, markers included).
    pub comment: String,
    /// Line sits inside a `#[cfg(test)]`-gated brace region.
    pub in_test: bool,
}

#[derive(PartialEq)]
enum Mode {
    Code,
    LineComment,
    /// Nesting depth of `/* … */`.
    BlockComment(u32),
    Str,
    /// Number of `#`s that (with a quote) terminate the raw string.
    RawStr(u32),
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

/// Split `text` into channelled lines. Total work is linear in the file.
pub fn scan(text: &str) -> Vec<SourceLine> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines: Vec<(String, String)> = vec![(String::new(), String::new())];
    let mut mode = Mode::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            if mode == Mode::LineComment {
                mode = Mode::Code;
            }
            lines.push((String::new(), String::new()));
            i += 1;
            continue;
        }
        let last = lines.last_mut().expect("lines starts non-empty");
        match mode {
            Mode::Code => {
                if c == '/' && chars.get(i + 1) == Some(&'/') {
                    mode = Mode::LineComment;
                    last.1.push_str("//");
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    mode = Mode::BlockComment(1);
                    last.1.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    last.0.push('"');
                    mode = Mode::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(chars[i - 1])) {
                    // Raw (or raw-byte) string prefix: `r`/`br` + `#`* + `"`.
                    let mut j = i + 1;
                    if c == 'b' && chars.get(j) == Some(&'r') {
                        j += 1;
                    }
                    let mut hashes = 0u32;
                    while chars.get(j) == Some(&'#') {
                        hashes += 1;
                        j += 1;
                    }
                    if (c == 'r' || j > i + 1) && chars.get(j) == Some(&'"') {
                        for &p in &chars[i..=j] {
                            last.0.push(p);
                        }
                        mode = Mode::RawStr(hashes);
                        i = j + 1;
                    } else {
                        last.0.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime: a literal is `'\…'` or
                    // `'x'`; anything else (`'a`, `'static`) is a
                    // lifetime and the quote passes through as code.
                    let j = i + 1;
                    let escaped = chars.get(j) == Some(&'\\');
                    let single = chars.get(j).is_some() && chars.get(j + 1) == Some(&'\'');
                    if escaped || single {
                        last.0.push('\'');
                        i += 1;
                        while i < chars.len() && chars[i] != '\'' && chars[i] != '\n' {
                            if chars[i] == '\\' && i + 1 < chars.len() && chars[i + 1] != '\n' {
                                i += 1;
                            }
                            i += 1;
                        }
                        if chars.get(i) == Some(&'\'') {
                            last.0.push('\'');
                            i += 1;
                        }
                    } else {
                        last.0.push('\'');
                        i += 1;
                    }
                } else {
                    last.0.push(c);
                    i += 1;
                }
            }
            Mode::LineComment => {
                last.1.push(c);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if c == '*' && chars.get(i + 1) == Some(&'/') {
                    last.1.push_str("*/");
                    mode = if depth == 1 { Mode::Code } else { Mode::BlockComment(depth - 1) };
                    i += 2;
                } else if c == '/' && chars.get(i + 1) == Some(&'*') {
                    last.1.push_str("/*");
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    last.1.push(c);
                    i += 1;
                }
            }
            Mode::Str => {
                if c == '\\' {
                    // Skip the escaped char — unless it is the newline of
                    // a line-continuation, which must still break lines.
                    if i + 1 < chars.len() && chars[i + 1] != '\n' {
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    last.0.push('"');
                    mode = Mode::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            Mode::RawStr(hashes) => {
                if c == '"' {
                    let mut j = i + 1;
                    let mut seen = 0u32;
                    while seen < hashes && chars.get(j) == Some(&'#') {
                        seen += 1;
                        j += 1;
                    }
                    if seen == hashes {
                        last.0.push('"');
                        for _ in 0..hashes {
                            last.0.push('#');
                        }
                        mode = Mode::Code;
                        i = j;
                    } else {
                        i += 1;
                    }
                } else {
                    i += 1;
                }
            }
        }
    }
    mark_test_regions(lines)
}

/// Second pass: brace-count the stripped code channel to mark every line
/// inside a `#[cfg(test)]`-gated region (the attribute gates the next
/// brace region to open — a `mod tests { … }` or a bare `#[test]`-style
/// fn). Regions nest; a stack of opening depths tracks them.
fn mark_test_regions(lines: Vec<(String, String)>) -> Vec<SourceLine> {
    let mut out = Vec::with_capacity(lines.len());
    let mut depth: i64 = 0;
    let mut regions: Vec<i64> = Vec::new();
    let mut pending = false;
    for (idx, (code, comment)) in lines.into_iter().enumerate() {
        if code.contains("#[cfg(test)]") {
            pending = true;
        }
        let mut in_test = pending || !regions.is_empty();
        for ch in code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        regions.push(depth);
                        pending = false;
                        in_test = true;
                    }
                }
                '}' => {
                    if regions.last() == Some(&depth) {
                        regions.pop();
                    }
                    depth -= 1;
                }
                _ => {}
            }
        }
        out.push(SourceLine { number: idx + 1, code, comment, in_test });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(text: &str) -> Vec<String> {
        scan(text).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn strings_and_comments_leave_the_code_channel() {
        let src = "let a = m.lock(); // .lock().unwrap() in a comment\nlet b = \".unwrap()\";\n";
        let lines = scan(src);
        assert!(!lines[0].code.contains(".unwrap()"), "{}", lines[0].code);
        assert!(lines[0].comment.contains(".lock().unwrap()"));
        assert_eq!(lines[1].code, "let b = \"\";");
    }

    #[test]
    fn nested_block_comments_and_raw_strings() {
        let src = "/* outer /* inner */ still */ code();\nlet r = r#\"has \".unwrap()\" inside\"#;\n";
        let c = codes(src);
        assert_eq!(c[0].trim(), "code();");
        assert_eq!(c[1], "let r = r#\"\"#;");
    }

    #[test]
    fn lifetimes_are_not_char_literals() {
        let src = "fn f<'a>(x: &'a str) -> &'a str { x }\nlet c = 'x'; let n = '\\n'; // tail\n";
        let c = codes(src);
        assert!(c[0].contains("<'a>"), "{}", c[0]);
        assert!(c[0].contains("{ x }"), "lifetime must not swallow code: {}", c[0]);
        assert_eq!(c[1], "let c = ''; let n = ''; ");
    }

    #[test]
    fn multiline_strings_span_lines_without_leaking_code() {
        let src = "let s = \"first\nsecond .unwrap()\nthird\"; done();\n";
        let c = codes(src);
        assert_eq!(c[0], "let s = \"");
        assert_eq!(c[1], "");
        assert_eq!(c[2], "\"; done();");
    }

    #[test]
    fn cfg_test_regions_cover_the_module_and_nothing_else() {
        let src = "fn live() { x.unwrap(); }\n#[cfg(test)]\nmod tests {\n    fn t() { y.unwrap(); }\n}\nfn after() {}\n";
        let lines = scan(src);
        assert!(!lines[0].in_test);
        assert!(lines[1].in_test, "the attribute line is part of the region");
        assert!(lines[2].in_test);
        assert!(lines[3].in_test);
        assert!(lines[4].in_test, "closing brace still inside");
        assert!(!lines[5].in_test, "region ends with its brace");
    }

    #[test]
    fn braces_in_strings_do_not_desync_test_tracking() {
        let src = "#[cfg(test)]\nmod tests {\n    let s = \"}\";\n    fn t() {}\n}\nfn after() {}\n";
        let lines = scan(src);
        assert!(lines[3].in_test, "stray brace inside a string must not close the region");
        assert!(!lines[5].in_test);
    }
}
