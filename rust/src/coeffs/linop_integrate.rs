//! Quadrature and ODE integration lifted to [`LinOp`]-valued functions.
//!
//! All coefficient operators of one process share a structure (scalar /
//! diag / 2×2 block), so we flatten to a coefficient vector, reuse the
//! scalar machinery, and re-wrap.

use std::sync::Arc;

use crate::math::linop::LinOp;
use crate::math::mat2::Mat2;
use crate::math::ode::{rk4_step, Rk4Scratch};
use crate::math::quad::integrate_gl_vec;

/// Flatten a LinOp into its coefficient vector.
pub fn flatten(op: &LinOp) -> Vec<f64> {
    match op {
        LinOp::Scalar(s) => vec![*s],
        LinOp::Diag(d) => d.as_ref().clone(),
        LinOp::Block2(m) => m.to_array().to_vec(),
    }
}

/// Rebuild a LinOp with the same structure as `like` from coefficients.
pub fn unflatten(like: &LinOp, v: &[f64]) -> LinOp {
    match like {
        LinOp::Scalar(_) => LinOp::Scalar(v[0]),
        LinOp::Diag(_) => LinOp::Diag(Arc::new(v.to_vec())),
        LinOp::Block2(_) => LinOp::Block2(Mat2::from_array([v[0], v[1], v[2], v[3]])),
    }
}

/// `∫_a^b f(τ) dτ` for a LinOp-valued integrand with `n`-point
/// Gauss–Legendre (works with a > b; orientation in the affine map).
pub fn integrate_linop<F: Fn(f64) -> LinOp>(f: F, a: f64, b: f64, n: usize) -> LinOp {
    let probe = f(0.5 * (a + b));
    let k = flatten(&probe).len();
    let mut out = vec![0.0; k];
    integrate_gl_vec(
        |t, buf: &mut [f64]| {
            let v = flatten(&f(t));
            buf.copy_from_slice(&v);
        },
        a,
        b,
        n,
        &mut out,
    );
    unflatten(&probe, &out)
}

/// Composite Gauss–Legendre for LinOp integrands with a quadratic node
/// concentration toward the *lower* endpoint — the Type-II integrands
/// carry `K_τ^{-T} ~ (1−α_τ)^{-1/2}`-style behaviour near `t_min`, where
/// plain GL converges slowly. `pieces = 1` reduces to plain GL.
pub fn integrate_linop_composite<F: Fn(f64) -> LinOp>(
    f: F,
    a: f64,
    b: f64,
    n: usize,
    pieces: usize,
) -> LinOp {
    if pieces <= 1 {
        return integrate_linop(f, a, b, n);
    }
    let (lo, hi, sign) = if a < b { (a, b, 1.0) } else { (b, a, -1.0) };
    let mut total: Option<LinOp> = None;
    for k in 0..pieces {
        // Quadratic spacing: segment edges at lo + (hi−lo)·(k/p)².
        let x0 = lo + (hi - lo) * (k as f64 / pieces as f64).powi(2);
        let x1 = lo + (hi - lo) * ((k + 1) as f64 / pieces as f64).powi(2);
        let seg = integrate_linop(&f, x0, x1, n);
        total = Some(match total {
            None => seg,
            Some(t) => t.add(&seg),
        });
    }
    total.unwrap().scale(sign)
}

/// Solve the matrix ODE `dY/dτ = rhs(τ, Y)` from `t0` to `t1` (either
/// direction) with `nsteps` RK4 steps, where `Y` is LinOp-structured.
pub fn solve_linop_ode<F: Fn(f64, &LinOp) -> LinOp>(
    rhs: F,
    t0: f64,
    t1: f64,
    nsteps: usize,
    y0: LinOp,
) -> LinOp {
    let proto = y0.clone();
    let mut y = flatten(&y0);
    let mut scratch = Rk4Scratch::default();
    let h = (t1 - t0) / nsteps as f64;
    let mut f = |t: f64, y: &[f64], dy: &mut [f64]| {
        let d = rhs(t, &unflatten(&proto, y));
        dy.copy_from_slice(&flatten(&d));
    };
    let mut t = t0;
    for _ in 0..nsteps {
        rk4_step(&mut f, t, h, &mut y, &mut scratch);
        t += h;
    }
    unflatten(&proto, &y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    #[test]
    fn flatten_roundtrip() {
        let ops = [
            LinOp::Scalar(2.5),
            LinOp::diag(vec![1.0, -2.0]),
            LinOp::Block2(Mat2::new(1.0, 2.0, 3.0, 4.0)),
        ];
        for op in &ops {
            let back = unflatten(op, &flatten(op));
            assert!(op.dist(&back) < 1e-15);
        }
    }

    #[test]
    fn integrate_scalar_linop() {
        // ∫_0^1 t² I dt = I/3.
        let r = integrate_linop(|t| LinOp::Scalar(t * t), 0.0, 1.0, 16);
        assert!(r.dist(&LinOp::Scalar(1.0 / 3.0)) < 1e-12);
    }

    #[test]
    fn integrate_block_linop_reversed() {
        // Reverse-time orientation: ∫_1^0 M t dt = −M/2.
        let m = Mat2::new(1.0, 0.0, 2.0, -1.0);
        let r = integrate_linop(|t| LinOp::Block2(m.scale(t)), 1.0, 0.0, 16);
        assert!(r.dist(&LinOp::Block2(m.scale(-0.5))) < 1e-12);
    }

    #[test]
    fn solve_matrix_exponential() {
        // dY/dt = A Y, Y(0)=I -> Y(1) = expm(A).
        let a = Mat2::new(0.3, -0.2, 0.5, 0.1);
        let y = solve_linop_ode(
            |_t, y| LinOp::Block2(a).matmul(y),
            0.0,
            1.0,
            200,
            LinOp::Block2(Mat2::IDENT),
        );
        assert!(y.dist(&LinOp::Block2(a.expm())) < 1e-9);
    }

    #[test]
    fn solve_backwards() {
        // dy/dt = y integrated from 1 to 0: y(0) = y(1)·e^{-1}.
        let y = solve_linop_ode(|_t, y| y.clone(), 1.0, 0.0, 200, LinOp::Scalar(3.0));
        match y {
            LinOp::Scalar(v) => assert!(close(v, 3.0 * (-1.0f64).exp(), 1e-9, 0.0)),
            _ => unreachable!(),
        }
    }
}
