//! Stage I — offline preparation of gDDIM (paper App. C.3 / C.4).
//!
//! Everything a sampler run needs is computed **once** per
//! (process, time grid, K_t, q, λ) and packaged as a [`SamplerPlan`]:
//!
//! * Type-I quantities (matrix ODE solutions): `R_t` comes from the
//!   [`crate::diffusion::Process`]; `Ψ̂(t,s)` (transition of
//!   `F̂ = F + (1+λ²)/2·GGᵀΣ⁻¹`) and the injected-noise covariance
//!   `P_st` (Eq. 23) are integrated per grid interval here.
//! * Type-II quantities (definite integrals): the exponential-integrator
//!   multistep predictor/corrector coefficients `ᵖC_ij` (Eq. 19b) and
//!   `ᶜC_ij` (Eq. 46), evaluated with Gauss–Legendre quadrature.
//!
//! The plan is reused across every batch with the same discretization —
//! "calculated once and used everywhere" (App. C.3).

pub mod plan;
pub mod linop_integrate;

pub use plan::{PlanConfig, SamplerPlan};
