//! [`SamplerPlan`]: the precomputed per-run coefficient bundle
//! (paper App. C.4, "Stage I: Offline preparation of gDDIM").
//!
//! Step indexing: the grid is ascending (`t_0 = ε … t_N = T`); step `i`
//! (for `i = N, N−1, …, 1`) updates the state from `t_i` to `t_{i−1}`.
//! Arrays below are indexed by `i−1 ∈ [0, N)`.

use std::collections::BTreeMap;

use crate::diffusion::process::{KtKind, Process};
use crate::diffusion::schedule::TimeGrid;
use crate::coeffs::linop_integrate::{integrate_linop_composite, solve_linop_ode};
use crate::math::interp::lagrange_basis;
use crate::math::linop::LinOp;
use crate::util::json::Json;

/// Configuration of a sampling run's coefficients.
#[derive(Clone, Debug, PartialEq)]
pub struct PlanConfig {
    /// Multistep order q (q = 1 is the plain exponential integrator /
    /// deterministic gDDIM of Eq. 18; the paper's tables write this as
    /// polynomial order `q` with q=0 meaning 1-step — we use the count of
    /// history points, i.e. paper-q + 1).
    pub q: usize,
    /// Stochasticity λ of the marginal-equivalent SDE Eq. 6 (0 = ODE).
    pub lambda: f64,
    /// Score parameterization K_t (R_t for gDDIM, L_t for the ablation).
    pub kt: KtKind,
    /// Whether the corrector coefficients are also prepared (Table 8).
    pub with_corrector: bool,
    /// Gauss–Legendre points per interval for Type-II integrals.
    pub gl_points: usize,
    /// Composite-quadrature pieces per interval (denser near t_min).
    pub gl_pieces: usize,
    /// RK4 steps per interval for the Type-I (Ψ̂, P_st) ODEs.
    pub ode_steps: usize,
}

impl Default for PlanConfig {
    fn default() -> Self {
        PlanConfig {
            q: 2,
            lambda: 0.0,
            kt: KtKind::R,
            with_corrector: false,
            gl_points: 32,
            gl_pieces: 4,
            ode_steps: 512,
        }
    }
}

impl PlanConfig {
    pub fn deterministic(q: usize, kt: KtKind) -> Self {
        PlanConfig { q, kt, ..Default::default() }
    }

    pub fn stochastic(lambda: f64) -> Self {
        PlanConfig { q: 1, lambda, kt: KtKind::R, ..Default::default() }
    }
}

/// Precomputed coefficients for one (process, grid, config).
pub struct SamplerPlan {
    pub cfg: PlanConfig,
    pub grid: TimeGrid,
    /// `Ψ(t_{i−1}, t_i)` per step.
    pub psi: Vec<LinOp>,
    /// Predictor coefficients `ᵖC_ij^{(q_cur)}` (Eq. 19b): for step `i`,
    /// entry `j` multiplies `ε_θ(u(t_{i+j}), t_{i+j})`.
    pub pred: Vec<Vec<LinOp>>,
    /// Corrector coefficients `ᶜC_ij^{(q_cur)}` (Eq. 46): entry `jj`
    /// corresponds to `j = jj − 1` (node `t_{i+j}`, starting at t_{i−1}).
    pub corr: Vec<Vec<LinOp>>,
    /// Stochastic-gDDIM per-step mean factor `[Ψ̂ − Ψ]·K_{t_i}` (Eq. 22)
    /// and noise factor `chol(P_{t_i→t_{i−1}})` (Eq. 23); empty if λ = 0.
    pub stoch_mean: Vec<LinOp>,
    pub stoch_noise: Vec<LinOp>,
    /// `K_{t_i}` and `K_{t_i}^{-T}` at every grid node (score ⇄ ε).
    pub kt_nodes: Vec<LinOp>,
    pub kt_inv_t_nodes: Vec<LinOp>,
    /// Wall time spent building (reported by `gddim coeffs`).
    pub build_seconds: f64,
}

impl SamplerPlan {
    /// Build the full plan — the paper's Stage-I Steps 1–4.
    pub fn build(proc: &dyn Process, grid: &TimeGrid, cfg: &PlanConfig) -> SamplerPlan {
        assert!(grid.is_valid(), "time grid must be strictly increasing");
        assert!(cfg.q >= 1, "multistep order must be >= 1");
        assert!(cfg.lambda >= 0.0);
        if cfg.lambda > 0.0 {
            assert_eq!(
                cfg.kt,
                KtKind::R,
                "stochastic gDDIM (Prop 6) is derived for the R_t parameterization"
            );
        }
        let t_build = std::time::Instant::now();
        let ts = &grid.ts;
        let n = grid.n_steps();

        // Step 2: transition matrices at grid nodes.
        let psi: Vec<LinOp> = (1..=n).map(|i| proc.psi(ts[i - 1], ts[i])).collect();

        // Step 3: K_t at grid nodes.
        let kt_nodes: Vec<LinOp> = ts.iter().map(|&t| proc.kt(cfg.kt, t)).collect();
        let kt_inv_t_nodes: Vec<LinOp> =
            kt_nodes.iter().map(|k| k.inv().transpose()).collect();

        // Step 4: Type-II integrals — predictor & corrector coefficients.
        let integrand = |t_target: f64, tau: f64| -> LinOp {
            proc.psi(t_target, tau)
                .matmul(&proc.ggt_op(tau))
                .matmul(&proc.kt(cfg.kt, tau).inv().transpose())
                .scale(0.5)
        };
        let mut pred: Vec<Vec<LinOp>> = Vec::with_capacity(n);
        let mut corr: Vec<Vec<LinOp>> = Vec::with_capacity(n);
        for i in 1..=n {
            // Warm start (Algo 1): fewer history points near t_N.
            let q_cur = cfg.q.min(n - i + 1);
            let nodes: Vec<f64> = (0..q_cur).map(|j| ts[i + j]).collect();
            let coeffs: Vec<LinOp> = (0..q_cur)
                .map(|j| {
                    integrate_linop_composite(
                        |tau| integrand(ts[i - 1], tau).scale(lagrange_basis(&nodes, j, tau)),
                        ts[i],
                        ts[i - 1],
                        cfg.gl_points,
                        cfg.gl_pieces,
                    )
                })
                .collect();
            pred.push(coeffs);

            if cfg.with_corrector {
                let q_cur = cfg.q.min(n - i + 2).max(2);
                // Corrector nodes: t_{i-1}, t_i, …, t_{i+q_cur-2}.
                let q_cur = q_cur.min(n - i + 2);
                let nodes: Vec<f64> = (0..q_cur).map(|jj| ts[i - 1 + jj]).collect();
                let coeffs: Vec<LinOp> = (0..q_cur)
                    .map(|jj| {
                        integrate_linop_composite(
                            |tau| {
                                integrand(ts[i - 1], tau)
                                    .scale(lagrange_basis(&nodes, jj, tau))
                            },
                            ts[i],
                            ts[i - 1],
                            cfg.gl_points,
                            cfg.gl_pieces,
                        )
                    })
                    .collect();
                corr.push(coeffs);
            }
        }

        // Stochastic part (λ > 0): Ψ̂ and P per interval (Type I ODEs).
        let mut stoch_mean = Vec::new();
        let mut stoch_noise = Vec::new();
        if cfg.lambda > 0.0 {
            let lam2 = cfg.lambda * cfg.lambda;
            let f_hat = |t: f64| -> LinOp {
                // F̂ = F + (1+λ²)/2 · GGᵀ Σ⁻¹, with Σ⁻¹ via the Cholesky
                // factor (L⁻ᵀL⁻¹) to dodge the det-Σ cancellation.
                let l_inv = proc.sigma(t).cholesky().inv();
                let sig_inv = l_inv.transpose().matmul(&l_inv);
                proc.f_op(t)
                    .add(&proc.ggt_op(t).matmul(&sig_inv).scale(0.5 * (1.0 + lam2)))
            };
            for i in 1..=n {
                let (s, t) = (ts[i], ts[i - 1]); // integrate backwards s -> t
                // Ψ̂(t, s): dY/dτ = F̂(τ) Y from τ=s to τ=t, Y(s) = I.
                let ident = match proc.f_op(s) {
                    LinOp::Diag(d) => LinOp::diag(vec![1.0; d.len()]),
                    LinOp::Block2(_) => LinOp::Block2(crate::math::mat2::Mat2::IDENT),
                    LinOp::Scalar(_) => LinOp::Scalar(1.0),
                };
                let psi_hat =
                    solve_linop_ode(|tau, y| f_hat(tau).matmul(y), s, t, cfg.ode_steps, ident);
                // Mean factor [Ψ̂ − Ψ]·K_s (Eq. 22).
                stoch_mean.push(psi_hat.sub(&psi[i - 1]).matmul(&proc.kt(cfg.kt, s)));
                // P_st = Cov[u(t)|u(s)] (Eq. 23). The paper writes the
                // ODE for τ increasing away from s; integrating in the
                // *sampling* direction (τ: s → t with t < s) the noise
                // source flips sign:  dP/dτ = F̂P + PF̂ᵀ − λ²GGᵀ, P(s)=0,
                // which is the derivative of
                // P(τ) = λ²∫_τ^s Ψ̂(τ,r) GGᵀ(r) Ψ̂(τ,r)ᵀ dr ⪰ 0.
                let p0 = psi[i - 1].scale(0.0);
                let p = solve_linop_ode(
                    |tau, y| {
                        let fh = f_hat(tau);
                        fh.matmul(y)
                            .add(&y.matmul(&fh.transpose()))
                            .sub(&proc.ggt_op(tau).scale(lam2))
                    },
                    s,
                    t,
                    cfg.ode_steps,
                    p0,
                );
                // Symmetrize defensively before factoring.
                let p = p.add(&p.transpose()).scale(0.5);
                stoch_noise.push(p.sqrt_spd());
            }
        }

        SamplerPlan {
            cfg: cfg.clone(),
            grid: grid.clone(),
            psi,
            pred,
            corr,
            stoch_mean,
            stoch_noise,
            kt_nodes,
            kt_inv_t_nodes,
            build_seconds: t_build.elapsed().as_secs_f64(),
        }
    }

    pub fn n_steps(&self) -> usize {
        self.grid.n_steps()
    }

    /// Serialize the full coefficient bundle for the plan-cache
    /// persistence format (App. C.3: "calculated once and used
    /// everywhere" — here, across process restarts). Floats are written
    /// in shortest-roundtrip form, so [`SamplerPlan::from_json`] rebuilds
    /// a plan whose sampler output is bit-identical to the original's.
    pub fn to_json(&self) -> Json {
        let ops = |v: &[LinOp]| Json::Arr(v.iter().map(LinOp::to_json).collect());
        let nested =
            |v: &[Vec<LinOp>]| Json::Arr(v.iter().map(|row| ops(row)).collect());
        let mut cfg = BTreeMap::new();
        cfg.insert("q".to_string(), Json::Num(self.cfg.q as f64));
        cfg.insert("lambda".to_string(), Json::Num(self.cfg.lambda));
        cfg.insert("kt".to_string(), Json::Str(self.cfg.kt.token().to_string()));
        cfg.insert("with_corrector".to_string(), Json::Bool(self.cfg.with_corrector));
        cfg.insert("gl_points".to_string(), Json::Num(self.cfg.gl_points as f64));
        cfg.insert("gl_pieces".to_string(), Json::Num(self.cfg.gl_pieces as f64));
        cfg.insert("ode_steps".to_string(), Json::Num(self.cfg.ode_steps as f64));
        let mut obj = BTreeMap::new();
        obj.insert("cfg".to_string(), Json::Obj(cfg));
        obj.insert(
            "ts".to_string(),
            Json::Arr(self.grid.ts.iter().map(|&t| Json::Num(t)).collect()),
        );
        obj.insert("psi".to_string(), ops(&self.psi));
        obj.insert("pred".to_string(), nested(&self.pred));
        obj.insert("corr".to_string(), nested(&self.corr));
        obj.insert("stoch_mean".to_string(), ops(&self.stoch_mean));
        obj.insert("stoch_noise".to_string(), ops(&self.stoch_noise));
        obj.insert("kt_nodes".to_string(), ops(&self.kt_nodes));
        obj.insert("kt_inv_t_nodes".to_string(), ops(&self.kt_inv_t_nodes));
        Json::Obj(obj)
    }

    /// Inverse of [`SamplerPlan::to_json`] (with structural validation);
    /// `build_seconds` is 0 for a loaded plan.
    pub fn from_json(j: &Json) -> crate::Result<SamplerPlan> {
        let field =
            |k: &str| j.get(k).ok_or_else(|| crate::Error::msg(format!("plan: missing `{k}`")));
        let ops = |k: &str| -> crate::Result<Vec<LinOp>> {
            field(k)?
                .as_arr()
                .ok_or_else(|| crate::Error::msg(format!("plan: `{k}` not an array")))?
                .iter()
                .map(LinOp::from_json)
                .collect()
        };
        let nested = |k: &str| -> crate::Result<Vec<Vec<LinOp>>> {
            field(k)?
                .as_arr()
                .ok_or_else(|| crate::Error::msg(format!("plan: `{k}` not an array")))?
                .iter()
                .map(|row| {
                    row.as_arr()
                        .ok_or_else(|| crate::Error::msg(format!("plan: `{k}` row not an array")))?
                        .iter()
                        .map(LinOp::from_json)
                        .collect()
                })
                .collect()
        };
        let cj = field("cfg")?;
        let cfg_num = |k: &str| {
            cj.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| crate::Error::msg(format!("plan cfg: missing `{k}`")))
        };
        let cfg = PlanConfig {
            q: cfg_num("q")? as usize,
            lambda: cfg_num("lambda")?,
            kt: cj
                .get("kt")
                .and_then(Json::as_str)
                .ok_or_else(|| crate::Error::msg("plan cfg: missing `kt`"))?
                .parse()
                .map_err(crate::Error::msg)?,
            with_corrector: matches!(cj.get("with_corrector"), Some(Json::Bool(true))),
            gl_points: cfg_num("gl_points")? as usize,
            gl_pieces: cfg_num("gl_pieces")? as usize,
            ode_steps: cfg_num("ode_steps")? as usize,
        };
        let ts = field("ts")?
            .as_f64_vec()
            .ok_or_else(|| crate::Error::msg("plan: `ts` not numbers"))?;
        let grid = TimeGrid { ts };
        if !grid.is_valid() {
            return Err(crate::Error::msg("plan: persisted time grid is not increasing"));
        }
        let plan = SamplerPlan {
            cfg,
            psi: ops("psi")?,
            pred: nested("pred")?,
            corr: nested("corr")?,
            stoch_mean: ops("stoch_mean")?,
            stoch_noise: ops("stoch_noise")?,
            kt_nodes: ops("kt_nodes")?,
            kt_inv_t_nodes: ops("kt_inv_t_nodes")?,
            build_seconds: 0.0,
            grid,
        };
        let n = plan.grid.n_steps();
        if plan.cfg.q == 0 {
            return Err(crate::Error::msg("plan: q must be >= 1"));
        }
        if plan.psi.len() != n
            || plan.pred.len() != n
            || plan.kt_nodes.len() != n + 1
            || plan.kt_inv_t_nodes.len() != n + 1
            || (plan.cfg.with_corrector && plan.corr.len() != n)
            || (plan.cfg.lambda > 0.0
                && (plan.stoch_mean.len() != n || plan.stoch_noise.len() != n))
        {
            return Err(crate::Error::msg("plan: persisted arrays inconsistent with grid"));
        }
        // Per-row lengths must match the warm-start schedule `build` uses
        // (q_cur shrinks near t_N) — an over-long row would index past
        // the sampler's ε history at serve time.
        for (idx, row) in plan.pred.iter().enumerate() {
            let i = idx + 1;
            if row.len() != plan.cfg.q.min(n - i + 1) {
                return Err(crate::Error::msg("plan: predictor row length inconsistent"));
            }
        }
        for (idx, row) in plan.corr.iter().enumerate() {
            let i = idx + 1;
            let q_cur = plan.cfg.q.min(n - i + 2).max(2).min(n - i + 2);
            if row.len() != q_cur {
                return Err(crate::Error::msg("plan: corrector row length inconsistent"));
            }
        }
        // Every operator of one plan acts on the same state space: all
        // must share psi[0]'s structure (and dimension, for Diag) or a
        // tampered file would panic `LinOp::apply` inside a worker.
        let same_shape = |a: &LinOp, b: &LinOp| -> bool {
            match (a, b) {
                (LinOp::Scalar(_), LinOp::Scalar(_)) => true,
                (LinOp::Block2(_), LinOp::Block2(_)) => true,
                (LinOp::Diag(x), LinOp::Diag(y)) => x.len() == y.len(),
                _ => false,
            }
        };
        let anchor = plan.psi[0].clone();
        let all = plan
            .psi
            .iter()
            .chain(plan.pred.iter().flatten())
            .chain(plan.corr.iter().flatten())
            .chain(plan.stoch_mean.iter())
            .chain(plan.stoch_noise.iter())
            .chain(plan.kt_nodes.iter())
            .chain(plan.kt_inv_t_nodes.iter());
        for op in all {
            if !same_shape(op, &anchor) {
                return Err(crate::Error::msg("plan: mixed operator structures/dimensions"));
            }
        }
        Ok(plan)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::{Cld, Vpsde};
    use crate::math::close;

    fn scalar(op: &LinOp) -> f64 {
        match op {
            LinOp::Scalar(s) => *s,
            _ => panic!("expected scalar, got {op:?}"),
        }
    }

    #[test]
    fn one_step_predictor_matches_analytic_ddim_on_vpsde() {
        // Prop 2 / Eq. 12: the q=1 EI coefficient on VPSDE must equal
        //   √(1−α_{t−Δ}) − √(1−α_t)·√(α_{t−Δ}/α_t).
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min, p.t_max, 20);
        let plan = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(1, KtKind::R));
        for i in 1..=grid.n_steps() {
            let (s, t) = (grid.ts[i], grid.ts[i - 1]); // step from s down to t
            let expect = (1.0 - p.alpha(t)).sqrt()
                - (1.0 - p.alpha(s)).sqrt() * (p.alpha(t) / p.alpha(s)).sqrt();
            let got = scalar(&plan.pred[i - 1][0]);
            assert!(
                close(got, expect, 1e-8, 1e-10),
                "step {i}: C={got} vs analytic DDIM {expect}"
            );
        }
    }

    #[test]
    fn psi_nodes_match_process() {
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min, p.t_max, 10);
        let plan = SamplerPlan::build(&p, &grid, &PlanConfig::default());
        for i in 1..=10 {
            let expect = (p.alpha(grid.ts[i - 1]) / p.alpha(grid.ts[i])).sqrt();
            assert!(close(scalar(&plan.psi[i - 1]), expect, 1e-12, 0.0));
        }
    }

    #[test]
    fn multistep_coeffs_sum_to_one_step() {
        // Σ_j ᵖC_ij = one-step EI coefficient (Lagrange bases sum to 1) —
        // a structural identity of Eq. 19b.
        let p = Cld::standard(1);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 12);
        let multi = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(3, KtKind::R));
        let single = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(1, KtKind::R));
        for i in 0..grid.n_steps() {
            let mut sum = multi.pred[i][0].clone();
            for c in &multi.pred[i][1..] {
                sum = sum.add(c);
            }
            assert!(
                sum.dist(&single.pred[i][0]) < 1e-9 * (1.0 + single.pred[i][0].max_abs()),
                "step {i}: Σ_j C_ij != C^{{(1)}}"
            );
        }
    }

    #[test]
    fn corrector_coeffs_also_sum_to_one_step() {
        let p = Cld::standard(1);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 10);
        let cfg = PlanConfig { q: 2, with_corrector: true, ..PlanConfig::default() };
        let plan = SamplerPlan::build(&p, &grid, &cfg);
        let single = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(1, KtKind::R));
        for i in 0..grid.n_steps() {
            let mut sum = plan.corr[i][0].clone();
            for c in &plan.corr[i][1..] {
                sum = sum.add(c);
            }
            assert!(sum.dist(&single.pred[i][0]) < 1e-9 * (1.0 + single.pred[i][0].max_abs()));
        }
    }

    #[test]
    fn json_round_trip_preserves_every_coefficient() {
        // Scalar (VPSDE), Block2 (CLD), and Diag (BDM) plans, with and
        // without corrector / stochastic parts, must survive persistence
        // with zero drift in any operator.
        let grids_and_plans: Vec<SamplerPlan> = {
            let vp = Vpsde::standard(1);
            let cld = Cld::standard(1);
            let bdm = crate::diffusion::Bdm::standard(2, 2);
            let gv = TimeGrid::uniform(vp.t_min, vp.t_max, 6);
            let gc = TimeGrid::uniform(cld.t_min(), cld.t_max(), 6);
            let gb = TimeGrid::uniform(bdm.t_min(), bdm.t_max(), 4);
            vec![
                SamplerPlan::build(&vp, &gv, &PlanConfig::deterministic(2, KtKind::R)),
                SamplerPlan::build(&vp, &gv, &PlanConfig::stochastic(0.7)),
                SamplerPlan::build(
                    &cld,
                    &gc,
                    &PlanConfig { q: 2, with_corrector: true, ..PlanConfig::default() },
                ),
                SamplerPlan::build(&bdm, &gb, &PlanConfig::deterministic(1, KtKind::L)),
            ]
        };
        for plan in grids_and_plans {
            let text = plan.to_json().to_string_pretty();
            let back =
                SamplerPlan::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.cfg.q, plan.cfg.q);
            assert_eq!(back.cfg.kt, plan.cfg.kt);
            assert_eq!(back.cfg.lambda.to_bits(), plan.cfg.lambda.to_bits());
            assert_eq!(back.grid.ts, plan.grid.ts);
            let pairs = |a: &[LinOp], b: &[LinOp]| {
                assert_eq!(a.len(), b.len());
                for (x, y) in a.iter().zip(b) {
                    assert_eq!(x.dist(y), 0.0, "operator drifted through JSON");
                }
            };
            pairs(&back.psi, &plan.psi);
            pairs(&back.stoch_mean, &plan.stoch_mean);
            pairs(&back.stoch_noise, &plan.stoch_noise);
            pairs(&back.kt_nodes, &plan.kt_nodes);
            pairs(&back.kt_inv_t_nodes, &plan.kt_inv_t_nodes);
            for (a, b) in back.pred.iter().zip(&plan.pred) {
                pairs(a, b);
            }
            for (a, b) in back.corr.iter().zip(&plan.corr) {
                pairs(a, b);
            }
            assert_eq!(back.corr.len(), plan.corr.len());
        }
    }

    #[test]
    fn from_json_rejects_inconsistent_payloads() {
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min, p.t_max, 4);
        let plan = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(1, KtKind::R));
        let mut j = plan.to_json();
        // Truncate psi: array length no longer matches the grid.
        if let Json::Obj(obj) = &mut j {
            if let Some(Json::Arr(psi)) = obj.get_mut("psi") {
                psi.pop();
            }
        }
        assert!(SamplerPlan::from_json(&j).is_err());
        assert!(SamplerPlan::from_json(&Json::Null).is_err());
    }

    #[test]
    fn stochastic_matches_thm1_on_vpsde() {
        // Thm 1: on DDPM, the per-step noise std must be
        //   σ² = (1−α_t)[1 − ((1−α_t)/(1−α_s))^{λ²} (α_s/α_t)^{λ²}]
        // and the mean ε-coefficient −√(α_t/α_s)√(1−α_s) + √(1−α_t−σ²).
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min, p.t_max, 10);
        for lambda in [0.3, 1.0] {
            let plan = SamplerPlan::build(&p, &grid, &PlanConfig::stochastic(lambda));
            for i in 1..=10 {
                let (s, t) = (grid.ts[i], grid.ts[i - 1]);
                let (als, alt) = (p.alpha(s), p.alpha(t));
                let lam2 = lambda * lambda;
                let sig2 = (1.0 - alt)
                    * (1.0 - ((1.0 - alt) / (1.0 - als)).powf(lam2) * (als / alt).powf(lam2));
                let got_noise = scalar(&plan.stoch_noise[i - 1]);
                assert!(
                    close(got_noise, sig2.sqrt(), 1e-5, 1e-7),
                    "step {i} λ={lambda}: noise {got_noise} vs {}",
                    sig2.sqrt()
                );
                let mean_expect =
                    -(alt / als).sqrt() * (1.0 - als).sqrt() + (1.0 - alt - sig2).sqrt();
                let got_mean = scalar(&plan.stoch_mean[i - 1]);
                assert!(
                    close(got_mean, mean_expect, 1e-5, 1e-7),
                    "step {i} λ={lambda}: mean {got_mean} vs {mean_expect}"
                );
            }
        }
    }

    #[test]
    fn prop7_lambda_zero_limit() {
        // Prop 7: as λ→0 the stochastic mean factor [Ψ̂−Ψ]K_s equals the
        // deterministic one-step EI coefficient, and the noise vanishes.
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min, p.t_max, 8);
        let det = SamplerPlan::build(&p, &grid, &PlanConfig::deterministic(1, KtKind::R));
        let sto = SamplerPlan::build(
            &p,
            &grid,
            &PlanConfig { q: 1, lambda: 1e-6, ..PlanConfig::stochastic(1e-6) },
        );
        for i in 0..8 {
            let d = scalar(&det.pred[i][0]);
            let s = scalar(&sto.stoch_mean[i]);
            assert!(close(s, d, 1e-5, 1e-8), "step {i}: {s} vs {d}");
            assert!(scalar(&sto.stoch_noise[i]) < 1e-3);
        }
    }

    #[test]
    fn cld_psi_hat_equals_rt_rs_inv_at_lambda_zero() {
        // Ψ̂(t,s) = R_t R_s⁻¹ when λ=0 (used in the proof of Prop 7) —
        // here checked through the plan's stochastic path with tiny λ.
        let p = Cld::standard(1);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 6);
        let plan = SamplerPlan::build(
            &p,
            &grid,
            &PlanConfig { q: 1, lambda: 1e-8, kt: KtKind::R, ..PlanConfig::default() },
        );
        for i in 1..=6 {
            let (s, t) = (grid.ts[i], grid.ts[i - 1]);
            // stoch_mean = [Ψ̂ − Ψ]R_s ⇒ Ψ̂ = stoch_mean·R_s⁻¹ + Ψ.
            let psi_hat = plan.stoch_mean[i - 1]
                .matmul(&p.rt(s).inv())
                .add(&plan.psi[i - 1]);
            let expect = p.rt(t).matmul(&p.rt(s).inv());
            assert!(
                psi_hat.dist(&expect) < 1e-4 * (1.0 + expect.max_abs()),
                "step {i}: dist {}",
                psi_hat.dist(&expect)
            );
        }
    }
}
