//! Mixture-of-isotropic-Gaussians data specification.

use crate::math::linalg::MatD;
use crate::math::rng::Rng;
use crate::util::json::Json;
use std::collections::BTreeMap;

/// `p(x) = Σ_m w_m N(x; μ_m, σ² I_d)` (σ may be 0 → mixture of Diracs).
#[derive(Clone, Debug)]
pub struct GmmSpec {
    pub name: String,
    pub d: usize,
    pub weights: Vec<f64>,
    /// Component means, each of length `d`.
    pub means: Vec<Vec<f64>>,
    /// Shared isotropic component variance σ².
    pub var: f64,
}

impl GmmSpec {
    pub fn new(name: &str, means: Vec<Vec<f64>>, var: f64) -> GmmSpec {
        let m = means.len();
        assert!(m > 0);
        let d = means[0].len();
        assert!(means.iter().all(|mu| mu.len() == d));
        GmmSpec { name: name.to_string(), d, weights: vec![1.0 / m as f64; m], means, var }
    }

    pub fn n_modes(&self) -> usize {
        self.means.len()
    }

    /// Draw `n` samples (row-major `n × d`).
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<f64> {
        let mut out = Vec::with_capacity(n * self.d);
        let sd = self.var.sqrt();
        for _ in 0..n {
            let m = rng.categorical(&self.weights);
            for j in 0..self.d {
                out.push(self.means[m][j] + sd * rng.normal());
            }
        }
        out
    }

    /// Exact mixture mean.
    pub fn mean(&self) -> Vec<f64> {
        let mut mu = vec![0.0; self.d];
        for (w, m) in self.weights.iter().zip(&self.means) {
            for j in 0..self.d {
                mu[j] += w * m[j];
            }
        }
        mu
    }

    /// Exact mixture covariance: σ²I + Σ w_m μ_mμ_mᵀ − μμᵀ.
    pub fn cov(&self) -> MatD {
        let mu = self.mean();
        let mut c = MatD::zeros(self.d, self.d);
        for (w, m) in self.weights.iter().zip(&self.means) {
            for i in 0..self.d {
                for j in 0..self.d {
                    c[(i, j)] += w * (m[i] - mu[i]) * (m[j] - mu[j]);
                }
            }
        }
        for i in 0..self.d {
            c[(i, i)] += self.var;
        }
        c
    }

    /// Second moment scale `E‖x‖²/d` (used by the oracle's state-space lift).
    pub fn second_moment(&self) -> f64 {
        let mut acc = 0.0;
        for (w, m) in self.weights.iter().zip(&self.means) {
            acc += w * m.iter().map(|x| x * x).sum::<f64>();
        }
        acc / self.d as f64 + self.var
    }

    /// Exact log-density (for NLL ground truth; requires σ > 0).
    pub fn logpdf(&self, x: &[f64]) -> f64 {
        assert!(self.var > 0.0, "logpdf needs positive component variance");
        assert_eq!(x.len(), self.d);
        let inv2v = 0.5 / self.var;
        let log_norm =
            -0.5 * self.d as f64 * (2.0 * std::f64::consts::PI * self.var).ln();
        let mut best = f64::NEG_INFINITY;
        let logs: Vec<f64> = self
            .weights
            .iter()
            .zip(&self.means)
            .map(|(w, m)| {
                let d2: f64 = x.iter().zip(m).map(|(a, b)| (a - b) * (a - b)).sum();
                let l = w.max(1e-300).ln() + log_norm - d2 * inv2v;
                best = best.max(l);
                l
            })
            .collect();
        best + logs.iter().map(|l| (l - best).exp()).sum::<f64>().ln()
    }

    /// Serialize for `configs/datasets.json` (consumed by python/compile).
    pub fn to_json(&self) -> Json {
        let mut o = BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("d".into(), Json::Num(self.d as f64));
        o.insert("var".into(), Json::Num(self.var));
        o.insert(
            "weights".into(),
            Json::Arr(self.weights.iter().map(|&w| Json::Num(w)).collect()),
        );
        o.insert(
            "means".into(),
            Json::Arr(
                self.means
                    .iter()
                    .map(|m| Json::Arr(m.iter().map(|&x| Json::Num(x)).collect()))
                    .collect(),
            ),
        );
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<GmmSpec, String> {
        let name = j.get("name").and_then(|v| v.as_str()).ok_or("missing name")?;
        let var = j.get("var").and_then(|v| v.as_f64()).ok_or("missing var")?;
        let weights = j.get("weights").and_then(|v| v.as_f64_vec()).ok_or("missing weights")?;
        let means: Vec<Vec<f64>> = j
            .get("means")
            .and_then(|v| v.as_arr())
            .ok_or("missing means")?
            .iter()
            .map(|row| row.as_f64_vec().ok_or("bad mean row".to_string()))
            .collect::<Result<_, _>>()?;
        let d = means.first().map(|m| m.len()).ok_or("empty means")?;
        Ok(GmmSpec { name: name.to_string(), d, weights, means, var })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    fn two_mode() -> GmmSpec {
        GmmSpec::new("t", vec![vec![-2.0, 0.0], vec![2.0, 0.0]], 0.01)
    }

    #[test]
    fn sample_moments_match_exact() {
        let g = two_mode();
        let mut rng = Rng::seed_from(77);
        let xs = g.sample(100_000, &mut rng);
        let mu = crate::math::stats::mean(&xs, 2);
        let exact = g.mean();
        assert!((mu[0] - exact[0]).abs() < 0.02, "{mu:?}");
        let c = crate::math::stats::covariance(&xs, 2);
        let ce = g.cov();
        assert!((c[(0, 0)] - ce[(0, 0)]).abs() < 0.1, "{} vs {}", c[(0, 0)], ce[(0, 0)]);
        assert!((c[(1, 1)] - ce[(1, 1)]).abs() < 0.01);
    }

    #[test]
    fn exact_cov_of_two_symmetric_modes() {
        let g = two_mode();
        let c = g.cov();
        // Var(x1) = 4 + 0.01, Var(x2) = 0.01, no cross term.
        assert!(close(c[(0, 0)], 4.01, 1e-12, 0.0));
        assert!(close(c[(1, 1)], 0.01, 1e-12, 0.0));
        assert!(c[(0, 1)].abs() < 1e-15);
    }

    #[test]
    fn logpdf_integrates_to_one_ish() {
        // Monte-Carlo check: E_q[p/q] over a wide uniform box ≈ 1.
        let g = GmmSpec::new("t1", vec![vec![0.0]], 0.25);
        let mut rng = Rng::seed_from(3);
        let (lo, hi) = (-4.0, 4.0);
        let n = 200_000;
        let mut acc = 0.0;
        for _ in 0..n {
            let x = rng.uniform_in(lo, hi);
            acc += g.logpdf(&[x]).exp();
        }
        let integral = acc / n as f64 * (hi - lo);
        assert!((integral - 1.0).abs() < 0.02, "{integral}");
    }

    #[test]
    fn json_roundtrip() {
        let g = two_mode();
        let j = g.to_json();
        let back = GmmSpec::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.name, g.name);
        assert_eq!(back.means, g.means);
        assert!(close(back.var, g.var, 0.0, 1e-15));
    }
}
