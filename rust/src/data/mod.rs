//! Synthetic datasets.
//!
//! Everything is a **mixture of isotropic Gaussians** (possibly with zero
//! variance, i.e. a mixture of Diracs). That is a deliberate design
//! decision, not a simplification of convenience: the paper's own
//! explanation of why DDIM works (§3, Fig. 2) is that realistic datasets
//! behave like well-separated mixtures under the manifold hypothesis, and
//! mixtures admit a *closed-form* score — so every sampler comparison in
//! this repo can be run against the exact score, isolating the
//! integrator (which is what gDDIM is about) from score-model error.
//! The same specs are exported to `configs/datasets.json` for the python
//! training layer (`gddim gen-configs`), so the learned-score pipeline
//! trains on exactly these distributions.

pub mod gmm;
pub mod presets;

pub use gmm::GmmSpec;
