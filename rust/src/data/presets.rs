//! The repo's canonical datasets (the CIFAR10/CELEBA substitutes; see
//! DESIGN.md §3). All procedurally generated from fixed seeds so the
//! rust side and the exported `configs/datasets.json` (python training)
//! agree exactly.

use crate::data::gmm::GmmSpec;
use crate::math::rng::Rng;
use crate::util::json::Json;

/// 8 well-separated modes on a circle of radius 4 (the classic 2-D toy;
/// paper Fig. 2's "mixture of well-separated" modes).
pub fn gmm2d() -> GmmSpec {
    let means = (0..8)
        .map(|i| {
            let th = std::f64::consts::TAU * i as f64 / 8.0;
            vec![4.0 * th.cos(), 4.0 * th.sin()]
        })
        .collect();
    GmmSpec::new("gmm2d", means, 0.05)
}

/// Mixture of two 1-D Gaussians (paper Fig. 2's toy: "a mixture of two
/// one dimension Gaussian distributions").
pub fn gmm2d_1d() -> GmmSpec {
    GmmSpec::new("gmm1d", vec![vec![-2.0], vec![2.0]], 0.04)
}

/// The paper's "challenging 2D example" (Fig. 4): mixture of Gaussians
/// with *small variance* — hard for naive solvers at low NFE.
pub fn hard2d() -> GmmSpec {
    let mut means = Vec::new();
    for i in 0..5 {
        for j in 0..5 {
            means.push(vec![-4.0 + 2.0 * i as f64, -4.0 + 2.0 * j as f64]);
        }
    }
    GmmSpec::new("hard2d", means, 0.003)
}

/// A spiral discretized into a 24-mode mixture (manifold-like 2-D data).
pub fn spiral2d() -> GmmSpec {
    let means = (0..24)
        .map(|i| {
            let s = i as f64 / 23.0;
            let th = 1.5 * std::f64::consts::TAU * s;
            let r = 0.8 + 3.2 * s;
            vec![r * th.cos(), r * th.sin()]
        })
        .collect();
    GmmSpec::new("spiral2d", means, 0.01)
}

/// 8×8 grayscale "two blobs" images: 48 prototype images (random blob
/// centers/intensities from a fixed seed) + small pixel jitter. 64-dim
/// data exercising the image-scale path and the DCT/BDM machinery —
/// the repo's CIFAR10 stand-in.
pub fn blobs8() -> GmmSpec {
    let h = 8;
    let w = 8;
    let mut rng = Rng::seed_from(0xB10B5);
    let mut means = Vec::with_capacity(48);
    for _ in 0..48 {
        let mut img = vec![0.0f64; h * w];
        for _blob in 0..2 {
            let cx = rng.uniform_in(1.5, (w - 2) as f64);
            let cy = rng.uniform_in(1.5, (h - 2) as f64);
            let amp = rng.uniform_in(0.6, 1.0);
            let s2 = rng.uniform_in(0.6, 2.0);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                    img[y * w + x] += amp * (-d2 / (2.0 * s2)).exp();
                }
            }
        }
        // Center to roughly zero mean, scale to [-1, 1]-ish like image DMs.
        let mean = img.iter().sum::<f64>() / img.len() as f64;
        for p in img.iter_mut() {
            *p = (*p - mean) * 2.0;
        }
        means.push(img);
    }
    GmmSpec::new("blobs8", means, 0.005)
}

/// A 16-prototype variant on 8×8 used as the "CELEBA" analog (fewer,
/// more distinct modes).
pub fn faces8() -> GmmSpec {
    let h = 8;
    let w = 8;
    let mut rng = Rng::seed_from(0xFACE5);
    let mut means = Vec::with_capacity(16);
    for _ in 0..16 {
        let mut img = vec![0.0f64; h * w];
        // an oval + two "eyes": crude but consistently structured images
        let cx = rng.uniform_in(3.0, 4.0);
        let cy = rng.uniform_in(3.0, 4.0);
        let rx = rng.uniform_in(2.0, 3.0);
        let ry = rng.uniform_in(2.4, 3.4);
        for y in 0..h {
            for x in 0..w {
                let e = ((x as f64 - cx) / rx).powi(2) + ((y as f64 - cy) / ry).powi(2);
                img[y * w + x] = if e < 1.0 { 0.8 * (1.0 - e) } else { 0.0 };
            }
        }
        for eye in 0..2 {
            let ex = cx + if eye == 0 { -1.0 } else { 1.0 } * rng.uniform_in(0.8, 1.2);
            let ey = cy - rng.uniform_in(0.5, 1.0);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (x as f64 - ex).powi(2) + (y as f64 - ey).powi(2);
                    img[y * w + x] -= 0.5 * (-d2 / 0.5).exp();
                }
            }
        }
        let mean = img.iter().sum::<f64>() / img.len() as f64;
        for p in img.iter_mut() {
            *p = (*p - mean) * 2.0;
        }
        means.push(img);
    }
    GmmSpec::new("faces8", means, 0.005)
}

/// All canonical datasets by name.
pub fn by_name(name: &str) -> Option<GmmSpec> {
    match name {
        "gmm2d" => Some(gmm2d()),
        "hard2d" => Some(hard2d()),
        "spiral2d" => Some(spiral2d()),
        "blobs8" => Some(blobs8()),
        "faces8" => Some(faces8()),
        _ => None,
    }
}

pub const ALL: [&str; 5] = ["gmm2d", "hard2d", "spiral2d", "blobs8", "faces8"];

/// Serialize every preset into the shared `configs/datasets.json`.
pub fn export_json() -> Json {
    let mut o = std::collections::BTreeMap::new();
    for name in ALL {
        o.insert(name.to_string(), by_name(name).unwrap().to_json());
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_deterministic() {
        let a = blobs8();
        let b = blobs8();
        assert_eq!(a.means, b.means, "procedural generation must be seed-stable");
        assert_eq!(faces8().means, faces8().means);
    }

    #[test]
    fn all_presets_resolve() {
        for name in ALL {
            let g = by_name(name).unwrap();
            assert_eq!(g.name, name);
            assert!(g.n_modes() >= 2);
            assert!((g.weights.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    fn image_presets_are_64_dim() {
        assert_eq!(blobs8().d, 64);
        assert_eq!(faces8().d, 64);
    }

    #[test]
    fn modes_are_well_separated_relative_to_var() {
        // The manifold-hypothesis regime the paper argues from: distances
        // between modes >> component std.
        for name in ALL {
            let g = by_name(name).unwrap();
            let sd = g.var.sqrt();
            let mut min_dist = f64::INFINITY;
            for i in 0..g.n_modes() {
                for j in (i + 1)..g.n_modes() {
                    let d2: f64 = g.means[i]
                        .iter()
                        .zip(&g.means[j])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    min_dist = min_dist.min(d2.sqrt());
                }
            }
            assert!(min_dist > 3.0 * sd, "{name}: min mode distance {min_dist} vs sd {sd}");
        }
    }

    #[test]
    fn export_contains_all() {
        let j = export_json();
        for name in ALL {
            assert!(j.get(name).is_some());
        }
    }
}
