//! The repo's canonical datasets (the CIFAR10/CELEBA substitutes; see
//! DESIGN.md §3). All procedurally generated from fixed seeds so the
//! rust side and the exported `configs/datasets.json` (python training)
//! agree exactly.
//!
//! The catalogue is **registry-driven**: [`REGISTRY`] is the single
//! source of truth for every preset's `(name, h, w, d)` metadata, so the
//! CLI usage string, server-side validation ([`crate::server::request`]),
//! process construction ([`crate::diffusion::process_for`]), the table
//! defaults, and the JSON export all follow one list — adding a dataset
//! is one entry here, not a five-file hunt. The image generators are
//! parameterized by `(h, w, n_prototypes, seed)` with geometry scaled
//! against the 8×8 baseline, and the historical 8×8 presets regenerate
//! **bit-identically** from them (locked by the golden test below).

use crate::data::gmm::GmmSpec;
use crate::math::rng::Rng;
use crate::util::json::Json;

const BLOBS8_SEED: u64 = 0xB10B5;
const FACES8_SEED: u64 = 0xFACE5;
const BLOBS16_SEED: u64 = 0xB10B16;
const FACES16_SEED: u64 = 0xFACE16;
const BLOBS32_SEED: u64 = 0xB10B32;

/// One canonical dataset: identifying metadata plus its generator.
pub struct Preset {
    pub name: &'static str,
    /// Image height (0 for the analytic 2-D sets).
    pub h: usize,
    /// Image width (0 for the analytic 2-D sets).
    pub w: usize,
    /// Data dimension (`h · w` for image presets).
    pub d: usize,
    /// Mixture prototypes (modes).
    pub n_prototypes: usize,
    /// Procedural-generation seed (0 for the analytic sets).
    pub seed: u64,
    builder: fn() -> GmmSpec,
}

impl Preset {
    /// Build the dataset (procedural generation from the fixed seed).
    pub fn build(&self) -> GmmSpec {
        (self.builder)()
    }

    /// `(h, w)` for image presets, `None` for vector data.
    pub fn image_dims(&self) -> Option<(usize, usize)> {
        (self.h > 0 && self.w > 0).then_some((self.h, self.w))
    }

    /// `(h, w)` or the canonical image-process mismatch error — the one
    /// message shared by submit-time validation
    /// (`PlanKey::validate_dims`) and process construction
    /// (`diffusion::process_for`), so the two rejection paths can never
    /// drift apart.
    pub fn require_image_dims(&self) -> crate::Result<(usize, usize)> {
        self.image_dims().ok_or_else(|| {
            crate::Error::msg(format!(
                "process `bdm` needs h×w image data; dataset `{}` is {}-dim vector data",
                self.name, self.d
            ))
        })
    }
}

/// The dataset catalogue, in canonical order.
pub static REGISTRY: &[Preset] = &[
    Preset { name: "gmm2d", h: 0, w: 0, d: 2, n_prototypes: 8, seed: 0, builder: gmm2d },
    Preset { name: "hard2d", h: 0, w: 0, d: 2, n_prototypes: 25, seed: 0, builder: hard2d },
    Preset { name: "spiral2d", h: 0, w: 0, d: 2, n_prototypes: 24, seed: 0, builder: spiral2d },
    Preset {
        name: "blobs8",
        h: 8,
        w: 8,
        d: 64,
        n_prototypes: 48,
        seed: BLOBS8_SEED,
        builder: blobs8,
    },
    Preset {
        name: "faces8",
        h: 8,
        w: 8,
        d: 64,
        n_prototypes: 16,
        seed: FACES8_SEED,
        builder: faces8,
    },
    Preset {
        name: "blobs16",
        h: 16,
        w: 16,
        d: 256,
        n_prototypes: 48,
        seed: BLOBS16_SEED,
        builder: blobs16,
    },
    Preset {
        name: "faces16",
        h: 16,
        w: 16,
        d: 256,
        n_prototypes: 16,
        seed: FACES16_SEED,
        builder: faces16,
    },
    Preset {
        name: "blobs32",
        h: 32,
        w: 32,
        d: 1024,
        n_prototypes: 48,
        seed: BLOBS32_SEED,
        builder: blobs32,
    },
];

/// Default image dataset for CLIs and table harnesses (the CIFAR analog).
pub const DEFAULT_IMAGE: &str = "blobs8";

/// Default faces dataset (the CELEBA analog, Table 6).
pub const DEFAULT_FACES: &str = "faces8";

/// Registry entry by name.
pub fn info(name: &str) -> Option<&'static Preset> {
    REGISTRY.iter().find(|p| p.name == name)
}

/// Build a canonical dataset by name.
pub fn by_name(name: &str) -> Option<GmmSpec> {
    info(name).map(Preset::build)
}

/// All canonical dataset names, in registry order.
pub fn names() -> impl Iterator<Item = &'static str> {
    REGISTRY.iter().map(|p| p.name)
}

/// 8 well-separated modes on a circle of radius 4 (the classic 2-D toy;
/// paper Fig. 2's "mixture of well-separated" modes).
pub fn gmm2d() -> GmmSpec {
    let means = (0..8)
        .map(|i| {
            let th = std::f64::consts::TAU * i as f64 / 8.0;
            vec![4.0 * th.cos(), 4.0 * th.sin()]
        })
        .collect();
    GmmSpec::new("gmm2d", means, 0.05)
}

/// Mixture of two 1-D Gaussians (paper Fig. 2's toy: "a mixture of two
/// one dimension Gaussian distributions").
pub fn gmm2d_1d() -> GmmSpec {
    GmmSpec::new("gmm1d", vec![vec![-2.0], vec![2.0]], 0.04)
}

/// The paper's "challenging 2D example" (Fig. 4): mixture of Gaussians
/// with *small variance* — hard for naive solvers at low NFE.
pub fn hard2d() -> GmmSpec {
    let mut means = Vec::new();
    for i in 0..5 {
        for j in 0..5 {
            means.push(vec![-4.0 + 2.0 * i as f64, -4.0 + 2.0 * j as f64]);
        }
    }
    GmmSpec::new("hard2d", means, 0.003)
}

/// A spiral discretized into a 24-mode mixture (manifold-like 2-D data).
pub fn spiral2d() -> GmmSpec {
    let means = (0..24)
        .map(|i| {
            let s = i as f64 / 23.0;
            let th = 1.5 * std::f64::consts::TAU * s;
            let r = 0.8 + 3.2 * s;
            vec![r * th.cos(), r * th.sin()]
        })
        .collect();
    GmmSpec::new("spiral2d", means, 0.01)
}

/// Center to roughly zero mean, scale to [-1, 1]-ish like image DMs.
fn center_and_scale(img: &mut [f64]) {
    let mean = img.iter().sum::<f64>() / img.len() as f64;
    for p in img.iter_mut() {
        *p = (*p - mean) * 2.0;
    }
}

/// Shared blob-image generator: `n_prototypes` grayscale `h×w` prototype
/// images of `n_blobs` Gaussian bumps each (random centers, intensities
/// and widths from the fixed `seed`). Blob geometry scales with the 8×8
/// baseline (`h/8`, `w/8`), so at `h = w = 8` every bound degenerates to
/// the historical constants and the RNG draw sequence is unchanged —
/// which is what makes [`blobs8`] regenerate its pre-refactor means
/// bit for bit.
pub fn blob_images(
    name: &str,
    h: usize,
    w: usize,
    n_prototypes: usize,
    n_blobs: usize,
    seed: u64,
) -> GmmSpec {
    let (sh, sw) = (h as f64 / 8.0, w as f64 / 8.0);
    let mut rng = Rng::seed_from(seed);
    let mut means = Vec::with_capacity(n_prototypes);
    for _ in 0..n_prototypes {
        let mut img = vec![0.0f64; h * w];
        for _blob in 0..n_blobs {
            let cx = rng.uniform_in(1.5 * sw, w as f64 - 2.0 * sw);
            let cy = rng.uniform_in(1.5 * sh, h as f64 - 2.0 * sh);
            let amp = rng.uniform_in(0.6, 1.0);
            let s2 = rng.uniform_in(0.6, 2.0) * (sw * sh);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                    img[y * w + x] += amp * (-d2 / (2.0 * s2)).exp();
                }
            }
        }
        center_and_scale(&mut img);
        means.push(img);
    }
    GmmSpec::new(name, means, 0.005)
}

/// Shared face-image generator: an oval + two "eyes" per prototype —
/// crude but consistently structured images. Same 8×8-baseline scaling
/// contract as [`blob_images`], so [`faces8`] is bit-stable under the
/// parameterization.
pub fn face_images(name: &str, h: usize, w: usize, n_prototypes: usize, seed: u64) -> GmmSpec {
    let (sh, sw) = (h as f64 / 8.0, w as f64 / 8.0);
    let (half_h, half_w) = (0.5 * h as f64, 0.5 * w as f64);
    let mut rng = Rng::seed_from(seed);
    let mut means = Vec::with_capacity(n_prototypes);
    for _ in 0..n_prototypes {
        let mut img = vec![0.0f64; h * w];
        let cx = rng.uniform_in(half_w - sw, half_w);
        let cy = rng.uniform_in(half_h - sh, half_h);
        let rx = rng.uniform_in(2.0 * sw, 3.0 * sw);
        let ry = rng.uniform_in(2.4 * sh, 3.4 * sh);
        for y in 0..h {
            for x in 0..w {
                let e = ((x as f64 - cx) / rx).powi(2) + ((y as f64 - cy) / ry).powi(2);
                img[y * w + x] = if e < 1.0 { 0.8 * (1.0 - e) } else { 0.0 };
            }
        }
        for eye in 0..2 {
            let side = if eye == 0 { -1.0 } else { 1.0 };
            let ex = cx + side * rng.uniform_in(0.8 * sw, 1.2 * sw);
            let ey = cy - rng.uniform_in(0.5 * sh, 1.0 * sh);
            let eye_s2 = 0.5 * (sw * sh);
            for y in 0..h {
                for x in 0..w {
                    let d2 = (x as f64 - ex).powi(2) + (y as f64 - ey).powi(2);
                    img[y * w + x] -= 0.5 * (-d2 / eye_s2).exp();
                }
            }
        }
        center_and_scale(&mut img);
        means.push(img);
    }
    GmmSpec::new(name, means, 0.005)
}

/// 8×8 grayscale "two blobs" images: 48 prototype images + small pixel
/// jitter. 64-dim data exercising the image-scale path and the DCT/BDM
/// machinery — the repo's CIFAR10 stand-in.
pub fn blobs8() -> GmmSpec {
    blob_images("blobs8", 8, 8, 48, 2, BLOBS8_SEED)
}

/// A 16-prototype variant on 8×8 used as the "CELEBA" analog (fewer,
/// more distinct modes).
pub fn faces8() -> GmmSpec {
    face_images("faces8", 8, 8, 16, FACES8_SEED)
}

/// 16×16 two-blob images (256-dim): the first realistic-resolution rung
/// of the BDM/DCT scaling ladder.
pub fn blobs16() -> GmmSpec {
    blob_images("blobs16", 16, 16, 48, 2, BLOBS16_SEED)
}

/// 16×16 faces (256-dim), the CELEBA analog at the 16×16 rung.
pub fn faces16() -> GmmSpec {
    face_images("faces16", 16, 16, 16, FACES16_SEED)
}

/// 32×32 three-blob images (1024-dim): the full CIFAR-resolution stress
/// case for the DCT path and the engine's shard byte budget.
pub fn blobs32() -> GmmSpec {
    blob_images("blobs32", 32, 32, 48, 3, BLOBS32_SEED)
}

/// Serialize every preset into the shared `configs/datasets.json`.
pub fn export_json() -> Json {
    let mut o = std::collections::BTreeMap::new();
    for p in REGISTRY {
        o.insert(p.name.to_string(), p.build().to_json());
    }
    Json::Obj(o)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_are_deterministic() {
        for p in REGISTRY {
            assert_eq!(p.build().means, p.build().means, "{}: must be seed-stable", p.name);
        }
    }

    #[test]
    fn all_presets_resolve_and_match_registry_metadata() {
        for p in REGISTRY {
            let g = by_name(p.name).unwrap();
            assert_eq!(g.name, p.name);
            assert_eq!(g.d, p.d, "{}: registry d out of sync", p.name);
            assert_eq!(g.n_modes(), p.n_prototypes, "{}: registry prototype count", p.name);
            if let Some((h, w)) = p.image_dims() {
                assert_eq!(h * w, p.d, "{}: image dims must factor d", p.name);
            }
            assert!(g.n_modes() >= 2);
            assert!((g.weights.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        }
        assert!(info("no-such-set").is_none());
        assert!(info(DEFAULT_IMAGE).unwrap().image_dims().is_some());
        assert!(info(DEFAULT_FACES).unwrap().image_dims().is_some());
    }

    #[test]
    fn image_presets_have_registry_dims() {
        assert_eq!(blobs8().d, 64);
        assert_eq!(faces8().d, 64);
        assert_eq!(blobs16().d, 256);
        assert_eq!(faces16().d, 256);
        assert_eq!(blobs32().d, 1024);
    }

    /// Verbatim copy of the pre-refactor hard-coded `blobs8` generator:
    /// the golden reference the parameterized [`blob_images`] must
    /// reproduce bit for bit (same RNG draw order, same arithmetic).
    fn legacy_blobs8_means() -> Vec<Vec<f64>> {
        let h = 8;
        let w = 8;
        let mut rng = Rng::seed_from(0xB10B5);
        let mut means = Vec::with_capacity(48);
        for _ in 0..48 {
            let mut img = vec![0.0f64; h * w];
            for _blob in 0..2 {
                let cx = rng.uniform_in(1.5, (w - 2) as f64);
                let cy = rng.uniform_in(1.5, (h - 2) as f64);
                let amp = rng.uniform_in(0.6, 1.0);
                let s2 = rng.uniform_in(0.6, 2.0);
                for y in 0..h {
                    for x in 0..w {
                        let d2 = (x as f64 - cx).powi(2) + (y as f64 - cy).powi(2);
                        img[y * w + x] += amp * (-d2 / (2.0 * s2)).exp();
                    }
                }
            }
            let mean = img.iter().sum::<f64>() / img.len() as f64;
            for p in img.iter_mut() {
                *p = (*p - mean) * 2.0;
            }
            means.push(img);
        }
        means
    }

    /// Verbatim copy of the pre-refactor hard-coded `faces8` generator.
    fn legacy_faces8_means() -> Vec<Vec<f64>> {
        let h = 8;
        let w = 8;
        let mut rng = Rng::seed_from(0xFACE5);
        let mut means = Vec::with_capacity(16);
        for _ in 0..16 {
            let mut img = vec![0.0f64; h * w];
            let cx = rng.uniform_in(3.0, 4.0);
            let cy = rng.uniform_in(3.0, 4.0);
            let rx = rng.uniform_in(2.0, 3.0);
            let ry = rng.uniform_in(2.4, 3.4);
            for y in 0..h {
                for x in 0..w {
                    let e = ((x as f64 - cx) / rx).powi(2) + ((y as f64 - cy) / ry).powi(2);
                    img[y * w + x] = if e < 1.0 { 0.8 * (1.0 - e) } else { 0.0 };
                }
            }
            for eye in 0..2 {
                let ex = cx + if eye == 0 { -1.0 } else { 1.0 } * rng.uniform_in(0.8, 1.2);
                let ey = cy - rng.uniform_in(0.5, 1.0);
                for y in 0..h {
                    for x in 0..w {
                        let d2 = (x as f64 - ex).powi(2) + (y as f64 - ey).powi(2);
                        img[y * w + x] -= 0.5 * (-d2 / 0.5).exp();
                    }
                }
            }
            let mean = img.iter().sum::<f64>() / img.len() as f64;
            for p in img.iter_mut() {
                *p = (*p - mean) * 2.0;
            }
            means.push(img);
        }
        means
    }

    #[test]
    fn parameterized_generators_reproduce_the_8x8_presets_bit_identically() {
        assert_eq!(blobs8().means, legacy_blobs8_means(), "blobs8 drifted under refactor");
        assert_eq!(faces8().means, legacy_faces8_means(), "faces8 drifted under refactor");
    }

    #[test]
    fn modes_are_well_separated_relative_to_var() {
        // The manifold-hypothesis regime the paper argues from: distances
        // between modes >> component std.
        for p in REGISTRY {
            let g = by_name(p.name).unwrap();
            let sd = g.var.sqrt();
            let mut min_dist = f64::INFINITY;
            for i in 0..g.n_modes() {
                for j in (i + 1)..g.n_modes() {
                    let d2: f64 = g.means[i]
                        .iter()
                        .zip(&g.means[j])
                        .map(|(a, b)| (a - b) * (a - b))
                        .sum();
                    min_dist = min_dist.min(d2.sqrt());
                }
            }
            let name = p.name;
            assert!(min_dist > 3.0 * sd, "{name}: min mode distance {min_dist} vs sd {sd}");
        }
    }

    #[test]
    fn export_contains_all() {
        let j = export_json();
        for name in names() {
            assert!(j.get(name).is_some());
        }
    }
}
