//! Blurring diffusion model (Hoogeboom & Salimans 2022), as the linear
//! SDE of paper Eq. 11 / App. B.1.
//!
//! BDM noises images in *frequency space*: `y_t = Vᵀ x_t` (DCT) with
//! `p(y_t|y_0) = N(α_t y_0, σ_t² I)` where `α_t` is *diagonal per
//! frequency*: `α_{t,k} = a_t · exp(−λ_k τ_t)` — global scaling `a_t`
//! times heat dissipation at rate `λ_k` (the squared spatial frequency).
//!
//! We take the paper at its word and represent the **state as the DCT
//! spectrum**: `lift_data` applies the DCT, every coefficient is a
//! [`LinOp::Diag`], and the SDE drift/diffusion come from differentiating
//! the noising schedule (App. B.1, Eqs. 26–27):
//!
//! ```text
//!   f_k(t) = d log α_{t,k}/dt,      g_k²(t) = dσ_t²/dt − 2 f_k(t) σ_t²
//! ```
//!
//! Schedules: cosine ᾱ (Nichol & Dhariwal) for `a_t = √ᾱ_t`,
//! `σ_t² = 1 − ᾱ_t`, and dissipation time `τ_t = τ_max sin²(πt/2T)`
//! (Hoogeboom & Salimans' blur schedule).
//!
//! Note `Σ_t = σ_t² I` is diagonal, so `R_t = L_t = σ_t I` — gDDIM's `R`
//! and the Cholesky parameterization coincide for BDM (the paper's R/L
//! ablation is CLD-only for this reason); the gDDIM win on BDM comes from
//! the exponential integrator + multistep machinery versus ancestral
//! sampling (Table 3).

use std::sync::Arc;

use crate::diffusion::process::Process;
use crate::math::dct::Dct2;
use crate::math::linop::LinOp;

#[derive(Clone, Debug)]
pub struct BdmConfig {
    pub h: usize,
    pub w: usize,
    /// Maximum dissipation time (controls how much high frequencies blur).
    pub tau_max: f64,
    /// Cosine-schedule offset `s`.
    pub cosine_s: f64,
    pub t_max: f64,
    pub t_min: f64,
}

impl Default for BdmConfig {
    fn default() -> Self {
        BdmConfig { h: 8, w: 8, tau_max: 0.5, cosine_s: 0.008, t_max: 1.0, t_min: 1e-3 }
    }
}

pub struct Bdm {
    pub cfg: BdmConfig,
    dct: Dct2,
    /// Per-frequency dissipation rates λ_k (flattened row-major).
    lambda: Arc<Vec<f64>>,
}

impl Bdm {
    pub fn new(cfg: BdmConfig) -> Self {
        let dct = Dct2::new(cfg.h, cfg.w);
        let lambda = Arc::new(dct.blur_eigenvalues());
        Bdm { cfg, dct, lambda }
    }

    pub fn standard(h: usize, w: usize) -> Self {
        Bdm::new(BdmConfig { h, w, ..BdmConfig::default() })
    }

    /// Cosine-schedule phase θ(t), clamped away from π/2 to keep ᾱ > 0.
    fn theta(&self, t: f64) -> f64 {
        let s = self.cfg.cosine_s;
        let raw = std::f64::consts::FRAC_PI_2 * (t / self.cfg.t_max + s) / (1.0 + s);
        raw.min(std::f64::consts::FRAC_PI_2 - 1e-2)
    }

    /// ᾱ(t), normalised so ᾱ(0) = 1.
    pub fn alphabar(&self, t: f64) -> f64 {
        let th0 = self.theta(0.0);
        (self.theta(t).cos() / th0.cos()).powi(2)
    }

    /// d log ᾱ / dt.
    fn dlog_alphabar(&self, t: f64) -> f64 {
        let s = self.cfg.cosine_s;
        let th = self.theta(t);
        if th >= std::f64::consts::FRAC_PI_2 - 1e-2 {
            return 0.0; // clamped region
        }
        let dth = std::f64::consts::FRAC_PI_2 / (self.cfg.t_max * (1.0 + s));
        -2.0 * th.tan() * dth
    }

    /// Dissipation time τ(t) = τ_max sin²(πt/2T).
    pub fn tau(&self, t: f64) -> f64 {
        let x = std::f64::consts::FRAC_PI_2 * t / self.cfg.t_max;
        self.cfg.tau_max * x.sin().powi(2)
    }

    fn dtau(&self, t: f64) -> f64 {
        let w = std::f64::consts::PI / self.cfg.t_max;
        self.cfg.tau_max * 0.5 * w * (w * t).sin()
    }

    /// σ_t² = 1 − ᾱ(t), identical for every frequency.
    pub fn sigma2(&self, t: f64) -> f64 {
        1.0 - self.alphabar(t)
    }

    /// Per-frequency mean coefficients α_{t,k} = √ᾱ_t · exp(−λ_k τ_t).
    pub fn alpha_vec(&self, t: f64) -> Vec<f64> {
        let a = self.alphabar(t).sqrt();
        let tau = self.tau(t);
        self.lambda.iter().map(|&l| a * (-l * tau).exp()).collect()
    }

    pub fn dct(&self) -> &Dct2 {
        &self.dct
    }

    pub fn lambda(&self) -> &[f64] {
        &self.lambda
    }
}

impl Process for Bdm {
    fn name(&self) -> &str {
        "bdm"
    }

    fn dim_x(&self) -> usize {
        self.cfg.h * self.cfg.w
    }

    fn dim_u(&self) -> usize {
        self.cfg.h * self.cfg.w
    }

    fn t_max(&self) -> f64 {
        self.cfg.t_max
    }

    fn t_min(&self) -> f64 {
        self.cfg.t_min
    }

    fn f_op(&self, t: f64) -> LinOp {
        // f_k = ½ dlogᾱ − λ_k τ'
        let half_dla = 0.5 * self.dlog_alphabar(t);
        let dtau = self.dtau(t);
        LinOp::diag(self.lambda.iter().map(|&l| half_dla - l * dtau).collect())
    }

    fn ggt_op(&self, t: f64) -> LinOp {
        // g_k² = dσ²/dt − 2 f_k σ²  (App. B.1)
        let s2 = self.sigma2(t);
        let ds2 = -self.dlog_alphabar(t) * self.alphabar(t);
        let half_dla = 0.5 * self.dlog_alphabar(t);
        let dtau = self.dtau(t);
        LinOp::diag(
            self.lambda
                .iter()
                .map(|&l| {
                    let f = half_dla - l * dtau;
                    (ds2 - 2.0 * f * s2).max(0.0)
                })
                .collect(),
        )
    }

    fn psi(&self, t: f64, s: f64) -> LinOp {
        let at = self.alpha_vec(t);
        let as_ = self.alpha_vec(s);
        LinOp::diag(at.iter().zip(as_.iter()).map(|(x, y)| x / y).collect())
    }

    fn sigma(&self, t: f64) -> LinOp {
        LinOp::Scalar(self.sigma2(t))
    }

    fn sigma0(&self) -> LinOp {
        LinOp::Scalar(0.0)
    }

    fn rt(&self, t: f64) -> LinOp {
        LinOp::Scalar(self.sigma2(t).sqrt())
    }

    fn lift_data(&self, x: &[f64]) -> Vec<f64> {
        self.dct.forward(x)
    }

    fn proj_data(&self, u: &[f64]) -> Vec<f64> {
        self.dct.inverse(u)
    }

    fn prior_factor(&self) -> LinOp {
        LinOp::Scalar(self.sigma2(self.cfg.t_max).sqrt())
    }

    fn lift_cov(&self, m2: f64) -> LinOp {
        // An isotropic pixel-space covariance is isotropic in DCT space too
        // (orthonormal transform).
        LinOp::Scalar(m2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::process::validate_process;
    use crate::math::close;

    #[test]
    fn invariants() {
        let p = Bdm::standard(4, 4);
        validate_process(&p, &[1e-3, 0.1, 0.5, 0.9, 1.0]).unwrap();
    }

    #[test]
    fn alphabar_boundaries() {
        let p = Bdm::standard(4, 4);
        assert!(close(p.alphabar(0.0), 1.0, 0.0, 1e-12));
        assert!(p.alphabar(1.0) < 1e-3, "alphabar(T) = {}", p.alphabar(1.0));
    }

    #[test]
    fn high_frequencies_decay_faster() {
        let p = Bdm::standard(8, 8);
        let a = p.alpha_vec(0.5);
        // DC coefficient (index 0) keeps the most signal; the highest
        // frequency (last index) the least.
        assert!(a[0] > a[7], "{} vs {}", a[0], a[7]);
        assert!(a[7] > a[63], "{} vs {}", a[7], a[63]);
    }

    #[test]
    fn diffusion_nonnegative() {
        let p = Bdm::standard(8, 8);
        for &t in &[1e-3, 0.1, 0.3, 0.6, 0.9, 0.999] {
            if let LinOp::Diag(g2) = p.ggt_op(t) {
                assert!(g2.iter().all(|&x| x >= 0.0), "negative g² at t={t}");
            } else {
                panic!("expected Diag");
            }
        }
    }

    #[test]
    fn sde_moments_match_schedule() {
        // Integrating dm/dt = f_k m from s to t must reproduce α_{t,k}/α_{s,k};
        // integrating dv/dt = 2 f_k v + g_k² from 0 must reproduce σ_t².
        let p = Bdm::standard(4, 4);
        let k = 7; // some mid frequency
        let (s, t) = (0.1, 0.8);
        let mut y = vec![1.0];
        crate::math::ode::rk4_integrate(
            &mut |tt: f64, y: &[f64], dy: &mut [f64]| {
                if let LinOp::Diag(f) = p.f_op(tt) {
                    dy[0] = f[k] * y[0];
                } else {
                    unreachable!()
                }
            },
            s,
            t,
            4_000,
            &mut y,
        );
        let expect = p.alpha_vec(t)[k] / p.alpha_vec(s)[k];
        assert!(close(y[0], expect, 1e-5, 1e-8), "mean: {} vs {expect}", y[0]);

        let mut v = vec![0.0];
        crate::math::ode::rk4_integrate(
            &mut |tt: f64, v: &[f64], dv: &mut [f64]| {
                let (f, g2) = match (p.f_op(tt), p.ggt_op(tt)) {
                    (LinOp::Diag(f), LinOp::Diag(g2)) => (f[k], g2[k]),
                    _ => unreachable!(),
                };
                dv[0] = 2.0 * f * v[0] + g2;
            },
            0.0,
            t,
            8_000,
            &mut v,
        );
        assert!(close(v[0], p.sigma2(t), 1e-3, 1e-6), "var: {} vs {}", v[0], p.sigma2(t));
    }

    #[test]
    fn blur_eigenvalues_monotone_at_16x16() {
        // The dimension-generic contract of the dissipation spectrum:
        // λ grows along rows and columns at 16×16 exactly as at 8×8,
        // and higher-λ coefficients keep strictly less signal.
        let p = Bdm::standard(16, 16);
        let lam = p.dct().blur_eigenvalues();
        assert_eq!(lam[0], 0.0, "DC mode never dissipates");
        for i in 1..16 {
            assert!(lam[i] > lam[i - 1], "row-wise λ must increase at index {i}");
            assert!(lam[i * 16] > lam[(i - 1) * 16], "column-wise λ must increase at row {i}");
        }
        let a = p.alpha_vec(0.5);
        for i in 1..16 {
            assert!(a[i] < a[i - 1], "higher frequency must keep less signal (index {i})");
        }
        assert!(a[0] > a[255], "DC must outlive the highest frequency");
    }

    #[test]
    fn lift_proj_roundtrip() {
        let p = Bdm::standard(8, 8);
        let mut rng = crate::math::rng::Rng::seed_from(5);
        let x: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let back = p.proj_data(&p.lift_data(&x));
        crate::math::assert_allclose(&back, &x, 1e-12, 1e-12, "bdm lift/proj");
    }
}
