//! Critically-damped Langevin diffusion (Dockhorn et al. 2021; paper
//! Eq. 10). State `u = (x, v) ∈ R^{2d}`; only the velocity channel is
//! driven by noise, so `Σ_t` is non-diagonal and the choice of `K_t`
//! (its Cholesky `L_t` vs gDDIM's `R_t`) actually matters — this is the
//! paper's main experimental vehicle (Tables 1, 2, 5, 6, 8).
//!
//! Coefficients (constant-β convention of Dockhorn et al., critical
//! damping `Γ² = 4M`):
//!
//! ```text
//!   F_t = β [[0,  M⁻¹], [−1, −ΓM⁻¹]] ⊗ I_d,   G_tG_tᵀ = diag(0, 2Γβ) ⊗ I_d
//!   u(0) = (x₀, v₀),  v₀ ~ N(0, γM I_d)   ⇒  Σ₀ = diag(0, γM)
//! ```
//!
//! Under critical damping `A = βF/β` has a double eigenvalue `−ω`
//! (`ω = 1/√M`) and `A + ωI` is nilpotent, so both the transition matrix
//! `Ψ(t,s) = e^{−ωτ}(I + (A+ωI)τ)`, `τ = β(t−s)`, and the conditional
//! covariance `Σ_t` (elementary exponential-polynomial integrals) are
//! **closed form** — machine-precision Stage-I inputs.
//!
//! Only `R_t` (Eq. 17) has no closed form. Naively integrating the matrix
//! ODE is numerically hopeless near `t=0`: `x` is an integral of `v`, so
//! `corr(x,v) → 1` and `Σ_t` is nearly rank-one — `det Σ` cancels
//! catastrophically and `½G GᵀΣ_t⁻¹` is violently stiff (∼10⁹ at
//! t=10⁻⁵). We instead use the **polar trick**: any two factors of `Σ`
//! differ by an orthogonal matrix, so
//!
//! ```text
//!   R_t = L_t · Rot(φ_t),          L_t = chol(Σ_t)  (closed form),
//!   φ'  = [ L⁻¹F L + ½ L⁻¹G GᵀL⁻ᵀ − L⁻¹L' ]₍₂,₁₎
//! ```
//!
//! (`Σ⁻¹L = L⁻ᵀ` removes `det Σ` entirely; the bracket is skew-symmetric,
//! which the tests verify). `R_tR_tᵀ = Σ_t` then holds to machine
//! precision *by construction*, and the only numerical object is a scalar
//! angle tabulated on a geometric grid — the robust version of the
//! paper's "RK4 with step 1e-6" (App. C.3).

use crate::diffusion::process::Process;
use crate::math::interp::LogTable;
use crate::math::linop::LinOp;
use crate::math::mat2::Mat2;
use crate::math::ode::{rk4_step, Rk4Scratch};

#[derive(Clone, Debug)]
pub struct CldConfig {
    pub d: usize,
    /// Noise scale β (constant in t, Dockhorn et al. use 4.0).
    pub beta: f64,
    /// Mass M (critical damping fixes Γ = 2√M).
    pub mass: f64,
    /// Initial velocity variance scale: v₀ ~ N(0, γM).
    pub gamma0: f64,
    pub t_max: f64,
    pub t_min: f64,
    /// Stored rows of the (log-spaced) R_t table.
    pub table_len: usize,
    /// RK4 substeps between consecutive table rows.
    pub substeps: usize,
}

impl Default for CldConfig {
    fn default() -> Self {
        CldConfig {
            d: 1,
            beta: 4.0,
            mass: 0.25,
            gamma0: 0.04,
            t_max: 1.0,
            t_min: 1e-3,
            table_len: 4096,
            substeps: 8,
        }
    }
}

#[derive(Clone)]
pub struct Cld {
    pub cfg: CldConfig,
    /// Drift structure matrix A with F_t = β·A.
    a: Mat2,
    /// Γ (critical damping).
    gamma: f64,
    /// ω = 1/√M (the double eigenvalue magnitude of A).
    omega: f64,
    /// Rotation angle φ(t) with R_t = L_t·Rot(φ_t), on a geometric grid.
    phi_tab: LogTable,
    r_start: f64,
}

/// 2×2 rotation by angle φ.
fn rot(phi: f64) -> Mat2 {
    Mat2::new(phi.cos(), -phi.sin(), phi.sin(), phi.cos())
}

impl Cld {
    pub fn new(cfg: CldConfig) -> Self {
        let m_inv = 1.0 / cfg.mass;
        let gamma = 2.0 * cfg.mass.sqrt(); // critical damping Γ = 2√M
        let omega = 1.0 / cfg.mass.sqrt();
        let a = Mat2::new(0.0, m_inv, -1.0, -gamma * m_inv);

        let r_start = cfg.t_min * 1e-2;
        let proto = Cld {
            cfg: cfg.clone(),
            a,
            gamma,
            omega,
            phi_tab: LogTable::from_values(1.0, 2.0, vec![vec![0.0], vec![0.0]]),
            r_start,
        };

        // φ(r_start): R(r_start) = sqrtm(Σ) = L·Rot(φ₀)
        //   ⇒ Rot(φ₀) = L⁻¹ sqrtm(Σ).
        let s0 = proto.sigma_mat(r_start);
        let w0 = s0.cholesky().inv() * s0.sqrtm_spd();
        let phi0 = w0.c.atan2(w0.a);

        let mut rhs = |t: f64, _y: &[f64], dy: &mut [f64]| {
            dy[0] = proto.phi_rate(t);
        };
        let n = cfg.table_len;
        let ratio = (cfg.t_max / r_start).ln();
        let mut y = vec![phi0];
        let mut rows = Vec::with_capacity(n + 1);
        rows.push(y.clone());
        let mut scratch = Rk4Scratch::default();
        for i in 0..n {
            let t_lo = r_start * (ratio * i as f64 / n as f64).exp();
            let t_hi = r_start * (ratio * (i + 1) as f64 / n as f64).exp();
            let h = (t_hi - t_lo) / cfg.substeps as f64;
            for k in 0..cfg.substeps {
                rk4_step(&mut rhs, t_lo + k as f64 * h, h, &mut y, &mut scratch);
            }
            rows.push(y.clone());
        }
        let phi_tab = LogTable::from_values(r_start, cfg.t_max, rows);

        Cld { cfg, a, gamma, omega, phi_tab, r_start }
    }

    /// Time derivative of Σ_t (Lyapunov RHS with closed-form Σ).
    fn sigma_dot(&self, t: f64) -> Mat2 {
        let s = self.sigma_mat(t);
        let f = self.a.scale(self.cfg.beta);
        let ggt = Mat2::new(0.0, 0.0, 0.0, 2.0 * self.gamma * self.cfg.beta);
        (f * s + s * f.transpose() + ggt).sym()
    }

    /// Cholesky factor L_t and its derivative L'_t, both closed form.
    fn chol_and_dot(&self, t: f64) -> (Mat2, Mat2) {
        let s = self.sigma_mat(t);
        let sd = self.sigma_dot(t);
        let l11 = s.a.max(0.0).sqrt();
        let l21 = s.b / l11;
        let l22 = (s.d - l21 * l21).max(0.0).sqrt();
        let d11 = sd.a / (2.0 * l11);
        let d21 = (sd.b - l21 * d11) / l11;
        let d22 = (sd.d - 2.0 * l21 * d21) / (2.0 * l22);
        (Mat2::new(l11, 0.0, l21, l22), Mat2::new(d11, 0.0, d21, d22))
    }

    /// The generator of the rotation factor:
    /// `M = L⁻¹ F L + ½ L⁻¹ G GᵀL⁻ᵀ − L⁻¹L'` is skew-symmetric and
    /// `φ' = M₍₂,₁₎`.
    pub fn phi_rate(&self, t: f64) -> f64 {
        let (l, ld) = self.chol_and_dot(t);
        let li = l.inv();
        let f = self.a.scale(self.cfg.beta);
        let ggt_half = Mat2::new(0.0, 0.0, 0.0, self.gamma * self.cfg.beta);
        let m = li * f * l + li * ggt_half * li.transpose() - li * ld;
        m.c
    }

    /// Skew-residual of the rotation generator (diagnostic; ≈0 when the
    /// closed forms are consistent). Exposed for tests.
    pub fn phi_skew_residual(&self, t: f64) -> f64 {
        let (l, ld) = self.chol_and_dot(t);
        let li = l.inv();
        let f = self.a.scale(self.cfg.beta);
        let ggt_half = Mat2::new(0.0, 0.0, 0.0, self.gamma * self.cfg.beta);
        let m = li * f * l + li * ggt_half * li.transpose() - li * ld;
        m.a.abs().max(m.d.abs()).max((m.b + m.c).abs())
    }

    pub fn standard(d: usize) -> Self {
        Cld::new(CldConfig { d, ..CldConfig::default() })
    }

    /// Closed-form conditional covariance `Σ_t` (see module docs):
    /// `Σ_t = Ψ(t,0) Σ₀ Ψ(t,0)ᵀ + 2Γβ ∫₀ᵗ Ψ(t,s) e₂e₂ᵀ Ψ(t,s)ᵀ ds`.
    pub fn sigma_mat(&self, t: f64) -> Mat2 {
        let w = self.omega;
        let tb = self.cfg.beta * t.max(0.0); // integrated time τ = βt
        let e = (-2.0 * w * tb).exp();

        // Initial velocity Gaussian pushed through Ψ(t,0):
        // Ψ e₂ = e^{-ωτ} (ω²τ, 1-ωτ)ᵀ.
        let g0 = self.cfg.gamma0 * self.cfg.mass;
        let p = w * w * tb;
        let q = 1.0 - w * tb;
        let init = Mat2::new(p * p, p * q, p * q, q * q).scale(g0 * e);

        // Noise integral with a = 2ω:
        //   I0 = (1-e)/a, I1 = (1-e(1+aτ))/a², I2 = (2-e(2+2aτ+a²τ²))/a³.
        let aa = 2.0 * w;
        let at = aa * tb;
        let (i0, i1, i2) = if at < 1e-4 {
            // Series for small τ to avoid cancellation:
            // I0 ≈ τ - aτ²/2, I1 ≈ τ²/2 - aτ³/3, I2 ≈ τ³/3 - aτ⁴/4.
            (
                tb - aa * tb * tb / 2.0 + aa * aa * tb.powi(3) / 6.0,
                tb * tb / 2.0 - aa * tb.powi(3) / 3.0,
                tb.powi(3) / 3.0 - aa * tb.powi(4) / 4.0,
            )
        } else {
            (
                (1.0 - e) / aa,
                (1.0 - e * (1.0 + at)) / (aa * aa),
                (2.0 - e * (2.0 + 2.0 * at + at * at)) / (aa * aa * aa),
            )
        };
        // Ψ(t,s)e₂ = e^{-ωτ'}(ω²τ', 1-ωτ')ᵀ with τ' = β(t-s); ∫ ds = ∫ dτ'/β.
        let c = 2.0 * self.gamma; // (2Γβ)/β
        let noise = Mat2::new(
            w.powi(4) * i2,
            w * w * (i1 - w * i2),
            w * w * (i1 - w * i2),
            i0 - 2.0 * w * i1 + w * w * i2,
        )
        .scale(c);

        (init + noise).sym()
    }

    pub fn r_mat(&self, t: f64) -> Mat2 {
        let t = t.clamp(self.r_start, self.cfg.t_max);
        let phi = self.phi_tab.eval(t)[0];
        let (l, _) = self.chol_and_dot(t);
        l * rot(phi)
    }

    /// Closed-form `Ψ(t,s) = e^{−ωτ}(I + (A+ωI)τ)`, `τ = β(t−s)`.
    pub fn psi_mat(&self, t: f64, s: f64) -> Mat2 {
        let w = self.omega;
        let tau = self.cfg.beta * (t - s);
        let nil = self.a + Mat2::scalar(w);
        (Mat2::IDENT + nil.scale(tau)).scale((-w * tau).exp())
    }

    /// Γ (critical damping constant).
    pub fn damping(&self) -> f64 {
        self.gamma
    }
}

impl Process for Cld {
    fn name(&self) -> &str {
        "cld"
    }

    fn dim_x(&self) -> usize {
        self.cfg.d
    }

    fn dim_u(&self) -> usize {
        2 * self.cfg.d
    }

    fn t_max(&self) -> f64 {
        self.cfg.t_max
    }

    fn t_min(&self) -> f64 {
        self.cfg.t_min
    }

    fn f_op(&self, _t: f64) -> LinOp {
        LinOp::Block2(self.a.scale(self.cfg.beta))
    }

    fn ggt_op(&self, _t: f64) -> LinOp {
        LinOp::Block2(Mat2::new(0.0, 0.0, 0.0, 2.0 * self.gamma * self.cfg.beta))
    }

    fn g_op(&self, _t: f64) -> LinOp {
        LinOp::Block2(Mat2::new(0.0, 0.0, 0.0, (2.0 * self.gamma * self.cfg.beta).sqrt()))
    }

    fn psi(&self, t: f64, s: f64) -> LinOp {
        LinOp::Block2(self.psi_mat(t, s))
    }

    fn sigma(&self, t: f64) -> LinOp {
        LinOp::Block2(self.sigma_mat(t))
    }

    fn sigma0(&self) -> LinOp {
        LinOp::Block2(Mat2::diag(0.0, self.cfg.gamma0 * self.cfg.mass))
    }

    fn rt(&self, t: f64) -> LinOp {
        LinOp::Block2(self.r_mat(t))
    }

    fn lift_data(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.cfg.d);
        let mut u = vec![0.0; 2 * self.cfg.d];
        u[..self.cfg.d].copy_from_slice(x);
        u
    }

    fn proj_data(&self, u: &[f64]) -> Vec<f64> {
        u[..self.cfg.d].to_vec()
    }

    fn prior_factor(&self) -> LinOp {
        // Stationary covariance of CLD is diag(1, M).
        LinOp::Block2(Mat2::diag(1.0, self.cfg.mass.sqrt()))
    }

    fn lift_cov(&self, m2: f64) -> LinOp {
        LinOp::Block2(Mat2::diag(m2, 0.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::process::validate_process;
    use crate::math::close;

    #[test]
    fn invariants() {
        let p = Cld::standard(1);
        validate_process(&p, &[1e-3, 0.05, 0.3, 0.7, 1.0]).unwrap();
    }

    #[test]
    fn sigma_matches_lyapunov_ode() {
        // Closed form must agree with a brute-force RK4 Lyapunov solve.
        let p = Cld::standard(1);
        let beta = p.cfg.beta;
        let a = p.a;
        let ggt_vv = 2.0 * p.gamma * beta;
        for &t in &[1e-3, 0.05, 0.4, 1.0] {
            let mut y = vec![0.0, 0.0, p.cfg.gamma0 * p.cfg.mass];
            crate::math::ode::rk4_integrate(
                &mut |_tt: f64, y: &[f64], dy: &mut [f64]| {
                    let s = Mat2::new(y[0], y[1], y[1], y[2]);
                    let f = a.scale(beta);
                    let d = f * s + s * f.transpose();
                    dy[0] = d.a;
                    dy[1] = 0.5 * (d.b + d.c);
                    dy[2] = d.d + ggt_vv;
                },
                0.0,
                t,
                20_000,
                &mut y,
            );
            let s = p.sigma_mat(t);
            assert!(close(s.a, y[0], 1e-7, 1e-12), "t={t} xx: {} vs {}", s.a, y[0]);
            assert!(close(s.b, y[1], 1e-7, 1e-12), "t={t} xv: {} vs {}", s.b, y[1]);
            assert!(close(s.d, y[2], 1e-7, 1e-12), "t={t} vv: {} vs {}", s.d, y[2]);
        }
    }

    #[test]
    fn sigma_approaches_stationary() {
        // Stationary covariance is diag(1, M).
        let mut cfg = CldConfig::default();
        cfg.t_max = 4.0; // run long to converge
        let p = Cld::new(cfg.clone());
        let s = p.sigma_mat(4.0);
        assert!(close(s.a, 1.0, 0.0, 1e-2), "Sxx={}", s.a);
        assert!(close(s.d, cfg.mass, 0.0, 1e-2), "Svv={}", s.d);
        assert!(s.b.abs() < 1e-2, "Sxv={}", s.b);
    }

    #[test]
    fn psi_is_transition_matrix_of_f() {
        // Ψ(t,s) must solve dΨ/dt = FΨ; compare against RK4.
        let p = Cld::standard(1);
        let (s, t) = (0.2, 0.9);
        let beta = p.cfg.beta;
        let a = p.a;
        let mut y = Mat2::IDENT.to_array().to_vec();
        crate::math::ode::rk4_integrate(
            &mut move |_t: f64, y: &[f64], dy: &mut [f64]| {
                let m = Mat2::from_array([y[0], y[1], y[2], y[3]]);
                let d = a.scale(beta) * m;
                dy.copy_from_slice(&d.to_array());
            },
            s,
            t,
            4_000,
            &mut y,
        );
        let psi = p.psi_mat(t, s);
        for (u, v) in psi.to_array().iter().zip(&y) {
            assert!(close(*u, *v, 1e-8, 1e-10), "{u} vs {v}");
        }
    }

    #[test]
    fn psi_matches_expm() {
        let p = Cld::standard(1);
        let (s, t) = (0.1, 0.75);
        let via_expm = p.a.scale(p.cfg.beta * (t - s)).expm();
        assert!((p.psi_mat(t, s) - via_expm).max_abs() < 1e-12);
    }

    #[test]
    fn rt_factorizes_sigma_everywhere() {
        // By construction (polar trick) this must hold to machine precision.
        let p = Cld::standard(1);
        for &t in &[1e-3, 0.01, 0.1, 0.5, 1.0] {
            let r = p.r_mat(t);
            let s = p.sigma_mat(t);
            let err = (r * r.transpose() - s).max_abs();
            assert!(err < 1e-12 + 1e-12 * s.max_abs(), "t={t}: err={err}");
        }
    }

    #[test]
    fn rotation_generator_is_skew() {
        // The bracket L⁻¹FL + ½L⁻¹GGᵀL⁻ᵀ − L⁻¹L' must be skew-symmetric —
        // this is the internal consistency check of the polar-trick
        // derivation (it fails loudly if Σ, Σ', or L' are wrong).
        let p = Cld::standard(1);
        for &t in &[1e-4, 1e-3, 0.01, 0.1, 0.5, 1.0] {
            let res = p.phi_skew_residual(t);
            let scale = p.phi_rate(t).abs() + 1.0;
            assert!(res < 1e-7 * scale, "t={t}: skew residual {res} (rate {})", p.phi_rate(t));
        }
    }

    #[test]
    fn rt_differs_from_cholesky() {
        // The whole point of gDDIM on CLD: R_t is NOT the Cholesky factor.
        let p = Cld::standard(1);
        let t = 0.5;
        let r = p.r_mat(t);
        let l = p.sigma_mat(t).cholesky();
        assert!((r - l).max_abs() > 1e-2, "R_t should differ from L_t: {r:?} vs {l:?}");
        // but both factor Σ
        assert!((l * l.transpose() - p.sigma_mat(t)).max_abs() < 1e-9);
    }

    #[test]
    fn noise_only_enters_velocity() {
        let p = Cld::standard(3);
        let g = p.g_op(0.3);
        let mut rng = crate::math::rng::Rng::seed_from(9);
        let mut z = vec![0.0; 6];
        g.sample_noise(&mut rng, &mut z);
        assert!(z[..3].iter().all(|&x| x == 0.0), "x-channel must get no direct noise");
        assert!(z[3..].iter().any(|&x| x != 0.0));
    }

    #[test]
    fn rt_satisfies_eq17_ode() {
        // Residual check of dR/dt = (F + ½GGᵀΣ⁻¹)R via finite differences.
        let p = Cld::standard(1);
        let t = 0.4;
        let h = 1e-4;
        let num = (p.r_mat(t + h) - p.r_mat(t - h)).scale(1.0 / (2.0 * h));
        let ggt_half = Mat2::new(0.0, 0.0, 0.0, p.gamma * p.cfg.beta);
        let drift = p.a.scale(p.cfg.beta) + ggt_half * p.sigma_mat(t).inv();
        let ana = drift * p.r_mat(t);
        assert!((num - ana).max_abs() < 1e-3 * (1.0 + ana.max_abs()), "{num:?} vs {ana:?}");
    }
}
