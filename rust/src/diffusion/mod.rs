//! The diffusion-model substrate: the three linear-SDE forward processes
//! the paper evaluates (Sec. 2), behind one [`Process`] trait.
//!
//! A forward process is `du = F_t u dt + G_t dw` (Eq. 1) with Gaussian
//! transition `p_{0t}(u(t)|u(0)) = N(Ψ(t,0) u(0) + …, Σ_t)`; everything a
//! sampler or the Stage-I coefficient engine needs is a handful of
//! time-indexed structured matrices exposed here as
//! [`LinOp`](crate::math::linop::LinOp)s.

pub mod process;
pub mod vpsde;
pub mod cld;
pub mod bdm;
pub mod schedule;

pub use process::{Process, KtKind};
pub use vpsde::Vpsde;
pub use cld::Cld;
pub use bdm::Bdm;
pub use schedule::TimeGrid;

use crate::data::presets::Preset;
use std::sync::Arc;

/// Build the named forward process sized for a catalogue dataset — the
/// one construction path shared by the CLI, the experiment harnesses,
/// and the server's oracle factory (each used to hard-code its own
/// `sqrt(d)` guess for BDM's image side). VPSDE/CLD work at any `d`;
/// BDM is an image-space process and takes its `(h, w)` from the
/// preset's registry metadata, so a vector dataset is a clean error
/// here instead of a dimension-mismatch panic deep in model
/// construction.
pub fn process_for(process: &str, info: &Preset) -> crate::Result<Arc<dyn Process>> {
    match process {
        "vpsde" => Ok(Arc::new(Vpsde::standard(info.d))),
        "cld" => Ok(Arc::new(Cld::standard(info.d))),
        "bdm" => {
            let (h, w) = info.require_image_dims()?;
            Ok(Arc::new(Bdm::standard(h, w)))
        }
        other => Err(crate::Error::msg(format!("unknown process `{other}`"))),
    }
}
