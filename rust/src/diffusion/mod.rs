//! The diffusion-model substrate: the three linear-SDE forward processes
//! the paper evaluates (Sec. 2), behind one [`Process`] trait.
//!
//! A forward process is `du = F_t u dt + G_t dw` (Eq. 1) with Gaussian
//! transition `p_{0t}(u(t)|u(0)) = N(Ψ(t,0) u(0) + …, Σ_t)`; everything a
//! sampler or the Stage-I coefficient engine needs is a handful of
//! time-indexed structured matrices exposed here as
//! [`LinOp`](crate::math::linop::LinOp)s.

pub mod process;
pub mod vpsde;
pub mod cld;
pub mod bdm;
pub mod schedule;

pub use process::{Process, KtKind};
pub use vpsde::Vpsde;
pub use cld::Cld;
pub use bdm::Bdm;
pub use schedule::TimeGrid;
