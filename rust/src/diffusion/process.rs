//! The [`Process`] trait: everything the paper needs from a diffusion
//! model, as time-indexed structured matrices.
//!
//! Conventions (matching the paper, Sec. 2):
//! * forward SDE `du = F_t u dt + G_t dw`, `t ∈ [0, T]` (Eq. 1);
//! * `Ψ(t,s)` is the transition matrix of `F` (`∂Ψ/∂t = F_tΨ`, `Ψ(s,s)=I`);
//! * `Σ_t` is the covariance of `p_{0t}(u(t) | data point)` — for CLD this
//!   *includes* the initial velocity Gaussian `Σ₀ = diag(0, γM)` (Prop 4
//!   uses a Gaussian initial distribution precisely for this reason);
//! * `mean(t)` maps a data point into the state mean:
//!   `E[u(t)] = Ψ(t,0) · lift(x₀)`.
//!
//! For BDM the *state is the DCT spectrum* of the image: `lift_data`
//! applies the forward DCT and `proj_data` the inverse. That turns every
//! coefficient into a [`LinOp::Diag`] and makes the paper's Eq. 11 SDE
//! per-frequency scalar.

use crate::math::linop::LinOp;

/// Which square root of `Σ_t` parameterizes the score network
/// (`s_θ(u,t) = −K_t^{-T} ε_θ(u,t)`, Eq. 4). The whole point of gDDIM
/// (Sec. 4) is that `K_t = R_t` — the solution of Eq. 17 — is the right
/// choice, while CLD's original `L_t` (Cholesky) is not.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum KtKind {
    /// gDDIM's `R_t`: solves `dR/dt = (F_t + ½G_tG_tᵀΣ_t⁻¹)R_t` (Eq. 17).
    R,
    /// Cholesky factor `L_t` of `Σ_t` (Dockhorn et al.'s CLD choice, Eq. 78).
    L,
    /// Symmetric principal square root `Σ_t^{1/2}` (used in ablations).
    SqrtSigma,
}

impl KtKind {
    pub fn label(&self) -> &'static str {
        match self {
            KtKind::R => "R_t",
            KtKind::L => "L_t",
            KtKind::SqrtSigma => "sqrt(Sigma)",
        }
    }

    /// Short machine token; round-trips through the `FromStr` impl
    /// (used by the sampler-spec grammar and plan persistence).
    pub fn token(&self) -> &'static str {
        match self {
            KtKind::R => "R",
            KtKind::L => "L",
            KtKind::SqrtSigma => "sqrt",
        }
    }
}

impl std::str::FromStr for KtKind {
    type Err = String;
    fn from_str(s: &str) -> Result<Self, String> {
        match s.to_ascii_lowercase().as_str() {
            "r" | "rt" | "r_t" => Ok(KtKind::R),
            "l" | "lt" | "l_t" => Ok(KtKind::L),
            "sqrt" | "sqrtsigma" => Ok(KtKind::SqrtSigma),
            other => Err(format!("unknown K_t kind: {other}")),
        }
    }
}

/// A linear-SDE diffusion model (paper Eq. 1).
pub trait Process: Send + Sync {
    /// Short identifier ("vpsde", "cld", "bdm").
    fn name(&self) -> &str;

    /// Data dimension `d`.
    fn dim_x(&self) -> usize;

    /// State dimension `D` (`d`, or `2d` for CLD).
    fn dim_u(&self) -> usize;

    /// Final diffusion time `T`.
    fn t_max(&self) -> f64;

    /// Earliest sampling time ε (the "smaller stop sampling time" trick
    /// from Karras et al. that the paper adopts, Sec. 5).
    fn t_min(&self) -> f64;

    /// Drift coefficient `F_t`.
    fn f_op(&self, t: f64) -> LinOp;

    /// Diffusion outer product `G_t G_tᵀ`.
    fn ggt_op(&self, t: f64) -> LinOp;

    /// A factor `G_t` with `G_tG_tᵀ` as above (for injecting noise).
    fn g_op(&self, t: f64) -> LinOp {
        self.ggt_op(t).sqrt_spd()
    }

    /// Transition matrix `Ψ(t, s)` of `F`.
    fn psi(&self, t: f64, s: f64) -> LinOp;

    /// Conditional covariance `Σ_t` of `p_{0t}(u(t)|x₀)` (see module docs
    /// re: CLD's velocity Gaussian).
    fn sigma(&self, t: f64) -> LinOp;

    /// Initial covariance `Σ₀` (zero for Dirac data; `diag(0, γM)` for CLD).
    fn sigma0(&self) -> LinOp;

    /// gDDIM's `R_t` (Eq. 17). Implementations precompute a table.
    fn rt(&self, t: f64) -> LinOp;

    /// The `K_t` requested by a parameterization kind.
    fn kt(&self, kind: KtKind, t: f64) -> LinOp {
        match kind {
            KtKind::R => self.rt(t),
            KtKind::L => self.sigma(t).cholesky(),
            KtKind::SqrtSigma => self.sigma(t).sqrt_spd(),
        }
    }

    /// Embed a data point into state space (mean of `p₀` given `x₀`).
    fn lift_data(&self, x: &[f64]) -> Vec<f64>;

    /// Project a state back to data space.
    fn proj_data(&self, u: &[f64]) -> Vec<f64>;

    /// Stationary/prior std used to draw `u(T) ~ p_T`: the sampler draws
    /// `u(T) = prior_factor() · z`, `z ~ N(0, I)`.
    fn prior_factor(&self) -> LinOp {
        self.sigma(self.t_max()).sqrt_spd()
    }

    /// Marginal covariance of `u(t)` for data with second moment
    /// `E[x₀x₀ᵀ] = m2·I` (used by the exact-score oracle sanity checks).
    fn marginal_sigma(&self, t: f64, m2: f64) -> LinOp {
        let psi = self.psi(t, 0.0);
        let lifted = self.lift_cov(m2);
        psi.matmul(&lifted).matmul(&psi.transpose()).add(&self.sigma(t))
    }

    /// Lift an isotropic data covariance `m2·I_d` into state space
    /// (zero velocity block for CLD).
    fn lift_cov(&self, m2: f64) -> LinOp;
}

/// Verify `Process` invariants at a set of probe times; used by each
/// implementation's tests and by `gddim selfcheck`.
pub fn validate_process(p: &dyn Process, probes: &[f64]) -> Result<(), String> {
    let (t0, t1) = (p.t_min(), p.t_max());
    if !(t0 > 0.0 && t1 > t0) {
        return Err(format!("bad time range [{t0}, {t1}]"));
    }
    for &t in probes {
        // Ψ(t,t) = I
        if p.psi(t, t).dist(&LinOp::ident()) > 1e-9 {
            return Err(format!("Psi(t,t) != I at t={t}"));
        }
        // Σ_t symmetric positive semidefinite-ish: sqrt roundtrip
        let sig = p.sigma(t);
        let root = sig.sqrt_spd();
        if root.matmul(&root.transpose()).dist(&sig) > 1e-7 * (1.0 + sig.max_abs()) {
            return Err(format!("Sigma not PSD-consistent at t={t}"));
        }
        // R_t R_tᵀ = Σ_t (the paper remarks R_t satisfies this like K_t)
        let r = p.rt(t);
        let rrt = r.matmul(&r.transpose());
        if rrt.dist(&sig) > 1e-5 * (1.0 + sig.max_abs()) {
            return Err(format!(
                "R_t R_tᵀ != Σ_t at t={t}: dist={}",
                rrt.dist(&sig)
            ));
        }
    }
    // Semigroup: Ψ(t2, t0) = Ψ(t2, t1)Ψ(t1, t0)
    let (a, b, c) = (t0, 0.5 * (t0 + t1), t1);
    let lhs = p.psi(c, a);
    let rhs = p.psi(c, b).matmul(&p.psi(b, a));
    if lhs.dist(&rhs) > 1e-7 * (1.0 + lhs.max_abs()) {
        return Err(format!("Psi semigroup violated: dist={}", lhs.dist(&rhs)));
    }
    Ok(())
}
