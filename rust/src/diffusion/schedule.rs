//! Time discretization grids `{t_i}_{i=0}^N` (paper Sec. 4.1:
//! `t_0 = ε, t_N = T`). Stored ascending; samplers walk them backwards.

/// A sampling time grid. `ts[0] = t_min`, `ts.last() = t_max`.
#[derive(Clone, Debug, PartialEq)]
pub struct TimeGrid {
    pub ts: Vec<f64>,
}

impl TimeGrid {
    /// Uniform spacing on [t_min, t_max] with `n` steps (n+1 nodes) —
    /// the paper's default for the FID-vs-NFE tables.
    pub fn uniform(t_min: f64, t_max: f64, n: usize) -> TimeGrid {
        assert!(n >= 1 && t_max > t_min);
        let ts = (0..=n)
            .map(|i| t_min + (t_max - t_min) * i as f64 / n as f64)
            .collect();
        TimeGrid { ts }
    }

    /// Quadratic spacing (finer near t_min where the score is stiff).
    pub fn quadratic(t_min: f64, t_max: f64, n: usize) -> TimeGrid {
        assert!(n >= 1 && t_max > t_min);
        let ts = (0..=n)
            .map(|i| {
                let x = i as f64 / n as f64;
                t_min + (t_max - t_min) * x * x
            })
            .collect();
        TimeGrid { ts }
    }

    /// Power-law spacing with exponent ρ (ρ=1 uniform, ρ=2 quadratic, …).
    pub fn power(t_min: f64, t_max: f64, n: usize, rho: f64) -> TimeGrid {
        assert!(n >= 1 && t_max > t_min && rho > 0.0);
        let ts = (0..=n)
            .map(|i| {
                let x = i as f64 / n as f64;
                t_min + (t_max - t_min) * x.powf(rho)
            })
            .collect();
        TimeGrid { ts }
    }

    /// Number of steps N (grid has N+1 nodes).
    pub fn n_steps(&self) -> usize {
        self.ts.len() - 1
    }

    pub fn t_min(&self) -> f64 {
        self.ts[0]
    }

    pub fn t_max(&self) -> f64 {
        // gddim-lint: allow(panic-reachability) — constructors assert n >= 1 (two or more nodes) and plan construction revalidates with is_valid(), so a grid on the serving path is never empty
        *self.ts.last().unwrap()
    }

    /// Validate monotonicity; used by plan construction.
    pub fn is_valid(&self) -> bool {
        self.ts.len() >= 2 && self.ts.windows(2).all(|w| w[1] > w[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    #[test]
    fn uniform_endpoints_and_spacing() {
        let g = TimeGrid::uniform(1e-3, 1.0, 10);
        assert_eq!(g.ts.len(), 11);
        assert!(close(g.t_min(), 1e-3, 0.0, 1e-15));
        assert!(close(g.t_max(), 1.0, 0.0, 1e-15));
        let d0 = g.ts[1] - g.ts[0];
        for w in g.ts.windows(2) {
            assert!(close(w[1] - w[0], d0, 1e-10, 1e-12));
        }
        assert!(g.is_valid());
    }

    #[test]
    fn quadratic_is_finer_near_start() {
        let g = TimeGrid::quadratic(1e-3, 1.0, 10);
        assert!(g.ts[1] - g.ts[0] < g.ts[10] - g.ts[9]);
        assert!(g.is_valid());
    }

    #[test]
    fn power_one_is_uniform() {
        let a = TimeGrid::uniform(0.01, 2.0, 7);
        let b = TimeGrid::power(0.01, 2.0, 7, 1.0);
        for (x, y) in a.ts.iter().zip(&b.ts) {
            assert!(close(*x, *y, 1e-12, 1e-14));
        }
    }
}
