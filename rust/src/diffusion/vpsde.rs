//! VPSDE / continuous-time DDPM (paper Eq. 8).
//!
//! `F_t = ½ d log α_t/dt · I`, `G_t = √(−d log α_t/dt) · I` with the
//! standard linear-β schedule `β(t) = β₀ + t(β₁−β₀)` and
//! `α_t = exp(−∫₀ᵗ β)`. Every coefficient is scalar; `R_t = L_t =
//! √(1−α_t)·I`, which is exactly why gDDIM collapses to DDIM here
//! (Sec. 4: "we remark `K_t = √(1−α_t) I_d` is a solution to Eq. 17").

use crate::diffusion::process::Process;
use crate::math::linop::LinOp;

#[derive(Clone, Debug)]
pub struct Vpsde {
    pub d: usize,
    pub beta0: f64,
    pub beta1: f64,
    pub t_max: f64,
    pub t_min: f64,
}

impl Vpsde {
    /// Standard score-SDE hyperparameters (β₀=0.1, β₁=20, T=1).
    pub fn standard(d: usize) -> Self {
        Vpsde { d, beta0: 0.1, beta1: 20.0, t_max: 1.0, t_min: 1e-3 }
    }

    #[inline]
    pub fn beta(&self, t: f64) -> f64 {
        self.beta0 + t * (self.beta1 - self.beta0)
    }

    /// `∫₀ᵗ β(s) ds`.
    #[inline]
    pub fn beta_int(&self, t: f64) -> f64 {
        self.beta0 * t + 0.5 * (self.beta1 - self.beta0) * t * t
    }

    /// `α_t = exp(−∫β)` — the paper's decreasing α with α₀=1, α_T≈0.
    #[inline]
    pub fn alpha(&self, t: f64) -> f64 {
        (-self.beta_int(t)).exp()
    }
}

impl Process for Vpsde {
    fn name(&self) -> &str {
        "vpsde"
    }

    fn dim_x(&self) -> usize {
        self.d
    }

    fn dim_u(&self) -> usize {
        self.d
    }

    fn t_max(&self) -> f64 {
        self.t_max
    }

    fn t_min(&self) -> f64 {
        self.t_min
    }

    fn f_op(&self, t: f64) -> LinOp {
        // ½ dlogα/dt = −½β(t)
        LinOp::Scalar(-0.5 * self.beta(t))
    }

    fn ggt_op(&self, t: f64) -> LinOp {
        LinOp::Scalar(self.beta(t))
    }

    fn psi(&self, t: f64, s: f64) -> LinOp {
        // √(α_t/α_s) = exp(−½(B(t)−B(s)))
        LinOp::Scalar((-0.5 * (self.beta_int(t) - self.beta_int(s))).exp())
    }

    fn sigma(&self, t: f64) -> LinOp {
        LinOp::Scalar(1.0 - self.alpha(t))
    }

    fn sigma0(&self) -> LinOp {
        LinOp::Scalar(0.0)
    }

    fn rt(&self, t: f64) -> LinOp {
        LinOp::Scalar((1.0 - self.alpha(t)).sqrt())
    }

    fn lift_data(&self, x: &[f64]) -> Vec<f64> {
        x.to_vec()
    }

    fn proj_data(&self, u: &[f64]) -> Vec<f64> {
        u.to_vec()
    }

    fn prior_factor(&self) -> LinOp {
        LinOp::Scalar(1.0)
    }

    fn lift_cov(&self, m2: f64) -> LinOp {
        LinOp::Scalar(m2)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::process::validate_process;
    use crate::math::{close, ode::rk4_integrate};

    #[test]
    fn invariants() {
        let p = Vpsde::standard(2);
        validate_process(&p, &[1e-3, 0.1, 0.5, 0.9, 1.0]).unwrap();
    }

    #[test]
    fn alpha_boundary_values() {
        let p = Vpsde::standard(1);
        assert!(close(p.alpha(0.0), 1.0, 0.0, 1e-15));
        assert!(p.alpha(1.0) < 5e-5, "alpha_T = {}", p.alpha(1.0)); // ~exp(-10.05)
    }

    #[test]
    fn sigma_solves_lyapunov_ode() {
        // dΣ/dt = 2FΣ + GGᵀ with Σ(0)=0 must match 1−α_t.
        let p = Vpsde::standard(1);
        let mut y = vec![0.0];
        let pc = p.clone();
        rk4_integrate(
            &mut move |t: f64, y: &[f64], dy: &mut [f64]| {
                dy[0] = -pc.beta(t) * y[0] + pc.beta(t);
            },
            0.0,
            0.7,
            2_000,
            &mut y,
        );
        assert!(close(y[0], 1.0 - p.alpha(0.7), 1e-8, 1e-10));
    }

    #[test]
    fn psi_solves_transition_ode() {
        let p = Vpsde::standard(1);
        let mut y = vec![1.0];
        let pc = p.clone();
        rk4_integrate(
            &mut move |t: f64, y: &[f64], dy: &mut [f64]| {
                dy[0] = -0.5 * pc.beta(t) * y[0];
            },
            0.2,
            0.9,
            2_000,
            &mut y,
        );
        let psi = match p.psi(0.9, 0.2) {
            LinOp::Scalar(s) => s,
            _ => unreachable!(),
        };
        assert!(close(y[0], psi, 1e-10, 0.0));
    }

    #[test]
    fn ddpm_identity_sqrt_ratio() {
        // Ψ(t,s) = sqrt(α_t/α_s) (used throughout Sec. 3 derivations).
        let p = Vpsde::standard(1);
        let (s, t) = (0.3, 0.8);
        let psi = match p.psi(t, s) {
            LinOp::Scalar(x) => x,
            _ => unreachable!(),
        };
        assert!(close(psi, (p.alpha(t) / p.alpha(s)).sqrt(), 1e-13, 0.0));
    }
}
