//! The sharded parallel sampling engine.
//!
//! Few-NFE sampling makes per-request work small enough that coordinator
//! throughput — not the score model — becomes the serving bottleneck.
//! This module turns one batched sampling job into data-parallel work:
//!
//! 1. **Shard**: the batch of `n` samples is split into fixed-row shards
//!    sized by [`EngineConfig::rows_per_shard`] — either an explicit row
//!    count or a dimension-aware byte budget
//!    ([`EngineConfig::shard_bytes`]), so a 1024-dim blobs32 shard holds
//!    the same state footprint as a 64-dim blobs8 one. The layout
//!    depends only on `(n, rows_per_shard(dim_u))` — never on the worker
//!    count — so the output is stable under any pool size.
//! 2. **Seed**: every shard gets its own [`Rng`] stream, derived from the
//!    job seed by index. Stream derivation is a pure function of
//!    `(seed, shard_index)`, which makes the merged output bit-identical
//!    for 1 worker and for N workers.
//! 3. **Execute**: a *persistent* worker pool (threads spawned once in
//!    [`Engine::with_config`], fed through an `mpsc` job queue) drives the
//!    job's [`Sampler`] state machine on each shard — step by step, with
//!    every score evaluation crossing the explicit
//!    [`ScoreRequest`](crate::samplers::ScoreRequest) boundary (see
//!    [`run_shard`]'s source). Whichever worker is free pulls the next
//!    shard — work stealing by construction, so a slow shard never blocks
//!    the others — and signals a per-job condvar when its slot is filled.
//! 4. **Merge**: shard outputs are concatenated in shard order. NFE is
//!    reported per shard (max across shards), matching the paper's
//!    convention that a batched score call counts once.
//!
//! When [`EngineConfig::score_batch`] is non-zero, the score boundary is
//! the cross-key [`ScoreScheduler`] instead of a direct model call: each
//! shard *parks* its `ScoreRequest` in a per-`(model, t)` pool and a
//! drain answers whole pools with single `eps_batch` calls — so shards
//! of different jobs (heterogeneous `PlanKey`s included, as long as they
//! share a score model) fill the model's batch dimension together. The
//! execution model becomes "many parked state machines share a pooled
//! model frontier", but the output stays **bit-identical** to the
//! unscheduled path for every worker count — see the determinism
//! contract in [`scheduler`]. [`Engine::run_group`] admits several jobs
//! in one submission so the scheduler sees the whole group as
//! coalescable from the first evaluation.
//!
//! The pool is long-lived: at high request rates (the serving router
//! shares one engine across all dispatcher threads) a per-job
//! `thread::scope` spawn is measurable coordinator overhead, and Stage-I
//! plans being "calculated once and used everywhere" (App. C.3) means
//! dispatch cost is a real fraction of a few-NFE request. Jobs still pass
//! everything by reference: [`Engine::run`] blocks until every shard of
//! its job has completed, which is what makes handing borrowed data to
//! long-lived threads sound (see the safety notes on [`JobPtr`]).
//!
//! `workers <= 1` keeps the historical inline fast path: no threads are
//! ever spawned and shards run on the caller thread, byte-for-byte
//! equivalent to the pooled execution.

pub mod scheduler;

use std::panic::AssertUnwindSafe;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coeffs::plan::SamplerPlan;
use crate::diffusion::process::Process;
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers::common::SampleOutput;
use crate::samplers::{model_score, Sampler, SamplerSpec, ScoreRequest};
use crate::score::model::ScoreModel;
use crate::util::sync::{lock_unpoisoned, wait_unpoisoned};

pub use scheduler::{SchedulerConfig, ScoreScheduler, ScoreStats};

/// Engine tuning knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads kept alive by the pool (0 or 1 = run inline on the
    /// caller thread, no threads spawned).
    pub workers: usize,
    /// Explicit rows per shard; `0` (the default) derives the row count
    /// from [`EngineConfig::shard_bytes`] and the job's state dimension
    /// instead. Either way the layout is fixed per job (never derived
    /// from the worker count), so the merged output is identical for
    /// every pool size. Smaller shards = better load balance, more
    /// per-shard fixed cost (score-call batching shrinks with the shard).
    /// NB: the serving CLIs' `--shard-size` flag sets the **byte
    /// budget** ([`EngineConfig::shard_bytes`]), not this row count —
    /// an explicit row override is an API-level knob only.
    pub shard_size: usize,
    /// Per-shard state budget in **bytes** (`rows × dim_u × 8`), used
    /// when `shard_size == 0`. A flat row count sizes shards by request,
    /// not by memory: a 256-row shard of 1024-dim blobs32 state is 16×
    /// the footprint of the same shard on blobs8. The budget keeps shard
    /// memory roughly constant across dataset dimensions — rows are
    /// clamped to `[MIN_SHARD_ROWS, MAX_SHARD_ROWS]` so tiny dimensions
    /// still shard for load balance and huge ones never degenerate to
    /// single-row calls. Exposed as `--shard-size` on the serving CLIs.
    pub shard_bytes: usize,
    /// Maximum pooled rows per coalesced score call. `0` disables the
    /// [`ScoreScheduler`] entirely (the historical direct-call path);
    /// non-zero routes every shard's score evaluations through the
    /// cross-key pooling boundary. Values at or below the shard row
    /// count degenerate to per-shard calls — the point of the scheduler
    /// is a cut well above the typical shard. Output is bit-identical
    /// either way (see [`scheduler`]). Note this cut is still a flat
    /// row count, not a byte budget like [`EngineConfig::shard_bytes`]:
    /// at d=1024 a 4096-row pool stages ~32 MiB per coalesced call, so
    /// size it down (or make it dimension-aware, a future knob) when
    /// serving the high-resolution presets under memory pressure.
    pub score_batch: usize,
    /// Longest a parked score request waits before draining its own pool
    /// (the scheduler's liveness backstop; the stall cut usually answers
    /// much sooner). Ignored when `score_batch == 0`.
    pub score_wait: Duration,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            workers: 1,
            shard_size: 0,
            // 128 KiB of f64 state per shard: the historical 256 rows at
            // dim_u = 64 (vpsde/blobs8) and for every smaller dimension
            // (clamped), 16 rows at bdm/blobs32's dim_u = 1024.
            shard_bytes: 128 * 1024,
            score_batch: 0,
            score_wait: Duration::from_micros(200),
        }
    }
}

impl EngineConfig {
    /// Floor on derived shard rows: below this the per-shard fixed cost
    /// (task dispatch, RNG stream setup) dominates real work.
    pub const MIN_SHARD_ROWS: usize = 8;
    /// Ceiling on derived shard rows: above this load balance suffers
    /// and the per-key batcher's cuts stop sharding at all. Matches the
    /// historical flat default.
    pub const MAX_SHARD_ROWS: usize = 256;

    /// Rows per shard for a job with state dimension `dim_u`: the
    /// explicit `shard_size` when set, otherwise the `shard_bytes`
    /// budget divided by the row footprint (8 bytes per f64 lane),
    /// clamped to `[MIN_SHARD_ROWS, MAX_SHARD_ROWS]`. Pure function of
    /// the config and the dimension — the shard-layout half of the
    /// engine's determinism contract.
    pub fn rows_per_shard(&self, dim_u: usize) -> usize {
        if self.shard_size > 0 {
            self.shard_size
        } else {
            (self.shard_bytes / (8 * dim_u.max(1)))
                .clamp(Self::MIN_SHARD_ROWS, Self::MAX_SHARD_ROWS)
        }
    }
}

/// One batched sampling job: everything a shard needs, by reference. Any
/// [`Sampler`] impl works here — the seven paper samplers come from
/// [`SamplerSpec::instantiate`] or are built directly (e.g.
/// `samplers::GddimDet { plan: &plan }`).
pub struct Job<'a> {
    pub proc: &'a dyn Process,
    pub model: &'a dyn ScoreModel,
    pub sampler: &'a dyn Sampler,
    /// Total samples to generate across all shards.
    pub n: usize,
    /// Base seed; shard `i` samples from stream `i` of this seed.
    pub seed: u64,
}

/// A shard result as stored by a worker: the sampler output, or the
/// panic message if the shard panicked (re-raised by [`Engine::run`]
/// after the whole job has drained, never inside a worker).
type ShardResult = Result<SampleOutput, String>;

/// Per-job result collector: one slot per shard, a `done` count, and a
/// condvar [`Engine::run`] parks on until `done == slots.len()`.
struct Batch {
    inner: Mutex<BatchInner>,
    cv: Condvar,
}

struct BatchInner {
    slots: Vec<Option<ShardResult>>,
    done: usize,
}

impl Batch {
    fn new(n_shards: usize) -> Batch {
        Batch {
            inner: Mutex::new(BatchInner {
                slots: (0..n_shards).map(|_| None).collect(),
                done: 0,
            }),
            cv: Condvar::new(),
        }
    }
}

/// Lifetime-erased pointer to the job a shard belongs to.
///
/// SAFETY contract (upheld by `Engine::run`): the `Job` behind this
/// pointer outlives every `ShardTask` that references it, because `run`
/// does not return — and therefore the caller's borrows stay live —
/// until `Batch::done` equals the shard count, and workers bump `done`
/// strictly after their last use of the pointer. Workers never touch the
/// pointer after filling their slot.
#[derive(Clone, Copy)]
struct JobPtr(*const Job<'static>);

// SAFETY: the pointee is only dereferenced while `Engine::run` keeps the
// underlying `Job` (and everything it borrows) alive, and `Job` itself is
// `Send + Sync` (see `send_sync_audit`).
unsafe impl Send for JobPtr {}

/// One unit of pool work: run a shard (`n` rows, its own RNG stream) of
/// the job behind `job`, then fill `batch.slots[idx]` and signal.
struct ShardTask {
    job: JobPtr,
    /// Flat result-slot index within the submission (group-wide).
    idx: usize,
    /// Job sequence number (score-scheduler drain ordering).
    seq: u64,
    /// Shard index within its own job.
    shard: usize,
    n: usize,
    rng: Rng,
    batch: Arc<Batch>,
}

/// Pairs the scheduler's `task_started` with a guaranteed
/// `task_finished` (drop runs on panic unwinds too, so a dead shard can
/// never leave the stall detector counting a ghost).
struct StartGuard<'a>(&'a ScoreScheduler);

impl<'a> StartGuard<'a> {
    fn new(sched: &'a ScoreScheduler) -> StartGuard<'a> {
        sched.task_started();
        StartGuard(sched)
    }
}

impl Drop for StartGuard<'_> {
    fn drop(&mut self) {
        self.0.task_finished();
    }
}

/// The long-lived worker pool: an injector queue plus the worker handles.
/// Dropping the sender closes the queue; workers observe the disconnect
/// and exit, and `Engine::drop` joins them.
struct Pool {
    tx: Mutex<Sender<ShardTask>>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

/// Engine-level counters. All atomics: the hot path (one bump per shard)
/// never takes a lock.
struct EngineMetrics {
    jobs: AtomicU64,
    shards: AtomicU64,
    queue_depth: AtomicUsize,
    peak_queue_depth: AtomicUsize,
    /// Per-worker nanoseconds spent inside `run_shard` (slot 0 doubles as
    /// the caller-thread bucket on the inline path).
    busy_ns: Vec<AtomicU64>,
    started: Instant,
}

impl EngineMetrics {
    fn new(slots: usize) -> EngineMetrics {
        EngineMetrics {
            jobs: AtomicU64::new(0),
            shards: AtomicU64::new(0),
            queue_depth: AtomicUsize::new(0),
            peak_queue_depth: AtomicUsize::new(0),
            busy_ns: (0..slots).map(|_| AtomicU64::new(0)).collect(),
            started: Instant::now(),
        }
    }

    fn queue_push(&self) {
        let depth = self.queue_depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.peak_queue_depth.fetch_max(depth, Ordering::Relaxed);
    }

    fn queue_pop(&self) {
        self.queue_depth.fetch_sub(1, Ordering::Relaxed);
    }

    fn busy_add(&self, worker: usize, d: Duration) {
        self.busy_ns[worker].fetch_add(d.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// A point-in-time snapshot of the engine counters (see [`Engine::stats`]).
#[derive(Clone, Debug)]
pub struct EngineStats {
    /// Configured pool size (0/1 = inline execution, no pool threads).
    pub workers: usize,
    /// Jobs accepted by [`Engine::run`] (empty jobs included).
    pub jobs_run: u64,
    /// Shards executed across all jobs.
    pub shards_executed: u64,
    /// High-water mark of shards queued but not yet picked up.
    pub peak_queue_depth: usize,
    /// Seconds each worker spent executing shards (index 0 is the caller
    /// thread when running inline).
    pub worker_busy_secs: Vec<f64>,
    /// Seconds since the engine (and its pool) was constructed.
    pub uptime_secs: f64,
    /// Configured [`EngineConfig::score_batch`] (`0` = scheduler off; the
    /// score counters below then stay zero).
    pub score_batch: usize,
    /// `eps_batch` invocations issued by the score scheduler.
    pub score_calls: u64,
    /// Total rows across those invocations (`rows_per_call()` = fill).
    pub score_rows: u64,
    /// Scheduler calls that pooled more than one parked request.
    pub coalesced_calls: u64,
    /// Scheduler calls that pooled requests from more than one *job*
    /// (engine submission). Distinct jobs usually mean distinct cut
    /// batches — heterogeneous `PlanKey`s under grouped admission, or
    /// separate same-key cuts — either way, fill the per-key server
    /// batcher could not reach on its own.
    pub coalesced_keys: u64,
}

impl EngineStats {
    /// Fraction of the engine's uptime each worker spent busy, in [0, 1].
    pub fn busy_shares(&self) -> Vec<f64> {
        let up = self.uptime_secs.max(1e-12);
        self.worker_busy_secs.iter().map(|b| (b / up).clamp(0.0, 1.0)).collect()
    }

    /// Mean rows per scheduler-issued `eps_batch` call — the batch-fill
    /// ratio the cross-key scheduler exists to raise (0 when idle/off).
    pub fn rows_per_call(&self) -> f64 {
        if self.score_calls == 0 {
            0.0
        } else {
            self.score_rows as f64 / self.score_calls as f64
        }
    }
}

impl std::fmt::Display for EngineStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "engine: workers={} jobs={} shards={} peak-queue={} busy-share=[",
            self.workers, self.jobs_run, self.shards_executed, self.peak_queue_depth
        )?;
        for (i, s) in self.busy_shares().iter().enumerate() {
            if i > 0 {
                write!(f, " ")?;
            }
            write!(f, "{s:.2}")?;
        }
        write!(f, "] uptime={:.2}s", self.uptime_secs)?;
        if self.score_batch > 0 {
            write!(
                f,
                " score: calls={} rows/call={:.1} coalesced={} cross-job={}",
                self.score_calls,
                self.rows_per_call(),
                self.coalesced_calls,
                self.coalesced_keys
            )?;
        }
        Ok(())
    }
}

/// The sampling engine. `workers >= 2` spawns a persistent worker pool at
/// construction; jobs are sharded onto it by [`Engine::run`] and the pool
/// is torn down (queue closed, threads joined) on drop.
pub struct Engine {
    pub cfg: EngineConfig,
    pool: Option<Pool>,
    /// Cross-key score scheduler; present iff `cfg.score_batch > 0`.
    sched: Option<Arc<ScoreScheduler>>,
    /// Monotonic job sequence numbers (scheduler drain ordering).
    seq: AtomicU64,
    metrics: Arc<EngineMetrics>,
}

impl Engine {
    /// An engine with `workers` threads and the default shard size.
    pub fn new(workers: usize) -> Engine {
        Engine::with_config(EngineConfig { workers, ..EngineConfig::default() })
    }

    /// Build the engine; for `workers >= 2` this spawns the pool threads
    /// once, up front — `run` never spawns.
    pub fn with_config(cfg: EngineConfig) -> Engine {
        let metrics = Arc::new(EngineMetrics::new(cfg.workers.max(1)));
        let sched = (cfg.score_batch > 0).then(|| {
            Arc::new(ScoreScheduler::new(SchedulerConfig {
                max_batch: cfg.score_batch,
                max_wait: cfg.score_wait,
                workers: cfg.workers.max(1),
            }))
        });
        let pool = (cfg.workers >= 2).then(|| {
            let (tx, rx) = channel::<ShardTask>();
            let rx = Arc::new(Mutex::new(rx));
            let handles = (0..cfg.workers)
                .map(|w| {
                    let rx = Arc::clone(&rx);
                    let m = Arc::clone(&metrics);
                    let s = sched.clone();
                    std::thread::Builder::new()
                        .name(format!("gddim-engine-{w}"))
                        .spawn(move || pool_worker(&rx, &m, s.as_deref(), w))
                        // gddim-lint: allow(panic-reachability) — construction-time fail-fast: no pool exists yet, so no request can be wedged by this panic
                        .expect("engine: failed to spawn pool worker")
                })
                .collect();
            Pool { tx: Mutex::new(tx), handles }
        });
        Engine { cfg, pool, sched, seq: AtomicU64::new(0), metrics }
    }

    /// Whether the cross-key score scheduler is active (serving layers
    /// use this to decide on grouped admission).
    pub fn score_batching(&self) -> bool {
        self.sched.is_some()
    }

    /// Snapshot the engine counters.
    pub fn stats(&self) -> EngineStats {
        let score = self.sched.as_ref().map(|s| s.stats()).unwrap_or_default();
        EngineStats {
            workers: self.cfg.workers,
            jobs_run: self.metrics.jobs.load(Ordering::Relaxed),
            shards_executed: self.metrics.shards.load(Ordering::Relaxed),
            peak_queue_depth: self.metrics.peak_queue_depth.load(Ordering::Relaxed),
            worker_busy_secs: self
                .metrics
                .busy_ns
                .iter()
                .map(|ns| ns.load(Ordering::Relaxed) as f64 * 1e-9)
                .collect(),
            uptime_secs: self.metrics.started.elapsed().as_secs_f64(),
            score_batch: self.cfg.score_batch,
            score_calls: score.calls,
            score_rows: score.rows,
            coalesced_calls: score.coalesced_calls,
            coalesced_keys: score.coalesced_keys,
        }
    }

    /// Derive the per-shard RNG streams for `(seed, n_shards)`. Pure
    /// function of its inputs — the determinism contract of the engine.
    fn shard_rngs(seed: u64, n_shards: usize) -> Vec<Rng> {
        let mut root = Rng::seed_from(seed);
        (0..n_shards).map(|i| root.fork(i as u64)).collect()
    }

    /// Run one job: shard, execute (inline or on the pool), merge in
    /// shard order. Blocks until every shard has completed; panics (after
    /// the job has fully drained) if any shard panicked.
    pub fn run(&self, job: &Job<'_>) -> SampleOutput {
        self.run_group(std::slice::from_ref(job))
            .pop()
            // gddim-lint: allow(panic-reachability) — structural invariant: run_group returns exactly jobs.len() outputs, checked by its own tests
            .expect("run_group returns one output per job")
    }

    /// Run several jobs as **one submission**, returning outputs in job
    /// order. Every shard of every job is registered and enqueued before
    /// the first one executes, so the score scheduler (when enabled)
    /// sees the whole heterogeneous group as coalescable from its first
    /// evaluation — this is how the serving router hands a multi-key
    /// admission to the engine. With the scheduler off, a group is
    /// byte-equivalent to running the jobs one by one (same shard
    /// layout, same per-job RNG streams). Blocks until every shard of
    /// every job has completed; panics (after the whole group has
    /// drained) if any shard panicked.
    pub fn run_group(&self, jobs: &[Job<'_>]) -> Vec<SampleOutput> {
        self.metrics.jobs.fetch_add(jobs.len() as u64, Ordering::Relaxed);
        let seq0 = self.seq.fetch_add(jobs.len() as u64, Ordering::Relaxed);

        // Flatten the group into a job-major shard plan. An empty job
        // (n == 0) is a valid (if silly) thing for a client to send —
        // it contributes no shards and merges to an empty output.
        struct ShardPlan {
            job_idx: usize,
            seq: u64,
            shard: usize,
            n: usize,
            rng: Rng,
        }
        let mut plans: Vec<ShardPlan> = Vec::new();
        let mut job_shards: Vec<usize> = Vec::with_capacity(jobs.len());
        for (j, job) in jobs.iter().enumerate() {
            // Shard rows are derived per job: with the byte budget in
            // play two jobs of one group may shard at different row
            // counts (e.g. a blobs32 job next to a gmm2d one), each
            // deterministic in its own (n, dim_u).
            let rows = self.cfg.rows_per_shard(job.proc.dim_u());
            let n_shards = job.n.div_ceil(rows);
            job_shards.push(n_shards);
            let rngs = Engine::shard_rngs(job.seed, n_shards);
            for (i, rng) in rngs.into_iter().enumerate() {
                let n = rows.min(job.n - i * rows);
                plans.push(ShardPlan { job_idx: j, seq: seq0 + j as u64, shard: i, n, rng });
            }
        }
        let total_shards = plans.len();

        let mut slots: Vec<Option<ShardResult>> = if total_shards == 0 {
            Vec::new()
        } else {
            match &self.pool {
                None => {
                    // Inline fast path: same shard walk, caller thread, no
                    // queue. Bit-identical to pooled execution by the
                    // shard / seed / merge construction.
                    if let Some(s) = &self.sched {
                        s.task_enqueued(total_shards);
                    }
                    plans
                        .into_iter()
                        .map(|p| {
                            let _running = self.sched.as_deref().map(StartGuard::new);
                            let t0 = Instant::now();
                            let out = run_shard(
                                &jobs[p.job_idx],
                                p.n,
                                p.rng,
                                self.sched.as_deref(),
                                p.seq,
                                p.shard,
                            );
                            self.metrics.busy_add(0, t0.elapsed());
                            self.metrics.shards.fetch_add(1, Ordering::Relaxed);
                            Some(Ok(out))
                        })
                        .collect()
                }
                Some(pool) => {
                    let batch = Arc::new(Batch::new(total_shards));
                    // SAFETY: we erase each job's lifetime to hand it to
                    // the long-lived pool threads. This is sound because
                    // this very function waits (below) until
                    // `done == total_shards` before returning, and every
                    // worker bumps `done` only after its last use of the
                    // pointer — so the borrows can never be outlived. See
                    // `JobPtr`.
                    let job_ptrs: Vec<JobPtr> = jobs
                        .iter()
                        .map(|j| JobPtr(j as *const Job<'_> as *const Job<'static>))
                        .collect();
                    // Register the whole group before any shard becomes
                    // visible, so the scheduler's stall detector can
                    // never mistake half-admitted work for an idle queue.
                    if let Some(s) = &self.sched {
                        s.task_enqueued(total_shards);
                    }
                    {
                        // One lock for the whole group keeps its shards
                        // contiguous in the queue even with several
                        // dispatchers submitting concurrently.
                        let tx = lock_unpoisoned(&pool.tx);
                        for (slot_idx, p) in plans.into_iter().enumerate() {
                            self.metrics.queue_push();
                            tx.send(ShardTask {
                                job: job_ptrs[p.job_idx],
                                idx: slot_idx,
                                seq: p.seq,
                                shard: p.shard,
                                n: p.n,
                                rng: p.rng,
                                batch: Arc::clone(&batch),
                            })
                            // gddim-lint: allow(panic-reachability) — receiver closes only in Engine::drop, which cannot run concurrently with &self
                            .expect("engine: pool queue closed while engine alive");
                        }
                    }
                    let mut g = lock_unpoisoned(&batch.inner);
                    while g.done < total_shards {
                        g = wait_unpoisoned(&batch.cv, g);
                    }
                    std::mem::take(&mut g.slots)
                }
            }
        };

        // Merge per job, in job-major shard order — deterministic
        // regardless of which worker finished first. A panicked shard is
        // re-raised here, strictly after the wait above: by then no
        // worker holds any job pointer of the group.
        let mut outs = Vec::with_capacity(jobs.len());
        let mut cursor = 0usize;
        for (j, job) in jobs.iter().enumerate() {
            let k = job_shards[j];
            let mut xs = Vec::with_capacity(job.n * job.proc.dim_x());
            let mut us = Vec::with_capacity(job.n * job.proc.dim_u());
            let mut nfe = 0usize;
            for cell in slots[cursor..cursor + k].iter_mut() {
                // gddim-lint: allow(panic-reachability) — the condvar wait above holds until done == total_shards, so every slot is filled
                match cell.take().expect("engine: shard never executed") {
                    Ok(out) => {
                        xs.extend_from_slice(&out.xs);
                        us.extend_from_slice(&out.us);
                        nfe = nfe.max(out.nfe);
                    }
                    // gddim-lint: allow(panic-reachability) — shard-panic re-raise protocol: the worker's catch_unwind stored the message and the caller's own catch_unwind answers it
                    Err(msg) => panic!("engine: shard panicked: {msg}"),
                }
            }
            cursor += k;
            outs.push(SampleOutput { xs, us, nfe, traj: None });
        }
        outs
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        if let Some(Pool { tx, handles }) = self.pool.take() {
            // Closing the channel is the shutdown signal: recv() starts
            // returning Err and each worker exits its loop.
            drop(tx);
            for h in handles {
                let _ = h.join();
            }
        }
    }
}

/// Pool worker loop: pull shard tasks until the queue closes. Panics in
/// sampler code are caught and parked in the result slot — a worker never
/// dies mid-pool, and the panic resurfaces on the job's caller thread.
fn pool_worker(
    rx: &Mutex<Receiver<ShardTask>>,
    metrics: &EngineMetrics,
    sched: Option<&ScoreScheduler>,
    widx: usize,
) {
    loop {
        // Holding the lock across recv() is the single-consumer handoff:
        // exactly one idle worker waits on the channel, the rest queue on
        // the mutex. Err = sender dropped = engine shutdown.
        let task = match lock_unpoisoned(rx).recv() {
            Ok(t) => t,
            Err(_) => return,
        };
        metrics.queue_pop();
        let ShardTask { job, idx, seq, shard, n, rng, batch } = task;
        let t0 = Instant::now();
        // The guard's drop (normal or unwinding) is the scheduler's
        // `task_finished` — and may itself drain pools whose shards were
        // only waiting on this one to get out of the way.
        let running = sched.map(StartGuard::new);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            // SAFETY: `Engine::run_group` keeps the job alive until this
            // shard (and all its group siblings) are marked done below.
            let job: &Job<'_> = unsafe { &*job.0 };
            run_shard(job, n, rng, sched, seq, shard)
        }))
        .map_err(|e| {
            e.downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| e.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string())
        });
        drop(running);
        metrics.busy_add(widx, t0.elapsed());
        metrics.shards.fetch_add(1, Ordering::Relaxed);
        {
            let mut g = lock_unpoisoned(&batch.inner);
            g.slots[idx] = Some(result);
            g.done += 1;
        }
        batch.cv.notify_all();
    }
}

/// Execute one shard with its own RNG stream by driving the job's
/// [`Sampler`] state machine step by step.
///
/// The engine owns this loop (rather than calling [`Sampler::run`]) on
/// purpose: every score evaluation of every sampler funnels through one
/// `score` closure, so the boundary can be swapped without touching any
/// sampler. With `sched` absent that boundary is the plain
/// [`model_score`] call and the loop is byte-identical to
/// [`Sampler::run`]; with the cross-key [`ScoreScheduler`] present the
/// shard *parks* each request in the `(model, t)` pool and receives
/// exactly its slice of the pooled result — same bytes, fuller model
/// batches.
fn run_shard(
    job: &Job<'_>,
    n: usize,
    mut rng: Rng,
    sched: Option<&ScoreScheduler>,
    seq: u64,
    shard: usize,
) -> SampleOutput {
    let mut state = job.sampler.init(job.proc, job.model, n, &mut rng, false);
    match sched {
        None => {
            let mut score = model_score(job.model);
            for i in (1..=job.sampler.n_steps()).rev() {
                state.step(i, &mut score, &mut rng);
            }
        }
        Some(sched) => {
            let mut score = |req: ScoreRequest<'_>, out: &mut [f64]| {
                sched.eval(seq, shard, job.model, req.t, req.u, out);
            };
            for i in (1..=job.sampler.n_steps()).rev() {
                state.step(i, &mut score, &mut rng);
            }
        }
    }
    state.finish()
}

/// Compile-time Send/Sync audit for everything the engine shares across
/// worker threads by reference. A regression here (e.g. an `Rc` or a
/// non-`Sync` cache sneaking into a plan or model) fails the build, not
/// a run.
#[allow(dead_code)]
fn send_sync_audit() {
    fn assert_send_sync<T: Send + Sync + ?Sized>() {}
    fn assert_send<T: Send + ?Sized>() {}
    assert_send_sync::<dyn Process>();
    assert_send_sync::<dyn ScoreModel>();
    assert_send_sync::<dyn Sampler>();
    assert_send_sync::<SamplerPlan>();
    assert_send_sync::<SamplerSpec>();
    assert_send_sync::<TimeGrid>();
    assert_send_sync::<SampleOutput>();
    assert_send_sync::<Engine>();
    assert_send_sync::<Job<'_>>();
    assert_send_sync::<ScoreScheduler>();
    assert_send::<ShardTask>();
    assert_send::<dyn crate::samplers::SamplerState>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coeffs::plan::PlanConfig;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::{Cld, TimeGrid, Vpsde};
    use crate::metrics::frechet::frechet_to_spec;
    use crate::samplers::{Ancestral, Em, GddimDet, GddimSde, Heun, Rk45, Sscs};
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    fn cld_setup() -> (Arc<Cld>, crate::data::gmm::GmmSpec, GmmOracle) {
        let spec = presets::gmm2d();
        let proc = Arc::new(Cld::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        (proc, spec, oracle)
    }

    /// Pool size used by the concurrency-heavy tests; CI runs the suite a
    /// second time with `GDDIM_TEST_WORKERS=4` to exercise real contention.
    fn test_workers() -> usize {
        std::env::var("GDDIM_TEST_WORKERS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(2)
    }

    #[test]
    fn shard_rows_follow_the_byte_budget() {
        let auto = EngineConfig::default();
        // Historical parity: every dimension up to 64 keeps 256 rows.
        assert_eq!(auto.rows_per_shard(2), 256, "gmm2d/vpsde stays at the flat historical rows");
        assert_eq!(auto.rows_per_shard(4), 256, "gmm2d/cld likewise");
        assert_eq!(auto.rows_per_shard(64), 256, "blobs8/vpsde: 128 KiB / 512 B = 256 rows");
        // The budget actually bites at image scale.
        assert_eq!(auto.rows_per_shard(128), 128, "blobs8/cld halves");
        assert_eq!(auto.rows_per_shard(256), 64, "blobs16");
        assert_eq!(auto.rows_per_shard(1024), 16, "blobs32/bdm");
        assert_eq!(auto.rows_per_shard(2048), 8, "blobs32/cld hits MIN_SHARD_ROWS");
        assert_eq!(auto.rows_per_shard(1 << 30), EngineConfig::MIN_SHARD_ROWS);
        // An explicit shard_size always wins; dim 0 never divides by 0.
        let explicit = EngineConfig { shard_size: 40, ..EngineConfig::default() };
        assert_eq!(explicit.rows_per_shard(1024), 40);
        assert_eq!(auto.rows_per_shard(0), 256);
        // A degenerate zero budget still yields a positive row count.
        let zero = EngineConfig { shard_bytes: 0, ..EngineConfig::default() };
        assert_eq!(zero.rows_per_shard(64), EngineConfig::MIN_SHARD_ROWS);
    }

    #[test]
    fn byte_budget_sharding_is_worker_count_invariant() {
        // The auto-derived layout (shard_size == 0) must uphold the same
        // bit-identity contract as explicit rows: blobs16 on BDM shards
        // at 64 rows from the default budget.
        let spec = presets::blobs16();
        let proc = Arc::new(crate::diffusion::Bdm::standard(16, 16));
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig { workers, ..EngineConfig::default() });
            let out = engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &GddimDet { plan: &plan },
                n: 150, // 3 shards of 64/64/22 under the default budget
                seed: 0xD1517,
            });
            assert_eq!(engine.stats().shards_executed, 3, "budget must derive 64-row shards");
            out
        };
        let a = run(1);
        for workers in [2usize, 4] {
            let b = run(workers);
            assert_eq!(a.xs, b.xs, "budget-sharded xs diverged at {workers} workers");
            assert_eq!(a.us, b.us, "budget-sharded us diverged at {workers} workers");
        }
        assert_eq!(a.xs.len(), 150 * 256);
    }

    #[test]
    fn merged_output_is_bit_identical_across_worker_counts() {
        // The acceptance contract: 1/2/4/8 workers must produce the exact
        // same bytes for the same seed.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 15);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 128,
                ..EngineConfig::default()
            });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &GddimDet { plan: &plan },
                n: 700, // 6 shards, last one ragged
                seed: 0xC0FFEE,
            })
        };
        let a = run(1);
        for workers in [2usize, 4, 8] {
            let b = run(workers);
            assert_eq!(a.xs, b.xs, "merged xs must be bit-identical at {workers} workers");
            assert_eq!(a.us, b.us, "merged us must be bit-identical at {workers} workers");
            assert_eq!(a.nfe, b.nfe);
        }
    }

    #[test]
    fn stochastic_sampler_is_also_worker_count_invariant() {
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::stochastic(0.5));
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 64,
                ..EngineConfig::default()
            });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &GddimSde { plan: &plan },
                n: 300,
                seed: 9,
            })
        };
        assert_eq!(run(1).xs, run(3).xs);
    }

    #[test]
    fn sharded_quality_matches_unsharded() {
        // Sharding changes the RNG consumption pattern but not the
        // distribution: FD must stay in the same band as a direct run.
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 25);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let engine = Engine::with_config(EngineConfig {
            workers: 4,
            shard_size: 256,
            ..EngineConfig::default()
        });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &GddimDet { plan: &plan },
            n: 2_000,
            seed: 3,
        });
        assert_eq!(out.xs.len(), 2_000 * spec.d);
        assert_eq!(out.nfe, 25, "per-shard NFE, paper convention");
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.5, "sharded FD = {fd}");
    }

    #[test]
    fn shards_use_distinct_rng_streams() {
        // Two shards of the same job must not be copies of each other.
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::with_config(EngineConfig {
            workers: 2,
            shard_size: 32,
            ..EngineConfig::default()
        });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &GddimDet { plan: &plan },
            n: 64,
            seed: 1,
        });
        let d = spec.d;
        let (a, b) = out.xs.split_at(32 * d);
        assert_ne!(a, b, "shard outputs must come from independent streams");
    }

    #[test]
    fn every_baseline_runs_through_the_engine() {
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 12);
        let engine = Engine::with_config(EngineConfig {
            workers: 2,
            shard_size: 16,
            ..EngineConfig::default()
        });
        let samplers: Vec<Box<dyn Sampler + '_>> = vec![
            Box::new(Em { grid: &grid, lambda: 1.0 }),
            Box::new(Ancestral { grid: &grid }),
            Box::new(Heun { grid: &grid }),
            Box::new(Sscs { grid: &grid }),
            Box::new(Rk45 { rtol: 1e-3 }),
        ];
        for sampler in &samplers {
            let out = engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: sampler.as_ref(),
                n: 40,
                seed: 2,
            });
            assert_eq!(out.xs.len(), 40 * spec.d);
            assert!(out.xs.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn oversized_worker_count_is_harmless() {
        // More workers than shards must not deadlock or panic: the extra
        // pool threads simply never see a task.
        let spec = presets::gmm2d();
        let proc = Arc::new(Vpsde::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::with_config(EngineConfig {
            workers: 16,
            shard_size: 512,
            ..EngineConfig::default()
        });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &GddimDet { plan: &plan },
            n: 10, // a single shard
            seed: 4,
        });
        assert_eq!(out.xs.len(), 10 * spec.d);
    }

    #[test]
    fn empty_job_is_served_without_touching_the_pool() {
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        for workers in [0usize, 1, 4] {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 64,
                ..EngineConfig::default()
            });
            let out = engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &Ancestral { grid: &grid },
                n: 0,
                seed: 0,
            });
            assert!(out.xs.is_empty() && out.us.is_empty() && out.nfe == 0);
            assert_eq!(engine.stats().jobs_run, 1);
            assert_eq!(engine.stats().shards_executed, 0);
        }
    }

    #[test]
    fn zero_workers_falls_back_to_inline_and_matches_pooled() {
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 32,
                ..EngineConfig::default()
            });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &Ancestral { grid: &grid },
                n: 100,
                seed: 17,
            })
        };
        let zero = run(0);
        assert_eq!(zero.xs, run(1).xs, "0 workers must run inline like 1");
        assert_eq!(zero.xs, run(3).xs, "inline and pooled must agree");
    }

    #[test]
    fn drop_while_idle_shuts_the_pool_down_cleanly() {
        // Never-used pool: construct and drop. A shutdown bug (worker not
        // observing the closed queue) hangs this test rather than failing
        // an assert — that's the point.
        let engine = Engine::with_config(EngineConfig {
            workers: 4,
            shard_size: 64,
            ..EngineConfig::default()
        });
        drop(engine);

        // Used-then-idle pool: run a job, let the pool go idle, drop.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let engine = Engine::with_config(EngineConfig {
            workers: 4,
            shard_size: 16,
            ..EngineConfig::default()
        });
        let _ = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &Ancestral { grid: &grid },
            n: 64,
            seed: 5,
        });
        assert_eq!(engine.stats().shards_executed, 4);
        drop(engine);
    }

    #[test]
    fn many_small_jobs_stress_no_shard_lost_or_duplicated() {
        // Router-style usage: several caller threads share one engine and
        // hammer it with small jobs. Every job's output must be byte-equal
        // to the single-threaded reference — which is only possible if no
        // shard is lost, duplicated, or cross-wired between jobs.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let sampler = GddimDet { plan: &plan };
        let make_job = |seed: u64| Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &sampler,
            n: 40, // 5 shards of 8
            seed,
        };
        let reference = Engine::with_config(EngineConfig {
            workers: 1,
            shard_size: 8,
            ..EngineConfig::default()
        });
        let expected: Vec<Vec<f64>> =
            (0..100u64).map(|seed| reference.run(&make_job(seed)).xs).collect();

        let shared = Engine::with_config(EngineConfig {
            workers: test_workers(),
            shard_size: 8,
            ..EngineConfig::default()
        });
        std::thread::scope(|scope| {
            for caller in 0..4u64 {
                let shared = &shared;
                let expected = &expected;
                let make_job = &make_job;
                scope.spawn(move || {
                    for k in 0..25u64 {
                        let seed = caller * 25 + k;
                        let out = shared.run(&make_job(seed));
                        assert_eq!(
                            out.xs, expected[seed as usize],
                            "job seed {seed} diverged under the shared pool"
                        );
                    }
                });
            }
        });
        let stats = shared.stats();
        assert_eq!(stats.jobs_run, 100);
        assert_eq!(stats.shards_executed, 500, "every shard exactly once");
    }

    #[test]
    fn counters_track_jobs_shards_and_busy_time() {
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let engine = Engine::with_config(EngineConfig {
            workers: 2,
            shard_size: 16,
            ..EngineConfig::default()
        });
        for seed in 0..3u64 {
            let _ = engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &Ancestral { grid: &grid },
                n: 48, // 3 shards
                seed,
            });
        }
        let s = engine.stats();
        assert_eq!(s.jobs_run, 3);
        assert_eq!(s.shards_executed, 9);
        assert!(s.peak_queue_depth >= 1 && s.peak_queue_depth <= 9);
        assert_eq!(s.worker_busy_secs.len(), 2);
        assert!(s.worker_busy_secs.iter().sum::<f64>() > 0.0);
        assert!(s.busy_shares().iter().all(|b| (0.0..=1.0).contains(b)));
        let line = s.to_string();
        assert!(line.contains("jobs=3") && line.contains("shards=9"), "{line}");
        assert!(!line.contains("score:"), "scheduler-off stats must not print score counters");
    }

    #[test]
    fn run_group_matches_individual_runs_and_serves_empty_jobs() {
        // Group plumbing alone (scheduler off): a group submission must
        // produce exactly the bytes of one-by-one runs, empty members
        // included, for inline and pooled engines alike.
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let det = GddimDet { plan: &plan };
        let anc = Ancestral { grid: &grid };
        let jobs = [
            Job { proc: proc.as_ref(), model: &oracle, sampler: &det, n: 70, seed: 1 },
            Job { proc: proc.as_ref(), model: &oracle, sampler: &anc, n: 0, seed: 2 },
            Job { proc: proc.as_ref(), model: &oracle, sampler: &anc, n: 33, seed: 3 },
        ];
        for workers in [1usize, 4] {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 32,
                ..EngineConfig::default()
            });
            let grouped = engine.run_group(&jobs);
            assert_eq!(grouped.len(), 3);
            assert!(grouped[1].xs.is_empty() && grouped[1].nfe == 0);
            for (job, out) in jobs.iter().zip(&grouped) {
                let solo = engine.run(job);
                assert_eq!(out.xs, solo.xs, "grouped vs solo xs @ {workers} workers");
                assert_eq!(out.us, solo.us, "grouped vs solo us @ {workers} workers");
                assert_eq!(out.nfe, solo.nfe);
                assert_eq!(out.xs.len(), job.n * spec.d);
            }
        }
    }

    #[test]
    fn scheduler_on_is_bit_identical_for_single_jobs() {
        // The core determinism contract at engine level: pooled score
        // execution changes which rows share an eps_batch call, never
        // any row's bytes — for every worker count.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 12);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let run = |workers: usize, score_batch: usize| {
            let engine = Engine::with_config(EngineConfig {
                workers,
                shard_size: 64,
                score_batch,
                score_wait: Duration::from_millis(100),
                ..EngineConfig::default()
            });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: &GddimDet { plan: &plan },
                n: 300, // 5 shards, last one ragged
                seed: 0xFEED,
            })
        };
        let reference = run(1, 0);
        for workers in [1usize, 2, 4] {
            let pooled = run(workers, 4096);
            assert_eq!(reference.xs, pooled.xs, "scheduler-on xs diverged @ {workers} workers");
            assert_eq!(reference.us, pooled.us, "scheduler-on us diverged @ {workers} workers");
            assert_eq!(reference.nfe, pooled.nfe);
        }
    }

    #[test]
    fn scheduler_coalesces_heterogeneous_jobs_and_preserves_bytes() {
        // The cross-key acceptance test, built to be timing-independent:
        // four jobs with *distinct* sampler configs (gDDIM orders 1–4)
        // share one score model and one grid, so their evaluation-time
        // sequences are identical. Submitted as one group to a 4-worker
        // engine, the stall cut fires only when all four shards are
        // parked at the same t — every drain pools all four jobs, and
        // the model sees strictly fewer (and fuller) calls than the
        // scheduler-off runs, at bit-identical outputs.
        use crate::score::Counting;
        let spec = presets::gmm2d();
        let proc = Arc::new(Cld::standard(spec.d));
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let plans: Vec<SamplerPlan> = (1..=4)
            .map(|q| {
                SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(q, KtKind::R))
            })
            .collect();
        let samplers: Vec<GddimDet<'_>> = plans.iter().map(|plan| GddimDet { plan }).collect();
        fn jobs_for<'a>(
            proc: &'a dyn Process,
            model: &'a dyn ScoreModel,
            samplers: &'a [GddimDet<'a>],
        ) -> Vec<Job<'a>> {
            samplers
                .iter()
                .enumerate()
                .map(|(j, sampler)| Job {
                    proc,
                    model,
                    sampler,
                    n: 32, // one shard per job
                    seed: 100 + j as u64,
                })
                .collect()
        }

        // Reference: scheduler off, jobs run one by one.
        let off_model = Counting::new(GmmOracle::new(proc.clone(), spec.clone(), KtKind::R));
        let off_engine = Engine::with_config(EngineConfig {
            workers: 4,
            shard_size: 32,
            ..EngineConfig::default()
        });
        let off_jobs = jobs_for(proc.as_ref(), &off_model, &samplers);
        let off_outs: Vec<SampleOutput> = off_jobs.iter().map(|j| off_engine.run(j)).collect();
        let off_calls = off_model.calls();
        assert_eq!(off_calls, 4 * 8, "4 jobs × (warm-up + 7 steps) unpooled calls");

        // Scheduler on, same jobs as one group.
        let on_model = Counting::new(GmmOracle::new(proc.clone(), spec.clone(), KtKind::R));
        let on_engine = Engine::with_config(EngineConfig {
            workers: 4,
            shard_size: 32,
            score_batch: 4096,
            score_wait: Duration::from_secs(2),
            ..EngineConfig::default()
        });
        let on_jobs = jobs_for(proc.as_ref(), &on_model, &samplers);
        let on_outs = on_engine.run_group(&on_jobs);
        let on_calls = on_model.calls();

        for (j, (off, on)) in off_outs.iter().zip(&on_outs).enumerate() {
            assert_eq!(off.xs, on.xs, "job {j}: pooled xs diverged");
            assert_eq!(off.us, on.us, "job {j}: pooled us diverged");
            assert_eq!(off.nfe, on.nfe, "job {j}: NFE must be unchanged by pooling");
        }
        assert!(
            on_calls < off_calls,
            "heterogeneous 4-key group must issue strictly fewer eps_batch calls \
             with the scheduler on ({on_calls} vs {off_calls})"
        );
        assert!(on_calls >= 8, "pooling cannot drop below one call per shared t");
        assert_eq!(on_model.rows(), off_model.rows(), "pooling must not change total rows");

        let s = on_engine.stats();
        assert_eq!(s.score_calls, on_calls, "engine stats must count the scheduler's calls");
        assert!(s.coalesced_calls >= 1 && s.coalesced_keys >= 1, "{s:?}");
        assert!(s.rows_per_call() > 32.0, "pooled fill must beat the 32-row shard");
        let line = s.to_string();
        assert!(line.contains("score: calls="), "{line}");
    }

    #[test]
    fn scheduler_stress_many_jobs_bit_identical() {
        // Router-style usage with the scheduler on: several caller
        // threads hammer one engine with small same-key jobs, so drains
        // constantly mix rows from different jobs. Every output must
        // still be byte-equal to the single-threaded scheduler-off
        // reference — which is only possible if pooled slices are routed
        // back exactly and no request is lost, duplicated, or answered
        // with a neighbour's rows.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let sampler = GddimDet { plan: &plan };
        let make_job = |seed: u64| Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &sampler,
            n: 40, // 5 shards of 8
            seed,
        };
        let reference = Engine::with_config(EngineConfig {
            workers: 1,
            shard_size: 8,
            ..EngineConfig::default()
        });
        let expected: Vec<Vec<f64>> =
            (0..100u64).map(|seed| reference.run(&make_job(seed)).xs).collect();

        let shared = Engine::with_config(EngineConfig {
            workers: test_workers(),
            shard_size: 8,
            score_batch: 4096,
            score_wait: Duration::from_micros(500),
            ..EngineConfig::default()
        });
        std::thread::scope(|scope| {
            for caller in 0..4u64 {
                let shared = &shared;
                let expected = &expected;
                let make_job = &make_job;
                scope.spawn(move || {
                    for k in 0..25u64 {
                        let seed = caller * 25 + k;
                        let out = shared.run(&make_job(seed));
                        assert_eq!(
                            out.xs, expected[seed as usize],
                            "job seed {seed} diverged under the pooled score boundary"
                        );
                    }
                });
            }
        });
        let stats = shared.stats();
        assert_eq!(stats.jobs_run, 100);
        assert_eq!(stats.shards_executed, 500, "every shard exactly once");
        assert!(stats.score_calls > 0, "all score traffic must flow through the scheduler");
    }
}
