//! The sharded parallel sampling engine.
//!
//! Few-NFE sampling makes per-request work small enough that coordinator
//! throughput — not the score model — becomes the serving bottleneck.
//! This module turns one batched sampling job into data-parallel work:
//!
//! 1. **Shard**: the batch of `n` samples is split into *fixed-size*
//!    shards. The shard layout depends only on `(n, shard_size)` — never
//!    on the worker count — so the output is stable under any pool size.
//! 2. **Seed**: every shard gets its own [`Rng`] stream, derived from the
//!    job seed by index. Stream derivation is a pure function of
//!    `(seed, shard_index)`, which makes the merged output bit-identical
//!    for 1 worker and for N workers.
//! 3. **Execute**: a `std::thread::scope` worker pool pulls shard indices
//!    off an atomic counter (work stealing by construction — a slow shard
//!    never blocks the others) and runs the configured Stage-II sampler
//!    on its slice of the batch.
//! 4. **Merge**: shard outputs are concatenated in shard order. NFE is
//!    reported per shard (max across shards), matching the paper's
//!    convention that a batched score call counts once.
//!
//! The engine holds no threads between jobs: scoped threads make the
//! borrow story trivial (`&dyn Process`, `&SamplerPlan` etc. are shared
//! by reference, no `Arc` churn) and a pool spin-up is ~µs next to a
//! sampler run.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::coeffs::plan::SamplerPlan;
use crate::diffusion::process::Process;
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers;
use crate::samplers::common::SampleOutput;
use crate::score::model::ScoreModel;

/// Engine tuning knobs.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Worker threads used to execute shards (1 = run inline).
    pub workers: usize,
    /// Rows per shard. Fixed (not derived from the worker count) so that
    /// the shard layout — and therefore the merged output — is identical
    /// for every pool size. Smaller shards = better load balance, more
    /// per-shard fixed cost (score-call batching shrinks with the shard).
    pub shard_size: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig { workers: 1, shard_size: 256 }
    }
}

/// Which Stage-II sampler a [`Job`] runs on each shard.
pub enum SamplerSpec<'a> {
    /// Deterministic gDDIM (multistep predictor / PC) on a prebuilt plan.
    GddimDet(&'a SamplerPlan),
    /// Stochastic gDDIM (λ > 0) on a prebuilt plan.
    GddimSde(&'a SamplerPlan),
    /// Euler–Maruyama on the marginal-equivalent SDE (λ = 0: plain Euler).
    Em { grid: &'a TimeGrid, lambda: f64 },
    /// Generalized ancestral sampling.
    Ancestral { grid: &'a TimeGrid },
    /// 2nd-order Heun on the probability-flow ODE.
    Heun { grid: &'a TimeGrid },
    /// Symmetric splitting CLD sampler.
    Sscs { grid: &'a TimeGrid },
}

/// One batched sampling job: everything a shard needs, by reference.
pub struct Job<'a> {
    pub proc: &'a dyn Process,
    pub model: &'a dyn ScoreModel,
    pub sampler: SamplerSpec<'a>,
    /// Total samples to generate across all shards.
    pub n: usize,
    /// Base seed; shard `i` samples from stream `i` of this seed.
    pub seed: u64,
}

/// The worker pool. Cheap to construct; holds no threads between jobs.
pub struct Engine {
    pub cfg: EngineConfig,
}

impl Engine {
    /// An engine with `workers` threads and the default shard size.
    pub fn new(workers: usize) -> Engine {
        Engine::with_config(EngineConfig { workers, ..EngineConfig::default() })
    }

    pub fn with_config(cfg: EngineConfig) -> Engine {
        Engine { cfg }
    }

    /// Derive the per-shard RNG streams for `(seed, n_shards)`. Pure
    /// function of its inputs — the determinism contract of the engine.
    fn shard_rngs(seed: u64, n_shards: usize) -> Vec<Rng> {
        let mut root = Rng::seed_from(seed);
        (0..n_shards).map(|i| root.fork(i as u64)).collect()
    }

    /// Run one job: shard, execute on the pool, merge deterministically.
    pub fn run(&self, job: &Job<'_>) -> SampleOutput {
        if job.n == 0 {
            // An empty request is a valid (if silly) thing for a client to
            // send; panicking here would take a dispatcher thread with it.
            return SampleOutput { xs: Vec::new(), us: Vec::new(), nfe: 0, traj: None };
        }
        let shard_size = self.cfg.shard_size.max(1);
        let n_shards = job.n.div_ceil(shard_size);
        let rngs = Engine::shard_rngs(job.seed, n_shards);
        let shard_n =
            |i: usize| -> usize { shard_size.min(job.n - i * shard_size) };

        let results: Vec<Mutex<Option<SampleOutput>>> =
            (0..n_shards).map(|_| Mutex::new(None)).collect();
        let workers = self.cfg.workers.clamp(1, n_shards);
        if workers == 1 {
            // Inline fast path: same shard walk, no thread setup.
            for (i, rng) in rngs.iter().enumerate() {
                *results[i].lock().unwrap() = Some(run_shard(job, shard_n(i), rng.clone()));
            }
        } else {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n_shards {
                            break;
                        }
                        let out = run_shard(job, shard_n(i), rngs[i].clone());
                        *results[i].lock().unwrap() = Some(out);
                    });
                }
            });
        }

        // Merge in shard order — deterministic regardless of which worker
        // finished first.
        let mut xs = Vec::with_capacity(job.n * job.proc.dim_x());
        let mut us = Vec::with_capacity(job.n * job.proc.dim_u());
        let mut nfe = 0usize;
        for cell in results {
            let out = cell.into_inner().unwrap().expect("engine: shard never executed");
            xs.extend_from_slice(&out.xs);
            us.extend_from_slice(&out.us);
            nfe = nfe.max(out.nfe);
        }
        SampleOutput { xs, us, nfe, traj: None }
    }
}

/// Execute one shard with its own RNG stream.
fn run_shard(job: &Job<'_>, n: usize, mut rng: Rng) -> SampleOutput {
    match &job.sampler {
        SamplerSpec::GddimDet(plan) => {
            samplers::gddim::sample_deterministic(job.proc, plan, job.model, n, &mut rng, false)
        }
        SamplerSpec::GddimSde(plan) => {
            samplers::gddim::sample_stochastic(job.proc, plan, job.model, n, &mut rng, false)
        }
        SamplerSpec::Em { grid, lambda } => {
            samplers::em::sample_em(job.proc, job.model, grid, *lambda, n, &mut rng, false)
        }
        SamplerSpec::Ancestral { grid } => {
            samplers::ancestral::sample_ancestral(job.proc, job.model, grid, n, &mut rng)
        }
        SamplerSpec::Heun { grid } => {
            samplers::heun::sample_heun(job.proc, job.model, grid, n, &mut rng)
        }
        SamplerSpec::Sscs { grid } => {
            samplers::sscs::sample_sscs(job.proc, job.model, grid, n, &mut rng)
        }
    }
}

/// Compile-time Send/Sync audit for everything the engine shares across
/// worker threads by reference. A regression here (e.g. an `Rc` or a
/// non-`Sync` cache sneaking into a plan or model) fails the build, not
/// a run.
#[allow(dead_code)]
fn send_sync_audit() {
    fn assert_send_sync<T: Send + Sync + ?Sized>() {}
    assert_send_sync::<dyn Process>();
    assert_send_sync::<dyn ScoreModel>();
    assert_send_sync::<SamplerPlan>();
    assert_send_sync::<TimeGrid>();
    assert_send_sync::<SampleOutput>();
    assert_send_sync::<Engine>();
    assert_send_sync::<Job<'_>>();
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coeffs::plan::PlanConfig;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::{Cld, TimeGrid, Vpsde};
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    fn cld_setup() -> (Arc<Cld>, crate::data::gmm::GmmSpec, GmmOracle) {
        let spec = presets::gmm2d();
        let proc = Arc::new(Cld::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        (proc, spec, oracle)
    }

    #[test]
    fn merged_output_is_bit_identical_across_worker_counts() {
        // The acceptance contract: N=1 and N=4 workers must produce the
        // exact same bytes for the same seed.
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 15);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig { workers, shard_size: 128 });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: SamplerSpec::GddimDet(&plan),
                n: 700, // 6 shards, last one ragged
                seed: 0xC0FFEE,
            })
        };
        let a = run(1);
        let b = run(4);
        assert_eq!(a.xs, b.xs, "merged xs must be bit-identical");
        assert_eq!(a.us, b.us, "merged us must be bit-identical");
        assert_eq!(a.nfe, b.nfe);
    }

    #[test]
    fn stochastic_sampler_is_also_worker_count_invariant() {
        let (proc, _spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::stochastic(0.5));
        let run = |workers: usize| {
            let engine = Engine::with_config(EngineConfig { workers, shard_size: 64 });
            engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler: SamplerSpec::GddimSde(&plan),
                n: 300,
                seed: 9,
            })
        };
        assert_eq!(run(1).xs, run(3).xs);
    }

    #[test]
    fn sharded_quality_matches_unsharded() {
        // Sharding changes the RNG consumption pattern but not the
        // distribution: FD must stay in the same band as a direct run.
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 25);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let engine = Engine::with_config(EngineConfig { workers: 4, shard_size: 256 });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: SamplerSpec::GddimDet(&plan),
            n: 2_000,
            seed: 3,
        });
        assert_eq!(out.xs.len(), 2_000 * spec.d);
        assert_eq!(out.nfe, 25, "per-shard NFE, paper convention");
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.5, "sharded FD = {fd}");
    }

    #[test]
    fn shards_use_distinct_rng_streams() {
        // Two shards of the same job must not be copies of each other.
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 8);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::with_config(EngineConfig { workers: 2, shard_size: 32 });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: SamplerSpec::GddimDet(&plan),
            n: 64,
            seed: 1,
        });
        let d = spec.d;
        let (a, b) = out.xs.split_at(32 * d);
        assert_ne!(a, b, "shard outputs must come from independent streams");
    }

    #[test]
    fn every_baseline_runs_through_the_engine() {
        let (proc, spec, oracle) = cld_setup();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 12);
        let engine = Engine::with_config(EngineConfig { workers: 2, shard_size: 16 });
        let specs: Vec<SamplerSpec<'_>> = vec![
            SamplerSpec::Em { grid: &grid, lambda: 1.0 },
            SamplerSpec::Ancestral { grid: &grid },
            SamplerSpec::Heun { grid: &grid },
            SamplerSpec::Sscs { grid: &grid },
        ];
        for sampler in specs {
            let out = engine.run(&Job {
                proc: proc.as_ref(),
                model: &oracle,
                sampler,
                n: 40,
                seed: 2,
            });
            assert_eq!(out.xs.len(), 40 * spec.d);
            assert!(out.xs.iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn oversized_worker_count_is_clamped() {
        // More workers than shards must not deadlock or panic.
        let spec = presets::gmm2d();
        let proc = Arc::new(Vpsde::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::with_config(EngineConfig { workers: 16, shard_size: 512 });
        let out = engine.run(&Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: SamplerSpec::GddimDet(&plan),
            n: 10, // a single shard
            seed: 4,
        });
        assert_eq!(out.xs.len(), 10 * spec.d);
    }
}
