//! Cross-key score-batching scheduler.
//!
//! NFE — score-network evaluations — is *the* cost metric of gDDIM's
//! accelerated samplers (as in DDIM before it), so serving throughput is
//! decided by how full each [`ScoreModel::eps_batch`] call runs. The
//! per-key batcher in `server::batcher` only coalesces requests whose
//! `PlanKey`s are identical; heterogeneous small-request traffic
//! therefore issues near-empty model calls. This module closes that gap
//! at the layer below: shards of *different* jobs that evaluate the same
//! model at the same diffusion time `t` pool their
//! [`ScoreRequest`](crate::samplers::ScoreRequest)s into one
//! `eps_batch` invocation.
//!
//! # How a request flows
//!
//! A shard driven by [`run_shard`](crate::engine) hands every score
//! evaluation to [`ScoreScheduler::eval`], which **parks** the shard:
//! the request joins the pool keyed by `(model identity, t bits)` and
//! the worker thread blocks until some leader drains that pool. A pool
//! is drained — all its requests concatenated into a single `eps_batch`
//! call, the result sliced back to each parked shard — when one of
//! three cuts fires, mirroring the `server::batcher` semantics:
//!
//! 1. **size**: the pool's accumulated rows reach `max_batch` (drained
//!    by the request that crossed the threshold);
//! 2. **stall**: every shard currently executing is parked and no idle
//!    worker can start more (all pools drain — nothing new can arrive
//!    until the parked shards are answered, so waiting longer is pure
//!    latency). This is the common cut, and it is what makes the
//!    coalescing *deterministic* for a fixed job group: shards advance
//!    in lockstep, each drain pooling every in-flight same-`t` request;
//! 3. **wait**: `max_wait` elapsed since the shard parked (it drains
//!    its own pool). A pure liveness backstop — progress never depends
//!    on another thread scheduling a drain.
//!
//! Stall detection needs the engine's admission picture, so the engine
//! registers every shard: [`task_enqueued`](ScoreScheduler::task_enqueued)
//! when a job (group) is submitted, [`task_started`](ScoreScheduler::task_started)
//! when a worker picks the shard up, [`task_finished`](ScoreScheduler::task_finished)
//! when it completes. All counts move under one lock, so the cut
//! decision never races admission.
//!
//! # Determinism contract
//!
//! Pooled execution is **bit-identical** to unbatched execution:
//!
//! * entries drain in a deterministic order — a stable sort by
//!   `(job sequence number, shard index)`, rows within a shard keeping
//!   their submission order — and each entry receives exactly the slice
//!   of the result that corresponds to its rows;
//! * the contract requires [`ScoreModel::eps_batch`] to compute each row
//!   independently of its batch-mates (true of the closed-form oracle
//!   and of any pointwise network model), so *which* rows share a call
//!   cannot change any row's bytes;
//! * the scheduler draws no randomness and never reorders a shard's own
//!   rows, so RNG streams are untouched.
//!
//! `rust/tests/sampler_parity.rs` locks this for every sampler spec and
//! worker count.
//!
//! # Safety model
//!
//! Parked requests hold raw pointers to the caller's `u`/`out` buffers
//! (and a lifetime-erased model reference). This is sound for the same
//! reason as the engine's `JobPtr`: the parking thread blocks inside
//! [`ScoreScheduler::eval`] until its `done` flag flips, and a leader
//! stops touching an entry's buffers — and, for the model, every
//! entry's job — strictly before flipping that entry's flag.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::score::model::ScoreModel;
use crate::util::sync::{lock_unpoisoned, wait_timeout_unpoisoned};

/// Scheduler tuning knobs (built by the engine from its
/// [`EngineConfig`](crate::engine::EngineConfig)).
#[derive(Clone, Debug)]
pub struct SchedulerConfig {
    /// Size cut: a pool whose accumulated rows reach this drains
    /// immediately.
    pub max_batch: usize,
    /// Wait cut: the longest a parked shard waits before draining its
    /// own pool (liveness backstop; the stall cut usually fires first).
    pub max_wait: Duration,
    /// Engine worker count, for stall detection (`>= 1`).
    pub workers: usize,
}

/// Counter snapshot (see [`ScoreScheduler::stats`]).
#[derive(Clone, Copy, Debug, Default)]
pub struct ScoreStats {
    /// `eps_batch` invocations issued by the scheduler.
    pub calls: u64,
    /// Total rows across those invocations (`rows / calls` = batch fill).
    pub rows: u64,
    /// Invocations that pooled more than one parked request.
    pub coalesced_calls: u64,
    /// Invocations that pooled requests from more than one job (engine
    /// submission) — fill the per-key batcher could not see, whether
    /// the jobs carried different `PlanKey`s or separate same-key cuts.
    pub coalesced_keys: u64,
}

/// Per-request completion state the parked thread blocks on. `failure`
/// carries the panic message of a drain whose `eps_batch` panicked:
/// every affected owner re-raises on its own thread (each shard parks
/// its own panic, exactly like a panic in its own sampler code) instead
/// of hanging forever waiting for a result the dead call can no longer
/// deliver. Routing the failure exclusively through the slots — never
/// by unwinding out of the drain — is what keeps a drain executed from
/// [`ScoreScheduler::task_finished`] (a worker's completion hook, which
/// may run inside a `Drop` during unwinding) from killing the worker or
/// aborting the process.
#[derive(Default)]
struct SlotState {
    done: bool,
    failure: Option<String>,
}

struct Slot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

impl Slot {
    fn new() -> Slot {
        Slot { state: Mutex::new(SlotState::default()), cv: Condvar::new() }
    }

    /// Panic (joining the engine's shard-panic protocol) if the drain
    /// that answered this slot died inside the model.
    fn check(&self) {
        let g = lock_unpoisoned(&self.state);
        debug_assert!(g.done, "slot checked before completion");
        if let Some(msg) = &g.failure {
            // gddim-lint: allow(panic-reachability) — deliberate re-raise: the leader's catch_unwind recorded the failure and every parked owner must observe the same panic, not a silent zero
            panic!("score scheduler: pooled eps_batch call panicked: {msg}");
        }
    }
}

/// One parked score request.
///
/// SAFETY contract (upheld by [`ScoreScheduler::eval`]): the pointers
/// reference buffers owned by the parked thread's stack frame, which
/// cannot unwind or return until the slot's `done` flag is set — and a
/// leader sets it strictly after its last use of the pointers.
struct Entry {
    /// Engine-assigned job sequence number (primary drain-order key).
    seq: u64,
    /// Shard index within the job (secondary drain-order key).
    shard: usize,
    u: *const f64,
    out: *mut f64,
    len: usize,
    slot: Arc<Slot>,
}

// SAFETY: the pointees are only dereferenced while the parked owner
// blocks in `eval` (see `Entry`); the `Arc<Slot>` is Send on its own.
unsafe impl Send for Entry {}

/// All requests parked at one `(model, t)`, awaiting a drain.
struct Pool {
    /// Lifetime-erased model reference; valid while any entry is parked
    /// (every entry's job borrows the same model object).
    model: &'static dyn ScoreModel,
    t: f64,
    /// Accumulated rows (size-cut accounting + fill metrics).
    rows: usize,
    entries: Vec<Entry>,
}

#[derive(Default)]
struct Inner {
    /// Shards admitted to the engine but not yet picked up by a worker.
    queued: usize,
    /// Shards currently held by a worker (running or parked).
    running: usize,
    /// Running shards blocked in a pool.
    parked: usize,
    /// Key: (thin model address, `t.to_bits()`).
    pools: HashMap<(usize, u64), Pool>,
}

impl Inner {
    /// No running shard can make progress without a drain, and no idle
    /// worker can start one: every held shard is parked, and either
    /// nothing is queued or every worker is occupied.
    fn stalled(&self, workers: usize) -> bool {
        self.parked > 0
            && self.parked == self.running
            && (self.queued == 0 || self.running >= workers)
    }

    fn detach_all(&mut self) -> Vec<Pool> {
        let pools: Vec<Pool> = self.pools.drain().map(|(_, p)| p).collect();
        for p in &pools {
            self.parked -= p.entries.len();
        }
        pools
    }
}

/// The cross-key score-batching scheduler. One per [`Engine`]; shared by
/// every worker (and inline caller) of that engine.
///
/// [`Engine`]: crate::engine::Engine
pub struct ScoreScheduler {
    cfg: SchedulerConfig,
    inner: Mutex<Inner>,
    calls: AtomicU64,
    rows: AtomicU64,
    coalesced_calls: AtomicU64,
    coalesced_keys: AtomicU64,
}

impl ScoreScheduler {
    pub fn new(cfg: SchedulerConfig) -> ScoreScheduler {
        ScoreScheduler {
            cfg: SchedulerConfig {
                workers: cfg.workers.max(1),
                max_batch: cfg.max_batch.max(1),
                ..cfg
            },
            inner: Mutex::new(Inner::default()),
            calls: AtomicU64::new(0),
            rows: AtomicU64::new(0),
            coalesced_calls: AtomicU64::new(0),
            coalesced_keys: AtomicU64::new(0),
        }
    }

    /// Snapshot the coalescing counters.
    pub fn stats(&self) -> ScoreStats {
        ScoreStats {
            calls: self.calls.load(Ordering::Relaxed),
            rows: self.rows.load(Ordering::Relaxed),
            coalesced_calls: self.coalesced_calls.load(Ordering::Relaxed),
            coalesced_keys: self.coalesced_keys.load(Ordering::Relaxed),
        }
    }

    /// Register `n` shards admitted to the engine (called *before* the
    /// shards become visible to workers, so a stall can never be
    /// declared while admitted work is invisible).
    pub fn task_enqueued(&self, n: usize) {
        lock_unpoisoned(&self.inner).queued += n;
    }

    /// A worker picked a shard up.
    pub fn task_started(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        g.queued -= 1;
        g.running += 1;
    }

    /// A shard completed (normally or by panic). May fire the stall cut:
    /// with this shard gone, the remaining running shards may all be
    /// parked — they are drained here, on the finishing thread, rather
    /// than waiting out `max_wait`.
    pub fn task_finished(&self) {
        let drains = {
            let mut g = lock_unpoisoned(&self.inner);
            g.running -= 1;
            if g.stalled(self.cfg.workers) { g.detach_all() } else { Vec::new() }
        };
        if !drains.is_empty() {
            self.execute(drains);
        }
    }

    /// Evaluate `ε_θ(u, t)` through the pooling boundary: park the
    /// request in the `(model, t)` pool and block until a drain answers
    /// it. `seq`/`shard` order the request inside a pooled call.
    pub fn eval(
        &self,
        seq: u64,
        shard: usize,
        model: &dyn ScoreModel,
        t: f64,
        u: &[f64],
        out: &mut [f64],
    ) {
        debug_assert_eq!(u.len(), out.len(), "score request and output must have equal shapes");
        let rows = u.len() / model.dim_u().max(1);
        let key = ((model as *const dyn ScoreModel).cast::<()>() as usize, t.to_bits());
        // SAFETY: lifetime erasure only — the reference is used solely
        // inside a drain, before any of the pool's entries (whose jobs
        // all borrow this model) are marked done. See the module docs.
        let model_static: &'static dyn ScoreModel =
            unsafe { std::mem::transmute::<&dyn ScoreModel, &'static dyn ScoreModel>(model) };
        let slot = Arc::new(Slot::new());
        let drains = {
            let mut g = lock_unpoisoned(&self.inner);
            g.parked += 1;
            let pool = g.pools.entry(key).or_insert_with(|| Pool {
                model: model_static,
                t,
                rows: 0,
                entries: Vec::new(),
            });
            pool.rows += rows;
            pool.entries.push(Entry {
                seq,
                shard,
                u: u.as_ptr(),
                out: out.as_mut_ptr(),
                len: u.len(),
                slot: Arc::clone(&slot),
            });
            if pool.rows >= self.cfg.max_batch {
                // gddim-lint: allow(panic-reachability) — the entry() call three lines up inserted this key under the same guard
                let p = g.pools.remove(&key).expect("pool touched above");
                g.parked -= p.entries.len();
                vec![p]
            } else if g.stalled(self.cfg.workers) {
                g.detach_all()
            } else {
                Vec::new()
            }
        };
        if !drains.is_empty() {
            // We are the leader, and our own request is in the drained
            // set (size cut = our pool, stall cut = every pool).
            self.execute(drains);
            slot.check();
            return;
        }
        self.park(key, &slot);
        slot.check();
    }

    /// Block until `slot` is answered; after `max_wait` without an
    /// answer, self-drain our pool (liveness backstop). The caller
    /// checks the slot's failure flag after this returns.
    fn park(&self, key: (usize, u64), slot: &Arc<Slot>) {
        let mut deadline = Instant::now() + self.cfg.max_wait;
        loop {
            {
                let mut state = lock_unpoisoned(&slot.state);
                loop {
                    if state.done {
                        return;
                    }
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    state = wait_timeout_unpoisoned(&slot.cv, state, deadline - now);
                }
            }
            // Timed out. Self-drain our pool if we are still in it; if
            // the pool is gone (or replaced by a newer generation), a
            // leader holds our entry detached and the answer is
            // imminent — re-arm and wait again.
            let pool = {
                let mut g = lock_unpoisoned(&self.inner);
                let ours = g
                    .pools
                    .get(&key)
                    .is_some_and(|p| p.entries.iter().any(|e| Arc::ptr_eq(&e.slot, slot)));
                if ours {
                    // gddim-lint: allow(panic-reachability) — `ours` just witnessed the key in the map under this same guard
                    let p = g.pools.remove(&key).expect("checked above");
                    g.parked -= p.entries.len();
                    Some(p)
                } else {
                    None
                }
            };
            match pool {
                Some(p) => {
                    self.execute(vec![p]);
                    return;
                }
                None => deadline = Instant::now() + self.cfg.max_wait,
            }
        }
    }

    /// Drain detached pools in deterministic order: entries by
    /// `(seq, shard)` within each pool, pools by their lead entry.
    ///
    /// Never panics: a pool whose model call dies marks its own entries
    /// failed (see [`SlotState`]) and the remaining pools still drain —
    /// otherwise a stall drain dying on pool 1 would orphan pools 2…n
    /// (gone from the map, never woken).
    fn execute(&self, mut pools: Vec<Pool>) {
        pools.retain(|p| !p.entries.is_empty());
        for p in pools.iter_mut() {
            p.entries.sort_by_key(|e| (e.seq, e.shard));
        }
        pools.sort_by_key(|p| (p.entries[0].seq, p.entries[0].shard, p.t.to_bits()));
        for pool in pools {
            self.execute_pool(pool);
        }
    }

    /// One pooled `eps_batch` call: gather inputs (in drain order), call
    /// the model once, scatter the result, then wake every parked owner.
    ///
    /// A panic inside the model must not orphan the detached entries —
    /// their owners would wait forever on a drain nobody can deliver.
    /// The call runs under `catch_unwind`; every entry is woken either
    /// way, a failure carrying the panic message so each affected owner
    /// re-raises on its own thread (the engine's shard-panic protocol).
    /// The panic is **not** re-thrown here: a drain may run on a thread
    /// with no request of its own (`task_finished`, possibly inside a
    /// `Drop` during unwinding), where an escaping panic would kill a
    /// pool worker or abort the process.
    fn execute_pool(&self, pool: Pool) {
        let Pool { model, t, rows, entries } = pool;
        if entries.is_empty() {
            return;
        }
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.rows.fetch_add(rows as u64, Ordering::Relaxed);
        let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if entries.len() == 1 {
                // Solo drain: evaluate straight into the caller's
                // buffers, exactly like the unscheduled path (no
                // gather/scatter).
                let e = &entries[0];
                // SAFETY: the parked owner blocks until `done`; see
                // `Entry`.
                let (u, out) = unsafe {
                    (
                        std::slice::from_raw_parts(e.u, e.len),
                        std::slice::from_raw_parts_mut(e.out, e.len),
                    )
                };
                model.eps_batch(t, u, out);
            } else {
                self.coalesced_calls.fetch_add(1, Ordering::Relaxed);
                if entries.windows(2).any(|w| w[0].seq != w[1].seq) {
                    self.coalesced_keys.fetch_add(1, Ordering::Relaxed);
                }
                let total: usize = entries.iter().map(|e| e.len).sum();
                let mut us = Vec::with_capacity(total);
                for e in &entries {
                    // SAFETY: owner parked until `done` (see `Entry`).
                    us.extend_from_slice(unsafe { std::slice::from_raw_parts(e.u, e.len) });
                }
                let mut eps = vec![0.0; total];
                model.eps_batch(t, &us, &mut eps);
                let mut off = 0usize;
                for e in &entries {
                    // SAFETY: owner parked until `done` (see `Entry`).
                    let dst = unsafe { std::slice::from_raw_parts_mut(e.out, e.len) };
                    dst.copy_from_slice(&eps[off..off + e.len]);
                    off += e.len;
                }
            }
        }));
        let failure = outcome.err().map(|e| {
            e.downcast_ref::<&str>()
                .map(|s| s.to_string())
                .or_else(|| e.downcast_ref::<String>().cloned())
                .unwrap_or_else(|| "non-string panic payload".to_string())
        });
        // Wake strictly last: once an entry's flag flips, its buffers —
        // and with them the job's model borrow — may die with the owner.
        for e in &entries {
            let mut g = lock_unpoisoned(&e.slot.state);
            g.done = true;
            g.failure.clone_from(&failure);
            drop(g);
            e.slot.cv.notify_all();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::process::KtKind;

    /// Records every `eps_batch` input and answers `out = 2·u`, so tests
    /// can check both drain order and slice routing.
    struct Recorder {
        d: usize,
        seen: Mutex<Vec<(f64, Vec<f64>)>>,
    }

    impl Recorder {
        fn new(d: usize) -> Recorder {
            Recorder { d, seen: Mutex::new(Vec::new()) }
        }
    }

    impl ScoreModel for Recorder {
        fn dim_u(&self) -> usize {
            self.d
        }

        fn kt_kind(&self) -> KtKind {
            KtKind::R
        }

        fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]) {
            lock_unpoisoned(&self.seen).push((t, us.to_vec()));
            for (o, u) in out.iter_mut().zip(us) {
                *o = 2.0 * u;
            }
        }
    }

    fn worker_eval(
        sched: &ScoreScheduler,
        model: &dyn ScoreModel,
        seq: u64,
        t: f64,
        u: Vec<f64>,
    ) -> Vec<f64> {
        // Emulate the engine's registration protocol around one eval.
        sched.task_started();
        let mut out = vec![0.0; u.len()];
        sched.eval(seq, 0, model, t, &u, &mut out);
        sched.task_finished();
        out
    }

    #[test]
    fn same_t_requests_coalesce_into_one_call_in_seq_order() {
        let sched = ScoreScheduler::new(SchedulerConfig {
            max_batch: 1024,
            max_wait: Duration::from_secs(5),
            workers: 2,
        });
        let model = Recorder::new(1);
        sched.task_enqueued(2);
        let (a, b) = std::thread::scope(|s| {
            // Higher seq submitted first: drain order must still be 3, 7.
            let ha = s.spawn(|| worker_eval(&sched, &model, 7, 0.5, vec![70.0, 71.0]));
            let hb = s.spawn(|| worker_eval(&sched, &model, 3, 0.5, vec![30.0]));
            (ha.join().unwrap(), hb.join().unwrap())
        });
        assert_eq!(a, vec![140.0, 142.0], "seq 7 rows answered in place");
        assert_eq!(b, vec![60.0], "seq 3 rows answered in place");
        let seen = lock_unpoisoned(&model.seen);
        assert_eq!(seen.len(), 1, "two same-t requests must share one eps_batch call");
        assert_eq!(seen[0].1, vec![30.0, 70.0, 71.0], "gather order is (seq, shard)");
        let s = sched.stats();
        assert_eq!((s.calls, s.coalesced_calls, s.coalesced_keys), (1, 1, 1));
        assert_eq!(s.rows, 3);
    }

    #[test]
    fn lone_parked_request_self_drains_after_max_wait() {
        // One shard parks while a second runs (never parking): no stall,
        // so the wait cut must answer the parked one by itself.
        let sched = ScoreScheduler::new(SchedulerConfig {
            max_batch: 1024,
            max_wait: Duration::from_millis(10),
            workers: 4,
        });
        let model = Recorder::new(1);
        sched.task_enqueued(2);
        let out = std::thread::scope(|s| {
            let slow = s.spawn(|| {
                // A running-but-never-parking sibling.
                sched.task_started();
                std::thread::sleep(Duration::from_millis(200));
                sched.task_finished();
            });
            let parked = s.spawn(|| worker_eval(&sched, &model, 1, 0.25, vec![5.0]));
            let out = parked.join().unwrap();
            slow.join().unwrap();
            out
        });
        assert_eq!(out, vec![10.0]);
        let s = sched.stats();
        assert_eq!(s.calls, 1);
        assert_eq!(s.coalesced_calls, 0, "a self-drain is a solo call");
    }

    #[test]
    fn size_cut_fires_without_waiting() {
        // max_batch = 2 rows: the second same-t request triggers an
        // immediate drain even though a third shard keeps running.
        let sched = ScoreScheduler::new(SchedulerConfig {
            max_batch: 2,
            max_wait: Duration::from_secs(5),
            workers: 4,
        });
        let model = Recorder::new(1);
        sched.task_enqueued(3);
        std::thread::scope(|s| {
            let busy = s.spawn(|| {
                sched.task_started();
                std::thread::sleep(Duration::from_millis(100));
                sched.task_finished();
            });
            let ha = s.spawn(|| worker_eval(&sched, &model, 1, 0.5, vec![1.0]));
            let hb = s.spawn(|| worker_eval(&sched, &model, 2, 0.5, vec![2.0]));
            assert_eq!(ha.join().unwrap(), vec![2.0]);
            assert_eq!(hb.join().unwrap(), vec![4.0]);
            busy.join().unwrap();
        });
        assert_eq!(sched.stats().calls, 1, "size cut must not wait for the busy shard");
    }

    #[test]
    fn model_panic_wakes_every_parked_shard_with_a_panic() {
        // A drain leader dying inside eps_batch must not orphan the
        // other parked shards: everyone is woken with a failure set and
        // re-raises on its own thread (the engine's shard-panic
        // protocol), instead of hanging forever.
        struct Exploder;

        impl ScoreModel for Exploder {
            fn dim_u(&self) -> usize {
                1
            }

            fn kt_kind(&self) -> KtKind {
                KtKind::R
            }

            fn eps_batch(&self, _t: f64, _us: &[f64], _out: &mut [f64]) {
                panic!("synthetic model failure");
            }
        }

        let sched = ScoreScheduler::new(SchedulerConfig {
            max_batch: 1024,
            max_wait: Duration::from_secs(5),
            workers: 2,
        });
        let model = Exploder;
        sched.task_enqueued(2);
        std::thread::scope(|s| {
            let ha = s.spawn(|| worker_eval(&sched, &model, 1, 0.5, vec![1.0]));
            let hb = s.spawn(|| worker_eval(&sched, &model, 2, 0.5, vec![2.0]));
            assert!(ha.join().is_err(), "leader must re-raise the model panic");
            assert!(hb.join().is_err(), "parked follower must re-raise, not hang");
        });
    }

    #[test]
    fn distinct_t_requests_stay_in_distinct_calls() {
        let sched = ScoreScheduler::new(SchedulerConfig {
            max_batch: 1024,
            max_wait: Duration::from_secs(5),
            workers: 2,
        });
        let model = Recorder::new(1);
        sched.task_enqueued(2);
        std::thread::scope(|s| {
            let ha = s.spawn(|| worker_eval(&sched, &model, 1, 0.25, vec![1.0]));
            let hb = s.spawn(|| worker_eval(&sched, &model, 2, 0.75, vec![2.0]));
            assert_eq!(ha.join().unwrap(), vec![2.0]);
            assert_eq!(hb.join().unwrap(), vec![4.0]);
        });
        let s = sched.stats();
        assert_eq!(s.calls, 2, "different t must never share an eps_batch call");
        assert_eq!(s.coalesced_calls, 0);
    }
}
