//! Figure harnesses (paper Figs. 1/3, 2, 4, 5) — each prints the series
//! the figure plots plus a scalar smoothness/quality summary so the
//! "shape" claim is checkable without a plotting stack.

use crate::coeffs::plan::{PlanConfig, SamplerPlan};
use crate::diffusion::process::KtKind;
use crate::diffusion::TimeGrid;
use crate::exp::helpers::*;
use crate::math::rng::Rng;
use crate::samplers::{GddimDet, GddimSde, Sampler};
use crate::util::bench::Table;
use crate::util::cli::Args;

/// Fig. 1 / Fig. 3 — ε_θ smoothness along probability-flow trajectories
/// on CLD: with K=L the v-channel output oscillates like the pixel value;
/// with K=R it is nearly flat. We report the recorded series and the
/// total variation (TV) of each channel.
pub fn fig1(args: &Args) {
    let s = setup("cld", &args.get_or("dataset", "gmm2d"));
    let nfe = args.get_usize("nfe", 200);
    let mut t = Table::new(
        "Fig 1/3: ε_θ total variation along prob-flow trajectory (CLD; lower = smoother)",
        &["K_t", "TV(eps_x)", "TV(eps_v)", "TV(x pixel)"],
    );
    let mut series_dump = String::new();
    for kt in [KtKind::L, KtKind::R] {
        let out = run_gddim_traj(&s, kt, nfe);
        let traj = out.traj.as_ref().unwrap();
        let tv_x = traj_tv(&traj.eps, 0);
        let tv_v = traj_tv(&traj.eps, s.spec.d); // first v component
        let pixel_tv: f64 = traj
            .us
            .windows(2)
            .map(|w| (w[1][0] - w[0][0]).abs())
            .sum();
        t.row(vec![
            kt.label().into(),
            format!("{tv_x:.3}"),
            format!("{tv_v:.3}"),
            format!("{pixel_tv:.3}"),
        ]);
        series_dump.push_str(&format!("# K={}\n", kt.label()));
        for (i, tt) in traj.ts.iter().enumerate() {
            if !traj.eps[i].is_empty() {
                series_dump.push_str(&format!(
                    "{tt:.4} x={:.4} eps_x={:.4} eps_v={:.4}\n",
                    traj.us[i][0],
                    traj.eps[i][0],
                    traj.eps[i][s.spec.d]
                ));
            }
        }
    }
    t.emit("fig1");
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write("bench_results/fig1_series.txt", series_dump);
}

fn run_gddim_traj(s: &Setup, kt: KtKind, nfe: usize) -> crate::samplers::common::SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe);
    let plan = SamplerPlan::build(s.proc.as_ref(), &grid, &PlanConfig::deterministic(1, kt));
    let o = oracle(s, kt);
    let mut rng = Rng::seed_from(71);
    GddimDet { plan: &plan }.run(s.proc.as_ref(), &o, 1, &mut rng, true)
}

/// Fig. 2 — ε_GT smoothness on the 1-D two-Gaussian toy (VPSDE): the
/// trajectories are smooth at the start (fully mixed) and end (single
/// mode), validating the local Dirac approximation.
pub fn fig2(args: &Args) {
    let s = setup("vpsde", "gmm2d");
    // The paper's toy is 1-D; we use the canonical 1-D preset directly.
    let spec = crate::data::presets::gmm2d_1d();
    let proc = std::sync::Arc::new(crate::diffusion::Vpsde::standard(1));
    let o = crate::score::oracle::GmmOracle::new(proc.clone(), spec, KtKind::R);
    let _ = s;
    let nfe = args.get_usize("nfe", 200);
    let grid = TimeGrid::uniform(proc.t_min, proc.t_max, nfe);
    let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
    let mut t = Table::new(
        "Fig 2: ε_GT along prob-flow trajectories (VPSDE 1-D toy)",
        &["traj", "TV(eps)", "TV over last 20% (near data)"],
    );
    for k in 0..5u64 {
        let mut rng = Rng::seed_from(100 + k);
        let out = GddimDet { plan: &plan }.run(proc.as_ref(), &o, 1, &mut rng, true);
        let traj = out.traj.unwrap();
        let tv = traj_tv(&traj.eps, 0);
        let tail_start = traj.eps.len() * 4 / 5;
        let tail: Vec<Vec<f64>> = traj.eps[tail_start..].to_vec();
        let tv_tail = traj_tv(&tail, 0);
        t.row(vec![format!("{k}"), format!("{tv:.4}"), format!("{tv_tail:.4}")]);
    }
    t.emit("fig2");
}

/// Fig. 4 — the hard 2-D example with the exact score: Euler vs EI(K=L)
/// vs EI(K=R) at small NFE. Reports FD and mode coverage.
pub fn fig4(args: &Args) {
    let s = setup("cld", "hard2d");
    let n = n_samples(args, 4000);
    let nfes = [10usize, 20, 50];
    let mut t = Table::new(
        "Fig 4: hard 2-D mixture, exact score (FD | missing modes /25)",
        &["Sampler", "10", "20", "50"],
    );
    // The `'a` bound matters: the closures borrow the local setup, so the
    // trait objects must not default to 'static.
    type Runner<'a> = Box<dyn Fn(usize) -> crate::samplers::common::SampleOutput + 'a>;
    let rows: Vec<(String, Runner<'_>)> = vec![
        ("Euler (prob-flow)".into(), Box::new(|nfe| run_em(&s, 0.0, nfe, n, 81))),
        ("EI, K=L".into(), Box::new(|nfe| run_gddim(&s, KtKind::L, 1, nfe, false, n, 81))),
        ("EI, K=R (gDDIM)".into(), Box::new(|nfe| run_gddim(&s, KtKind::R, 1, nfe, false, n, 81))),
    ];
    for (label, runner) in rows {
        let mut row = vec![label];
        for &nfe in &nfes {
            let out = runner(nfe);
            let c = crate::metrics::coverage::coverage(&out.xs, &s.spec);
            row.push(format!("{:.3} | {}", fd(&out, &s.spec), c.missing));
        }
        t.row(row);
    }
    t.emit("fig4");
}

/// Fig. 5 — trajectory roughness vs λ (stochastic gDDIM on the 1-D toy):
/// higher λ ⇒ rougher paths ⇒ harder to extrapolate at low NFE.
pub fn fig5(args: &Args) {
    let spec = crate::data::presets::gmm2d_1d();
    let proc = std::sync::Arc::new(crate::diffusion::Vpsde::standard(1));
    let o = crate::score::oracle::GmmOracle::new(proc.clone(), spec, KtKind::R);
    let nfe = args.get_usize("nfe", 100);
    let grid = TimeGrid::uniform(proc.t_min, proc.t_max, nfe);
    let mut t = Table::new(
        "Fig 5: path roughness Σ|Δx| vs λ (stochastic gDDIM, same seed)",
        &["λ", "roughness", "TV(eps)"],
    );
    for lam in [0.05, 0.3, 0.6, 1.0] {
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::stochastic(lam));
        let mut rng = Rng::seed_from(91);
        let out = GddimSde { plan: &plan }.run(proc.as_ref(), &o, 1, &mut rng, true);
        let traj = out.traj.unwrap();
        let rough: f64 = traj.us.windows(2).map(|w| (w[1][0] - w[0][0]).abs()).sum();
        let tv = traj_tv(&traj.eps[..traj.eps.len() - 1].to_vec(), 0);
        t.row(vec![format!("{lam}"), format!("{rough:.3}"), format!("{tv:.3}")]);
    }
    t.emit("fig5");
}
