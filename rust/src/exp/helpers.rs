//! Shared experiment plumbing.

use std::sync::Arc;

use crate::coeffs::plan::{PlanConfig, SamplerPlan};
use crate::data::gmm::GmmSpec;
use crate::data::presets;
use crate::diffusion::process::KtKind;
use crate::diffusion::{Process, TimeGrid};
use crate::math::rng::Rng;
use crate::metrics::frechet::frechet_to_spec;
use crate::samplers::common::SampleOutput;
use crate::samplers::{Ancestral, Em, GddimDet, GddimSde, Heun, Rk45, Sampler};
use crate::score::oracle::GmmOracle;
use crate::util::cli::Args;

pub struct Setup {
    pub proc: Arc<dyn Process>,
    pub spec: GmmSpec,
}

pub fn setup(process: &str, dataset: &str) -> Setup {
    let info = presets::info(dataset).expect("unknown dataset");
    let proc = crate::diffusion::process_for(process, info).unwrap_or_else(|e| panic!("{e}"));
    Setup { proc, spec: info.build() }
}

pub fn oracle(s: &Setup, kt: KtKind) -> GmmOracle {
    GmmOracle::new(s.proc.clone(), s.spec.clone(), kt)
}

/// Sample count: `--n`, scaled down by `--fast` for smoke runs.
pub fn n_samples(args: &Args, default: usize) -> usize {
    let n = args.get_usize("n", default);
    if args.has("fast") {
        (n / 8).max(200)
    } else {
        n
    }
}

pub fn fd(out: &SampleOutput, spec: &GmmSpec) -> f64 {
    frechet_to_spec(&out.xs, spec)
}

/// Run deterministic gDDIM with a fresh plan (trait path).
pub fn run_gddim(
    s: &Setup,
    kt: KtKind,
    q: usize,
    nfe: usize,
    corrector: bool,
    n: usize,
    seed: u64,
) -> SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe);
    let cfg = PlanConfig { q, kt, with_corrector: corrector, ..PlanConfig::default() };
    let plan = SamplerPlan::build(s.proc.as_ref(), &grid, &cfg);
    let o = oracle(s, kt);
    let mut rng = Rng::seed_from(seed);
    GddimDet { plan: &plan }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

pub fn run_gddim_sde(s: &Setup, lambda: f64, nfe: usize, n: usize, seed: u64) -> SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe);
    let plan = SamplerPlan::build(s.proc.as_ref(), &grid, &PlanConfig::stochastic(lambda));
    let o = oracle(s, KtKind::R);
    let mut rng = Rng::seed_from(seed);
    GddimSde { plan: &plan }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

pub fn run_em(s: &Setup, lambda: f64, nfe: usize, n: usize, seed: u64) -> SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe);
    let o = oracle(s, KtKind::R);
    let mut rng = Rng::seed_from(seed);
    Em { grid: &grid, lambda }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

pub fn run_ancestral(s: &Setup, nfe: usize, n: usize, seed: u64) -> SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe);
    let o = oracle(s, KtKind::R);
    let mut rng = Rng::seed_from(seed);
    Ancestral { grid: &grid }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

pub fn run_heun(s: &Setup, nfe_grid: usize, n: usize, seed: u64) -> SampleOutput {
    let grid = TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), nfe_grid);
    let o = oracle(s, KtKind::R);
    let mut rng = Rng::seed_from(seed);
    Heun { grid: &grid }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

pub fn run_rk45_at(s: &Setup, target_nfe: usize, n: usize, seed: u64) -> SampleOutput {
    let o = oracle(s, KtKind::R);
    let (rtol, _) = crate::samplers::rk45::tune_rtol_for_nfe(s.proc.as_ref(), &o, target_nfe, seed);
    let mut rng = Rng::seed_from(seed);
    Rk45 { rtol }.run(s.proc.as_ref(), &o, n, &mut rng, false)
}

/// Total variation of a recorded ε-trajectory component (smoothness
/// measure for Figs. 1–3: small TV = flat = multistep-friendly).
pub fn traj_tv(eps: &[Vec<f64>], component: usize) -> f64 {
    let vals: Vec<f64> = eps
        .iter()
        .filter(|e| !e.is_empty())
        .map(|e| e[component])
        .collect();
    vals.windows(2).map(|w| (w[1] - w[0]).abs()).sum()
}
