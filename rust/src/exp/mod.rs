//! Experiment harnesses — one per paper table/figure (DESIGN.md §5).
//!
//! Every harness prints the same rows/columns the paper reports (plus the
//! paper's own numbers where useful for shape comparison) and appends the
//! rendered table to `bench_results/`. All of them run against the exact
//! mixture oracle so the comparison isolates the integrator, exactly like
//! the paper's Fig. 4 protocol; the learned-score path is exercised by
//! `examples/e2e_blobs.rs`.

pub mod helpers;
pub mod tables;
pub mod figures;

use crate::util::cli::Args;

/// Dispatch an experiment by name ("all" runs the whole battery).
pub fn run(which: &str, args: &Args) {
    let all = [
        "table1", "table2", "table3", "table5", "table6", "table7", "table8", "fig1", "fig2",
        "fig4", "fig5", "nll",
    ];
    if which == "all" {
        for w in all {
            run(w, args);
        }
        return;
    }
    match which {
        "table1" => tables::table1(args),
        "table2" => tables::table2(args),
        "table3" => tables::table3(args),
        "table5" => tables::table5(args),
        "table6" => tables::table6(args),
        "table7" => tables::table7(args),
        "table8" => tables::table8(args),
        "fig1" => figures::fig1(args),
        "fig2" => figures::fig2(args),
        "fig4" => figures::fig4(args),
        "fig5" => figures::fig5(args),
        "nll" => tables::nll(args),
        other => eprintln!("unknown experiment '{other}'; one of {all:?}"),
    }
}
