//! Table harnesses (paper Tables 1, 2, 3, 5, 6, 7, 8 and App. C.8 NLL).
//!
//! Absolute numbers differ from the paper (our metric is data-space
//! Fréchet distance on mixture data, not Inception-FID on CIFAR); the
//! reproduction target is the *shape*: who wins, by what factor, where
//! the crossovers sit. EXPERIMENTS.md records paper-vs-measured.

use crate::diffusion::process::KtKind;
use crate::exp::helpers::*;
use crate::samplers::{Sampler, Sscs};
use crate::metrics::coverage::coverage;
use crate::util::bench::Table;
use crate::util::cli::Args;

/// Table 1 — L_t vs R_t on CLD (paper: FID 368/167/4.12/3.31 vs
/// 3.90/2.64/2.37/2.26 at NFE 20/30/40/50, q=2 multistep).
pub fn table1(args: &Args) {
    let s = setup("cld", &args.get_or("dataset", "gmm2d"));
    let n = n_samples(args, 4000);
    let nfes = [20usize, 30, 40, 50];
    let mut t = Table::new(
        "Table 1: L_t vs R_t on CLD (FD at different NFE)",
        &["K_t", "20", "30", "40", "50"],
    );
    for kt in [KtKind::L, KtKind::R] {
        let mut row = vec![kt.label().to_string()];
        for &nfe in &nfes {
            let out = run_gddim(&s, kt, 3, nfe, false, n, 7);
            row.push(format!("{:.3}", fd(&out, &s.spec)));
        }
        t.row(row);
    }
    t.emit("table1");
}

/// Table 2 — λ and integrator choice at NFE=50 (paper: gDDIM
/// 5.17/5.51/12.13/33/41/49, EM 346/168/137/89/45/57 for λ = 0→1).
pub fn table2(args: &Args) {
    let s = setup("cld", &args.get_or("dataset", "gmm2d"));
    let n = n_samples(args, 4000);
    let nfe = args.get_usize("nfe", 50);
    let lambdas = [0.0, 0.1, 0.3, 0.5, 0.7, 1.0];
    let mut t = Table::new(
        "Table 2: λ and integrator at NFE=50 (FD)",
        &["Method", "0.0", "0.1", "0.3", "0.5", "0.7", "1.0"],
    );
    let mut row = vec!["gDDIM".to_string()];
    for &lam in &lambdas {
        // Paper note: no polynomial extrapolation here, even at λ=0.
        let out = if lam == 0.0 {
            run_gddim(&s, KtKind::R, 1, nfe, false, n, 11)
        } else {
            run_gddim_sde(&s, lam, nfe, n, 11)
        };
        row.push(format!("{:.3}", fd(&out, &s.spec)));
    }
    t.row(row);
    let mut row = vec!["EM".to_string()];
    for &lam in &lambdas {
        let out = run_em(&s, lam, nfe, n, 11);
        row.push(format!("{:.3}", fd(&out, &s.spec)));
    }
    t.row(row);
    t.emit("table2");
}

/// Table 3 — acceleration across DMs (DDPM/BDM/CLD × sampler × NFE).
pub fn table3(args: &Args) {
    let dataset_2d = args.get_or("dataset", "gmm2d");
    let img = args.get_or("image-dataset", crate::data::presets::DEFAULT_IMAGE);
    let n2 = n_samples(args, 4000);
    let nimg = n_samples(args, 2000);
    let nfes: Vec<usize> =
        if args.has("full") { vec![10, 20, 50, 100, 1000] } else { vec![10, 20, 50, 100] };
    let mut header = vec!["DM".to_string(), "Sampler".to_string()];
    header.extend(nfes.iter().map(|n| n.to_string()));
    let header_refs: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new("Table 3: FD under different NFE", &header_refs);

    let cases: [(&str, &str, usize); 3] = [
        ("vpsde", dataset_2d.as_str(), n2),
        ("bdm", img.as_str(), nimg),
        ("cld", dataset_2d.as_str(), n2),
    ];

    for (proc, dataset, n) in cases {
        let s = setup(proc, dataset);
        let dm = match proc {
            "vpsde" => "DDPM",
            "bdm" => "BDM",
            _ => "CLD",
        };
        // Baseline SDE sampler: EM for DDPM/CLD, ancestral for BDM.
        let base_name = if proc == "bdm" { "Ancestral" } else { "EM" };
        let mut row = vec![dm.to_string(), base_name.to_string()];
        for &nfe in &nfes {
            let out = if proc == "bdm" {
                run_ancestral(&s, nfe, n, 21)
            } else {
                run_em(&s, 1.0, nfe, n, 21)
            };
            row.push(format!("{:.3}", fd(&out, &s.spec)));
        }
        t.row(row);

        let mut row = vec![dm.to_string(), "Prob.Flow RK45".to_string()];
        for &nfe in &nfes {
            let out = run_rk45_at(&s, nfe, n, 21);
            row.push(format!("{:.3} (nfe {})", fd(&out, &s.spec), out.nfe));
        }
        t.row(row);

        let mut row = vec![dm.to_string(), "2nd Heun".to_string()];
        for &nfe in &nfes {
            // Heun uses 2N−1 evals; pick grid so real NFE ≈ target.
            let grid_n = (nfe + 1) / 2;
            let out = run_heun(&s, grid_n.max(2), n, 21);
            row.push(format!("{:.3}", fd(&out, &s.spec)));
        }
        t.row(row);

        let mut row = vec![dm.to_string(), "gDDIM".to_string()];
        for &nfe in &nfes {
            let out = run_gddim(&s, KtKind::R, 3, nfe, false, n, 21);
            row.push(format!("{:.3}", fd(&out, &s.spec)));
        }
        t.row(row);
    }
    t.emit("table3");
}

/// Tables 5/6 — q × K_t sweep (paper Tables 5 on CIFAR10, 6 on CELEBA;
/// ours on the blobs8 / faces8 analogs + CLD).
fn table_q_kt(name: &str, dataset: &str, args: &Args) {
    let s = setup("cld", dataset);
    let n = n_samples(args, 2000);
    let nfes = [20usize, 30, 40, 50];
    let mut t = Table::new(
        &format!("{name}: multistep order q × K_t on CLD/{dataset} (FD)"),
        &["q", "K_t", "20", "30", "40", "50"],
    );
    for q in [1usize, 2, 3, 4] {
        for kt in [KtKind::L, KtKind::R] {
            let mut row = vec![format!("{}", q - 1), kt.label().to_string()];
            for &nfe in &nfes {
                let out = run_gddim(&s, kt, q, nfe, false, n, 31);
                row.push(format!("{:.3}", fd(&out, &s.spec)));
            }
            t.row(row);
        }
    }
    t.emit(name);
}

pub fn table5(args: &Args) {
    table_q_kt("table5", &args.get_or("dataset", crate::data::presets::DEFAULT_IMAGE), args);
}

pub fn table6(args: &Args) {
    table_q_kt("table6", &args.get_or("dataset", crate::data::presets::DEFAULT_FACES), args);
}

/// Table 7 — cross-method comparison on CLD (FD + NFE).
pub fn table7(args: &Args) {
    let s = setup("cld", &args.get_or("dataset", "gmm2d"));
    let n = n_samples(args, 4000);
    let mut t = Table::new(
        "Table 7: method comparison on CLD (NFE, FD)",
        &["Method", "NFE", "FD"],
    );
    let gd = run_gddim(&s, KtKind::R, 3, 50, false, n, 41);
    t.row(vec!["gDDIM (q=2, K=R)".into(), gd.nfe.to_string(), format!("{:.3}", fd(&gd, &s.spec))]);
    let em = run_em(&s, 1.0, if args.has("fast") { 200 } else { 2000 }, n, 41);
    t.row(vec!["SDE (EM)".into(), em.nfe.to_string(), format!("{:.3}", fd(&em, &s.spec))]);
    let rk = run_rk45_at(&s, 155, n, 41);
    t.row(vec!["Prob.Flow RK45".into(), rk.nfe.to_string(), format!("{:.3}", fd(&rk, &s.spec))]);
    let sscs = {
        let grid = crate::diffusion::TimeGrid::uniform(s.proc.t_min(), s.proc.t_max(), 150);
        let o = oracle(&s, KtKind::R);
        let mut rng = crate::math::rng::Rng::seed_from(41);
        Sscs { grid: &grid }.run(s.proc.as_ref(), &o, n, &mut rng, false)
    };
    t.row(vec!["SSCS (λ=1)".into(), sscs.nfe.to_string(), format!("{:.3}", fd(&sscs, &s.spec))]);
    t.emit("table7");
}

/// Table 8 — predictor-only vs predictor-corrector.
pub fn table8(args: &Args) {
    let s = setup("cld", &args.get_or("dataset", "gmm2d"));
    let n = n_samples(args, 4000);
    let steps = [20usize, 30, 40, 50];
    let mut t = Table::new(
        "Table 8: Predictor-only vs Predictor-Corrector (FD at N steps; PC uses 2N−1 NFE)",
        &["q", "Method", "20", "30", "40", "50"],
    );
    for q in [1usize, 2, 3, 4] {
        for (label, corr) in [("Predictor", false), ("PC", true)] {
            if q == 1 && corr {
                // PC needs at least two nodes for the corrector poly.
            }
            let mut row = vec![format!("{}", q - 1), label.to_string()];
            for &nsteps in &steps {
                let out = run_gddim(&s, KtKind::R, q, nsteps, corr, n, 51);
                row.push(format!("{:.3} ({} nfe)", fd(&out, &s.spec), out.nfe));
            }
            t.row(row);
        }
    }
    t.emit("table8");
}

/// App. C.8 — NLL (bits/dim) via the probability flow with exact
/// divergence; CLD uses the velocity-marginalization bound.
pub fn nll(args: &Args) {
    use crate::metrics::nll::nll_bits_per_dim;
    let n_pts = if args.has("fast") { 4 } else { 16 };
    let mut t = Table::new("App C.8: NLL (bits/dim)", &["process", "dataset", "bits/dim"]);
    for (proc, dataset) in [("vpsde", "gmm2d"), ("cld", "gmm2d")] {
        let s = setup(proc, dataset);
        let o = oracle(&s, KtKind::R);
        let mut rng = crate::math::rng::Rng::seed_from(61);
        let xs = s.spec.sample(n_pts, &mut rng);
        let bpd = nll_bits_per_dim(&o, &xs, 2, &mut rng, 1e-6);
        t.row(vec![proc.into(), dataset.into(), format!("{bpd:.3}")]);
    }
    t.emit("nll");
}

/// Coverage diagnostic used by fig4 and the quickstart.
pub fn coverage_line(xs: &[f64], spec: &crate::data::gmm::GmmSpec) -> String {
    let c = coverage(xs, spec);
    format!(
        "missing {}/{} modes, chi2 {:.1}, outliers {:.3}",
        c.missing,
        spec.n_modes(),
        c.chi2,
        c.outliers
    )
}
