//! # gDDIM — Generalized Denoising Diffusion Implicit Models
//!
//! A production-quality reproduction of *"gDDIM: Generalized denoising
//! diffusion implicit models"* (Zhang, Tao, Chen — ICLR 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate is organised bottom-up:
//!
//! * [`math`] — the numerical substrate (small-matrix linear algebra,
//!   ODE solvers, quadrature, interpolation, RNG, statistics, DCT) —
//!   everything is hand-rolled on `std` because the build is offline.
//! * [`diffusion`] — the three diffusion processes the paper evaluates
//!   (VPSDE/DDPM, CLD, BDM) behind a common [`diffusion::Process`] trait.
//! * [`coeffs`] — the paper's App. C.3/C.4 "Stage I": offline computation
//!   of `R_t`, transition matrices, and multistep predictor/corrector
//!   coefficients, packaged as a reusable [`coeffs::SamplerPlan`].
//! * [`score`] — score models: exact oracles for mixture data (closed
//!   form, used to validate Props 1–7) and PJRT-backed neural nets
//!   AOT-compiled from JAX/Pallas.
//! * [`samplers`] — "Stage II": gDDIM (deterministic + stochastic,
//!   multistep predictor-corrector) and every baseline the paper
//!   compares against (EM, ancestral, RK45 probability flow, Heun, SSCS).
//! * [`metrics`] — Fréchet distance (the repo's FID analog), Wasserstein,
//!   mode coverage, probability-flow NLL.
//! * [`data`] — synthetic datasets shared with the python build layer.
//! * [`runtime`] — the PJRT client wrapper that loads `artifacts/*.hlo.txt`.
//! * [`server`] — a batched sampling service (router + dynamic batcher).
//! * [`exp`] — experiment harnesses regenerating every paper table/figure.

pub mod math;
pub mod util;
pub mod diffusion;
pub mod coeffs;
pub mod data;
pub mod score;
pub mod samplers;
pub mod metrics;
pub mod runtime;
pub mod server;
pub mod workload;
pub mod exp;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
