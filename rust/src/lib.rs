//! # gDDIM — Generalized Denoising Diffusion Implicit Models
//!
//! A production-quality reproduction of *"gDDIM: Generalized denoising
//! diffusion implicit models"* (Zhang, Tao, Chen — ICLR 2023) as a
//! three-layer Rust + JAX + Pallas system.
//!
//! The crate is organised bottom-up:
//!
//! * [`math`] — the numerical substrate (small-matrix linear algebra,
//!   ODE solvers, quadrature, interpolation, RNG, statistics, DCT) —
//!   everything is hand-rolled on `std` because the build is offline.
//! * [`diffusion`] — the three diffusion processes the paper evaluates
//!   (VPSDE/DDPM, CLD, BDM) behind a common [`diffusion::Process`] trait.
//! * [`coeffs`] — the paper's App. C.3/C.4 "Stage I": offline computation
//!   of `R_t`, transition matrices, and multistep predictor/corrector
//!   coefficients, packaged as a reusable [`coeffs::SamplerPlan`].
//! * [`score`] — score models: exact oracles for mixture data (closed
//!   form, used to validate Props 1–7) and the pure-Rust
//!   [`score::ScoreNet`] that serves JAX-trained checkpoints natively
//!   (plus the optional PJRT executor behind the `pjrt` feature).
//! * [`samplers`] — "Stage II": the step-level [`samplers::Sampler`]
//!   trait and the owned [`samplers::SamplerSpec`], implemented by gDDIM
//!   (deterministic + stochastic, multistep predictor-corrector) and
//!   every baseline the paper compares against (EM, ancestral, RK45
//!   probability flow, Heun, SSCS).
//! * [`metrics`] — Fréchet distance (the repo's FID analog), Wasserstein,
//!   mode coverage, probability-flow NLL.
//! * [`data`] — synthetic datasets shared with the python build layer.
//! * [`runtime`] — the artifact layer: the validated `manifest.json`
//!   contract with `python/compile`, plus the feature-gated PJRT client
//!   that executes `artifacts/*.hlo.txt`.
//! * [`engine`] — the sharded parallel sampling engine: fixed-size shards,
//!   per-shard RNG streams, deterministic merge, a persistent worker pool
//!   (mpsc job queue, condvar result collection, counters).
//! * [`server`] — a batched sampling service (router + dynamic batcher +
//!   LRU plan cache + the engine as its execution backend).
//! * [`workload`] — closed- and open-loop (SLO-at-rate) workload drivers.
//! * [`exp`] — experiment harnesses regenerating every paper table/figure.
//! * [`analysis`] — `gddim lint`: the repo-invariant static-analysis
//!   pass that keeps the concurrency core honest (lock hygiene, SAFETY
//!   comments, bounded network reads, bit-identity fences).

pub mod math;
pub mod analysis;
pub mod util;
pub mod diffusion;
pub mod coeffs;
pub mod data;
pub mod score;
pub mod samplers;
pub mod metrics;
pub mod runtime;
pub mod engine;
pub mod server;
pub mod workload;
pub mod exp;

/// Crate-wide error type. The build is offline and std-only (no
/// `anyhow`), and every fallible path in this crate is I/O- or
/// parse-shaped, so a message string is the whole contract.
#[derive(Debug)]
pub struct Error(String);

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error(msg.into())
    }
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error(e.to_string())
    }
}

impl From<String> for Error {
    fn from(s: String) -> Error {
        Error(s)
    }
}

impl From<&str> for Error {
    fn from(s: &str) -> Error {
        Error(s.to_string())
    }
}

/// Crate-wide result type.
pub type Result<T> = std::result::Result<T, Error>;
