//! `gddim` — the leader binary.
//!
//! Subcommands:
//!   gen-configs            write configs/datasets.json + configs/cld_tables.json
//!   selfcheck              validate processes, plans and oracle invariants
//!   sample                 run one sampling config and report metrics
//!   exp <table1|...|nll>   regenerate a paper table/figure (also via `cargo bench`)
//!   coeffs                 time Stage-I plan construction (App. C.3 "within 1 min")
//!   serve                  batched sampling service (demo, or TCP edge via --listen)
//!   workload               open-loop SLO workload: rate sweep + latency percentiles
//!   benchdiff              compare two BENCH_serving.json snapshots (perf gate)
//!   lint                   repo-invariant static analysis over rust/src (CI gate)

use std::sync::Arc;

use gddim::data::presets;
use gddim::diffusion::process::KtKind;
use gddim::diffusion::{Bdm, Cld, Process, TimeGrid, Vpsde};
use gddim::coeffs::plan::{PlanConfig, SamplerPlan};
use gddim::engine::{Engine, EngineConfig, Job};
use gddim::metrics::coverage::coverage;
use gddim::metrics::frechet::frechet_to_spec;
use gddim::samplers::{OrderedF64, SamplerSpec};
use gddim::score::oracle::GmmOracle;
use gddim::util::cli::Args;
use gddim::util::json::Json;

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "gen-configs" => gen_configs(),
        "selfcheck" => selfcheck(),
        "sample" => sample(&args),
        "coeffs" => coeffs(&args),
        "exp" => exp(&args),
        "serve" => serve(&args),
        "workload" => workload(&args),
        "benchdiff" => benchdiff(&args),
        "lint" => std::process::exit(gddim::analysis::run_cli(&args)),
        _ => {
            // The dataset list comes from the preset registry, so a new
            // preset shows up here without touching the usage string.
            let datasets = presets::names().collect::<Vec<_>>().join("|");
            eprintln!(
                "usage: gddim \
                 <gen-configs|selfcheck|sample|coeffs|exp|serve|workload|benchdiff|lint> \
                 [--flags]\n\
                 sample flags: --process vpsde|cld|bdm --dataset {datasets}\n\
                 \u{20}              --sampler gddim|gddim-sde|em|ancestral|rk45|heun|sscs\n\
                 \u{20}                        (or full spec grammar, e.g. \"em:lambda=0.5\")\n\
                 \u{20}              --nfe N --q Q --kt R|L --lambda L --rtol R --n N --seed S --corrector\n\
                 \u{20}              --workers W   (persistent engine pool size)\n\
                 \u{20}              --shard-size BYTES   (per-shard engine state budget)\n\
                 \u{20}              --score-batch N --score-wait MICROS   (cross-key score pooling)\n\
                 serve flags:  --workers W --dispatchers D --requests R --samples S --rate RPS\n\
                 \u{20}              --dataset NAME --samplers SPEC+SPEC+.. --plan-cache-dir DIR\n\
                 \u{20}              --models-dir DIR   (serve learned ScoreNet models from a manifest)\n\
                 \u{20}              --shard-size BYTES --score-batch N (0 = off) --score-wait MICROS\n\
                 \u{20}              --listen ADDR   (TCP edge; line-delimited JSON wire protocol)\n\
                 \u{20}              --conn-threads N --accept-queue N --rate-limit RPS --rate-burst B\n\
                 \u{20}              --max-inflight N --slo-ms M --max-frame BYTES\n\
                 \u{20}              --duration-secs S --report-secs S\n\
                 workload flags: --rates R1,R2,.. (or --rate R) --slo-ms M --poisson\n\
                 \u{20}                --requests R --samples S --nfe N --workers W --dispatchers D\n\
                 \u{20}                --dataset NAME --samplers SPEC+SPEC+.. --plan-cache-dir DIR\n\
                 \u{20}                --models-dir DIR --shard-size BYTES\n\
                 \u{20}                --score-batch N (0 = off) --score-wait MICROS\n\
                 \u{20}                --tcp --conns C   (drive the loopback TCP edge, C connections)\n\
                 benchdiff:    gddim benchdiff OLD.json NEW.json [--tol FRAC]   (exit 1 on regression)\n\
                 \u{20}              gddim benchdiff --validate FILE.json       (schema check only)\n\
                 lint:         gddim lint [PATHS] [--fix-plan] [--no-graph]   (default rust/src)\n\
                 \u{20}              gddim lint --format json | --explain RULE  (exit 1 on findings)"
            );
        }
    }
}

fn gen_configs() {
    std::fs::create_dir_all("configs").unwrap();
    let j = presets::export_json();
    std::fs::write("configs/datasets.json", j.to_string_pretty()).unwrap();
    println!("wrote configs/datasets.json");

    // CLD Stage-I tables for the python training layer: Ψ(t,0), Σ_t, R_t,
    // L_t on a dense grid (python interpolates linearly).
    let cld = Cld::standard(1);
    let n = 2000;
    let mut rows = Vec::with_capacity(n + 1);
    for i in 0..=n {
        let t = cld.t_min() * 0.1 + (cld.t_max() - cld.t_min() * 0.1) * i as f64 / n as f64;
        let psi = cld.psi_mat(t, 0.0);
        let sig = cld.sigma_mat(t);
        let r = cld.r_mat(t);
        let l = sig.cholesky();
        let mut row = vec![t];
        row.extend_from_slice(&psi.to_array());
        row.extend_from_slice(&[sig.a, sig.b, sig.d]);
        row.extend_from_slice(&r.to_array());
        row.extend_from_slice(&[l.a, l.c, l.d]);
        rows.push(Json::Arr(row.into_iter().map(Json::Num).collect()));
    }
    let mut obj = std::collections::BTreeMap::new();
    obj.insert(
        "columns".to_string(),
        Json::Str("t, psi(a,b,c,d), sigma(xx,xv,vv), R(a,b,c,d), L(l11,l21,l22)".into()),
    );
    obj.insert("beta".to_string(), Json::Num(cld.cfg.beta));
    obj.insert("mass".to_string(), Json::Num(cld.cfg.mass));
    obj.insert("gamma0".to_string(), Json::Num(cld.cfg.gamma0));
    obj.insert("rows".to_string(), Json::Arr(rows));
    std::fs::write("configs/cld_tables.json", Json::Obj(obj).to_string_pretty()).unwrap();
    println!("wrote configs/cld_tables.json");
}

/// Explicit-dimension process construction for the diagnostic
/// subcommands (`selfcheck`, `coeffs`) that sweep dimensions without a
/// dataset. Dataset-driven paths (`sample`, the server) size processes
/// from the preset registry via `gddim::diffusion::process_for`.
fn build_process(name: &str, d: usize) -> Arc<dyn Process> {
    match name {
        "vpsde" => Arc::new(Vpsde::standard(d)),
        "cld" => Arc::new(Cld::standard(d)),
        "bdm" => {
            let side = (d as f64).sqrt() as usize;
            assert_eq!(side * side, d, "bdm needs a square image dimension");
            Arc::new(Bdm::standard(side, side))
        }
        other => panic!("unknown process {other}"),
    }
}

fn selfcheck() {
    use gddim::diffusion::process::validate_process;
    for (name, d) in [("vpsde", 2usize), ("cld", 2), ("bdm", 16)] {
        let p = build_process(name, d);
        let probes = [p.t_min(), 0.1, 0.5, 0.9, p.t_max()];
        match validate_process(p.as_ref(), &probes) {
            Ok(()) => println!("{name}: process invariants OK"),
            Err(e) => println!("{name}: FAILED — {e}"),
        }
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 10);
        let plan = SamplerPlan::build(p.as_ref(), &grid, &PlanConfig::default());
        println!("{name}: plan built in {:.3}s", plan.build_seconds);
    }
}

/// Resolve the CLI sampler flags into one owned spec. Bare names pick up
/// `--q/--kt/--lambda/--rtol/--corrector`; a full spec-grammar string
/// (e.g. `"em:lambda=0.5"`) is passed through verbatim.
fn spec_from_args(args: &Args) -> Result<SamplerSpec, gddim::Error> {
    let sampler = args.get_or("sampler", "gddim");
    let kt: KtKind = args.get_or("kt", "R").parse().map_err(gddim::Error::msg)?;
    let q = args.get_usize("q", 2);
    let lambda = args.get_f64("lambda", 0.0);
    let rtol = args.get_f64("rtol", 1e-4);
    // Reject "nan"/"inf" here (f64 parses them) so the bare-flag path
    // errors cleanly like the grammar path, instead of asserting inside
    // OrderedF64.
    if !lambda.is_finite() {
        return Err(gddim::Error::msg("--lambda must be finite"));
    }
    if !rtol.is_finite() {
        return Err(gddim::Error::msg("--rtol must be finite"));
    }
    match sampler.as_str() {
        "gddim" => Ok(SamplerSpec::GddimDet { q, kt, corrector: args.has("corrector") }),
        "gddim-sde" => Ok(SamplerSpec::GddimSde { lambda: OrderedF64::new(lambda.max(0.1)) }),
        "em" => Ok(SamplerSpec::Em { lambda: OrderedF64::new(lambda) }),
        "ancestral" => Ok(SamplerSpec::Ancestral),
        "heun" => Ok(SamplerSpec::Heun),
        "sscs" => Ok(SamplerSpec::Sscs),
        "rk45" => Ok(SamplerSpec::Rk45 { rtol: OrderedF64::new(rtol) }),
        grammar => SamplerSpec::parse(grammar),
    }
}

fn sample(args: &Args) {
    let dataset = args.get_or("dataset", "gmm2d");
    let Some(info) = presets::info(&dataset) else {
        let known = presets::names().collect::<Vec<_>>().join(", ");
        eprintln!("error: unknown dataset `{dataset}` (known: {known})");
        std::process::exit(2);
    };
    let spec = info.build();
    let proc_name = args.get_or("process", "cld");
    // Registry-sized process: BDM takes the preset's (h, w); vector data
    // on BDM is a clean CLI error instead of a dimension assert.
    let proc = match gddim::diffusion::process_for(&proc_name, info) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let nfe = args.get_usize("nfe", 50);
    let n = args.get_usize("n", 2000);
    let seed = args.get_u64("seed", 0);
    let workers = args.get_usize("workers", 1);
    let shard_bytes = args.get_usize("shard-size", EngineConfig::default().shard_bytes);
    // Cross-key score batching: off by default for the one-shot CLI.
    // Pooling needs concurrent shards, i.e. `--workers >= 2` — on the
    // inline engine the scheduler only adds per-eval overhead. Output
    // is bit-identical either way.
    let score_batch = args.get_usize("score-batch", 0);
    let score_wait = std::time::Duration::from_micros(args.get_u64("score-wait", 200));

    // One owned spec drives everything: validation, Stage-I plan
    // construction, oracle parameterization, and the engine job. All
    // seven samplers shard through the engine (RK45 adapts per shard).
    let sampler_spec = match spec_from_args(args).and_then(|s| {
        s.validate(&proc_name)?;
        Ok(s)
    }) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    let oracle = GmmOracle::new(proc.clone(), spec.clone(), sampler_spec.model_kt());
    let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), nfe);
    let engine = Engine::with_config(EngineConfig {
        workers,
        shard_bytes,
        score_batch,
        score_wait,
        ..EngineConfig::default()
    });

    let t0 = std::time::Instant::now();
    let plan = sampler_spec
        .plan_config()
        .map(|cfg| SamplerPlan::build(proc.as_ref(), &grid, &cfg));
    let sampler = sampler_spec
        .instantiate(plan.as_ref(), &grid)
        .expect("validated spec must instantiate");
    let out = engine.run(&Job {
        proc: proc.as_ref(),
        model: &oracle,
        sampler: sampler.as_ref(),
        n,
        seed,
    });
    let wall = t0.elapsed().as_secs_f64();
    let fd = frechet_to_spec(&out.xs, &spec);
    let cov = coverage(&out.xs, &spec);
    println!(
        "process={proc_name} dataset={dataset} sampler={sampler_spec} workers={workers}\n\
         NFE={} FD={fd:.4} missing-modes={}/{} outliers={:.3} wall={wall:.2}s",
        out.nfe,
        cov.missing,
        spec.n_modes(),
        cov.outliers,
    );
}

fn coeffs(args: &Args) {
    // App. C.3: "The calculation of all these coefficients can be done
    // within 1 min." Report our Stage-I timings — BDM is swept across the
    // image-resolution ladder (8/16/32), since its plan cost scales with
    // the per-frequency diagonal dimension.
    let nfe = args.get_usize("nfe", 50);
    for (name, d) in [("vpsde", 2usize), ("cld", 2), ("bdm", 64), ("bdm", 256), ("bdm", 1024)] {
        let p = build_process(name, d);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), nfe);
        for (label, cfg) in [
            ("det q=3", PlanConfig::deterministic(3, KtKind::R)),
            (
                "det q=3 + corrector",
                PlanConfig { q: 3, with_corrector: true, ..PlanConfig::default() },
            ),
            ("stochastic λ=1", PlanConfig::stochastic(1.0)),
        ] {
            let plan = SamplerPlan::build(p.as_ref(), &grid, &cfg);
            println!("{name:6} d={d:<5} {label:22} N={nfe}: {:.3}s", plan.build_seconds);
        }
    }
}

fn exp(args: &Args) {
    let which = args.positional.get(1).map(|s| s.as_str()).unwrap_or("all");
    gddim::exp::run(which, args);
}

fn serve(args: &Args) {
    // `--listen ADDR` runs the real TCP edge; without it, the in-process
    // synthetic-load demo (the original `serve` behavior) keeps working.
    if args.has("listen") {
        gddim::server::net::run_cli(args);
    } else {
        gddim::server::demo::run(args);
    }
}

fn workload(args: &Args) {
    gddim::workload::run_cli(args);
}

/// `gddim benchdiff OLD.json NEW.json [--tol FRAC]` — the perf-trajectory
/// gate. Exit codes: 0 within tolerance, 1 regression (throughput drop or
/// p99 inflation beyond `--tol`, default 10%, or a vanished scenario),
/// 2 unreadable/invalid input or bad usage. `--validate FILE` checks one
/// snapshot against the schema without comparing (CI's hard gate on the
/// emitted artifact; the cross-machine diff stays advisory).
fn benchdiff(args: &Args) {
    use gddim::workload::bench_report::{diff, BenchReport, DEFAULT_TOL};
    fn fail(msg: &str) -> ! {
        eprintln!("benchdiff: {msg}");
        std::process::exit(2);
    }
    if let Some(path) = args.get("validate") {
        match BenchReport::read(path) {
            Ok(r) => {
                println!(
                    "{path}: schema v{} ok — {} scenarios (quick={}, source={})",
                    r.schema_version,
                    r.scenarios.len(),
                    r.quick,
                    r.source
                );
            }
            Err(e) => fail(&e),
        }
        return;
    }
    let (Some(old_path), Some(new_path)) = (args.positional.get(1), args.positional.get(2)) else {
        fail("usage: gddim benchdiff OLD.json NEW.json [--tol FRAC] | --validate FILE.json");
    };
    let tol = args.get_f64("tol", DEFAULT_TOL);
    if !(tol.is_finite() && tol >= 0.0) {
        fail("--tol must be a finite non-negative fraction");
    }
    let old = BenchReport::read(old_path).unwrap_or_else(|e| fail(&e));
    let new = BenchReport::read(new_path).unwrap_or_else(|e| fail(&e));
    let d = diff(&old, &new, tol);
    println!("{d}");
    if d.passed() {
        println!("benchdiff: ok ({} scenarios within {:.0}% tol)", d.scenarios.len(), tol * 100.0);
    } else {
        let failing: Vec<&str> = d
            .scenarios
            .iter()
            .filter(|s| !s.failures.is_empty())
            .map(|s| s.name.as_str())
            .collect();
        eprintln!("benchdiff: REGRESSION in {}", failing.join(", "));
        std::process::exit(1);
    }
}
