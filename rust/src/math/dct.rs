//! Orthonormal DCT-II transforms for the Blurring Diffusion Model.
//!
//! BDM (paper Eq. 11, App. B.1) defines its forward process in frequency
//! space: `y_t = Vᵀ x_t` with `Vᵀ` the (orthonormal) DCT and `V` the
//! inverse DCT, and diagonal `α_t`, `σ_t` per frequency. We implement the
//! 1-D DCT-II matrix and its separable 2-D application; image sizes here
//! are small (≤ 32) so the dense O(n²) matrix apply is the right tool
//! (and is exactly invertible by the transpose, which the tests verify).

use crate::math::linalg::MatD;

/// Orthonormal DCT-II matrix `C` with `y = C x`:
/// `C[k][n] = s_k * cos(π (n + ½) k / N)`, `s_0 = √(1/N)`, `s_k = √(2/N)`.
pub fn dct_matrix(n: usize) -> MatD {
    let mut c = MatD::zeros(n, n);
    let nf = n as f64;
    for k in 0..n {
        let s = if k == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
        for j in 0..n {
            c[(k, j)] = s * (std::f64::consts::PI * (j as f64 + 0.5) * k as f64 / nf).cos();
        }
    }
    c
}

/// Squared spatial frequencies `λ_k = (π k / N)²` used by the blurring
/// schedule (heat dissipation in frequency space).
pub fn frequencies_squared(n: usize) -> Vec<f64> {
    (0..n).map(|k| (std::f64::consts::PI * k as f64 / n as f64).powi(2)).collect()
}

/// Separable 2-D DCT over a row-major `h×w` image: `Y = C_h X C_wᵀ`.
pub struct Dct2 {
    pub h: usize,
    pub w: usize,
    ch: MatD,
    cw: MatD,
}

impl Dct2 {
    pub fn new(h: usize, w: usize) -> Self {
        Dct2 { h, w, ch: dct_matrix(h), cw: dct_matrix(w) }
    }

    /// Forward DCT (pixel -> frequency), out-of-place.
    pub fn forward(&self, img: &[f64]) -> Vec<f64> {
        self.apply(img, false)
    }

    /// Inverse DCT (frequency -> pixel).
    pub fn inverse(&self, freq: &[f64]) -> Vec<f64> {
        self.apply(freq, true)
    }

    fn apply(&self, x: &[f64], inverse: bool) -> Vec<f64> {
        assert_eq!(x.len(), self.h * self.w);
        let xm = MatD { n: self.h, m: self.w, data: x.to_vec() };
        let out = if inverse {
            // X = C_hᵀ Y C_w
            self.ch.transpose().matmul(&xm).matmul(&self.cw)
        } else {
            // Y = C_h X C_wᵀ
            self.ch.matmul(&xm).matmul(&self.cw.transpose())
        };
        out.data
    }

    /// Per-coefficient eigenvalues of the 2-D Laplacian blur:
    /// `λ_{ij} = λ_i + λ_j` flattened row-major (the BDM dissipation rates).
    pub fn blur_eigenvalues(&self) -> Vec<f64> {
        let fh = frequencies_squared(self.h);
        let fw = frequencies_squared(self.w);
        let mut out = Vec::with_capacity(self.h * self.w);
        for i in 0..self.h {
            for j in 0..self.w {
                out.push(fh[i] + fw[j]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{assert_allclose, rng::Rng};

    #[test]
    fn dct_matrix_is_orthonormal() {
        for n in [1usize, 2, 4, 8, 16] {
            let c = dct_matrix(n);
            let ctc = c.transpose().matmul(&c);
            assert!(
                ctc.sub(&MatD::eye(n)).max_abs() < 1e-12,
                "n={n}: CᵀC != I ({})",
                ctc.sub(&MatD::eye(n)).max_abs()
            );
        }
    }

    #[test]
    fn dct2_roundtrip() {
        let mut rng = Rng::seed_from(31);
        let d = Dct2::new(8, 8);
        let img: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let back = d.inverse(&d.forward(&img));
        assert_allclose(&back, &img, 1e-12, 1e-12, "dct2 roundtrip");
    }

    #[test]
    fn dct_of_constant_is_dc_only() {
        let d = Dct2::new(4, 4);
        let img = vec![2.5; 16];
        let f = d.forward(&img);
        assert!((f[0] - 2.5 * 4.0).abs() < 1e-12, "DC = mean * sqrt(h*w)");
        for &v in &f[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn dct_preserves_l2_norm() {
        let mut rng = Rng::seed_from(37);
        let d = Dct2::new(8, 8);
        let img: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let f = d.forward(&img);
        let n1: f64 = img.iter().map(|x| x * x).sum();
        let n2: f64 = f.iter().map(|x| x * x).sum();
        assert!((n1 - n2).abs() < 1e-10 * n1, "Parseval");
    }

    #[test]
    fn blur_eigenvalues_monotone_per_row() {
        let d = Dct2::new(8, 8);
        let lam = d.blur_eigenvalues();
        assert_eq!(lam[0], 0.0, "DC mode never dissipates");
        for i in 1..8 {
            assert!(lam[i] > lam[i - 1], "frequencies increase along a row");
        }
    }
}
