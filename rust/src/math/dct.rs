//! Orthonormal DCT-II transforms for the Blurring Diffusion Model.
//!
//! BDM (paper Eq. 11, App. B.1) defines its forward process in frequency
//! space: `y_t = Vᵀ x_t` with `Vᵀ` the (orthonormal) DCT and `V` the
//! inverse DCT, and diagonal `α_t`, `σ_t` per frequency. We implement the
//! 1-D DCT-II matrix and its separable 2-D application; image sizes here
//! are small (≤ 32) so the dense O(n²) matrix apply is the right tool
//! (and is exactly invertible by the transpose, which the tests verify).
//!
//! The 2-D apply is the per-row hot path of BDM serving (`lift_data` /
//! `proj_data` run once per sample and once per oracle mode), so it
//! works out of a reusable per-thread scratch buffer: after the first
//! call on a thread, [`Dct2::forward_into`] / [`Dct2::inverse_into`] do
//! **zero heap allocation** — at 32×32 (1024-dim rows) the old
//! fresh-`Vec`-per-pass scheme was the dominant per-call cost. The
//! allocating [`Dct2::forward`] / [`Dct2::inverse`] wrappers remain for
//! the `Process` trait surface (which returns `Vec`s); their only
//! allocation is that output vector.

use crate::math::linalg::MatD;
use std::cell::RefCell;

/// Orthonormal DCT-II matrix `C` with `y = C x`:
/// `C[k][n] = s_k * cos(π (n + ½) k / N)`, `s_0 = √(1/N)`, `s_k = √(2/N)`.
pub fn dct_matrix(n: usize) -> MatD {
    let mut c = MatD::zeros(n, n);
    let nf = n as f64;
    for k in 0..n {
        let s = if k == 0 { (1.0 / nf).sqrt() } else { (2.0 / nf).sqrt() };
        for j in 0..n {
            c[(k, j)] = s * (std::f64::consts::PI * (j as f64 + 0.5) * k as f64 / nf).cos();
        }
    }
    c
}

/// Squared spatial frequencies `λ_k = (π k / N)²` used by the blurring
/// schedule (heat dissipation in frequency space).
pub fn frequencies_squared(n: usize) -> Vec<f64> {
    (0..n).map(|k| (std::f64::consts::PI * k as f64 / n as f64).powi(2)).collect()
}

thread_local! {
    /// Per-thread intermediate for the separable 2-D apply. Keyed by
    /// thread rather than by `Dct2` instance so one shared transform
    /// (`Bdm` crosses engine worker threads by reference) never needs a
    /// lock, and so the buffer amortizes across every transform size a
    /// thread touches (grown, never shrunk).
    static DCT_SCRATCH: RefCell<Vec<f64>> = const { RefCell::new(Vec::new()) };
}

/// Separable 2-D DCT over a row-major `h×w` image: `Y = C_h X C_wᵀ`.
///
/// Both transform matrices are stored together with their transposes so
/// each pass of [`Dct2::apply`] streams **rows** (contiguous memory) no
/// matter the direction — the index-swapped strided reads of the old
/// column pass are gone, and the inner loops are flat fixed-stride
/// accumulations the compiler vectorizes (via [`crate::math::simd`]).
pub struct Dct2 {
    pub h: usize,
    pub w: usize,
    ch: MatD,
    cw: MatD,
    /// `C_hᵀ` — the rows pass of the inverse transform reads its rows.
    cht: MatD,
    /// `C_wᵀ` — the columns pass of the forward transform reads its rows.
    cwt: MatD,
}

impl Dct2 {
    pub fn new(h: usize, w: usize) -> Self {
        let ch = dct_matrix(h);
        let cw = dct_matrix(w);
        let cht = ch.transpose();
        let cwt = cw.transpose();
        Dct2 { h, w, ch, cw, cht, cwt }
    }

    /// Forward DCT (pixel -> frequency), allocating the output.
    pub fn forward(&self, img: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.h * self.w];
        self.forward_into(img, &mut out);
        out
    }

    /// Inverse DCT (frequency -> pixel), allocating the output.
    pub fn inverse(&self, freq: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; self.h * self.w];
        self.inverse_into(freq, &mut out);
        out
    }

    /// Forward DCT into a caller-provided buffer (allocation-free after
    /// the per-thread scratch warms up).
    pub fn forward_into(&self, img: &[f64], out: &mut [f64]) {
        self.apply(img, out, false);
    }

    /// Inverse DCT into a caller-provided buffer.
    pub fn inverse_into(&self, freq: &[f64], out: &mut [f64]) {
        self.apply(freq, out, true);
    }

    /// Both passes of the separable transform — `Y = C_h X C_wᵀ`
    /// forward, `X = C_hᵀ Y C_w` inverse — through one `h×w` per-thread
    /// scratch row block. No per-call `Vec`s, and both passes run
    /// k-outer / element-inner over *contiguous* matrix rows: each output
    /// element still accumulates its terms in k-ascending order (so the
    /// result is bit-identical to the classic scalar dot-product pass —
    /// golden-locked below), but the inner loop is a flat `axpy` over the
    /// row the compiler turns into SIMD lanes instead of a strided
    /// serial reduction.
    fn apply(&self, x: &[f64], out: &mut [f64], inverse: bool) {
        let (h, w) = (self.h, self.w);
        assert_eq!(x.len(), h * w);
        assert_eq!(out.len(), h * w);
        // M₁ = C_h (forward) or C_hᵀ (inverse), read as `m1[(i, k)]`;
        // M₂ = C_wᵀ (forward) or C_w (inverse), read as rows `m2.row(k)`.
        let m1 = if inverse { &self.cht } else { &self.ch };
        let m2 = if inverse { &self.cw } else { &self.cwt };
        DCT_SCRATCH.with(|cell| {
            let mut scratch = cell.borrow_mut();
            if scratch.len() < h * w {
                scratch.resize(h * w, 0.0);
            }
            let tmp = &mut scratch[..h * w];
            // Rows pass: tmp = M₁ X, accumulated one input row at a time.
            for i in 0..h {
                let trow = &mut tmp[i * w..(i + 1) * w];
                trow.fill(0.0);
                for k in 0..h {
                    let a = m1[(i, k)];
                    if a == 0.0 {
                        continue;
                    }
                    crate::math::simd::axpy(a, &x[k * w..(k + 1) * w], trow);
                }
            }
            // Columns pass: out = tmp M₂ᵀ-shaped product, i.e.
            // out[i][j] = Σ_k tmp[i][k] · m2[k][j], accumulated k-outer
            // so `m2.row(k)` streams contiguously.
            for i in 0..h {
                let trow = &tmp[i * w..(i + 1) * w];
                let orow = &mut out[i * w..(i + 1) * w];
                orow.fill(0.0);
                for (k, &tv) in trow.iter().enumerate() {
                    crate::math::simd::axpy(tv, m2.row(k), orow);
                }
            }
        });
    }

    /// Per-coefficient eigenvalues of the 2-D Laplacian blur:
    /// `λ_{ij} = λ_i + λ_j` flattened row-major (the BDM dissipation rates).
    pub fn blur_eigenvalues(&self) -> Vec<f64> {
        let fh = frequencies_squared(self.h);
        let fw = frequencies_squared(self.w);
        let mut out = Vec::with_capacity(self.h * self.w);
        for i in 0..self.h {
            for j in 0..self.w {
                out.push(fh[i] + fw[j]);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{assert_allclose, rng::Rng};

    #[test]
    fn dct_matrix_is_orthonormal() {
        for n in [1usize, 2, 4, 8, 16, 32] {
            let c = dct_matrix(n);
            let ctc = c.transpose().matmul(&c);
            assert!(
                ctc.sub(&MatD::eye(n)).max_abs() < 1e-12,
                "n={n}: CᵀC != I ({})",
                ctc.sub(&MatD::eye(n)).max_abs()
            );
        }
    }

    #[test]
    fn dct2_roundtrip_at_every_supported_side() {
        let mut rng = Rng::seed_from(31);
        for side in [8usize, 16, 32] {
            let d = Dct2::new(side, side);
            let img: Vec<f64> = (0..side * side).map(|_| rng.normal()).collect();
            let back = d.inverse(&d.forward(&img));
            assert_allclose(&back, &img, 1e-12, 1e-12, &format!("dct2 roundtrip {side}"));
        }
    }

    #[test]
    fn dct2_roundtrip_non_square() {
        let mut rng = Rng::seed_from(33);
        let d = Dct2::new(8, 16);
        let img: Vec<f64> = (0..128).map(|_| rng.normal()).collect();
        let back = d.inverse(&d.forward(&img));
        assert_allclose(&back, &img, 1e-12, 1e-12, "dct2 roundtrip 8x16");
    }

    #[test]
    fn dct_of_constant_is_dc_only() {
        let d = Dct2::new(4, 4);
        let img = vec![2.5; 16];
        let f = d.forward(&img);
        assert!((f[0] - 2.5 * 4.0).abs() < 1e-12, "DC = mean * sqrt(h*w)");
        for &v in &f[1..] {
            assert!(v.abs() < 1e-12);
        }
    }

    #[test]
    fn dct_preserves_l2_norm_at_every_supported_side() {
        let mut rng = Rng::seed_from(37);
        for side in [8usize, 16, 32] {
            let d = Dct2::new(side, side);
            let img: Vec<f64> = (0..side * side).map(|_| rng.normal()).collect();
            let f = d.forward(&img);
            let n1: f64 = img.iter().map(|x| x * x).sum();
            let n2: f64 = f.iter().map(|x| x * x).sum();
            assert!((n1 - n2).abs() < 1e-10 * n1, "Parseval at {side}");
        }
    }

    #[test]
    fn into_variants_match_allocating_ones_bit_for_bit() {
        // The scratch-buffer path is the same arithmetic as the
        // allocating wrappers (they delegate), and interleaving sizes on
        // one thread must not cross-contaminate the shared scratch.
        let mut rng = Rng::seed_from(41);
        let small = Dct2::new(8, 8);
        let big = Dct2::new(32, 32);
        let a: Vec<f64> = (0..64).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..1024).map(|_| rng.normal()).collect();
        let mut out_a = vec![0.0; 64];
        let mut out_b = vec![0.0; 1024];
        big.forward_into(&b, &mut out_b);
        small.forward_into(&a, &mut out_a);
        assert_eq!(out_a, small.forward(&a), "8x8 forward diverged after 32x32 warm-up");
        assert_eq!(out_b, big.forward(&b), "32x32 forward_into vs forward");
        small.inverse_into(&a, &mut out_a);
        assert_eq!(out_a, small.inverse(&a), "inverse_into vs inverse");
    }

    /// Verbatim pre-vectorization separable apply (PR 6): index-swapped
    /// reads, j-outer serial dot products in the columns pass. The
    /// golden reference the blocked passes must match bit-for-bit.
    fn reference_apply(d: &Dct2, x: &[f64], out: &mut [f64], inverse: bool) {
        let (h, w) = (d.h, d.w);
        let mut tmp = vec![0.0; h * w];
        for i in 0..h {
            let trow = &mut tmp[i * w..(i + 1) * w];
            trow.fill(0.0);
            for k in 0..h {
                let a = if inverse { d.ch[(k, i)] } else { d.ch[(i, k)] };
                if a == 0.0 {
                    continue;
                }
                let xrow = &x[k * w..(k + 1) * w];
                for (t, &xv) in trow.iter_mut().zip(xrow) {
                    *t += a * xv;
                }
            }
        }
        for i in 0..h {
            let trow = &tmp[i * w..(i + 1) * w];
            let orow = &mut out[i * w..(i + 1) * w];
            for (j, o) in orow.iter_mut().enumerate() {
                let mut acc = 0.0;
                for (k, &tv) in trow.iter().enumerate() {
                    let b = if inverse { d.cw[(k, j)] } else { d.cw[(j, k)] };
                    acc += tv * b;
                }
                *o = acc;
            }
        }
    }

    #[test]
    fn dct_blocked_passes_match_goldens_at_8_16_32() {
        // The k-outer blocked passes keep every output element's
        // accumulation in k-ascending order, so they must reproduce the
        // pre-change scalar passes exactly — BDM's lifted prototype
        // means, sampler goldens, and persisted plans all depend on
        // these bits. Swept across the supported resolution ladder plus
        // a non-square shape, forward and inverse.
        let mut rng = Rng::seed_from(53);
        let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
        for (h, w) in [(8usize, 8usize), (16, 16), (32, 32), (8, 16)] {
            let d = Dct2::new(h, w);
            let img: Vec<f64> = (0..h * w).map(|_| rng.normal()).collect();
            for inverse in [false, true] {
                let mut got = vec![0.0; h * w];
                let mut want = vec![0.0; h * w];
                d.apply(&img, &mut got, inverse);
                reference_apply(&d, &img, &mut want, inverse);
                assert_eq!(
                    bits(&got),
                    bits(&want),
                    "{h}x{w} {} pass diverged from the scalar golden",
                    if inverse { "inverse" } else { "forward" }
                );
            }
        }
    }

    #[test]
    fn blur_eigenvalues_monotone_per_row() {
        let d = Dct2::new(8, 8);
        let lam = d.blur_eigenvalues();
        assert_eq!(lam[0], 0.0, "DC mode never dissipates");
        for i in 1..8 {
            assert!(lam[i] > lam[i - 1], "frequencies increase along a row");
        }
    }
}
