//! Interpolation utilities:
//!
//! * Uniform-grid linear interpolation tables (the paper's Stage-I
//!   strategy: "Since the output of numerical solvers are discrete in
//!   time, we employ a linear interpolation to handle query in continuous
//!   time" — App. C.3).
//! * Lagrange basis polynomials `ℓ_j(τ) = Π_{k≠j} (τ−t_k)/(t_j−t_k)` for
//!   the multistep predictor/corrector (Eqs. 39/44).

/// A vector-valued function of time tabulated on a uniform grid, with
/// linear interpolation between samples (and clamping at the ends).
#[derive(Clone, Debug)]
pub struct UniformTable {
    pub t0: f64,
    pub t1: f64,
    /// values[i] is the sample at t0 + i*dt; each sample is a k-vector.
    pub values: Vec<Vec<f64>>,
    pub k: usize,
}

impl UniformTable {
    /// Tabulate `f` at `n+1` uniformly spaced points on [t0, t1].
    pub fn build<F: FnMut(f64, &mut [f64])>(
        t0: f64,
        t1: f64,
        n: usize,
        k: usize,
        mut f: F,
    ) -> Self {
        assert!(n >= 1 && t1 > t0);
        let dt = (t1 - t0) / n as f64;
        let mut values = Vec::with_capacity(n + 1);
        for i in 0..=n {
            let mut v = vec![0.0; k];
            f(t0 + i as f64 * dt, &mut v);
            values.push(v);
        }
        UniformTable { t0, t1, values, k }
    }

    /// Build directly from precomputed rows (used when the samples come
    /// out of a single ODE sweep rather than independent evaluations).
    pub fn from_values(t0: f64, t1: f64, values: Vec<Vec<f64>>) -> Self {
        assert!(values.len() >= 2);
        let k = values[0].len();
        UniformTable { t0, t1, values, k }
    }

    #[inline]
    pub fn eval_into(&self, t: f64, out: &mut [f64]) {
        let n = self.values.len() - 1;
        let x = ((t - self.t0) / (self.t1 - self.t0) * n as f64).clamp(0.0, n as f64);
        let i = (x as usize).min(n - 1);
        let frac = x - i as f64;
        let lo = &self.values[i];
        let hi = &self.values[i + 1];
        for j in 0..self.k {
            out[j] = lo[j] + frac * (hi[j] - lo[j]);
        }
    }

    pub fn eval(&self, t: f64) -> Vec<f64> {
        let mut v = vec![0.0; self.k];
        self.eval_into(t, &mut v);
        v
    }

    /// Scalar convenience for k == 1 tables.
    pub fn eval1(&self, t: f64) -> f64 {
        debug_assert_eq!(self.k, 1);
        let mut v = [0.0];
        self.eval_into(t, &mut v);
        v[0]
    }
}

/// Two-segment table: a fine uniform grid on `[t0, knee]` and a coarse
/// one on `[knee, t1]`. The CLD Stage-I ODEs (`Σ_t`, `R_t`, `Ψ̂`) are
/// stiff near `t=0` (`Σ^{xx} ~ t³` makes `Σ⁻¹` blow up) but smooth
/// afterwards; this keeps the paper's RK4-with-1e-6-step accuracy near
/// the origin without paying for it across the whole horizon.
#[derive(Clone, Debug)]
pub struct TwoScaleTable {
    pub fine: UniformTable,
    pub coarse: UniformTable,
    pub knee: f64,
}

impl TwoScaleTable {
    pub fn new(fine: UniformTable, coarse: UniformTable) -> Self {
        assert!((fine.t1 - coarse.t0).abs() < 1e-12, "segments must touch");
        assert_eq!(fine.k, coarse.k);
        TwoScaleTable { knee: fine.t1, fine, coarse }
    }

    #[inline]
    pub fn eval_into(&self, t: f64, out: &mut [f64]) {
        if t <= self.knee {
            self.fine.eval_into(t, out)
        } else {
            self.coarse.eval_into(t, out)
        }
    }

    pub fn eval(&self, t: f64) -> Vec<f64> {
        let mut v = vec![0.0; self.fine.k];
        self.eval_into(t, &mut v);
        v
    }

    pub fn t0(&self) -> f64 {
        self.fine.t0
    }

    pub fn t1(&self) -> f64 {
        self.coarse.t1
    }
}

/// Geometrically-spaced table: nodes at `t0·r^i`, linear interpolation in
/// `ln t`. The right tool for Stage-I quantities with power-law behaviour
/// near `t = 0` (CLD's `R_t`): uniform *relative* resolution means the
/// interpolation error is a constant relative error across decades.
#[derive(Clone, Debug)]
pub struct LogTable {
    pub t0: f64,
    pub t1: f64,
    ln_t0: f64,
    ln_span: f64,
    pub values: Vec<Vec<f64>>,
    pub k: usize,
}

impl LogTable {
    pub fn from_values(t0: f64, t1: f64, values: Vec<Vec<f64>>) -> Self {
        assert!(t0 > 0.0 && t1 > t0 && values.len() >= 2);
        let k = values[0].len();
        LogTable { t0, t1, ln_t0: t0.ln(), ln_span: (t1 / t0).ln(), values, k }
    }

    /// The i-th node time (geometric spacing).
    pub fn node(&self, i: usize, n: usize) -> f64 {
        self.t0 * ((self.ln_span * i as f64 / n as f64).exp())
    }

    /// Catmull–Rom cubic interpolation in `ln t` (linear at the two
    /// boundary cells). O(Δ⁴) error on smooth tables — the Stage-I
    /// coefficient queries inherit RK4-level accuracy from the grid.
    #[inline]
    pub fn eval_into(&self, t: f64, out: &mut [f64]) {
        let n = self.values.len() - 1;
        let t = t.clamp(self.t0, self.t1);
        let x = ((t.ln() - self.ln_t0) / self.ln_span * n as f64).clamp(0.0, n as f64);
        let i = (x as usize).min(n - 1);
        let s = x - i as f64;
        if i == 0 || i + 2 > n {
            let lo = &self.values[i];
            let hi = &self.values[i + 1];
            for j in 0..self.k {
                out[j] = lo[j] + s * (hi[j] - lo[j]);
            }
            return;
        }
        let (p0, p1, p2, p3) =
            (&self.values[i - 1], &self.values[i], &self.values[i + 1], &self.values[i + 2]);
        for j in 0..self.k {
            let (a, b, c, d) = (p0[j], p1[j], p2[j], p3[j]);
            out[j] = 0.5
                * (2.0 * b
                    + s * ((c - a)
                        + s * ((2.0 * a - 5.0 * b + 4.0 * c - d)
                            + s * (3.0 * (b - c) + d - a))));
        }
    }

    pub fn eval(&self, t: f64) -> Vec<f64> {
        let mut v = vec![0.0; self.k];
        self.eval_into(t, &mut v);
        v
    }
}

/// Evaluate the Lagrange basis `ℓ_j(τ)` over the nodes `ts`.
/// Used by the q-step predictor (Eq. 39) / corrector (Eq. 44).
pub fn lagrange_basis(ts: &[f64], j: usize, tau: f64) -> f64 {
    let tj = ts[j];
    let mut p = 1.0;
    for (k, &tk) in ts.iter().enumerate() {
        if k != j {
            p *= (tau - tk) / (tj - tk);
        }
    }
    p
}

/// Evaluate the full interpolating polynomial through `(ts[j], ys[j])`.
pub fn lagrange_interp(ts: &[f64], ys: &[f64], tau: f64) -> f64 {
    assert_eq!(ts.len(), ys.len());
    (0..ts.len()).map(|j| ys[j] * lagrange_basis(ts, j, tau)).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    #[test]
    fn table_roundtrips_linear_functions_exactly() {
        let tab = UniformTable::build(0.0, 2.0, 10, 2, |t, v| {
            v[0] = 3.0 * t - 1.0;
            v[1] = -t;
        });
        for &t in &[0.0, 0.123, 0.77, 1.5, 2.0] {
            let v = tab.eval(t);
            assert!(close(v[0], 3.0 * t - 1.0, 1e-13, 1e-13));
            assert!(close(v[1], -t, 1e-13, 1e-13));
        }
    }

    #[test]
    fn table_clamps_out_of_range() {
        let tab = UniformTable::build(0.0, 1.0, 4, 1, |t, v| v[0] = t);
        assert!(close(tab.eval1(-5.0), 0.0, 0.0, 1e-14));
        assert!(close(tab.eval1(9.0), 1.0, 0.0, 1e-14));
    }

    #[test]
    fn table_converges_quadratically() {
        let f = |t: f64| (3.0 * t).sin();
        let err = |n: usize| {
            let tab = UniformTable::build(0.0, 1.0, n, 1, |t, v| v[0] = f(t));
            let mut e = 0.0f64;
            for i in 0..1000 {
                let t = i as f64 / 999.0;
                e = e.max((tab.eval1(t) - f(t)).abs());
            }
            e
        };
        assert!(err(100) / err(200) > 3.5, "linear interp should be O(h^2)");
    }

    #[test]
    fn two_scale_table_dispatches_by_knee() {
        let f = |t: f64| t * t * t;
        let fine = UniformTable::build(0.0, 0.1, 1000, 1, |t, v| v[0] = f(t));
        let coarse = UniformTable::build(0.1, 1.0, 100, 1, |t, v| v[0] = f(t));
        let tab = TwoScaleTable::new(fine, coarse);
        for &t in &[0.0, 0.05, 0.0999, 0.1, 0.3, 1.0] {
            let v = tab.eval(t)[0];
            assert!(close(v, f(t), 1e-3, 1e-9), "t={t}: {v} vs {}", f(t));
        }
        // Near zero the fine grid must be much more accurate than the
        // coarse spacing would allow.
        let t = 0.003;
        assert!((tab.eval(t)[0] - f(t)).abs() < 1e-9);
    }

    #[test]
    fn log_table_uniform_relative_error_on_power_law() {
        // f(t) = t^2.5 over four decades: relative error must stay small
        // even at the bottom of the range.
        let f = |t: f64| t.powf(2.5);
        let n = 2048;
        let t0: f64 = 1e-4;
        let t1: f64 = 1.0;
        let values: Vec<Vec<f64>> = (0..=n)
            .map(|i| vec![f(t0 * ((t1 / t0).ln() * i as f64 / n as f64).exp())])
            .collect();
        let tab = LogTable::from_values(t0, t1, values);
        for &t in &[1.3e-4, 1e-3, 3.7e-3, 0.02, 0.5, 1.0] {
            let v = tab.eval(t)[0];
            assert!(close(v, f(t), 1e-5, 0.0), "t={t}: {v} vs {}", f(t));
        }
    }

    #[test]
    fn log_table_clamps() {
        let values = vec![vec![1.0], vec![2.0], vec![4.0]];
        let tab = LogTable::from_values(0.1, 10.0, values);
        assert_eq!(tab.eval(0.001)[0], 1.0);
        assert_eq!(tab.eval(100.0)[0], 4.0);
    }

    #[test]
    fn lagrange_partition_of_unity() {
        let ts = [0.0, 0.3, 0.9, 1.4];
        for &tau in &[-0.2, 0.1, 0.5, 1.2, 2.0] {
            let s: f64 = (0..ts.len()).map(|j| lagrange_basis(&ts, j, tau)).sum();
            assert!(close(s, 1.0, 1e-12, 1e-12), "tau={tau} s={s}");
        }
    }

    #[test]
    fn lagrange_reproduces_polynomials() {
        // 3 nodes reproduce any quadratic exactly.
        let ts = [0.1, 0.6, 1.1];
        let f = |t: f64| 2.0 * t * t - t + 0.5;
        let ys: Vec<f64> = ts.iter().map(|&t| f(t)).collect();
        for &tau in &[0.0, 0.4, 0.9, 1.5] {
            assert!(close(lagrange_interp(&ts, &ys, tau), f(tau), 1e-12, 1e-12));
        }
    }

    #[test]
    fn lagrange_interpolates_nodes() {
        let ts = [0.0, 1.0, 2.0, 3.5];
        let ys = [5.0, -1.0, 2.0, 0.0];
        for j in 0..4 {
            assert!(close(lagrange_interp(&ts, &ys, ts[j]), ys[j], 1e-12, 1e-12));
        }
    }
}
