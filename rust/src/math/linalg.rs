//! Dense `d×d` linear algebra for the evaluation metrics (Fréchet
//! distance needs a symmetric matrix square root of data-space
//! covariances, `d` up to a few hundred) and for the DCT matrices used by
//! the blurring diffusion model.
//!
//! Only what the repo needs: matmul, symmetric eigendecomposition
//! (cyclic Jacobi — robust and dependency-free), SPD square root,
//! Cholesky, and a couple of norms.

/// Row-major dense matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct MatD {
    pub n: usize,
    pub m: usize,
    pub data: Vec<f64>,
}

impl MatD {
    pub fn zeros(n: usize, m: usize) -> Self {
        MatD { n, m, data: vec![0.0; n * m] }
    }

    pub fn eye(n: usize) -> Self {
        let mut a = MatD::zeros(n, n);
        for i in 0..n {
            a[(i, i)] = 1.0;
        }
        a
    }

    pub fn from_rows(rows: &[Vec<f64>]) -> Self {
        let n = rows.len();
        let m = if n == 0 { 0 } else { rows[0].len() };
        let mut data = Vec::with_capacity(n * m);
        for r in rows {
            assert_eq!(r.len(), m, "ragged rows");
            data.extend_from_slice(r);
        }
        MatD { n, m, data }
    }

    pub fn diag(v: &[f64]) -> Self {
        let mut a = MatD::zeros(v.len(), v.len());
        for (i, &x) in v.iter().enumerate() {
            a[(i, i)] = x;
        }
        a
    }

    /// Row `i` as a contiguous slice (row-major storage).
    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.m..(i + 1) * self.m]
    }

    pub fn transpose(&self) -> MatD {
        let mut t = MatD::zeros(self.m, self.n);
        for i in 0..self.n {
            for j in 0..self.m {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    pub fn matmul(&self, other: &MatD) -> MatD {
        assert_eq!(self.m, other.n, "matmul: inner dims {} vs {}", self.m, other.n);
        let mut out = MatD::zeros(self.n, other.m);
        // ikj loop order for cache friendliness.
        for i in 0..self.n {
            for k in 0..self.m {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = &other.data[k * other.m..(k + 1) * other.m];
                let out_row = &mut out.data[i * other.m..(i + 1) * other.m];
                for j in 0..other.m {
                    out_row[j] += aik * orow[j];
                }
            }
        }
        out
    }

    /// Matrix–vector product.
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.m, x.len());
        let mut y = vec![0.0; self.n];
        for i in 0..self.n {
            let row = &self.data[i * self.m..(i + 1) * self.m];
            let mut acc = 0.0;
            for j in 0..self.m {
                acc += row[j] * x[j];
            }
            y[i] = acc;
        }
        y
    }

    pub fn add(&self, other: &MatD) -> MatD {
        assert_eq!((self.n, self.m), (other.n, other.m));
        MatD {
            n: self.n,
            m: self.m,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn sub(&self, other: &MatD) -> MatD {
        assert_eq!((self.n, self.m), (other.n, other.m));
        MatD {
            n: self.n,
            m: self.m,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> MatD {
        MatD { n: self.n, m: self.m, data: self.data.iter().map(|a| a * s).collect() }
    }

    pub fn trace(&self) -> f64 {
        assert_eq!(self.n, self.m);
        (0..self.n).map(|i| self[(i, i)]).sum()
    }

    pub fn frob(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, &x| m.max(x.abs()))
    }

    /// Symmetric eigendecomposition by cyclic Jacobi rotations.
    /// Returns `(eigenvalues, V)` with `self = V diag(λ) Vᵀ`
    /// (columns of `V` are eigenvectors).
    pub fn sym_eig(&self) -> (Vec<f64>, MatD) {
        assert_eq!(self.n, self.m, "sym_eig: square only");
        let n = self.n;
        let mut a = self.clone();
        // Enforce exact symmetry.
        for i in 0..n {
            for j in (i + 1)..n {
                let v = 0.5 * (a[(i, j)] + a[(j, i)]);
                a[(i, j)] = v;
                a[(j, i)] = v;
            }
        }
        let mut v = MatD::eye(n);
        let max_sweeps = 64;
        for _sweep in 0..max_sweeps {
            let mut off = 0.0;
            for i in 0..n {
                for j in (i + 1)..n {
                    off += a[(i, j)] * a[(i, j)];
                }
            }
            if off.sqrt() < 1e-14 * (1.0 + a.frob()) {
                break;
            }
            for p in 0..n {
                for q in (p + 1)..n {
                    let apq = a[(p, q)];
                    if apq.abs() < 1e-300 {
                        continue;
                    }
                    let app = a[(p, p)];
                    let aqq = a[(q, q)];
                    let theta = 0.5 * (aqq - app) / apq;
                    let t = theta.signum() / (theta.abs() + (1.0 + theta * theta).sqrt());
                    let c = 1.0 / (1.0 + t * t).sqrt();
                    let s = t * c;
                    // Apply the rotation J(p,q,θ): A <- JᵀAJ, V <- VJ.
                    for k in 0..n {
                        let akp = a[(k, p)];
                        let akq = a[(k, q)];
                        a[(k, p)] = c * akp - s * akq;
                        a[(k, q)] = s * akp + c * akq;
                    }
                    for k in 0..n {
                        let apk = a[(p, k)];
                        let aqk = a[(q, k)];
                        a[(p, k)] = c * apk - s * aqk;
                        a[(q, k)] = s * apk + c * aqk;
                    }
                    for k in 0..n {
                        let vkp = v[(k, p)];
                        let vkq = v[(k, q)];
                        v[(k, p)] = c * vkp - s * vkq;
                        v[(k, q)] = s * vkp + c * vkq;
                    }
                }
            }
        }
        let lam = (0..n).map(|i| a[(i, i)]).collect();
        (lam, v)
    }

    /// Principal square root of a symmetric PSD matrix via eigendecomposition
    /// (negative eigenvalues from numerical noise are clamped to zero).
    pub fn sqrtm_psd(&self) -> MatD {
        let (lam, v) = self.sym_eig();
        let sq: Vec<f64> = lam.iter().map(|&l| l.max(0.0).sqrt()).collect();
        v.matmul(&MatD::diag(&sq)).matmul(&v.transpose())
    }

    /// Cholesky factorisation (lower-triangular) of a symmetric PD matrix.
    pub fn cholesky(&self) -> Option<MatD> {
        assert_eq!(self.n, self.m);
        let n = self.n;
        let mut l = MatD::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut s = self[(i, j)];
                for k in 0..j {
                    s -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if s <= 0.0 {
                        return None;
                    }
                    l[(i, j)] = s.sqrt();
                } else {
                    l[(i, j)] = s / l[(j, j)];
                }
            }
        }
        Some(l)
    }
}

impl std::ops::Index<(usize, usize)> for MatD {
    type Output = f64;
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.m + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for MatD {
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.m + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> MatD {
        let mut a = MatD::zeros(n, n);
        for x in a.data.iter_mut() {
            *x = rng.normal();
        }
        let mut m = a.matmul(&a.transpose());
        for i in 0..n {
            m[(i, i)] += 0.5;
        }
        m
    }

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::seed_from(7);
        let a = random_spd(5, &mut rng);
        let i5 = MatD::eye(5);
        assert!(a.matmul(&i5).sub(&a).max_abs() < 1e-14);
        assert!(i5.matmul(&a).sub(&a).max_abs() < 1e-14);
    }

    #[test]
    fn sym_eig_reconstructs() {
        let mut rng = Rng::seed_from(11);
        for n in [2usize, 3, 8, 16] {
            let m = random_spd(n, &mut rng);
            let (lam, v) = m.sym_eig();
            let rec = v.matmul(&MatD::diag(&lam)).matmul(&v.transpose());
            assert!(
                rec.sub(&m).max_abs() < 1e-9 * (1.0 + m.max_abs()),
                "n={n}: reconstruction error {}",
                rec.sub(&m).max_abs()
            );
            // V orthogonal
            let vtv = v.transpose().matmul(&v);
            assert!(vtv.sub(&MatD::eye(n)).max_abs() < 1e-10, "n={n}: V not orthogonal");
            // all eigenvalues positive for SPD
            assert!(lam.iter().all(|&l| l > 0.0), "n={n}: non-positive eigenvalue");
        }
    }

    #[test]
    fn sqrtm_squares_back() {
        let mut rng = Rng::seed_from(13);
        for n in [2usize, 4, 12] {
            let m = random_spd(n, &mut rng);
            let r = m.sqrtm_psd();
            assert!(r.matmul(&r).sub(&m).max_abs() < 1e-9 * (1.0 + m.max_abs()));
        }
    }

    #[test]
    fn cholesky_reconstructs() {
        let mut rng = Rng::seed_from(17);
        let m = random_spd(6, &mut rng);
        let l = m.cholesky().expect("SPD must factor");
        assert!(l.matmul(&l.transpose()).sub(&m).max_abs() < 1e-10 * (1.0 + m.max_abs()));
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let m = MatD::from_rows(&[vec![1.0, 2.0], vec![2.0, 1.0]]); // eigenvalues 3, -1
        assert!(m.cholesky().is_none());
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::seed_from(19);
        let a = random_spd(5, &mut rng);
        let x: Vec<f64> = (0..5).map(|_| rng.normal()).collect();
        let xs = MatD { n: 5, m: 1, data: x.clone() };
        let via_mm = a.matmul(&xs).data;
        crate::math::assert_allclose(&a.matvec(&x), &via_mm, 1e-13, 1e-13, "matvec");
    }
}
