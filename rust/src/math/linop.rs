//! Structured linear operators on the diffusion state.
//!
//! Every coefficient matrix in the three diffusion models has block
//! structure that makes dense D×D algebra unnecessary:
//!
//! * VPSDE/DDPM: scalar multiples of `I_d` ([`LinOp::Scalar`]),
//! * CLD: `M ⊗ I_d` with `M ∈ R^{2×2}` over `u = [x; v]` ([`LinOp::Block2`]),
//! * BDM: diagonal per DCT frequency ([`LinOp::Diag`]).
//!
//! The Stage-I coefficient engine and the samplers are written once
//! against this enum; each variant stores O(1) or O(d) data instead of
//! O(D²), which is also what makes the coefficient tables cheap to cache.
//! State layout convention: for `Block2`, `u = [x(0..d), v(0..d)]`.

use std::sync::Arc;

use crate::math::mat2::Mat2;

/// A structured `D×D` linear operator.
#[derive(Clone, Debug)]
pub enum LinOp {
    /// `s · I_D`.
    Scalar(f64),
    /// `diag(v)`, one entry per state dimension.
    Diag(Arc<Vec<f64>>),
    /// `M ⊗ I_d` acting on `u = [x; v]` (CLD).
    Block2(Mat2),
}

impl LinOp {
    pub fn ident() -> LinOp {
        LinOp::Scalar(1.0)
    }

    pub fn zero() -> LinOp {
        LinOp::Scalar(0.0)
    }

    pub fn diag(v: Vec<f64>) -> LinOp {
        LinOp::Diag(Arc::new(v))
    }

    /// Apply to a state vector: `out = A u`. For `Block2` the state is
    /// `[x; v]` with `d = u.len()/2`.
    ///
    /// The three structure cases dispatch once per call into the chunked
    /// wide-lane kernels in [`crate::math::simd`]; every per-element
    /// operation is the same f64 expression as the historical scalar
    /// loops, so outputs are bit-identical (locked by a test below) while
    /// the inner loops vectorize. This is the per-row apply the sampler
    /// steps and the score oracle drive, so it is on the serving hot path.
    pub fn apply(&self, u: &[f64], out: &mut [f64]) {
        assert_eq!(u.len(), out.len());
        match self {
            LinOp::Scalar(s) => crate::math::simd::scale(*s, u, out),
            LinOp::Diag(d) => {
                assert_eq!(d.len(), u.len(), "Diag dim mismatch");
                crate::math::simd::mul(d, u, out);
            }
            LinOp::Block2(m) => {
                let d = u.len() / 2;
                assert_eq!(u.len(), 2 * d);
                let (x, v) = u.split_at(d);
                let (ox, ov) = out.split_at_mut(d);
                crate::math::simd::block2(m.a, m.b, m.c, m.d, x, v, ox, ov);
            }
        }
    }

    /// `out += A u` (fused multiply-accumulate form used in the sampler
    /// hot loop to avoid temporaries). Chunked like [`LinOp::apply`].
    pub fn apply_add(&self, u: &[f64], out: &mut [f64]) {
        match self {
            LinOp::Scalar(s) => crate::math::simd::axpy(*s, u, out),
            LinOp::Diag(d) => crate::math::simd::mul_add(d, u, out),
            LinOp::Block2(m) => {
                let d = u.len() / 2;
                let (x, v) = u.split_at(d);
                let (ox, ov) = out.split_at_mut(d);
                crate::math::simd::block2_add(m.a, m.b, m.c, m.d, x, v, ox, ov);
            }
        }
    }

    pub fn apply_vec(&self, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; u.len()];
        self.apply(u, &mut out);
        out
    }

    /// Operator composition `self · other` (matrix product).
    pub fn matmul(&self, other: &LinOp) -> LinOp {
        use LinOp::*;
        match (self, other) {
            (Scalar(a), Scalar(b)) => Scalar(a * b),
            (Scalar(a), Diag(d)) | (Diag(d), Scalar(a)) => {
                LinOp::diag(d.iter().map(|x| a * x).collect())
            }
            (Scalar(a), Block2(m)) | (Block2(m), Scalar(a)) => Block2(m.scale(*a)),
            (Diag(a), Diag(b)) => {
                assert_eq!(a.len(), b.len());
                LinOp::diag(a.iter().zip(b.iter()).map(|(x, y)| x * y).collect())
            }
            (Block2(a), Block2(b)) => Block2(*a * *b),
            _ => panic!("LinOp::matmul: incompatible structures {self:?} vs {other:?}"),
        }
    }

    pub fn add(&self, other: &LinOp) -> LinOp {
        use LinOp::*;
        match (self, other) {
            (Scalar(a), Scalar(b)) => Scalar(a + b),
            (Scalar(a), Diag(d)) | (Diag(d), Scalar(a)) => {
                LinOp::diag(d.iter().map(|x| a + x).collect())
            }
            (Scalar(a), Block2(m)) | (Block2(m), Scalar(a)) => Block2(*m + Mat2::scalar(*a)),
            (Diag(a), Diag(b)) => {
                assert_eq!(a.len(), b.len());
                LinOp::diag(a.iter().zip(b.iter()).map(|(x, y)| x + y).collect())
            }
            (Block2(a), Block2(b)) => Block2(*a + *b),
            _ => panic!("LinOp::add: incompatible structures"),
        }
    }

    pub fn sub(&self, other: &LinOp) -> LinOp {
        self.add(&other.scale(-1.0))
    }

    pub fn scale(&self, s: f64) -> LinOp {
        match self {
            LinOp::Scalar(a) => LinOp::Scalar(a * s),
            LinOp::Diag(d) => LinOp::diag(d.iter().map(|x| x * s).collect()),
            LinOp::Block2(m) => LinOp::Block2(m.scale(s)),
        }
    }

    pub fn transpose(&self) -> LinOp {
        match self {
            LinOp::Block2(m) => LinOp::Block2(m.transpose()),
            other => other.clone(),
        }
    }

    pub fn inv(&self) -> LinOp {
        match self {
            LinOp::Scalar(a) => {
                assert!(a.abs() > 1e-300, "LinOp::inv: zero scalar");
                LinOp::Scalar(1.0 / a)
            }
            LinOp::Diag(d) => LinOp::diag(
                d.iter()
                    .map(|x| {
                        assert!(x.abs() > 1e-300, "LinOp::inv: zero diagonal entry");
                        1.0 / x
                    })
                    .collect(),
            ),
            LinOp::Block2(m) => LinOp::Block2(m.inv()),
        }
    }

    /// Principal square root (symmetric-PSD semantics for `Block2`).
    pub fn sqrt_spd(&self) -> LinOp {
        match self {
            LinOp::Scalar(a) => LinOp::Scalar(a.max(0.0).sqrt()),
            LinOp::Diag(d) => LinOp::diag(d.iter().map(|x| x.max(0.0).sqrt()).collect()),
            LinOp::Block2(m) => LinOp::Block2(m.sqrtm_spd()),
        }
    }

    /// Cholesky factor (lower-triangular): the paper's `L_t` (App. C.2).
    /// For scalar/diag operators this equals the square root.
    pub fn cholesky(&self) -> LinOp {
        match self {
            LinOp::Block2(m) => LinOp::Block2(m.cholesky()),
            other => other.sqrt_spd(),
        }
    }

    /// Largest absolute entry (structure-aware) — used by tests/validators.
    pub fn max_abs(&self) -> f64 {
        match self {
            LinOp::Scalar(a) => a.abs(),
            LinOp::Diag(d) => d.iter().fold(0.0f64, |m, x| m.max(x.abs())),
            LinOp::Block2(m) => m.max_abs(),
        }
    }

    /// Structure-aware distance between two operators.
    pub fn dist(&self, other: &LinOp) -> f64 {
        self.sub(other).max_abs()
    }

    /// Trace of the operator acting on a `dim`-dimensional state.
    pub fn trace(&self, dim: usize) -> f64 {
        match self {
            LinOp::Scalar(s) => s * dim as f64,
            LinOp::Diag(d) => {
                assert_eq!(d.len(), dim);
                d.iter().sum()
            }
            LinOp::Block2(m) => m.trace() * (dim / 2) as f64,
        }
    }

    /// log|det| of the operator on a `dim`-dimensional state.
    pub fn logdet(&self, dim: usize) -> f64 {
        match self {
            LinOp::Scalar(s) => dim as f64 * s.abs().max(1e-300).ln(),
            LinOp::Diag(d) => d.iter().map(|x| x.abs().max(1e-300).ln()).sum(),
            LinOp::Block2(m) => (dim / 2) as f64 * m.det().abs().max(1e-300).ln(),
        }
    }

    /// JSON form for the Stage-I plan persistence format: a one-key
    /// object tagging the structure (`{"s": x}`, `{"d": [..]}`,
    /// `{"b2": [a,b,c,d]}`). Numbers print in Rust's shortest-roundtrip
    /// form, so [`LinOp::from_json`] reconstructs the exact bits.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut obj = std::collections::BTreeMap::new();
        match self {
            LinOp::Scalar(s) => {
                obj.insert("s".to_string(), Json::Num(*s));
            }
            LinOp::Diag(d) => {
                obj.insert(
                    "d".to_string(),
                    Json::Arr(d.iter().map(|&x| Json::Num(x)).collect()),
                );
            }
            LinOp::Block2(m) => {
                obj.insert(
                    "b2".to_string(),
                    Json::Arr(m.to_array().iter().map(|&x| Json::Num(x)).collect()),
                );
            }
        }
        Json::Obj(obj)
    }

    /// Inverse of [`LinOp::to_json`].
    pub fn from_json(j: &crate::util::json::Json) -> crate::Result<LinOp> {
        if let Some(s) = j.get("s") {
            return s
                .as_f64()
                .map(LinOp::Scalar)
                .ok_or_else(|| crate::Error::msg("LinOp: scalar not a number"));
        }
        if let Some(d) = j.get("d") {
            let v = d.as_f64_vec().ok_or_else(|| crate::Error::msg("LinOp: diag not numbers"))?;
            return Ok(LinOp::diag(v));
        }
        if let Some(b) = j.get("b2") {
            let v = b.as_f64_vec().ok_or_else(|| crate::Error::msg("LinOp: b2 not numbers"))?;
            if v.len() != 4 {
                return Err(crate::Error::msg("LinOp: b2 needs 4 entries"));
            }
            return Ok(LinOp::Block2(Mat2::new(v[0], v[1], v[2], v[3])));
        }
        Err(crate::Error::msg("LinOp: expected one of `s`, `d`, `b2`"))
    }

    /// Draw `z ~ N(0, A Aᵀ)` given this operator as the factor `A`,
    /// writing into `out` (used for injected sampler noise).
    pub fn sample_noise(&self, rng: &mut crate::math::rng::Rng, out: &mut [f64]) {
        match self {
            LinOp::Scalar(s) => {
                for o in out.iter_mut() {
                    *o = s * rng.normal();
                }
            }
            LinOp::Diag(d) => {
                assert_eq!(d.len(), out.len());
                for (o, &s) in out.iter_mut().zip(d.iter()) {
                    *o = s * rng.normal();
                }
            }
            LinOp::Block2(m) => {
                let d = out.len() / 2;
                let (ox, ov) = out.split_at_mut(d);
                for i in 0..d {
                    let z0 = rng.normal();
                    let z1 = rng.normal();
                    ox[i] = m.a * z0 + m.b * z1;
                    ov[i] = m.c * z0 + m.d * z1;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    #[test]
    fn scalar_apply_and_compose() {
        let a = LinOp::Scalar(2.0);
        let b = LinOp::Scalar(-0.5);
        let u = [1.0, 2.0, 3.0];
        assert_eq!(a.apply_vec(&u), vec![2.0, 4.0, 6.0]);
        assert_eq!(a.matmul(&b).apply_vec(&u), vec![-1.0, -2.0, -3.0]);
    }

    #[test]
    fn block2_matches_dense_kron() {
        // (M ⊗ I_2) on [x0,x1,v0,v1] must equal per-pair 2x2 action.
        let m = Mat2::new(1.0, 2.0, 3.0, 4.0);
        let op = LinOp::Block2(m);
        let u = [10.0, 20.0, 1.0, 2.0]; // x=(10,20), v=(1,2)
        let out = op.apply_vec(&u);
        // per pair i: (x_i', v_i') = M (x_i, v_i)
        assert_eq!(out, vec![10.0 + 2.0, 20.0 + 4.0, 30.0 + 4.0, 60.0 + 8.0]);
    }

    #[test]
    fn inverse_is_inverse() {
        let mut rng = Rng::seed_from(41);
        let ops = [
            LinOp::Scalar(1.7),
            LinOp::diag(vec![0.5, -2.0, 3.0, 1.0]),
            LinOp::Block2(Mat2::new(2.0, 0.3, -0.4, 1.5)),
        ];
        for op in &ops {
            let u: Vec<f64> = (0..4).map(|_| rng.normal()).collect();
            let v = op.inv().apply_vec(&op.apply_vec(&u));
            crate::math::assert_allclose(&v, &u, 1e-12, 1e-12, "inv roundtrip");
        }
    }

    #[test]
    fn sqrt_squares_back() {
        let ops = [
            LinOp::Scalar(4.0),
            LinOp::diag(vec![1.0, 9.0, 0.25]),
            LinOp::Block2(Mat2::new(2.0, 0.3, 0.3, 1.5)),
        ];
        for op in &ops {
            let r = op.sqrt_spd();
            assert!(r.matmul(&r).dist(op) < 1e-12);
        }
    }

    #[test]
    fn cholesky_factorizes() {
        let sig = LinOp::Block2(Mat2::new(1.3, 0.4, 0.4, 2.0));
        let l = sig.cholesky();
        assert!(l.matmul(&l.transpose()).dist(&sig) < 1e-12);
    }

    #[test]
    fn sample_noise_has_right_covariance() {
        let mut rng = Rng::seed_from(43);
        let m = Mat2::new(1.0, 0.0, 0.7, 0.5); // cov = L L^T = [[1, .7], [.7, .74]]
        let op = LinOp::Block2(m);
        let n = 100_000;
        let mut acc = [0.0f64; 3]; // xx, xv, vv
        let mut z = [0.0; 2];
        for _ in 0..n {
            op.sample_noise(&mut rng, &mut z);
            acc[0] += z[0] * z[0];
            acc[1] += z[0] * z[1];
            acc[2] += z[1] * z[1];
        }
        let nf = n as f64;
        assert!((acc[0] / nf - 1.0).abs() < 0.02);
        assert!((acc[1] / nf - 0.7).abs() < 0.02);
        assert!((acc[2] / nf - 0.74).abs() < 0.02);
    }

    #[test]
    fn json_round_trip_is_bit_exact() {
        let ops = [
            LinOp::Scalar(0.1 + 0.2), // a value with a non-terminating decimal
            LinOp::diag(vec![1.0 / 3.0, -2.5e-17, 4.0]),
            LinOp::Block2(Mat2::new(std::f64::consts::PI, -0.0, 1e-300, 7.0)),
        ];
        for op in &ops {
            let text = op.to_json().to_string_pretty();
            let back =
                LinOp::from_json(&crate::util::json::Json::parse(&text).unwrap()).unwrap();
            assert_eq!(back.sub(op).max_abs(), 0.0, "bits drifted through {text}");
        }
        assert!(LinOp::from_json(&crate::util::json::Json::Null).is_err());
    }

    #[test]
    fn apply_add_accumulates() {
        let op = LinOp::Scalar(3.0);
        let u = [1.0, 1.0];
        let mut out = vec![10.0, 20.0];
        op.apply_add(&u, &mut out);
        assert_eq!(out, vec![13.0, 23.0]);
    }

    /// Verbatim pre-vectorization apply/apply_add loops (PR 6): the
    /// scalar reference the chunked kernels must match bit-for-bit.
    fn reference_apply(op: &LinOp, u: &[f64], out: &mut [f64]) {
        match op {
            LinOp::Scalar(s) => {
                for (o, &x) in out.iter_mut().zip(u) {
                    *o = s * x;
                }
            }
            LinOp::Diag(d) => {
                for i in 0..u.len() {
                    out[i] = d[i] * u[i];
                }
            }
            LinOp::Block2(m) => {
                let d = u.len() / 2;
                let (x, v) = u.split_at(d);
                let (ox, ov) = out.split_at_mut(d);
                for i in 0..d {
                    ox[i] = m.a * x[i] + m.b * v[i];
                    ov[i] = m.c * x[i] + m.d * v[i];
                }
            }
        }
    }

    fn reference_apply_add(op: &LinOp, u: &[f64], out: &mut [f64]) {
        match op {
            LinOp::Scalar(s) => {
                for (o, &x) in out.iter_mut().zip(u) {
                    *o += s * x;
                }
            }
            LinOp::Diag(d) => {
                for i in 0..u.len() {
                    out[i] += d[i] * u[i];
                }
            }
            LinOp::Block2(m) => {
                let d = u.len() / 2;
                let (x, v) = u.split_at(d);
                let (ox, ov) = out.split_at_mut(d);
                for i in 0..d {
                    ox[i] += m.a * x[i] + m.b * v[i];
                    ov[i] += m.c * x[i] + m.d * v[i];
                }
            }
        }
    }

    #[test]
    fn chunked_apply_matches_scalar_reference_bitwise() {
        // Lengths off the 4-lane grid (6, 10, 1026) and on it (8, 64):
        // the chunked kernels must reproduce the historical scalar loops
        // exactly — this is what keeps every sampler plan, golden sample,
        // and persisted Stage-I table stable across the vectorization.
        let mut rng = Rng::seed_from(47);
        for n in [2usize, 6, 8, 10, 64, 1026] {
            let u: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let ops = [
                LinOp::Scalar(0.37),
                LinOp::diag((0..n).map(|_| rng.normal()).collect()),
                LinOp::Block2(Mat2::new(1.1, -0.2, 0.45, 0.9)),
            ];
            for op in &ops {
                let mut got = vec![0.0; n];
                let mut want = vec![0.0; n];
                op.apply(&u, &mut got);
                reference_apply(op, &u, &mut want);
                let bits = |x: &[f64]| x.iter().map(|v| v.to_bits()).collect::<Vec<u64>>();
                assert_eq!(bits(&got), bits(&want), "apply {op:?} at n={n}");

                let seed: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
                let mut got_acc = seed.clone();
                let mut want_acc = seed;
                op.apply_add(&u, &mut got_acc);
                reference_apply_add(op, &u, &mut want_acc);
                assert_eq!(bits(&got_acc), bits(&want_acc), "apply_add {op:?} at n={n}");
            }
        }
    }
}
