//! Dense 2×2 matrices with the full algebra the CLD coefficient engine
//! needs: products, inverses, matrix exponential (closed form), symmetric
//! square root, and Frobenius norms.
//!
//! CLD state is `u = (x, v) ∈ R^{2d}` and every coefficient matrix in the
//! paper (`F_t`, `G_tG_tᵀ`, `Σ_t`, `R_t`, `L_t`, `Ψ(t,s)`, `Ψ̂(t,s)`,
//! `P_st`, `C_ij`) is of the form `M ⊗ I_d` with `M ∈ R^{2×2}`
//! (paper Eq. 10 and App. C.3: "each of these coefficients corresponds to
//! a 2×2 matrix"). This module is therefore the whole linear-algebra cost
//! of CLD Stage-I preparation.

use std::ops::{Add, Mul, Neg, Sub};

/// Row-major 2×2 matrix.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Mat2 {
    pub a: f64, // (0,0)
    pub b: f64, // (0,1)
    pub c: f64, // (1,0)
    pub d: f64, // (1,1)
}

impl Mat2 {
    pub const ZERO: Mat2 = Mat2 { a: 0.0, b: 0.0, c: 0.0, d: 0.0 };
    pub const IDENT: Mat2 = Mat2 { a: 1.0, b: 0.0, c: 0.0, d: 1.0 };

    pub fn new(a: f64, b: f64, c: f64, d: f64) -> Self {
        Mat2 { a, b, c, d }
    }

    pub fn diag(x: f64, y: f64) -> Self {
        Mat2::new(x, 0.0, 0.0, y)
    }

    pub fn scalar(x: f64) -> Self {
        Mat2::diag(x, x)
    }

    pub fn det(&self) -> f64 {
        self.a * self.d - self.b * self.c
    }

    pub fn trace(&self) -> f64 {
        self.a + self.d
    }

    pub fn transpose(&self) -> Mat2 {
        Mat2::new(self.a, self.c, self.b, self.d)
    }

    pub fn inv(&self) -> Mat2 {
        let det = self.det();
        assert!(det.abs() > 1e-300, "Mat2::inv: singular matrix {self:?}");
        let s = 1.0 / det;
        Mat2::new(self.d * s, -self.b * s, -self.c * s, self.a * s)
    }

    pub fn scale(&self, s: f64) -> Mat2 {
        Mat2::new(self.a * s, self.b * s, self.c * s, self.d * s)
    }

    /// Apply to a column vector `(x, y)`.
    pub fn apply(&self, x: f64, y: f64) -> (f64, f64) {
        (self.a * x + self.b * y, self.c * x + self.d * y)
    }

    pub fn frob(&self) -> f64 {
        (self.a * self.a + self.b * self.b + self.c * self.c + self.d * self.d).sqrt()
    }

    pub fn max_abs(&self) -> f64 {
        self.a.abs().max(self.b.abs()).max(self.c.abs()).max(self.d.abs())
    }

    /// Matrix exponential, closed form via the Cayley–Hamilton / Putzer
    /// formula: with `m = tr/2`, `s² = m² − det` the eigenvalue spread,
    /// `exp(A) = e^m [ cosh(s)·I + sinh(s)/s · (A − m I) ]`
    /// (trig branch when `s²<0`, series limit when `s≈0`).
    pub fn expm(&self) -> Mat2 {
        let m = 0.5 * self.trace();
        let disc = m * m - self.det(); // s^2
        let dev = *self - Mat2::scalar(m);
        let (ch, shs) = if disc > 1e-24 {
            let s = disc.sqrt();
            (s.cosh(), s.sinh() / s)
        } else if disc < -1e-24 {
            let w = (-disc).sqrt();
            (w.cos(), w.sin() / w)
        } else {
            // cosh(s) -> 1 + s^2/2, sinh(s)/s -> 1 + s^2/6
            (1.0 + disc / 2.0, 1.0 + disc / 6.0)
        };
        (Mat2::scalar(ch) + dev.scale(shs)).scale(m.exp())
    }

    /// Principal square root of a symmetric positive-(semi)definite matrix:
    /// `sqrt(M) = (M + √det · I) / √(tr + 2√det)`.
    pub fn sqrtm_spd(&self) -> Mat2 {
        debug_assert!(
            (self.b - self.c).abs() <= 1e-9 * (1.0 + self.max_abs()),
            "sqrtm_spd: not symmetric: {self:?}"
        );
        let tau = self.det().max(0.0).sqrt();
        let denom = (self.trace() + 2.0 * tau).max(0.0).sqrt();
        if denom < 1e-300 {
            return Mat2::ZERO;
        }
        (*self + Mat2::scalar(tau)).scale(1.0 / denom)
    }

    /// Cholesky factor (lower triangular) of a symmetric PD matrix:
    /// the paper's `L_t` parameterization (App. C.2, Eq. 78).
    pub fn cholesky(&self) -> Mat2 {
        let l11 = self.a.max(0.0).sqrt();
        assert!(l11 > 0.0, "cholesky: Σ^xx must be positive, got {self:?}");
        let l21 = self.c / l11;
        let l22 = (self.d - l21 * l21).max(0.0).sqrt();
        Mat2::new(l11, 0.0, l21, l22)
    }

    /// Symmetrize: (M + Mᵀ)/2 — used to fight drift in Lyapunov ODE solves.
    pub fn sym(&self) -> Mat2 {
        let off = 0.5 * (self.b + self.c);
        Mat2::new(self.a, off, off, self.d)
    }

    pub fn to_array(&self) -> [f64; 4] {
        [self.a, self.b, self.c, self.d]
    }

    pub fn from_array(v: [f64; 4]) -> Mat2 {
        Mat2::new(v[0], v[1], v[2], v[3])
    }
}

impl Add for Mat2 {
    type Output = Mat2;
    fn add(self, o: Mat2) -> Mat2 {
        Mat2::new(self.a + o.a, self.b + o.b, self.c + o.c, self.d + o.d)
    }
}

impl Sub for Mat2 {
    type Output = Mat2;
    fn sub(self, o: Mat2) -> Mat2 {
        Mat2::new(self.a - o.a, self.b - o.b, self.c - o.c, self.d - o.d)
    }
}

impl Mul for Mat2 {
    type Output = Mat2;
    fn mul(self, o: Mat2) -> Mat2 {
        Mat2::new(
            self.a * o.a + self.b * o.c,
            self.a * o.b + self.b * o.d,
            self.c * o.a + self.d * o.c,
            self.c * o.b + self.d * o.d,
        )
    }
}

impl Neg for Mat2 {
    type Output = Mat2;
    fn neg(self) -> Mat2 {
        self.scale(-1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    fn assert_mat_close(x: Mat2, y: Mat2, tol: f64, what: &str) {
        assert!((x - y).max_abs() < tol, "{what}: {x:?} vs {y:?}");
    }

    #[test]
    fn inverse_roundtrip() {
        let m = Mat2::new(2.0, 1.0, -0.5, 3.0);
        assert_mat_close(m * m.inv(), Mat2::IDENT, 1e-12, "m*m^-1");
        assert_mat_close(m.inv() * m, Mat2::IDENT, 1e-12, "m^-1*m");
    }

    #[test]
    fn expm_diagonal() {
        let m = Mat2::diag(0.3, -1.2).expm();
        assert!(close(m.a, 0.3f64.exp(), 1e-12, 0.0));
        assert!(close(m.d, (-1.2f64).exp(), 1e-12, 0.0));
        assert_eq!(m.b, 0.0);
    }

    #[test]
    fn expm_rotation() {
        // A = [[0, -w], [w, 0]] -> exp(A) = rotation by w.
        let w: f64 = 0.7;
        let m = Mat2::new(0.0, -w, w, 0.0).expm();
        assert_mat_close(m, Mat2::new(w.cos(), -w.sin(), w.sin(), w.cos()), 1e-12, "rot");
    }

    #[test]
    fn expm_nilpotent_limit() {
        // A = [[0, 1], [0, 0]] has s = 0; exp(A) = I + A.
        let m = Mat2::new(0.0, 1.0, 0.0, 0.0).expm();
        assert_mat_close(m, Mat2::new(1.0, 1.0, 0.0, 1.0), 1e-10, "nilpotent");
    }

    #[test]
    fn expm_matches_series() {
        // Dense matrix vs 30-term Taylor series.
        let a = Mat2::new(0.4, -0.3, 0.9, -0.2);
        let mut acc = Mat2::IDENT;
        let mut term = Mat2::IDENT;
        for k in 1..30 {
            term = (term * a).scale(1.0 / k as f64);
            acc = acc + term;
        }
        assert_mat_close(a.expm(), acc, 1e-12, "series");
    }

    #[test]
    fn sqrtm_spd_squares_back() {
        let m = Mat2::new(2.0, 0.3, 0.3, 1.5);
        let r = m.sqrtm_spd();
        assert_mat_close(r * r, m, 1e-12, "sqrtm^2");
    }

    #[test]
    fn sqrtm_of_singular() {
        // rank-1 PSD: [[1, 1], [1, 1]].
        let m = Mat2::new(1.0, 1.0, 1.0, 1.0);
        let r = m.sqrtm_spd();
        assert_mat_close(r * r, m, 1e-12, "singular sqrtm");
    }

    #[test]
    fn cholesky_matches_paper_form() {
        // Eq. 78: L = [[sqrt(Sxx), 0], [Sxv/sqrt(Sxx), sqrt((Sxx*Svv - Sxv^2)/Sxx)]].
        let (sxx, sxv, svv) = (1.7, 0.4, 2.1);
        let m = Mat2::new(sxx, sxv, sxv, svv);
        let l = m.cholesky();
        assert!(close(l.a, sxx.sqrt(), 1e-14, 0.0));
        assert!(close(l.c, sxv / sxx.sqrt(), 1e-14, 0.0));
        assert!(close(l.d, ((sxx * svv - sxv * sxv) / sxx).sqrt(), 1e-14, 0.0));
        assert_mat_close(l * l.transpose(), m, 1e-12, "LL^T");
    }

    #[test]
    fn expm_group_property() {
        // exp(A)·exp(A) = exp(2A) for any A (same matrix commutes with itself).
        let a = Mat2::new(0.1, 0.5, -0.4, 0.2);
        assert_mat_close(a.expm() * a.expm(), a.scale(2.0).expm(), 1e-12, "group");
    }
}
