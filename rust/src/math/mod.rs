//! Numerical substrate: everything the coefficient engine, samplers and
//! metrics need, implemented on `std` only (the build environment is
//! offline; see DESIGN.md §7).

pub mod mat2;
pub mod linalg;
pub mod linop;
pub mod ode;
pub mod quad;
pub mod interp;
pub mod rng;
pub mod stats;
pub mod dct;
pub mod prop;
pub mod simd;

pub use mat2::Mat2;
pub use linalg::MatD;
pub use linop::LinOp;
pub use rng::Rng;

/// Relative/absolute closeness check used across tests.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> bool {
    (a - b).abs() <= atol + rtol * b.abs().max(a.abs())
}

/// Assert two slices are element-wise close; panics with context otherwise.
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        assert!(
            close(x, y, rtol, atol),
            "{what}: element {i} differs: {x} vs {y} (rtol={rtol}, atol={atol})"
        );
    }
}
