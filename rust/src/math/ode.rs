//! ODE integrators used throughout:
//!
//! * Fixed-step classical RK4 for Stage-I coefficient ODEs (the paper
//!   uses "RK4 with a step size 1e-6" for `R_t`/`Ψ̂` — App. C.3 Type I);
//!   we expose the step size so the coefficient cache can trade accuracy
//!   for preparation time.
//! * Adaptive RK45 (Dormand–Prince) with NFE accounting for the paper's
//!   "Prob.Flow, RK45" baseline (Table 3: the tolerance is tuned so the
//!   real NFE lands near the target).

/// Right-hand side `f(t, y) -> dy/dt` over a flat state vector.
pub trait OdeRhs {
    fn eval(&mut self, t: f64, y: &[f64], dy: &mut [f64]);
}

impl<F: FnMut(f64, &[f64], &mut [f64])> OdeRhs for F {
    fn eval(&mut self, t: f64, y: &[f64], dy: &mut [f64]) {
        self(t, y, dy)
    }
}

/// One classical RK4 step from `t` with step `h` (may be negative for
/// reverse-time integration), in place.
pub fn rk4_step<R: OdeRhs>(rhs: &mut R, t: f64, h: f64, y: &mut [f64], scratch: &mut Rk4Scratch) {
    let n = y.len();
    scratch.ensure(n);
    let Rk4Scratch { k1, k2, k3, k4, tmp } = scratch;
    rhs.eval(t, y, k1);
    for i in 0..n {
        tmp[i] = y[i] + 0.5 * h * k1[i];
    }
    rhs.eval(t + 0.5 * h, tmp, k2);
    for i in 0..n {
        tmp[i] = y[i] + 0.5 * h * k2[i];
    }
    rhs.eval(t + 0.5 * h, tmp, k3);
    for i in 0..n {
        tmp[i] = y[i] + h * k3[i];
    }
    rhs.eval(t + h, tmp, k4);
    for i in 0..n {
        y[i] += h / 6.0 * (k1[i] + 2.0 * k2[i] + 2.0 * k3[i] + k4[i]);
    }
}

/// Reusable scratch buffers for `rk4_step` (hot path: no allocation).
#[derive(Default)]
pub struct Rk4Scratch {
    k1: Vec<f64>,
    k2: Vec<f64>,
    k3: Vec<f64>,
    k4: Vec<f64>,
    tmp: Vec<f64>,
}

impl Rk4Scratch {
    fn ensure(&mut self, n: usize) {
        if self.k1.len() != n {
            self.k1 = vec![0.0; n];
            self.k2 = vec![0.0; n];
            self.k3 = vec![0.0; n];
            self.k4 = vec![0.0; n];
            self.tmp = vec![0.0; n];
        }
    }
}

/// Integrate from `t0` to `t1` with `nsteps` RK4 steps, in place.
pub fn rk4_integrate<R: OdeRhs>(rhs: &mut R, t0: f64, t1: f64, nsteps: usize, y: &mut [f64]) {
    assert!(nsteps > 0);
    let h = (t1 - t0) / nsteps as f64;
    let mut scratch = Rk4Scratch::default();
    let mut t = t0;
    for _ in 0..nsteps {
        rk4_step(rhs, t, h, y, &mut scratch);
        t += h;
    }
}

/// Result of an adaptive RK45 solve.
pub struct Rk45Result {
    /// Number of RHS evaluations (the paper's "NFE" for the RK45 baseline).
    pub nfe: usize,
    /// Number of accepted steps.
    pub accepted: usize,
    /// Number of rejected steps.
    pub rejected: usize,
}

/// Dormand–Prince 5(4) adaptive integrator from `t0` to `t1` (either
/// direction), controlling the per-step local error against
/// `atol + rtol·|y|`. State updated in place.
pub fn rk45_integrate<R: OdeRhs>(
    rhs: &mut R,
    t0: f64,
    t1: f64,
    rtol: f64,
    atol: f64,
    y: &mut [f64],
) -> Rk45Result {
    // Dormand–Prince coefficients.
    const A: [[f64; 6]; 6] = [
        [1.0 / 5.0, 0.0, 0.0, 0.0, 0.0, 0.0],
        [3.0 / 40.0, 9.0 / 40.0, 0.0, 0.0, 0.0, 0.0],
        [44.0 / 45.0, -56.0 / 15.0, 32.0 / 9.0, 0.0, 0.0, 0.0],
        [19372.0 / 6561.0, -25360.0 / 2187.0, 64448.0 / 6561.0, -212.0 / 729.0, 0.0, 0.0],
        [9017.0 / 3168.0, -355.0 / 33.0, 46732.0 / 5247.0, 49.0 / 176.0, -5103.0 / 18656.0, 0.0],
        [35.0 / 384.0, 0.0, 500.0 / 1113.0, 125.0 / 192.0, -2187.0 / 6784.0, 11.0 / 84.0],
    ];
    const C: [f64; 6] = [1.0 / 5.0, 3.0 / 10.0, 4.0 / 5.0, 8.0 / 9.0, 1.0, 1.0];
    // 5th-order solution weights = last row of A; 4th-order (embedded):
    const B4: [f64; 7] = [
        5179.0 / 57600.0,
        0.0,
        7571.0 / 16695.0,
        393.0 / 640.0,
        -92097.0 / 339200.0,
        187.0 / 2100.0,
        1.0 / 40.0,
    ];

    let n = y.len();
    let dir = if t1 >= t0 { 1.0 } else { -1.0 };
    let total = (t1 - t0).abs();
    let mut t = t0;
    let mut h = dir * (total / 100.0).max(1e-12);
    let mut k: Vec<Vec<f64>> = (0..7).map(|_| vec![0.0; n]).collect();
    let mut ytmp = vec![0.0; n];
    let mut res = Rk45Result { nfe: 0, accepted: 0, rejected: 0 };

    rhs.eval(t, y, &mut k[0]);
    res.nfe += 1;

    let max_iter = 100_000;
    for _ in 0..max_iter {
        if (t - t1).abs() < 1e-14 || (t1 - t) * dir <= 0.0 {
            break;
        }
        if ((t + h) - t1) * dir > 0.0 {
            h = t1 - t;
        }
        // Stages 2..7.
        for s in 0..6 {
            for i in 0..n {
                let mut acc = 0.0;
                for (j, kj) in k.iter().enumerate().take(s + 1) {
                    acc += A[s][j] * kj[i];
                }
                ytmp[i] = y[i] + h * acc;
            }
            rhs.eval(t + C[s] * h, &ytmp, &mut k[s + 1]);
            res.nfe += 1;
        }
        // 5th order update lives in k-stage combination of row A[5] plus k7
        // (FSAL: y5 uses A[5] over k1..k6, error uses B4 over k1..k7).
        let mut err: f64 = 0.0;
        for i in 0..n {
            let mut y5 = 0.0;
            for j in 0..6 {
                y5 += A[5][j] * k[j][i];
            }
            let y5 = y[i] + h * y5;
            let mut y4 = 0.0;
            for (j, kj) in k.iter().enumerate() {
                y4 += B4[j] * kj[i];
            }
            let y4 = y[i] + h * y4;
            let sc = atol + rtol * y[i].abs().max(y5.abs());
            let e = (y5 - y4) / sc;
            err += e * e;
            ytmp[i] = y5;
        }
        let err = (err / n as f64).sqrt();
        if err <= 1.0 {
            t += h;
            y.copy_from_slice(&ytmp);
            k.swap(0, 6); // FSAL: k7 becomes k1 of the next step
            res.accepted += 1;
        } else {
            res.rejected += 1;
        }
        let fac = (0.9 * err.powf(-0.2)).clamp(0.2, 5.0);
        h *= fac;
        if h.abs() < 1e-14 * total.max(1.0) {
            h = dir * 1e-14 * total.max(1.0);
        }
        if !err.is_finite() {
            // bail out: halve aggressively
            h *= 0.1;
        }
    }
    res
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    #[test]
    fn rk4_exponential_decay() {
        let mut y = vec![1.0];
        let mut f = |_t: f64, y: &[f64], dy: &mut [f64]| dy[0] = -y[0];
        rk4_integrate(&mut f, 0.0, 1.0, 100, &mut y);
        assert!(close(y[0], (-1.0f64).exp(), 1e-9, 0.0), "{}", y[0]);
    }

    #[test]
    fn rk4_reverse_time() {
        // Integrate forward then back; should return to start.
        let mut y = vec![0.3, -0.7];
        let f = |t: f64, y: &[f64], dy: &mut [f64]| {
            dy[0] = y[1] + t;
            dy[1] = -y[0];
        };
        let y0 = y.clone();
        rk4_integrate(&mut f.clone(), 0.0, 2.0, 400, &mut y);
        rk4_integrate(&mut f.clone(), 2.0, 0.0, 400, &mut y);
        crate::math::assert_allclose(&y, &y0, 1e-8, 1e-10, "roundtrip");
    }

    #[test]
    fn rk4_order_four() {
        // Error should shrink ~16x when steps double.
        let f = |t: f64, _y: &[f64], dy: &mut [f64]| dy[0] = (3.0 * t).sin();
        let exact = (1.0 - (3.0f64).cos()) / 3.0;
        let run = |n: usize| {
            let mut y = vec![0.0];
            rk4_integrate(&mut f.clone(), 0.0, 1.0, n, &mut y);
            (y[0] - exact).abs()
        };
        let e1 = run(20);
        let e2 = run(40);
        assert!(e1 / e2 > 12.0, "order too low: {} -> {}", e1, e2);
    }

    #[test]
    fn rk45_harmonic_oscillator() {
        let mut y = vec![1.0, 0.0];
        let res = rk45_integrate(
            &mut |_t: f64, y: &[f64], dy: &mut [f64]| {
                dy[0] = y[1];
                dy[1] = -y[0];
            },
            0.0,
            std::f64::consts::TAU,
            1e-9,
            1e-12,
            &mut y,
        );
        assert!(close(y[0], 1.0, 0.0, 1e-6), "{}", y[0]);
        assert!(close(y[1], 0.0, 0.0, 1e-6), "{}", y[1]);
        assert!(res.nfe > 10 && res.nfe < 10_000, "nfe={}", res.nfe);
    }

    #[test]
    fn rk45_nfe_scales_with_tolerance() {
        let run = |rtol: f64| {
            let mut y = vec![1.0];
            rk45_integrate(
                &mut |t: f64, y: &[f64], dy: &mut [f64]| dy[0] = -y[0] * (5.0 * t).cos() * 3.0,
                0.0,
                4.0,
                rtol,
                rtol * 1e-2,
                &mut y,
            )
            .nfe
        };
        assert!(run(1e-10) > run(1e-3), "tighter tolerance must cost more NFE");
    }

    #[test]
    fn rk45_reverse_direction() {
        let mut y = vec![2.0];
        rk45_integrate(
            &mut |_t: f64, y: &[f64], dy: &mut [f64]| dy[0] = y[0],
            1.0,
            0.0,
            1e-10,
            1e-12,
            &mut y,
        );
        assert!(close(y[0], 2.0 * (-1.0f64).exp(), 1e-7, 0.0), "{}", y[0]);
    }
}
