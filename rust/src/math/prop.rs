//! Minimal in-repo property-testing harness (the offline build has no
//! `proptest`). Seeded generators + many random cases + a failure report
//! that includes the case index and seed so any failure replays
//! deterministically with `PROP_SEED=<seed> PROP_CASE=<i>`.

use crate::math::rng::Rng;

/// Number of cases per property (override with env `PROP_CASES`).
pub fn default_cases() -> usize {
    std::env::var("PROP_CASES").ok().and_then(|s| s.parse().ok()).unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("PROP_SEED").ok().and_then(|s| s.parse().ok()).unwrap_or(0xD1FF_05E5)
}

/// Run a property: `gen` builds a random case, `check` returns
/// `Err(message)` on violation. Panics with replay info on failure.
pub fn check<T: std::fmt::Debug>(
    name: &str,
    gen: impl Fn(&mut Rng) -> T,
    check: impl Fn(&T) -> Result<(), String>,
) {
    let seed = base_seed();
    let only: Option<usize> = std::env::var("PROP_CASE").ok().and_then(|s| s.parse().ok());
    let cases = default_cases();
    for i in 0..cases {
        if let Some(c) = only {
            if c != i {
                continue;
            }
        }
        let mut rng = Rng::seed_from(seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let case = gen(&mut rng);
        if let Err(msg) = check(&case) {
            panic!(
                "property '{name}' failed at case {i}/{cases}: {msg}\n\
                 case: {case:?}\n\
                 replay with PROP_SEED={seed} PROP_CASE={i}"
            );
        }
    }
}

/// Common generators.
pub mod gen {
    use super::*;
    use crate::math::mat2::Mat2;

    pub fn f64_in(rng: &mut Rng, lo: f64, hi: f64) -> f64 {
        rng.uniform_in(lo, hi)
    }

    /// A well-conditioned random 2×2 matrix.
    pub fn mat2(rng: &mut Rng) -> Mat2 {
        loop {
            let m = Mat2::new(rng.normal(), rng.normal(), rng.normal(), rng.normal());
            if m.det().abs() > 0.05 && m.max_abs() < 4.0 {
                return m;
            }
        }
    }

    /// A random SPD 2×2 matrix with eigenvalues in [0.1, ~5].
    pub fn spd2(rng: &mut Rng) -> Mat2 {
        let a = mat2(rng);
        a * a.transpose() + Mat2::scalar(0.1)
    }

    pub fn vecf(rng: &mut Rng, n: usize, scale: f64) -> Vec<f64> {
        (0..n).map(|_| scale * rng.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::mat2::Mat2;

    #[test]
    fn prop_mat2_inverse() {
        check(
            "mat2 inverse roundtrip",
            gen::mat2,
            |m| {
                let err = (*m * m.inv() - Mat2::IDENT).max_abs();
                if err < 1e-9 {
                    Ok(())
                } else {
                    Err(format!("err={err}"))
                }
            },
        );
    }

    #[test]
    fn prop_spd_sqrtm() {
        check("spd sqrtm squares back", gen::spd2, |m| {
            let r = m.sqrtm_spd();
            let err = (r * r - *m).max_abs();
            if err < 1e-9 * (1.0 + m.max_abs()) {
                Ok(())
            } else {
                Err(format!("err={err}"))
            }
        });
    }

    #[test]
    fn prop_expm_inverse_is_expm_neg() {
        check("expm(A)^-1 = expm(-A)", gen::mat2, |m| {
            let err = (m.expm().inv() - (-*m).expm()).max_abs();
            if err < 1e-8 {
                Ok(())
            } else {
                Err(format!("err={err}"))
            }
        });
    }
}
