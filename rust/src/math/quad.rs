//! Numerical quadrature for the Stage-I "Type II" definite integrals
//! (App. C.3): the exponential-integrator coefficients
//! `C_ij = ∫ ½ Ψ(t_{i-1},τ) G_τG_τᵀ R_τ^{-T} ℓ_j(τ) dτ`.
//!
//! Gauss–Legendre is the default (the integrands are smooth in τ);
//! composite Simpson is kept as a cross-check used by the tests and the
//! plan validator.

/// Gauss–Legendre nodes and weights on [-1, 1], computed by Newton
/// iteration on the Legendre polynomial (standard Golub–Welsch-free
/// construction; accurate to ~1e-15 for n ≤ 128).
pub fn gauss_legendre(n: usize) -> (Vec<f64>, Vec<f64>) {
    assert!(n >= 1);
    let mut nodes = vec![0.0; n];
    let mut weights = vec![0.0; n];
    let m = n.div_ceil(2);
    for i in 0..m {
        // Initial guess (Chebyshev-like).
        let mut x = (std::f64::consts::PI * (i as f64 + 0.75) / (n as f64 + 0.5)).cos();
        let mut pp = 0.0;
        for _ in 0..100 {
            // Evaluate P_n(x) and P'_n(x) by recurrence.
            let mut p0 = 1.0;
            let mut p1 = x;
            for k in 2..=n {
                let kf = k as f64;
                let p2 = ((2.0 * kf - 1.0) * x * p1 - (kf - 1.0) * p0) / kf;
                p0 = p1;
                p1 = p2;
            }
            // p1 = P_n, p0 = P_{n-1}
            pp = n as f64 * (x * p1 - p0) / (x * x - 1.0);
            let dx = p1 / pp;
            x -= dx;
            if dx.abs() < 1e-16 {
                break;
            }
        }
        nodes[i] = -x;
        nodes[n - 1 - i] = x;
        let w = 2.0 / ((1.0 - x * x) * pp * pp);
        weights[i] = w;
        weights[n - 1 - i] = w;
    }
    if n % 2 == 1 {
        nodes[n / 2] = 0.0;
    }
    (nodes, weights)
}

/// ∫_a^b f(τ) dτ with `n`-point Gauss–Legendre. Works for a > b
/// (orientation carried by the affine map), which is exactly how the
/// reverse-time coefficients `∫_{t_i}^{t_{i-1}}` are evaluated.
pub fn integrate_gl<F: FnMut(f64) -> f64>(mut f: F, a: f64, b: f64, n: usize) -> f64 {
    let (nodes, weights) = gauss_legendre(n);
    let half = 0.5 * (b - a);
    let mid = 0.5 * (a + b);
    let mut acc = 0.0;
    for (x, w) in nodes.iter().zip(weights.iter()) {
        acc += w * f(mid + half * x);
    }
    acc * half
}

/// Vector-valued Gauss–Legendre: integrates `f: τ -> R^k` into `out`.
pub fn integrate_gl_vec<F: FnMut(f64, &mut [f64])>(
    mut f: F,
    a: f64,
    b: f64,
    n: usize,
    out: &mut [f64],
) {
    let (nodes, weights) = gauss_legendre(n);
    let half = 0.5 * (b - a);
    let mid = 0.5 * (a + b);
    out.iter_mut().for_each(|x| *x = 0.0);
    let mut buf = vec![0.0; out.len()];
    for (x, w) in nodes.iter().zip(weights.iter()) {
        f(mid + half * x, &mut buf);
        for (o, v) in out.iter_mut().zip(buf.iter()) {
            *o += w * v;
        }
    }
    for o in out.iter_mut() {
        *o *= half;
    }
}

/// Composite Simpson's rule with `n` (even) subintervals — the slow,
/// simple cross-check for Gauss–Legendre.
pub fn integrate_simpson<F: FnMut(f64) -> f64>(mut f: F, a: f64, b: f64, n: usize) -> f64 {
    let n = if n % 2 == 0 { n } else { n + 1 };
    let h = (b - a) / n as f64;
    let mut acc = f(a) + f(b);
    for i in 1..n {
        let c = if i % 2 == 1 { 4.0 } else { 2.0 };
        acc += c * f(a + i as f64 * h);
    }
    acc * h / 3.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::close;

    #[test]
    fn gl_nodes_symmetric_and_weights_sum_to_two() {
        for n in [1usize, 2, 3, 8, 16, 32, 64] {
            let (x, w) = gauss_legendre(n);
            let wsum: f64 = w.iter().sum();
            assert!(close(wsum, 2.0, 1e-13, 0.0), "n={n} wsum={wsum}");
            for i in 0..n {
                assert!(close(x[i], -x[n - 1 - i], 0.0, 1e-13), "n={n} not symmetric");
            }
        }
    }

    #[test]
    fn gl_exact_for_polynomials() {
        // n-point GL is exact for degree 2n-1.
        let n = 5;
        let f = |x: f64| 3.0 * x.powi(9) - x.powi(8) + 2.0 * x.powi(3) - x + 4.0;
        // exact integral over [-1,1]: odd terms vanish; -x^8: -2/9; +4: 8.
        let exact = -2.0 / 9.0 + 8.0;
        assert!(close(integrate_gl(f, -1.0, 1.0, n), exact, 1e-13, 0.0));
    }

    #[test]
    fn gl_matches_simpson_on_smooth() {
        let f = |x: f64| (2.0 * x).sin() * (-x).exp();
        let g = integrate_gl(f, 0.2, 1.7, 32);
        let s = integrate_simpson(f, 0.2, 1.7, 20_000);
        assert!(close(g, s, 1e-10, 1e-12), "{g} vs {s}");
    }

    #[test]
    fn gl_reversed_limits_flip_sign() {
        let f = |x: f64| x * x + 1.0;
        let a = integrate_gl(f, 0.0, 2.0, 16);
        let b = integrate_gl(f, 2.0, 0.0, 16);
        assert!(close(a, -b, 1e-13, 0.0));
    }

    #[test]
    fn gl_vec_matches_scalar() {
        let mut out = [0.0; 2];
        integrate_gl_vec(
            |t, o: &mut [f64]| {
                o[0] = t.cos();
                o[1] = t * t;
            },
            0.0,
            1.0,
            24,
            &mut out,
        );
        assert!(close(out[0], 1.0f64.sin(), 1e-12, 0.0));
        assert!(close(out[1], 1.0 / 3.0, 1e-12, 0.0));
    }
}
