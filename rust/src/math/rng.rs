//! Deterministic, seedable RNG for all stochastic samplers and workload
//! generators: xoshiro256++ seeded through splitmix64, Gaussian variates
//! via the polar Box–Muller method. Hand-rolled because the offline build
//! has no `rand` crate; determinism across runs is a feature for the
//! experiment harnesses (every table in EXPERIMENTS.md is reproducible
//! bit-for-bit from its seed).

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    gauss_cache: Option<f64>,
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed from a single u64 (expanded by splitmix64 as recommended by
    /// the xoshiro authors).
    pub fn seed_from(seed: u64) -> Self {
        let mut sm = seed;
        let s =
            [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)];
        Rng { s, gauss_cache: None }
    }

    /// Derive an independent stream (for per-request / per-thread RNGs).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::seed_from(self.next_u64() ^ stream.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = (s[0].wrapping_add(s[3])).rotate_left(23).wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> [0,1) double.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via polar Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(v) = self.gauss_cache.take() {
            return v;
        }
        loop {
            let u = 2.0 * self.uniform() - 1.0;
            let v = 2.0 * self.uniform() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_cache = Some(v * f);
                return u * f;
            }
        }
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.normal();
        }
    }

    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v);
        v
    }

    /// Sample an index from unnormalised weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.uniform() * total;
        for (i, &w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Exponential variate with the given rate (for Poisson arrivals in
    /// the serving workload generator).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.uniform().max(1e-300).ln().max(f64::MIN) / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Rng::seed_from(42);
        let mut b = Rng::seed_from(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed_from(1);
        let mut b = Rng::seed_from(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same == 0);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut rng = Rng::seed_from(3);
        let n = 100_000;
        let mut mean = 0.0;
        for _ in 0..n {
            let u = rng.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Rng::seed_from(4);
        let n = 200_000;
        let (mut m, mut m2, mut m4) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.normal();
            m += x;
            m2 += x * x;
            m4 += x * x * x * x;
        }
        let nf = n as f64;
        assert!((m / nf).abs() < 0.01);
        assert!((m2 / nf - 1.0).abs() < 0.02);
        assert!((m4 / nf - 3.0).abs() < 0.1, "kurtosis {}", m4 / nf);
    }

    #[test]
    fn categorical_frequencies() {
        let mut rng = Rng::seed_from(5);
        let w = [1.0, 3.0, 6.0];
        let mut counts = [0usize; 3];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.categorical(&w)] += 1;
        }
        for i in 0..3 {
            let p = counts[i] as f64 / n as f64;
            let expect = w[i] / 10.0;
            assert!((p - expect).abs() < 0.01, "i={i} p={p} expect={expect}");
        }
    }

    #[test]
    fn fork_streams_are_independent_ish() {
        let mut root = Rng::seed_from(6);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
