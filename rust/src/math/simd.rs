//! Chunked wide-lane f64 kernels for the serving hot loops.
//!
//! The inner loops of [`crate::score::oracle::GmmOracle::eps_batch`], the
//! [`crate::math::dct::Dct2`] separable passes, and the
//! [`crate::math::linop::LinOp`] applies are all flat fixed-stride f64
//! loops. The compiler autovectorizes most of them, but not reliably:
//! iterator adaptors with bounds checks, or loops whose trip count the
//! optimizer cannot see, fall back to scalar code. The kernels here make
//! the wide-lane shape explicit — `chunks_exact(LANES)` bodies with four
//! independent element operations per iteration (an `f64x4` in spirit,
//! spelled in scalar Rust so the offline build needs no new deps) plus a
//! scalar remainder loop — so every call site gets SIMD lanes whether or
//! not the autovectorizer would have found them.
//!
//! ## Bit-identity policy
//!
//! Elementwise kernels (`sub`, `mul`, `scale`, `axpy`, `block2*`) perform
//! exactly the same f64 operation per element as the scalar loops they
//! replace, in any chunking — results are bit-identical by construction,
//! and the sampler parity suite enforces it.
//!
//! Reductions are different: a 4-accumulator sum reassociates f64
//! addition and changes bits. The default f64 sampler path is pinned to
//! bit-identity (every golden and parity test in the repo), so [`sum_sq`]
//! keeps strict left-to-right order. The reassociating variant is
//! available as [`sum_sq_blocked`] for tolerance-checked consumers; using
//! it anywhere on the default sampler path requires explicitly re-locking
//! the goldens, never silently absorbing the change.

/// Lane width the chunked kernels unroll to. Four f64s = one AVX2
/// register; on narrower ISAs the compiler splits the chunk body.
pub const LANES: usize = 4;

/// `out[i] = a[i] - b[i]`.
#[inline]
pub fn sub(a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for ((x, y), o) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        o[0] = x[0] - y[0];
        o[1] = x[1] - y[1];
        o[2] = x[2] - y[2];
        o[3] = x[3] - y[3];
    }
    for ((x, y), o) in ac.remainder().iter().zip(bc.remainder()).zip(oc.into_remainder()) {
        *o = x - y;
    }
}

/// `out[i] = a[i] * b[i]`.
#[inline]
pub fn mul(a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for ((x, y), o) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        o[0] = x[0] * y[0];
        o[1] = x[1] * y[1];
        o[2] = x[2] * y[2];
        o[3] = x[3] * y[3];
    }
    for ((x, y), o) in ac.remainder().iter().zip(bc.remainder()).zip(oc.into_remainder()) {
        *o = x * y;
    }
}

/// `out[i] += a[i] * b[i]` (elementwise multiply-accumulate).
#[inline]
pub fn mul_add(a: &[f64], b: &[f64], out: &mut [f64]) {
    assert_eq!(a.len(), b.len());
    assert_eq!(a.len(), out.len());
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for ((x, y), o) in (&mut ac).zip(&mut bc).zip(&mut oc) {
        o[0] += x[0] * y[0];
        o[1] += x[1] * y[1];
        o[2] += x[2] * y[2];
        o[3] += x[3] * y[3];
    }
    for ((x, y), o) in ac.remainder().iter().zip(bc.remainder()).zip(oc.into_remainder()) {
        *o += x * y;
    }
}

/// `out[i] = s * x[i]`.
#[inline]
pub fn scale(s: f64, x: &[f64], out: &mut [f64]) {
    assert_eq!(x.len(), out.len());
    let mut xc = x.chunks_exact(LANES);
    let mut oc = out.chunks_exact_mut(LANES);
    for (v, o) in (&mut xc).zip(&mut oc) {
        o[0] = s * v[0];
        o[1] = s * v[1];
        o[2] = s * v[2];
        o[3] = s * v[3];
    }
    for (v, o) in xc.remainder().iter().zip(oc.into_remainder()) {
        *o = s * v;
    }
}

/// `y[i] += s * x[i]` — the accumulation kernel of both DCT passes and
/// the oracle's posterior-mean update. Adds occur per element in slice
/// order, so a k-outer caller keeps each output's accumulation sequence
/// identical to the classic scalar j-inner loop.
#[inline]
pub fn axpy(s: f64, x: &[f64], y: &mut [f64]) {
    assert_eq!(x.len(), y.len());
    let mut xc = x.chunks_exact(LANES);
    let mut yc = y.chunks_exact_mut(LANES);
    for (v, o) in (&mut xc).zip(&mut yc) {
        o[0] += s * v[0];
        o[1] += s * v[1];
        o[2] += s * v[2];
        o[3] += s * v[3];
    }
    for (v, o) in xc.remainder().iter().zip(yc.into_remainder()) {
        *o += s * v;
    }
}

/// `(ox, ov)[i] = M (x, v)[i]` for a 2×2 `M = [[a, b], [c, d]]` applied
/// per index pair — the [`crate::math::linop::LinOp::Block2`] (CLD
/// `M ⊗ I_d`) apply, split into its two output halves.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn block2(
    a: f64,
    b: f64,
    c: f64,
    d: f64,
    x: &[f64],
    v: &[f64],
    ox: &mut [f64],
    ov: &mut [f64],
) {
    assert_eq!(x.len(), v.len());
    assert_eq!(x.len(), ox.len());
    assert_eq!(x.len(), ov.len());
    let mut xc = x.chunks_exact(LANES);
    let mut vc = v.chunks_exact(LANES);
    let mut oxc = ox.chunks_exact_mut(LANES);
    let mut ovc = ov.chunks_exact_mut(LANES);
    for (((xs, vs), oxs), ovs) in (&mut xc).zip(&mut vc).zip(&mut oxc).zip(&mut ovc) {
        oxs[0] = a * xs[0] + b * vs[0];
        oxs[1] = a * xs[1] + b * vs[1];
        oxs[2] = a * xs[2] + b * vs[2];
        oxs[3] = a * xs[3] + b * vs[3];
        ovs[0] = c * xs[0] + d * vs[0];
        ovs[1] = c * xs[1] + d * vs[1];
        ovs[2] = c * xs[2] + d * vs[2];
        ovs[3] = c * xs[3] + d * vs[3];
    }
    let (xr, vr) = (xc.remainder(), vc.remainder());
    let (oxr, ovr) = (oxc.into_remainder(), ovc.into_remainder());
    for i in 0..xr.len() {
        oxr[i] = a * xr[i] + b * vr[i];
        ovr[i] = c * xr[i] + d * vr[i];
    }
}

/// `(ox, ov)[i] += M (x, v)[i]` — accumulating [`block2`].
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn block2_add(
    a: f64,
    b: f64,
    c: f64,
    d: f64,
    x: &[f64],
    v: &[f64],
    ox: &mut [f64],
    ov: &mut [f64],
) {
    assert_eq!(x.len(), v.len());
    assert_eq!(x.len(), ox.len());
    assert_eq!(x.len(), ov.len());
    let mut xc = x.chunks_exact(LANES);
    let mut vc = v.chunks_exact(LANES);
    let mut oxc = ox.chunks_exact_mut(LANES);
    let mut ovc = ov.chunks_exact_mut(LANES);
    for (((xs, vs), oxs), ovs) in (&mut xc).zip(&mut vc).zip(&mut oxc).zip(&mut ovc) {
        oxs[0] += a * xs[0] + b * vs[0];
        oxs[1] += a * xs[1] + b * vs[1];
        oxs[2] += a * xs[2] + b * vs[2];
        oxs[3] += a * xs[3] + b * vs[3];
        ovs[0] += c * xs[0] + d * vs[0];
        ovs[1] += c * xs[1] + d * vs[1];
        ovs[2] += c * xs[2] + d * vs[2];
        ovs[3] += c * xs[3] + d * vs[3];
    }
    let (xr, vr) = (xc.remainder(), vc.remainder());
    let (oxr, ovr) = (oxc.into_remainder(), ovc.into_remainder());
    for i in 0..xr.len() {
        oxr[i] += a * xr[i] + b * vr[i];
        ovr[i] += c * xr[i] + d * vr[i];
    }
}

/// `Σ x[i]²` in strict left-to-right order — bit-identical to the scalar
/// `iter().map(|x| x * x).sum()` it replaces. The squares are independent
/// (vector lanes); only the adds are serialized, which is what the
/// default-path bit-identity contract requires (see module docs).
#[inline]
pub fn sum_sq(x: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &v in x {
        acc += v * v;
    }
    acc
}

/// `Σ x[i]²` with four independent accumulators (the true wide-lane
/// reduction). **Reassociates f64 addition** — not bit-identical to
/// [`sum_sq`] — so it must never feed the default f64 sampler path
/// without an explicit golden re-lock. Intended for tolerance-checked
/// consumers (metrics, diagnostics) where the ~4× reduction speedup is
/// free.
#[inline]
pub fn sum_sq_blocked(x: &[f64]) -> f64 {
    let mut c = x.chunks_exact(LANES);
    let (mut a0, mut a1, mut a2, mut a3) = (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    for v in &mut c {
        a0 += v[0] * v[0];
        a1 += v[1] * v[1];
        a2 += v[2] * v[2];
        a3 += v[3] * v[3];
    }
    let mut acc = (a0 + a1) + (a2 + a3);
    for &v in c.remainder() {
        acc += v * v;
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::rng::Rng;

    fn vec_of(n: usize, seed: u64) -> Vec<f64> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.normal()).collect()
    }

    fn bits(x: &[f64]) -> Vec<u64> {
        x.iter().map(|v| v.to_bits()).collect()
    }

    #[test]
    fn elementwise_kernels_match_scalar_loops_bitwise() {
        // Lengths straddling the lane width: empty, sub-lane, exact
        // multiples, and off-by-one/three remainders.
        for n in [0usize, 1, 3, 4, 5, 8, 13, 64, 257] {
            let a = vec_of(n, 1);
            let b = vec_of(n, 2);
            let s = 0.7361;
            let mut got = vec![0.0; n];
            let mut want = vec![0.0; n];

            sub(&a, &b, &mut got);
            for i in 0..n {
                want[i] = a[i] - b[i];
            }
            assert_eq!(bits(&got), bits(&want), "sub at n={n}");

            mul(&a, &b, &mut got);
            for i in 0..n {
                want[i] = a[i] * b[i];
            }
            assert_eq!(bits(&got), bits(&want), "mul at n={n}");

            scale(s, &a, &mut got);
            for i in 0..n {
                want[i] = s * a[i];
            }
            assert_eq!(bits(&got), bits(&want), "scale at n={n}");

            let mut got_acc = b.clone();
            let mut want_acc = b.clone();
            axpy(s, &a, &mut got_acc);
            for i in 0..n {
                want_acc[i] += s * a[i];
            }
            assert_eq!(bits(&got_acc), bits(&want_acc), "axpy at n={n}");

            let mut got_ma = b.clone();
            let mut want_ma = b.clone();
            mul_add(&a, &b, &mut got_ma);
            for i in 0..n {
                want_ma[i] += a[i] * b[i];
            }
            assert_eq!(bits(&got_ma), bits(&want_ma), "mul_add at n={n}");
        }
    }

    #[test]
    fn block2_kernels_match_scalar_loops_bitwise() {
        let (a, b, c, d) = (1.25, -0.3, 0.7, 2.0);
        for n in [0usize, 1, 3, 4, 7, 16, 33] {
            let x = vec_of(n, 3);
            let v = vec_of(n, 4);
            let mut ox = vec![0.0; n];
            let mut ov = vec![0.0; n];
            block2(a, b, c, d, &x, &v, &mut ox, &mut ov);
            let mut wx = vec![0.0; n];
            let mut wv = vec![0.0; n];
            for i in 0..n {
                wx[i] = a * x[i] + b * v[i];
                wv[i] = c * x[i] + d * v[i];
            }
            assert_eq!(bits(&ox), bits(&wx), "block2 x at n={n}");
            assert_eq!(bits(&ov), bits(&wv), "block2 v at n={n}");

            let mut ax = vec_of(n, 5);
            let mut av = vec_of(n, 6);
            let (mut wax, mut wav) = (ax.clone(), av.clone());
            block2_add(a, b, c, d, &x, &v, &mut ax, &mut av);
            for i in 0..n {
                wax[i] += a * x[i] + b * v[i];
                wav[i] += c * x[i] + d * v[i];
            }
            assert_eq!(bits(&ax), bits(&wax), "block2_add x at n={n}");
            assert_eq!(bits(&av), bits(&wav), "block2_add v at n={n}");
        }
    }

    #[test]
    fn sum_sq_is_bit_identical_to_sequential_sum() {
        for n in [0usize, 1, 5, 64, 1023] {
            let x = vec_of(n, 7);
            let want: f64 = x.iter().map(|v| v * v).sum();
            assert_eq!(sum_sq(&x).to_bits(), want.to_bits(), "sum_sq at n={n}");
        }
    }

    #[test]
    fn sum_sq_blocked_agrees_within_tolerance_only() {
        // The blocked reduction is numerically equivalent but not
        // bit-pinned — exactly why it stays off the default sampler path.
        for n in [4usize, 63, 1024] {
            let x = vec_of(n, 8);
            let strict = sum_sq(&x);
            let blocked = sum_sq_blocked(&x);
            assert!(
                (strict - blocked).abs() <= 1e-12 * strict.abs().max(1.0),
                "n={n}: {strict} vs {blocked}"
            );
        }
    }
}
