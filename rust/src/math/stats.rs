//! Sample statistics: moment fits for the Fréchet metric, percentile
//! summaries for the serving benchmarks, online accumulators.

use crate::math::linalg::MatD;

/// Mean vector of row-major samples (`n` rows of dimension `d`).
pub fn mean(samples: &[f64], d: usize) -> Vec<f64> {
    assert!(d > 0 && samples.len() % d == 0);
    let n = samples.len() / d;
    assert!(n > 0);
    let mut mu = vec![0.0; d];
    for row in samples.chunks_exact(d) {
        for (m, &x) in mu.iter_mut().zip(row) {
            *m += x;
        }
    }
    for m in mu.iter_mut() {
        *m /= n as f64;
    }
    mu
}

/// Sample covariance (denominator `n-1`) of row-major samples.
pub fn covariance(samples: &[f64], d: usize) -> MatD {
    let n = samples.len() / d;
    assert!(n > 1, "covariance needs at least 2 samples");
    let mu = mean(samples, d);
    let mut c = MatD::zeros(d, d);
    let mut diff = vec![0.0; d];
    for row in samples.chunks_exact(d) {
        for j in 0..d {
            diff[j] = row[j] - mu[j];
        }
        for i in 0..d {
            let di = diff[i];
            let crow = &mut c.data[i * d..(i + 1) * d];
            for j in 0..d {
                crow[j] += di * diff[j];
            }
        }
    }
    c.scale(1.0 / (n as f64 - 1.0))
}

/// Welford online mean/variance accumulator (scalar).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    pub n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    pub fn var(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }
}

/// Percentile of a sample set (linear interpolation between order
/// statistics) — used for latency p50/p95/p99 in the serving benches.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!(!xs.is_empty());
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Summary of a latency/throughput measurement series.
#[derive(Clone, Debug)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

impl Summary {
    pub fn from(xs: &[f64]) -> Summary {
        let mut w = Welford::default();
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &x in xs {
            w.push(x);
            min = min.min(x);
            max = max.max(x);
        }
        Summary {
            n: xs.len(),
            mean: w.mean(),
            std: w.std(),
            min,
            p50: percentile(xs, 50.0),
            p95: percentile(xs, 95.0),
            p99: percentile(xs, 99.0),
            max,
        }
    }
}

impl std::fmt::Display for Summary {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "n={} mean={:.4} std={:.4} min={:.4} p50={:.4} p95={:.4} p99={:.4} max={:.4}",
            self.n, self.mean, self.std, self.min, self.p50, self.p95, self.p99, self.max
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::math::{close, rng::Rng};

    #[test]
    fn mean_and_cov_of_known_gaussian() {
        let mut rng = Rng::seed_from(21);
        let n = 60_000;
        let d = 2;
        // x = (z0, 0.5 z0 + z1): cov = [[1, .5], [.5, 1.25]]
        let mut xs = Vec::with_capacity(n * d);
        for _ in 0..n {
            let z0 = rng.normal();
            let z1 = rng.normal();
            xs.push(1.0 + z0);
            xs.push(-2.0 + 0.5 * z0 + z1);
        }
        let mu = mean(&xs, d);
        assert!(close(mu[0], 1.0, 0.0, 0.02), "{}", mu[0]);
        assert!(close(mu[1], -2.0, 0.0, 0.02), "{}", mu[1]);
        let c = covariance(&xs, d);
        assert!(close(c[(0, 0)], 1.0, 0.0, 0.03));
        assert!(close(c[(0, 1)], 0.5, 0.0, 0.03));
        assert!(close(c[(1, 1)], 1.25, 0.0, 0.03));
    }

    #[test]
    fn welford_matches_two_pass() {
        let xs = [1.0, 2.0, 4.0, 8.0, 16.5, -3.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        let v = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64;
        assert!(close(w.mean(), m, 1e-13, 0.0));
        assert!(close(w.var(), v, 1e-13, 0.0));
    }

    #[test]
    fn percentile_endpoints() {
        let xs = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [0.0, 10.0];
        assert!(close(percentile(&xs, 25.0), 2.5, 1e-13, 0.0));
    }
}
