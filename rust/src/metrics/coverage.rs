//! Mode-coverage diagnostics for mixture ground truth: assign each sample
//! to its nearest mode, count hits, report missing modes and the χ²
//! statistic against the mixture weights. Low-NFE samplers fail here
//! first (mode dropping), which FD can under-report.

use crate::data::gmm::GmmSpec;

#[derive(Clone, Debug)]
pub struct Coverage {
    /// Samples assigned to each mode.
    pub counts: Vec<usize>,
    /// Modes with zero assigned samples.
    pub missing: usize,
    /// χ² statistic of counts against expected weights.
    pub chi2: f64,
    /// Fraction of samples farther than `3σ + margin` from every mode
    /// ("off-manifold" mass).
    pub outliers: f64,
}

/// Compute coverage of `samples` (row-major n×d) against `spec`.
pub fn coverage(samples: &[f64], spec: &GmmSpec) -> Coverage {
    let d = spec.d;
    let n = samples.len() / d;
    assert!(n > 0);
    let mut counts = vec![0usize; spec.n_modes()];
    let mut outliers = 0usize;
    let sd = spec.var.sqrt();
    let thresh = (3.0 * sd + 0.5) * (d as f64).sqrt();
    for row in samples.chunks_exact(d) {
        let mut best = f64::INFINITY;
        let mut arg = 0;
        for (m, mu) in spec.means.iter().enumerate() {
            let d2: f64 = row.iter().zip(mu).map(|(a, b)| (a - b) * (a - b)).sum();
            if d2 < best {
                best = d2;
                arg = m;
            }
        }
        counts[arg] += 1;
        if best.sqrt() > thresh {
            outliers += 1;
        }
    }
    let missing = counts.iter().filter(|&&c| c == 0).count();
    let mut chi2 = 0.0;
    for (c, w) in counts.iter().zip(&spec.weights) {
        let expect = w * n as f64;
        if expect > 0.0 {
            chi2 += (*c as f64 - expect).powi(2) / expect;
        }
    }
    Coverage { counts, missing, chi2, outliers: outliers as f64 / n as f64 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::math::rng::Rng;

    #[test]
    fn true_samples_cover_all_modes() {
        let spec = presets::gmm2d();
        let mut rng = Rng::seed_from(1);
        let xs = spec.sample(8_000, &mut rng);
        let c = coverage(&xs, &spec);
        assert_eq!(c.missing, 0);
        assert!(c.outliers < 0.01, "outliers={}", c.outliers);
        // χ² for 7 dof should be small for true samples (allow wide margin).
        assert!(c.chi2 < 40.0, "chi2={}", c.chi2);
    }

    #[test]
    fn collapse_is_detected() {
        let spec = presets::gmm2d();
        // All samples at mode 0.
        let mut xs = Vec::new();
        for _ in 0..1000 {
            xs.extend_from_slice(&spec.means[0]);
        }
        let c = coverage(&xs, &spec);
        assert_eq!(c.missing, spec.n_modes() - 1);
        assert!(c.chi2 > 1000.0);
    }

    #[test]
    fn garbage_is_outliers() {
        let spec = presets::gmm2d();
        let mut rng = Rng::seed_from(2);
        let xs: Vec<f64> = (0..2000).map(|_| 30.0 + rng.normal()).collect();
        let c = coverage(&xs, &spec);
        assert!(c.outliers > 0.9);
    }
}
