//! Fréchet distance between Gaussian moment fits — the repo's FID.
//!
//! `FD² = ‖μ₁−μ₂‖² + tr(C₁ + C₂ − 2(C₁^½ C₂ C₁^½)^½)` — the exact
//! functional form of FID (Heusel et al. 2017), evaluated in data space
//! against the *analytic* moments of the ground-truth mixture instead of
//! Inception features (which do not exist for synthetic mixtures).

use crate::data::gmm::GmmSpec;
use crate::math::linalg::MatD;
use crate::math::stats;

/// Fréchet distance between two Gaussians given moments.
pub fn frechet_distance(mu1: &[f64], c1: &MatD, mu2: &[f64], c2: &MatD) -> f64 {
    assert_eq!(mu1.len(), mu2.len());
    let diff2: f64 = mu1.iter().zip(mu2).map(|(a, b)| (a - b) * (a - b)).sum();
    let s1 = c1.sqrtm_psd();
    let inner = s1.matmul(c2).matmul(&s1);
    let cross = inner.sqrtm_psd();
    let tr = c1.trace() + c2.trace() - 2.0 * cross.trace();
    (diff2 + tr).max(0.0)
}

/// FD of generated samples (row-major `n × d`) against a [`GmmSpec`]'s
/// exact moments.
pub fn frechet_to_spec(samples: &[f64], spec: &GmmSpec) -> f64 {
    let d = spec.d;
    let mu = stats::mean(samples, d);
    let c = stats::covariance(samples, d);
    frechet_distance(&mu, &c, &spec.mean(), &spec.cov())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::math::rng::Rng;

    #[test]
    fn identical_moments_give_zero() {
        let mu = vec![1.0, -2.0];
        let c = MatD::from_rows(&[vec![2.0, 0.3], vec![0.3, 1.0]]);
        assert!(frechet_distance(&mu, &c, &mu, &c) < 1e-9);
    }

    #[test]
    fn mean_shift_is_squared_distance() {
        let c = MatD::eye(3);
        let mu1 = vec![0.0; 3];
        let mu2 = vec![1.0, 2.0, 2.0];
        let fd = frechet_distance(&mu1, &c, &mu2, &c);
        assert!((fd - 9.0).abs() < 1e-9, "{fd}");
    }

    #[test]
    fn scalar_case_matches_formula() {
        // 1-D: FD = (μ1−μ2)² + (σ1−σ2)².
        let c1 = MatD::from_rows(&[vec![4.0]]);
        let c2 = MatD::from_rows(&[vec![1.0]]);
        let fd = frechet_distance(&[0.0], &c1, &[3.0], &c2);
        assert!((fd - (9.0 + 1.0)).abs() < 1e-9, "{fd}");
    }

    #[test]
    fn true_samples_score_near_zero_and_garbage_scores_high() {
        let spec = presets::gmm2d();
        let mut rng = Rng::seed_from(55);
        let good = spec.sample(20_000, &mut rng);
        let fd_good = frechet_to_spec(&good, &spec);
        assert!(fd_good < 0.05, "true samples FD = {fd_good}");
        // Pure Gaussian noise (what an unconverged sampler emits):
        let noise: Vec<f64> = (0..40_000).map(|_| rng.normal()).collect();
        let fd_bad = frechet_to_spec(&noise, &spec);
        assert!(fd_bad > 10.0 * fd_good.max(1e-3), "noise FD = {fd_bad}");
    }

    #[test]
    fn symmetric_in_arguments() {
        let c1 = MatD::from_rows(&[vec![2.0, 0.5], vec![0.5, 1.5]]);
        let c2 = MatD::from_rows(&[vec![1.0, -0.2], vec![-0.2, 3.0]]);
        let a = frechet_distance(&[0.0, 1.0], &c1, &[2.0, -1.0], &c2);
        let b = frechet_distance(&[2.0, -1.0], &c2, &[0.0, 1.0], &c1);
        assert!((a - b).abs() < 1e-8);
    }
}
