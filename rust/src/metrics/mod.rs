//! Evaluation metrics.
//!
//! * [`frechet`] — the repo's FID analog: Fréchet distance between
//!   Gaussian moment fits of generated samples and the *exact* moments of
//!   the ground-truth mixture (identical functional form to FID; see
//!   DESIGN.md §3 for why this is the right substitute on mixture data).
//! * [`wasserstein`] — 1-D and sliced Wasserstein-1.
//! * [`coverage`] — per-mode assignment counts / missing-mode detection
//!   (mode collapse is what low-NFE samplers get wrong first).
//! * [`nll`] — probability-flow NLL with the oracle's exact divergence
//!   (paper App. C.8).

pub mod frechet;
pub mod wasserstein;
pub mod coverage;
pub mod nll;

pub use frechet::{frechet_distance, frechet_to_spec};
