//! Probability-flow NLL (paper App. C.8).
//!
//! Along the probability-flow ODE (Eq. 7) the log-density evolves as the
//! usual continuous normalizing flow:
//! `d log p_t(u(t))/dt = −∇·[F_t u − ½ G_tG_tᵀ s(u,t)]
//!                     = −tr F_t + ½ tr(G_tGᵀ ∇s)`,
//! and with the exact mixture oracle the divergence is closed form
//! (no Hutchinson estimator needed). We integrate data→noise and read the
//! bound the way the paper does for CLD: `log p(x₀) ≥ E_v[log p(x₀,v₀)]
//! + H(p(v₀))` with `v₀ ~ N(0, γM I)`.

use std::sync::Arc;

use crate::diffusion::process::Process;
use crate::math::ode::rk45_integrate;
use crate::score::oracle::GmmOracle;

/// Exact prob-flow log-likelihood of a *state* `u` at t_min, in nats.
pub fn state_logp(oracle: &GmmOracle, u0: &[f64], rtol: f64) -> f64 {
    let proc: &Arc<dyn Process> = &oracle.proc;
    let du = proc.dim_u();
    assert_eq!(u0.len(), du);
    let (t0, t1) = (proc.t_min(), proc.t_max());
    // Augmented state [u, Δlogp].
    let mut y = u0.to_vec();
    y.push(0.0);
    let o = oracle;
    rk45_integrate(
        &mut |t: f64, y: &[f64], dy: &mut [f64]| {
            let u = &y[..du];
            let s = o.score(t, u);
            let f = proc.f_op(t);
            let ggt = proc.ggt_op(t);
            // du/dt = F u − ½ GGᵀ s
            let mut drift = vec![0.0; du];
            f.apply(u, &mut drift);
            let mut gs = vec![0.0; du];
            ggt.apply(&s, &mut gs);
            for j in 0..du {
                dy[j] = drift[j] - 0.5 * gs[j];
            }
            // dΔlogp/dt = tr F − ½ tr(GGᵀ ∇s). For our processes GGᵀ is
            // scalar/diag/block2 and ∇s has matching structure only in
            // trace form; we use tr(GGᵀ∇s) = Σ g²_jj (∇s)_jj which for
            // scalar GGᵀ = g²·tr∇s. Structure-aware below.
            let tr_f = f.trace(du);
            let tr_ggt_js = match &ggt {
                crate::math::linop::LinOp::Scalar(g2) => g2 * o.score_jacobian_trace(t, u),
                _ => {
                    // Generic fallback: finite-difference the needed
                    // diagonal entries of ∇s weighted by GGᵀ's diagonal.
                    let h = 1e-5;
                    let diag: Vec<f64> = match &ggt {
                        crate::math::linop::LinOp::Diag(d) => d.as_ref().clone(),
                        crate::math::linop::LinOp::Block2(m) => {
                            let half = du / 2;
                            let mut v = vec![m.a; half];
                            v.extend(vec![m.d; half]);
                            v
                        }
                        crate::math::linop::LinOp::Scalar(_) => unreachable!(),
                    };
                    let mut acc = 0.0;
                    let mut up = u.to_vec();
                    let mut dn = u.to_vec();
                    for j in 0..du {
                        if diag[j] == 0.0 {
                            continue;
                        }
                        up[j] += h;
                        dn[j] -= h;
                        let sj = (o.score(t, &up)[j] - o.score(t, &dn)[j]) / (2.0 * h);
                        up[j] = u[j];
                        dn[j] = u[j];
                        acc += diag[j] * sj;
                    }
                    acc
                }
            };
            dy[du] = tr_f - 0.5 * tr_ggt_js;
        },
        t0,
        t1,
        rtol,
        rtol * 1e-2,
        &mut y,
    );
    // log p_{t0}(u0) = log p_T(u(T)) + ∫_{t0}^{T} div dt  (change of vars
    // integrating forward accumulates +∫ div; the sign is verified by the
    // roundtrip test against the oracle's exact logp).
    let log_pt = oracle.logp(t1, &y[..du]);
    log_pt + y[du]
}

/// NLL in bits/dim of data points under the model, with CLD's velocity
/// marginalization bound when `dim_u != dim_x` (App. C.8):
/// `log p(x₀) ≥ E_{v₀}[log p(x₀, v₀)] + H(p(v₀))`.
pub fn nll_bits_per_dim(
    oracle: &GmmOracle,
    xs: &[f64],
    n_velocity_draws: usize,
    rng: &mut crate::math::rng::Rng,
    rtol: f64,
) -> f64 {
    let proc = &oracle.proc;
    let d = proc.dim_x();
    let du = proc.dim_u();
    let n = xs.len() / d;
    let mut total = 0.0;
    for row in xs.chunks_exact(d) {
        if du == d {
            total += state_logp(oracle, row, rtol);
        } else {
            // CLD: draw v₀ ~ N(0, γM), average log p(x,v), add entropy.
            let s0 = proc.sigma0();
            let aug = du - d;
            let mut acc = 0.0;
            for _ in 0..n_velocity_draws.max(1) {
                let mut u = proc.lift_data(row);
                let mut noise = vec![0.0; du];
                s0.sqrt_spd().sample_noise(rng, &mut noise);
                for j in d..du {
                    u[j] += noise[j];
                }
                acc += state_logp(oracle, &u, rtol);
            }
            acc /= n_velocity_draws.max(1) as f64;
            // Entropy of N(0, γM I_aug).
            let gm = match s0 {
                crate::math::linop::LinOp::Block2(m) => m.d,
                ref other => other.max_abs(),
            };
            let h = 0.5 * aug as f64 * (2.0 * std::f64::consts::PI * std::f64::consts::E * gm).ln();
            total += acc + h;
        }
    }
    // bits/dim = −logp / (d ln 2)
    -total / (n as f64 * d as f64 * std::f64::consts::LN_2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gmm::GmmSpec;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;

    #[test]
    fn prob_flow_logp_matches_exact_mixture_logp() {
        // The CNF likelihood along the exact-score prob-flow must equal
        // the analytic mixture log-density at t_min.
        let proc = Arc::new(Vpsde::standard(1));
        let spec = GmmSpec::new("m", vec![vec![-1.5], vec![1.5]], 0.04);
        let o = GmmOracle::new(proc.clone(), spec, KtKind::R);
        for &x in &[0.2f64, -1.4, 1.6] {
            let got = state_logp(&o, &[x], 1e-8);
            let exact = o.logp(proc.t_min(), &[x]);
            assert!(
                (got - exact).abs() < 2e-3 * (1.0 + exact.abs()),
                "x={x}: CNF {got} vs exact {exact}"
            );
        }
    }

    #[test]
    fn nll_of_true_samples_near_mixture_entropy() {
        let proc = Arc::new(Vpsde::standard(1));
        let spec = GmmSpec::new("m", vec![vec![-1.5], vec![1.5]], 0.04);
        let o = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let mut rng = crate::math::rng::Rng::seed_from(4);
        let xs = spec.sample(20, &mut rng);
        let bpd = nll_bits_per_dim(&o, &xs, 1, &mut rng, 1e-6);
        // Ground truth −E[log p]/ln2: estimate directly from the spec.
        let mut exact = 0.0;
        for row in xs.chunks_exact(1) {
            exact += spec.logpdf(row);
        }
        let exact_bpd = -exact / (20.0 * std::f64::consts::LN_2);
        assert!(
            (bpd - exact_bpd).abs() < 0.05 * (1.0 + exact_bpd.abs()),
            "bpd {bpd} vs exact {exact_bpd}"
        );
    }
}
