//! Wasserstein-1 distances: exact in 1-D (sorted coupling), sliced via
//! random projections in higher dimension. Complements the Fréchet
//! metric: FD only sees two moments, W1 sees mode structure.

use crate::math::rng::Rng;

/// Exact W1 between two equal-size 1-D samples.
pub fn w1_1d(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut a: Vec<f64> = a.to_vec();
    let mut b: Vec<f64> = b.to_vec();
    a.sort_by(|x, y| x.partial_cmp(y).unwrap());
    b.sort_by(|x, y| x.partial_cmp(y).unwrap());
    a.iter().zip(&b).map(|(x, y)| (x - y).abs()).sum::<f64>() / a.len() as f64
}

/// Sliced W1: average of 1-D W1 over `n_proj` random unit directions.
pub fn sliced_w1(xs: &[f64], ys: &[f64], d: usize, n_proj: usize, rng: &mut Rng) -> f64 {
    assert_eq!(xs.len() % d, 0);
    assert_eq!(ys.len() % d, 0);
    assert_eq!(xs.len(), ys.len(), "sliced_w1 wants equal sample counts");
    let n = xs.len() / d;
    let mut acc = 0.0;
    let mut px = vec![0.0; n];
    let mut py = vec![0.0; n];
    for _ in 0..n_proj {
        // random unit vector
        let mut v: Vec<f64> = (0..d).map(|_| rng.normal()).collect();
        let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
        v.iter_mut().for_each(|x| *x /= norm);
        for (i, row) in xs.chunks_exact(d).enumerate() {
            px[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        for (i, row) in ys.chunks_exact(d).enumerate() {
            py[i] = row.iter().zip(&v).map(|(a, b)| a * b).sum();
        }
        acc += w1_1d(&px, &py);
    }
    acc / n_proj as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identical_samples_zero() {
        let a = [1.0, 5.0, -2.0, 0.3];
        assert_eq!(w1_1d(&a, &a), 0.0);
    }

    #[test]
    fn translation_equals_shift() {
        let a = [0.0, 1.0, 2.0, 3.0];
        let b: Vec<f64> = a.iter().map(|x| x + 2.5).collect();
        assert!((w1_1d(&a, &b) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn order_invariant() {
        let a = [3.0, 1.0, 2.0];
        let b = [9.0, 7.0, 8.0];
        let a2 = [1.0, 2.0, 3.0];
        assert!((w1_1d(&a, &b) - w1_1d(&a2, &b)).abs() < 1e-12);
    }

    #[test]
    fn sliced_detects_mode_collapse() {
        let mut rng = Rng::seed_from(8);
        // Two modes vs one mode in 2-D: sliced W1 must be clearly positive.
        let n = 2000;
        let mut both = Vec::new();
        let mut one = Vec::new();
        for i in 0..n {
            let c = if i % 2 == 0 { -3.0 } else { 3.0 };
            both.push(c + 0.1 * rng.normal());
            both.push(0.1 * rng.normal());
            one.push(3.0 + 0.1 * rng.normal());
            one.push(0.1 * rng.normal());
        }
        let w = sliced_w1(&both, &one, 2, 16, &mut rng);
        assert!(w > 1.0, "w={w}");
        // Same distribution: near zero.
        let mut both2 = Vec::new();
        for i in 0..n {
            let c = if i % 2 == 0 { -3.0 } else { 3.0 };
            both2.push(c + 0.1 * rng.normal());
            both2.push(0.1 * rng.normal());
        }
        let w0 = sliced_w1(&both, &both2, 2, 16, &mut rng);
        assert!(w0 < 0.2, "w0={w0}");
    }
}
