//! `artifacts/manifest.json` — the contract between `python/compile` and
//! the rust runtime.

use std::path::{Path, PathBuf};

use crate::diffusion::process::KtKind;
use crate::util::json::Json;
use crate::{Error, Result};

#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    pub file: PathBuf,
    pub process: String,
    pub dataset: String,
    pub kt: KtKind,
    pub dim_u: usize,
    pub batch: usize,
    pub final_loss: Option<f64>,
    /// Frozen cross-layer probe: ε(u_row0, t) recorded by jax.
    pub probe_t: f64,
    pub probe_u_row0: Vec<f64>,
    pub probe_eps_row0: Vec<f64>,
    pub probe_seed: u64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        let j = Json::parse(&text).map_err(|e| Error::msg(format!("manifest parse: {e}")))?;
        let models_obj = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| Error::msg("manifest missing models"))?;
        let mut models = Vec::new();
        for (name, m) in models_obj {
            let get_str = |k: &str| {
                m.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| Error::msg(format!("model {name}: missing {k}")))
            };
            let probe = m.get("probe").ok_or_else(|| Error::msg("missing probe"))?;
            models.push(ModelEntry {
                name: name.clone(),
                file: dir.join(get_str("file")?),
                process: get_str("process")?,
                dataset: get_str("dataset")?,
                kt: get_str("kt")?.parse().map_err(Error::msg)?,
                dim_u: m.get("dim_u").and_then(|v| v.as_usize()).unwrap_or(0),
                batch: m.get("batch").and_then(|v| v.as_usize()).unwrap_or(256),
                final_loss: m.get("final_loss").and_then(|v| v.as_f64()),
                probe_t: probe.get("t").and_then(|v| v.as_f64()).unwrap_or(0.5),
                probe_u_row0: probe
                    .get("u_row0")
                    .and_then(|v| v.as_f64_vec())
                    .unwrap_or_default(),
                probe_eps_row0: probe
                    .get("eps_row0")
                    .and_then(|v| v.as_f64_vec())
                    .unwrap_or_default(),
                probe_seed: probe.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            });
        }
        Ok(Manifest { dir, models })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Default artifacts directory (repo-root-relative, overridable).
    pub fn default_dir() -> PathBuf {
        std::env::var("GDDIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_a_minimal_manifest() {
        let dir = std::env::temp_dir().join("gddim_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"batch": 256, "models": {"m1": {
                "file": "m1.hlo.txt", "process": "cld", "dataset": "gmm2d",
                "kt": "R", "dim_u": 4, "batch": 256, "final_loss": 0.12,
                "probe": {"t": 0.5, "u_row0": [1, 2, 3, 4],
                          "eps_row0": [0.1, 0.2, 0.3, 0.4], "seed": 1234}}}}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("m1").unwrap();
        assert_eq!(e.dim_u, 4);
        assert_eq!(e.kt, KtKind::R);
        assert_eq!(e.probe_u_row0.len(), 4);
        assert_eq!(e.probe_seed, 1234);
    }
}
