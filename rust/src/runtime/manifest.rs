//! `artifacts/manifest.json` — the contract between `python/compile` and
//! the rust serving layer.
//!
//! Both python exporters (`aot.py` for real models, `fixture.py` for the
//! committed test fixture) write the same schema; [`Manifest::load`]
//! validates it **eagerly** with per-entry errors, so a malformed
//! artifact directory fails at startup with the model named instead of
//! panicking later inside a forward pass.
//!
//! # Schema
//!
//! ```json
//! {
//!   "batch": 256,
//!   "models": {
//!     "<name>": {
//!       "file":       "name.hlo.txt",   // optional: HLO text (PJRT path)
//!       "weights":    "name.gdw",       // optional: raw weights (ScoreNet)
//!       "process":    "vpsde|cld|bdm",
//!       "dataset":    "gmm2d|blobs8|...",
//!       "kt":         "R|L|sqrt",       // K_t the ε output is trained in
//!       "dim_u":      2,                // state dimension (required, > 0)
//!       "batch":      256,              // export batch of the HLO artifact
//!       "hidden":     128,              // ScoreNet width
//!       "blocks":     3,                // FiLM residual block count
//!       "emb_half":   16,               // half-width of the time embedding
//!       "final_loss": 0.12,             // training diagnostic (optional)
//!       "probe": {                      // frozen cross-layer probe
//!         "t":        0.5,
//!         "u_row0":   [..dim_u floats],   // input row
//!         "eps_row0": [..dim_u floats],   // float64 reference ε of row 0
//!         "seed":     1234                // RNG seed of the full probe batch
//!       }
//!     }
//!   }
//! }
//! ```
//!
//! At least one of `file` / `weights` must be present per entry and must
//! name a readable file next to the manifest. `probe.eps_row0` is the
//! *float64 reference forward* of the exported f32 weights (see
//! `python/compile/weights.py`); the pure-Rust loader
//! [`crate::score::net::ScoreNet`] replays it within 1e-6 at load time,
//! and the PJRT executor checks the same row against its f32 output at a
//! looser float32 tolerance.

use std::path::{Path, PathBuf};

use crate::diffusion::process::KtKind;
use crate::util::io::read_string_capped;
use crate::util::json::Json;
use crate::{Error, Result};

/// Size cap on `manifest.json` itself (it holds probe vectors, not
/// weights — 4 MiB is three orders of magnitude of headroom).
pub const MANIFEST_CAP_BYTES: u64 = 4 << 20;

/// One exported model: artifact paths (already joined onto the manifest
/// directory), serving metadata, and the frozen probe. See the module
/// docs for the JSON schema and which fields are optional.
#[derive(Clone, Debug)]
pub struct ModelEntry {
    pub name: String,
    /// HLO-text artifact for the PJRT executor, when exported.
    pub file: Option<PathBuf>,
    /// `.gdw` raw-weight artifact for [`crate::score::net::ScoreNet`],
    /// when exported.
    pub weights: Option<PathBuf>,
    pub process: String,
    pub dataset: String,
    pub kt: KtKind,
    pub dim_u: usize,
    pub batch: usize,
    /// Network shape (defaults mirror python's `ScoreNetConfig`).
    pub hidden: usize,
    pub blocks: usize,
    pub emb_half: usize,
    pub final_loss: Option<f64>,
    /// Frozen cross-layer probe: ε(u_row0, t), float64 reference.
    pub probe_t: f64,
    pub probe_u_row0: Vec<f64>,
    pub probe_eps_row0: Vec<f64>,
    pub probe_seed: u64,
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: Vec<ModelEntry>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let text = read_string_capped(&dir.join("manifest.json"), MANIFEST_CAP_BYTES)?;
        let j = Json::parse(&text).map_err(|e| Error::msg(format!("manifest parse: {e}")))?;
        let models_obj = j
            .get("models")
            .and_then(|m| m.as_obj())
            .ok_or_else(|| Error::msg("manifest missing models"))?;
        let mut models = Vec::new();
        for (name, m) in models_obj {
            let fail = |what: &str| Error::msg(format!("model {name}: {what}"));
            let get_str = |k: &str| {
                m.get(k)
                    .and_then(|v| v.as_str())
                    .map(|s| s.to_string())
                    .ok_or_else(|| fail(&format!("missing {k}")))
            };
            let get_dim = |k: &str, default: usize| {
                m.get(k).and_then(|v| v.as_usize()).unwrap_or(default)
            };
            let probe = m.get("probe").ok_or_else(|| fail("missing probe"))?;
            let dim_u =
                m.get("dim_u").and_then(|v| v.as_usize()).ok_or_else(|| fail("missing dim_u"))?;
            if dim_u == 0 {
                return Err(fail("dim_u must be > 0"));
            }

            // Artifact paths: at least one of `file`/`weights`, readable.
            let artifact = |k: &str| -> Result<Option<PathBuf>> {
                match m.get(k).and_then(|v| v.as_str()) {
                    None => Ok(None),
                    Some(rel) => {
                        let p = dir.join(rel);
                        if !p.is_file() {
                            let msg = format!("{k} {} is not a readable file", p.display());
                            return Err(fail(&msg));
                        }
                        Ok(Some(p))
                    }
                }
            };
            let (file, weights) = (artifact("file")?, artifact("weights")?);
            if file.is_none() && weights.is_none() {
                return Err(fail("needs at least one of `file` (HLO) / `weights` (.gdw)"));
            }

            let probe_vec = |k: &str| -> Result<Vec<f64>> {
                let v = probe
                    .get(k)
                    .and_then(|v| v.as_f64_vec())
                    .ok_or_else(|| fail(&format!("probe missing {k}")))?;
                if v.len() != dim_u {
                    let msg = format!("probe {k} has {} entries, dim_u is {dim_u}", v.len());
                    return Err(fail(&msg));
                }
                Ok(v)
            };

            models.push(ModelEntry {
                name: name.clone(),
                file,
                weights,
                process: get_str("process")?,
                dataset: get_str("dataset")?,
                kt: get_str("kt")?.parse().map_err(Error::msg)?,
                dim_u,
                batch: get_dim("batch", 256),
                hidden: get_dim("hidden", 128),
                blocks: get_dim("blocks", 3),
                emb_half: get_dim("emb_half", 16),
                final_loss: m.get("final_loss").and_then(|v| v.as_f64()),
                probe_t: probe.get("t").and_then(|v| v.as_f64()).unwrap_or(0.5),
                probe_u_row0: probe_vec("u_row0")?,
                probe_eps_row0: probe_vec("eps_row0")?,
                probe_seed: probe.get("seed").and_then(|v| v.as_f64()).unwrap_or(0.0) as u64,
            });
        }
        Ok(Manifest { dir, models })
    }

    pub fn get(&self, name: &str) -> Option<&ModelEntry> {
        self.models.iter().find(|m| m.name == name)
    }

    /// Default artifacts directory (repo-root-relative, overridable).
    pub fn default_dir() -> PathBuf {
        std::env::var("GDDIM_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(tag: &str, body: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("gddim_manifest_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), body).unwrap();
        dir
    }

    const GOOD: &str = r#"{"batch": 256, "models": {"m1": {
        "file": "m1.hlo.txt", "process": "cld", "dataset": "gmm2d",
        "kt": "R", "dim_u": 4, "batch": 256, "final_loss": 0.12,
        "probe": {"t": 0.5, "u_row0": [1, 2, 3, 4],
                  "eps_row0": [0.1, 0.2, 0.3, 0.4], "seed": 1234}}}}"#;

    #[test]
    fn parses_a_minimal_manifest() {
        let dir = write_manifest("ok", GOOD);
        std::fs::write(dir.join("m1.hlo.txt"), "HloModule m1").unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.get("m1").unwrap();
        assert_eq!(e.dim_u, 4);
        assert_eq!(e.kt, KtKind::R);
        assert_eq!(e.probe_u_row0.len(), 4);
        assert_eq!(e.probe_seed, 1234);
        assert!(e.file.is_some() && e.weights.is_none());
        // Shape fields fall back to the python ScoreNetConfig defaults.
        assert_eq!((e.hidden, e.blocks, e.emb_half), (128, 3, 16));
    }

    #[test]
    fn rejects_missing_artifact_file() {
        // Same manifest, but m1.hlo.txt was never written.
        let dir = write_manifest("nofile", GOOD);
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("model m1") && err.contains("not a readable file"), "{err}");
    }

    #[test]
    fn rejects_zero_or_missing_dim_u() {
        for (tag, entry) in [
            ("dim0", r#""dim_u": 0,"#),
            ("dimmissing", ""),
        ] {
            let body = format!(
                r#"{{"models": {{"m1": {{"file": "f", "process": "vpsde",
                    "dataset": "gmm2d", "kt": "R", {entry}
                    "probe": {{"t": 0.5, "u_row0": [1], "eps_row0": [1]}}}}}}}}"#
            );
            let dir = write_manifest(tag, &body);
            let err = Manifest::load(&dir).unwrap_err().to_string();
            assert!(err.contains("model m1") && err.contains("dim_u"), "{tag}: {err}");
        }
    }

    #[test]
    fn rejects_probe_length_mismatch_and_missing_artifacts() {
        let dir = write_manifest(
            "shortprobe",
            r#"{"models": {"m1": {"file": "m1.hlo.txt", "process": "cld",
                "dataset": "gmm2d", "kt": "R", "dim_u": 4,
                "probe": {"t": 0.5, "u_row0": [1, 2], "eps_row0": [1, 2]}}}}"#,
        );
        std::fs::write(dir.join("m1.hlo.txt"), "HloModule m1").unwrap();
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("probe u_row0 has 2 entries, dim_u is 4"), "{err}");

        let dir = write_manifest(
            "noartifact",
            r#"{"models": {"m1": {"process": "cld", "dataset": "gmm2d",
                "kt": "R", "dim_u": 1,
                "probe": {"t": 0.5, "u_row0": [1], "eps_row0": [1]}}}}"#,
        );
        let err = Manifest::load(&dir).unwrap_err().to_string();
        assert!(err.contains("at least one of"), "{err}");
    }

    #[test]
    fn loads_the_committed_learned_fixture() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/learned");
        let m = Manifest::load(dir).unwrap();
        assert_eq!(m.models.len(), 2);
        for e in &m.models {
            assert!(e.weights.is_some() && e.file.is_none(), "{}", e.name);
            assert_eq!(e.probe_eps_row0.len(), e.dim_u);
            assert_eq!((e.hidden, e.blocks, e.emb_half), (16, 1, 8));
        }
        assert_eq!(m.get("tiny_cld_gmm2d").unwrap().dim_u, 4);
    }
}
