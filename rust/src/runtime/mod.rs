//! Artifact runtime: load AOT exports (`artifacts/` + [`manifest`]) and
//! serve them as [`ScoreModel`](crate::score::ScoreModel)s on the rust
//! hot path. Two executors share the manifest contract:
//!
//! * [`crate::score::net::ScoreNet`] (always available, std-only) reads
//!   the `.gdw` raw-weight artifact and replays the MLP forward with the
//!   `math::simd` kernels — the default serving backend.
//! * `net::NetScore` (behind the `pjrt` cargo feature) executes the HLO
//!   text artifact via PJRT; it needs an external `xla` binding crate
//!   the offline std-only build does not vendor. Interchange is HLO
//!   *text* — jax ≥ 0.5 serialized protos carry 64-bit instruction ids
//!   that xla_extension 0.5.1 rejects; the text parser reassigns ids
//!   (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//! The [`manifest`] parser is always available (plain JSON) so the
//! artifact contract stays testable without either executor.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod net;

pub use manifest::Manifest;
#[cfg(feature = "pjrt")]
pub use net::NetScore;
