//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt` + manifest)
//! and serve them as [`ScoreModel`]s on the rust hot path.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).

pub mod manifest;
pub mod net;

pub use manifest::Manifest;
pub use net::NetScore;
