//! PJRT runtime: load AOT artifacts (`artifacts/*.hlo.txt` + manifest)
//! and serve them as [`ScoreModel`](crate::score::ScoreModel)s on the
//! rust hot path.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 serialized protos carry 64-bit
//! instruction ids that xla_extension 0.5.1 rejects; the text parser
//! reassigns ids (see /opt/xla-example/README.md and DESIGN.md §2).
//!
//! The executor itself (`net::NetScore`) sits behind the `pjrt` cargo
//! feature: it needs an external `xla` binding crate that the offline
//! std-only build does not vendor. The manifest parser is always
//! available (it is plain JSON) so the artifact contract stays testable.

pub mod manifest;
#[cfg(feature = "pjrt")]
pub mod net;

pub use manifest::Manifest;
#[cfg(feature = "pjrt")]
pub use net::NetScore;
