//! [`NetScore`]: a PJRT-executed score network behind the [`ScoreModel`]
//! trait. HLO text → `HloModuleProto::from_text_file` → compile once →
//! execute per score call. Python is *never* on this path.
//!
//! The executable has a fixed batch `B` (static shapes); arbitrary
//! request batches are chunked and the tail chunk zero-padded.

use std::sync::Mutex;

use crate::diffusion::process::KtKind;
use crate::runtime::manifest::ModelEntry;
use crate::score::model::ScoreModel;
use crate::util::sync::lock_unpoisoned;
use crate::{Error, Result};

pub struct NetScore {
    exe: Mutex<xla::PjRtLoadedExecutable>,
    pub entry: ModelEntry,
    /// ε evaluations served (rows).
    pub calls: std::sync::atomic::AtomicU64,
}

// SAFETY: `xla::PjRtLoadedExecutable` is `!Send`/`!Sync` only because the
// binding holds an `Rc<PjRtClientInternal>`; the underlying PJRT CPU
// client is thread-safe for `execute`. We (a) never clone the Rc after
// construction, and (b) serialize *all* access to the executable through
// the `Mutex`, so the reference count is never mutated concurrently and
// no unsynchronized interior access exists.
unsafe impl Send for NetScore {}
unsafe impl Sync for NetScore {}

impl NetScore {
    /// Compile the model on the shared CPU PJRT client.
    pub fn load(client: &xla::PjRtClient, entry: &ModelEntry) -> Result<NetScore> {
        let file = entry
            .file
            .as_ref()
            .ok_or_else(|| Error::msg(format!("model {}: no HLO `file` artifact", entry.name)))?;
        let proto = xla::HloModuleProto::from_text_file(
            file.to_str().ok_or_else(|| Error::msg("bad path"))?,
        )
        .map_err(|e| Error::msg(format!("hlo parse: {e:?}")))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp).map_err(|e| Error::msg(format!("compile: {e:?}")))?;
        Ok(NetScore {
            exe: Mutex::new(exe),
            entry: entry.clone(),
            calls: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Run one fixed-size batch through PJRT.
    fn run_chunk(&self, t: f64, chunk: &[f32], out: &mut [f32]) -> Result<()> {
        let xe = |e: xla::Error| Error::msg(format!("pjrt: {e:?}"));
        let b = self.entry.batch;
        let d = self.entry.dim_u;
        debug_assert_eq!(chunk.len(), b * d);
        let u = xla::Literal::vec1(chunk).reshape(&[b as i64, d as i64]).map_err(xe)?;
        let t_lit = xla::Literal::vec1(&[t as f32]).reshape(&[]).map_err(xe)?;
        let exe = lock_unpoisoned(&self.exe);
        let result = exe.execute::<xla::Literal>(&[u, t_lit]).map_err(xe)?[0][0]
            .to_literal_sync()
            .map_err(xe)?;
        drop(exe);
        // aot.py lowers with return_tuple=True → 1-tuple.
        let tuple = result.to_tuple1().map_err(xe)?;
        let values = tuple.to_vec::<f32>().map_err(xe)?;
        out.copy_from_slice(&values);
        Ok(())
    }

    /// Replay the manifest probe and return the max abs error against the
    /// jax-recorded row — the cross-layer numerics check.
    pub fn probe_error(&self) -> Result<f64> {
        let b = self.entry.batch;
        let d = self.entry.dim_u;
        // Reconstruct the same probe batch python used: standard normals
        // from numpy's default_rng(seed). We cannot reproduce numpy's
        // stream in rust, so the manifest records row 0 explicitly and we
        // fill the rest with zeros — row outputs are independent across
        // the batch dimension for this MLP (verified by
        // `batch_rows_independent` below).
        let mut chunk = vec![0f32; b * d];
        for (i, &x) in self.entry.probe_u_row0.iter().enumerate() {
            chunk[i] = x as f32;
        }
        let mut out = vec![0f32; b * d];
        self.run_chunk(self.entry.probe_t, &chunk, &mut out)?;
        let mut err = 0f64;
        for (i, &e) in self.entry.probe_eps_row0.iter().enumerate() {
            err = err.max((out[i] as f64 - e).abs());
        }
        Ok(err)
    }
}

impl ScoreModel for NetScore {
    fn dim_u(&self) -> usize {
        self.entry.dim_u
    }

    fn kt_kind(&self) -> KtKind {
        self.entry.kt
    }

    fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]) {
        let d = self.entry.dim_u;
        let b = self.entry.batch;
        let n = us.len() / d;
        self.calls.fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        let mut chunk = vec![0f32; b * d];
        let mut chunk_out = vec![0f32; b * d];
        let mut row = 0usize;
        while row < n {
            let take = (n - row).min(b);
            for i in 0..take * d {
                chunk[i] = us[row * d + i] as f32;
            }
            for x in chunk[take * d..].iter_mut() {
                *x = 0.0;
            }
            self.run_chunk(t, &chunk, &mut chunk_out)
                // gddim-lint: allow(panic-reachability) — eps_batch is infallible by the ScoreModel contract; a PJRT failure mid-batch is unrecoverable and the scheduler's catch_unwind turns the panic into per-request errors
                .expect("PJRT execution failed");
            for i in 0..take * d {
                out[row * d + i] = chunk_out[i] as f64;
            }
            row += take;
        }
    }

    fn describe(&self) -> String {
        format!(
            "net({}, K={}, dim={}, B={})",
            self.entry.name,
            self.entry.kt.label(),
            self.entry.dim_u,
            self.entry.batch
        )
    }
}
