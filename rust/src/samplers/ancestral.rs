//! Ancestral sampling — the original DDPM/BDM sampler, generalized to
//! any linear-SDE process (paper Table 3 "Ancestral sampling" row; for
//! BDM this is the only sampler Hoogeboom & Salimans support).
//!
//! Per step `t_i → t_{i−1}` (write `s = t_i`, `t = t_{i−1}`,
//! `A = Ψ(s, t)` the *forward* transition):
//!
//! 1. ε-prediction denoises the state: `ẑ = u_s − K_s ε̂` estimates the
//!    clean state mean `Ψ(s,0)·lift(x₀)`.
//! 2. The linear-Gaussian posterior `q(u_t | u_s, ẑ)` is Gaussian with
//!    mean `Ψ(t,s)ẑ + Σ_t Aᵀ Σ_s⁻¹ (u_s − A·Ψ(t,s)ẑ)` and covariance
//!    `Σ_t − Σ_t Aᵀ Σ_s⁻¹ A Σ_t` — the exact generalization of DDPM's
//!    posterior (β̃ variance) to matrix-valued schedules.

use crate::diffusion::process::Process;
use crate::diffusion::schedule::TimeGrid;
use crate::math::linop::LinOp;
use crate::math::rng::Rng;
use crate::samplers::common::{apply_rows, draw_prior, project_batch, SampleOutput};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

struct StepOps {
    /// Ψ(t, s)ẑ coefficient after gain correction: see `build_steps`.
    mean_z: LinOp,
    /// Gain on the current state: Σ_t Aᵀ Σ_s⁻¹.
    gain: LinOp,
    /// K_s (to denoise).
    kt: LinOp,
    /// Factor of the posterior covariance.
    noise: LinOp,
}

fn sigma_inv(proc: &dyn Process, t: f64) -> LinOp {
    let li = proc.sigma(t).cholesky().inv();
    li.transpose().matmul(&li)
}

fn build_steps(proc: &dyn Process, grid: &TimeGrid, kt: crate::diffusion::KtKind) -> Vec<StepOps> {
    let ts = &grid.ts;
    (1..ts.len())
        .map(|i| {
            let (s, t) = (ts[i], ts[i - 1]);
            let a = proc.psi(s, t); // forward t -> s
            let psi_ts = proc.psi(t, s);
            let sig_t = proc.sigma(t);
            let sinv = sigma_inv(proc, s);
            let gain = sig_t.matmul(&a.transpose()).matmul(&sinv);
            // mean = Ψ(t,s)ẑ + gain·(u_s − A Ψ(t,s) ẑ)
            //      = [Ψ(t,s) − gain·A·Ψ(t,s)] ẑ + gain·u_s
            let mean_z = psi_ts.sub(&gain.matmul(&a).matmul(&psi_ts));
            let cov = sig_t.sub(&gain.matmul(&a).matmul(&sig_t));
            // Defensive symmetrization before factoring.
            let cov = cov.add(&cov.transpose()).scale(0.5);
            StepOps { mean_z, gain, kt: proc.kt(kt, s), noise: cov.sqrt_spd() }
        })
        .collect()
}

/// Generalized ancestral sampling on a time grid.
pub struct Ancestral<'a> {
    pub grid: &'a TimeGrid,
}

struct AncestralState<'a> {
    proc: &'a dyn Process,
    grid: &'a TimeGrid,
    steps: Vec<StepOps>,
    du: usize,
    u: Vec<f64>,
    eps: Vec<f64>,
    zhat: Vec<f64>,
    next: Vec<f64>,
    keps: Vec<f64>,
    noise: Vec<f64>,
    nfe: usize,
}

impl Sampler for Ancestral<'_> {
    fn n_steps(&self) -> usize {
        self.grid.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        _record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        let du = proc.dim_u();
        let steps = build_steps(proc, self.grid, model.kt_kind());
        let u = draw_prior(proc, n, rng);
        Box::new(AncestralState {
            proc,
            grid: self.grid,
            steps,
            du,
            eps: vec![0.0; n * du],
            zhat: vec![0.0; n * du],
            next: vec![0.0; n * du],
            keps: vec![0.0; du],
            noise: vec![0.0; du],
            u,
            nfe: 0,
        })
    }
}

impl SamplerState for AncestralState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, rng: &mut Rng) {
        let du = self.du;
        let ops = &self.steps[i - 1];
        score(ScoreRequest { t: self.grid.ts[i], u: &self.u }, &mut self.eps);
        self.nfe += 1;
        // ẑ = u − K_s ε
        for ((zrow, urow), erow) in self
            .zhat
            .chunks_exact_mut(du)
            .zip(self.u.chunks_exact(du))
            .zip(self.eps.chunks_exact(du))
        {
            ops.kt.apply(erow, &mut self.keps);
            for j in 0..du {
                zrow[j] = urow[j] - self.keps[j];
            }
        }
        // u ← mean_z ẑ + gain u (+ noise except at the final step)
        apply_rows(&ops.mean_z, &self.zhat, &mut self.next, du);
        for (nrow, urow) in self.next.chunks_exact_mut(du).zip(self.u.chunks_exact(du)) {
            ops.gain.apply_add(urow, nrow);
            if i > 1 {
                ops.noise.sample_noise(rng, &mut self.noise);
                for j in 0..du {
                    nrow[j] += self.noise[j];
                }
            }
        }
        std::mem::swap(&mut self.u, &mut self.next);
    }

    fn finish(self: Box<Self>) -> SampleOutput {
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: None }
    }
}

/// Run ancestral sampling — thin wrapper over [`Ancestral`]; prefer the
/// [`Sampler`] trait for new code.
pub fn sample_ancestral(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    n: usize,
    rng: &mut Rng,
) -> SampleOutput {
    Ancestral { grid }.run(proc, model, n, rng, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::{Bdm, Vpsde};
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn posterior_matches_ddpm_formulas_on_vpsde() {
        // On DDPM the posterior variance must be the textbook
        // β̃ = (1−ᾱ_{t−1})/(1−ᾱ_t)·(1−ᾱ_t/ᾱ_{t−1}).
        let proc = Vpsde::standard(1);
        let grid = TimeGrid::uniform(proc.t_min, proc.t_max, 10);
        let steps = build_steps(&proc, &grid, KtKind::R);
        for i in 1..=10 {
            let (s, t) = (grid.ts[i], grid.ts[i - 1]);
            let (als, alt) = (proc.alpha(s), proc.alpha(t));
            let beta_tilde = (1.0 - alt) / (1.0 - als) * (1.0 - als / alt);
            let got = match steps[i - 1].noise {
                crate::math::linop::LinOp::Scalar(x) => x * x,
                _ => unreachable!(),
            };
            assert!(
                crate::math::close(got, beta_tilde, 1e-9, 1e-12),
                "step {i}: {got} vs {beta_tilde}"
            );
        }
    }

    #[test]
    fn ancestral_converges_at_high_nfe() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 500);
        let mut rng = Rng::seed_from(33);
        let out = sample_ancestral(proc.as_ref(), &oracle, &grid, 2_000, &mut rng);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.3, "ancestral@500 FD = {fd}");
    }

    #[test]
    fn ancestral_works_on_bdm() {
        let proc = Arc::new(Bdm::standard(4, 4));
        // Mixture of two 16-dim "images".
        let mut m1 = vec![0.0; 16];
        let mut m2 = vec![0.0; 16];
        for i in 0..16 {
            m1[i] = if i % 2 == 0 { 0.8 } else { -0.3 };
            m2[i] = if i < 8 { -0.6 } else { 0.5 };
        }
        let spec = crate::data::gmm::GmmSpec::new("imgs", vec![m1, m2], 0.01);
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 300);
        let mut rng = Rng::seed_from(34);
        let out = sample_ancestral(proc.as_ref(), &oracle, &grid, 500, &mut rng);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 1.0, "BDM ancestral@300 FD = {fd}");
    }
}
