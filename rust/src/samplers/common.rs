//! Shared sampler plumbing: batched states, prior draws, trajectory
//! recording, output container.

use crate::diffusion::process::Process;
use crate::math::linop::LinOp;
use crate::math::rng::Rng;

/// Result of a sampling run.
pub struct SampleOutput {
    /// Generated data-space samples, row-major `n × dim_x`.
    pub xs: Vec<f64>,
    /// Final state-space batch (`n × dim_u`) — useful for diagnostics.
    pub us: Vec<f64>,
    /// Score-network evaluations consumed (counted in *batched* calls ×1,
    /// matching how the paper reports NFE).
    pub nfe: usize,
    /// Optional recorded trajectory of batch element 0.
    pub traj: Option<Traj>,
}

/// Recorded trajectory of one sample (Fig. 1/3/5-style diagnostics).
#[derive(Clone, Debug, Default)]
pub struct Traj {
    pub ts: Vec<f64>,
    /// State at each recorded time (dim_u each).
    pub us: Vec<Vec<f64>>,
    /// ε_θ output at each recorded time (dim_u each; empty for samplers
    /// that don't evaluate ε at that point).
    pub eps: Vec<Vec<f64>>,
}

impl Traj {
    pub fn push(&mut self, t: f64, u: &[f64], eps: &[f64]) {
        self.ts.push(t);
        self.us.push(u.to_vec());
        self.eps.push(eps.to_vec());
    }
}

/// Apply a LinOp to each row of a batched state.
pub fn apply_rows(op: &LinOp, src: &[f64], dst: &mut [f64], du: usize) {
    debug_assert_eq!(src.len(), dst.len());
    for (s, d) in src.chunks_exact(du).zip(dst.chunks_exact_mut(du)) {
        op.apply(s, d);
    }
}

/// `dst += op · src` per row.
pub fn apply_add_rows(op: &LinOp, src: &[f64], dst: &mut [f64], du: usize) {
    for (s, d) in src.chunks_exact(du).zip(dst.chunks_exact_mut(du)) {
        op.apply_add(s, d);
    }
}

/// Draw the prior batch `u(T) ~ p_T` (n × dim_u).
pub fn draw_prior(proc: &dyn Process, n: usize, rng: &mut Rng) -> Vec<f64> {
    let du = proc.dim_u();
    let factor = proc.prior_factor();
    let mut us = vec![0.0; n * du];
    for row in us.chunks_exact_mut(du) {
        factor.sample_noise(rng, row);
    }
    us
}

/// Project the final state batch to data space.
pub fn project_batch(proc: &dyn Process, us: &[f64]) -> Vec<f64> {
    let du = proc.dim_u();
    let mut xs = Vec::with_capacity(us.len() / du * proc.dim_x());
    for row in us.chunks_exact(du) {
        xs.extend(proc.proj_data(row));
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::diffusion::{Cld, Process, Vpsde};

    #[test]
    fn prior_moments_match_process() {
        let proc = Vpsde::standard(3);
        let mut rng = Rng::seed_from(31);
        let us = draw_prior(&proc, 50_000, &mut rng);
        let c = crate::math::stats::covariance(&us, 3);
        for i in 0..3 {
            assert!((c[(i, i)] - 1.0).abs() < 0.03, "{}", c[(i, i)]);
        }
    }

    #[test]
    fn cld_prior_has_mass_scaled_velocity() {
        let proc = Cld::standard(2);
        let mut rng = Rng::seed_from(32);
        let us = draw_prior(&proc, 50_000, &mut rng);
        let c = crate::math::stats::covariance(&us, 4);
        assert!((c[(0, 0)] - 1.0).abs() < 0.03); // x variance 1
        assert!((c[(2, 2)] - 0.25).abs() < 0.02); // v variance M
    }

    #[test]
    fn project_batch_strips_velocity() {
        let proc = Cld::standard(2);
        let us = vec![1.0, 2.0, 9.0, 9.0, 3.0, 4.0, 9.0, 9.0];
        let xs = project_batch(&proc, &us);
        assert_eq!(xs, vec![1.0, 2.0, 3.0, 4.0]);
    }
}
