//! Euler–Maruyama on the marginal-equivalent SDE (paper Eq. 6):
//! `du = [F_t u − (1+λ²)/2 G_tG_tᵀ s_θ(u,t)]dt + λ G_t dw̄`,
//! integrated backwards on the grid. λ=0 degenerates to plain Euler on
//! the probability-flow ODE — the paper's weakest baseline, kept
//! deliberately (Fig. 4's "Euler" curve and Table 2's "EM" row).

use crate::diffusion::process::Process;
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers::common::{draw_prior, project_batch, SampleOutput, Traj};
use crate::score::model::ScoreModel;

pub fn sample_em(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    lambda: f64,
    n: usize,
    rng: &mut Rng,
    record_traj: bool,
) -> SampleOutput {
    let du = proc.dim_u();
    let ts = &grid.ts;
    let n_steps = grid.n_steps();
    let mut u = draw_prior(proc, n, rng);
    let mut eps = vec![0.0; n * du];
    let mut score_buf = vec![0.0; du];
    let mut drift = vec![0.0; du];
    let mut nfe = 0usize;
    let mut traj = record_traj.then(Traj::default);

    for i in (1..=n_steps).rev() {
        let t = ts[i];
        let dt = ts[i - 1] - ts[i]; // negative
        model.eps_batch(t, &u, &mut eps);
        nfe += 1;
        if let Some(tr) = traj.as_mut() {
            tr.push(t, &u[..du], &eps[..du]);
        }
        let f = proc.f_op(t);
        let ggt = proc.ggt_op(t);
        let g = proc.g_op(t);
        let kinv_t = proc.kt(model.kt_kind(), t).inv().transpose();
        let half = 0.5 * (1.0 + lambda * lambda);
        let sq = dt.abs().sqrt() * lambda;
        for (row, erow) in u.chunks_exact_mut(du).zip(eps.chunks_exact(du)) {
            // s = −K^{-T} ε
            kinv_t.apply(erow, &mut score_buf);
            for s in score_buf.iter_mut() {
                *s = -*s;
            }
            // drift = F u − half·GGᵀ s
            f.apply(row, &mut drift);
            let mut gs = vec![0.0; du];
            ggt.apply(&score_buf, &mut gs);
            for j in 0..du {
                row[j] += dt * (drift[j] - half * gs[j]);
            }
            if lambda > 0.0 {
                let mut z = vec![0.0; du];
                g.sample_noise(rng, &mut z);
                for j in 0..du {
                    row[j] += sq * z[j];
                }
            }
        }
    }
    if let Some(tr) = traj.as_mut() {
        tr.push(ts[0], &u[..du], &[]);
    }
    let xs = project_batch(proc, &u);
    SampleOutput { xs, us: u, nfe, traj }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn em_converges_with_many_steps() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 400);
        let mut rng = Rng::seed_from(21);
        let out = sample_em(proc.as_ref(), &oracle, &grid, 1.0, 2_000, &mut rng, false);
        assert_eq!(out.nfe, 400);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.5, "EM@400 FD = {fd}");
    }

    #[test]
    fn em_is_bad_at_low_nfe() {
        // The motivating failure: EM at small NFE is far worse than the
        // exponential-integrator path (paper Tables 2–3).
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let mut rng = Rng::seed_from(22);
        let em = sample_em(proc.as_ref(), &oracle, &grid, 1.0, 2_000, &mut rng, false);
        let fd_em = frechet_to_spec(&em.xs, &spec);

        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let mut rng = Rng::seed_from(22);
        let gd = crate::samplers::gddim::sample_deterministic(
            proc.as_ref(),
            &plan,
            &oracle,
            2_000,
            &mut rng,
            false,
        );
        let fd_gd = frechet_to_spec(&gd.xs, &spec);
        assert!(fd_gd < fd_em, "gDDIM {fd_gd} must beat EM {fd_em} at NFE 10");
    }

    #[test]
    fn lambda_zero_is_deterministic() {
        let proc = Arc::new(Vpsde::standard(1));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d_1d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 50);
        let mut r1 = Rng::seed_from(5);
        let mut r2 = Rng::seed_from(5);
        let a = sample_em(proc.as_ref(), &oracle, &grid, 0.0, 16, &mut r1, false);
        let b = sample_em(proc.as_ref(), &oracle, &grid, 0.0, 16, &mut r2, false);
        crate::math::assert_allclose(&a.xs, &b.xs, 0.0, 0.0, "λ=0 determinism");
    }
}
