//! Euler–Maruyama on the marginal-equivalent SDE (paper Eq. 6):
//! `du = [F_t u − (1+λ²)/2 G_tG_tᵀ s_θ(u,t)]dt + λ G_t dw̄`,
//! integrated backwards on the grid. λ=0 degenerates to plain Euler on
//! the probability-flow ODE — the paper's weakest baseline, kept
//! deliberately (Fig. 4's "Euler" curve and Table 2's "EM" row).

use crate::diffusion::process::{KtKind, Process};
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers::common::{draw_prior, project_batch, SampleOutput, Traj};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

/// Euler–Maruyama on the marginal-equivalent SDE (λ=0: plain Euler on
/// the probability-flow ODE).
pub struct Em<'a> {
    pub grid: &'a TimeGrid,
    pub lambda: f64,
}

struct EmState<'a> {
    proc: &'a dyn Process,
    grid: &'a TimeGrid,
    kt: KtKind,
    lambda: f64,
    du: usize,
    u: Vec<f64>,
    eps: Vec<f64>,
    score_buf: Vec<f64>,
    drift: Vec<f64>,
    nfe: usize,
    traj: Option<Traj>,
}

impl Sampler for Em<'_> {
    fn n_steps(&self) -> usize {
        self.grid.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        let du = proc.dim_u();
        let u = draw_prior(proc, n, rng);
        Box::new(EmState {
            proc,
            grid: self.grid,
            kt: model.kt_kind(),
            lambda: self.lambda,
            du,
            eps: vec![0.0; n * du],
            score_buf: vec![0.0; du],
            drift: vec![0.0; du],
            u,
            nfe: 0,
            traj: record_traj.then(Traj::default),
        })
    }
}

impl SamplerState for EmState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, rng: &mut Rng) {
        let ts = &self.grid.ts;
        let du = self.du;
        let lambda = self.lambda;
        let t = ts[i];
        let dt = ts[i - 1] - ts[i]; // negative
        score(ScoreRequest { t, u: &self.u }, &mut self.eps);
        self.nfe += 1;
        if let Some(tr) = self.traj.as_mut() {
            tr.push(t, &self.u[..du], &self.eps[..du]);
        }
        let f = self.proc.f_op(t);
        let ggt = self.proc.ggt_op(t);
        let g = self.proc.g_op(t);
        let kinv_t = self.proc.kt(self.kt, t).inv().transpose();
        let half = 0.5 * (1.0 + lambda * lambda);
        let sq = dt.abs().sqrt() * lambda;
        for (row, erow) in self.u.chunks_exact_mut(du).zip(self.eps.chunks_exact(du)) {
            // s = −K^{-T} ε
            kinv_t.apply(erow, &mut self.score_buf);
            for s in self.score_buf.iter_mut() {
                *s = -*s;
            }
            // drift = F u − half·GGᵀ s
            f.apply(row, &mut self.drift);
            let mut gs = vec![0.0; du];
            ggt.apply(&self.score_buf, &mut gs);
            for j in 0..du {
                row[j] += dt * (self.drift[j] - half * gs[j]);
            }
            if lambda > 0.0 {
                let mut z = vec![0.0; du];
                g.sample_noise(rng, &mut z);
                for j in 0..du {
                    row[j] += sq * z[j];
                }
            }
        }
    }

    fn finish(mut self: Box<Self>) -> SampleOutput {
        if let Some(tr) = self.traj.as_mut() {
            tr.push(self.grid.ts[0], &self.u[..self.du], &[]);
        }
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: self.traj }
    }
}

/// Run Euler–Maruyama — thin wrapper over [`Em`]; prefer the [`Sampler`]
/// trait for new code.
pub fn sample_em(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    lambda: f64,
    n: usize,
    rng: &mut Rng,
    record_traj: bool,
) -> SampleOutput {
    Em { grid, lambda }.run(proc, model, n, rng, record_traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn em_converges_with_many_steps() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 400);
        let mut rng = Rng::seed_from(21);
        let out = sample_em(proc.as_ref(), &oracle, &grid, 1.0, 2_000, &mut rng, false);
        assert_eq!(out.nfe, 400);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.5, "EM@400 FD = {fd}");
    }

    #[test]
    fn em_is_bad_at_low_nfe() {
        // The motivating failure: EM at small NFE is far worse than the
        // exponential-integrator path (paper Tables 2–3).
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let mut rng = Rng::seed_from(22);
        let em = sample_em(proc.as_ref(), &oracle, &grid, 1.0, 2_000, &mut rng, false);
        let fd_em = frechet_to_spec(&em.xs, &spec);

        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let mut rng = Rng::seed_from(22);
        let gd = crate::samplers::gddim::sample_deterministic(
            proc.as_ref(),
            &plan,
            &oracle,
            2_000,
            &mut rng,
            false,
        );
        let fd_gd = frechet_to_spec(&gd.xs, &spec);
        assert!(fd_gd < fd_em, "gDDIM {fd_gd} must beat EM {fd_em} at NFE 10");
    }

    #[test]
    fn lambda_zero_is_deterministic() {
        let proc = Arc::new(Vpsde::standard(1));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d_1d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 50);
        let mut r1 = Rng::seed_from(5);
        let mut r2 = Rng::seed_from(5);
        let a = sample_em(proc.as_ref(), &oracle, &grid, 0.0, 16, &mut r1, false);
        let b = sample_em(proc.as_ref(), &oracle, &grid, 0.0, 16, &mut r2, false);
        crate::math::assert_allclose(&a.xs, &b.xs, 0.0, 0.0, "λ=0 determinism");
    }
}
