//! gDDIM — the paper's contribution (Sec. 4, App. B.2.4 Algo 1).
//!
//! * Deterministic (λ=0): exponential-integrator multistep predictor
//!   (Eq. 19) with optional corrector pass (Eq. 45; Table 8's "PC").
//! * Stochastic (λ>0): the exact linear-SDE solve under the Prop 5 score
//!   approximator — the Gaussian update of Eq. 22 with noise cov Eq. 23.
//!
//! All coefficients come precomputed in a [`SamplerPlan`] (Stage I);
//! the hot loop is pure BLAS-1-style arithmetic plus one score call per
//! step, so coordinator overhead stays negligible relative to the model.

use std::collections::VecDeque;

use crate::coeffs::plan::SamplerPlan;
use crate::diffusion::process::Process;
use crate::math::rng::Rng;
use crate::samplers::common::{
    apply_add_rows, apply_rows, draw_prior, project_batch, SampleOutput, Traj,
};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

/// Deterministic gDDIM (multistep predictor, optional PC) on a prebuilt
/// Stage-I plan.
///
/// NFE: `N` predictor-only, `2N−1` with corrector (paper Table 8).
pub struct GddimDet<'a> {
    pub plan: &'a SamplerPlan,
}

struct DetState<'a> {
    plan: &'a SamplerPlan,
    proc: &'a dyn Process,
    du: usize,
    with_corr: bool,
    u: Vec<f64>,
    next: Vec<f64>,
    /// ε history: hist[0] is ε at the current time t_i, hist[1] at t_{i+1}, …
    hist: VecDeque<Vec<f64>>,
    nfe: usize,
    traj: Option<Traj>,
}

impl Sampler for GddimDet<'_> {
    fn n_steps(&self) -> usize {
        self.plan.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        assert_eq!(self.plan.cfg.lambda, 0.0, "use GddimSde for λ>0");
        assert_eq!(
            model.kt_kind(),
            self.plan.cfg.kt,
            "plan/model K_t parameterization mismatch"
        );
        let du = proc.dim_u();
        let u = draw_prior(proc, n, rng);
        Box::new(DetState {
            plan: self.plan,
            proc,
            du,
            with_corr: self.plan.cfg.with_corrector && !self.plan.corr.is_empty(),
            next: vec![0.0; n * du],
            hist: VecDeque::new(),
            u,
            nfe: 0,
            traj: record_traj.then(Traj::default),
        })
    }
}

impl SamplerState for DetState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, _rng: &mut Rng) {
        let ts = &self.plan.grid.ts;
        let du = self.du;
        if self.hist.is_empty() {
            // First step: seed the ε history at t_N.
            debug_assert_eq!(i, self.plan.n_steps(), "gDDIM steps count down from n_steps");
            let mut eps0 = vec![0.0; self.u.len()];
            score(ScoreRequest { t: ts[self.plan.n_steps()], u: &self.u }, &mut eps0);
            self.nfe += 1;
            if let Some(tr) = self.traj.as_mut() {
                tr.push(ts[self.plan.n_steps()], &self.u[..du], &eps0[..du]);
            }
            self.hist.push_front(eps0);
        }
        let step = i - 1; // plan arrays are indexed by i−1
        let coeffs = &self.plan.pred[step];
        // Predictor: ū(t_{i−1}) = Ψ u(t_i) + Σ_j C_ij ε_j   (Eq. 19a)
        apply_rows(&self.plan.psi[step], &self.u, &mut self.next, du);
        for (j, c) in coeffs.iter().enumerate() {
            apply_add_rows(c, &self.hist[j], &mut self.next, du);
        }

        if self.with_corr && i > 1 {
            // ε̄ at the predicted state (paper Table 8: "PC adds one more
            // correcting step after each predicting step except the last",
            // for a total of 2N−1 NFE).
            let mut eps_bar = vec![0.0; self.u.len()];
            score(ScoreRequest { t: ts[i - 1], u: &self.next }, &mut eps_bar);
            self.nfe += 1;
            // Corrector (Eq. 45): rebuild from u(t_i) with ᶜC.
            let cc = &self.plan.corr[step];
            apply_rows(&self.plan.psi[step], &self.u, &mut self.next, du);
            apply_add_rows(&cc[0], &eps_bar, &mut self.next, du);
            for (jj, c) in cc.iter().enumerate().skip(1) {
                apply_add_rows(c, &self.hist[jj - 1], &mut self.next, du);
            }
            std::mem::swap(&mut self.u, &mut self.next);
            // Fresh ε at the corrected state feeds the next predictor.
            let mut eps_new = vec![0.0; self.u.len()];
            score(ScoreRequest { t: ts[i - 1], u: &self.u }, &mut eps_new);
            self.nfe += 1;
            self.hist.push_front(eps_new);
        } else if self.with_corr {
            // Final step: predictor only.
            std::mem::swap(&mut self.u, &mut self.next);
        } else {
            std::mem::swap(&mut self.u, &mut self.next);
            if i > 1 {
                let mut eps_new = vec![0.0; self.u.len()];
                score(ScoreRequest { t: ts[i - 1], u: &self.u }, &mut eps_new);
                self.nfe += 1;
                self.hist.push_front(eps_new);
            }
        }
        while self.hist.len() > self.plan.cfg.q {
            self.hist.pop_back();
        }
        if let Some(tr) = self.traj.as_mut() {
            let e = self.hist.front().map(|h| &h[..du]).unwrap_or(&[]);
            tr.push(ts[i - 1], &self.u[..du], e);
        }
    }

    fn finish(self: Box<Self>) -> SampleOutput {
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: self.traj }
    }
}

/// Stochastic gDDIM (Eq. 22) on a plan built with λ > 0 (which implies
/// `K_t = R_t` and q = 1).
pub struct GddimSde<'a> {
    pub plan: &'a SamplerPlan,
}

struct SdeState<'a> {
    plan: &'a SamplerPlan,
    proc: &'a dyn Process,
    du: usize,
    u: Vec<f64>,
    eps: Vec<f64>,
    next: Vec<f64>,
    noise: Vec<f64>,
    nfe: usize,
    traj: Option<Traj>,
}

impl Sampler for GddimSde<'_> {
    fn n_steps(&self) -> usize {
        self.plan.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        _model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        assert!(self.plan.cfg.lambda > 0.0, "use GddimDet for λ=0");
        assert!(!self.plan.stoch_mean.is_empty());
        let du = proc.dim_u();
        let u = draw_prior(proc, n, rng);
        Box::new(SdeState {
            plan: self.plan,
            proc,
            du,
            eps: vec![0.0; n * du],
            next: vec![0.0; n * du],
            noise: vec![0.0; du],
            u,
            nfe: 0,
            traj: record_traj.then(Traj::default),
        })
    }
}

impl SamplerState for SdeState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, rng: &mut Rng) {
        let ts = &self.plan.grid.ts;
        let du = self.du;
        let step = i - 1;
        score(ScoreRequest { t: ts[i], u: &self.u }, &mut self.eps);
        self.nfe += 1;
        if let Some(tr) = self.traj.as_mut() {
            tr.push(ts[i], &self.u[..du], &self.eps[..du]);
        }
        // mean: Ψ u + [Ψ̂ − Ψ]K_s ε   (Eq. 22)
        apply_rows(&self.plan.psi[step], &self.u, &mut self.next, du);
        apply_add_rows(&self.plan.stoch_mean[step], &self.eps, &mut self.next, du);
        // noise: chol(P_st) z
        for row in self.next.chunks_exact_mut(du) {
            self.plan.stoch_noise[step].sample_noise(rng, &mut self.noise);
            for j in 0..du {
                row[j] += self.noise[j];
            }
        }
        std::mem::swap(&mut self.u, &mut self.next);
    }

    fn finish(mut self: Box<Self>) -> SampleOutput {
        if let Some(tr) = self.traj.as_mut() {
            tr.push(self.plan.grid.ts[0], &self.u[..self.du], &[]);
        }
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: self.traj }
    }
}

/// Run deterministic gDDIM — thin wrapper over [`GddimDet`]; prefer the
/// [`Sampler`] trait for new code.
pub fn sample_deterministic(
    proc: &dyn Process,
    plan: &SamplerPlan,
    model: &dyn ScoreModel,
    n: usize,
    rng: &mut Rng,
    record_traj: bool,
) -> SampleOutput {
    GddimDet { plan }.run(proc, model, n, rng, record_traj)
}

/// Run stochastic gDDIM — thin wrapper over [`GddimSde`]; prefer the
/// [`Sampler`] trait for new code.
pub fn sample_stochastic(
    proc: &dyn Process,
    plan: &SamplerPlan,
    model: &dyn ScoreModel,
    n: usize,
    rng: &mut Rng,
    record_traj: bool,
) -> SampleOutput {
    GddimSde { plan }.run(proc, model, n, rng, record_traj)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coeffs::plan::PlanConfig;
    use crate::data::gmm::GmmSpec;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::{Cld, TimeGrid, Vpsde};
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    /// Paper Sec. 3: "DDIMs can recover the single data point in this toy
    /// example in one step" — deterministic gDDIM, Dirac data, N=1.
    #[test]
    fn one_step_exact_recovery_on_dirac_vpsde() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = GmmSpec {
            name: "dirac".into(),
            d: 2,
            weights: vec![1.0],
            means: vec![vec![0.7, -1.2]],
            var: 0.0,
        };
        let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 1);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let mut rng = Rng::seed_from(100);
        let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 64, &mut rng, false);
        assert_eq!(out.nfe, 1);
        // Every sample lands (nearly) on the data point: the residual is
        // O(α_{t_min}) from stopping at t_min rather than 0.
        for row in out.xs.chunks_exact(2) {
            assert!((row[0] - 0.7).abs() < 0.05, "{row:?}");
            assert!((row[1] + 1.2).abs() < 0.05, "{row:?}");
        }
    }

    /// Prop 4 analog on CLD: Gaussian (Dirac data + velocity Gaussian)
    /// recovered in very few steps with K=R.
    #[test]
    fn few_step_recovery_on_dirac_cld() {
        let proc = Arc::new(Cld::standard(1));
        let spec = GmmSpec {
            name: "dirac".into(),
            d: 1,
            weights: vec![1.0],
            means: vec![vec![1.1]],
            var: 0.0,
        };
        let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 2);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let mut rng = Rng::seed_from(101);
        let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 64, &mut rng, false);
        for row in out.xs.chunks_exact(1) {
            assert!((row[0] - 1.1).abs() < 0.1, "{}", row[0]);
        }
    }

    #[test]
    fn matches_analytic_ddim_formula_on_vpsde() {
        // Eq. 12: the update must equal the textbook DDIM step exactly.
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        // Manual DDIM from the same prior draw:
        let mut rng_a = Rng::seed_from(7);
        let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 4, &mut rng_a, false);
        let mut rng_b = Rng::seed_from(7);
        let mut u = crate::samplers::common::draw_prior(proc.as_ref(), 4, &mut rng_b);
        let ts = &grid.ts;
        for i in (1..=5).rev() {
            let (s, t) = (ts[i], ts[i - 1]);
            let (als, alt) = (proc.alpha(s), proc.alpha(t));
            let ratio = (alt / als).sqrt();
            let coef = (1.0 - alt).sqrt() - (1.0 - als).sqrt() * ratio;
            let mut eps = vec![0.0; u.len()];
            oracle.eps_batch(s, &u, &mut eps);
            for (uu, ee) in u.iter_mut().zip(&eps) {
                *uu = ratio * *uu + coef * *ee;
            }
        }
        crate::math::assert_allclose(&out.us, &u, 1e-6, 1e-8, "gDDIM vs analytic DDIM");
    }

    #[test]
    fn stochastic_reduces_to_deterministic_at_tiny_lambda() {
        // Prop 7 at the sampler level: with the same RNG draws the λ→0
        // stochastic path converges to the deterministic one.
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let det =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let sto = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::stochastic(1e-6));
        let mut rng_a = Rng::seed_from(9);
        let a = sample_deterministic(proc.as_ref(), &det, &oracle, 8, &mut rng_a, false);
        let mut rng_b = Rng::seed_from(9);
        let b = sample_stochastic(proc.as_ref(), &sto, &oracle, 8, &mut rng_b, false);
        crate::math::assert_allclose(&a.xs, &b.xs, 1e-3, 1e-4, "λ→0 limit");
    }

    #[test]
    fn multistep_beats_single_step_at_low_nfe() {
        // The headline mechanism (Table 5): higher q → better quality at
        // the same NFE, on CLD with the exact score.
        let proc = Arc::new(Cld::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 20);
        let mut fds = Vec::new();
        for q in [1usize, 2] {
            let plan =
                SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(q, KtKind::R));
            let mut rng = Rng::seed_from(11);
            let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 2_000, &mut rng, false);
            assert_eq!(out.nfe, 20);
            fds.push(frechet_to_spec(&out.xs, &spec));
        }
        assert!(
            fds[1] < fds[0],
            "q=2 (FD {}) should beat q=1 (FD {}) at NFE 20",
            fds[1],
            fds[0]
        );
    }

    #[test]
    fn r_parameterization_beats_l_on_cld() {
        // Table 1's core claim with the exact score.
        let proc = Arc::new(Cld::standard(2));
        let spec = presets::gmm2d();
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 20);
        let mut fds = Vec::new();
        for kt in [KtKind::R, KtKind::L] {
            let oracle = GmmOracle::new(proc.clone(), spec.clone(), kt);
            let plan = SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, kt));
            let mut rng = Rng::seed_from(13);
            let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 2_000, &mut rng, false);
            fds.push(frechet_to_spec(&out.xs, &spec));
        }
        assert!(
            fds[0] < fds[1],
            "K=R (FD {}) must beat K=L (FD {}) at NFE 20 on CLD",
            fds[0],
            fds[1]
        );
    }

    #[test]
    fn corrector_consumes_2n_minus_1_nfe() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let cfg = PlanConfig { q: 2, with_corrector: true, ..PlanConfig::default() };
        let plan = SamplerPlan::build(proc.as_ref(), &grid, &cfg);
        let mut rng = Rng::seed_from(14);
        let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 16, &mut rng, false);
        assert_eq!(out.nfe, 2 * 10 - 1);
    }

    #[test]
    fn trajectory_is_recorded_on_grid() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 6);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let mut rng = Rng::seed_from(15);
        let out = sample_deterministic(proc.as_ref(), &plan, &oracle, 2, &mut rng, true);
        let tr = out.traj.unwrap();
        assert_eq!(tr.ts.len(), 7);
        assert!(tr.ts[0] > tr.ts[6], "recorded T → t_min");
    }
}
