//! 2nd-order Heun on the probability-flow ODE — the paper's
//! "2ⁿᵈ Heun††" baseline (Karras et al. 2022's deterministic sampler,
//! which the paper notes "is essentially a variant of DEIS"). Grid-based:
//! each step does an Euler predictor + trapezoidal correction; the final
//! step falls back to Euler (Karras convention), so NFE = 2N−1.

use crate::diffusion::process::Process;
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers::common::{draw_prior, project_batch, SampleOutput};
use crate::score::model::ScoreModel;

/// Probability-flow drift for a whole batch.
fn drift_batch(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    t: f64,
    u: &[f64],
    out: &mut [f64],
    eps: &mut [f64],
) {
    let du = proc.dim_u();
    model.eps_batch(t, u, eps);
    let f = proc.f_op(t);
    let ggt = proc.ggt_op(t);
    let kinv_t = proc.kt(model.kt_kind(), t).inv().transpose();
    let mut score = vec![0.0; du];
    let mut fu = vec![0.0; du];
    let mut gs = vec![0.0; du];
    for ((urow, erow), orow) in
        u.chunks_exact(du).zip(eps.chunks_exact(du)).zip(out.chunks_exact_mut(du))
    {
        kinv_t.apply(erow, &mut score);
        for s in score.iter_mut() {
            *s = -*s;
        }
        f.apply(urow, &mut fu);
        ggt.apply(&score, &mut gs);
        for j in 0..du {
            orow[j] = fu[j] - 0.5 * gs[j];
        }
    }
}

pub fn sample_heun(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    n: usize,
    rng: &mut Rng,
) -> SampleOutput {
    let du = proc.dim_u();
    let ts = &grid.ts;
    let n_steps = grid.n_steps();
    let mut u = draw_prior(proc, n, rng);
    let mut k1 = vec![0.0; n * du];
    let mut k2 = vec![0.0; n * du];
    let mut mid = vec![0.0; n * du];
    let mut eps = vec![0.0; n * du];
    let mut nfe = 0usize;

    for i in (1..=n_steps).rev() {
        let (s, t) = (ts[i], ts[i - 1]);
        let dt = t - s;
        drift_batch(proc, model, s, &u, &mut k1, &mut eps);
        nfe += 1;
        if i == 1 {
            // Final step: Euler (Karras convention).
            for (uu, kk) in u.iter_mut().zip(&k1) {
                *uu += dt * kk;
            }
            break;
        }
        for j in 0..u.len() {
            mid[j] = u[j] + dt * k1[j];
        }
        drift_batch(proc, model, t, &mid, &mut k2, &mut eps);
        nfe += 1;
        for j in 0..u.len() {
            u[j] += 0.5 * dt * (k1[j] + k2[j]);
        }
    }
    let xs = project_batch(proc, &u);
    SampleOutput { xs, us: u, nfe, traj: None }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn nfe_is_2n_minus_1() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let mut rng = Rng::seed_from(51);
        let out = sample_heun(proc.as_ref(), &oracle, &grid, 16, &mut rng);
        assert_eq!(out.nfe, 19);
    }

    #[test]
    fn heun_beats_euler_at_same_grid() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 25);
        let mut r1 = Rng::seed_from(52);
        let heun = sample_heun(proc.as_ref(), &oracle, &grid, 1_500, &mut r1);
        let mut r2 = Rng::seed_from(52);
        let euler =
            crate::samplers::em::sample_em(proc.as_ref(), &oracle, &grid, 0.0, 1_500, &mut r2, false);
        let fh = frechet_to_spec(&heun.xs, &spec);
        let fe = frechet_to_spec(&euler.xs, &spec);
        assert!(fh < fe, "Heun {fh} should beat Euler {fe} on the same grid");
    }
}
