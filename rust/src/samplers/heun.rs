//! 2nd-order Heun on the probability-flow ODE — the paper's
//! "2ⁿᵈ Heun††" baseline (Karras et al. 2022's deterministic sampler,
//! which the paper notes "is essentially a variant of DEIS"). Grid-based:
//! each step does an Euler predictor + trapezoidal correction; the final
//! step falls back to Euler (Karras convention), so NFE = 2N−1.

use crate::diffusion::process::{KtKind, Process};
use crate::diffusion::schedule::TimeGrid;
use crate::math::rng::Rng;
use crate::samplers::common::{draw_prior, project_batch, SampleOutput};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

/// Probability-flow drift for a whole batch (ε via the score boundary).
fn drift_batch(
    proc: &dyn Process,
    kt: KtKind,
    score: &mut ScoreFn<'_>,
    t: f64,
    u: &[f64],
    out: &mut [f64],
    eps: &mut [f64],
) {
    let du = proc.dim_u();
    score(ScoreRequest { t, u }, eps);
    let f = proc.f_op(t);
    let ggt = proc.ggt_op(t);
    let kinv_t = proc.kt(kt, t).inv().transpose();
    let mut s_buf = vec![0.0; du];
    let mut fu = vec![0.0; du];
    let mut gs = vec![0.0; du];
    for ((urow, erow), orow) in
        u.chunks_exact(du).zip(eps.chunks_exact(du)).zip(out.chunks_exact_mut(du))
    {
        kinv_t.apply(erow, &mut s_buf);
        for s in s_buf.iter_mut() {
            *s = -*s;
        }
        f.apply(urow, &mut fu);
        ggt.apply(&s_buf, &mut gs);
        for j in 0..du {
            orow[j] = fu[j] - 0.5 * gs[j];
        }
    }
}

/// 2nd-order Heun on the probability-flow ODE.
pub struct Heun<'a> {
    pub grid: &'a TimeGrid,
}

struct HeunState<'a> {
    proc: &'a dyn Process,
    grid: &'a TimeGrid,
    kt: KtKind,
    u: Vec<f64>,
    k1: Vec<f64>,
    k2: Vec<f64>,
    mid: Vec<f64>,
    eps: Vec<f64>,
    nfe: usize,
}

impl Sampler for Heun<'_> {
    fn n_steps(&self) -> usize {
        self.grid.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        _record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        let du = proc.dim_u();
        let u = draw_prior(proc, n, rng);
        Box::new(HeunState {
            proc,
            grid: self.grid,
            kt: model.kt_kind(),
            k1: vec![0.0; n * du],
            k2: vec![0.0; n * du],
            mid: vec![0.0; n * du],
            eps: vec![0.0; n * du],
            u,
            nfe: 0,
        })
    }
}

impl SamplerState for HeunState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, _rng: &mut Rng) {
        let ts = &self.grid.ts;
        let (s, t) = (ts[i], ts[i - 1]);
        let dt = t - s;
        drift_batch(self.proc, self.kt, score, s, &self.u, &mut self.k1, &mut self.eps);
        self.nfe += 1;
        if i == 1 {
            // Final step: Euler (Karras convention).
            for (uu, kk) in self.u.iter_mut().zip(&self.k1) {
                *uu += dt * kk;
            }
            return;
        }
        for j in 0..self.u.len() {
            self.mid[j] = self.u[j] + dt * self.k1[j];
        }
        drift_batch(self.proc, self.kt, score, t, &self.mid, &mut self.k2, &mut self.eps);
        self.nfe += 1;
        for j in 0..self.u.len() {
            self.u[j] += 0.5 * dt * (self.k1[j] + self.k2[j]);
        }
    }

    fn finish(self: Box<Self>) -> SampleOutput {
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: None }
    }
}

/// Run 2nd-order Heun — thin wrapper over [`Heun`]; prefer the
/// [`Sampler`] trait for new code.
pub fn sample_heun(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    n: usize,
    rng: &mut Rng,
) -> SampleOutput {
    Heun { grid }.run(proc, model, n, rng, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn nfe_is_2n_minus_1() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 10);
        let mut rng = Rng::seed_from(51);
        let out = sample_heun(proc.as_ref(), &oracle, &grid, 16, &mut rng);
        assert_eq!(out.nfe, 19);
    }

    #[test]
    fn heun_beats_euler_at_same_grid() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 25);
        let mut r1 = Rng::seed_from(52);
        let heun = sample_heun(proc.as_ref(), &oracle, &grid, 1_500, &mut r1);
        let mut r2 = Rng::seed_from(52);
        let euler = crate::samplers::em::sample_em(
            proc.as_ref(),
            &oracle,
            &grid,
            0.0,
            1_500,
            &mut r2,
            false,
        );

        let fh = frechet_to_spec(&heun.xs, &spec);
        let fe = frechet_to_spec(&euler.xs, &spec);
        assert!(fh < fe, "Heun {fh} should beat Euler {fe} on the same grid");
    }
}
