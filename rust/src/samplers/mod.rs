//! Stage II — the samplers (paper App. C.4 "Online execution of gDDIM")
//! plus every baseline the paper's evaluation compares against:
//!
//! | paper name                    | module       |
//! |-------------------------------|--------------|
//! | gDDIM (det., multistep P/PC)  | [`gddim`]    |
//! | gDDIM (stochastic, Eq. 22)    | [`gddim`]    |
//! | Euler–Maruyama on Eq. 6       | [`em`]       |
//! | Ancestral sampling            | [`ancestral`]|
//! | Prob.Flow RK45                | [`rk45`]     |
//! | 2nd-order Heun (Karras-style) | [`heun`]     |
//! | SSCS (Dockhorn et al., CLD)   | [`sscs`]     |
//!
//! All samplers share the batched-state conventions of [`common`] and
//! report NFE so the benches reproduce the paper's FID-vs-NFE axes.

pub mod common;
pub mod gddim;
pub mod em;
pub mod ancestral;
pub mod rk45;
pub mod heun;
pub mod sscs;

pub use common::{SampleOutput, Traj};
