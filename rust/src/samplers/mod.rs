//! Stage II — the samplers (paper App. C.4 "Online execution of gDDIM")
//! plus every baseline the paper's evaluation compares against, unified
//! behind one step-level [`Sampler`] trait:
//!
//! | paper name                    | module       | impl            |
//! |-------------------------------|--------------|-----------------|
//! | gDDIM (det., multistep P/PC)  | [`gddim`]    | [`GddimDet`]    |
//! | gDDIM (stochastic, Eq. 22)    | [`gddim`]    | [`GddimSde`]    |
//! | Euler–Maruyama on Eq. 6       | [`em`]       | [`Em`]          |
//! | Ancestral sampling            | [`ancestral`]| [`Ancestral`]   |
//! | Prob.Flow RK45                | [`rk45`]     | [`Rk45`]        |
//! | 2nd-order Heun (Karras-style) | [`heun`]     | [`Heun`]        |
//! | SSCS (Dockhorn et al., CLD)   | [`sscs`]     | [`Sscs`]        |
//!
//! The paper's central claim (Sec. 4, App. C.4) is that all of these are
//! the *same object*: a numerical scheme stepping the reverse SDE/ODE
//! under a score approximation, differing only in coefficients. The trait
//! encodes that: [`Sampler::init`] draws the prior and builds per-run
//! state, [`SamplerState::step`] advances one grid interval, and every
//! score-network evaluation crosses an explicit [`ScoreRequest`] → ε
//! boundary ([`ScoreFn`]) instead of being buried in a per-sampler loop.
//! That boundary is what lets the serving engine coalesce score calls
//! across concurrent jobs that share `(process, dataset, t)`.
//!
//! Configuration lives in the owned, hashable [`SamplerSpec`] (module
//! [`spec`]), which the server uses as the batchable part of a request
//! key and which instantiates any of the seven impls uniformly.
//!
//! All samplers share the batched-state conventions of [`common`] and
//! report NFE so the benches reproduce the paper's FID-vs-NFE axes. The
//! historical free functions (`gddim::sample_deterministic`,
//! `em::sample_em`, …) survive as thin wrappers over the trait; prefer
//! the trait for new code.

pub mod common;
pub mod spec;
pub mod gddim;
pub mod em;
pub mod ancestral;
pub mod rk45;
pub mod heun;
pub mod sscs;

pub use ancestral::Ancestral;
pub use common::{SampleOutput, Traj};
pub use em::Em;
pub use gddim::{GddimDet, GddimSde};
pub use heun::Heun;
pub use rk45::Rk45;
pub use spec::{OrderedF64, SamplerSpec};
pub use sscs::Sscs;

use crate::diffusion::process::Process;
use crate::math::rng::Rng;
use crate::score::model::ScoreModel;

/// One batched score evaluation crossing the sampler ↔ model boundary:
/// "give me `ε_θ(u, t)` for these states". Samplers *request* scores
/// through this type instead of holding a model, so a driver (engine,
/// batcher) can route, coalesce, or instrument the calls.
pub struct ScoreRequest<'a> {
    /// Diffusion time of the evaluation (shared by the whole batch).
    pub t: f64,
    /// Batched states, row-major `n × dim_u`.
    pub u: &'a [f64],
}

/// The score boundary a [`SamplerState`] pulls on: fill `eps` (same shape
/// as `req.u`) with `ε_θ` for the request. [`model_score`] is the plain
/// model-backed implementation; the serving layer can substitute a
/// coalescing one.
pub type ScoreFn<'s> = dyn for<'r> FnMut(ScoreRequest<'r>, &mut [f64]) + 's;

/// The plain [`ScoreFn`] implementation: forward every request to
/// `model.eps_batch` unchanged (what [`Sampler::run`] and the engine's
/// shard driver use).
pub fn model_score(
    model: &dyn ScoreModel,
) -> impl for<'r> FnMut(ScoreRequest<'r>, &mut [f64]) + '_ {
    move |req, out| model.eps_batch(req.t, req.u, out)
}

/// A Stage-II sampling scheme: coefficients + step rule, independent of
/// any particular run. Implementations are cheap handles (borrowing a
/// [`crate::coeffs::SamplerPlan`] or a [`crate::diffusion::TimeGrid`]),
/// so they can be built per batch on the stack or boxed from a
/// [`SamplerSpec`].
pub trait Sampler: Send + Sync {
    /// Macro steps the default driver runs, `i = n_steps() … 1`, step `i`
    /// advancing `t_i → t_{i−1}`. Adaptive samplers ([`Rk45`]) report 1
    /// and do their own sub-stepping inside it.
    fn n_steps(&self) -> usize;

    /// Draw the prior `u(T) ~ p_T` and build the per-run state machine.
    /// `model` is consulted only for its `K_t` parameterization (and
    /// compatibility assertions) — score values flow exclusively through
    /// the [`ScoreFn`] handed to [`SamplerState::step`].
    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        record_traj: bool,
    ) -> Box<dyn SamplerState + 'a>;

    /// Default whole-trajectory driver: `init`, then `step` from
    /// `n_steps()` down to 1 with the plain model-backed score boundary,
    /// then `finish`. Byte-identical to driving the state machine by
    /// hand (which is exactly what the engine does per shard).
    fn run(
        &self,
        proc: &dyn Process,
        model: &dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        record_traj: bool,
    ) -> SampleOutput {
        let mut state = self.init(proc, model, n, rng, record_traj);
        let mut score = model_score(model);
        for i in (1..=self.n_steps()).rev() {
            state.step(i, &mut score, rng);
        }
        state.finish()
    }
}

/// The per-run state machine produced by [`Sampler::init`]: the batched
/// state plus whatever the scheme carries between steps (ε history for
/// multistep gDDIM, posterior operators for ancestral, …).
pub trait SamplerState: Send {
    /// Advance one macro step `t_i → t_{i−1}` (`i` counts down from
    /// [`Sampler::n_steps`] to 1). Every score evaluation the step needs
    /// goes through `score`; injected noise draws from `rng` in a fixed
    /// order, which is what keeps sharded runs bit-reproducible.
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, rng: &mut Rng);

    /// Project the final state to data space and hand back the output.
    fn finish(self: Box<Self>) -> SampleOutput;
}
