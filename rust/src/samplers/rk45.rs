//! Adaptive "Prob.Flow, RK45" baseline (paper Table 3): Dormand–Prince
//! on the probability-flow ODE (Eq. 7) over the whole batch, with the
//! tolerance as the NFE knob ("we tune its tolerance hyperparameters so
//! that the real NFE is close but not equal to the given NFE").

use crate::diffusion::process::{KtKind, Process};
use crate::math::ode::rk45_integrate;
use crate::math::rng::Rng;
use crate::samplers::common::{draw_prior, project_batch, SampleOutput};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

/// Adaptive Dormand–Prince on the probability-flow ODE. The step-level
/// decomposition is degenerate by design: the controller owns the time
/// axis, so the whole integration is one macro step (`n_steps() == 1`)
/// and NFE is whatever the tolerance demanded.
pub struct Rk45 {
    pub rtol: f64,
}

struct Rk45State<'a> {
    proc: &'a dyn Process,
    kt: KtKind,
    rtol: f64,
    u: Vec<f64>,
    nfe: usize,
}

impl Sampler for Rk45 {
    fn n_steps(&self) -> usize {
        1
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        _record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        let u = draw_prior(proc, n, rng);
        Box::new(Rk45State { proc, kt: model.kt_kind(), rtol: self.rtol, u, nfe: 0 })
    }
}

impl SamplerState for Rk45State<'_> {
    fn step(&mut self, _i: usize, score: &mut ScoreFn<'_>, _rng: &mut Rng) {
        let proc = self.proc;
        let kt = self.kt;
        let du = proc.dim_u();
        let mut eps = vec![0.0; self.u.len()];
        let mut s_buf = vec![0.0; du];
        let mut drift = vec![0.0; du];
        let mut gs = vec![0.0; du];
        let nfe_ref = &mut self.nfe;
        rk45_integrate(
            &mut |t: f64, y: &[f64], dy: &mut [f64]| {
                *nfe_ref += 1;
                score(ScoreRequest { t, u: y }, &mut eps);
                let f = proc.f_op(t);
                let ggt = proc.ggt_op(t);
                let kinv_t = proc.kt(kt, t).inv().transpose();
                for ((yrow, erow), drow) in y
                    .chunks_exact(du)
                    .zip(eps.chunks_exact(du))
                    .zip(dy.chunks_exact_mut(du))
                {
                    kinv_t.apply(erow, &mut s_buf);
                    for s in s_buf.iter_mut() {
                        *s = -*s;
                    }
                    f.apply(yrow, &mut drift);
                    ggt.apply(&s_buf, &mut gs);
                    for j in 0..du {
                        drow[j] = drift[j] - 0.5 * gs[j];
                    }
                }
            },
            proc.t_max(),
            proc.t_min(),
            self.rtol,
            self.rtol * 1e-2,
            &mut self.u,
        );
    }

    fn finish(self: Box<Self>) -> SampleOutput {
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: None }
    }
}

/// Run adaptive RK45 — thin wrapper over [`Rk45`]; prefer the
/// [`Sampler`] trait for new code.
pub fn sample_rk45(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    rtol: f64,
    n: usize,
    rng: &mut Rng,
) -> SampleOutput {
    Rk45 { rtol }.run(proc, model, n, rng, false)
}

/// Find an rtol whose actual NFE lands near `target_nfe` (the paper's
/// Table 3 protocol), by bisection on log-rtol with a small probe batch.
pub fn tune_rtol_for_nfe(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    target_nfe: usize,
    seed: u64,
) -> (f64, usize) {
    let mut lo = 1e-12f64.ln();
    let mut hi = 1e0f64.ln();
    let mut best = (1e-3, usize::MAX);
    for _ in 0..18 {
        let mid = 0.5 * (lo + hi);
        let rtol = mid.exp();
        let mut rng = Rng::seed_from(seed);
        let out = sample_rk45(proc, model, rtol, 8, &mut rng);
        let diff = out.nfe.abs_diff(target_nfe);
        if diff < best.1.abs_diff(target_nfe) {
            best = (rtol, out.nfe);
        }
        if out.nfe > target_nfe {
            lo = mid; // need looser tolerance
        } else {
            hi = mid;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Vpsde;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn rk45_tight_tolerance_is_accurate() {
        let proc = Arc::new(Vpsde::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let mut rng = Rng::seed_from(41);
        let out = sample_rk45(proc.as_ref(), &oracle, 1e-6, 1_000, &mut rng);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 0.2, "RK45 tight FD = {fd} (nfe={})", out.nfe);
        assert!(out.nfe > 50);
    }

    #[test]
    fn looser_tolerance_uses_fewer_nfe() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let mut r1 = Rng::seed_from(42);
        let tight = sample_rk45(proc.as_ref(), &oracle, 1e-8, 64, &mut r1);
        let mut r2 = Rng::seed_from(42);
        let loose = sample_rk45(proc.as_ref(), &oracle, 1e-2, 64, &mut r2);
        assert!(loose.nfe < tight.nfe, "{} vs {}", loose.nfe, tight.nfe);
    }

    #[test]
    fn tuner_hits_target_roughly() {
        let proc = Arc::new(Vpsde::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let (_rtol, nfe) = tune_rtol_for_nfe(proc.as_ref(), &oracle, 100, 7);
        assert!(
            nfe >= 40 && nfe <= 260,
            "tuned NFE {nfe} should be in the ballpark of 100"
        );
    }
}
