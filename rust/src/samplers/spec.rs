//! The owned sampler specification: one hashable, serializable value
//! describing *which* Stage-II scheme to run and its full configuration.
//!
//! [`SamplerSpec`] is the single source of truth the whole stack shares:
//! the server's `PlanKey` embeds it (requests with equal specs are
//! batchable), Stage-I plan construction derives its
//! [`PlanConfig`] from it, and [`SamplerSpec::instantiate`] turns it
//! into a runnable [`Sampler`] for the engine. The seven variants map
//! 1:1 onto the impls in this crate's sampler modules.
//!
//! # Spec grammar
//!
//! Every spec round-trips through a compact text form (`Display` ⇄
//! [`SamplerSpec::parse`]), used by the CLI (`--sampler`), the plan
//! persistence format, and logs:
//!
//! ```text
//! gddim[:q=Q,kt=R|L|sqrt[,corrector]]   deterministic gDDIM (defaults q=2, kt=R)
//! gddim-sde[:lambda=λ]                  stochastic gDDIM, λ > 0 (default 1)
//! em[:lambda=λ]                         Euler–Maruyama (default λ=0: prob-flow Euler)
//! ancestral                             generalized DDPM ancestral sampling
//! heun                                  2nd-order Heun on the prob-flow ODE
//! rk45[:rtol=R]                         adaptive Dormand–Prince (default rtol=1e-4)
//! sscs                                  symmetric splitting CLD sampler
//! ```
//!
//! Floats print in Rust's shortest-roundtrip form, so λ and rtol survive
//! the text form bit-exactly (no milli-unit truncation — λ=0.0001 is a
//! distinct, hashable value).

use crate::coeffs::plan::{PlanConfig, SamplerPlan};
use crate::diffusion::process::KtKind;
use crate::diffusion::schedule::TimeGrid;
use crate::samplers::{Ancestral, Em, GddimDet, GddimSde, Heun, Rk45, Sampler, Sscs};
use crate::Error;

/// A finite `f64` with total equality and hashing (by bit pattern, with
/// `-0.0` normalized to `0.0`), so float-configured sampler specs can be
/// `HashMap` keys without precision-losing integerization.
#[derive(Clone, Copy, Debug)]
pub struct OrderedF64(f64);

impl OrderedF64 {
    /// Wrap a finite value. Panics on NaN/∞ — a non-finite λ or rtol is
    /// a caller bug, not a request to be hashed.
    pub fn new(x: f64) -> OrderedF64 {
        assert!(x.is_finite(), "OrderedF64 requires a finite value, got {x}");
        OrderedF64(if x == 0.0 { 0.0 } else { x })
    }

    pub fn get(self) -> f64 {
        self.0
    }
}

impl From<f64> for OrderedF64 {
    fn from(x: f64) -> OrderedF64 {
        OrderedF64::new(x)
    }
}

impl PartialEq for OrderedF64 {
    fn eq(&self, other: &OrderedF64) -> bool {
        self.0.to_bits() == other.0.to_bits()
    }
}

impl Eq for OrderedF64 {}

impl std::hash::Hash for OrderedF64 {
    fn hash<H: std::hash::Hasher>(&self, state: &mut H) {
        self.0.to_bits().hash(state);
    }
}

impl std::fmt::Display for OrderedF64 {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Which Stage-II sampler to run, with its full configuration. Owned,
/// `Eq + Hash` (batchable / cacheable), and serializable via the spec
/// grammar (see the module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum SamplerSpec {
    /// Deterministic gDDIM: exponential-integrator multistep predictor
    /// of order `q`, score parameterized by `kt`, optional corrector
    /// pass (paper Table 8's "PC").
    GddimDet { q: usize, kt: KtKind, corrector: bool },
    /// Stochastic gDDIM (Eq. 22) with λ > 0 (implies `K_t = R_t`, q=1).
    GddimSde { lambda: OrderedF64 },
    /// Euler–Maruyama on the marginal-equivalent SDE Eq. 6 (λ=0
    /// degenerates to plain Euler on the probability-flow ODE).
    Em { lambda: OrderedF64 },
    /// Generalized DDPM/BDM ancestral sampling.
    Ancestral,
    /// 2nd-order Heun on the probability-flow ODE (NFE = 2N−1).
    Heun,
    /// Adaptive Dormand–Prince on the probability-flow ODE; `rtol` is
    /// the NFE knob (the time grid is ignored).
    Rk45 { rtol: OrderedF64 },
    /// Symmetric splitting CLD sampler (Dockhorn et al.) — CLD only.
    Sscs,
}

impl SamplerSpec {
    /// Deterministic gDDIM with the crate-default configuration.
    pub fn gddim(q: usize) -> SamplerSpec {
        SamplerSpec::GddimDet { q, kt: KtKind::R, corrector: false }
    }

    /// The grammar head naming this variant.
    pub fn name(&self) -> &'static str {
        match self {
            SamplerSpec::GddimDet { .. } => "gddim",
            SamplerSpec::GddimSde { .. } => "gddim-sde",
            SamplerSpec::Em { .. } => "em",
            SamplerSpec::Ancestral => "ancestral",
            SamplerSpec::Heun => "heun",
            SamplerSpec::Rk45 { .. } => "rk45",
            SamplerSpec::Sscs => "sscs",
        }
    }

    /// The Stage-I plan this spec needs, if any (only the two gDDIM
    /// variants precompute coefficients).
    pub fn plan_config(&self) -> Option<PlanConfig> {
        match self {
            SamplerSpec::GddimDet { q, kt, corrector } => Some(PlanConfig {
                q: *q,
                kt: *kt,
                with_corrector: *corrector,
                ..PlanConfig::default()
            }),
            SamplerSpec::GddimSde { lambda } => Some(PlanConfig::stochastic(lambda.get())),
            _ => None,
        }
    }

    /// The `K_t` parameterization the score model must expose for this
    /// spec (only deterministic gDDIM varies it; everything else uses
    /// the paper's default `R_t`).
    pub fn model_kt(&self) -> KtKind {
        match self {
            SamplerSpec::GddimDet { kt, .. } => *kt,
            _ => KtKind::R,
        }
    }

    /// Whether `plan` was built for exactly this spec (guards preloaded
    /// / persisted plans against config drift). The *entire*
    /// [`PlanConfig`] is compared — including the quadrature knobs
    /// (`gl_points`, `gl_pieces`, `ode_steps`), so a plan persisted
    /// under different numerics is rebuilt rather than silently adopted.
    /// Specs without a Stage-I plan trivially match.
    pub fn matches_plan(&self, plan: &SamplerPlan) -> bool {
        match self.plan_config() {
            Some(cfg) => cfg == plan.cfg,
            None => true,
        }
    }

    /// Validate the configuration against a process name. This is the
    /// server's submit-time gate: it turns what used to be dispatcher
    /// panics into clean per-request errors.
    pub fn validate(&self, process: &str) -> crate::Result<()> {
        match self {
            SamplerSpec::GddimDet { q, .. } if *q == 0 => {
                Err(Error::msg("gddim: multistep order q must be >= 1"))
            }
            SamplerSpec::GddimSde { lambda } if lambda.get() <= 0.0 => Err(Error::msg(
                "gddim-sde: λ must be > 0 (use `gddim` for the deterministic λ=0 limit)",
            )),
            SamplerSpec::Em { lambda } if lambda.get() < 0.0 => {
                Err(Error::msg("em: λ must be >= 0"))
            }
            SamplerSpec::Rk45 { rtol } if rtol.get() <= 0.0 => {
                Err(Error::msg("rk45: rtol must be > 0"))
            }
            SamplerSpec::Sscs if process != "cld" => Err(Error::msg(format!(
                "sscs is the CLD-specific splitting sampler and cannot run on `{process}` \
                 (its analytic half-step reverses the CLD Ornstein–Uhlenbeck structure)"
            ))),
            _ => Ok(()),
        }
    }

    /// Build the runnable [`Sampler`] for this spec. gDDIM variants need
    /// the prebuilt Stage-I `plan` (and check it matches); grid samplers
    /// borrow `grid`; RK45 ignores both inputs beyond the borrow.
    pub fn instantiate<'a>(
        &self,
        plan: Option<&'a SamplerPlan>,
        grid: &'a TimeGrid,
    ) -> crate::Result<Box<dyn Sampler + 'a>> {
        match self {
            SamplerSpec::GddimDet { .. } | SamplerSpec::GddimSde { .. } => {
                let plan = plan.ok_or_else(|| {
                    Error::msg(format!("{self} needs a prebuilt Stage-I SamplerPlan"))
                })?;
                if !self.matches_plan(plan) {
                    return Err(Error::msg(format!(
                        "plan built for {:?} does not match spec {self}",
                        plan.cfg
                    )));
                }
                let built: Box<dyn Sampler + 'a> = match self {
                    SamplerSpec::GddimDet { .. } => Box::new(GddimDet { plan }),
                    _ => Box::new(GddimSde { plan }),
                };
                Ok(built)
            }
            SamplerSpec::Em { lambda } => Ok(Box::new(Em { grid, lambda: lambda.get() })),
            SamplerSpec::Ancestral => Ok(Box::new(Ancestral { grid })),
            SamplerSpec::Heun => Ok(Box::new(Heun { grid })),
            SamplerSpec::Rk45 { rtol } => Ok(Box::new(Rk45 { rtol: rtol.get() })),
            SamplerSpec::Sscs => Ok(Box::new(Sscs { grid })),
        }
    }

    /// Parse the spec grammar (see the module docs). Inverse of
    /// `Display`. Options that do not apply to the chosen sampler (e.g.
    /// `gddim:lambda=…`) are an error, not silently dropped, and
    /// non-finite floats are rejected here rather than panicking in
    /// [`OrderedF64`].
    pub fn parse(s: &str) -> crate::Result<SamplerSpec> {
        let (head, tail) = match s.split_once(':') {
            Some((h, t)) => (h.trim(), Some(t)),
            None => (s.trim(), None),
        };
        let finite = |name: &str, v: &str| -> crate::Result<f64> {
            let x: f64 =
                v.parse().map_err(|_| Error::msg(format!("bad {name} `{v}` in `{s}`")))?;
            if !x.is_finite() {
                return Err(Error::msg(format!("{name} must be finite, got `{v}` in `{s}`")));
            }
            Ok(x)
        };
        let mut q = 2usize;
        let mut kt = KtKind::R;
        let mut corrector = false;
        let mut lambda: Option<f64> = None;
        let mut rtol = 1e-4f64;
        let mut seen: Vec<&str> = Vec::new();
        if let Some(tail) = tail {
            for item in tail.split(',') {
                let item = item.trim();
                if item.is_empty() {
                    continue;
                }
                match item.split_once('=') {
                    Some(("q", v)) => {
                        q = v.parse().map_err(|_| Error::msg(format!("bad q `{v}`")))?;
                        seen.push("q");
                    }
                    Some(("kt", v)) => {
                        kt = v.parse().map_err(Error::msg)?;
                        seen.push("kt");
                    }
                    Some(("lambda", v)) => {
                        lambda = Some(finite("lambda", v)?);
                        seen.push("lambda");
                    }
                    Some(("rtol", v)) => {
                        rtol = finite("rtol", v)?;
                        seen.push("rtol");
                    }
                    None if item == "corrector" => {
                        corrector = true;
                        seen.push("corrector");
                    }
                    _ => {
                        return Err(Error::msg(format!("unknown sampler option `{item}` in `{s}`")))
                    }
                }
            }
        }
        let allowed: &[&str] = match head {
            "gddim" => &["q", "kt", "corrector"],
            "gddim-sde" | "em" => &["lambda"],
            "rk45" => &["rtol"],
            _ => &[],
        };
        if let Some(bad) = seen.iter().find(|o| !allowed.contains(o)) {
            return Err(Error::msg(format!(
                "option `{bad}` does not apply to sampler `{head}` in `{s}`"
            )));
        }
        match head {
            "gddim" => Ok(SamplerSpec::GddimDet { q, kt, corrector }),
            "gddim-sde" => Ok(SamplerSpec::GddimSde {
                lambda: OrderedF64::new(lambda.unwrap_or(1.0)),
            }),
            "em" => Ok(SamplerSpec::Em { lambda: OrderedF64::new(lambda.unwrap_or(0.0)) }),
            "ancestral" => Ok(SamplerSpec::Ancestral),
            "heun" => Ok(SamplerSpec::Heun),
            "rk45" => Ok(SamplerSpec::Rk45 { rtol: OrderedF64::new(rtol) }),
            "sscs" => Ok(SamplerSpec::Sscs),
            other => Err(Error::msg(format!(
                "unknown sampler `{other}` (expected gddim|gddim-sde|em|ancestral|heun|rk45|sscs)"
            ))),
        }
    }
}

impl std::fmt::Display for SamplerSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerSpec::GddimDet { q, kt, corrector } => {
                write!(f, "gddim:q={q},kt={}", kt.token())?;
                if *corrector {
                    write!(f, ",corrector")?;
                }
                Ok(())
            }
            SamplerSpec::GddimSde { lambda } => write!(f, "gddim-sde:lambda={lambda}"),
            SamplerSpec::Em { lambda } => write!(f, "em:lambda={lambda}"),
            SamplerSpec::Ancestral => write!(f, "ancestral"),
            SamplerSpec::Heun => write!(f, "heun"),
            SamplerSpec::Rk45 { rtol } => write!(f, "rk45:rtol={rtol}"),
            SamplerSpec::Sscs => write!(f, "sscs"),
        }
    }
}

impl std::str::FromStr for SamplerSpec {
    type Err = Error;

    fn from_str(s: &str) -> crate::Result<SamplerSpec> {
        SamplerSpec::parse(s)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::hash_map::DefaultHasher;
    use std::hash::{Hash, Hasher};

    fn hash_of<T: Hash>(v: &T) -> u64 {
        let mut h = DefaultHasher::new();
        v.hash(&mut h);
        h.finish()
    }

    #[test]
    fn grammar_round_trips_every_variant() {
        let specs = [
            SamplerSpec::GddimDet { q: 3, kt: KtKind::L, corrector: true },
            SamplerSpec::gddim(2),
            SamplerSpec::GddimSde { lambda: OrderedF64::new(0.3) },
            SamplerSpec::Em { lambda: OrderedF64::new(0.0) },
            SamplerSpec::Em { lambda: OrderedF64::new(1e-4) },
            SamplerSpec::Ancestral,
            SamplerSpec::Heun,
            SamplerSpec::Rk45 { rtol: OrderedF64::new(1e-6) },
            SamplerSpec::Sscs,
        ];
        for spec in specs {
            let text = spec.to_string();
            let back = SamplerSpec::parse(&text).unwrap();
            assert_eq!(back, spec, "grammar round trip failed for `{text}`");
            assert_eq!(hash_of(&back), hash_of(&spec));
        }
    }

    #[test]
    fn parse_defaults_and_errors() {
        assert_eq!(SamplerSpec::parse("gddim").unwrap(), SamplerSpec::gddim(2));
        assert_eq!(
            SamplerSpec::parse("gddim-sde").unwrap(),
            SamplerSpec::GddimSde { lambda: OrderedF64::new(1.0) }
        );
        assert_eq!(
            SamplerSpec::parse("em").unwrap(),
            SamplerSpec::Em { lambda: OrderedF64::new(0.0) }
        );
        assert_eq!(
            SamplerSpec::parse("rk45").unwrap(),
            SamplerSpec::Rk45 { rtol: OrderedF64::new(1e-4) }
        );
        assert!(SamplerSpec::parse("dpm-solver").is_err());
        assert!(SamplerSpec::parse("gddim:bogus=1").is_err());
        assert!(SamplerSpec::parse("gddim:kt=Z").is_err());
    }

    #[test]
    fn parse_rejects_non_finite_floats_cleanly() {
        // f64::from_str accepts "nan"/"inf"; the grammar must turn those
        // into errors, not a panic inside OrderedF64.
        for bad in ["em:lambda=nan", "em:lambda=inf", "gddim-sde:lambda=-inf", "rk45:rtol=NaN"] {
            assert!(SamplerSpec::parse(bad).is_err(), "`{bad}` must be a clean error");
        }
    }

    #[test]
    fn parse_rejects_options_foreign_to_the_sampler() {
        // An option the grammar knows but the chosen head ignores would
        // silently serve the wrong sampler — reject instead.
        for bad in ["gddim:lambda=0.5", "em:q=5", "heun:rtol=1e-6", "rk45:lambda=1",
                    "ancestral:q=2", "sscs:corrector"] {
            assert!(SamplerSpec::parse(bad).is_err(), "`{bad}` must be rejected");
        }
    }

    #[test]
    fn matches_plan_compares_the_full_config() {
        use crate::diffusion::{Process, TimeGrid, Vpsde};
        let p = Vpsde::standard(1);
        let grid = TimeGrid::uniform(p.t_min(), p.t_max(), 4);
        let spec = SamplerSpec::gddim(2);
        let cfg = spec.plan_config().unwrap();
        let plan = SamplerPlan::build(&p, &grid, &cfg);
        assert!(spec.matches_plan(&plan));
        // Same q/kt but different quadrature settings: numerically a
        // different plan, so it must not be adopted.
        let coarse = SamplerPlan::build(&p, &grid, &PlanConfig { gl_points: 8, ..cfg });
        assert!(!spec.matches_plan(&coarse));
    }

    #[test]
    fn tiny_lambda_is_not_truncated() {
        // The old PlanKey stored λ×1000 as u32, so 0.0001 hashed equal
        // to 0.0 and two distinct requests shared a batch. OrderedF64
        // keeps the full bit pattern.
        let a = SamplerSpec::Em { lambda: OrderedF64::new(0.0001) };
        let b = SamplerSpec::Em { lambda: OrderedF64::new(0.0) };
        assert_ne!(a, b);
        assert_ne!(hash_of(&a), hash_of(&b));
        let back = SamplerSpec::parse(&a.to_string()).unwrap();
        match back {
            SamplerSpec::Em { lambda } => {
                assert_eq!(lambda.get().to_bits(), 0.0001f64.to_bits())
            }
            _ => unreachable!(),
        }
    }

    #[test]
    fn ordered_f64_normalizes_negative_zero() {
        assert_eq!(OrderedF64::new(-0.0), OrderedF64::new(0.0));
        assert_eq!(hash_of(&OrderedF64::new(-0.0)), hash_of(&OrderedF64::new(0.0)));
    }

    #[test]
    fn validation_gates_sscs_and_bad_configs() {
        assert!(SamplerSpec::Sscs.validate("cld").is_ok());
        assert!(SamplerSpec::Sscs.validate("vpsde").is_err());
        assert!(SamplerSpec::Sscs.validate("bdm").is_err());
        assert!(SamplerSpec::GddimDet { q: 0, kt: KtKind::R, corrector: false }
            .validate("cld")
            .is_err());
        assert!(SamplerSpec::GddimSde { lambda: OrderedF64::new(0.0) }.validate("vpsde").is_err());
        assert!(SamplerSpec::Rk45 { rtol: OrderedF64::new(0.0) }.validate("vpsde").is_err());
        assert!(SamplerSpec::Em { lambda: OrderedF64::new(0.0) }.validate("bdm").is_ok());
    }
}
