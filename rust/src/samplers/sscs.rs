//! Symmetric Splitting CLD Sampler (SSCS; Dockhorn et al. 2021), the
//! structure-exploiting SDE sampler the paper compares against in
//! App. C.6 ("both methods perform worse than SSCS when λ=1 … SSCS with
//! λ=1.0 performs much worse than gDDIM with λ=0").
//!
//! Strang splitting of the reverse SDE written in reverse time
//! `s = T − t`:  `du/ds = −F u + GGᵀ s_θ + G dw`. Naively taking
//! `−Fu ds + G dw` as the analytic part is *anti-dissipative* (the
//! reverse of a contraction expands) and blows up; SSCS instead uses the
//! **exact time-reversal of the OU process toward its stationary
//! Gaussian** `N(0, Σ∞)` as the analytic piece:
//!
//! ```text
//!   A: du = [−F − GGᵀΣ∞⁻¹] u ds + G dw      (exact Gaussian transition)
//!   B: du = GGᵀ (s_θ(u, t) + Σ∞⁻¹ u) ds     (residual score kick)
//! ```
//!
//! `A + B` recovers the full reverse SDE, `A` is what the reverse SDE is
//! when `p_t = N(0, Σ∞)` (true at large `t`), and the Strang step is
//! `A(h/2) ∘ B(h) ∘ A(h/2)`.

use crate::coeffs::linop_integrate::solve_linop_ode;
use crate::diffusion::process::{KtKind, Process};
use crate::diffusion::schedule::TimeGrid;
use crate::math::linop::LinOp;
use crate::math::rng::Rng;
use crate::samplers::common::{apply_rows, draw_prior, project_batch, SampleOutput};
use crate::samplers::{Sampler, SamplerState, ScoreFn, ScoreRequest};
use crate::score::model::ScoreModel;

struct OuHalf {
    mean: LinOp,
    noise: LinOp,
}

/// Exact reversed-OU half-step operators over duration `h`, evaluated at
/// frozen mid-point coefficients (F is constant in t for CLD, so this is
/// exact there). Drift `Ā = −F − GGᵀΣ∞⁻¹` contracts.
fn ou_half(proc: &dyn Process, t_mid: f64, h: f64, sinf_inv: &LinOp) -> OuHalf {
    let f = proc.f_op(t_mid);
    let ggt = proc.ggt_op(t_mid);
    let a_bar = f.scale(-1.0).sub(&ggt.matmul(sinf_inv));
    let ident = match &f {
        LinOp::Diag(d) => LinOp::diag(vec![1.0; d.len()]),
        LinOp::Block2(_) => LinOp::Block2(crate::math::mat2::Mat2::IDENT),
        LinOp::Scalar(_) => LinOp::Scalar(1.0),
    };
    let mean = solve_linop_ode(|_r, y| a_bar.matmul(y), 0.0, h, 32, ident);
    // covariance: dP/dr = ĀP + PĀᵀ + GGᵀ, P(0)=0
    let p = solve_linop_ode(
        |_r, y| a_bar.matmul(y).add(&y.matmul(&a_bar.transpose())).add(&ggt),
        0.0,
        h,
        32,
        f.scale(0.0),
    );
    let p = p.add(&p.transpose()).scale(0.5);
    OuHalf { mean, noise: p.sqrt_spd() }
}

/// Symmetric splitting CLD sampler on a time grid.
pub struct Sscs<'a> {
    pub grid: &'a TimeGrid,
}

struct SscsState<'a> {
    proc: &'a dyn Process,
    grid: &'a TimeGrid,
    kt: KtKind,
    sinf_inv: LinOp,
    du: usize,
    u: Vec<f64>,
    eps: Vec<f64>,
    buf: Vec<f64>,
    score_buf: Vec<f64>,
    gs: Vec<f64>,
    z: Vec<f64>,
    sinf_u: Vec<f64>,
    nfe: usize,
}

impl Sampler for Sscs<'_> {
    fn n_steps(&self) -> usize {
        self.grid.n_steps()
    }

    fn init<'a>(
        &'a self,
        proc: &'a dyn Process,
        model: &'a dyn ScoreModel,
        n: usize,
        rng: &mut Rng,
        _record_traj: bool,
    ) -> Box<dyn SamplerState + 'a> {
        let du = proc.dim_u();
        let u = draw_prior(proc, n, rng);
        // Σ∞⁻¹ from the prior factor (stationary covariance of the forward OU).
        let pf = proc.prior_factor();
        let sinf_inv = pf.matmul(&pf.transpose()).inv();
        Box::new(SscsState {
            proc,
            grid: self.grid,
            kt: model.kt_kind(),
            sinf_inv,
            du,
            eps: vec![0.0; n * du],
            buf: vec![0.0; n * du],
            score_buf: vec![0.0; du],
            gs: vec![0.0; du],
            z: vec![0.0; du],
            sinf_u: vec![0.0; du],
            u,
            nfe: 0,
        })
    }
}

impl SamplerState for SscsState<'_> {
    fn step(&mut self, i: usize, score: &mut ScoreFn<'_>, rng: &mut Rng) {
        let ts = &self.grid.ts;
        let du = self.du;
        let (s, t) = (ts[i], ts[i - 1]);
        let h = s - t; // positive duration of the reverse step
        let mid = 0.5 * (s + t);
        let ou = ou_half(self.proc, mid, 0.5 * h, &self.sinf_inv);

        // First half OU.
        apply_rows(&ou.mean, &self.u, &mut self.buf, du);
        for row in self.buf.chunks_exact_mut(du) {
            ou.noise.sample_noise(rng, &mut self.z);
            for j in 0..du {
                row[j] += self.z[j];
            }
        }
        std::mem::swap(&mut self.u, &mut self.buf);

        // Residual score kick (full step): GGᵀ(s_θ + Σ∞⁻¹u)·h.
        score(ScoreRequest { t: s, u: &self.u }, &mut self.eps);
        self.nfe += 1;
        let ggt = self.proc.ggt_op(mid);
        let kinv_t = self.proc.kt(self.kt, s).inv().transpose();
        for (row, erow) in self.u.chunks_exact_mut(du).zip(self.eps.chunks_exact(du)) {
            kinv_t.apply(erow, &mut self.score_buf);
            self.sinf_inv.apply(row, &mut self.sinf_u);
            for (x, si) in self.score_buf.iter_mut().zip(&self.sinf_u) {
                *x = -*x + si;
            }
            ggt.apply(&self.score_buf, &mut self.gs);
            for j in 0..du {
                row[j] += h * self.gs[j];
            }
        }

        // Second half OU.
        apply_rows(&ou.mean, &self.u, &mut self.buf, du);
        for row in self.buf.chunks_exact_mut(du) {
            ou.noise.sample_noise(rng, &mut self.z);
            for j in 0..du {
                row[j] += self.z[j];
            }
        }
        std::mem::swap(&mut self.u, &mut self.buf);
    }

    fn finish(self: Box<Self>) -> SampleOutput {
        let xs = project_batch(self.proc, &self.u);
        SampleOutput { xs, us: self.u, nfe: self.nfe, traj: None }
    }
}

/// Run SSCS — thin wrapper over [`Sscs`]; prefer the [`Sampler`] trait
/// for new code. CLD only (the analytic half-step reverses the CLD OU
/// structure); the owned `SamplerSpec` rejects other processes.
pub fn sample_sscs(
    proc: &dyn Process,
    model: &dyn ScoreModel,
    grid: &TimeGrid,
    n: usize,
    rng: &mut Rng,
) -> SampleOutput {
    Sscs { grid }.run(proc, model, n, rng, false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::process::KtKind;
    use crate::diffusion::Cld;
    use crate::metrics::frechet::frechet_to_spec;
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn sscs_converges_on_cld() {
        let proc = Arc::new(Cld::standard(2));
        let spec = presets::gmm2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 200);
        let mut rng = Rng::seed_from(61);
        let out = sample_sscs(proc.as_ref(), &oracle, &grid, 1_500, &mut rng);
        let fd = frechet_to_spec(&out.xs, &spec);
        assert!(fd < 1.0, "SSCS@200 FD = {fd}");
    }

    #[test]
    fn gddim_at_lambda_zero_beats_sscs_at_low_nfe() {
        // Paper App. C.6: "SSCS with λ=1.0 performs much worse than gDDIM
        // with λ=0" — the stochasticity cannot be removed by the score at
        // low NFE, while the smooth ODE path can be extrapolated.
        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        let proc = Arc::new(Cld::standard(2));
        let spec = presets::hard2d();
        let oracle = GmmOracle::new(proc.clone(), spec.clone(), KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 25);
        let mut r1 = Rng::seed_from(62);
        let sscs = sample_sscs(proc.as_ref(), &oracle, &grid, 1_500, &mut r1);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(2, KtKind::R));
        let mut r2 = Rng::seed_from(62);
        let gd = crate::samplers::gddim::sample_deterministic(
            proc.as_ref(),
            &plan,
            &oracle,
            1_500,
            &mut r2,
            false,
        );
        let fs = frechet_to_spec(&sscs.xs, &spec);
        let fg = frechet_to_spec(&gd.xs, &spec);
        assert!(
            fg < fs,
            "gDDIM λ=0 ({fg}) must beat SSCS λ=1 ({fs}) at NFE 25 on CLD"
        );
    }
}
