//! Call-counting [`ScoreModel`] wrapper.
//!
//! NFE is the paper's cost metric, and the serving stack's whole point
//! is issuing *fewer, fuller* `eps_batch` calls — so tests and benches
//! need a way to observe exactly how many model invocations a
//! configuration produced, independent of which model backs it.
//! [`Counting`] wraps any [`ScoreModel`] and counts invocations and
//! rows; `rows / calls` is the realized batch fill. It is the
//! instrument behind the scheduler's coalescing-efficiency tests (a
//! heterogeneous key mix must issue strictly fewer calls with the
//! cross-key scheduler on than off, at bit-identical outputs).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::diffusion::process::KtKind;
use crate::score::model::ScoreModel;

/// A transparent [`ScoreModel`] wrapper counting `eps_batch` calls and
/// rows. The counters are atomic: the wrapper is freely shared across
/// engine workers.
pub struct Counting<M> {
    inner: M,
    calls: AtomicU64,
    rows: AtomicU64,
}

impl<M: ScoreModel> Counting<M> {
    pub fn new(inner: M) -> Counting<M> {
        Counting { inner, calls: AtomicU64::new(0), rows: AtomicU64::new(0) }
    }

    /// `eps_batch` invocations observed so far.
    pub fn calls(&self) -> u64 {
        self.calls.load(Ordering::SeqCst)
    }

    /// Rows evaluated across all invocations.
    pub fn rows(&self) -> u64 {
        self.rows.load(Ordering::SeqCst)
    }

    /// Mean rows per invocation — the realized batch fill (0 when idle).
    pub fn rows_per_call(&self) -> f64 {
        let calls = self.calls();
        if calls == 0 { 0.0 } else { self.rows() as f64 / calls as f64 }
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::SeqCst);
        self.rows.store(0, Ordering::SeqCst);
    }

    pub fn inner(&self) -> &M {
        &self.inner
    }
}

impl<M: ScoreModel> ScoreModel for Counting<M> {
    fn dim_u(&self) -> usize {
        self.inner.dim_u()
    }

    fn kt_kind(&self) -> KtKind {
        self.inner.kt_kind()
    }

    fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]) {
        self.calls.fetch_add(1, Ordering::SeqCst);
        self.rows.fetch_add((us.len() / self.inner.dim_u().max(1)) as u64, Ordering::SeqCst);
        self.inner.eps_batch(t, us, out);
    }

    fn describe(&self) -> String {
        format!("counting({})", self.inner.describe())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::{Cld, Process};
    use crate::score::oracle::GmmOracle;
    use std::sync::Arc;

    #[test]
    fn counts_calls_and_rows_transparently() {
        let proc = Arc::new(Cld::standard(2));
        let oracle = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R);
        let counted = Counting::new(GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::R));
        let us: Vec<f64> = (0..12).map(|i| 0.1 * i as f64).collect(); // 3 rows of dim 4
        let mut a = vec![0.0; 12];
        let mut b = vec![0.0; 12];
        oracle.eps_batch(0.4, &us, &mut a);
        counted.eps_batch(0.4, &us, &mut b);
        assert_eq!(a, b, "the wrapper must be numerically transparent");
        assert_eq!(counted.calls(), 1);
        assert_eq!(counted.rows(), 3);
        let mut c = vec![0.0; 4];
        counted.eps_batch(0.4, &us[..4], &mut c);
        assert_eq!((counted.calls(), counted.rows()), (2, 4));
        assert!((counted.rows_per_call() - 2.0).abs() < 1e-12);
        assert!(counted.describe().starts_with("counting("));
        counted.reset();
        assert_eq!((counted.calls(), counted.rows()), (0, 0));
    }
}
