//! Score models.
//!
//! A [`ScoreModel`] produces the ε-prediction `ε_θ(u, t) = −K_tᵀ s(u, t)`
//! under a declared `K_t` parameterization (paper Eq. 4). Two families:
//!
//! * [`oracle::GmmOracle`] — the *exact* score of a Gaussian-mixture data
//!   distribution pushed through the forward SDE (closed form). This is
//!   what validates Props 1–7 and runs every sampler comparison free of
//!   training error.
//! * `runtime::net::NetScore` (behind the `pjrt` cargo feature) — a
//!   JAX/Pallas-trained network AOT-compiled to HLO, executed via PJRT.

pub mod counting;
pub mod oracle;
pub mod model;

pub use counting::Counting;
pub use model::ScoreModel;
pub use oracle::GmmOracle;
