//! Score models: everything the samplers call through [`ScoreModel`].
//!
//! A [`ScoreModel`] produces the ε-prediction `ε_θ(u, t) = −K_tᵀ s(u, t)`
//! under a declared `K_t` parameterization (paper Eq. 4). The trait's
//! load-bearing clause is the **row-independence contract** on
//! [`ScoreModel::eps_batch`]: each output row may depend only on its own
//! input row and `t`, which is what lets the cross-key score scheduler
//! ([`crate::engine::scheduler`]) concatenate shards from different
//! requests into one call and slice the result back bit-identically.
//!
//! Three backends:
//!
//! * [`oracle::GmmOracle`] — the *exact* score of a Gaussian-mixture data
//!   distribution pushed through the forward SDE (closed form). This is
//!   what validates Props 1–7 and runs every sampler comparison free of
//!   training error.
//! * [`net::ScoreNet`] — the **learned** backend: a std-only float64
//!   replay of the MLP that `python/compile/train.py` trains, loaded
//!   from the `.gdw` artifact in a [`crate::runtime::manifest`]
//!   directory and verified against its frozen probe. [`registry`]
//!   memoizes one shared session per entry.
//! * `runtime::net::NetScore` (behind the `pjrt` cargo feature) — the
//!   same trained models executed from HLO text via PJRT, for parity
//!   checks against the native forward.
//!
//! [`counting::Counting`] wraps any of them to meter evaluations in
//! tests and benches.

pub mod counting;
pub mod net;
pub mod oracle;
pub mod model;
pub mod registry;

pub use counting::Counting;
pub use model::ScoreModel;
pub use net::ScoreNet;
pub use oracle::GmmOracle;
pub use registry::ModelRegistry;
