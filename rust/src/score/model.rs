//! The [`ScoreModel`] trait — what samplers consume.

use crate::diffusion::process::KtKind;

/// A batched ε-prediction model: `ε(u, t) = −K_tᵀ ∇log p_t(u)` for the
/// parameterization `K_t` declared by [`ScoreModel::kt_kind`].
///
/// Batching convention: `us` is row-major `n × dim_u`, `out` likewise.
/// Implementations must be `Send + Sync` (the server fans batches across
/// worker threads).
pub trait ScoreModel: Send + Sync {
    /// State dimension D this model operates on.
    fn dim_u(&self) -> usize;

    /// Which `K_t` the ε output is parameterized by.
    fn kt_kind(&self) -> KtKind;

    /// Evaluate ε for a batch of states at one shared time `t`.
    ///
    /// Contract: each row of `out` must depend only on the matching row
    /// of `us` (and `t`), never on its batch-mates. The cross-key score
    /// scheduler ([`crate::engine::scheduler`]) relies on this to
    /// concatenate rows from several shards into one call and slice the
    /// result back bit-identically; it holds for the closed-form oracle
    /// and for any pointwise network model.
    fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]);

    /// Convenience single-state evaluation.
    fn eps(&self, t: f64, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; u.len()];
        self.eps_batch(t, u, &mut out);
        out
    }

    /// Human-readable identifier for logs/benches.
    fn describe(&self) -> String {
        format!("score-model(dim={})", self.dim_u())
    }
}
