//! Pure-Rust learned-score forward: the serving side of
//! `python/compile/model.py::score_eps`.
//!
//! [`ScoreNet`] loads the `.gdw` raw-weight artifact that
//! `python/compile/weights.py` exports next to each HLO file and replays
//! the network in float64 with **zero native deps** — no PJRT, no BLAS.
//! Architecture (must mirror the python forward op-for-op):
//!
//! ```text
//!   emb  = silu(lin₁(silu(lin₀(time_embed(t)))))        (t-only)
//!   ss_i = film_i(emb), (scale_i, shift_i) = split(ss_i) (t-only)
//!   h    = silu(stem(u))                                 (per row)
//!   h   += silu(block_i(h·(1+scale_i) + shift_i))        (per row, ×blocks)
//!   ε    = head(h)
//! ```
//!
//! with `time_embed(t) = [sin(t·f), cos(t·f)]`,
//! `f_k = 2π / 100^(k/max(half−1,1))`, and
//! `silu(y) = y·(1/(1+e^{−y}))` — the exact expression both layers pin.
//!
//! Numerics contract: every matmul is the k-outer [`simd::axpy`] loop
//! over contiguous `(fan_in, fan_out)` weight rows, so (a) accumulation
//! order is fixed ascending-k (bit-reproducible across batch sizes and
//! worker counts), and (b) each output row of [`ScoreModel::eps_batch`]
//! depends only on its own input row and `t` — the row-independence the
//! cross-key score scheduler requires. The t-only context (embedding +
//! FiLM pairs) is hoisted out of the row loop; it is identical however
//! many rows share the call, so pooled and direct evaluation agree
//! bit-for-bit. Loading replays the manifest probe and rejects nets
//! whose `(probe_t, probe_u_row0)` forward strays ≥ 1e-6 from the
//! recorded float64 reference (see `compile/weights.py` for why 1e-6 is
//! safe: the reference is the float64 forward of the same f32 weights,
//! which this module reproduces to ~1e-12).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::diffusion::process::KtKind;
use crate::math::simd;
use crate::runtime::manifest::ModelEntry;
use crate::score::model::ScoreModel;
use crate::util::io::read_capped;
use crate::util::json::Json;
use crate::{Error, Result};

/// Size cap on `.gdw` weight files (64 MiB ≈ 16M f32 parameters — two
/// orders of magnitude above the MLPs `python/compile` trains).
pub const WEIGHTS_CAP_BYTES: u64 = 64 << 20;

/// Gate on the load-time probe replay (see module docs).
pub const PROBE_TOL: f64 = 1e-6;

/// A dense layer with weights stored row-major `(fan_in, fan_out)`,
/// exactly as trained (no transpose on load, no transpose at run time).
struct Linear {
    fan_in: usize,
    fan_out: usize,
    w: Vec<f64>,
    b: Vec<f64>,
}

impl Linear {
    /// `out = x·W + b` via the k-outer axpy over contiguous weight rows.
    fn apply(&self, x: &[f64], out: &mut [f64]) {
        debug_assert_eq!(x.len(), self.fan_in);
        out.copy_from_slice(&self.b);
        for (k, &xk) in x.iter().enumerate() {
            simd::axpy(xk, &self.w[k * self.fan_out..(k + 1) * self.fan_out], out);
        }
    }
}

fn silu_inplace(y: &mut [f64]) {
    for v in y.iter_mut() {
        *v *= 1.0 / (1.0 + (-*v).exp());
    }
}

/// A loaded learned-score network (see module docs for the contract).
pub struct ScoreNet {
    name: String,
    kt: KtKind,
    dim: usize,
    hidden: usize,
    emb_half: usize,
    emb0: Linear,
    emb1: Linear,
    stem: Linear,
    films: Vec<Linear>,
    blocks: Vec<Linear>,
    head: Linear,
    /// ε evaluations served (a batch counts once per row) and
    /// `eps_batch` invocations (once per call): `calls / batch_calls`
    /// is the realized batch fill, same accounting as [`super::GmmOracle`].
    pub calls: AtomicU64,
    pub batch_calls: AtomicU64,
}

impl ScoreNet {
    /// Load the entry's `.gdw` weights (size-capped) and verify its
    /// frozen probe within [`PROBE_TOL`].
    pub fn load(entry: &ModelEntry) -> Result<ScoreNet> {
        let path = entry.weights.as_ref().ok_or_else(|| {
            Error::msg(format!("model {}: no `weights` file (PJRT-only entry)", entry.name))
        })?;
        let raw = read_capped(path, WEIGHTS_CAP_BYTES)?;
        let net = Self::from_gdw(&raw, entry)?;
        let err = net.probe_error(entry);
        if !(err < PROBE_TOL) {
            return Err(Error::msg(format!(
                "model {}: probe replay off by {err:.3e} (gate {PROBE_TOL:.0e}) — \
                 weights do not match the manifest probe",
                entry.name
            )));
        }
        Ok(net)
    }

    /// Parse `.gdw` bytes: one line of compact JSON, then little-endian
    /// f32 tensor data in exactly the header's declared order.
    fn from_gdw(raw: &[u8], entry: &ModelEntry) -> Result<ScoreNet> {
        let ctx = |m: String| Error::msg(format!("model {}: {m}", entry.name));
        let nl = raw
            .iter()
            .position(|&b| b == b'\n')
            .ok_or_else(|| ctx("gdw header: no newline".into()))?;
        let header_text = std::str::from_utf8(&raw[..nl])
            .map_err(|e| ctx(format!("gdw header not UTF-8: {e}")))?;
        let h = Json::parse(header_text).map_err(|e| ctx(format!("gdw header parse: {e}")))?;
        let str_field = |k: &str| {
            h.get(k)
                .and_then(|v| v.as_str())
                .ok_or_else(|| ctx(format!("gdw header missing {k}")))
        };
        let dim_field = |k: &str| {
            h.get(k)
                .and_then(|v| v.as_usize())
                .filter(|&v| v > 0)
                .ok_or_else(|| ctx(format!("gdw header missing/zero {k}")))
        };
        if str_field("magic")? != "gddim-weights" {
            return Err(ctx("bad gdw magic".into()));
        }
        let version = h.get("version").and_then(|v| v.as_usize()).unwrap_or(0);
        if version != 1 {
            return Err(ctx(format!("unsupported gdw version {version}")));
        }
        if str_field("dtype")? != "f32" || str_field("order")? != "row-major" {
            return Err(ctx("gdw dtype/order must be f32 row-major".into()));
        }
        let (dim, hidden) = (dim_field("dim")?, dim_field("hidden")?);
        let (blocks, emb_half) = (dim_field("blocks")?, dim_field("emb_half")?);
        for (k, want, got) in [
            ("dim_u", entry.dim_u, dim),
            ("hidden", entry.hidden, hidden),
            ("blocks", entry.blocks, blocks),
            ("emb_half", entry.emb_half, emb_half),
        ] {
            if want != got {
                return Err(ctx(format!("gdw {k}={got} but manifest says {want}")));
            }
        }

        // Canonical tensor order with the expected (fan_in, fan_out) per
        // layer — must match python's `weights.tensor_names`.
        let mut expect: Vec<(String, usize, usize)> =
            vec![("emb0".into(), 2 * emb_half, hidden), ("emb1".into(), hidden, hidden)];
        expect.push(("stem".into(), dim, hidden));
        for i in 0..blocks {
            expect.push((format!("film{i}"), hidden, 2 * hidden));
            expect.push((format!("block{i}"), hidden, hidden));
        }
        expect.push(("head".into(), hidden, dim));

        let tensors = h
            .get("tensors")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| ctx("gdw header missing tensors".into()))?;
        if tensors.len() != 2 * expect.len() {
            return Err(ctx(format!(
                "gdw declares {} tensors, expected {}",
                tensors.len(),
                2 * expect.len()
            )));
        }

        let mut data = &raw[nl + 1..];
        let mut take = |count: usize, what: &str| -> Result<Vec<f64>> {
            let bytes = count * 4;
            if data.len() < bytes {
                return Err(ctx(format!("gdw truncated reading {what}")));
            }
            let (head, rest) = data.split_at(bytes);
            data = rest;
            Ok(head
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]) as f64)
                .collect())
        };
        let mut layers = Vec::with_capacity(expect.len());
        for (i, (name, fan_in, fan_out)) in expect.iter().enumerate() {
            for (suffix, shape) in
                [("_w", vec![*fan_in, *fan_out]), ("_b", vec![*fan_out])]
            {
                let t = &tensors[2 * i + usize::from(suffix == "_b")];
                let tname = t.get("name").and_then(|v| v.as_str()).unwrap_or("");
                let tshape: Vec<usize> = t
                    .get("shape")
                    .and_then(|v| v.as_arr())
                    .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
                    .unwrap_or_default();
                if tname != format!("{name}{suffix}") || tshape != shape {
                    return Err(ctx(format!(
                        "gdw tensor {} is {tname}{tshape:?}, expected {name}{suffix}{shape:?}",
                        2 * i + usize::from(suffix == "_b")
                    )));
                }
            }
            let w = take(fan_in * fan_out, name)?;
            let b = take(*fan_out, name)?;
            layers.push(Linear { fan_in: *fan_in, fan_out: *fan_out, w, b });
        }
        if !data.is_empty() {
            return Err(ctx(format!("{} trailing bytes after the last tensor", data.len())));
        }

        let mut it = layers.into_iter();
        let (emb0, emb1, stem) = (it.next().unwrap(), it.next().unwrap(), it.next().unwrap());
        let mut films = Vec::with_capacity(blocks);
        let mut blks = Vec::with_capacity(blocks);
        for _ in 0..blocks {
            films.push(it.next().unwrap());
            blks.push(it.next().unwrap());
        }
        let head = it.next().unwrap();

        Ok(ScoreNet {
            name: entry.name.clone(),
            kt: entry.kt,
            dim,
            hidden,
            emb_half,
            emb0,
            emb1,
            stem,
            films,
            blocks: blks,
            head,
            calls: AtomicU64::new(0),
            batch_calls: AtomicU64::new(0),
        })
    }

    /// `[sin(t·f), cos(t·f)]` with `f_k = 2π/100^(k/max(half−1,1))`.
    fn time_embed(&self, t: f64, out: &mut [f64]) {
        let half = self.emb_half;
        let denom = half.saturating_sub(1).max(1) as f64;
        for k in 0..half {
            let freq = (2.0 * std::f64::consts::PI) / 100f64.powf(k as f64 / denom);
            let phase = t * freq;
            out[k] = phase.sin();
            out[half + k] = phase.cos();
        }
    }

    /// The t-only context: the per-block (scale‖shift) FiLM vectors.
    fn t_context(&self, t: f64) -> Vec<Vec<f64>> {
        let mut tbuf = vec![0.0; 2 * self.emb_half];
        self.time_embed(t, &mut tbuf);
        let mut emb = vec![0.0; self.hidden];
        self.emb0.apply(&tbuf, &mut emb);
        silu_inplace(&mut emb);
        let mut emb2 = vec![0.0; self.hidden];
        self.emb1.apply(&emb, &mut emb2);
        silu_inplace(&mut emb2);
        self.films
            .iter()
            .map(|f| {
                let mut ss = vec![0.0; 2 * self.hidden];
                f.apply(&emb2, &mut ss);
                ss
            })
            .collect()
    }

    /// Max-abs deviation replaying the manifest's frozen probe row.
    pub fn probe_error(&self, entry: &ModelEntry) -> f64 {
        let eps = self.eps(entry.probe_t, &entry.probe_u_row0);
        eps.iter()
            .zip(&entry.probe_eps_row0)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl ScoreModel for ScoreNet {
    fn dim_u(&self) -> usize {
        self.dim
    }

    fn kt_kind(&self) -> KtKind {
        self.kt
    }

    fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]) {
        let d = self.dim;
        assert_eq!(us.len() % d, 0, "us not a multiple of dim_u");
        assert_eq!(us.len(), out.len());
        let films = self.t_context(t);
        let mut h = vec![0.0; self.hidden];
        let mut g = vec![0.0; self.hidden];
        let mut hb = vec![0.0; self.hidden];
        for (u_row, out_row) in us.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
            self.stem.apply(u_row, &mut h);
            silu_inplace(&mut h);
            for (ss, blk) in films.iter().zip(&self.blocks) {
                let (scale, shift) = ss.split_at(self.hidden);
                for j in 0..self.hidden {
                    g[j] = h[j] * (1.0 + scale[j]) + shift[j];
                }
                blk.apply(&g, &mut hb);
                silu_inplace(&mut hb);
                // h += silu(block(g)) — the residual add, via the same
                // simd kernel (1.0·x + y is exact).
                simd::axpy(1.0, &hb, &mut h);
            }
            self.head.apply(&h, out_row);
        }
        self.calls.fetch_add((us.len() / d) as u64, Ordering::Relaxed);
        self.batch_calls.fetch_add(1, Ordering::Relaxed);
    }

    fn describe(&self) -> String {
        format!(
            "score-net({}, dim={}, hidden={}, blocks={}, kt={})",
            self.name,
            self.dim,
            self.hidden,
            self.blocks.len(),
            self.kt.token()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    /// Build `.gdw` bytes for a net whose every parameter is `fill(i)`
    /// over the flat canonical parameter index (matches the python
    /// writer's layout byte-for-byte by construction).
    fn gdw_bytes(
        dim: usize,
        hidden: usize,
        blocks: usize,
        emb_half: usize,
        fill: impl Fn(usize) -> f32,
    ) -> Vec<u8> {
        let mut names: Vec<(String, Vec<usize>)> = vec![
            ("emb0_w".into(), vec![2 * emb_half, hidden]),
            ("emb0_b".into(), vec![hidden]),
            ("emb1_w".into(), vec![hidden, hidden]),
            ("emb1_b".into(), vec![hidden]),
            ("stem_w".into(), vec![dim, hidden]),
            ("stem_b".into(), vec![hidden]),
        ];
        for i in 0..blocks {
            names.push((format!("film{i}_w"), vec![hidden, 2 * hidden]));
            names.push((format!("film{i}_b"), vec![2 * hidden]));
            names.push((format!("block{i}_w"), vec![hidden, hidden]));
            names.push((format!("block{i}_b"), vec![hidden]));
        }
        names.push(("head_w".into(), vec![hidden, dim]));
        names.push(("head_b".into(), vec![dim]));
        let tensors = names
            .iter()
            .map(|(n, s)| {
                let dims =
                    s.iter().map(|x| x.to_string()).collect::<Vec<_>>().join(",");
                format!(r#"{{"name":"{n}","shape":[{dims}]}}"#)
            })
            .collect::<Vec<_>>()
            .join(",");
        let mut out = format!(
            r#"{{"blocks":{blocks},"dim":{dim},"dtype":"f32","emb_half":{emb_half},"hidden":{hidden},"magic":"gddim-weights","order":"row-major","tensors":[{tensors}],"version":1}}"#
        )
        .into_bytes();
        out.push(b'\n');
        let mut idx = 0usize;
        for (_, shape) in &names {
            for _ in 0..shape.iter().product::<usize>() {
                out.extend_from_slice(&fill(idx).to_le_bytes());
                idx += 1;
            }
        }
        out
    }

    fn entry(dim: usize, hidden: usize, blocks: usize, emb_half: usize) -> ModelEntry {
        ModelEntry {
            name: "t".into(),
            file: None,
            weights: Some(PathBuf::from("unused.gdw")),
            process: "vpsde".into(),
            dataset: "gmm2d".into(),
            kt: KtKind::R,
            dim_u: dim,
            batch: 8,
            hidden,
            blocks,
            emb_half,
            final_loss: None,
            probe_t: 0.5,
            probe_u_row0: vec![0.0; dim],
            probe_eps_row0: vec![0.0; dim],
            probe_seed: 0,
        }
    }

    #[test]
    fn zero_weights_give_zero_eps() {
        let raw = gdw_bytes(2, 4, 1, 3, |_| 0.0);
        let net = ScoreNet::from_gdw(&raw, &entry(2, 4, 1, 3)).unwrap();
        assert_eq!(net.eps(0.3, &[1.0, -2.0]), vec![0.0, 0.0]);
    }

    #[test]
    fn hand_computed_forward_matches() {
        // dim=1, hidden=1, blocks=1, emb_half=1, all params = 0.1: small
        // enough to replay the whole architecture by hand.
        let raw = gdw_bytes(1, 1, 1, 1, |_| 0.1);
        let net = ScoreNet::from_gdw(&raw, &entry(1, 1, 1, 1)).unwrap();
        let silu = |y: f64| y * (1.0 / (1.0 + (-y).exp()));
        let w = 0.1f32 as f64;
        let (t, u) = (0.3, 0.7);
        let tau = std::f64::consts::TAU;
        let emb = silu((t * tau).sin() * w + (t * tau).cos() * w + w);
        let emb = silu(emb * w + w);
        let (scale, shift) = (emb * w + w, emb * w + w);
        let mut h = silu(u * w + w);
        h += silu((h * (1.0 + scale) + shift) * w + w);
        let expected = h * w + w;
        let got = net.eps(t, &[u])[0];
        assert!((got - expected).abs() < 1e-15, "{got} vs {expected}");
    }

    #[test]
    fn eps_batch_is_bit_identical_to_row_by_row() {
        let raw = gdw_bytes(3, 8, 2, 4, |i| ((i % 17) as f32 - 8.0) * 0.037);
        let net = ScoreNet::from_gdw(&raw, &entry(3, 8, 2, 4)).unwrap();
        for n in [1usize, 3, 33] {
            let us: Vec<f64> = (0..n * 3).map(|i| ((i * 7919) % 23) as f64 * 0.11 - 1.2).collect();
            let mut pooled = vec![0.0; n * 3];
            net.eps_batch(0.42, &us, &mut pooled);
            for r in 0..n {
                let one = net.eps(0.42, &us[r * 3..(r + 1) * 3]);
                assert_eq!(
                    one.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    pooled[r * 3..(r + 1) * 3].iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "row {r} of n={n}"
                );
            }
        }
    }

    #[test]
    fn malformed_gdw_is_rejected_with_context() {
        let e = entry(2, 4, 1, 3);
        let good = gdw_bytes(2, 4, 1, 3, |_| 0.0);
        // No newline / bad magic / truncated data / trailing bytes /
        // manifest-header mismatch — each must fail naming the model.
        for (raw, what) in [
            (b"not json at all".to_vec(), "no newline"),
            (good[..good.len() - 2].to_vec(), "truncated"),
            ([good.clone(), vec![0u8; 4]].concat(), "trailing"),
        ] {
            let err = ScoreNet::from_gdw(&raw, &e).unwrap_err().to_string();
            assert!(err.contains("model t"), "{what}: {err}");
        }
        let bad_magic = gdw_bytes(2, 4, 1, 3, |_| 0.0);
        let bad_magic = String::from_utf8(bad_magic).unwrap().replace("gddim-weights", "nope");
        assert!(ScoreNet::from_gdw(bad_magic.as_bytes(), &e).is_err());
        let err = ScoreNet::from_gdw(&good, &entry(3, 4, 1, 3)).unwrap_err().to_string();
        assert!(err.contains("manifest says 3"), "{err}");
    }
}
