//! Exact score oracle for Gaussian-mixture data (paper Eq. 15).
//!
//! For data `p₀ = Σ_m w_m N(μ_m, σ²I_d)` pushed through the linear SDE,
//! the marginal at time `t` is again a mixture:
//! `p_t(u) = Σ_m w_m N(u; Ψ(t,0)·lift(μ_m), C_t)` with the *shared*
//! component covariance `C_t = Ψ(t,0)·lift(σ²I)·Ψ(t,0)ᵀ + Σ_t`, so
//!
//! ```text
//!   ∇log p_t(u) = Σ_m w̃_m(u) · (−C_t⁻¹ (u − μ_m(t))),
//!   w̃_m ∝ w_m · exp(−½‖L_C⁻¹(u − μ_m(t))‖²)          (Eq. 15)
//! ```
//!
//! The Jacobian trace (needed by the probability-flow NLL, App. C.8) is
//! also closed form:
//! `tr ∇s = −tr C⁻¹ + Σ w̃_m‖s_m‖² − ‖s‖²`.

use std::sync::Arc;

use crate::data::gmm::GmmSpec;
use crate::diffusion::process::{KtKind, Process};
use crate::math::linop::LinOp;
use crate::math::simd;
use crate::score::model::ScoreModel;

/// Rows per block of the batched score kernel: large enough that the
/// mode-outer responsibility pass streams each `μ_m` across many states
/// per read, small enough that a block's log-weights stay cache-resident
/// at every supported mixture size.
const ROW_BLOCK: usize = 32;

/// Cached per-`t` quantities (the oracle is called many times at the same
/// grid times; recomputing the 2×2/diag algebra is cheap but the lifted
/// means are O(M·D)). Keyed by `t` bits in a read-mostly map: one oracle
/// is now shared across every `PlanKey` that agrees on
/// `(process, dataset, K_t)` — including keys with different grids — so
/// a single-slot cache would thrash between interleaved grids, and a
/// plain mutex would serialize all keys' evaluations.
struct TimeCache {
    /// L_C⁻¹ with C = L_C L_Cᵀ.
    l_inv: LinOp,
    /// C⁻¹ = L_C⁻ᵀ L_C⁻¹.
    c_inv: LinOp,
    /// −K_tᵀ (for the ε conversion).
    neg_kt_t: LinOp,
    /// Component means at time t (row-major M × D).
    mus: Vec<f64>,
}

/// Exact mixture score for a [`GmmSpec`] under a [`Process`].
pub struct GmmOracle {
    pub proc: Arc<dyn Process>,
    pub spec: GmmSpec,
    pub kt: KtKind,
    cache: std::sync::RwLock<std::collections::HashMap<u64, Arc<TimeCache>>>,
    /// Number of ε evaluations served (batch counts once per row).
    pub calls: std::sync::atomic::AtomicU64,
    /// Number of `eps_batch` invocations (a batch counts once).
    /// `calls / batch_calls` is the realized batch fill — the quantity
    /// the cross-key score scheduler exists to raise.
    pub batch_calls: std::sync::atomic::AtomicU64,
}

impl GmmOracle {
    pub fn new(proc: Arc<dyn Process>, spec: GmmSpec, kt: KtKind) -> Self {
        assert_eq!(proc.dim_x(), spec.d, "process/data dimension mismatch");
        GmmOracle {
            proc,
            spec,
            kt,
            cache: std::sync::RwLock::new(std::collections::HashMap::new()),
            calls: std::sync::atomic::AtomicU64::new(0),
            batch_calls: std::sync::atomic::AtomicU64::new(0),
        }
    }

    fn cache_for(&self, t: f64) -> Arc<TimeCache> {
        {
            let g = crate::util::sync::read_unpoisoned(&self.cache);
            if let Some(c) = g.get(&t.to_bits()) {
                return c.clone();
            }
        }
        let du = self.proc.dim_u();
        let psi0 = self.proc.psi(t, 0.0);
        // C_t = Ψ lift(σ²) Ψᵀ + Σ_t
        let c = psi0
            .matmul(&self.proc.lift_cov(self.spec.var))
            .matmul(&psi0.transpose())
            .add(&self.proc.sigma(t));
        let l = c.cholesky();
        let l_inv = l.inv();
        let c_inv = l_inv.transpose().matmul(&l_inv);
        let neg_kt_t = self.proc.kt(self.kt, t).transpose().scale(-1.0);
        let mut mus = Vec::with_capacity(self.spec.n_modes() * du);
        let mut tmp = vec![0.0; du];
        for m in &self.spec.means {
            let lifted = self.proc.lift_data(m);
            psi0.apply(&lifted, &mut tmp);
            mus.extend_from_slice(&tmp);
        }
        let cache = Arc::new(TimeCache { l_inv, c_inv, neg_kt_t, mus });
        let mut g = crate::util::sync::write_unpoisoned(&self.cache);
        // Bound the map: grid samplers touch a few dozen t's, but RK45's
        // adaptive stepping can mint unboundedly many distinct times
        // over a long-lived shared oracle. A rare wholesale clear is
        // cheaper than an eviction policy here.
        if g.len() >= 1024 {
            g.clear();
        }
        g.entry(t.to_bits()).or_insert(cache).clone()
    }

    /// Exact score `∇log p_t(u)` for a single state.
    pub fn score(&self, t: f64, u: &[f64]) -> Vec<f64> {
        let mut out = vec![0.0; u.len()];
        self.score_into(t, u, &mut out, None);
        out
    }

    /// Score with optional responsibility output (for the NLL Jacobian).
    fn score_into(&self, t: f64, u: &[f64], out: &mut [f64], mut resp: Option<&mut Vec<f64>>) {
        let cache = self.cache_for(t);
        let du = u.len();
        let m_count = self.spec.n_modes();
        // log w̃_m (unnormalised): log w_m − ½ ‖L⁻¹(u − μ_m)‖².
        let mut logw = vec![0.0; m_count];
        let mut diff = vec![0.0; du];
        let mut white = vec![0.0; du];
        let mut best = f64::NEG_INFINITY;
        for m in 0..m_count {
            let mu = &cache.mus[m * du..(m + 1) * du];
            for j in 0..du {
                diff[j] = u[j] - mu[j];
            }
            cache.l_inv.apply(&diff, &mut white);
            let d2: f64 = white.iter().map(|x| x * x).sum();
            logw[m] = self.spec.weights[m].max(1e-300).ln() - 0.5 * d2;
            best = best.max(logw[m]);
        }
        let mut total = 0.0;
        for lw in logw.iter_mut() {
            *lw = (*lw - best).exp();
            total += *lw;
        }
        // score = −C⁻¹ (u − Σ w̃ μ_m)  (since C is shared across modes)
        let mut mean_mu = vec![0.0; du];
        for m in 0..m_count {
            let w = logw[m] / total;
            let mu = &cache.mus[m * du..(m + 1) * du];
            for j in 0..du {
                mean_mu[j] += w * mu[j];
            }
        }
        for j in 0..du {
            diff[j] = u[j] - mean_mu[j];
        }
        cache.c_inv.apply(&diff, out);
        for o in out.iter_mut() {
            *o = -*o;
        }
        if let Some(r) = resp.as_deref_mut() {
            r.clear();
            r.extend(logw.iter().map(|w| w / total));
        }
    }

    /// Trace of the score Jacobian `tr ∇_u s(u,t)` — exact, for NLL.
    pub fn score_jacobian_trace(&self, t: f64, u: &[f64]) -> f64 {
        let cache = self.cache_for(t);
        let du = u.len();
        let m_count = self.spec.n_modes();
        let mut resp = Vec::with_capacity(m_count);
        let mut s = vec![0.0; du];
        self.score_into(t, u, &mut s, Some(&mut resp));
        // s_m = −C⁻¹(u − μ_m); tr ∇s = −tr C⁻¹ + Σ w̃‖s_m‖² − ‖s‖².
        let mut diff = vec![0.0; du];
        let mut sm = vec![0.0; du];
        let mut acc = -cache.c_inv.trace(du);
        for m in 0..m_count {
            let mu = &cache.mus[m * du..(m + 1) * du];
            for j in 0..du {
                diff[j] = u[j] - mu[j];
            }
            cache.c_inv.apply(&diff, &mut sm);
            let n2: f64 = sm.iter().map(|x| x * x).sum();
            acc += resp[m] * n2;
        }
        acc -= s.iter().map(|x| x * x).sum::<f64>();
        acc
    }

    /// Verbatim pre-vectorization batch loop (PR 6) minus the counter
    /// bumps: per-row `score_into` with its per-row cache lookup and
    /// fresh allocations. The golden reference the blocked kernel must
    /// match bit-for-bit.
    #[cfg(test)]
    fn eps_batch_scalar_reference(&self, t: f64, us: &[f64], out: &mut [f64]) {
        let du = self.proc.dim_u();
        assert_eq!(us.len() % du, 0);
        let cache = self.cache_for(t);
        let mut score = vec![0.0; du];
        for (row_in, row_out) in us.chunks_exact(du).zip(out.chunks_exact_mut(du)) {
            self.score_into(t, row_in, &mut score, None);
            cache.neg_kt_t.apply(&score, row_out);
        }
    }

    /// Exact log-density of the diffused mixture at time t (NLL tests).
    pub fn logp(&self, t: f64, u: &[f64]) -> f64 {
        let cache = self.cache_for(t);
        let du = u.len();
        let psi0 = self.proc.psi(t, 0.0);
        let c = psi0
            .matmul(&self.proc.lift_cov(self.spec.var))
            .matmul(&psi0.transpose())
            .add(&self.proc.sigma(t));
        let logdet = c.logdet(du);
        let log_norm = -0.5 * (du as f64 * (2.0 * std::f64::consts::PI).ln() + logdet);
        let mut diff = vec![0.0; du];
        let mut white = vec![0.0; du];
        let mut best = f64::NEG_INFINITY;
        let logs: Vec<f64> = (0..self.spec.n_modes())
            .map(|m| {
                let mu = &cache.mus[m * du..(m + 1) * du];
                for j in 0..du {
                    diff[j] = u[j] - mu[j];
                }
                cache.l_inv.apply(&diff, &mut white);
                let d2: f64 = white.iter().map(|x| x * x).sum();
                let l = self.spec.weights[m].max(1e-300).ln() + log_norm - 0.5 * d2;
                best = best.max(l);
                l
            })
            .collect();
        best + logs.iter().map(|l| (l - best).exp()).sum::<f64>().ln()
    }
}

impl ScoreModel for GmmOracle {
    fn dim_u(&self) -> usize {
        self.proc.dim_u()
    }

    fn kt_kind(&self) -> KtKind {
        self.kt
    }

    /// Blocked, vectorized ε evaluation (the serving hot loop).
    ///
    /// Works [`ROW_BLOCK`] rows at a time over flat fixed-stride slices:
    /// the responsibility pass runs mode-outer so each lifted mean
    /// streams once per block (not once per row), and all inner loops are
    /// [`crate::math::simd`] kernels. Per (row, mode) every f64 op runs
    /// in the same order as the scalar [`GmmOracle::score_into`] path, so
    /// the output is bit-identical to it — the parity test below sweeps
    /// dimensions and odd row counts to enforce that. Rows stay
    /// independent (the [`ScoreModel`] contract the cross-key scheduler
    /// relies on): block boundaries never change any row's result.
    fn eps_batch(&self, t: f64, us: &[f64], out: &mut [f64]) {
        let du = self.proc.dim_u();
        assert_eq!(us.len() % du, 0);
        assert_eq!(out.len(), us.len());
        let n = us.len() / du;
        // One counter bump per batch — `calls / batch_calls` is the
        // realized fill ratio and must not see internal row blocks.
        self.calls.fetch_add(n as u64, std::sync::atomic::Ordering::Relaxed);
        self.batch_calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        if n == 0 {
            return;
        }
        // One cache lookup per batch. The old path re-acquired the
        // `RwLock` read guard (plus a `HashMap` probe and an `Arc`
        // clone) once per *row* through `score_into`, which serialized
        // large pooled batches on lock traffic.
        let cache = self.cache_for(t);
        let m_count = self.spec.n_modes();
        let logw0: Vec<f64> = self.spec.weights.iter().map(|w| w.max(1e-300).ln()).collect();
        let mut logw = vec![0.0; ROW_BLOCK * m_count];
        let mut diff = vec![0.0; du];
        let mut white = vec![0.0; du];
        let mut mean = vec![0.0; du];
        let mut score = vec![0.0; du];
        for (ub, ob) in us.chunks(ROW_BLOCK * du).zip(out.chunks_mut(ROW_BLOCK * du)) {
            let rows = ub.len() / du;
            // Pass 1 (mode-outer): log w̃ for every (row, mode) of the
            // block. Same j-ascending subtract / whiten / strict
            // left-to-right ‖·‖² sequence as the scalar path.
            for m in 0..m_count {
                let mu = &cache.mus[m * du..(m + 1) * du];
                for r in 0..rows {
                    simd::sub(&ub[r * du..(r + 1) * du], mu, &mut diff);
                    cache.l_inv.apply(&diff, &mut white);
                    let d2 = simd::sum_sq(&white);
                    logw[r * m_count + m] = logw0[m] - 0.5 * d2;
                }
            }
            // Pass 2 (row-wise): softmax over modes, posterior mean,
            // score, ε conversion — accumulation orders verbatim from
            // `score_into`.
            for r in 0..rows {
                let lw = &mut logw[r * m_count..(r + 1) * m_count];
                let mut best = f64::NEG_INFINITY;
                for &l in lw.iter() {
                    best = best.max(l);
                }
                let mut total = 0.0;
                for l in lw.iter_mut() {
                    *l = (*l - best).exp();
                    total += *l;
                }
                mean.fill(0.0);
                for m in 0..m_count {
                    simd::axpy(lw[m] / total, &cache.mus[m * du..(m + 1) * du], &mut mean);
                }
                simd::sub(&ub[r * du..(r + 1) * du], &mean, &mut diff);
                cache.c_inv.apply(&diff, &mut score);
                for s in score.iter_mut() {
                    *s = -*s;
                }
                cache.neg_kt_t.apply(&score, &mut ob[r * du..(r + 1) * du]);
            }
        }
    }

    fn describe(&self) -> String {
        format!("oracle({}/{}, K={})", self.proc.name(), self.spec.name, self.kt.label())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::presets;
    use crate::diffusion::{Cld, Vpsde};
    use crate::math::rng::Rng;

    fn fd_score(o: &GmmOracle, t: f64, u: &[f64]) -> Vec<f64> {
        // Finite-difference ∇log p_t via the closed-form logp.
        let h = 1e-5;
        (0..u.len())
            .map(|j| {
                let mut up = u.to_vec();
                let mut dn = u.to_vec();
                up[j] += h;
                dn[j] -= h;
                (o.logp(t, &up) - o.logp(t, &dn)) / (2.0 * h)
            })
            .collect()
    }

    #[test]
    fn score_matches_logp_gradient_vpsde() {
        let proc = Arc::new(Vpsde::standard(2));
        let o = GmmOracle::new(proc, presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(10);
        for &t in &[0.05, 0.3, 0.9] {
            for _ in 0..5 {
                let u: Vec<f64> = (0..2).map(|_| 3.0 * rng.normal()).collect();
                let s = o.score(t, &u);
                let fd = fd_score(&o, t, &u);
                crate::math::assert_allclose(&s, &fd, 1e-4, 1e-6, "vpsde score vs FD");
            }
        }
    }

    #[test]
    fn score_matches_logp_gradient_cld() {
        let proc = Arc::new(Cld::standard(2));
        let o = GmmOracle::new(proc, presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(11);
        for &t in &[0.05, 0.5] {
            for _ in 0..5 {
                let u: Vec<f64> = (0..4).map(|_| 2.0 * rng.normal()).collect();
                let s = o.score(t, &u);
                let fd = fd_score(&o, t, &u);
                crate::math::assert_allclose(&s, &fd, 1e-4, 1e-5, "cld score vs FD");
            }
        }
    }

    #[test]
    fn jacobian_trace_matches_fd() {
        let proc = Arc::new(Vpsde::standard(2));
        let o = GmmOracle::new(proc, presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(12);
        for &t in &[0.1, 0.6] {
            let u: Vec<f64> = (0..2).map(|_| 3.0 * rng.normal()).collect();
            let h = 1e-5;
            let mut tr = 0.0;
            for j in 0..2 {
                let mut up = u.clone();
                let mut dn = u.clone();
                up[j] += h;
                dn[j] -= h;
                tr += (o.score(t, &up)[j] - o.score(t, &dn)[j]) / (2.0 * h);
            }
            let got = o.score_jacobian_trace(t, &u);
            assert!(
                (got - tr).abs() < 1e-3 * (1.0 + tr.abs()),
                "t={t}: {got} vs FD {tr}"
            );
        }
    }

    #[test]
    fn eps_is_neg_ktt_score() {
        let proc = Arc::new(Cld::standard(2));
        let o = GmmOracle::new(proc.clone(), presets::gmm2d(), KtKind::L);
        let t = 0.4;
        let u = vec![0.5, -0.2, 0.1, 0.3];
        let eps = o.eps(t, &u);
        let s = o.score(t, &u);
        let manual = proc.kt(KtKind::L, t).transpose().scale(-1.0).apply_vec(&s);
        crate::math::assert_allclose(&eps, &manual, 1e-12, 1e-12, "eps conversion");
    }

    #[test]
    fn single_dirac_score_is_linear() {
        // One Dirac mode: score = −Σ_t⁻¹(u − Ψμ) exactly (Prop 1 setup).
        let proc = Arc::new(Vpsde::standard(1));
        let spec = GmmSpec {
            name: "dirac".into(),
            d: 1,
            weights: vec![1.0],
            means: vec![vec![1.5]],
            var: 0.0,
        };
        let o = GmmOracle::new(proc.clone(), spec, KtKind::R);
        let t = 0.5;
        let alpha = proc.alpha(t);
        for &u in &[-1.0, 0.0, 2.0] {
            let s = o.score(t, &[u])[0];
            let expect = -(u - alpha.sqrt() * 1.5) / (1.0 - alpha);
            assert!((s - expect).abs() < 1e-10, "{s} vs {expect}");
        }
    }

    #[test]
    fn vectorized_eps_batch_is_bit_identical_to_scalar_reference() {
        use crate::diffusion::Bdm;

        fn synth_spec(d: usize, modes: usize, seed: u64) -> GmmSpec {
            let mut rng = Rng::seed_from(seed);
            let means: Vec<Vec<f64>> =
                (0..modes).map(|_| (0..d).map(|_| 2.0 * rng.normal()).collect()).collect();
            GmmSpec::new(&format!("synth{d}"), means, 0.25)
        }

        // One oracle per structured-operator family (Block2 / Scalar /
        // Diag), state dims 4 / 64 / 256 / 1024.
        let oracles = vec![
            GmmOracle::new(Arc::new(Cld::standard(2)), presets::gmm2d(), KtKind::R),
            GmmOracle::new(Arc::new(Vpsde::standard(64)), synth_spec(64, 3, 21), KtKind::L),
            GmmOracle::new(Arc::new(Bdm::standard(16, 16)), synth_spec(256, 3, 22), KtKind::R),
            GmmOracle::new(Arc::new(Vpsde::standard(1024)), synth_spec(1024, 2, 23), KtKind::R),
        ];
        let mut rng = Rng::seed_from(29);
        for o in &oracles {
            let du = o.dim_u();
            // Row counts off every lane/block multiple: single row,
            // sub-lane, just past a lane, and one past the 32-row block.
            for n in [1usize, 3, 5, 33] {
                let us: Vec<f64> = (0..n * du).map(|_| 1.5 * rng.normal()).collect();
                let mut got = vec![0.0; n * du];
                let mut want = vec![0.0; n * du];
                o.eps_batch(0.35, &us, &mut got);
                o.eps_batch_scalar_reference(0.35, &us, &mut want);
                let gb: Vec<u64> = got.iter().map(|v| v.to_bits()).collect();
                let wb: Vec<u64> = want.iter().map(|v| v.to_bits()).collect();
                assert_eq!(gb, wb, "{} at n={n}", o.describe());
            }
        }
    }

    #[test]
    fn fill_counters_bump_once_per_batch_across_row_blocks() {
        // 33 rows crosses the internal row-block boundary; the counters
        // must still record exactly one invocation and 33 rows — the
        // scheduler's fill-ratio metric counts batches, never kernel
        // blocks.
        let o = GmmOracle::new(Arc::new(Vpsde::standard(2)), presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(31);
        let us: Vec<f64> = (0..33 * 2).map(|_| rng.normal()).collect();
        let mut out = vec![0.0; 33 * 2];
        o.eps_batch(0.5, &us, &mut out);
        use std::sync::atomic::Ordering;
        assert_eq!(o.calls.load(Ordering::Relaxed), 33);
        assert_eq!(o.batch_calls.load(Ordering::Relaxed), 1);
        o.eps_batch(0.5, &us[..2], &mut out[..2]);
        assert_eq!(o.calls.load(Ordering::Relaxed), 34);
        assert_eq!(o.batch_calls.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn batch_matches_single() {
        let proc = Arc::new(Cld::standard(2));
        let o = GmmOracle::new(proc, presets::gmm2d(), KtKind::R);
        let mut rng = Rng::seed_from(13);
        let us: Vec<f64> = (0..12).map(|_| rng.normal()).collect(); // 3 states of dim 4
        let mut out = vec![0.0; 12];
        o.eps_batch(0.3, &us, &mut out);
        for i in 0..3 {
            let single = o.eps(0.3, &us[i * 4..(i + 1) * 4]);
            crate::math::assert_allclose(&out[i * 4..(i + 1) * 4], &single, 1e-13, 1e-13, "batch");
        }
        use std::sync::atomic::Ordering;
        // Counter semantics: `calls` is rows, `batch_calls` invocations
        // (1 batched call + 3 singles above = 4 invocations, 6 rows).
        assert_eq!(o.calls.load(Ordering::Relaxed), 6);
        assert_eq!(o.batch_calls.load(Ordering::Relaxed), 4);
    }
}
