//! Lazy, shared learned-model sessions over an artifact directory.
//!
//! [`ModelRegistry`] owns a validated [`Manifest`] and memoizes one
//! [`ScoreNet`] per entry behind a `Mutex<HashMap>`: models load on
//! first use (startup cost is one manifest parse, not N weight reads)
//! and every caller gets the **same** `Arc` — so all `PlanKey`s routed
//! to one model share a session, and the cross-key score scheduler's
//! same-model pooling (which groups shards by `Arc` pointer identity)
//! works for learned models exactly as it does for oracles.
//!
//! Loading is where the probe gate lives: [`ScoreNet::load`] replays the
//! manifest's frozen `(probe_t, probe_u_row0) → probe_eps_row0` row and
//! refuses weights that drift ≥ 1e-6 from the float64 reference, so a
//! registry never hands out a net that disagrees with its manifest.

use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};

use crate::diffusion::process::KtKind;
use crate::runtime::manifest::{Manifest, ModelEntry};
use crate::score::net::ScoreNet;
use crate::util::sync::lock_unpoisoned;
use crate::{Error, Result};

pub struct ModelRegistry {
    manifest: Manifest,
    loaded: Mutex<HashMap<String, Arc<ScoreNet>>>,
}

impl ModelRegistry {
    /// Parse + validate `dir/manifest.json` (no weights are read yet).
    pub fn open(dir: impl AsRef<Path>) -> Result<ModelRegistry> {
        Ok(ModelRegistry { manifest: Manifest::load(dir)?, loaded: Mutex::new(HashMap::new()) })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The manifest entry that can serve `(process, dataset, K_t)`
    /// natively (i.e. has a `.gdw` weights artifact), if any.
    pub fn find(&self, process: &str, dataset: &str, kt: KtKind) -> Option<&ModelEntry> {
        self.manifest.models.iter().find(|m| {
            m.weights.is_some() && m.process == process && m.dataset == dataset && m.kt == kt
        })
    }

    /// Load (or reuse) the named model. Every call returns the same
    /// shared `Arc` — see the module docs for why that matters.
    pub fn get(&self, name: &str) -> Result<Arc<ScoreNet>> {
        let entry = self.manifest.get(name).ok_or_else(|| {
            Error::msg(format!("no model {name} in {}", self.manifest.dir.display()))
        })?;
        let mut loaded = lock_unpoisoned(&self.loaded);
        if let Some(net) = loaded.get(name) {
            return Ok(net.clone());
        }
        let net = Arc::new(ScoreNet::load(entry)?);
        loaded.insert(name.to_string(), net.clone());
        Ok(net)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::score::model::ScoreModel;

    fn fixture() -> ModelRegistry {
        ModelRegistry::open(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/learned")).unwrap()
    }

    #[test]
    fn sessions_are_shared_arcs() {
        let reg = fixture();
        let a = reg.get("tiny_vpsde_gmm2d").unwrap();
        let b = reg.get("tiny_vpsde_gmm2d").unwrap();
        assert!(Arc::ptr_eq(&a, &b), "repeat get() must reuse the loaded session");
        assert_eq!(a.dim_u(), 2);
    }

    #[test]
    fn find_routes_by_process_dataset_kt() {
        let reg = fixture();
        let e = reg.find("cld", "gmm2d", KtKind::R).expect("cld fixture entry");
        assert_eq!(e.name, "tiny_cld_gmm2d");
        assert_eq!(e.dim_u, 4);
        assert!(reg.find("cld", "gmm2d", KtKind::L).is_none());
        assert!(reg.find("bdm", "gmm2d", KtKind::R).is_none());
    }

    #[test]
    fn unknown_model_errors_with_directory() {
        let err = fixture().get("nope").unwrap_err().to_string();
        assert!(err.contains("no model nope"), "{err}");
    }
}
