//! Dynamic batcher: coalesce same-key requests into one sampler run.
//!
//! Policy (vLLM-flavoured, adapted to one-shot generation requests):
//! a batch closes when (a) the accumulated sample count reaches
//! `max_batch`, or (b) `max_wait` has elapsed since the *oldest* queued
//! request, or (c) the queue is drained and `flush()` is called.
//! FIFO per key; requests never split across keys.

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use crate::server::request::Envelope;

pub struct BatcherConfig {
    /// Maximum total samples per sampler invocation.
    pub max_batch: usize,
    /// Deadline from the oldest waiting request.
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(5) }
    }
}

/// Per-key FIFO queue with deadline-or-size batch cuts.
pub struct KeyQueue {
    pub cfg: BatcherConfig,
    queue: VecDeque<Envelope>,
    queued_samples: usize,
}

impl KeyQueue {
    pub fn new(cfg: BatcherConfig) -> Self {
        KeyQueue { cfg, queue: VecDeque::new(), queued_samples: 0 }
    }

    pub fn push(&mut self, env: Envelope) {
        self.queued_samples += env.req.n;
        self.queue.push_back(env);
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Would a cut fire now?
    pub fn ready(&self, now: Instant) -> bool {
        if self.queue.is_empty() {
            return false;
        }
        self.queued_samples >= self.cfg.max_batch
            || now.duration_since(self.queue[0].enqueued) >= self.cfg.max_wait
    }

    /// Cut a batch: FIFO prefix with total samples ≤ max_batch (always at
    /// least one request, even an oversized one — it runs alone).
    pub fn cut(&mut self) -> Vec<Envelope> {
        let mut out = Vec::new();
        let mut total = 0usize;
        loop {
            let Some(front) = self.queue.front() else { break };
            let n = front.req.n;
            if !out.is_empty() && total + n > self.cfg.max_batch {
                break;
            }
            let Some(env) = self.queue.pop_front() else { break };
            total += n;
            self.queued_samples -= n;
            out.push(env);
            if total >= self.cfg.max_batch {
                break;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::request::{GenRequest, PlanKey};
    use std::sync::mpsc::channel;

    fn env(id: u64, n: usize) -> Envelope {
        let (tx, _rx) = channel();
        Envelope {
            req: GenRequest { id, n, key: PlanKey::gddim("vpsde", "gmm2d", 10, 2), seed: id },
            reply: tx,
            enqueued: Instant::now(),
        }
    }

    #[test]
    fn cuts_fifo_prefix_up_to_max_batch() {
        let mut q = KeyQueue::new(BatcherConfig { max_batch: 100, max_wait: Duration::ZERO });
        for i in 0..5 {
            q.push(env(i, 40));
        }
        // 40 + 40 = 80; adding the third (120) would exceed 100 → cut at 2.
        let batch = q.cut();
        assert_eq!(batch.len(), 2);
        assert_eq!(q.len(), 3);
    }

    #[test]
    fn cut_semantics_exact() {
        let mut q = KeyQueue::new(BatcherConfig { max_batch: 100, max_wait: Duration::ZERO });
        for i in 0..5 {
            q.push(env(i, 40));
        }
        let batch = q.cut();
        let total: usize = batch.iter().map(|e| e.req.n).sum();
        assert!(total <= 120 && !batch.is_empty());
        // FIFO: ids must be increasing from 0.
        for (k, e) in batch.iter().enumerate() {
            assert_eq!(e.req.id, k as u64);
        }
    }

    #[test]
    fn oversized_request_runs_alone() {
        let mut q = KeyQueue::new(BatcherConfig { max_batch: 10, max_wait: Duration::ZERO });
        q.push(env(0, 500));
        q.push(env(1, 5));
        let batch = q.cut();
        assert_eq!(batch.len(), 1);
        assert_eq!(batch[0].req.id, 0);
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn ready_respects_deadline() {
        let mut q = KeyQueue::new(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(50),
        });
        q.push(env(0, 1));
        let now = Instant::now();
        assert!(!q.ready(now));
        assert!(q.ready(now + Duration::from_millis(60)));
    }

    #[test]
    fn ready_respects_size() {
        let mut q = KeyQueue::new(BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_secs(100),
        });
        q.push(env(0, 4));
        assert!(!q.ready(Instant::now()));
        q.push(env(1, 4));
        assert!(q.ready(Instant::now()));
    }

    #[test]
    fn size_cut_counts_samples_not_requests() {
        // Coalescing is by accumulated *samples*: 3 requests of 4 cross a
        // max_batch of 10 (the threshold request is included in the cut).
        let mut q =
            KeyQueue::new(BatcherConfig { max_batch: 10, max_wait: Duration::from_secs(9) });
        q.push(env(0, 4));
        q.push(env(1, 4));
        assert!(!q.ready(Instant::now()), "8 < 10: not ready");
        q.push(env(2, 4));
        assert!(q.ready(Instant::now()), "12 >= 10: size cut fires");
        let batch = q.cut();
        assert_eq!(batch.len(), 2, "cut never exceeds max_batch: the 3rd request stays queued");
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn partial_cuts_keep_sample_accounting_consistent() {
        // After a partial cut the remaining queue must still fire a size
        // cut at the same threshold — i.e. queued_samples tracks pops.
        let mut q =
            KeyQueue::new(BatcherConfig { max_batch: 80, max_wait: Duration::from_secs(9) });
        for i in 0..6 {
            q.push(env(i, 40)); // 240 samples queued
        }
        assert_eq!(q.cut().len(), 2); // 80 out
        assert!(q.ready(Instant::now()), "160 samples still ≥ max_batch");
        assert_eq!(q.cut().len(), 2);
        assert_eq!(q.cut().len(), 2);
        assert!(q.is_empty());
        assert!(!q.ready(Instant::now()), "drained queue must not fire");
    }

    #[test]
    fn deadline_applies_to_oldest_not_newest() {
        let mut q = KeyQueue::new(BatcherConfig {
            max_batch: 1000,
            max_wait: Duration::from_millis(50),
        });
        q.push(env(0, 1));
        let now = Instant::now();
        // A fresh request arriving later must not reset the clock of the
        // oldest one.
        q.push(env(1, 1));
        assert!(q.ready(now + Duration::from_millis(60)), "oldest request's deadline rules");
        let batch = q.cut();
        assert_eq!(batch.len(), 2, "deadline cut takes everything under max_batch");
    }

    #[test]
    fn no_request_lost() {
        let mut q = KeyQueue::new(BatcherConfig { max_batch: 64, max_wait: Duration::ZERO });
        for i in 0..23 {
            q.push(env(i, 7));
        }
        let mut seen = Vec::new();
        while !q.is_empty() {
            for e in q.cut() {
                seen.push(e.req.id);
            }
        }
        let expect: Vec<u64> = (0..23).collect();
        assert_eq!(seen, expect, "every request exactly once, in order");
    }
}
