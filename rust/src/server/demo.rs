//! `gddim serve` — drive the sampling service with a synthetic workload
//! and print the metrics report, including the engine pool's counters
//! (also used by `examples/serve_demo.rs`).

use std::time::Duration;

use crate::engine::{Engine, EngineConfig};
use crate::server::batcher::BatcherConfig;
use crate::server::request::GenRequest;
use crate::server::router::{factory_for, Router, RouterConfig};
use crate::util::cli::Args;
use crate::workload::{cli_key_mix, ClosedLoop, WorkloadSpec};

pub fn run(args: &Args) {
    let workers = args.get_usize("workers", 4);
    let dispatchers = args.get_usize("dispatchers", 2);
    let n_requests = args.get_usize("requests", 64);
    let samples = args.get_usize("samples", 128);
    let nfe = args.get_usize("nfe", 20);
    let rate = args.get_f64("rate", 200.0);
    let max_wait_ms = args.get_u64("max-wait-ms", 5);
    // `+`-separated sampler specs (the spec grammar uses commas); every
    // (vpsde|cld|bdm) × spec combination that validates becomes a key —
    // so e.g. `--samplers gddim:q=2+heun+sscs+rk45` serves heun and rk45
    // on both vector processes, sscs on CLD only, and (for an image
    // `--dataset` like blobs16) everything BDM-compatible on BDM too.
    let samplers = args.get_or("samplers", "gddim:q=2");
    let dataset = args.get_or("dataset", "gmm2d");
    let keys = match cli_key_mix(&samplers, &dataset, nfe) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("error: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a bad sampler spec exits with status 2 before the router starts
            std::process::exit(2);
        }
    };

    // Cross-key score batching: on by default for the serving demo
    // (`--score-batch 0` restores the direct-call engine). With it on,
    // dispatchers admit all ready keys as one engine group and same-`t`
    // score requests pool across keys — the stats line below shows the
    // realized fill (`rows/call`) and cross-key coalescing counters.
    let score_batch = args.get_usize("score-batch", 4096);
    let score_wait = Duration::from_micros(args.get_u64("score-wait", 200));
    // `--models-dir DIR`: serve manifest-matching keys with the learned
    // ScoreNet backend; everything else falls back to the oracle.
    let models_dir = args.get("models-dir").map(std::path::PathBuf::from);
    let factory = match factory_for(models_dir.as_deref()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: --models-dir: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a bad artifacts directory exits with status 2 before the router starts
            std::process::exit(2);
        }
    };
    let router = Router::with_options(
        RouterConfig {
            dispatchers,
            plan_cache_capacity: args.get_usize("plan-cache", 64),
            plan_cache_dir: args.get("plan-cache-dir").map(std::path::PathBuf::from),
        },
        Engine::with_config(EngineConfig {
            workers,
            shard_bytes: args.get_usize("shard-size", EngineConfig::default().shard_bytes),
            score_batch,
            score_wait,
            ..EngineConfig::default()
        }),
        BatcherConfig {
            max_batch: args.get_usize("max-batch", 4096),
            max_wait: Duration::from_millis(max_wait_ms),
        },
        factory,
    );

    let spec = WorkloadSpec {
        n_requests,
        samples_per_request: samples,
        rate_per_sec: rate,
        keys,
        seed: args.get_u64("seed", 0),
    };
    println!(
        "serving {} requests × {} samples on {} (poisson {:.0} req/s, {} engine workers, \
         {} dispatchers, NFE {}, samplers [{}])…",
        n_requests, samples, dataset, rate, workers, dispatchers, nfe, samplers
    );
    let gen = ClosedLoop::new(spec);
    let responses = gen.drive(&router, |id, key, n, seed| GenRequest {
        id,
        n,
        key: key.clone(),
        seed,
    });
    // `report()` (vs `metrics().report()`) folds in the engine snapshot:
    // jobs/shards, peak queue depth, per-worker busy shares.
    println!("{}", router.report());
    println!("plan cache: {} key(s) resident", router.plan_cache_len());
    let ok = responses.iter().filter(|r| r.error.is_none() && !r.xs.is_empty()).count();
    println!("responses with data: {ok}/{n_requests}");
    router.shutdown();
}
