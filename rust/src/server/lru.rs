//! A small LRU cache for the router's Stage-I plan cache.
//!
//! Hand-rolled (offline build: no `lru` crate) and deliberately simple:
//! recency is a monotone tick per entry and eviction scans for the
//! minimum. That is O(capacity) per insert-at-capacity, which is the
//! right trade at the capacities a plan cache runs at (tens of entries,
//! each worth milliseconds of Stage-I rebuild) — no intrusive list to get
//! wrong.

use std::collections::HashMap;
use std::hash::Hash;

pub struct LruCache<K, V> {
    cap: usize,
    tick: u64,
    map: HashMap<K, (V, u64)>,
}

impl<K: Eq + Hash + Clone, V: Clone> LruCache<K, V> {
    /// A cache holding at most `cap` entries (`cap` is clamped to ≥ 1).
    pub fn new(cap: usize) -> LruCache<K, V> {
        LruCache { cap: cap.max(1), tick: 0, map: HashMap::new() }
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn contains(&self, key: &K) -> bool {
        self.map.contains_key(key)
    }

    /// Look up `key`, marking it most-recently-used on a hit.
    pub fn get(&mut self, key: &K) -> Option<V> {
        self.tick += 1;
        let tick = self.tick;
        self.map.get_mut(key).map(|(v, t)| {
            *t = tick;
            v.clone()
        })
    }

    /// Insert `key → value`, evicting the least-recently-used entry if the
    /// cache is at capacity and `key` is new. Returns the evicted key, if
    /// any (observability: the router counts plan rebuilds).
    pub fn insert(&mut self, key: K, value: V) -> Option<K> {
        self.tick += 1;
        let mut evicted = None;
        if !self.map.contains_key(&key) && self.map.len() >= self.cap {
            if let Some(lru) = self
                .map
                .iter()
                .min_by_key(|(_, (_, t))| *t)
                .map(|(k, _)| k.clone())
            {
                self.map.remove(&lru);
                evicted = Some(lru);
            }
        }
        self.map.insert(key, (value, self.tick));
        evicted
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn evicts_least_recently_used_first() {
        let mut c: LruCache<&str, u32> = LruCache::new(2);
        assert_eq!(c.insert("a", 1), None);
        assert_eq!(c.insert("b", 2), None);
        // Touch "a": now "b" is the LRU entry.
        assert_eq!(c.get(&"a"), Some(1));
        assert_eq!(c.insert("c", 3), Some("b"));
        assert!(c.contains(&"a") && c.contains(&"c") && !c.contains(&"b"));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn eviction_order_follows_access_sequence() {
        let mut c: LruCache<u32, u32> = LruCache::new(3);
        for k in 0..3 {
            c.insert(k, k);
        }
        // Recency now 0 < 1 < 2; each new key evicts the current minimum.
        assert_eq!(c.insert(3, 3), Some(0));
        assert_eq!(c.insert(4, 4), Some(1));
        c.get(&3); // protect 3; next eviction takes 2
        assert_eq!(c.insert(5, 5), Some(2));
        assert!(c.contains(&3) && c.contains(&4) && c.contains(&5));
    }

    #[test]
    fn reinserting_existing_key_does_not_evict() {
        let mut c: LruCache<&str, u32> = LruCache::new(2);
        c.insert("a", 1);
        c.insert("b", 2);
        assert_eq!(c.insert("a", 10), None, "overwrite is not an eviction");
        assert_eq!(c.get(&"a"), Some(10));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn miss_does_not_perturb_recency() {
        let mut c: LruCache<u32, u32> = LruCache::new(2);
        c.insert(1, 1);
        c.insert(2, 2);
        assert_eq!(c.get(&99), None);
        assert_eq!(c.insert(3, 3), Some(1), "misses must not bump anything");
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let mut c: LruCache<u32, u32> = LruCache::new(0);
        assert_eq!(c.capacity(), 1);
        c.insert(1, 1);
        assert_eq!(c.insert(2, 2), Some(1));
        assert_eq!(c.len(), 1);
    }
}
