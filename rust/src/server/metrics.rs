//! Server observability: per-request latency, batch occupancy, NFE and
//! throughput counters (lock-guarded; the hot path touches them once per
//! batch, not per sample), plus the TCP edge's admission counters
//! ([`EdgeCounters`] → [`EdgeStats`]).

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::engine::EngineStats;
use crate::math::stats::Summary;
use crate::server::lock_unpoisoned;

#[derive(Default)]
struct Inner {
    latencies: Vec<f64>,
    batch_sizes: Vec<f64>,
    samples_done: u64,
    requests_done: u64,
    batches_done: u64,
    nfe_total: u64,
    started: Option<Instant>,
}

#[derive(Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

impl ServerMetrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn start_clock(&self) {
        let mut g = lock_unpoisoned(&self.inner);
        if g.started.is_none() {
            g.started = Some(Instant::now());
        }
    }

    pub fn record_batch(&self, n_requests: usize, n_samples: usize, nfe: usize, latencies: &[f64]) {
        let mut g = lock_unpoisoned(&self.inner);
        g.latencies.extend_from_slice(latencies);
        g.batch_sizes.push(n_requests as f64);
        g.samples_done += n_samples as u64;
        g.requests_done += n_requests as u64;
        g.batches_done += 1;
        g.nfe_total += nfe as u64;
    }

    pub fn report(&self) -> MetricsReport {
        self.report_with_engine(None)
    }

    /// Like [`ServerMetrics::report`], with an engine counter snapshot
    /// attached (the router passes its shared engine's stats here so one
    /// report covers both serving and execution layers).
    pub fn report_with_engine(&self, engine: Option<EngineStats>) -> MetricsReport {
        let g = lock_unpoisoned(&self.inner);
        let elapsed = g.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
        MetricsReport {
            engine,
            edge: None,
            latency: if g.latencies.is_empty() { None } else { Some(Summary::from(&g.latencies)) },
            mean_batch_requests: if g.batch_sizes.is_empty() {
                0.0
            } else {
                g.batch_sizes.iter().sum::<f64>() / g.batch_sizes.len() as f64
            },
            requests_done: g.requests_done,
            samples_done: g.samples_done,
            batches_done: g.batches_done,
            nfe_total: g.nfe_total,
            samples_per_sec: if elapsed > 0.0 { g.samples_done as f64 / elapsed } else { 0.0 },
            elapsed,
        }
    }
}

/// Live admission counters for the TCP edge (`server::net`). Atomics,
/// not a mutex: the accept loop and every connection thread bump them on
/// the request hot path.
#[derive(Default)]
pub struct EdgeCounters {
    pub connections_accepted: AtomicU64,
    /// Connections turned away at the accept queue (queue full).
    pub connections_shed: AtomicU64,
    /// Requests admitted past rate limiting + the inflight watermark.
    pub requests_admitted: AtomicU64,
    /// Requests answered with a shed + `Retry-After` hint.
    pub requests_shed: AtomicU64,
    /// Lines that failed wire parsing (answered, connection kept).
    pub requests_malformed: AtomicU64,
    /// Lines that exceeded [`NetConfig::max_frame_len`] (answered with a
    /// wire error; connection kept, bytes discarded to the next newline).
    ///
    /// [`NetConfig::max_frame_len`]: crate::server::net::NetConfig::max_frame_len
    pub requests_oversized: AtomicU64,
    /// Result lines actually written back to a client.
    pub requests_completed: AtomicU64,
    /// Of the completed, how many finished during graceful drain.
    pub requests_drained: AtomicU64,
    /// High-water mark of any single connection's in-flight queue depth.
    pub peak_conn_depth: AtomicUsize,
}

impl EdgeCounters {
    /// Record one connection's current in-flight depth, keeping the max.
    pub fn note_conn_depth(&self, depth: usize) {
        self.peak_conn_depth.fetch_max(depth, Ordering::Relaxed);
    }

    pub fn snapshot(&self) -> EdgeStats {
        EdgeStats {
            connections_accepted: self.connections_accepted.load(Ordering::Relaxed),
            connections_shed: self.connections_shed.load(Ordering::Relaxed),
            requests_admitted: self.requests_admitted.load(Ordering::Relaxed),
            requests_shed: self.requests_shed.load(Ordering::Relaxed),
            requests_malformed: self.requests_malformed.load(Ordering::Relaxed),
            requests_oversized: self.requests_oversized.load(Ordering::Relaxed),
            requests_completed: self.requests_completed.load(Ordering::Relaxed),
            requests_drained: self.requests_drained.load(Ordering::Relaxed),
            peak_conn_depth: self.peak_conn_depth.load(Ordering::Relaxed),
        }
    }
}

/// Point-in-time snapshot of [`EdgeCounters`], riding
/// [`MetricsReport::edge`] when the report comes from a [`NetServer`]
/// (in-process routers leave it `None`).
///
/// [`NetServer`]: crate::server::net::NetServer
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct EdgeStats {
    pub connections_accepted: u64,
    pub connections_shed: u64,
    pub requests_admitted: u64,
    pub requests_shed: u64,
    pub requests_malformed: u64,
    pub requests_oversized: u64,
    pub requests_completed: u64,
    pub requests_drained: u64,
    pub peak_conn_depth: usize,
}

impl std::fmt::Display for EdgeStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "edge: conns={}(+{} shed) requests: admitted={} shed={} malformed={} oversized={} \
             completed={} drained={} peak-conn-depth={}",
            self.connections_accepted,
            self.connections_shed,
            self.requests_admitted,
            self.requests_shed,
            self.requests_malformed,
            self.requests_oversized,
            self.requests_completed,
            self.requests_drained,
            self.peak_conn_depth
        )
    }
}

pub struct MetricsReport {
    /// Execution-layer counters (jobs/shards/queue depth/worker busy
    /// shares), when the caller has an engine to snapshot.
    pub engine: Option<EngineStats>,
    /// Network-edge admission counters, when the caller is a
    /// [`NetServer`](crate::server::net::NetServer).
    pub edge: Option<EdgeStats>,
    pub latency: Option<Summary>,
    pub mean_batch_requests: f64,
    pub requests_done: u64,
    pub samples_done: u64,
    pub batches_done: u64,
    pub nfe_total: u64,
    pub samples_per_sec: f64,
    pub elapsed: f64,
}

impl std::fmt::Display for MetricsReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(
            f,
            "requests={} samples={} batches={} mean-batch={:.1} req NFE-total={}",
            self.requests_done,
            self.samples_done,
            self.batches_done,
            self.mean_batch_requests,
            self.nfe_total
        )?;
        writeln!(f, "throughput={:.0} samples/s over {:.2}s", self.samples_per_sec, self.elapsed)?;
        if let Some(edge) = &self.edge {
            writeln!(f, "{edge}")?;
        }
        if let Some(e) = &self.engine {
            writeln!(f, "{e}")?;
        }
        if let Some(l) = &self.latency {
            write!(f, "latency(s): {l}")?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = ServerMetrics::new();
        m.start_clock();
        m.record_batch(3, 300, 50, &[0.1, 0.2, 0.3]);
        m.record_batch(1, 100, 50, &[0.4]);
        let r = m.report();
        assert_eq!(r.requests_done, 4);
        assert_eq!(r.samples_done, 400);
        assert_eq!(r.batches_done, 2);
        assert_eq!(r.nfe_total, 100);
        assert_eq!(r.latency.unwrap().n, 4);
        assert!((r.mean_batch_requests - 2.0).abs() < 1e-12);
        assert!(r.engine.is_none(), "plain report carries no engine snapshot");
        assert!(r.edge.is_none(), "in-process reports carry no edge counters");
    }

    #[test]
    fn engine_snapshot_rides_the_report() {
        use crate::engine::Engine;
        let m = ServerMetrics::new();
        m.start_clock();
        m.record_batch(1, 10, 5, &[0.1]);
        let engine = Engine::new(1);
        let r = m.report_with_engine(Some(engine.stats()));
        let e = r.engine.as_ref().unwrap();
        assert_eq!(e.jobs_run, 0);
        assert!(r.to_string().contains("engine: workers=1"), "{r}");
    }

    #[test]
    fn edge_counters_snapshot_and_display() {
        let c = EdgeCounters::default();
        c.connections_accepted.fetch_add(3, Ordering::Relaxed);
        c.requests_admitted.fetch_add(10, Ordering::Relaxed);
        c.requests_shed.fetch_add(2, Ordering::Relaxed);
        c.requests_oversized.fetch_add(1, Ordering::Relaxed);
        c.requests_completed.fetch_add(10, Ordering::Relaxed);
        c.note_conn_depth(4);
        c.note_conn_depth(2);
        let s = c.snapshot();
        assert_eq!(s.connections_accepted, 3);
        assert_eq!(s.requests_shed, 2);
        assert_eq!(s.requests_oversized, 1);
        assert_eq!(s.peak_conn_depth, 4, "depth keeps its high-water mark");
        let mut r = ServerMetrics::new().report();
        r.edge = Some(s.clone());
        let text = r.to_string();
        assert!(text.contains("edge: conns=3(+0 shed)"), "{text}");
        assert!(text.contains("peak-conn-depth=4"), "{text}");
    }
}
