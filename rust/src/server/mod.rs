//! The batched sampling service — the L3 "serving" coordinator.
//!
//! Clients submit [`request::GenRequest`]s; the [`router::Router`] groups
//! them by *plan key* (process, dataset, sampler config, NFE), the
//! [`batcher`] coalesces compatible requests into one batched sampler run
//! (score-model batching is where all the throughput is), worker threads
//! execute runs, and per-request latency/throughput metrics come back
//! through [`metrics::ServerMetrics`].
//!
//! Thread-based (std::thread + mpsc): the offline build has no tokio, and
//! the workload (CPU-bound numeric batches, few queues) fits the
//! one-thread-per-worker model exactly.

pub mod request;
pub mod batcher;
pub mod lru;
pub mod router;
pub mod metrics;
pub mod net;
pub mod wire;
pub mod demo;

pub use net::{NetConfig, NetServer};
pub use request::{GenRequest, GenResponse, PlanKey};
pub use router::{Router, RouterConfig};

/// Poison-proof lock acquisition, promoted to [`crate::util::sync`] so
/// the engine/scheduler/runtime layers share the serving edge's policy
/// (see the rationale there). Re-exported here for compatibility: PR 7
/// introduced the helper under `server::` and callers still import it
/// from this path.
pub use crate::util::sync::lock_unpoisoned;
