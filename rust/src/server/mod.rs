//! The batched sampling service — the L3 "serving" coordinator.
//!
//! Clients submit [`request::GenRequest`]s; the [`router::Router`] groups
//! them by *plan key* (process, dataset, sampler config, NFE), the
//! [`batcher`] coalesces compatible requests into one batched sampler run
//! (score-model batching is where all the throughput is), worker threads
//! execute runs, and per-request latency/throughput metrics come back
//! through [`metrics::ServerMetrics`].
//!
//! Thread-based (std::thread + mpsc): the offline build has no tokio, and
//! the workload (CPU-bound numeric batches, few queues) fits the
//! one-thread-per-worker model exactly.

pub mod request;
pub mod batcher;
pub mod lru;
pub mod router;
pub mod metrics;
pub mod net;
pub mod wire;
pub mod demo;

pub use net::{NetConfig, NetServer};
pub use request::{GenRequest, GenResponse, PlanKey};
pub use router::{Router, RouterConfig};

use std::sync::{Mutex, MutexGuard};

/// Poison-proof lock acquisition for the serving boundary.
///
/// A panic in one dispatcher (or in a custom `PreparedFactory`) poisons
/// any mutex whose guard it held, and the default `.lock().unwrap()`
/// then panics every *later* caller too — one bad request would take
/// the whole edge down. The shared router/metrics state is simple data
/// (queues, counters, the plan cache) that stays structurally valid at
/// every await-free lock region, so the recovery policy is: take the
/// guard back with [`PoisonError::into_inner`](std::sync::PoisonError)
/// and keep serving.
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}
