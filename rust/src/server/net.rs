//! The TCP serving edge: a [`std::net::TcpListener`] front end over the
//! in-process [`Router`], speaking the line-delimited JSON format of
//! [`crate::server::wire`].
//!
//! Architecture (all std::thread, matching the rest of the stack):
//!
//! - an **accept thread** polls a nonblocking listener and pushes fresh
//!   connections into a *bounded* queue — when the queue is full the
//!   connection is answered with one shed line (carrying a `Retry-After`
//!   hint) and closed, so overload degrades into fast refusals instead
//!   of unbounded accept backlog;
//! - a **connection pool** of [`NetConfig::conn_threads`] workers pulls
//!   connections off that queue. Each connection gets a reader (the
//!   worker itself) plus a scoped writer thread, so responses stream
//!   back in completion order while the reader keeps parsing;
//! - per request, **admission control** runs in order: wire parse (a
//!   malformed line is answered and the connection *kept*), a
//!   per-client-IP token bucket, then the global in-flight watermark.
//!   Sheds carry `retry_after_ms`, derived from the SLO target: the
//!   edge expects to clear about one watermark's worth of requests per
//!   SLO window, so the hint scales with the overload depth;
//! - **graceful drain** on shutdown: stop accepting, stop admitting,
//!   finish every in-flight request (the writer threads block until the
//!   router has answered each admitted request), then join.
//!
//! All shared state goes through [`lock_unpoisoned`]: one panicking
//! thread must never convert into a poisoned-mutex panic storm across
//! the edge (see the policy note on the helper).

use std::collections::HashMap;
use std::io::{Read as _, Write as _};
use std::net::{IpAddr, Ipv4Addr, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::server::lock_unpoisoned;
use crate::server::metrics::{EdgeCounters, MetricsReport};
use crate::server::request::GenResponse;
use crate::server::router::Router;
use crate::server::wire::{self, WireRequest, WireResponse};
use crate::util::cli::Args;

/// Edge knobs. Defaults suit a loopback bench; a deployment tunes the
/// watermark and rate limit to its SLO.
#[derive(Clone, Debug)]
pub struct NetConfig {
    /// Connection-pool threads (each serves one connection at a time).
    pub conn_threads: usize,
    /// Bound of the accept queue between the accept thread and the
    /// pool; connections beyond it are shed at accept time.
    pub accept_queue: usize,
    /// Per-client-IP token-bucket refill rate (requests/second).
    /// `0.0` disables rate limiting.
    pub rate_limit: f64,
    /// Token-bucket capacity: the burst a client may send instantly.
    pub rate_burst: f64,
    /// Global in-flight watermark: requests admitted past it are shed
    /// with a `Retry-After` hint instead of queued without bound.
    pub max_inflight: usize,
    /// SLO target the `Retry-After` hint is derived from.
    pub slo_ms: u64,
    /// Largest request line (bytes, excluding the newline) a connection
    /// may send. Past it the reader answers a wire error, discards bytes
    /// until the next newline, and keeps the connection — a client
    /// cannot grow the per-connection buffer without bound.
    pub max_frame_len: usize,
    /// Poll granularity of the accept loop and connection readers (how
    /// quickly they notice `stop`).
    pub poll_interval: Duration,
}

impl Default for NetConfig {
    fn default() -> Self {
        NetConfig {
            conn_threads: 8,
            accept_queue: 64,
            rate_limit: 0.0,
            rate_burst: 32.0,
            max_inflight: 256,
            slo_ms: 50,
            max_frame_len: 64 * 1024,
            poll_interval: Duration::from_millis(20),
        }
    }
}

/// Classic token bucket, time passed in explicitly so the refill math
/// is unit-testable without sleeping.
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    fn full(burst: f64, now: Instant) -> TokenBucket {
        TokenBucket { tokens: burst.max(1.0), last: now }
    }

    /// Take one token, or say how long (ms) until one is available.
    fn admit(&mut self, now: Instant, rate: f64, burst: f64) -> Result<(), u64> {
        if rate <= 0.0 {
            return Ok(());
        }
        let dt = now.saturating_duration_since(self.last).as_secs_f64();
        self.tokens = (self.tokens + dt * rate).min(burst.max(1.0));
        self.last = now;
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            Ok(())
        } else {
            Err(((1.0 - self.tokens) / rate * 1000.0).ceil().max(1.0) as u64)
        }
    }
}

/// The SLO-derived `Retry-After` hint: the edge clears about one
/// watermark's worth of in-flight requests per SLO window, so a client
/// arriving `k` windows deep should back off ~`(k+1)` windows.
fn retry_after_ms(cfg: &NetConfig, inflight: usize) -> u64 {
    let windows = (inflight / cfg.max_inflight.max(1)) as u64 + 1;
    cfg.slo_ms.max(1) * windows
}

struct EdgeShared {
    router: Router,
    cfg: NetConfig,
    stop: AtomicBool,
    /// Requests admitted but not yet answered, across all connections.
    inflight: AtomicUsize,
    counters: EdgeCounters,
    buckets: Mutex<HashMap<IpAddr, TokenBucket>>,
}

/// A live serving edge. Dropping it (or calling
/// [`NetServer::shutdown`]) performs the graceful drain.
pub struct NetServer {
    shared: Arc<EdgeShared>,
    local_addr: SocketAddr,
    acceptor: Option<std::thread::JoinHandle<()>>,
    conns: Vec<std::thread::JoinHandle<()>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start serving `router` through it.
    pub fn bind(addr: &str, cfg: NetConfig, router: Router) -> crate::Result<NetServer> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| crate::Error::msg(format!("bind {addr}: {e}")))?;
        listener
            .set_nonblocking(true)
            .map_err(|e| crate::Error::msg(format!("set_nonblocking: {e}")))?;
        let local_addr =
            listener.local_addr().map_err(|e| crate::Error::msg(format!("local_addr: {e}")))?;
        let shared = Arc::new(EdgeShared {
            router,
            cfg: cfg.clone(),
            stop: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            counters: EdgeCounters::default(),
            buckets: Mutex::new(HashMap::new()),
        });

        let (conn_tx, conn_rx) = mpsc::sync_channel::<TcpStream>(cfg.accept_queue.max(1));
        // The pool shares one receiver behind a mutex (the same
        // single-consumer handoff idiom the engine's shard queue uses).
        let conn_rx = Arc::new(Mutex::new(conn_rx));

        let acceptor = {
            let sh = shared.clone();
            std::thread::Builder::new()
                .name("gddim-accept".to_string())
                .spawn(move || accept_loop(&sh, &listener, &conn_tx))
                .map_err(|e| crate::Error::msg(format!("spawn accept thread: {e}")))?
        };
        let mut conns = Vec::with_capacity(cfg.conn_threads.max(1));
        for i in 0..cfg.conn_threads.max(1) {
            let sh = shared.clone();
            let rx = conn_rx.clone();
            let h = std::thread::Builder::new()
                .name(format!("gddim-conn-{i}"))
                .spawn(move || conn_worker(&sh, &rx))
                .map_err(|e| crate::Error::msg(format!("spawn conn thread: {e}")))?;
            conns.push(h);
        }
        Ok(NetServer { shared, local_addr, acceptor: Some(acceptor), conns })
    }

    /// The bound address (useful with `--listen 127.0.0.1:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Router + engine + edge counters in one report.
    pub fn report(&self) -> MetricsReport {
        let mut r = self.shared.router.report();
        r.edge = Some(self.shared.counters.snapshot());
        r
    }

    /// Graceful drain: stop accepting and admitting, let every admitted
    /// request finish and reach its client, join the edge threads, then
    /// (via the router's own `Drop`) the dispatchers. Returns the final
    /// report.
    pub fn shutdown(mut self) -> MetricsReport {
        self.join_edge();
        let report = self.report();
        drop(self);
        report
    }

    fn join_edge(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        if let Some(a) = self.acceptor.take() {
            let _ = a.join();
        }
        for c in self.conns.drain(..) {
            let _ = c.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.join_edge();
    }
}

fn accept_loop(sh: &EdgeShared, listener: &TcpListener, conn_tx: &mpsc::SyncSender<TcpStream>) {
    loop {
        if sh.stop.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _peer)) => match conn_tx.try_send(stream) {
                Ok(()) => {
                    sh.counters.connections_accepted.fetch_add(1, Ordering::Relaxed);
                }
                Err(TrySendError::Full(stream)) => {
                    // Bounded accept queue: refuse fast, with a hint,
                    // instead of queueing connections without bound.
                    sh.counters.connections_shed.fetch_add(1, Ordering::Relaxed);
                    let hint = retry_after_ms(&sh.cfg, sh.inflight.load(Ordering::Relaxed));
                    let line = WireResponse::Error {
                        id: 0,
                        error: "accept queue full".to_string(),
                        retry_after_ms: Some(hint),
                    }
                    .to_line();
                    let mut stream = stream;
                    let _ = stream.write_all(line.as_bytes());
                }
                Err(TrySendError::Disconnected(_)) => return,
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                ) =>
            {
                std::thread::sleep(sh.cfg.poll_interval);
            }
            // Transient accept errors (EMFILE, aborted handshakes):
            // back off and keep listening.
            Err(_) => std::thread::sleep(sh.cfg.poll_interval),
        }
    }
}

fn conn_worker(sh: &EdgeShared, conn_rx: &Mutex<Receiver<TcpStream>>) {
    loop {
        let next = {
            let rx = lock_unpoisoned(conn_rx);
            rx.recv_timeout(sh.cfg.poll_interval)
        };
        match next {
            Ok(stream) => handle_conn(sh, stream),
            Err(RecvTimeoutError::Timeout) => {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
            }
            Err(RecvTimeoutError::Disconnected) => return,
        }
    }
}

/// Serve one connection until EOF, a hard I/O error, or drain.
///
/// The reader (this thread) parses and admits; a scoped writer thread
/// streams responses back in completion order. The status line for a
/// request is written *before* its reply channel reaches the writer, so
/// a client always sees `accepted` before the matching result line.
fn handle_conn(sh: &EdgeShared, stream: TcpStream) {
    let peer = stream.peer_addr().map(|a| a.ip()).unwrap_or(IpAddr::V4(Ipv4Addr::UNSPECIFIED));
    let _ = stream.set_nodelay(true);
    if stream.set_read_timeout(Some(sh.cfg.poll_interval)).is_err() {
        return;
    }
    let Ok(write_half) = stream.try_clone() else { return };
    let writer = Mutex::new(write_half);
    let depth = AtomicUsize::new(0);
    let (pend_tx, pend_rx) = mpsc::channel::<(u64, Receiver<GenResponse>)>();

    std::thread::scope(|scope| {
        let writer = &writer;
        let depth = &depth;
        scope.spawn(move || {
            for (id, rx) in pend_rx.iter() {
                // Block until the router answers: this is what makes
                // drain "finish in-flight" rather than "drop on stop".
                let resp = rx
                    .recv()
                    .unwrap_or_else(|_| GenResponse::rejected(id, "request lost".to_string()));
                write_line(writer, &WireResponse::from_gen(&resp).to_line());
                sh.counters.requests_completed.fetch_add(1, Ordering::Relaxed);
                if sh.stop.load(Ordering::SeqCst) {
                    sh.counters.requests_drained.fetch_add(1, Ordering::Relaxed);
                }
                depth.fetch_sub(1, Ordering::Relaxed);
                sh.inflight.fetch_sub(1, Ordering::Relaxed);
            }
        });

        // Byte-level line framing (not BufRead::read_line): with a read
        // timeout on the socket, a line can arrive split across reads,
        // and `read_line` may drop a partial multi-byte char on the
        // timeout error path. Accumulate raw bytes; cut at `\n`. The
        // accumulator is bounded by `max_frame_len`: a line that outgrows
        // it is answered immediately and its remaining bytes discarded up
        // to the next newline (`skipping`), so framing — and the
        // connection — survive.
        let mut sock = stream;
        let mut buf: Vec<u8> = Vec::new();
        let mut chunk = [0u8; 4096];
        let mut skipping = false;
        let max_frame = sh.cfg.max_frame_len.max(1);
        loop {
            while let Some(pos) = buf.iter().position(|&b| b == b'\n') {
                let line: Vec<u8> = buf.drain(..=pos).collect();
                if skipping {
                    // Tail of an already-answered oversized line.
                    skipping = false;
                    continue;
                }
                if pos > max_frame {
                    // A whole line can arrive in one read and still be
                    // over the cap; enforce it here too.
                    answer_oversized(sh, writer, &line[..pos.min(4096)], max_frame);
                    continue;
                }
                let text = String::from_utf8_lossy(&line);
                handle_line(sh, writer, depth, peer, &text, &pend_tx);
            }
            // No complete line buffered: bound the partial one. Past the
            // cap it can never become a valid frame, so answer it now and
            // drop bytes until a newline restores framing.
            if skipping {
                buf.clear();
            } else if buf.len() > max_frame {
                answer_oversized(sh, writer, &buf[..buf.len().min(4096)], max_frame);
                buf.clear();
                skipping = true;
            }
            if sh.stop.load(Ordering::SeqCst) {
                break;
            }
            match sock.read(&mut chunk) {
                Ok(0) => break,
                Ok(n) => buf.extend_from_slice(&chunk[..n]),
                Err(e)
                    if matches!(
                        e.kind(),
                        std::io::ErrorKind::WouldBlock
                            | std::io::ErrorKind::TimedOut
                            | std::io::ErrorKind::Interrupted
                    ) => {}
                Err(_) => break,
            }
        }
        drop(pend_tx);
        // Scope exit joins the writer: every admitted request has been
        // answered on the wire before the connection closes.
    });
}

/// Parse + admit one request line, answering it (shed/error) or handing
/// it to the router and the writer.
fn handle_line(
    sh: &EdgeShared,
    writer: &Mutex<TcpStream>,
    depth: &AtomicUsize,
    peer: IpAddr,
    line: &str,
    pending: &mpsc::Sender<(u64, Receiver<GenResponse>)>,
) {
    if line.trim().is_empty() {
        return;
    }
    let req = match WireRequest::parse_line(line) {
        Ok(r) => r,
        Err(e) => {
            // Answer the bad line and keep the connection: one typo'd
            // request must not kill its neighbours on the same socket.
            sh.counters.requests_malformed.fetch_add(1, Ordering::Relaxed);
            let resp = WireResponse::Error {
                id: wire::extract_id(line),
                error: format!("bad request: {e}"),
                retry_after_ms: None,
            };
            write_line(writer, &resp.to_line());
            return;
        }
    };
    if sh.stop.load(Ordering::SeqCst) {
        shed(sh, writer, req.id, "server draining");
        return;
    }
    if sh.cfg.rate_limit > 0.0 {
        let verdict = {
            let now = Instant::now();
            let mut buckets = lock_unpoisoned(&sh.buckets);
            let bucket =
                buckets.entry(peer).or_insert_with(|| TokenBucket::full(sh.cfg.rate_burst, now));
            bucket.admit(now, sh.cfg.rate_limit, sh.cfg.rate_burst)
        };
        if let Err(wait_ms) = verdict {
            sh.counters.requests_shed.fetch_add(1, Ordering::Relaxed);
            let resp = WireResponse::Error {
                id: req.id,
                error: "rate limit exceeded".to_string(),
                retry_after_ms: Some(wait_ms),
            };
            write_line(writer, &resp.to_line());
            return;
        }
    }
    let inflight = sh.inflight.load(Ordering::Relaxed);
    if inflight >= sh.cfg.max_inflight.max(1) {
        shed(sh, writer, req.id, "overloaded: in-flight watermark reached");
        return;
    }
    sh.inflight.fetch_add(1, Ordering::Relaxed);
    sh.counters.note_conn_depth(depth.fetch_add(1, Ordering::Relaxed) + 1);
    sh.counters.requests_admitted.fetch_add(1, Ordering::Relaxed);
    // Status before submit: the writer can only see the reply channel
    // after `pending.send`, so `accepted` always precedes the result.
    let status = WireResponse::Status { id: req.id, status: "accepted".to_string() };
    write_line(writer, &status.to_line());
    let rx = sh.router.submit(req.to_gen());
    if pending.send((req.id, rx)).is_err() {
        // Writer already gone (connection tear-down); undo admission.
        depth.fetch_sub(1, Ordering::Relaxed);
        sh.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Answer an over-length frame with a wire error and count it. Only a
/// bounded prefix of the line is passed in, so this never copies the
/// attacker-sized payload. Id recovery is best-effort: a complete
/// over-cap line that still parses gets its id echoed back; a truncated
/// prefix is answered with id 0.
fn answer_oversized(sh: &EdgeShared, writer: &Mutex<TcpStream>, seen: &[u8], max_frame: usize) {
    sh.counters.requests_oversized.fetch_add(1, Ordering::Relaxed);
    let prefix = String::from_utf8_lossy(seen);
    let resp = WireResponse::Error {
        id: wire::extract_id(&prefix),
        error: format!("frame exceeds max-frame ({max_frame} bytes)"),
        retry_after_ms: None,
    };
    write_line(writer, &resp.to_line());
}

/// Answer a request with a load-shed line carrying the SLO-derived
/// `Retry-After` hint.
fn shed(sh: &EdgeShared, writer: &Mutex<TcpStream>, id: u64, why: &str) {
    sh.counters.requests_shed.fetch_add(1, Ordering::Relaxed);
    let hint = retry_after_ms(&sh.cfg, sh.inflight.load(Ordering::Relaxed));
    let resp = WireResponse::Error { id, error: why.to_string(), retry_after_ms: Some(hint) };
    write_line(writer, &resp.to_line());
}

/// Whole-line write under the connection's write lock. Errors are
/// dropped: a vanished client surfaces as EOF on the reader side.
fn write_line(writer: &Mutex<TcpStream>, line: &str) {
    let mut w = lock_unpoisoned(writer);
    let _ = w.write_all(line.as_bytes());
}

/// `gddim serve --listen ADDR`: bind the edge over an oracle-backed
/// router (same construction knobs as the in-process demo) and serve
/// until `--duration-secs` elapses (0 = forever), reporting every
/// `--report-secs`. With `--models-dir DIR`, keys matching the
/// directory's manifest are served by the pure-Rust learned-score
/// backend (others still fall back to the oracle).
pub fn run_cli(args: &Args) {
    use crate::engine::{Engine, EngineConfig};
    use crate::server::batcher::BatcherConfig;
    use crate::server::router::{factory_for, RouterConfig};

    let Some(addr) = args.get("listen") else {
        eprintln!("error: serve --listen needs an address (e.g. 127.0.0.1:7878)");
        // gddim-lint: allow(no-process-exit) — CLI entry point: usage errors exit with status 2 before any server state exists
        std::process::exit(2);
    };
    let models_dir = args.get("models-dir").map(std::path::PathBuf::from);
    let factory = match factory_for(models_dir.as_deref()) {
        Ok(f) => f,
        Err(e) => {
            eprintln!("error: --models-dir: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a bad artifacts directory exits with status 2 before any server state exists
            std::process::exit(2);
        }
    };
    let router = Router::with_options(
        RouterConfig {
            dispatchers: args.get_usize("dispatchers", 2),
            plan_cache_capacity: args.get_usize("plan-cache", 64),
            plan_cache_dir: args.get("plan-cache-dir").map(std::path::PathBuf::from),
        },
        Engine::with_config(EngineConfig {
            workers: args.get_usize("workers", 4),
            shard_bytes: args.get_usize("shard-size", EngineConfig::default().shard_bytes),
            score_batch: args.get_usize("score-batch", 4096),
            score_wait: Duration::from_micros(args.get_u64("score-wait", 200)),
            ..EngineConfig::default()
        }),
        BatcherConfig {
            max_batch: args.get_usize("max-batch", 4096),
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 5)),
        },
        factory,
    );
    let cfg = NetConfig {
        conn_threads: args.get_usize("conn-threads", 8),
        accept_queue: args.get_usize("accept-queue", 64),
        rate_limit: args.get_f64("rate-limit", 0.0),
        rate_burst: args.get_f64("rate-burst", 32.0),
        max_inflight: args.get_usize("max-inflight", 256),
        slo_ms: args.get_u64("slo-ms", 50),
        max_frame_len: args.get_usize("max-frame", 64 * 1024),
        ..NetConfig::default()
    };
    let server = match NetServer::bind(&addr, cfg, router) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("error: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a failed bind exits with status 2 before any connection is accepted
            std::process::exit(2);
        }
    };
    println!(
        "listening on {} (line-delimited JSON; ^C or --duration-secs to stop)",
        server.local_addr()
    );
    let duration = args.get_u64("duration-secs", 0);
    let report_every = args.get_u64("report-secs", 10).max(1);
    let started = Instant::now();
    let mut last_report = Instant::now();
    loop {
        std::thread::sleep(Duration::from_millis(250));
        if last_report.elapsed().as_secs() >= report_every {
            println!("{}", server.report());
            last_report = Instant::now();
        }
        if duration > 0 && started.elapsed().as_secs() >= duration {
            break;
        }
    }
    println!("draining…");
    println!("{}", server.shutdown());
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::BatcherConfig;
    use crate::server::request::PlanKey;
    use crate::server::router::oracle_factory;
    use std::io::{BufRead, BufReader};

    #[test]
    fn token_bucket_enforces_rate_and_says_when_to_retry() {
        let t0 = Instant::now();
        let mut b = TokenBucket::full(2.0, t0);
        // Burst of 2 passes instantly, the third is refused with a hint
        // that matches the refill rate (10/s → ~100 ms per token).
        assert!(b.admit(t0, 10.0, 2.0).is_ok());
        assert!(b.admit(t0, 10.0, 2.0).is_ok());
        let wait = b.admit(t0, 10.0, 2.0).unwrap_err();
        assert!((50..=150).contains(&wait), "hint {wait} ms should be ~100 ms");
        // After 150 ms of refill a token is back.
        let t1 = t0 + Duration::from_millis(150);
        assert!(b.admit(t1, 10.0, 2.0).is_ok());
        // Refill never exceeds the burst.
        let t2 = t1 + Duration::from_secs(60);
        let mut ok = 0;
        while b.admit(t2, 10.0, 2.0).is_ok() {
            ok += 1;
        }
        assert_eq!(ok, 2, "a long idle client still only gets its burst");
        // Rate 0 disables the limiter entirely.
        let mut open = TokenBucket::full(1.0, t0);
        for _ in 0..100 {
            assert!(open.admit(t0, 0.0, 1.0).is_ok());
        }
    }

    #[test]
    fn retry_hint_scales_with_overload_depth() {
        let cfg = NetConfig { max_inflight: 10, slo_ms: 50, ..NetConfig::default() };
        assert_eq!(retry_after_ms(&cfg, 0), 50);
        assert_eq!(retry_after_ms(&cfg, 10), 100);
        assert_eq!(retry_after_ms(&cfg, 35), 200);
        let degenerate = NetConfig { max_inflight: 0, slo_ms: 0, ..NetConfig::default() };
        assert!(retry_after_ms(&degenerate, 5) >= 1, "hint is never 0");
    }

    #[test]
    fn oversized_line_is_answered_and_the_connection_survives() {
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        let cfg = NetConfig { conn_threads: 1, max_frame_len: 256, ..NetConfig::default() };
        let server = NetServer::bind("127.0.0.1:0", cfg, router).unwrap();
        let mut conn = TcpStream::connect(server.local_addr()).unwrap();
        // A line far past the 256-byte cap: answered with a wire error
        // instead of growing the reader's buffer to match.
        let mut big = vec![b'x'; 10 * 1024];
        big.push(b'\n');
        conn.write_all(&big).unwrap();
        let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
        let err = WireResponse::parse_line(&lines.next().unwrap().unwrap()).unwrap();
        match err {
            WireResponse::Error { error, retry_after_ms, .. } => {
                assert!(error.contains("max-frame"), "{error}");
                assert_eq!(retry_after_ms, None, "oversized is a client bug, not overload");
            }
            other => panic!("expected an error line, got {other:?}"),
        }
        // The same connection still serves a well-formed request.
        let req = WireRequest { id: 7, n: 2, seed: 1, key: PlanKey::gddim("vpsde", "gmm2d", 6, 2) };
        conn.write_all(req.to_line().as_bytes()).unwrap();
        let status = WireResponse::parse_line(&lines.next().unwrap().unwrap()).unwrap();
        assert_eq!(status, WireResponse::Status { id: 7, status: "accepted".to_string() });
        let result = WireResponse::parse_line(&lines.next().unwrap().unwrap()).unwrap();
        assert!(matches!(result, WireResponse::Result { id: 7, .. }), "{result:?}");
        drop(lines);
        drop(conn);
        let report = server.shutdown();
        let edge = report.edge.expect("edge counters ride the NetServer report");
        assert_eq!(edge.requests_oversized, 1, "one oversized line, answered exactly once");
        assert_eq!(edge.requests_completed, 1);
        assert_eq!(edge.requests_malformed, 0, "the oversized line is not double-counted");
    }

    #[test]
    fn loopback_single_request_round_trips() {
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        let cfg = NetConfig { conn_threads: 2, ..NetConfig::default() };
        let server = NetServer::bind("127.0.0.1:0", cfg, router).unwrap();
        let addr = server.local_addr();

        let mut conn = TcpStream::connect(addr).unwrap();
        let req =
            WireRequest { id: 11, n: 4, seed: 3, key: PlanKey::gddim("vpsde", "gmm2d", 6, 2) };
        conn.write_all(req.to_line().as_bytes()).unwrap();
        let mut lines = BufReader::new(conn.try_clone().unwrap()).lines();
        let status = WireResponse::parse_line(&lines.next().unwrap().unwrap()).unwrap();
        assert_eq!(status, WireResponse::Status { id: 11, status: "accepted".to_string() });
        let result = WireResponse::parse_line(&lines.next().unwrap().unwrap()).unwrap();
        match result {
            WireResponse::Result { id, dim_x, nfe, xs, .. } => {
                assert_eq!((id, dim_x, nfe), (11, 2, 6));
                assert_eq!(xs.len(), 4 * 2);
                assert!(xs.iter().all(|x| x.is_finite()));
            }
            other => panic!("expected a result line, got {other:?}"),
        }
        drop(lines);

        let report = server.shutdown();
        let edge = report.edge.expect("edge counters ride the NetServer report");
        assert_eq!(edge.connections_accepted, 1);
        assert_eq!(edge.requests_admitted, 1);
        assert_eq!(edge.requests_completed, 1);
        assert_eq!(edge.requests_shed, 0);
        assert!(edge.peak_conn_depth >= 1);
    }
}
