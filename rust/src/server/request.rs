//! Request/response types for the sampling service.

use std::sync::mpsc::Sender;

use crate::data::presets;
use crate::samplers::SamplerSpec;
use crate::util::json::Json;
use crate::Error;

/// What a client asks for.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Unique id assigned by the client (echoed back).
    pub id: u64,
    /// Number of samples wanted.
    pub n: usize,
    /// Sampling configuration (requests with equal keys are batchable).
    pub key: PlanKey,
    /// RNG seed for this request's share of the batch.
    pub seed: u64,
}

/// The batchable part of a request: requests with identical keys run in
/// one sampler invocation. The sampler and its full configuration live
/// in the owned [`SamplerSpec`] — every float in it (λ, rtol) is kept
/// bit-exact, so distinct configurations can never alias one key.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub process: String,
    pub dataset: String,
    pub spec: SamplerSpec,
    /// Time-grid steps for grid-driven samplers. RK45 ignores it for
    /// stepping (its `rtol` is the NFE knob) but it stays part of the
    /// key's identity.
    pub nfe: usize,
}

impl PlanKey {
    pub fn new(process: &str, dataset: &str, spec: SamplerSpec, nfe: usize) -> PlanKey {
        PlanKey { process: process.to_string(), dataset: dataset.to_string(), spec, nfe }
    }

    /// Deterministic gDDIM with the crate defaults (the historical
    /// constructor most call sites use).
    pub fn gddim(process: &str, dataset: &str, nfe: usize, q: usize) -> PlanKey {
        PlanKey::new(process, dataset, SamplerSpec::gddim(q), nfe)
    }

    /// Full validation against the built-in oracle catalogue: structural
    /// sampler checks (SSCS off CLD, λ ≤ 0, …) plus known
    /// process/dataset names. The oracle-backed CLIs use this to filter
    /// key mixes up front; the router itself only enforces the
    /// structural part at submit time and lets its `PreparedFactory`
    /// judge process/dataset servability (custom factories may serve
    /// names this catalogue does not know).
    pub fn validate(&self) -> crate::Result<()> {
        match self.process.as_str() {
            "vpsde" | "cld" | "bdm" => {}
            other => return Err(Error::msg(format!("unknown process `{other}`"))),
        }
        if presets::info(&self.dataset).is_none() {
            return Err(Error::msg(format!("unknown dataset `{}`", self.dataset)));
        }
        self.validate_dims()?;
        if self.nfe == 0 {
            return Err(Error::msg("nfe must be >= 1"));
        }
        self.spec.validate(&self.process)
    }

    /// Dimension compatibility of `(process, dataset)` for datasets the
    /// built-in catalogue knows. BDM is an image-space process whose
    /// `(h, w)` comes from the dataset's registry metadata, so a vector
    /// dataset (or any preset without image dims) on BDM is rejected
    /// here — at submit time — instead of panicking a dispatcher deep in
    /// oracle construction. Dataset names the catalogue does *not* know
    /// pass: a custom `PreparedFactory` may serve them and remains the
    /// authority on its own dimensioning.
    pub fn validate_dims(&self) -> crate::Result<()> {
        if self.process == "bdm" {
            if let Some(info) = presets::info(&self.dataset) {
                info.require_image_dims()?;
            }
        }
        Ok(())
    }

    /// JSON form used by the plan persistence files (the spec rides as
    /// its grammar string, which round-trips floats bit-exactly).
    pub fn to_json(&self) -> Json {
        let mut obj = std::collections::BTreeMap::new();
        obj.insert("process".to_string(), Json::Str(self.process.clone()));
        obj.insert("dataset".to_string(), Json::Str(self.dataset.clone()));
        obj.insert("spec".to_string(), Json::Str(self.spec.to_string()));
        obj.insert("nfe".to_string(), Json::Num(self.nfe as f64));
        Json::Obj(obj)
    }

    pub fn from_json(j: &Json) -> crate::Result<PlanKey> {
        let field = |k: &str| j.get(k).ok_or_else(|| Error::msg(format!("PlanKey: missing `{k}`")));
        let process = field("process")?.as_str().ok_or("PlanKey: process not a string")?;
        let dataset = field("dataset")?.as_str().ok_or("PlanKey: dataset not a string")?;
        let spec =
            SamplerSpec::parse(field("spec")?.as_str().ok_or("PlanKey: spec not a string")?)?;
        let nfe = field("nfe")?.as_usize().ok_or("PlanKey: nfe not a number")?;
        Ok(PlanKey::new(process, dataset, spec, nfe))
    }

    /// Deterministic file name for this key in a plan-cache directory:
    /// readable prefix + FNV-1a hash of the canonical JSON form (stable
    /// across runs and platforms, unlike `DefaultHasher`).
    pub fn cache_file_name(&self) -> String {
        let canonical = self.to_json().to_string_pretty();
        let mut h: u64 = 0xcbf29ce484222325;
        for b in canonical.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        format!("{}-{}-{}-{h:016x}.json", self.process, self.dataset, self.spec.name())
    }
}

/// What the client gets back.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated samples, row-major n × dim_x (empty if `error` is set).
    pub xs: Vec<f64>,
    pub dim_x: usize,
    /// NFE consumed by the batch this request rode in.
    pub nfe: usize,
    /// End-to-end latency (seconds): `queue_latency + service_latency`.
    pub latency: f64,
    /// Time spent queued before the batch was cut and execution started
    /// (seconds) — this is the component that explodes under overload.
    pub queue_latency: f64,
    /// Time spent preparing + executing the batch this request rode in
    /// (seconds); identical for all members of one batch.
    pub service_latency: f64,
    /// How many requests shared the batch (observability).
    pub batch_size: usize,
    /// Why the request was rejected, if it was (invalid key / sampler
    /// config). A rejected request is answered immediately and never
    /// reaches a dispatcher.
    pub error: Option<String>,
}

impl GenResponse {
    /// The immediate reply for a request that failed validation.
    pub fn rejected(id: u64, error: String) -> GenResponse {
        GenResponse {
            id,
            xs: Vec::new(),
            dim_x: 0,
            nfe: 0,
            latency: 0.0,
            queue_latency: 0.0,
            service_latency: 0.0,
            batch_size: 0,
            error: Some(error),
        }
    }
}

/// Internal envelope: request + reply channel + enqueue timestamp.
pub struct Envelope {
    pub req: GenRequest,
    pub reply: Sender<GenResponse>,
    pub enqueued: std::time::Instant,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::OrderedF64;

    #[test]
    fn key_json_round_trips_bit_exactly() {
        let keys = [
            PlanKey::gddim("cld", "gmm2d", 20, 3),
            PlanKey::new(
                "vpsde",
                "blobs8",
                SamplerSpec::Em { lambda: OrderedF64::new(0.0001) },
                50,
            ),
            PlanKey::new("cld", "hard2d", SamplerSpec::Sscs, 25),
            PlanKey::new("bdm", "blobs8", SamplerSpec::Rk45 { rtol: OrderedF64::new(3.7e-5) }, 1),
        ];
        for key in keys {
            let j = key.to_json();
            let back = PlanKey::from_json(&Json::parse(&j.to_string_pretty()).unwrap()).unwrap();
            assert_eq!(back, key);
            assert_eq!(back.cache_file_name(), key.cache_file_name());
        }
    }

    #[test]
    fn validation_rejects_bad_keys() {
        assert!(PlanKey::gddim("cld", "gmm2d", 10, 2).validate().is_ok());
        assert!(PlanKey::gddim("ddim", "gmm2d", 10, 2).validate().is_err());
        assert!(PlanKey::gddim("cld", "no-such-set", 10, 2).validate().is_err());
        assert!(PlanKey::gddim("cld", "gmm2d", 0, 2).validate().is_err());
        assert!(PlanKey::new("vpsde", "gmm2d", SamplerSpec::Sscs, 10).validate().is_err());
        assert!(PlanKey::new("cld", "gmm2d", SamplerSpec::Sscs, 10).validate().is_ok());
    }

    #[test]
    fn validation_checks_bdm_image_dims_at_submit_time() {
        // BDM on vector data is a structural mismatch, caught before any
        // dispatcher touches the key (the old path panicked inside the
        // oracle factory's dimension assert).
        for dataset in ["gmm2d", "hard2d", "spiral2d"] {
            let key = PlanKey::gddim("bdm", dataset, 10, 2);
            assert!(key.validate().is_err(), "{dataset} on bdm must be rejected");
            assert!(key.validate_dims().is_err(), "{dataset} dims check must fail");
        }
        // Every image preset serves on BDM at its registry dims.
        for dataset in ["blobs8", "faces8", "blobs16", "faces16", "blobs32"] {
            assert!(PlanKey::gddim("bdm", dataset, 10, 2).validate().is_ok(), "{dataset}");
        }
        // Unknown names pass the dims check (custom-factory freedom) but
        // still fail full catalogue validation.
        let custom = PlanKey::gddim("bdm", "my-own-images", 10, 2);
        assert!(custom.validate_dims().is_ok());
        assert!(custom.validate().is_err());
    }

    #[test]
    fn cache_file_names_distinguish_close_lambdas() {
        let a =
            PlanKey::new("cld", "gmm2d", SamplerSpec::Em { lambda: OrderedF64::new(0.0001) }, 10);
        let b = PlanKey::new("cld", "gmm2d", SamplerSpec::Em { lambda: OrderedF64::new(0.0) }, 10);
        assert_ne!(a.cache_file_name(), b.cache_file_name());
        assert!(a.cache_file_name().ends_with(".json"));
    }
}
