//! Request/response types for the sampling service.

use std::sync::mpsc::Sender;

use crate::diffusion::process::KtKind;

/// What a client asks for.
#[derive(Clone, Debug)]
pub struct GenRequest {
    /// Unique id assigned by the client (echoed back).
    pub id: u64,
    /// Number of samples wanted.
    pub n: usize,
    /// Sampling configuration (requests with equal keys are batchable).
    pub key: PlanKey,
    /// RNG seed for this request's share of the batch.
    pub seed: u64,
}

/// The batchable part of a request: requests with identical keys run in
/// one sampler invocation.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct PlanKey {
    pub process: String,
    pub dataset: String,
    pub sampler: SamplerKind,
    pub nfe: usize,
    pub q: usize,
    pub kt: KtKind,
    /// λ × 1000 (integerized so the key is hashable).
    pub lambda_milli: u32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum SamplerKind {
    GddimDet,
    GddimSde,
    Em,
    Ancestral,
}

impl PlanKey {
    pub fn gddim(process: &str, dataset: &str, nfe: usize, q: usize) -> PlanKey {
        PlanKey {
            process: process.to_string(),
            dataset: dataset.to_string(),
            sampler: SamplerKind::GddimDet,
            nfe,
            q,
            kt: KtKind::R,
            lambda_milli: 0,
        }
    }

    pub fn lambda(&self) -> f64 {
        self.lambda_milli as f64 / 1000.0
    }
}

/// What the client gets back.
#[derive(Clone, Debug)]
pub struct GenResponse {
    pub id: u64,
    /// Generated samples, row-major n × dim_x.
    pub xs: Vec<f64>,
    pub dim_x: usize,
    /// NFE consumed by the batch this request rode in.
    pub nfe: usize,
    /// End-to-end latency (seconds): `queue_latency + service_latency`.
    pub latency: f64,
    /// Time spent queued before the batch was cut and execution started
    /// (seconds) — this is the component that explodes under overload.
    pub queue_latency: f64,
    /// Time spent preparing + executing the batch this request rode in
    /// (seconds); identical for all members of one batch.
    pub service_latency: f64,
    /// How many requests shared the batch (observability).
    pub batch_size: usize,
}

/// Internal envelope: request + reply channel + enqueue timestamp.
pub struct Envelope {
    pub req: GenRequest,
    pub reply: Sender<GenResponse>,
    pub enqueued: std::time::Instant,
}
