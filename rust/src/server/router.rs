//! Request router + dispatch pool + sharded execution engine.
//!
//! `submit()` enqueues into the per-key [`KeyQueue`]; dispatcher threads
//! scan for ready queues (size or deadline cut), hand each cut batch to
//! the shared [`Engine`] — which shards it across its own worker pool —
//! and fan results back out to the per-request reply channels. Stage-I
//! plans and score models are built once per key and cached
//! ([`Prepared`]), so steady-state request cost is pure Stage-II.

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coeffs::plan::{PlanConfig, SamplerPlan};
use crate::data::presets;
use crate::diffusion::{Bdm, Cld, Process, TimeGrid, Vpsde};
use crate::engine::{Engine, Job, SamplerSpec};
use crate::score::model::ScoreModel;
use crate::score::oracle::GmmOracle;
use crate::server::batcher::{BatcherConfig, KeyQueue};
use crate::server::lru::LruCache;
use crate::server::metrics::{MetricsReport, ServerMetrics};
use crate::server::request::{Envelope, GenRequest, GenResponse, PlanKey, SamplerKind};

/// Everything needed to execute one key's batches.
pub struct Prepared {
    pub proc: Arc<dyn Process>,
    pub model: Arc<dyn ScoreModel>,
    pub plan: Option<Arc<SamplerPlan>>,
    pub grid: TimeGrid,
    pub dim_x: usize,
}

/// Builds [`Prepared`] state for a key. The default factory uses the
/// exact-score oracle; the serving demo swaps in PJRT-backed nets.
pub type PreparedFactory = dyn Fn(&PlanKey) -> Arc<Prepared> + Send + Sync;

/// Default factory: oracle scores on the named preset dataset.
pub fn oracle_factory() -> Box<PreparedFactory> {
    Box::new(|key: &PlanKey| {
        let spec = presets::by_name(&key.dataset).expect("unknown dataset");
        let proc: Arc<dyn Process> = match key.process.as_str() {
            "vpsde" => Arc::new(Vpsde::standard(spec.d)),
            "cld" => Arc::new(Cld::standard(spec.d)),
            "bdm" => {
                let side = (spec.d as f64).sqrt() as usize;
                Arc::new(Bdm::standard(side, side))
            }
            other => panic!("unknown process {other}"),
        };
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), key.nfe);
        let model: Arc<dyn ScoreModel> =
            Arc::new(GmmOracle::new(proc.clone(), spec.clone(), key.kt));
        let plan = match key.sampler {
            SamplerKind::GddimDet => Some(Arc::new(SamplerPlan::build(
                proc.as_ref(),
                &grid,
                &PlanConfig { q: key.q, kt: key.kt, ..PlanConfig::default() },
            ))),
            SamplerKind::GddimSde => Some(Arc::new(SamplerPlan::build(
                proc.as_ref(),
                &grid,
                &PlanConfig::stochastic(key.lambda().max(0.1)),
            ))),
            _ => None,
        };
        Arc::new(Prepared { dim_x: proc.dim_x(), proc, model, plan, grid })
    })
}

/// Router-level knobs (the batcher has its own [`BatcherConfig`]).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Threads cutting and routing batches.
    pub dispatchers: usize,
    /// Capacity of the [`Prepared`] plan cache. Bounded (LRU) so a
    /// long-tailed key population can't grow the cache without bound;
    /// an evicted key just pays Stage-I again on its next request
    /// (App. C.3: milliseconds, not a correctness event).
    pub plan_cache_capacity: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { dispatchers: 2, plan_cache_capacity: 64 }
    }
}

struct Shared {
    queues: Mutex<HashMap<PlanKey, KeyQueue>>,
    cv: Condvar,
    stop: AtomicBool,
    prepared: Mutex<LruCache<PlanKey, Arc<Prepared>>>,
    factory: Box<PreparedFactory>,
    engine: Engine,
    pub metrics: ServerMetrics,
    batcher_max_batch: usize,
    batcher_max_wait: Duration,
}

/// The sampling service.
pub struct Router {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// `n_workers` concurrent batches, each executed unsharded (a
    /// 1-worker engine) — the same total thread budget as the
    /// pre-engine router, so existing call sites keep their thread
    /// profile. Use [`Router::with_engine`] to shard *within* batches;
    /// note dispatchers × engine workers multiply.
    pub fn new(n_workers: usize, cfg: BatcherConfig, factory: Box<PreparedFactory>) -> Router {
        Router::with_engine(n_workers, Engine::new(1), cfg, factory)
    }

    /// Full control: `n_dispatchers` threads cut and route batches, and
    /// every cut batch is sharded across `engine`'s worker pool.
    pub fn with_engine(
        n_dispatchers: usize,
        engine: Engine,
        cfg: BatcherConfig,
        factory: Box<PreparedFactory>,
    ) -> Router {
        let rcfg = RouterConfig { dispatchers: n_dispatchers, ..RouterConfig::default() };
        Router::with_options(rcfg, engine, cfg, factory)
    }

    /// Everything configurable, including the plan-cache bound.
    pub fn with_options(
        rcfg: RouterConfig,
        engine: Engine,
        cfg: BatcherConfig,
        factory: Box<PreparedFactory>,
    ) -> Router {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            prepared: Mutex::new(LruCache::new(rcfg.plan_cache_capacity)),
            factory,
            engine,
            metrics: ServerMetrics::new(),
            batcher_max_batch: cfg.max_batch,
            batcher_max_wait: cfg.max_wait,
        });
        shared.metrics.start_clock();
        let workers = (0..rcfg.dispatchers.max(1))
            .map(|w| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("gddim-dispatch-{w}"))
                    .spawn(move || worker_loop(sh))
                    .unwrap()
            })
            .collect();
        Router { shared, workers }
    }

    /// Enqueue a request; the receiver yields exactly one response.
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let env = Envelope { req, reply: tx, enqueued: Instant::now() };
        {
            let mut qs = self.shared.queues.lock().unwrap();
            qs.entry(env.req.key.clone())
                .or_insert_with(|| {
                    KeyQueue::new(BatcherConfig {
                        max_batch: self.shared.batcher_max_batch,
                        max_wait: self.shared.batcher_max_wait,
                    })
                })
                .push(env);
        }
        self.shared.cv.notify_one();
        rx
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.shared.metrics
    }

    /// One report covering both layers: server counters plus a snapshot
    /// of the shared engine's pool counters.
    pub fn report(&self) -> MetricsReport {
        self.shared.metrics.report_with_engine(Some(self.shared.engine.stats()))
    }

    /// Entries currently held by the Stage-I plan cache (observability +
    /// eviction tests).
    pub fn plan_cache_len(&self) -> usize {
        self.shared.prepared.lock().unwrap().len()
    }

    /// Whether `key`'s Stage-I state is currently cached.
    pub fn plan_cache_contains(&self, key: &PlanKey) -> bool {
        self.shared.prepared.lock().unwrap().contains(key)
    }

    /// Graceful shutdown: drain queues, stop workers.
    pub fn shutdown(mut self) {
        // Wait for queues to drain.
        loop {
            let empty = {
                let qs = self.shared.queues.lock().unwrap();
                qs.values().all(|q| q.is_empty())
            };
            if empty {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    loop {
        // Find (or wait for) a ready queue.
        let batch = {
            let mut qs = sh.queues.lock().unwrap();
            loop {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                let now = Instant::now();
                let ready_key = qs
                    .iter()
                    .filter(|(_, q)| q.ready(now))
                    .map(|(k, _)| k.clone())
                    .next();
                if let Some(key) = ready_key {
                    break qs.get_mut(&key).unwrap().cut();
                }
                // Sleep briefly (deadline granularity) or until notified.
                let (guard, _timeout) =
                    sh.cv.wait_timeout(qs, Duration::from_millis(1)).unwrap();
                qs = guard;
            }
        };
        if batch.is_empty() {
            continue;
        }
        execute_batch(&sh, batch);
    }
}

fn prepared_for(sh: &Shared, key: &PlanKey) -> Arc<Prepared> {
    if let Some(p) = sh.prepared.lock().unwrap().get(key) {
        return p;
    }
    // Build outside the lock (plan construction can take milliseconds).
    let built = (sh.factory)(key);
    let mut cache = sh.prepared.lock().unwrap();
    // Another dispatcher may have built the same key while we did; keep
    // the first build so every batch of a key sees one Prepared.
    if let Some(p) = cache.get(key) {
        return p;
    }
    cache.insert(key.clone(), built.clone());
    built
}

fn execute_batch(sh: &Shared, batch: Vec<Envelope>) {
    // The queueing/service split is measured here: everything before
    // `t_exec` is queueing (batcher wait + dispatcher pickup), everything
    // after — plan lookup/build + engine run — is service.
    let t_exec = Instant::now();
    let key = batch[0].req.key.clone();
    let prep = prepared_for(sh, &key);
    let total_n: usize = batch.iter().map(|e| e.req.n).sum();
    // Batch seed: a deterministic fold of the member requests' seeds, so
    // identical traffic replays identically; the engine derives per-shard
    // streams from it.
    let seed = batch.iter().fold(0xBA7C4 ^ total_n as u64, |acc, e| {
        acc.wrapping_mul(0x100000001B3).wrapping_add(e.req.seed)
    });

    let sampler = match key.sampler {
        SamplerKind::GddimDet => SamplerSpec::GddimDet(prep.plan.as_deref().unwrap()),
        SamplerKind::GddimSde => SamplerSpec::GddimSde(prep.plan.as_deref().unwrap()),
        SamplerKind::Em => SamplerSpec::Em { grid: &prep.grid, lambda: key.lambda() },
        SamplerKind::Ancestral => SamplerSpec::Ancestral { grid: &prep.grid },
    };
    let out = sh.engine.run(&Job {
        proc: prep.proc.as_ref(),
        model: prep.model.as_ref(),
        sampler,
        n: total_n,
        seed,
    });

    // Record metrics *before* fanning out responses: a client that has
    // received its response must observe it in the counters.
    let now = Instant::now();
    let service = now.duration_since(t_exec).as_secs_f64();
    let n_requests = batch.len();
    let queue_lats: Vec<f64> = batch
        .iter()
        .map(|env| t_exec.duration_since(env.enqueued).as_secs_f64())
        .collect();
    let latencies: Vec<f64> = queue_lats.iter().map(|q| q + service).collect();
    sh.metrics.record_batch(n_requests, total_n, out.nfe, &latencies);

    // Fan out per-request slices.
    let dim_x = prep.dim_x;
    let mut offset = 0usize;
    for (env, queue_latency) in batch.into_iter().zip(queue_lats) {
        let n = env.req.n;
        let xs = out.xs[offset * dim_x..(offset + n) * dim_x].to_vec();
        offset += n;
        let _ = env.reply.send(GenResponse {
            id: env.req.id,
            xs,
            dim_x,
            nfe: out.nfe,
            latency: queue_latency + service,
            queue_latency,
            service_latency: service,
            batch_size: n_requests,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> PlanKey {
        PlanKey::gddim("vpsde", "gmm2d", 10, 2)
    }

    #[test]
    fn single_request_roundtrip() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let rx = router.submit(GenRequest { id: 7, n: 32, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.xs.len(), 32 * 2);
        assert_eq!(resp.nfe, 10);
        router.shutdown();
    }

    #[test]
    fn concurrent_requests_all_served_exactly_once() {
        let router = Router::new(3, BatcherConfig::default(), oracle_factory());
        let mut rxs = Vec::new();
        for id in 0..24u64 {
            rxs.push((id, router.submit(GenRequest { id, n: 16, key: key(), seed: id })));
        }
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.xs.len(), 16 * 2);
        }
        let report = router.metrics().report();
        assert_eq!(report.requests_done, 24);
        assert_eq!(report.samples_done, 24 * 16);
        router.shutdown();
    }

    #[test]
    fn batching_actually_happens() {
        // Long deadline + many small same-key requests → shared batches.
        let router = Router::new(
            1,
            BatcherConfig { max_batch: 1024, max_wait: Duration::from_millis(30) },
            oracle_factory(),
        );
        let mut rxs = Vec::new();
        for id in 0..16u64 {
            rxs.push(router.submit(GenRequest { id, n: 8, key: key(), seed: id }));
        }
        let mut max_batch = 0usize;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "expected coalesced batches, got max {max_batch}");
        router.shutdown();
    }

    #[test]
    fn with_engine_shards_large_batches() {
        use crate::engine::EngineConfig;
        let router = Router::with_engine(
            1,
            Engine::with_config(EngineConfig { workers: 4, shard_size: 64 }),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let rx = router.submit(GenRequest { id: 1, n: 500, key: key(), seed: 3 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.xs.len(), 500 * 2);
        assert!(resp.xs.iter().all(|x| x.is_finite()));
        router.shutdown();
    }

    #[test]
    fn plan_cache_evicts_least_recently_used_key() {
        let router = Router::with_options(
            RouterConfig { dispatchers: 1, plan_cache_capacity: 2 },
            Engine::new(1),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let k1 = PlanKey::gddim("vpsde", "gmm2d", 5, 1);
        let k2 = PlanKey::gddim("cld", "gmm2d", 5, 1);
        let k3 = PlanKey::gddim("vpsde", "gmm2d", 8, 1);
        for k in [&k1, &k2, &k3] {
            let rx = router.submit(GenRequest { id: 0, n: 4, key: k.clone(), seed: 0 });
            rx.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        assert_eq!(router.plan_cache_len(), 2, "cache must stay at capacity");
        assert!(!router.plan_cache_contains(&k1), "oldest key must be evicted");
        assert!(router.plan_cache_contains(&k2) && router.plan_cache_contains(&k3));
        // A request for the evicted key rebuilds it (evicting k2, now LRU).
        let rx = router.submit(GenRequest { id: 9, n: 4, key: k1.clone(), seed: 0 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.xs.len(), 4 * 2);
        assert!(router.plan_cache_contains(&k1));
        assert!(!router.plan_cache_contains(&k2));
        router.shutdown();
    }

    #[test]
    fn latency_split_adds_up() {
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        let rx = router.submit(GenRequest { id: 0, n: 64, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.queue_latency >= 0.0 && resp.service_latency > 0.0);
        assert!(
            (resp.queue_latency + resp.service_latency - resp.latency).abs() < 1e-9,
            "queue {} + service {} != total {}",
            resp.queue_latency,
            resp.service_latency,
            resp.latency
        );
        router.shutdown();
    }

    #[test]
    fn report_includes_engine_counters() {
        use crate::engine::EngineConfig;
        let router = Router::with_engine(
            1,
            Engine::with_config(EngineConfig { workers: 2, shard_size: 32 }),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let rx = router.submit(GenRequest { id: 0, n: 100, key: key(), seed: 1 });
        rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let report = router.report();
        let e = report.engine.as_ref().expect("router report carries engine stats");
        assert_eq!(e.workers, 2);
        assert_eq!(e.jobs_run, 1);
        assert_eq!(e.shards_executed, 4, "100 samples / shard_size 32 = 4 shards");
        assert!(report.to_string().contains("engine: workers=2"));
        router.shutdown();
    }

    #[test]
    fn different_keys_do_not_mix() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let k1 = PlanKey::gddim("vpsde", "gmm2d", 10, 1);
        let k2 = PlanKey::gddim("cld", "gmm2d", 10, 2);
        let r1 = router.submit(GenRequest { id: 1, n: 8, key: k1, seed: 0 });
        let r2 = router.submit(GenRequest { id: 2, n: 8, key: k2, seed: 0 });
        let a = r1.recv_timeout(Duration::from_secs(60)).unwrap();
        let b = r2.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(a.dim_x, 2);
        assert_eq!(b.dim_x, 2);
        assert_eq!(a.batch_size, 1);
        assert_eq!(b.batch_size, 1);
        router.shutdown();
    }
}
