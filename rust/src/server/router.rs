//! Request router + dispatch pool + sharded execution engine.
//!
//! `submit()` enqueues into the per-key [`KeyQueue`]; dispatcher threads
//! scan for ready queues (size or deadline cut), hand the cut batches to
//! the shared [`Engine`] — which shards them across its own worker pool —
//! and fan results back out to the per-request reply channels. Stage-I
//! plans and score models are built once per key and cached
//! ([`Prepared`]), so steady-state request cost is pure Stage-II.
//!
//! When the engine's cross-key score scheduler is enabled
//! ([`EngineConfig::score_batch`](crate::engine::EngineConfig)), a
//! dispatcher cuts *every* ready key in one scan and admits the batches
//! as one [`Engine::run_group`] submission: heterogeneous `PlanKey`s
//! execute together and their same-`t` score requests pool into shared
//! `eps_batch` calls (see [`crate::engine::scheduler`]). With the
//! scheduler off, dispatch is the historical one-key-per-scan loop.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coeffs::plan::SamplerPlan;
use crate::data::presets;
use crate::diffusion::process::KtKind;
use crate::diffusion::{Process, TimeGrid};
use crate::engine::{Engine, Job};
use crate::samplers::{SampleOutput, Sampler, SamplerSpec};
use crate::score::model::ScoreModel;
use crate::score::oracle::GmmOracle;
use crate::server::batcher::{BatcherConfig, KeyQueue};
use crate::server::lock_unpoisoned;
use crate::server::lru::LruCache;
use crate::server::metrics::{MetricsReport, ServerMetrics};
use crate::server::request::{Envelope, GenRequest, GenResponse, PlanKey};
use crate::util::json::Json;

/// Everything needed to execute one key's batches.
pub struct Prepared {
    pub proc: Arc<dyn Process>,
    pub model: Arc<dyn ScoreModel>,
    pub plan: Option<Arc<SamplerPlan>>,
    pub grid: TimeGrid,
    pub dim_x: usize,
}

impl Prepared {
    /// Instantiate the runnable Stage-II sampler for `spec` over this
    /// key's prepared state — the single construction path every served
    /// sampler goes through.
    pub fn sampler<'a>(&'a self, spec: &SamplerSpec) -> crate::Result<Box<dyn Sampler + 'a>> {
        spec.instantiate(self.plan.as_deref(), &self.grid)
    }
}

/// Builds [`Prepared`] state for a key, or rejects it — the factory is
/// the authority on which processes/datasets it can serve, so custom
/// factories (e.g. PJRT-backed nets over their own datasets) are not
/// constrained by the oracle catalogue. The second argument is a plan
/// preloaded from the persistence cache, if any — a factory should adopt
/// it (after checking `spec.matches_plan`) instead of re-running Stage I.
pub type PreparedFactory =
    dyn Fn(&PlanKey, Option<Arc<SamplerPlan>>) -> crate::Result<Arc<Prepared>> + Send + Sync;

/// Default factory: oracle scores on the named preset dataset. Handles
/// every [`SamplerSpec`] variant — gDDIM variants get a Stage-I plan
/// (preloaded or built), grid samplers just the grid. Unknown
/// processes/datasets come back as errors (answered per request), not
/// panics.
///
/// Keys that agree on `(process, dataset, K_t)` share **one**
/// [`GmmOracle`] instance (the factory memoizes them): the engine's
/// cross-key score scheduler pools requests by model identity, so
/// heterogeneous sampler specs over the same marginals can only fill one
/// another's `eps_batch` calls if they hold the same model object. The
/// memo is bounded by the preset catalogue (a few dozen combinations at
/// most), so it needs no eviction.
pub fn oracle_factory() -> Box<PreparedFactory> {
    let models: Mutex<HashMap<(String, String, KtKind), Arc<dyn ScoreModel>>> =
        Mutex::new(HashMap::new());
    Box::new(move |key: &PlanKey, preloaded: Option<Arc<SamplerPlan>>| {
        let info = presets::info(&key.dataset)
            .ok_or_else(|| crate::Error::msg(format!("unknown dataset `{}`", key.dataset)))?;
        let spec = info.build();
        // Registry-sized construction: BDM gets the preset's real (h, w)
        // instead of a sqrt(d) guess, and a vector dataset on BDM is a
        // clean rejection rather than a dimension-assert panic.
        let proc = crate::diffusion::process_for(&key.process, info)?;
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), key.nfe);
        let kt = key.spec.model_kt();
        let model: Arc<dyn ScoreModel> = {
            let mut cache = lock_unpoisoned(&models);
            cache
                .entry((key.process.clone(), key.dataset.clone(), kt))
                .or_insert_with(|| {
                    let built: Arc<dyn ScoreModel> =
                        Arc::new(GmmOracle::new(proc.clone(), spec.clone(), kt));
                    built
                })
                .clone()
        };
        let plan = match preloaded {
            Some(p) if key.spec.matches_plan(&p) && p.n_steps() == key.nfe => Some(p),
            _ => key
                .spec
                .plan_config()
                .map(|cfg| Arc::new(SamplerPlan::build(proc.as_ref(), &grid, &cfg))),
        };
        Ok(Arc::new(Prepared { dim_x: proc.dim_x(), proc, model, plan, grid }))
    })
}

/// Learned-score factory: keys whose `(process, dataset, K_t)` matches a
/// manifest entry with a `.gdw` artifact are served by the pure-Rust
/// [`crate::score::ScoreNet`]; everything else falls back to
/// [`oracle_factory`] — one `--models-dir` flag upgrades matching
/// traffic to learned scores without shrinking the servable key space.
///
/// The manifest is validated here (startup), each model loads lazily on
/// its first key and is probe-gated by
/// [`ScoreNet::load`](crate::score::ScoreNet::load); all keys matching
/// one entry share a single session `Arc` via
/// [`crate::score::ModelRegistry`], so the cross-key score scheduler
/// pools their `eps_batch` traffic exactly as it does for shared
/// oracles.
pub fn learned_factory(models_dir: impl AsRef<Path>) -> crate::Result<Box<PreparedFactory>> {
    let registry = crate::score::ModelRegistry::open(models_dir)?;
    let fallback = oracle_factory();
    Ok(Box::new(move |key: &PlanKey, preloaded: Option<Arc<SamplerPlan>>| {
        let kt = key.spec.model_kt();
        let Some(name) = registry.find(&key.process, &key.dataset, kt).map(|e| e.name.clone())
        else {
            return fallback(key, preloaded);
        };
        let model = registry.get(&name)?;
        let info = presets::info(&key.dataset)
            .ok_or_else(|| crate::Error::msg(format!("unknown dataset `{}`", key.dataset)))?;
        let proc = crate::diffusion::process_for(&key.process, info)?;
        if model.dim_u() != proc.dim_u() {
            return Err(crate::Error::msg(format!(
                "model {name} has dim_u={} but process {} needs {}",
                model.dim_u(),
                key.process,
                proc.dim_u()
            )));
        }
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), key.nfe);
        let plan = match preloaded {
            Some(p) if key.spec.matches_plan(&p) && p.n_steps() == key.nfe => Some(p),
            _ => key
                .spec
                .plan_config()
                .map(|cfg| Arc::new(SamplerPlan::build(proc.as_ref(), &grid, &cfg))),
        };
        Ok(Arc::new(Prepared { dim_x: proc.dim_x(), proc, model, plan, grid }))
    }))
}

/// The factory the CLI surfaces pick: [`learned_factory`] when a models
/// directory was given, plain [`oracle_factory`] otherwise.
pub fn factory_for(models_dir: Option<&Path>) -> crate::Result<Box<PreparedFactory>> {
    match models_dir {
        Some(dir) => learned_factory(dir),
        None => Ok(oracle_factory()),
    }
}

/// Router-level knobs (the batcher has its own [`BatcherConfig`]).
#[derive(Clone, Debug)]
pub struct RouterConfig {
    /// Threads cutting and routing batches.
    pub dispatchers: usize,
    /// Capacity of the [`Prepared`] plan cache. Bounded (LRU) so a
    /// long-tailed key population can't grow the cache without bound;
    /// an evicted key just pays Stage-I again on its next request
    /// (App. C.3: milliseconds, not a correctness event).
    pub plan_cache_capacity: usize,
    /// Directory for Stage-I plan persistence. When set, every plan the
    /// router builds is written here as `{key, plan}` JSON, and on
    /// startup all readable files warm the LRU — so plans survive
    /// restarts (App. C.3 "calculated once and used everywhere", across
    /// processes). Corrupt files are skipped, never fatal.
    pub plan_cache_dir: Option<PathBuf>,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig { dispatchers: 2, plan_cache_capacity: 64, plan_cache_dir: None }
    }
}

struct Shared {
    queues: Mutex<HashMap<PlanKey, KeyQueue>>,
    cv: Condvar,
    stop: AtomicBool,
    prepared: Mutex<LruCache<PlanKey, Arc<Prepared>>>,
    factory: Box<PreparedFactory>,
    engine: Engine,
    plan_cache_dir: Option<PathBuf>,
    pub metrics: ServerMetrics,
    batcher_max_batch: usize,
    batcher_max_wait: Duration,
}

/// The sampling service.
pub struct Router {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl Router {
    /// `n_workers` concurrent batches, each executed unsharded (a
    /// 1-worker engine) — the same total thread budget as the
    /// pre-engine router, so existing call sites keep their thread
    /// profile. Use [`Router::with_engine`] to shard *within* batches;
    /// note dispatchers × engine workers multiply.
    pub fn new(n_workers: usize, cfg: BatcherConfig, factory: Box<PreparedFactory>) -> Router {
        Router::with_engine(n_workers, Engine::new(1), cfg, factory)
    }

    /// Full control: `n_dispatchers` threads cut and route batches, and
    /// every cut batch is sharded across `engine`'s worker pool.
    pub fn with_engine(
        n_dispatchers: usize,
        engine: Engine,
        cfg: BatcherConfig,
        factory: Box<PreparedFactory>,
    ) -> Router {
        let rcfg = RouterConfig { dispatchers: n_dispatchers, ..RouterConfig::default() };
        Router::with_options(rcfg, engine, cfg, factory)
    }

    /// Everything configurable, including the plan-cache bound.
    pub fn with_options(
        rcfg: RouterConfig,
        engine: Engine,
        cfg: BatcherConfig,
        factory: Box<PreparedFactory>,
    ) -> Router {
        let shared = Arc::new(Shared {
            queues: Mutex::new(HashMap::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            prepared: Mutex::new(LruCache::new(rcfg.plan_cache_capacity)),
            factory,
            engine,
            plan_cache_dir: rcfg.plan_cache_dir.clone(),
            metrics: ServerMetrics::new(),
            batcher_max_batch: cfg.max_batch,
            batcher_max_wait: cfg.max_wait,
        });
        if let Some(dir) = shared.plan_cache_dir.clone() {
            warm_plan_cache(&shared, &dir);
        }
        shared.metrics.start_clock();
        let workers = (0..rcfg.dispatchers.max(1))
            .map(|w| {
                let sh = shared.clone();
                std::thread::Builder::new()
                    .name(format!("gddim-dispatch-{w}"))
                    .spawn(move || worker_loop(sh))
                    // gddim-lint: allow(panic-reachability) — construction-time fail-fast: no request can be queued before the router exists
                    .expect("router: failed to spawn dispatcher")
            })
            .collect();
        Router { shared, workers }
    }

    /// Enqueue a request; the receiver yields exactly one response. A
    /// structurally invalid request (`n = 0`, or a bad sampler config —
    /// e.g. SSCS off CLD, λ ≤ 0, nfe = 0 — or a catalogue dataset whose
    /// dimensions cannot fit the process, e.g. 2-D vector data on the
    /// image-space BDM) is answered immediately with
    /// `GenResponse::error` set and never reaches a dispatcher; whether
    /// a *well-formed* key's process/dataset is servable is the
    /// factory's call, answered per request at preparation time
    /// (datasets the catalogue does not know pass the dims check
    /// untouched).
    pub fn submit(&self, req: GenRequest) -> Receiver<GenResponse> {
        let (tx, rx) = channel();
        let structural = if req.key.nfe == 0 {
            Err(crate::Error::msg("nfe must be >= 1"))
        } else if req.n == 0 {
            // A zero-sample request would flow into batch accounting as
            // a zero-row slice of someone else's batch, skewing the
            // fill/throughput counters — reject it like any other
            // structural error.
            Err(crate::Error::msg("n must be >= 1"))
        } else {
            req.key.validate_dims().and_then(|()| req.key.spec.validate(&req.key.process))
        };
        if let Err(e) = structural {
            let _ = tx.send(GenResponse::rejected(req.id, e.to_string()));
            return rx;
        }
        let env = Envelope { req, reply: tx, enqueued: Instant::now() };
        {
            let mut qs = lock_unpoisoned(&self.shared.queues);
            qs.entry(env.req.key.clone())
                .or_insert_with(|| {
                    KeyQueue::new(BatcherConfig {
                        max_batch: self.shared.batcher_max_batch,
                        max_wait: self.shared.batcher_max_wait,
                    })
                })
                .push(env);
        }
        self.shared.cv.notify_one();
        rx
    }

    pub fn metrics(&self) -> &ServerMetrics {
        &self.shared.metrics
    }

    /// One report covering both layers: server counters plus a snapshot
    /// of the shared engine's pool counters.
    pub fn report(&self) -> MetricsReport {
        self.shared.metrics.report_with_engine(Some(self.shared.engine.stats()))
    }

    /// Entries currently held by the Stage-I plan cache (observability +
    /// eviction tests).
    pub fn plan_cache_len(&self) -> usize {
        lock_unpoisoned(&self.shared.prepared).len()
    }

    /// Whether `key`'s Stage-I state is currently cached.
    pub fn plan_cache_contains(&self, key: &PlanKey) -> bool {
        lock_unpoisoned(&self.shared.prepared).contains(key)
    }

    /// Graceful shutdown: drain queues, stop workers.
    pub fn shutdown(mut self) {
        // Wait for queues to drain.
        loop {
            let empty = {
                let qs = lock_unpoisoned(&self.shared.queues);
                qs.values().all(|q| q.is_empty())
            };
            if empty {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for Router {
    fn drop(&mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        self.shared.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

fn worker_loop(sh: Arc<Shared>) {
    // With the engine's cross-key score scheduler on, a dispatcher cuts
    // *every* ready key in one scan and submits the cuts as one engine
    // group — heterogeneous `PlanKey`s in one `run_group` admission, so
    // their same-`t` score calls can pool from the first evaluation.
    // With the scheduler off, the historical one-key-per-scan dispatch
    // (and its latency profile) is preserved exactly.
    let group_admission = sh.engine.score_batching();
    loop {
        // Find (or wait for) ready queues.
        let batches: Vec<Vec<Envelope>> = {
            let mut qs = lock_unpoisoned(&sh.queues);
            loop {
                if sh.stop.load(Ordering::SeqCst) {
                    return;
                }
                let now = Instant::now();
                let ready: Vec<PlanKey> = if group_admission {
                    qs.iter().filter(|(_, q)| q.ready(now)).map(|(k, _)| k.clone()).collect()
                } else {
                    // One key per scan, found without cloning the rest —
                    // the historical hot path, allocation profile intact.
                    let first = qs.iter().find(|(_, q)| q.ready(now)).map(|(k, _)| k.clone());
                    first.into_iter().collect()
                };
                if !ready.is_empty() {
                    break ready
                        .into_iter()
                        .filter_map(|key| qs.get_mut(&key).map(|q| q.cut()))
                        .filter(|b| !b.is_empty())
                        .collect();
                }
                // Sleep briefly (deadline granularity) or until notified.
                let (guard, _timeout) = sh
                    .cv
                    .wait_timeout(qs, Duration::from_millis(1))
                    .unwrap_or_else(|e| e.into_inner());
                qs = guard;
            }
        };
        if batches.is_empty() {
            continue;
        }
        execute_group(&sh, batches);
    }
}

fn prepared_for(sh: &Shared, key: &PlanKey) -> crate::Result<Arc<Prepared>> {
    if let Some(p) = lock_unpoisoned(&sh.prepared).get(key) {
        return Ok(p);
    }
    // Build outside the lock (plan construction can take milliseconds).
    // A factory rejection is answered per request by the caller, never
    // cached: a transient failure must not poison the key. The call is
    // also panic-contained: a panicking custom factory must cost only
    // the requests riding this batch — not the dispatcher thread, and
    // with it every queue the dispatcher would have served.
    let built = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| (sh.factory)(key, None)))
        .unwrap_or_else(|_| Err(crate::Error::msg("prepared factory panicked")))?;
    if let Some(dir) = &sh.plan_cache_dir {
        persist_plan(dir, key, built.plan.as_deref());
    }
    let mut cache = lock_unpoisoned(&sh.prepared);
    // Another dispatcher may have built the same key while we did; keep
    // the first build so every batch of a key sees one Prepared.
    if let Some(p) = cache.get(key) {
        return Ok(p);
    }
    cache.insert(key.clone(), built.clone());
    Ok(built)
}

/// Best-effort write of a freshly built Stage-I plan to the persistence
/// directory (skipped if the key's file already exists). I/O failures
/// are swallowed: persistence is an optimization, never a correctness
/// event.
fn persist_plan(dir: &Path, key: &PlanKey, plan: Option<&SamplerPlan>) {
    let Some(plan) = plan else { return };
    let path = dir.join(key.cache_file_name());
    if path.exists() || std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let mut obj = BTreeMap::new();
    obj.insert("key".to_string(), key.to_json());
    obj.insert("plan".to_string(), plan.to_json());
    // Write-then-rename so a reader never sees a torn file. The temp
    // name carries pid + a process-wide counter: two dispatchers racing
    // on the same key (prepared_for allows a double build) must not
    // interleave writes into one temp path.
    static TMP_SEQ: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
    let seq = TMP_SEQ.fetch_add(1, Ordering::Relaxed);
    let tmp = dir.join(format!(
        "{}.tmp{}-{seq}",
        key.cache_file_name(),
        std::process::id()
    ));
    if std::fs::write(&tmp, Json::Obj(obj).to_string_pretty()).is_ok() {
        let _ = std::fs::rename(&tmp, &path);
    }
}

/// Parse one persisted `{key, plan}` file (shared by the warm start and
/// tests). Validates that the plan actually belongs to the key.
pub fn parse_plan_file(text: &str) -> crate::Result<(PlanKey, SamplerPlan)> {
    let j = Json::parse(text)?;
    let key = PlanKey::from_json(j.get("key").ok_or("plan file: missing `key`")?)?;
    let plan = SamplerPlan::from_json(j.get("plan").ok_or("plan file: missing `plan`")?)?;
    if !key.spec.matches_plan(&plan) || plan.n_steps() != key.nfe {
        return Err(crate::Error::msg("plan file: plan does not match its key"));
    }
    Ok((key, plan))
}

/// Warm the Stage-I LRU from a persistence directory: every readable
/// `{key, plan}` file becomes a cached [`Prepared`] without re-running
/// Stage I. Files are visited in sorted order (deterministic LRU state),
/// and anything unreadable or inconsistent is skipped with a note.
fn warm_plan_cache(sh: &Shared, dir: &Path) {
    let Ok(entries) = std::fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries
        .flatten()
        .map(|e| e.path())
        .filter(|p| p.extension().is_some_and(|e| e == "json"))
        .collect();
    paths.sort();
    for path in paths {
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        match parse_plan_file(&text).and_then(|(key, plan)| {
            let prep = (sh.factory)(&key, Some(Arc::new(plan)))?;
            Ok((key, prep))
        }) {
            Ok((key, prep)) => {
                lock_unpoisoned(&sh.prepared).insert(key, prep);
            }
            Err(e) => eprintln!("plan cache: skipping {}: {e}", path.display()),
        }
    }
}

/// Execute one admission group: one cut batch per key, run as a single
/// engine [`Engine::run_group`] submission (the scheduler-on path hands
/// heterogeneous keys to the engine together; the scheduler-off path
/// always has exactly one batch here, preserving the historical
/// behavior byte for byte).
///
/// The queueing/service split is measured here: everything before
/// `t_exec` is queueing (batcher wait + dispatcher pickup), everything
/// after — plan lookup/build + engine run — is service. Grouped batches
/// share one service window (their shards share the engine), so a
/// request's reported service latency includes its group siblings'
/// execution — and, on a cold cache, their Stage-I builds. In steady
/// state plans are cache hits (the workload probes warm every key up
/// front), so this mainly matters for cold-start measurements.
fn execute_group(sh: &Shared, batches: Vec<Vec<Envelope>>) {
    let t_exec = Instant::now();
    let reject = |batch: Vec<Envelope>, msg: &str| {
        for env in batch {
            let _ = env.reply.send(GenResponse::rejected(env.req.id, msg.to_string()));
        }
    };

    // Admission: resolve each batch's Prepared state. A factory
    // rejection (unknown process/dataset for *this* factory, failed
    // model load, …) is answered per request — the dispatcher survives
    // and sibling batches are unaffected.
    struct Admitted {
        batch: Vec<Envelope>,
        prep: Arc<Prepared>,
        total_n: usize,
        seed: u64,
    }
    let mut admitted: Vec<Admitted> = Vec::with_capacity(batches.len());
    for batch in batches {
        let key = batch[0].req.key.clone();
        let prep = match prepared_for(sh, &key) {
            Ok(p) => p,
            Err(e) => {
                reject(batch, &e.to_string());
                continue;
            }
        };
        let total_n: usize = batch.iter().map(|e| e.req.n).sum();
        // Batch seed: a deterministic fold of the member requests' seeds,
        // so identical traffic replays identically; the engine derives
        // per-shard streams from it.
        let seed = batch.iter().fold(0xBA7C4 ^ total_n as u64, |acc, e| {
            acc.wrapping_mul(0x100000001B3).wrapping_add(e.req.seed)
        });
        admitted.push(Admitted { batch, prep, total_n, seed });
    }

    // Uniform construction path: any SamplerSpec variant becomes a trait
    // object the engine drives. Submit-time validation makes a failure
    // here a defensive branch (e.g. a custom factory dropping the plan),
    // answered per-request instead of panicking the dispatcher. The
    // boxes borrow `admitted`'s Prepared Arcs, so errors are extracted
    // first and the failed indices answered after the group runs.
    let samplers: Vec<crate::Result<Box<dyn Sampler + '_>>> =
        admitted.iter().map(|a| a.prep.sampler(&a.batch[0].req.key.spec)).collect();
    let errs: Vec<Option<String>> =
        samplers.iter().map(|r| r.as_ref().err().map(|e| e.to_string())).collect();
    let mut jobs: Vec<Job<'_>> = Vec::with_capacity(admitted.len());
    let mut job_of: Vec<Option<usize>> = vec![None; admitted.len()];
    for (i, built) in samplers.iter().enumerate() {
        if let Ok(sampler) = built {
            job_of[i] = Some(jobs.len());
            let a = &admitted[i];
            jobs.push(Job {
                proc: a.prep.proc.as_ref(),
                model: a.prep.model.as_ref(),
                sampler: sampler.as_ref(),
                n: a.total_n,
                seed: a.seed,
            });
        }
    }
    let mut outs: Vec<Option<SampleOutput>> = if jobs.is_empty() {
        Vec::new()
    } else {
        sh.engine.run_group(&jobs).into_iter().map(Some).collect()
    };
    drop(jobs);
    drop(samplers);

    // Record metrics *before* fanning out responses: a client that has
    // received its response must observe it in the counters.
    let now = Instant::now();
    let service = now.duration_since(t_exec).as_secs_f64();
    for (i, a) in admitted.into_iter().enumerate() {
        let Admitted { batch, prep, total_n, .. } = a;
        let Some(j) = job_of[i] else {
            reject(batch, errs[i].as_deref().unwrap_or("sampler construction failed"));
            continue;
        };
        // gddim-lint: allow(panic-reachability) — structural invariant: run_group returned one output per job and j indexes this batch's job
        let out = outs[j].take().expect("one engine output per admitted job");
        let n_requests = batch.len();
        let queue_lats: Vec<f64> = batch
            .iter()
            .map(|env| t_exec.duration_since(env.enqueued).as_secs_f64())
            .collect();
        let latencies: Vec<f64> = queue_lats.iter().map(|q| q + service).collect();
        sh.metrics.record_batch(n_requests, total_n, out.nfe, &latencies);

        // Fan out per-request slices.
        let dim_x = prep.dim_x;
        let mut offset = 0usize;
        for (env, queue_latency) in batch.into_iter().zip(queue_lats) {
            let n = env.req.n;
            let xs = out.xs[offset * dim_x..(offset + n) * dim_x].to_vec();
            offset += n;
            let _ = env.reply.send(GenResponse {
                id: env.req.id,
                xs,
                dim_x,
                nfe: out.nfe,
                latency: queue_latency + service,
                queue_latency,
                service_latency: service,
                batch_size: n_requests,
                error: None,
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key() -> PlanKey {
        PlanKey::gddim("vpsde", "gmm2d", 10, 2)
    }

    #[test]
    fn learned_factory_routes_fixture_keys_and_falls_back() {
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/tests/fixtures/learned");
        let factory = learned_factory(dir).unwrap();
        // (vpsde, gmm2d, R) has a fixture entry → served by the ScoreNet.
        let prep = factory(&key(), None).unwrap();
        assert!(
            prep.model.describe().starts_with("score-net(tiny_vpsde_gmm2d"),
            "{}",
            prep.model.describe()
        );
        // No fixture for blobs8 → transparent oracle fallback.
        let prep = factory(&PlanKey::gddim("vpsde", "blobs8", 10, 2), None).unwrap();
        assert!(!prep.model.describe().starts_with("score-net"), "{}", prep.model.describe());
        // Missing manifest is a startup error, not a request-time one.
        assert!(learned_factory("/nonexistent/gddim-models").is_err());
    }

    #[test]
    fn single_request_roundtrip() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let rx = router.submit(GenRequest { id: 7, n: 32, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.id, 7);
        assert_eq!(resp.xs.len(), 32 * 2);
        assert_eq!(resp.nfe, 10);
        router.shutdown();
    }

    #[test]
    fn concurrent_requests_all_served_exactly_once() {
        let router = Router::new(3, BatcherConfig::default(), oracle_factory());
        let mut rxs = Vec::new();
        for id in 0..24u64 {
            rxs.push((id, router.submit(GenRequest { id, n: 16, key: key(), seed: id })));
        }
        for (id, rx) in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            assert_eq!(resp.id, id);
            assert_eq!(resp.xs.len(), 16 * 2);
        }
        let report = router.metrics().report();
        assert_eq!(report.requests_done, 24);
        assert_eq!(report.samples_done, 24 * 16);
        router.shutdown();
    }

    #[test]
    fn batching_actually_happens() {
        // Long deadline + many small same-key requests → shared batches.
        let router = Router::new(
            1,
            BatcherConfig { max_batch: 1024, max_wait: Duration::from_millis(30) },
            oracle_factory(),
        );
        let mut rxs = Vec::new();
        for id in 0..16u64 {
            rxs.push(router.submit(GenRequest { id, n: 8, key: key(), seed: id }));
        }
        let mut max_batch = 0usize;
        for rx in rxs {
            let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
            max_batch = max_batch.max(resp.batch_size);
        }
        assert!(max_batch > 1, "expected coalesced batches, got max {max_batch}");
        router.shutdown();
    }

    #[test]
    fn with_engine_shards_large_batches() {
        use crate::engine::EngineConfig;
        let router = Router::with_engine(
            1,
            Engine::with_config(EngineConfig { workers: 4, shard_size: 64, ..Default::default() }),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let rx = router.submit(GenRequest { id: 1, n: 500, key: key(), seed: 3 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.xs.len(), 500 * 2);
        assert!(resp.xs.iter().all(|x| x.is_finite()));
        router.shutdown();
    }

    #[test]
    fn plan_cache_evicts_least_recently_used_key() {
        let router = Router::with_options(
            RouterConfig { dispatchers: 1, plan_cache_capacity: 2, ..RouterConfig::default() },
            Engine::new(1),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let k1 = PlanKey::gddim("vpsde", "gmm2d", 5, 1);
        let k2 = PlanKey::gddim("cld", "gmm2d", 5, 1);
        let k3 = PlanKey::gddim("vpsde", "gmm2d", 8, 1);
        for k in [&k1, &k2, &k3] {
            let rx = router.submit(GenRequest { id: 0, n: 4, key: k.clone(), seed: 0 });
            rx.recv_timeout(Duration::from_secs(60)).unwrap();
        }
        assert_eq!(router.plan_cache_len(), 2, "cache must stay at capacity");
        assert!(!router.plan_cache_contains(&k1), "oldest key must be evicted");
        assert!(router.plan_cache_contains(&k2) && router.plan_cache_contains(&k3));
        // A request for the evicted key rebuilds it (evicting k2, now LRU).
        let rx = router.submit(GenRequest { id: 9, n: 4, key: k1.clone(), seed: 0 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(resp.xs.len(), 4 * 2);
        assert!(router.plan_cache_contains(&k1));
        assert!(!router.plan_cache_contains(&k2));
        router.shutdown();
    }

    #[test]
    fn latency_split_adds_up() {
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        let rx = router.submit(GenRequest { id: 0, n: 64, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.queue_latency >= 0.0 && resp.service_latency > 0.0);
        assert!(
            (resp.queue_latency + resp.service_latency - resp.latency).abs() < 1e-9,
            "queue {} + service {} != total {}",
            resp.queue_latency,
            resp.service_latency,
            resp.latency
        );
        router.shutdown();
    }

    #[test]
    fn report_includes_engine_counters() {
        use crate::engine::EngineConfig;
        let router = Router::with_engine(
            1,
            Engine::with_config(EngineConfig { workers: 2, shard_size: 32, ..Default::default() }),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let rx = router.submit(GenRequest { id: 0, n: 100, key: key(), seed: 1 });
        rx.recv_timeout(Duration::from_secs(60)).unwrap();
        let report = router.report();
        let e = report.engine.as_ref().expect("router report carries engine stats");
        assert_eq!(e.workers, 2);
        assert_eq!(e.jobs_run, 1);
        assert_eq!(e.shards_executed, 4, "100 samples / shard_size 32 = 4 shards");
        assert!(report.to_string().contains("engine: workers=2"));
        router.shutdown();
    }

    #[test]
    fn invalid_keys_are_rejected_cleanly_not_panicked() {
        use crate::samplers::SamplerSpec;
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        // SSCS off CLD, unknown process, unknown dataset: each must come
        // back as an error response (the old router panicked a
        // dispatcher on the unknown-process path).
        let bad = [
            PlanKey::new("vpsde", "gmm2d", SamplerSpec::Sscs, 10),
            PlanKey::new("ddpmpp", "gmm2d", SamplerSpec::gddim(2), 10),
            PlanKey::new("cld", "imagenet", SamplerSpec::gddim(2), 10),
            // 2-D vector data on the image-space BDM: rejected at submit
            // time (the old path panicked inside the oracle's dimension
            // assert once the batch reached a dispatcher).
            PlanKey::new("bdm", "gmm2d", SamplerSpec::gddim(2), 10),
        ];
        for (id, key) in bad.into_iter().enumerate() {
            let rx = router.submit(GenRequest { id: id as u64, n: 8, key, seed: 0 });
            let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
            assert_eq!(resp.id, id as u64);
            assert!(resp.error.is_some(), "key {id} should be rejected");
            assert!(resp.xs.is_empty());
        }
        // The router is still healthy: a valid request round-trips.
        let rx = router.submit(GenRequest { id: 9, n: 8, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(resp.error.is_none());
        assert_eq!(resp.xs.len(), 8 * 2);
        router.shutdown();
    }

    #[test]
    fn zero_sample_requests_are_rejected_at_submit() {
        let router = Router::new(1, BatcherConfig::default(), oracle_factory());
        let rx = router.submit(GenRequest { id: 3, n: 0, key: key(), seed: 1 });
        let resp = rx.recv_timeout(Duration::from_secs(10)).unwrap();
        assert_eq!(resp.id, 3);
        assert_eq!(resp.error.as_deref(), Some("n must be >= 1"));
        assert!(resp.xs.is_empty());
        // The rejection never reached a dispatcher, so no counter moved
        // — a zero-row request must not skew fill/throughput stats.
        let report = router.metrics().report();
        assert_eq!(report.requests_done, 0);
        assert_eq!(report.samples_done, 0);
        // And the router still serves real traffic afterwards.
        let rx = router.submit(GenRequest { id: 4, n: 8, key: key(), seed: 1 });
        let ok = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(ok.error.is_none());
        assert_eq!(ok.xs.len(), 8 * 2);
        router.shutdown();
    }

    #[test]
    fn panicking_factory_leaves_router_serving_other_keys() {
        // A factory that panics on one dataset and delegates the rest to
        // the oracle factory — the "bad model load" failure mode a
        // custom factory can hit once real networks are behind it.
        let inner = oracle_factory();
        let factory: Box<PreparedFactory> = Box::new(move |key, pre| {
            if key.dataset == "hard2d" {
                panic!("factory blew up on `{}`", key.dataset);
            }
            inner(key, pre)
        });
        let router = Router::new(2, BatcherConfig::default(), factory);
        let bad = PlanKey::gddim("cld", "hard2d", 6, 1);
        let rx = router.submit(GenRequest { id: 1, n: 4, key: bad.clone(), seed: 0 });
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.error.as_deref(), Some("prepared factory panicked"));
        // The dispatcher survived and nothing is poisoned: an unrelated
        // key round-trips, and a retry of the panicking key is answered
        // again (not cached, not a hang, not a poisoned-lock panic).
        let rx = router.submit(GenRequest { id: 2, n: 8, key: key(), seed: 1 });
        let ok = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert!(ok.error.is_none(), "{:?}", ok.error);
        assert_eq!(ok.xs.len(), 8 * 2);
        let rx = router.submit(GenRequest { id: 3, n: 4, key: bad, seed: 0 });
        let resp = rx.recv_timeout(Duration::from_secs(30)).unwrap();
        assert_eq!(resp.error.as_deref(), Some("prepared factory panicked"));
        router.shutdown();
    }

    #[test]
    fn plan_cache_persists_to_disk_and_warms_next_router() {
        let dir = std::env::temp_dir().join(format!(
            "gddim-plan-cache-test-{}-{:?}",
            std::process::id(),
            std::thread::current().id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        let rcfg = || RouterConfig {
            dispatchers: 1,
            plan_cache_dir: Some(dir.clone()),
            ..RouterConfig::default()
        };
        let key = PlanKey::gddim("cld", "gmm2d", 8, 2);
        let first = Router::with_options(
            rcfg(),
            Engine::new(1),
            BatcherConfig::default(),
            oracle_factory(),
        );
        let rx = first.submit(GenRequest { id: 1, n: 16, key: key.clone(), seed: 3 });
        let a = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        first.shutdown();

        // The plan landed on disk and parses back against its key.
        let file = dir.join(key.cache_file_name());
        assert!(file.exists(), "plan file must be persisted at {}", file.display());
        let (pk, plan) = parse_plan_file(&std::fs::read_to_string(&file).unwrap()).unwrap();
        assert_eq!(pk, key);
        assert_eq!(plan.n_steps(), 8);

        // A fresh router warms its LRU from the directory before serving
        // anything — and the served bytes match the first router's.
        let second = Router::with_options(
            rcfg(),
            Engine::new(1),
            BatcherConfig::default(),
            oracle_factory(),
        );
        assert!(
            second.plan_cache_contains(&key),
            "warm start must preload the persisted plan"
        );
        let rx = second.submit(GenRequest { id: 1, n: 16, key: key.clone(), seed: 3 });
        let b = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(a.xs, b.xs, "a loaded plan must reproduce the built plan's bytes");
        second.shutdown();

        // Corrupt files are skipped, not fatal.
        std::fs::write(dir.join("garbage.json"), "{not json").unwrap();
        let third = Router::with_options(
            rcfg(),
            Engine::new(1),
            BatcherConfig::default(),
            oracle_factory(),
        );
        assert!(third.plan_cache_contains(&key));
        third.shutdown();
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn heterogeneous_keys_are_bit_identical_with_scheduler_on_and_off() {
        use crate::engine::EngineConfig;
        use crate::samplers::{OrderedF64, SamplerSpec};
        // One request per key: each batch holds exactly that request, so
        // the batch seed — and therefore the engine output — is
        // deterministic and comparable across router configurations.
        let keys: Vec<PlanKey> = vec![
            PlanKey::gddim("cld", "gmm2d", 6, 1),
            PlanKey::gddim("cld", "gmm2d", 6, 2),
            PlanKey::gddim("cld", "gmm2d", 6, 3),
            PlanKey::new("cld", "gmm2d", SamplerSpec::Em { lambda: OrderedF64::new(0.0) }, 6),
        ];
        let run = |score_batch: usize| -> Vec<Vec<f64>> {
            let router = Router::with_engine(
                2,
                Engine::with_config(EngineConfig {
                    workers: 4,
                    shard_size: 64,
                    score_batch,
                    score_wait: Duration::from_millis(20),
                    ..EngineConfig::default()
                }),
                BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(10) },
                oracle_factory(),
            );
            let rxs: Vec<_> = keys
                .iter()
                .enumerate()
                .map(|(id, key)| {
                    router.submit(GenRequest {
                        id: id as u64,
                        n: 24,
                        key: key.clone(),
                        seed: 7 + id as u64,
                    })
                })
                .collect();
            let outs: Vec<Vec<f64>> = rxs
                .into_iter()
                .map(|rx| {
                    let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
                    assert!(resp.error.is_none(), "{:?}", resp.error);
                    assert_eq!(resp.xs.len(), 24 * 2);
                    resp.xs
                })
                .collect();
            if score_batch > 0 {
                let e = router.report().engine.expect("engine stats ride the report");
                assert!(e.score_calls > 0, "scheduler-on traffic must flow through the pool");
                assert!(e.score_rows >= e.score_calls);
            }
            router.shutdown();
            outs
        };
        assert_eq!(run(0), run(4096), "grouped + pooled admission must not change any byte");
    }

    #[test]
    fn different_keys_do_not_mix() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let k1 = PlanKey::gddim("vpsde", "gmm2d", 10, 1);
        let k2 = PlanKey::gddim("cld", "gmm2d", 10, 2);
        let r1 = router.submit(GenRequest { id: 1, n: 8, key: k1, seed: 0 });
        let r2 = router.submit(GenRequest { id: 2, n: 8, key: k2, seed: 0 });
        let a = r1.recv_timeout(Duration::from_secs(60)).unwrap();
        let b = r2.recv_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(a.dim_x, 2);
        assert_eq!(b.dim_x, 2);
        assert_eq!(a.batch_size, 1);
        assert_eq!(b.batch_size, 1);
        router.shutdown();
    }
}
