//! Line-delimited JSON wire format for the TCP serving edge.
//!
//! One JSON object per `\n`-terminated line, both directions. A request
//! line carries the client-assigned `id`, the sample count `n`, the RNG
//! `seed`, and the four [`PlanKey`] fields inline — `spec` rides as the
//! round-trip-exact [`SamplerSpec`](crate::samplers::SamplerSpec) text
//! grammar, so a wire request parses straight into a [`GenRequest`]
//! without a lossy intermediate:
//!
//! ```json
//! {"dataset":"gmm2d","id":1,"n":16,"nfe":20,"process":"cld","seed":7,"spec":"gddim:q=2"}
//! ```
//!
//! The server answers each admitted request with a status line first and
//! a result line later (responses for different requests on one
//! connection may interleave; match on `id`):
//!
//! ```json
//! {"id":1,"status":"accepted"}
//! {"batch_size":1,"dim_x":2,"id":1,"latency":0.004,"nfe":20,"ok":true,...,"xs":[0.5,-1.5]}
//! ```
//!
//! Rejections and sheds are `{"error":...,"id":N,"ok":false}` lines; a
//! shed additionally carries `retry_after_ms`, the edge's `Retry-After`
//! hint. Floats round-trip bit-exactly through [`Json`]'s shortest
//! representation, which is what makes the loopback-TCP bit-identity
//! test against in-process [`Router::submit`](crate::server::Router)
//! meaningful.

use crate::server::request::{GenRequest, GenResponse, PlanKey};
use crate::util::json::Json;
use crate::Error;
use std::collections::BTreeMap;

/// A client→server request line.
#[derive(Clone, Debug, PartialEq)]
pub struct WireRequest {
    pub id: u64,
    pub n: usize,
    pub seed: u64,
    pub key: PlanKey,
}

fn field_u64(j: &Json, k: &str) -> crate::Result<u64> {
    let v = j.get(k).ok_or_else(|| Error::msg(format!("wire: missing `{k}`")))?;
    let x = v.as_f64().ok_or_else(|| Error::msg(format!("wire: `{k}` not a number")))?;
    if x < 0.0 || x.fract() != 0.0 {
        return Err(Error::msg(format!("wire: `{k}` not a non-negative integer")));
    }
    Ok(x as u64)
}

impl WireRequest {
    /// Parse one request line (trailing newline tolerated).
    pub fn parse_line(line: &str) -> crate::Result<WireRequest> {
        let j = Json::parse(line.trim_end()).map_err(|e| Error::msg(format!("wire: {e}")))?;
        let key = PlanKey::from_json(&j)?;
        Ok(WireRequest {
            id: field_u64(&j, "id")?,
            n: field_u64(&j, "n")? as usize,
            seed: field_u64(&j, "seed")?,
            key,
        })
    }

    /// Serialize as one `\n`-terminated line.
    pub fn to_line(&self) -> String {
        let mut obj = match self.key.to_json() {
            Json::Obj(o) => o,
            _ => unreachable!("PlanKey::to_json is an object"),
        };
        obj.insert("id".to_string(), Json::Num(self.id as f64));
        obj.insert("n".to_string(), Json::Num(self.n as f64));
        obj.insert("seed".to_string(), Json::Num(self.seed as f64));
        let mut line = Json::Obj(obj).to_string_compact();
        line.push('\n');
        line
    }

    /// The in-process request this wire request stands for.
    pub fn to_gen(&self) -> GenRequest {
        GenRequest { id: self.id, n: self.n, key: self.key.clone(), seed: self.seed }
    }
}

/// A server→client response line.
#[derive(Clone, Debug, PartialEq)]
pub enum WireResponse {
    /// Admission acknowledgement, streamed before the result.
    Status { id: u64, status: String },
    /// A completed request's samples + latency split.
    Result {
        id: u64,
        dim_x: usize,
        nfe: usize,
        latency: f64,
        queue_latency: f64,
        service_latency: f64,
        batch_size: usize,
        /// Row-major n × dim_x samples, bit-exact over the wire.
        xs: Vec<f64>,
    },
    /// Rejection or shed. `retry_after_ms` is set on load sheds — the
    /// edge's `Retry-After` hint, derived from its SLO target.
    Error { id: u64, error: String, retry_after_ms: Option<u64> },
}

impl WireResponse {
    /// The request id this line answers.
    pub fn id(&self) -> u64 {
        match self {
            WireResponse::Status { id, .. }
            | WireResponse::Result { id, .. }
            | WireResponse::Error { id, .. } => *id,
        }
    }

    /// Map a router response onto the wire: an error response becomes an
    /// `Error` line (no retry hint — structural rejections are not
    /// retryable), everything else a `Result` line.
    pub fn from_gen(r: &GenResponse) -> WireResponse {
        if let Some(error) = &r.error {
            return WireResponse::Error { id: r.id, error: error.clone(), retry_after_ms: None };
        }
        WireResponse::Result {
            id: r.id,
            dim_x: r.dim_x,
            nfe: r.nfe,
            latency: r.latency,
            queue_latency: r.queue_latency,
            service_latency: r.service_latency,
            batch_size: r.batch_size,
            xs: r.xs.clone(),
        }
    }

    /// The client-side view: rebuild the [`GenResponse`] a wire line
    /// stands for (status lines have no `GenResponse` equivalent).
    pub fn to_gen(&self) -> Option<GenResponse> {
        match self {
            WireResponse::Status { .. } => None,
            WireResponse::Result {
                id,
                dim_x,
                nfe,
                latency,
                queue_latency,
                service_latency,
                batch_size,
                xs,
            } => Some(GenResponse {
                id: *id,
                xs: xs.clone(),
                dim_x: *dim_x,
                nfe: *nfe,
                latency: *latency,
                queue_latency: *queue_latency,
                service_latency: *service_latency,
                batch_size: *batch_size,
                error: None,
            }),
            WireResponse::Error { id, error, .. } => {
                Some(GenResponse::rejected(*id, error.clone()))
            }
        }
    }

    /// Serialize as one `\n`-terminated line.
    pub fn to_line(&self) -> String {
        let mut obj = BTreeMap::new();
        obj.insert("id".to_string(), Json::Num(self.id() as f64));
        match self {
            WireResponse::Status { status, .. } => {
                obj.insert("status".to_string(), Json::Str(status.clone()));
            }
            WireResponse::Result {
                dim_x,
                nfe,
                latency,
                queue_latency,
                service_latency,
                batch_size,
                xs,
                ..
            } => {
                obj.insert("ok".to_string(), Json::Bool(true));
                obj.insert("dim_x".to_string(), Json::Num(*dim_x as f64));
                obj.insert("nfe".to_string(), Json::Num(*nfe as f64));
                obj.insert("latency".to_string(), Json::Num(*latency));
                obj.insert("queue_latency".to_string(), Json::Num(*queue_latency));
                obj.insert("service_latency".to_string(), Json::Num(*service_latency));
                obj.insert("batch_size".to_string(), Json::Num(*batch_size as f64));
                obj.insert("xs".to_string(), Json::Arr(xs.iter().map(|x| Json::Num(*x)).collect()));
            }
            WireResponse::Error { error, retry_after_ms, .. } => {
                obj.insert("ok".to_string(), Json::Bool(false));
                obj.insert("error".to_string(), Json::Str(error.clone()));
                if let Some(ms) = retry_after_ms {
                    obj.insert("retry_after_ms".to_string(), Json::Num(*ms as f64));
                }
            }
        }
        let mut line = Json::Obj(obj).to_string_compact();
        line.push('\n');
        line
    }

    /// Parse one response line (trailing newline tolerated).
    pub fn parse_line(line: &str) -> crate::Result<WireResponse> {
        let j = Json::parse(line.trim_end()).map_err(|e| Error::msg(format!("wire: {e}")))?;
        let id = field_u64(&j, "id")?;
        if let Some(status) = j.get("status") {
            let status = status.as_str().ok_or("wire: `status` not a string")?.to_string();
            return Ok(WireResponse::Status { id, status });
        }
        match j.get("ok") {
            Some(Json::Bool(true)) => {
                let xs = j
                    .get("xs")
                    .and_then(|v| v.as_f64_vec())
                    .ok_or("wire: result missing `xs`")?;
                Ok(WireResponse::Result {
                    id,
                    dim_x: field_u64(&j, "dim_x")? as usize,
                    nfe: field_u64(&j, "nfe")? as usize,
                    latency: j.get("latency").and_then(Json::as_f64).unwrap_or(0.0),
                    queue_latency: j.get("queue_latency").and_then(Json::as_f64).unwrap_or(0.0),
                    service_latency: j.get("service_latency").and_then(Json::as_f64).unwrap_or(0.0),
                    batch_size: field_u64(&j, "batch_size")? as usize,
                    xs,
                })
            }
            Some(Json::Bool(false)) => {
                let error =
                    j.get("error").and_then(Json::as_str).unwrap_or("unspecified").to_string();
                let retry_after_ms = match j.get("retry_after_ms") {
                    Some(v) => Some(
                        v.as_f64().ok_or("wire: `retry_after_ms` not a number")?.max(0.0) as u64,
                    ),
                    None => None,
                };
                Ok(WireResponse::Error { id, error, retry_after_ms })
            }
            _ => Err(Error::msg("wire: response has neither `status` nor boolean `ok`")),
        }
    }
}

/// Best-effort id recovery from a line that failed full parsing, so a
/// malformed request can still be answered with an `Error` line carrying
/// the id the client is waiting on (0 when even that is unrecoverable).
pub fn extract_id(line: &str) -> u64 {
    Json::parse(line.trim_end())
        .ok()
        .and_then(|j| j.get("id").and_then(Json::as_f64))
        .filter(|x| *x >= 0.0 && x.fract() == 0.0)
        .map(|x| x as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::samplers::{OrderedF64, SamplerSpec};

    #[test]
    fn request_line_round_trips_bit_exactly() {
        let reqs = [
            WireRequest { id: 1, n: 16, seed: 7, key: PlanKey::gddim("cld", "gmm2d", 20, 2) },
            WireRequest {
                id: u64::MAX >> 12,
                n: 1,
                seed: 0,
                key: PlanKey::new(
                    "vpsde",
                    "blobs8",
                    SamplerSpec::Em { lambda: OrderedF64::new(1e-4) },
                    50,
                ),
            },
        ];
        for req in reqs {
            let line = req.to_line();
            assert!(line.ends_with('\n') && !line[..line.len() - 1].contains('\n'));
            let back = WireRequest::parse_line(&line).unwrap();
            assert_eq!(back, req);
            let gen = back.to_gen();
            assert_eq!((gen.id, gen.n, gen.seed), (req.id, req.n, req.seed));
            assert_eq!(gen.key, req.key);
        }
    }

    #[test]
    fn result_line_round_trips_awkward_floats() {
        let resp = WireResponse::Result {
            id: 42,
            dim_x: 2,
            nfe: 20,
            latency: 0.1 + 0.2,
            queue_latency: 1e-17,
            service_latency: 0.30000000000000004,
            batch_size: 3,
            xs: vec![0.1 + 0.2, -0.0, 1.0 / 3.0, f64::MIN_POSITIVE, -1.5e300],
        };
        let back = WireResponse::parse_line(&resp.to_line()).unwrap();
        match (&resp, &back) {
            (WireResponse::Result { xs: a, .. }, WireResponse::Result { xs: b, .. }) => {
                let bits = |v: &[f64]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
                assert_eq!(bits(a), bits(b));
            }
            other => panic!("{other:?}"),
        }
        assert_eq!(back, resp);
        let gen = back.to_gen().unwrap();
        assert_eq!(gen.batch_size, 3);
        assert!(gen.error.is_none());
    }

    #[test]
    fn status_error_and_retry_hint_round_trip() {
        let status = WireResponse::Status { id: 9, status: "accepted".to_string() };
        assert_eq!(WireResponse::parse_line(&status.to_line()).unwrap(), status);

        let shed = WireResponse::Error {
            id: 9,
            error: "shed: queue depth over watermark".to_string(),
            retry_after_ms: Some(125),
        };
        let back = WireResponse::parse_line(&shed.to_line()).unwrap();
        assert_eq!(back, shed);
        let gen = back.to_gen().unwrap();
        assert_eq!(gen.error.as_deref(), Some("shed: queue depth over watermark"));

        let reject =
            WireResponse::Error { id: 3, error: "nfe must be >= 1".into(), retry_after_ms: None };
        assert!(!reject.to_line().contains("retry_after_ms"));
        assert_eq!(WireResponse::parse_line(&reject.to_line()).unwrap(), reject);
    }

    #[test]
    fn from_gen_maps_errors_and_results() {
        let ok = GenResponse {
            id: 5,
            xs: vec![1.0, 2.0],
            dim_x: 2,
            nfe: 6,
            latency: 0.01,
            queue_latency: 0.002,
            service_latency: 0.008,
            batch_size: 1,
            error: None,
        };
        assert!(matches!(WireResponse::from_gen(&ok), WireResponse::Result { id: 5, .. }));
        let bad = GenResponse::rejected(6, "unknown process `ddim`".into());
        match WireResponse::from_gen(&bad) {
            WireResponse::Error { id: 6, retry_after_ms: None, .. } => {}
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn malformed_lines_are_rejected_not_panicked() {
        for line in [
            "",
            "not json",
            "{",
            "[1,2,3]",
            r#"{"id":"x","n":1,"seed":0}"#,
            r#"{"id":1,"n":1}"#,
            r#"{"id":1,"n":-2,"seed":0,"process":"cld","dataset":"gmm2d","spec":"sscs","nfe":5}"#,
            r#"{"id":1,"n":1,"seed":0,"process":"cld","dataset":"gmm2d","spec":"warp:9","nfe":5}"#,
        ] {
            assert!(WireRequest::parse_line(line).is_err(), "{line:?}");
        }
        assert!(WireResponse::parse_line(r#"{"id":1}"#).is_err());
        assert!(WireResponse::parse_line("zzz").is_err());
    }

    #[test]
    fn extract_id_recovers_what_it_can() {
        assert_eq!(extract_id(r#"{"id":77,"n":"oops"}"#), 77);
        assert_eq!(extract_id("garbage"), 0);
        assert_eq!(extract_id(r#"{"id":-4}"#), 0);
    }
}
