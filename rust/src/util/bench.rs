//! Benchmark timing harness (offline build: no criterion).
//!
//! `time_it` runs warmup + measured iterations and reports a
//! [`crate::math::stats::Summary`] of per-iteration wall time; the
//! table/figure benches use [`Table`] to print paper-shaped rows into
//! both stdout and (optionally) a results file under `bench_results/`.

use std::time::Instant;

use crate::math::stats::Summary;

/// Time `f` for `iters` measured iterations after `warmup` unmeasured
/// ones; returns per-iteration seconds.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Summary {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from(&samples)
}

/// Adaptive variant: keeps iterating until `min_time` seconds of samples
/// or `max_iters` reached (criterion-ish behaviour for microbenches).
pub fn time_until<F: FnMut()>(min_time: f64, max_iters: usize, mut f: F) -> Summary {
    // Warmup.
    f();
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed().as_secs_f64() < min_time && samples.len() < max_iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Summary::from(&samples)
}

/// A printable results table with paper-style layout.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.header.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, w) in cells.iter().zip(widths) {
                line.push_str(&format!("{c:>w$} | ", w = w));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &widths));
        let total: usize = widths.iter().map(|w| w + 3).sum::<usize>() + 1;
        out.push_str(&"-".repeat(total));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Print to stdout and append to `bench_results/<name>.txt`.
    pub fn emit(&self, name: &str) {
        let text = self.render();
        println!("{text}");
        let _ = std::fs::create_dir_all("bench_results");
        let _ = std::fs::write(format!("bench_results/{name}.txt"), &text);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn time_it_counts_iters() {
        let mut n = 0;
        let s = time_it(2, 5, || n += 1);
        assert_eq!(n, 7);
        assert_eq!(s.n, 5);
        assert!(s.mean >= 0.0);
    }

    #[test]
    fn table_renders_all_rows() {
        let mut t = Table::new("Tab X", &["K_t", "20", "50"]);
        t.row(vec!["L_t".into(), "368".into(), "3.31".into()]);
        t.row(vec!["R_t".into(), "3.90".into(), "2.26".into()]);
        let r = t.render();
        assert!(r.contains("Tab X"));
        assert!(r.contains("L_t"));
        assert!(r.contains("2.26"));
        assert_eq!(r.matches('\n').count() >= 5, true);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(vec!["1".into()]);
    }
}
