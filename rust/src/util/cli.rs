//! Tiny CLI argument parser for the `gddim` binary and the benchmark
//! harnesses (offline build: no clap). Supports `--key value`,
//! `--key=value`, boolean `--flag`, and positional arguments.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub flags: BTreeMap<String, String>,
}

impl Args {
    pub fn parse(argv: impl Iterator<Item = String>) -> Args {
        let mut out = Args::default();
        let mut argv = argv.peekable();
        while let Some(a) = argv.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if argv.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = argv.next().unwrap();
                    out.flags.insert(rest.to_string(), v);
                } else {
                    out.flags.insert(rest.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn mixed_forms() {
        let a = parse(&["table1", "--nfe", "50", "--kt=R", "--verbose", "--seed", "7"]);
        assert_eq!(a.positional, vec!["table1"]);
        assert_eq!(a.get_usize("nfe", 0), 50);
        assert_eq!(a.get("kt"), Some("R"));
        assert!(a.has("verbose"));
        assert_eq!(a.get_u64("seed", 0), 7);
    }

    #[test]
    fn defaults() {
        let a = parse(&[]);
        assert_eq!(a.get_or("x", "d"), "d");
        assert_eq!(a.get_f64("lam", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag_is_boolean() {
        let a = parse(&["--fast"]);
        assert_eq!(a.get("fast"), Some("true"));
    }
}
