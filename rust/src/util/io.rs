//! Size-capped file reads for artifact ingestion.
//!
//! Every artifact the serving path reads from disk (manifest JSON,
//! `.gdw` weight blobs, HLO text) goes through [`read_capped`] /
//! [`read_string_capped`] so a corrupt or hostile file cannot balloon
//! into an unbounded allocation — the same "no unbounded reads" policy
//! `gddim lint`'s `bounded-io` rule enforces on the network edge, and
//! the reason that rule also watches `score/` and `runtime/` for naked
//! `fs::read*` calls (this module is the sanctioned replacement).
//!
//! The cap is checked against the file's metadata length *before* the
//! allocation, then enforced again on the actual byte count via
//! [`std::io::Read::take`] (metadata can lie on special files).

use std::io::Read;
use std::path::Path;

use crate::{Error, Result};

/// Read at most `cap` bytes from `path`; error (naming the path and the
/// cap) if the file is larger, missing, or unreadable.
pub fn read_capped(path: &Path, cap: u64) -> Result<Vec<u8>> {
    let meta = std::fs::metadata(path)
        .map_err(|e| Error::msg(format!("stat {}: {e}", path.display())))?;
    if meta.len() > cap {
        return Err(Error::msg(format!(
            "{} is {} bytes, over the {cap}-byte cap",
            path.display(),
            meta.len()
        )));
    }
    let f = std::fs::File::open(path)
        .map_err(|e| Error::msg(format!("open {}: {e}", path.display())))?;
    let mut buf = Vec::with_capacity(meta.len() as usize);
    // gddim-lint: allow(bounded-io) — the read is capped by `take` right here.
    f.take(cap + 1).read_to_end(&mut buf).map_err(|e| {
        Error::msg(format!("read {}: {e}", path.display()))
    })?;
    if buf.len() as u64 > cap {
        return Err(Error::msg(format!("{} grew past the {cap}-byte cap", path.display())));
    }
    Ok(buf)
}

/// [`read_capped`], then UTF-8 decode.
pub fn read_string_capped(path: &Path, cap: u64) -> Result<String> {
    String::from_utf8(read_capped(path, cap)?)
        .map_err(|e| Error::msg(format!("{}: not UTF-8: {e}", path.display())))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str, bytes: &[u8]) -> std::path::PathBuf {
        let p = std::env::temp_dir().join(format!("gddim_io_{name}_{}", std::process::id()));
        std::fs::write(&p, bytes).unwrap();
        p
    }

    #[test]
    fn reads_within_cap() {
        let p = tmp("ok", b"hello");
        assert_eq!(read_capped(&p, 16).unwrap(), b"hello");
        assert_eq!(read_string_capped(&p, 5).unwrap(), "hello");
        std::fs::remove_file(&p).unwrap();
    }

    #[test]
    fn rejects_over_cap_and_missing() {
        let p = tmp("big", &[0u8; 64]);
        let err = read_capped(&p, 63).unwrap_err().to_string();
        assert!(err.contains("64 bytes") && err.contains("63-byte cap"), "{err}");
        std::fs::remove_file(&p).unwrap();
        assert!(read_capped(Path::new("/nonexistent/gddim"), 8).is_err());
    }

    #[test]
    fn rejects_non_utf8() {
        let p = tmp("bin", &[0xff, 0xfe, 0x00]);
        assert!(read_string_capped(&p, 16).unwrap_err().to_string().contains("not UTF-8"));
        std::fs::remove_file(&p).unwrap();
    }
}
