//! Minimal JSON parser + writer.
//!
//! Used for `artifacts/manifest.json` (written by `python/compile/aot.py`)
//! and `configs/datasets.json` (shared ground truth between the python
//! training layer and the rust data module). Implemented in-repo because
//! the offline build has no serde. Supports the full JSON grammar except
//! `\u` surrogate pairs beyond the BMP.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, String> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access that threads Option.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Array of numbers convenience.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    /// Single-line form (no newlines, no padding) — the wire format for
    /// `server::wire` is one JSON value per line, so the writer must
    /// never emit a `\n` of its own.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |n: usize| "  ".repeat(n);
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // Integral values print as integers — except -0.0, whose
                // sign bit `as i64` would drop (the plan persistence
                // format relies on bit-exact float round trips; Display
                // prints "-0", which parses back to -0.0).
                if x.fract() == 0.0 && x.abs() < 1e15 && !(*x == 0.0 && x.is_sign_negative()) {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(out, "\\u{:04x}", c as u32);
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                if a.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    v.write(out, indent + 1, pretty);
                    if i + 1 < a.len() {
                        out.push(',');
                    }
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push(']');
            }
            Json::Obj(o) => {
                if o.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if pretty {
                        out.push('\n');
                        out.push_str(&pad(indent + 1));
                    }
                    Json::Str(k.clone()).write(out, indent + 1, pretty);
                    out.push_str(if pretty { ": " } else { ":" });
                    v.write(out, indent + 1, pretty);
                    if i + 1 < o.len() {
                        out.push(',');
                    }
                }
                if pretty {
                    out.push('\n');
                    out.push_str(&pad(indent));
                }
                out.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        let digitish = |c: u8| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-');
        while matches!(self.peek(), Some(c) if digitish(c)) {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("truncated \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => return Err(format!("bad escape {other:?}")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let rest = std::str::from_utf8(&self.b[self.i..]).map_err(|_| "bad utf8")?;
                    let c = rest.chars().next().ok_or("empty utf8 tail")?;
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.eat(b'{')?;
        let mut o = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(o));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let v = self.value()?;
            o.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(o));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(" true ").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.get("c"), Some(&Json::Null));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[0].as_f64(), Some(1.0));
        assert_eq!(arr[2].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn roundtrip_through_writer() {
        let src = r#"{"name":"cld_gmm2d","dims":[2,4],"eps":1e-3,"ok":true,"nested":{"xs":[0.5,-1.5]}}"#;
        let j = Json::parse(src).unwrap();
        let j2 = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, j2);
    }

    #[test]
    fn compact_form_is_one_line_and_round_trips() {
        let src = r#"{"id":7,"nested":{"xs":[0.5,-1.5,3.25]},"spec":"gddim:q=2"}"#;
        let j = Json::parse(src).unwrap();
        let line = j.to_string_compact();
        assert!(!line.contains('\n'));
        assert_eq!(line, src);
        assert_eq!(Json::parse(&line).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn negative_zero_keeps_its_sign_bit() {
        let text = Json::Num(-0.0).to_string_pretty();
        assert_eq!(text, "-0");
        match Json::parse(&text).unwrap() {
            Json::Num(x) => assert_eq!(x.to_bits(), (-0.0f64).to_bits()),
            other => panic!("{other:?}"),
        }
        // Plain zero still prints as an integer.
        assert_eq!(Json::Num(0.0).to_string_pretty(), "0");
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }
}
