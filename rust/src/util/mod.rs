//! Non-numerical utilities: JSON (for the artifact manifest and dataset
//! configs shared with the python layer), a tiny CLI argument parser,
//! the benchmark timing harness (the offline build has no criterion),
//! size-capped artifact reads, and the poison-proof lock/condvar helpers
//! shared by every concurrent layer (engine, scheduler, runtime, server).

pub mod json;
pub mod cli;
pub mod bench;
pub mod io;
pub mod sync;

pub use sync::{
    lock_unpoisoned, read_unpoisoned, wait_timeout_unpoisoned, wait_unpoisoned, write_unpoisoned,
};
