//! Non-numerical utilities: JSON (for the artifact manifest and dataset
//! configs shared with the python layer), a tiny CLI argument parser, and
//! the benchmark timing harness (the offline build has no criterion).

pub mod json;
pub mod cli;
pub mod bench;
