//! Poison-proof synchronization primitives for the serving stack.
//!
//! A panic in one thread (a custom `PreparedFactory`, a score model, a
//! sampler shard) poisons any mutex whose guard it held, and the default
//! `.lock().unwrap()` then panics every *later* caller too — one bad
//! request would take the whole engine pool or serving edge down. All
//! shared state in this crate is simple data (queues, counters, caches,
//! result slots) that stays structurally valid at every lock region, so
//! the crate-wide recovery policy is: take the guard back with
//! [`PoisonError::into_inner`](std::sync::PoisonError) and keep going.
//!
//! These helpers are the *only* sanctioned way to acquire a lock or wait
//! on a condvar in this crate; the `no-raw-lock-unwrap` rule of
//! `gddim lint` (see [`crate::analysis`]) enforces it. Originally these
//! lived in `server/` (PR 7 poison-proofed the edge); they are promoted
//! here so the engine, scheduler, and runtime share one policy, and
//! `server::lock_unpoisoned` remains as a re-export for compatibility.

use std::sync::{Condvar, Mutex, MutexGuard, RwLock, RwLockReadGuard, RwLockWriteGuard};
use std::time::Duration;

/// Poison-proof [`Mutex::lock`].
pub fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Poison-proof [`RwLock::read`].
pub fn read_unpoisoned<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(|e| e.into_inner())
}

/// Poison-proof [`RwLock::write`].
pub fn write_unpoisoned<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(|e| e.into_inner())
}

/// Poison-proof [`Condvar::wait`]: a panic in another holder of the
/// mutex must wake this waiter normally, not convert into a second
/// panic here.
pub fn wait_unpoisoned<'a, T>(cv: &Condvar, guard: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(guard).unwrap_or_else(|e| e.into_inner())
}

/// Poison-proof [`Condvar::wait_timeout`]. The timeout flag is dropped:
/// every caller in this crate re-checks its predicate and its own
/// deadline after waking, which is the only robust pattern anyway
/// (spurious wakeups make the flag advisory at best).
pub fn wait_timeout_unpoisoned<'a, T>(
    cv: &Condvar,
    guard: MutexGuard<'a, T>,
    dur: Duration,
) -> MutexGuard<'a, T> {
    match cv.wait_timeout(guard, dur) {
        Ok((g, _timeout)) => g,
        Err(e) => e.into_inner().0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex, RwLock};
    use std::time::Instant;

    /// Deliberately poison `m` by panicking while holding its guard.
    fn poison<T: Send + 'static>(m: &Arc<Mutex<T>>) {
        let m2 = Arc::clone(m);
        let _ = std::thread::spawn(move || {
            let _g = m2.lock().unwrap_or_else(|e| e.into_inner());
            panic!("poison the mutex");
        })
        .join();
    }

    #[test]
    fn lock_unpoisoned_recovers_a_poisoned_mutex() {
        let m = Arc::new(Mutex::new(41));
        poison(&m);
        assert!(m.lock().is_err(), "the mutex really is poisoned");
        let mut g = lock_unpoisoned(&m);
        *g += 1;
        assert_eq!(*g, 42, "the data survives the panic untouched");
    }

    #[test]
    fn rwlock_helpers_recover_a_poisoned_rwlock() {
        let l = Arc::new(RwLock::new(7));
        let l2 = Arc::clone(&l);
        let _ = std::thread::spawn(move || {
            let _g = l2.write().unwrap_or_else(|e| e.into_inner());
            panic!("poison the rwlock");
        })
        .join();
        assert_eq!(*read_unpoisoned(&l), 7);
        *write_unpoisoned(&l) = 8;
        assert_eq!(*read_unpoisoned(&l), 8);
    }

    #[test]
    fn wait_unpoisoned_wakes_despite_a_poisoning_notifier() {
        // The notifier flips the flag, poisons the mutex by panicking
        // with the guard held, and the waiter must still come back with
        // the flag visible rather than panicking on the poison.
        let pair = Arc::new((Mutex::new(false), Condvar::new()));
        let pair2 = Arc::clone(&pair);
        let h = std::thread::spawn(move || {
            let (m, cv) = &*pair2;
            let mut g = lock_unpoisoned(m);
            while !*g {
                g = wait_unpoisoned(cv, g);
            }
            true
        });
        std::thread::sleep(Duration::from_millis(20));
        let pair3 = Arc::clone(&pair);
        let _ = std::thread::spawn(move || {
            let (m, cv) = &*pair3;
            let mut g = lock_unpoisoned(m);
            *g = true;
            cv.notify_all();
            panic!("poison while holding the flag mutex");
        })
        .join();
        assert!(h.join().expect("waiter must wake, not die on poison"));
    }

    #[test]
    fn wait_timeout_unpoisoned_still_times_out() {
        let pair = (Mutex::new(()), Condvar::new());
        let t0 = Instant::now();
        let g = lock_unpoisoned(&pair.0);
        let _g = wait_timeout_unpoisoned(&pair.1, g, Duration::from_millis(10));
        assert!(t0.elapsed() >= Duration::from_millis(5), "the timeout path must elapse");
    }
}
