//! Committed, diffable serving-bench results — the perf trajectory.
//!
//! `cargo bench --bench serving -- --json PATH` serializes its scenario
//! tables through [`BenchReport`] into a schema-versioned JSON file
//! (`BENCH_serving.json` at the repo root), committed once per PR so the
//! throughput/latency history lives in git next to the code that moved
//! it. `gddim benchdiff old.json new.json` re-reads two snapshots and
//! fails (exit 1) on a >10% throughput drop or >10% p99 inflation in any
//! scenario — CI runs it against the committed baseline on every PR.
//!
//! The schema is deliberately flat (one object per scenario, scalar
//! fields only) so any plotting script can consume it without knowing
//! the repo's internals; [`SCHEMA_VERSION`] gates readers against silent
//! drift.

use crate::engine::EngineStats;
use crate::util::bench::Table;
use crate::util::json::Json;
use crate::workload::OpenLoopReport;

/// Version of the on-disk layout. Bump on any field rename/removal;
/// additive optional fields do not require a bump.
pub const SCHEMA_VERSION: u64 = 1;

/// Default regression tolerance for [`diff`]: 10% throughput drop or
/// 10% p99 inflation fails.
pub const DEFAULT_TOL: f64 = 0.10;

/// One bench scenario's results. Latencies are seconds; throughput is
/// samples per second. `None` serializes as JSON `null` (closed-loop
/// scenarios have no queueing split; scheduler-off runs have no
/// coalescing counters).
#[derive(Clone, Debug, PartialEq)]
pub struct BenchScenario {
    pub name: String,
    pub samples_per_sec: Option<f64>,
    pub issued: u64,
    pub completed: u64,
    pub queue_p50: Option<f64>,
    pub queue_p95: Option<f64>,
    pub queue_p99: Option<f64>,
    pub service_p50: Option<f64>,
    pub service_p95: Option<f64>,
    pub service_p99: Option<f64>,
    pub total_p50: Option<f64>,
    pub total_p95: Option<f64>,
    pub total_p99: Option<f64>,
    /// Realized score-batch fill (`score_rows / score_calls`).
    pub fill_rows_per_call: Option<f64>,
    pub coalesced_keys: Option<u64>,
    pub score_calls: Option<u64>,
}

impl BenchScenario {
    /// Empty scenario shell (every optional field `None`).
    pub fn named(name: &str) -> BenchScenario {
        BenchScenario {
            name: name.to_string(),
            samples_per_sec: None,
            issued: 0,
            completed: 0,
            queue_p50: None,
            queue_p95: None,
            queue_p99: None,
            service_p50: None,
            service_p95: None,
            service_p99: None,
            total_p50: None,
            total_p95: None,
            total_p99: None,
            fill_rows_per_call: None,
            coalesced_keys: None,
            score_calls: None,
        }
    }

    /// Condense an open-loop probe (+ optional engine counters) into a
    /// scenario row. Throughput is completed requests × samples each
    /// over the run's wall clock.
    pub fn from_probe(
        name: &str,
        report: &OpenLoopReport,
        samples_per_request: usize,
        engine: Option<&EngineStats>,
    ) -> BenchScenario {
        let mut s = BenchScenario::named(name);
        s.issued = report.issued as u64;
        s.completed = report.completed as u64;
        if report.elapsed > 0.0 {
            s.samples_per_sec =
                Some(report.completed as f64 * samples_per_request as f64 / report.elapsed);
        }
        if let Some(q) = &report.queueing {
            s.queue_p50 = Some(q.p50);
            s.queue_p95 = Some(q.p95);
            s.queue_p99 = Some(q.p99);
        }
        if let Some(sv) = &report.service {
            s.service_p50 = Some(sv.p50);
            s.service_p95 = Some(sv.p95);
            s.service_p99 = Some(sv.p99);
        }
        if let Some(t) = &report.total {
            s.total_p50 = Some(t.p50);
            s.total_p95 = Some(t.p95);
            s.total_p99 = Some(t.p99);
        }
        if let Some(e) = engine {
            if e.score_calls > 0 {
                s.fill_rows_per_call = Some(e.score_rows as f64 / e.score_calls as f64);
            }
            s.score_calls = Some(e.score_calls);
            s.coalesced_keys = Some(e.coalesced_keys);
        }
        s
    }

    fn to_json(&self) -> Json {
        let opt = |v: Option<f64>| v.map(Json::Num).unwrap_or(Json::Null);
        let optu = |v: Option<u64>| v.map(|x| Json::Num(x as f64)).unwrap_or(Json::Null);
        let mut o = std::collections::BTreeMap::new();
        o.insert("name".into(), Json::Str(self.name.clone()));
        o.insert("samples_per_sec".into(), opt(self.samples_per_sec));
        o.insert("issued".into(), Json::Num(self.issued as f64));
        o.insert("completed".into(), Json::Num(self.completed as f64));
        o.insert("queue_p50".into(), opt(self.queue_p50));
        o.insert("queue_p95".into(), opt(self.queue_p95));
        o.insert("queue_p99".into(), opt(self.queue_p99));
        o.insert("service_p50".into(), opt(self.service_p50));
        o.insert("service_p95".into(), opt(self.service_p95));
        o.insert("service_p99".into(), opt(self.service_p99));
        o.insert("total_p50".into(), opt(self.total_p50));
        o.insert("total_p95".into(), opt(self.total_p95));
        o.insert("total_p99".into(), opt(self.total_p99));
        o.insert("fill_rows_per_call".into(), opt(self.fill_rows_per_call));
        o.insert("coalesced_keys".into(), optu(self.coalesced_keys));
        o.insert("score_calls".into(), optu(self.score_calls));
        Json::Obj(o)
    }

    fn from_json(j: &Json) -> Result<BenchScenario, String> {
        let name = j
            .get("name")
            .and_then(|v| v.as_str())
            .ok_or("scenario missing string field 'name'")?;
        let opt = |key: &str| -> Result<Option<f64>, String> {
            match j.get(key) {
                None | Some(Json::Null) => Ok(None),
                Some(Json::Num(x)) => Ok(Some(*x)),
                Some(other) => Err(format!("scenario '{name}': field '{key}' is {other:?}")),
            }
        };
        let req = |key: &str| -> Result<u64, String> {
            j.get(key)
                .and_then(|v| v.as_f64())
                .map(|x| x as u64)
                .ok_or_else(|| format!("scenario '{name}': missing numeric field '{key}'"))
        };
        let mut s = BenchScenario::named(name);
        s.issued = req("issued")?;
        s.completed = req("completed")?;
        s.samples_per_sec = opt("samples_per_sec")?;
        s.queue_p50 = opt("queue_p50")?;
        s.queue_p95 = opt("queue_p95")?;
        s.queue_p99 = opt("queue_p99")?;
        s.service_p50 = opt("service_p50")?;
        s.service_p95 = opt("service_p95")?;
        s.service_p99 = opt("service_p99")?;
        s.total_p50 = opt("total_p50")?;
        s.total_p95 = opt("total_p95")?;
        s.total_p99 = opt("total_p99")?;
        s.fill_rows_per_call = opt("fill_rows_per_call")?;
        s.coalesced_keys = opt("coalesced_keys")?.map(|x| x as u64);
        s.score_calls = opt("score_calls")?.map(|x| x as u64);
        Ok(s)
    }
}

/// A full serving-bench snapshot: what gets committed as
/// `BENCH_serving.json`.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub schema_version: u64,
    /// Bench binary that produced this ("serving").
    pub bench: String,
    /// True when produced under `GDDIM_BENCH_QUICK=1` (CI's perf-probe
    /// mode — smaller request counts, same scenario set).
    pub quick: bool,
    /// Where the numbers came from: "ci", "local", or "bootstrap" (a
    /// hand-seeded baseline predating the first CI emission).
    pub source: String,
    pub scenarios: Vec<BenchScenario>,
}

impl BenchReport {
    pub fn new(quick: bool, source: &str) -> BenchReport {
        BenchReport {
            schema_version: SCHEMA_VERSION,
            bench: "serving".to_string(),
            quick,
            source: source.to_string(),
            scenarios: Vec::new(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut o = std::collections::BTreeMap::new();
        o.insert("schema_version".into(), Json::Num(self.schema_version as f64));
        o.insert("bench".into(), Json::Str(self.bench.clone()));
        o.insert("quick".into(), Json::Bool(self.quick));
        o.insert("source".into(), Json::Str(self.source.clone()));
        o.insert(
            "scenarios".into(),
            Json::Arr(self.scenarios.iter().map(BenchScenario::to_json).collect()),
        );
        Json::Obj(o)
    }

    pub fn from_json(j: &Json) -> Result<BenchReport, String> {
        let version = j
            .get("schema_version")
            .and_then(|v| v.as_f64())
            .ok_or("missing numeric field 'schema_version'")? as u64;
        if version != SCHEMA_VERSION {
            return Err(format!(
                "schema_version {version} != supported {SCHEMA_VERSION} \
                 (regenerate with this binary or pin the matching one)"
            ));
        }
        let bench =
            j.get("bench").and_then(|v| v.as_str()).ok_or("missing string field 'bench'")?;
        let quick = match j.get("quick") {
            Some(Json::Bool(b)) => *b,
            _ => return Err("missing bool field 'quick'".into()),
        };
        let source =
            j.get("source").and_then(|v| v.as_str()).ok_or("missing string field 'source'")?;
        let scenarios = j
            .get("scenarios")
            .and_then(|v| v.as_arr())
            .ok_or("missing array field 'scenarios'")?
            .iter()
            .map(BenchScenario::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        let report = BenchReport {
            schema_version: version,
            bench: bench.to_string(),
            quick,
            source: source.to_string(),
            scenarios,
        };
        report.validate()?;
        Ok(report)
    }

    /// Structural checks beyond parse-ability: scenario names unique and
    /// nonempty, counts consistent, every number finite.
    pub fn validate(&self) -> Result<(), String> {
        if self.scenarios.is_empty() {
            return Err("report has no scenarios".into());
        }
        let mut seen = std::collections::BTreeSet::new();
        for s in &self.scenarios {
            if s.name.is_empty() {
                return Err("scenario with empty name".into());
            }
            if !seen.insert(&s.name) {
                return Err(format!("duplicate scenario name '{}'", s.name));
            }
            if s.completed > s.issued {
                return Err(format!(
                    "scenario '{}': completed {} > issued {}",
                    s.name, s.completed, s.issued
                ));
            }
            let fields = [
                ("samples_per_sec", s.samples_per_sec),
                ("queue_p50", s.queue_p50),
                ("queue_p95", s.queue_p95),
                ("queue_p99", s.queue_p99),
                ("service_p50", s.service_p50),
                ("service_p95", s.service_p95),
                ("service_p99", s.service_p99),
                ("total_p50", s.total_p50),
                ("total_p95", s.total_p95),
                ("total_p99", s.total_p99),
                ("fill_rows_per_call", s.fill_rows_per_call),
            ];
            for (label, v) in fields {
                if let Some(x) = v {
                    if !x.is_finite() || x < 0.0 {
                        return Err(format!("scenario '{}': {label} = {x}", s.name));
                    }
                }
            }
        }
        Ok(())
    }

    pub fn write(&self, path: &str) -> Result<(), String> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::create_dir_all(dir).map_err(|e| format!("mkdir {dir:?}: {e}"))?;
            }
        }
        let mut text = self.to_json().to_string_pretty();
        text.push('\n');
        std::fs::write(path, text).map_err(|e| format!("write {path}: {e}"))
    }

    pub fn read(path: &str) -> Result<BenchReport, String> {
        let text = std::fs::read_to_string(path).map_err(|e| format!("read {path}: {e}"))?;
        BenchReport::from_json(&Json::parse(&text).map_err(|e| format!("{path}: {e}"))?)
    }
}

/// Verdict for one scenario of a [`diff`].
#[derive(Clone, Debug, PartialEq)]
pub struct ScenarioDiff {
    pub name: String,
    pub old_throughput: Option<f64>,
    pub new_throughput: Option<f64>,
    pub old_p99: Option<f64>,
    pub new_p99: Option<f64>,
    /// Empty = within tolerance. Each entry is one violated gate.
    pub failures: Vec<String>,
    /// Scenario exists only in the new report (informational).
    pub new_only: bool,
}

/// Result of comparing two snapshots.
#[derive(Clone, Debug)]
pub struct BenchDiff {
    pub tol: f64,
    pub scenarios: Vec<ScenarioDiff>,
}

impl BenchDiff {
    pub fn passed(&self) -> bool {
        self.scenarios.iter().all(|s| s.failures.is_empty())
    }
}

impl std::fmt::Display for BenchDiff {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let pct = |v: Option<f64>, w: Option<f64>| -> String {
            match (v, w) {
                (Some(a), Some(b)) if a > 0.0 => format!("{:+.1}%", 100.0 * (b - a) / a),
                _ => "-".to_string(),
            }
        };
        let num = |v: Option<f64>| v.map(|x| format!("{x:.4}")).unwrap_or_else(|| "-".into());
        let mut t = Table::new(
            &format!("benchdiff (tol {:.0}%)", self.tol * 100.0),
            &["scenario", "thpt old", "thpt new", "Δ", "p99 old", "p99 new", "Δ", "verdict"],
        );
        for s in &self.scenarios {
            let verdict = if s.new_only {
                "new".to_string()
            } else if s.failures.is_empty() {
                "ok".to_string()
            } else {
                s.failures.join("; ")
            };
            t.row(vec![
                s.name.clone(),
                num(s.old_throughput),
                num(s.new_throughput),
                pct(s.old_throughput, s.new_throughput),
                num(s.old_p99),
                num(s.new_p99),
                pct(s.old_p99, s.new_p99),
                verdict,
            ]);
        }
        write!(f, "{}", t.render())
    }
}

/// Compare two snapshots scenario-by-scenario. Fails a scenario when
/// throughput drops more than `tol` below old, when total p99 inflates
/// more than `tol` above old, or when an old scenario disappeared.
/// Scenarios present only in `new` are reported but never fail — adding
/// coverage must not require touching the baseline first.
pub fn diff(old: &BenchReport, new: &BenchReport, tol: f64) -> BenchDiff {
    let find = |r: &BenchReport, name: &str| -> Option<BenchScenario> {
        r.scenarios.iter().find(|s| s.name == name).cloned()
    };
    let mut out = Vec::new();
    for o in &old.scenarios {
        let mut d = ScenarioDiff {
            name: o.name.clone(),
            old_throughput: o.samples_per_sec,
            new_throughput: None,
            old_p99: o.total_p99,
            new_p99: None,
            failures: Vec::new(),
            new_only: false,
        };
        match find(new, &o.name) {
            None => d.failures.push("missing in new report".to_string()),
            Some(n) => {
                d.new_throughput = n.samples_per_sec;
                d.new_p99 = n.total_p99;
                if let (Some(a), Some(b)) = (o.samples_per_sec, n.samples_per_sec) {
                    if a > 0.0 && b < a * (1.0 - tol) {
                        d.failures.push(format!(
                            "throughput -{:.1}% (> {:.0}% tol)",
                            100.0 * (a - b) / a,
                            tol * 100.0
                        ));
                    }
                }
                if let (Some(a), Some(b)) = (o.total_p99, n.total_p99) {
                    if a > 0.0 && b > a * (1.0 + tol) {
                        d.failures.push(format!(
                            "p99 +{:.1}% (> {:.0}% tol)",
                            100.0 * (b - a) / a,
                            tol * 100.0
                        ));
                    }
                }
            }
        }
        out.push(d);
    }
    for n in &new.scenarios {
        if find(old, &n.name).is_none() {
            out.push(ScenarioDiff {
                name: n.name.clone(),
                old_throughput: None,
                new_throughput: n.samples_per_sec,
                old_p99: None,
                new_p99: n.total_p99,
                failures: Vec::new(),
                new_only: true,
            });
        }
    }
    BenchDiff { tol, scenarios: out }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn scenario(name: &str, thpt: f64, p99: f64) -> BenchScenario {
        let mut s = BenchScenario::named(name);
        s.issued = 40;
        s.completed = 40;
        s.samples_per_sec = Some(thpt);
        s.total_p50 = Some(p99 * 0.4);
        s.total_p95 = Some(p99 * 0.8);
        s.total_p99 = Some(p99);
        s.fill_rows_per_call = Some(12.5);
        s.coalesced_keys = Some(7);
        s.score_calls = Some(220);
        s
    }

    fn report(pairs: &[(&str, f64, f64)]) -> BenchReport {
        let mut r = BenchReport::new(true, "local");
        r.scenarios = pairs.iter().map(|(n, t, p)| scenario(n, *t, *p)).collect();
        r
    }

    #[test]
    fn json_roundtrip_preserves_every_field() {
        let mut r = report(&[("hetero4_sched_on", 812.5, 0.0123), ("dim_blobs16_bdm", 96.0, 0.2)]);
        // Exercise null fields too.
        r.scenarios[1].queue_p50 = None;
        r.scenarios[1].coalesced_keys = None;
        let back = BenchReport::from_json(&Json::parse(&r.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn wrong_schema_version_is_rejected() {
        let mut j = report(&[("a", 1.0, 1.0)]).to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("schema_version".into(), Json::Num(999.0));
        }
        let err = BenchReport::from_json(&j).unwrap_err();
        assert!(err.contains("schema_version"), "{err}");
    }

    #[test]
    fn validate_catches_structural_problems() {
        assert!(BenchReport::new(true, "local").validate().is_err(), "empty scenario list");
        let mut dup = report(&[("a", 1.0, 1.0), ("a", 2.0, 1.0)]);
        assert!(dup.validate().is_err(), "duplicate names");
        dup.scenarios[1].name = "b".into();
        dup.scenarios[1].samples_per_sec = Some(f64::NAN);
        assert!(dup.validate().is_err(), "non-finite number");
        let mut bad = report(&[("a", 1.0, 1.0)]);
        bad.scenarios[0].completed = bad.scenarios[0].issued + 1;
        assert!(bad.validate().is_err(), "completed > issued");
        assert!(report(&[("a", 1.0, 1.0)]).validate().is_ok());
    }

    #[test]
    fn diff_passes_within_tolerance() {
        let old = report(&[("a", 100.0, 0.100)]);
        let new = report(&[("a", 95.0, 0.105)]);
        let d = diff(&old, &new, DEFAULT_TOL);
        assert!(d.passed(), "{d}");
    }

    #[test]
    fn diff_fails_on_throughput_regression_and_p99_inflation() {
        let old = report(&[("a", 100.0, 0.100), ("b", 50.0, 0.050)]);
        let new = report(&[("a", 85.0, 0.100), ("b", 50.0, 0.060)]);
        let d = diff(&old, &new, DEFAULT_TOL);
        assert!(!d.passed());
        let a = d.scenarios.iter().find(|s| s.name == "a").unwrap();
        assert!(a.failures.iter().any(|f| f.contains("throughput")), "{a:?}");
        let b = d.scenarios.iter().find(|s| s.name == "b").unwrap();
        assert!(b.failures.iter().any(|f| f.contains("p99")), "{b:?}");
    }

    #[test]
    fn diff_fails_on_missing_scenario_but_not_new_ones() {
        let old = report(&[("a", 100.0, 0.1)]);
        let new = report(&[("b", 100.0, 0.1)]);
        let d = diff(&old, &new, DEFAULT_TOL);
        assert!(!d.passed());
        assert!(d.scenarios.iter().any(|s| s.name == "a" && !s.failures.is_empty()));
        assert!(d.scenarios.iter().any(|s| s.name == "b" && s.new_only && s.failures.is_empty()));
    }

    #[test]
    fn write_read_roundtrip_on_disk() {
        let dir = std::env::temp_dir().join("gddim_bench_report_test");
        let _ = std::fs::create_dir_all(&dir);
        let path = dir.join("BENCH_serving.json");
        let path = path.to_str().unwrap();
        let r = report(&[("hetero4_sched_off", 420.0, 0.033)]);
        r.write(path).unwrap();
        assert_eq!(BenchReport::read(path).unwrap(), r);
        let _ = std::fs::remove_file(path);
    }
}
