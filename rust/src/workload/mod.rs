//! Workload generation for the serving benches: Poisson arrivals over a
//! mix of plan keys, driven open- or closed-loop against a [`Router`].

use std::time::{Duration, Instant};

use crate::math::rng::Rng;
use crate::server::request::{GenRequest, GenResponse, PlanKey};
use crate::server::router::Router;

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    pub samples_per_request: usize,
    /// Poisson arrival rate (requests/second). `f64::INFINITY` = burst.
    pub rate_per_sec: f64,
    /// Keys are drawn round-robin.
    pub keys: Vec<PlanKey>,
    pub seed: u64,
}

/// Drives a workload and collects all responses (closed loop at the end:
/// every request is awaited, arrival times follow the Poisson clock).
pub struct ClosedLoop {
    pub spec: WorkloadSpec,
}

impl ClosedLoop {
    pub fn new(spec: WorkloadSpec) -> Self {
        ClosedLoop { spec }
    }

    pub fn drive(
        &self,
        router: &Router,
        make: impl Fn(u64, &PlanKey, usize, u64) -> GenRequest,
    ) -> Vec<GenResponse> {
        let mut rng = Rng::seed_from(self.spec.seed);
        let start = Instant::now();
        let mut next_arrival = 0.0f64;
        let mut rxs = Vec::with_capacity(self.spec.n_requests);
        for id in 0..self.spec.n_requests as u64 {
            if self.spec.rate_per_sec.is_finite() {
                next_arrival += rng.exponential(self.spec.rate_per_sec);
                let target = Duration::from_secs_f64(next_arrival);
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            let key = &self.spec.keys[id as usize % self.spec.keys.len()];
            let req = make(id, key, self.spec.samples_per_request, id);
            rxs.push(router.submit(req));
        }
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(300)).expect("response"))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::BatcherConfig;
    use crate::server::router::oracle_factory;

    #[test]
    fn burst_workload_completes() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let spec = WorkloadSpec {
            n_requests: 10,
            samples_per_request: 8,
            rate_per_sec: f64::INFINITY,
            keys: vec![PlanKey::gddim("vpsde", "gmm2d", 5, 1)],
            seed: 3,
        };
        let out = ClosedLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
            id,
            n,
            key: key.clone(),
            seed,
        });
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|r| r.xs.len() == 8 * 2));
        router.shutdown();
    }

    #[test]
    fn poisson_interarrivals_have_expected_mean() {
        let mut rng = Rng::seed_from(9);
        let n = 50_000;
        let rate = 40.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean={mean}");
    }
}
