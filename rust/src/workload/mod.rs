//! Workload generation for the serving benches: Poisson arrivals over a
//! mix of plan keys, driven open- or closed-loop against a [`Router`],
//! plus a direct [`Engine`] throughput driver for worker-scaling sweeps.

use std::time::{Duration, Instant};

use crate::engine::{Engine, Job};
use crate::math::rng::Rng;
use crate::server::request::{GenRequest, GenResponse, PlanKey};
use crate::server::router::Router;

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    pub samples_per_request: usize,
    /// Poisson arrival rate (requests/second). `f64::INFINITY` = burst.
    pub rate_per_sec: f64,
    /// Keys are drawn round-robin.
    pub keys: Vec<PlanKey>,
    pub seed: u64,
}

/// Drives a workload and collects all responses (closed loop at the end:
/// every request is awaited, arrival times follow the Poisson clock).
pub struct ClosedLoop {
    pub spec: WorkloadSpec,
}

impl ClosedLoop {
    pub fn new(spec: WorkloadSpec) -> Self {
        ClosedLoop { spec }
    }

    pub fn drive(
        &self,
        router: &Router,
        make: impl Fn(u64, &PlanKey, usize, u64) -> GenRequest,
    ) -> Vec<GenResponse> {
        let mut rng = Rng::seed_from(self.spec.seed);
        let start = Instant::now();
        let mut next_arrival = 0.0f64;
        let mut rxs = Vec::with_capacity(self.spec.n_requests);
        for id in 0..self.spec.n_requests as u64 {
            if self.spec.rate_per_sec.is_finite() {
                next_arrival += rng.exponential(self.spec.rate_per_sec);
                let target = Duration::from_secs_f64(next_arrival);
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            let key = &self.spec.keys[id as usize % self.spec.keys.len()];
            let req = make(id, key, self.spec.samples_per_request, id);
            rxs.push(router.submit(req));
        }
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(300)).expect("response"))
            .collect()
    }
}

/// Drive one engine job back-to-back `repeats` times and report steady
/// throughput in samples/second. The serving and micro benches use this
/// for the worker-scaling sweep (`--workers 1` vs `--workers N`).
pub fn engine_throughput(engine: &Engine, job: &Job<'_>, repeats: usize) -> f64 {
    assert!(repeats > 0);
    // One warmup run outside the clock (plan caches, allocator, pages).
    let _ = engine.run(job);
    let t0 = Instant::now();
    for _ in 0..repeats {
        let _ = engine.run(job);
    }
    (repeats * job.n) as f64 / t0.elapsed().as_secs_f64()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::BatcherConfig;
    use crate::server::router::oracle_factory;

    #[test]
    fn burst_workload_completes() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let spec = WorkloadSpec {
            n_requests: 10,
            samples_per_request: 8,
            rate_per_sec: f64::INFINITY,
            keys: vec![PlanKey::gddim("vpsde", "gmm2d", 5, 1)],
            seed: 3,
        };
        let out = ClosedLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
            id,
            n,
            key: key.clone(),
            seed,
        });
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|r| r.xs.len() == 8 * 2));
        router.shutdown();
    }

    #[test]
    fn engine_throughput_reports_positive_rate() {
        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        use crate::data::presets;
        use crate::diffusion::process::KtKind;
        use crate::diffusion::{Cld, Process, TimeGrid};
        use crate::engine::SamplerSpec;
        use crate::score::oracle::GmmOracle;
        use std::sync::Arc;
        let spec = presets::gmm2d();
        let proc = Arc::new(Cld::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::new(2);
        let job = Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: SamplerSpec::GddimDet(&plan),
            n: 128,
            seed: 1,
        };
        assert!(engine_throughput(&engine, &job, 2) > 0.0);
    }

    #[test]
    fn poisson_interarrivals_have_expected_mean() {
        let mut rng = Rng::seed_from(9);
        let n = 50_000;
        let rate = 40.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean={mean}");
    }
}
