//! Workload generation for the serving benches: Poisson arrivals over a
//! mix of plan keys, driven open- or closed-loop against a [`Router`],
//! plus a direct [`Engine`] throughput driver for worker-scaling sweeps.
//!
//! The two loop disciplines answer different questions:
//!
//! * [`ClosedLoop`] waits for every response before reporting — good for
//!   throughput, but under overload its effective arrival rate silently
//!   degrades to the service rate, which *hides* tail latency.
//! * [`OpenLoop`] injects requests on a schedule fixed before the run
//!   starts, regardless of completions — the standard SLO methodology
//!   (queueing delay is allowed to grow without bound, and the p99 shows
//!   it). [`max_rate_under_slo`] sweeps rates against a latency target.

pub mod bench_report;

use std::time::{Duration, Instant};

use crate::engine::{Engine, Job};
use crate::math::rng::Rng;
use crate::math::stats::Summary;
use crate::server::request::{GenRequest, GenResponse, PlanKey};
use crate::server::router::Router;

#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub n_requests: usize,
    pub samples_per_request: usize,
    /// Arrival rate (requests/second). `f64::INFINITY` = burst.
    pub rate_per_sec: f64,
    /// Keys are drawn round-robin.
    pub keys: Vec<PlanKey>,
    pub seed: u64,
}

/// Drives a workload and collects all responses (closed loop at the end:
/// every request is awaited, arrival times follow the Poisson clock).
pub struct ClosedLoop {
    pub spec: WorkloadSpec,
}

impl ClosedLoop {
    pub fn new(spec: WorkloadSpec) -> Self {
        ClosedLoop { spec }
    }

    pub fn drive(
        &self,
        router: &Router,
        make: impl Fn(u64, &PlanKey, usize, u64) -> GenRequest,
    ) -> Vec<GenResponse> {
        let mut rng = Rng::seed_from(self.spec.seed);
        let start = Instant::now();
        let mut next_arrival = 0.0f64;
        let mut rxs = Vec::with_capacity(self.spec.n_requests);
        for id in 0..self.spec.n_requests as u64 {
            if self.spec.rate_per_sec.is_finite() {
                next_arrival += rng.exponential(self.spec.rate_per_sec);
                let target = Duration::from_secs_f64(next_arrival);
                let elapsed = start.elapsed();
                if target > elapsed {
                    std::thread::sleep(target - elapsed);
                }
            }
            let key = &self.spec.keys[id as usize % self.spec.keys.len()];
            let req = make(id, key, self.spec.samples_per_request, id);
            rxs.push(router.submit(req));
        }
        rxs.into_iter()
            .map(|rx| rx.recv_timeout(Duration::from_secs(300)).expect("response"))
            .collect()
    }
}

/// Open-loop driver: the injection schedule is computed *before* the run
/// from `(rate, seed)` alone, and requests are submitted at those times
/// whether or not earlier ones have completed. Responses are collected
/// afterwards; per-request queueing and service latency come from the
/// router's own timestamps, so serial collection does not distort them.
///
/// Latencies are charged from the request's **scheduled** arrival time:
/// if the injecting thread itself falls behind the schedule, the lag is
/// added to that request's queueing latency rather than silently
/// excluded (the classic coordinated-omission error, which would let an
/// overloaded run report a flattering p99).
pub struct OpenLoop {
    pub spec: WorkloadSpec,
    /// `false` = evenly spaced arrivals at exactly `rate_per_sec`;
    /// `true` = Poisson arrivals with that mean rate (seeded, so the
    /// schedule is still deterministic).
    pub poisson: bool,
    /// Per-response collection timeout; a request unanswered within it is
    /// counted in [`OpenLoopRun::dropped`] rather than hanging the bench.
    pub timeout: Duration,
}

impl OpenLoop {
    pub fn new(spec: WorkloadSpec) -> OpenLoop {
        OpenLoop { spec, poisson: false, timeout: Duration::from_secs(300) }
    }

    pub fn poisson(spec: WorkloadSpec) -> OpenLoop {
        OpenLoop { poisson: true, ..OpenLoop::new(spec) }
    }

    /// The arrival schedule (seconds from run start), a pure function of
    /// the spec — this is what makes the workload replayable.
    pub fn schedule(&self) -> Vec<f64> {
        let n = self.spec.n_requests;
        if !self.spec.rate_per_sec.is_finite() {
            return vec![0.0; n]; // burst: everything at t=0
        }
        assert!(self.spec.rate_per_sec > 0.0, "open loop needs a positive rate");
        if self.poisson {
            let mut rng = Rng::seed_from(self.spec.seed);
            let mut t = 0.0f64;
            (0..n)
                .map(|_| {
                    t += rng.exponential(self.spec.rate_per_sec);
                    t
                })
                .collect()
        } else {
            (0..n).map(|i| i as f64 / self.spec.rate_per_sec).collect()
        }
    }

    pub fn drive(
        &self,
        router: &Router,
        make: impl Fn(u64, &PlanKey, usize, u64) -> GenRequest,
    ) -> OpenLoopRun {
        let schedule = self.schedule();
        let start = Instant::now();
        let mut rxs = Vec::with_capacity(schedule.len());
        let mut lags = Vec::with_capacity(schedule.len());
        for (i, &at) in schedule.iter().enumerate() {
            let target = Duration::from_secs_f64(at);
            let elapsed = start.elapsed();
            if target > elapsed {
                std::thread::sleep(target - elapsed);
            }
            // Injector lag: how far behind its own schedule the submit
            // happens. Charged to the request below.
            lags.push((start.elapsed().as_secs_f64() - at).max(0.0));
            let id = i as u64;
            let key = &self.spec.keys[i % self.spec.keys.len()];
            rxs.push(router.submit(make(id, key, self.spec.samples_per_request, id)));
        }
        let inject_elapsed = start.elapsed().as_secs_f64();
        let mut responses = Vec::with_capacity(rxs.len());
        let mut dropped = 0usize;
        for (i, rx) in rxs.into_iter().enumerate() {
            match rx.recv_timeout(self.timeout) {
                Ok(mut r) => {
                    // Coordinated-omission correction: the clock starts at
                    // the scheduled arrival, so a late submit inflates the
                    // request's queueing (and total) latency.
                    r.queue_latency += lags[i];
                    r.latency += lags[i];
                    responses.push(r);
                }
                Err(_) => dropped += 1,
            }
        }
        OpenLoopRun {
            offered_rate: self.spec.rate_per_sec,
            issued: schedule.len(),
            dropped,
            inject_elapsed,
            max_inject_lag: lags.iter().cloned().fold(0.0, f64::max),
            elapsed: start.elapsed().as_secs_f64(),
            responses,
        }
    }
}

/// Raw outcome of one open-loop run (responses kept for fine-grained
/// assertions; [`OpenLoopRun::report`] condenses them).
pub struct OpenLoopRun {
    pub offered_rate: f64,
    pub issued: usize,
    pub dropped: usize,
    /// Seconds the injection phase took (≈ last schedule entry unless the
    /// submitting thread itself fell behind).
    pub inject_elapsed: f64,
    /// Worst injector lag behind the schedule (already charged into the
    /// affected requests' queueing latency; surfaced for observability).
    pub max_inject_lag: f64,
    /// Seconds until the last response was collected (or timed out).
    pub elapsed: f64,
    pub responses: Vec<GenResponse>,
}

impl OpenLoopRun {
    pub fn report(&self) -> OpenLoopReport {
        let pull = |f: fn(&GenResponse) -> f64| -> Option<Summary> {
            if self.responses.is_empty() {
                None
            } else {
                Some(Summary::from(&self.responses.iter().map(f).collect::<Vec<f64>>()))
            }
        };
        OpenLoopReport {
            offered_rate: self.offered_rate,
            issued: self.issued,
            completed: self.responses.len(),
            dropped: self.dropped,
            max_inject_lag: self.max_inject_lag,
            achieved_rate: if self.elapsed > 0.0 {
                self.responses.len() as f64 / self.elapsed
            } else {
                0.0
            },
            elapsed: self.elapsed,
            queueing: pull(|r| r.queue_latency),
            service: pull(|r| r.service_latency),
            total: pull(|r| r.latency),
        }
    }
}

/// Condensed open-loop results: completion counts, achieved rate, and
/// p50/p95/p99 for queueing, service, and total latency.
#[derive(Clone, Debug)]
pub struct OpenLoopReport {
    pub offered_rate: f64,
    pub issued: usize,
    pub completed: usize,
    pub dropped: usize,
    /// Worst injector lag behind the schedule (already folded into the
    /// queueing/total summaries — see [`OpenLoop`] on coordinated
    /// omission).
    pub max_inject_lag: f64,
    pub achieved_rate: f64,
    pub elapsed: f64,
    pub queueing: Option<Summary>,
    pub service: Option<Summary>,
    pub total: Option<Summary>,
}

impl OpenLoopReport {
    /// SLO check used by [`max_rate_under_slo`]: every issued request
    /// completed and total-latency p99 is within `slo_secs`.
    pub fn meets_slo(&self, slo_secs: f64) -> bool {
        self.dropped == 0
            && self.completed == self.issued
            && self.total.as_ref().is_some_and(|t| t.p99 <= slo_secs)
    }
}

impl std::fmt::Display for OpenLoopReport {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let rate = if self.offered_rate.is_finite() {
            format!("{:.0} req/s", self.offered_rate)
        } else {
            "burst".to_string()
        };
        writeln!(
            f,
            "open-loop @ {rate}: issued={} completed={} dropped={} achieved={:.0} req/s \
             over {:.2}s (max inject lag {:.4}s)",
            self.issued,
            self.completed,
            self.dropped,
            self.achieved_rate,
            self.elapsed,
            self.max_inject_lag
        )?;
        if let (Some(q), Some(s), Some(t)) = (&self.queueing, &self.service, &self.total) {
            writeln!(f, "  queueing(s): p50={:.4} p95={:.4} p99={:.4}", q.p50, q.p95, q.p99)?;
            writeln!(f, "  service(s):  p50={:.4} p95={:.4} p99={:.4}", s.p50, s.p95, s.p99)?;
            write!(
                f,
                "  total(s):    p50={:.4} p95={:.4} p99={:.4} max={:.4}",
                t.p50, t.p95, t.p99, t.max
            )?;
        }
        Ok(())
    }
}

/// One rate point of an SLO sweep.
pub struct SloPoint {
    pub rate: f64,
    pub report: OpenLoopReport,
    pub meets_slo: bool,
}

/// Result of [`max_rate_under_slo`]: every probed point plus the highest
/// rate whose p99 stayed within the SLO.
pub struct SloSweep {
    pub slo_secs: f64,
    pub points: Vec<SloPoint>,
    pub max_rate: Option<f64>,
}

/// One self-contained open-loop probe: build a fresh oracle-backed
/// router, warm the plan cache for every key (Stage-I builds must not
/// land on the first arrivals — App. C.3), drive the run, tear the
/// router down. The per-rate harness shared by `gddim workload` and
/// `cargo bench --bench serving`; returns the open-loop report plus the
/// router's combined server+engine metrics. `ecfg` carries the full
/// engine configuration — in particular `score_batch`/`score_wait`,
/// which turn on the cross-key score scheduler (and with it grouped
/// multi-key admission in the router).
pub fn open_loop_probe(
    rcfg: crate::server::router::RouterConfig,
    ecfg: crate::engine::EngineConfig,
    bcfg: crate::server::batcher::BatcherConfig,
    spec: WorkloadSpec,
    poisson: bool,
) -> (OpenLoopReport, crate::server::metrics::MetricsReport) {
    open_loop_probe_with(rcfg, ecfg, bcfg, spec, poisson, crate::server::router::oracle_factory())
}

/// [`open_loop_probe`] with an explicit
/// [`PreparedFactory`](crate::server::router::PreparedFactory) — how the
/// learned-model benches and `gddim workload --models-dir` route traffic
/// to [`crate::score::ScoreNet`] backends instead of the oracle.
pub fn open_loop_probe_with(
    rcfg: crate::server::router::RouterConfig,
    ecfg: crate::engine::EngineConfig,
    bcfg: crate::server::batcher::BatcherConfig,
    spec: WorkloadSpec,
    poisson: bool,
    factory: Box<crate::server::router::PreparedFactory>,
) -> (OpenLoopReport, crate::server::metrics::MetricsReport) {
    let router = Router::with_options(rcfg, Engine::with_config(ecfg), bcfg, factory);
    for key in &spec.keys {
        let rx = router.submit(GenRequest { id: u64::MAX, n: 1, key: key.clone(), seed: 0 });
        let _ = rx.recv_timeout(Duration::from_secs(60));
    }
    let driver = if poisson { OpenLoop::poisson(spec) } else { OpenLoop::new(spec) };
    let run = driver.drive(&router, |id, key, n, seed| GenRequest {
        id,
        n,
        key: key.clone(),
        seed,
    });
    let report = run.report();
    let metrics = router.report();
    router.shutdown();
    (report, metrics)
}

/// Like [`open_loop_probe`], but over loopback TCP through a
/// [`NetServer`](crate::server::net::NetServer): same schedule and key
/// mix, with requests riding the line-delimited wire format round-robin
/// across `conns` client connections. Latency is *client-measured* —
/// from each request's scheduled arrival to the moment its result line
/// is read off the socket — so the report prices the full edge path
/// (framing, admission control, both socket hops), not just the router.
/// The server-reported service latency is kept and queueing is rebuilt
/// as `total − service`, so the split still adds up exactly.
pub fn open_loop_tcp_probe(
    rcfg: crate::server::router::RouterConfig,
    ecfg: crate::engine::EngineConfig,
    bcfg: crate::server::batcher::BatcherConfig,
    ncfg: crate::server::net::NetConfig,
    conns: usize,
    spec: WorkloadSpec,
    poisson: bool,
) -> (OpenLoopReport, crate::server::metrics::MetricsReport) {
    open_loop_tcp_probe_with(
        rcfg,
        ecfg,
        bcfg,
        ncfg,
        conns,
        spec,
        poisson,
        crate::server::router::oracle_factory(),
    )
}

/// [`open_loop_tcp_probe`] with an explicit
/// [`PreparedFactory`](crate::server::router::PreparedFactory) (see
/// [`open_loop_probe_with`]).
#[allow(clippy::too_many_arguments)]
pub fn open_loop_tcp_probe_with(
    rcfg: crate::server::router::RouterConfig,
    ecfg: crate::engine::EngineConfig,
    bcfg: crate::server::batcher::BatcherConfig,
    mut ncfg: crate::server::net::NetConfig,
    conns: usize,
    spec: WorkloadSpec,
    poisson: bool,
    factory: Box<crate::server::router::PreparedFactory>,
) -> (OpenLoopReport, crate::server::metrics::MetricsReport) {
    use crate::server::net::NetServer;
    use crate::server::wire::{WireRequest, WireResponse};
    use std::io::{BufRead, BufReader, Write as _};
    use std::net::TcpStream;

    let conns = conns.max(1);
    // The client connections are held for the whole run, so the pool
    // needs one thread per connection or the round-robin tail starves.
    ncfg.conn_threads = ncfg.conn_threads.max(conns);
    let router = Router::with_options(rcfg, Engine::with_config(ecfg), bcfg, factory);
    let server = NetServer::bind("127.0.0.1:0", ncfg, router).expect("bind loopback edge");
    let addr = server.local_addr();

    // Warm every key over the wire, mirroring the in-process probe.
    {
        let mut warm = TcpStream::connect(addr).expect("connect warm client");
        for (i, key) in spec.keys.iter().enumerate() {
            let line = WireRequest { id: i as u64, n: 1, seed: 0, key: key.clone() }.to_line();
            warm.write_all(line.as_bytes()).expect("warm write");
        }
        let mut done = 0usize;
        // gddim-lint: allow(bounded-io) — bench client reading its own loopback server's replies, not an untrusted peer
        let mut lines = BufReader::new(warm.try_clone().expect("clone warm client")).lines();
        while done < spec.keys.len() {
            let Some(Ok(line)) = lines.next() else { break };
            match WireResponse::parse_line(&line) {
                Ok(WireResponse::Status { .. }) | Err(_) => {}
                Ok(_) => done += 1,
            }
        }
    }

    let driver = if poisson { OpenLoop::poisson(spec) } else { OpenLoop::new(spec) };
    let schedule = driver.schedule();
    let n = schedule.len();
    let spec = &driver.spec;
    let start = Instant::now();
    let mut socks: Vec<TcpStream> = (0..conns)
        .map(|_| {
            let s = TcpStream::connect(addr).expect("connect client");
            let _ = s.set_nodelay(true);
            s
        })
        .collect();
    let readers: Vec<std::thread::JoinHandle<Vec<(f64, GenResponse)>>> = socks
        .iter()
        .enumerate()
        .map(|(c, s)| {
            let want = (0..n).filter(|i| i % conns == c).count();
            let rd = s.try_clone().expect("clone client");
            let _ = rd.set_read_timeout(Some(driver.timeout));
            std::thread::spawn(move || {
                let mut out = Vec::with_capacity(want);
                // gddim-lint: allow(bounded-io) — bench client reading its own loopback server's replies, not an untrusted peer
                let mut lines = BufReader::new(rd).lines();
                while out.len() < want {
                    let Some(Ok(line)) = lines.next() else { break };
                    match WireResponse::parse_line(&line) {
                        Ok(WireResponse::Status { .. }) | Err(_) => {}
                        Ok(resp) => {
                            let t = start.elapsed().as_secs_f64();
                            if let Some(gen) = resp.to_gen() {
                                out.push((t, gen));
                            }
                        }
                    }
                }
                out
            })
        })
        .collect();

    let mut max_lag = 0.0f64;
    for (i, &at) in schedule.iter().enumerate() {
        let target = Duration::from_secs_f64(at);
        let elapsed = start.elapsed();
        if target > elapsed {
            std::thread::sleep(target - elapsed);
        }
        max_lag = max_lag.max((start.elapsed().as_secs_f64() - at).max(0.0));
        let key = &spec.keys[i % spec.keys.len()];
        let wire = WireRequest {
            id: i as u64,
            n: spec.samples_per_request,
            seed: i as u64,
            key: key.clone(),
        };
        let _ = socks[i % conns].write_all(wire.to_line().as_bytes());
    }
    let inject_elapsed = start.elapsed().as_secs_f64();

    let mut responses = Vec::with_capacity(n);
    for h in readers {
        for (recv_t, mut r) in h.join().expect("reader thread") {
            // The client clock starts at the *scheduled* arrival, so
            // injector lag is inside recv_t and coordinated omission
            // stays corrected, exactly as in the in-process driver.
            let at = schedule.get(r.id as usize).copied().unwrap_or(0.0);
            let total = (recv_t - at).max(r.service_latency).max(0.0);
            r.queue_latency = total - r.service_latency;
            r.latency = total;
            responses.push(r);
        }
    }
    let run = OpenLoopRun {
        offered_rate: spec.rate_per_sec,
        issued: n,
        dropped: n - responses.len(),
        inject_elapsed,
        max_inject_lag: max_lag,
        elapsed: start.elapsed().as_secs_f64(),
        responses,
    };
    drop(socks);
    let report = run.report();
    let metrics = server.shutdown();
    (report, metrics)
}

/// Probe `rates` (each via `run_at`, typically [`open_loop_probe`]) and
/// report the maximum rate meeting `p99 ≤ slo_secs`.
pub fn max_rate_under_slo(
    rates: &[f64],
    slo_secs: f64,
    mut run_at: impl FnMut(f64) -> OpenLoopReport,
) -> SloSweep {
    let mut points = Vec::with_capacity(rates.len());
    let mut max_rate: Option<f64> = None;
    for &rate in rates {
        let report = run_at(rate);
        let meets_slo = report.meets_slo(slo_secs);
        if meets_slo {
            max_rate = Some(max_rate.map_or(rate, |m| m.max(rate)));
        }
        points.push(SloPoint { rate, report, meets_slo });
    }
    SloSweep { slo_secs, points, max_rate }
}

/// Drive one engine job back-to-back and report steady throughput in
/// samples/second. The *first* of the `repeats` runs is the warm-up
/// (pool spin-up, plan caches, allocator, pages) and is excluded from the
/// timed window, so cold-start cost cannot skew the rate; with
/// `repeats == 1` the single run is necessarily both. Exactly `repeats`
/// jobs are executed — there is no hidden extra run.
pub fn engine_throughput(engine: &Engine, job: &Job<'_>, repeats: usize) -> f64 {
    assert!(repeats > 0);
    let mut t0 = Instant::now();
    let mut timed = 0usize;
    for r in 0..repeats {
        let _ = engine.run(job);
        if r == 0 && repeats > 1 {
            t0 = Instant::now(); // warm-up done; open the timed window
        } else {
            timed += 1;
        }
    }
    (timed * job.n) as f64 / t0.elapsed().as_secs_f64().max(1e-12)
}

/// Build the key mix for the serving CLIs: one key per (process ×
/// sampler spec) on `dataset`, with specs parsed from a `+`-separated
/// `--samplers` list (`+` because the spec grammar itself uses commas).
/// Every known process is probed and keys a spec or dataset cannot
/// serve (SSCS off CLD, BDM on vector data) are filtered by validation
/// rather than hard-coded pairs — so an image dataset like `blobs16`
/// automatically serves on BDM while `gmm2d` stays vpsde/cld. An
/// *empty* result (every combination invalid) is an error the CLI
/// reports cleanly.
pub fn cli_key_mix(samplers: &str, dataset: &str, nfe: usize) -> crate::Result<Vec<PlanKey>> {
    let mut keys = Vec::new();
    for token in samplers.split('+') {
        let token = token.trim();
        if token.is_empty() {
            continue;
        }
        let spec = match crate::samplers::SamplerSpec::parse(token) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("skipping sampler `{token}`: {e}");
                continue;
            }
        };
        for process in ["vpsde", "cld", "bdm"] {
            let key = PlanKey::new(process, dataset, spec.clone(), nfe);
            if key.validate().is_ok() {
                keys.push(key);
            }
        }
    }
    if keys.is_empty() {
        return Err(crate::Error::msg(format!(
            "no valid (process, sampler) combinations in `{samplers}`"
        )));
    }
    Ok(keys)
}

/// `gddim workload` — open-loop SLO characterization from the CLI: sweep
/// injection rates against a fresh router each, print per-rate latency
/// percentiles and the max rate meeting the SLO. With `--tcp` the probe
/// runs over loopback TCP through the `server::net` edge (`--conns`
/// client connections), so the SLO prices the full network path.
pub fn run_cli(args: &crate::util::cli::Args) {
    let tcp = args.has("tcp");
    let conns = args.get_usize("conns", 4);
    let workers = args.get_usize("workers", 4);
    let dispatchers = args.get_usize("dispatchers", 2);
    let n_requests = args.get_usize("requests", 64);
    let samples = args.get_usize("samples", 64);
    let nfe = args.get_usize("nfe", 20);
    let slo_ms = args.get_f64("slo-ms", 50.0);
    let seed = args.get_u64("seed", 0);
    let poisson = args.has("poisson");
    let samplers = args.get_or("samplers", "gddim:q=2");
    let dataset = args.get_or("dataset", "gmm2d");
    // `--models-dir DIR`: route manifest-matching keys to the learned
    // ScoreNet backend (validated once up front; per-rate probes each
    // build their own factory over the same directory).
    let models_dir = args.get("models-dir").map(std::path::PathBuf::from);
    if let Some(dir) = &models_dir {
        if let Err(e) = crate::server::router::factory_for(Some(dir)) {
            eprintln!("error: --models-dir: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a bad artifacts directory exits with status 2 before any router exists
            std::process::exit(2);
        }
    }
    let shard_bytes = args.get_usize("shard-size", EngineConfig::default().shard_bytes);
    // Cross-key score batching (the engine's scheduler): on by default
    // for the serving CLIs — `--score-batch 0` turns it off.
    let score_batch = args.get_usize("score-batch", 4096);
    let score_wait = Duration::from_micros(args.get_u64("score-wait", 200));
    let rates: Vec<f64> = match args.get("rates") {
        Some(list) => list
            .split(',')
            .map(|s| s.trim().parse().expect("bad --rates entry"))
            .collect(),
        None => vec![args.get_f64("rate", 200.0)],
    };

    use crate::engine::EngineConfig;
    use crate::server::batcher::BatcherConfig;
    use crate::server::router::RouterConfig;

    println!(
        "open-loop workload: {} requests × {} samples on {}, NFE {}, {} workers, \
         {} dispatchers, samplers [{}], SLO p99 ≤ {:.0}ms, arrivals {}, score-batch {}",
        n_requests,
        samples,
        dataset,
        nfe,
        workers,
        dispatchers,
        samplers,
        slo_ms,
        if poisson { "poisson" } else { "uniform" },
        if score_batch > 0 { score_batch.to_string() } else { "off".to_string() },
    );
    if tcp {
        println!("mode: loopback TCP edge ({conns} client connections)");
    }
    let keys = match cli_key_mix(&samplers, &dataset, nfe) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("error: {e}");
            // gddim-lint: allow(no-process-exit) — CLI entry point: a bad sampler spec exits with status 2 before any router exists
            std::process::exit(2);
        }
    };
    let sweep = max_rate_under_slo(&rates, slo_ms / 1e3, |rate| {
        let rcfg = RouterConfig {
            dispatchers,
            plan_cache_capacity: args.get_usize("plan-cache", 64),
            plan_cache_dir: args.get("plan-cache-dir").map(std::path::PathBuf::from),
        };
        let ecfg = EngineConfig {
            workers,
            shard_bytes,
            score_batch,
            score_wait,
            ..EngineConfig::default()
        };
        let bcfg = BatcherConfig {
            max_batch: args.get_usize("max-batch", 4096),
            max_wait: Duration::from_millis(args.get_u64("max-wait-ms", 5)),
        };
        let wspec = WorkloadSpec {
            n_requests,
            samples_per_request: samples,
            rate_per_sec: rate,
            keys: keys.clone(),
            seed,
        };
        let factory = crate::server::router::factory_for(models_dir.as_deref())
            .expect("models dir validated before the sweep");
        let (report, metrics) = if tcp {
            let ncfg = crate::server::net::NetConfig {
                max_inflight: args.get_usize("max-inflight", 256),
                rate_limit: args.get_f64("rate-limit", 0.0),
                slo_ms: slo_ms.max(1.0) as u64,
                ..crate::server::net::NetConfig::default()
            };
            open_loop_tcp_probe_with(rcfg, ecfg, bcfg, ncfg, conns, wspec, poisson, factory)
        } else {
            open_loop_probe_with(rcfg, ecfg, bcfg, wspec, poisson, factory)
        };
        println!("{report}");
        println!("{metrics}");
        report
    });
    match sweep.max_rate {
        Some(r) => println!("max rate under SLO (p99 ≤ {:.0}ms): {r:.0} req/s", slo_ms),
        None => println!("no probed rate met the SLO (p99 ≤ {:.0}ms)", slo_ms),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::server::batcher::BatcherConfig;
    use crate::server::router::oracle_factory;

    #[test]
    fn burst_workload_completes() {
        let router = Router::new(2, BatcherConfig::default(), oracle_factory());
        let spec = WorkloadSpec {
            n_requests: 10,
            samples_per_request: 8,
            rate_per_sec: f64::INFINITY,
            keys: vec![PlanKey::gddim("vpsde", "gmm2d", 5, 1)],
            seed: 3,
        };
        let out = ClosedLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
            id,
            n,
            key: key.clone(),
            seed,
        });
        assert_eq!(out.len(), 10);
        assert!(out.iter().all(|r| r.xs.len() == 8 * 2));
        router.shutdown();
    }

    #[test]
    fn cli_key_mix_adds_bdm_for_image_datasets_only() {
        // Validation, not a hard-coded process list, decides the mix: 2-D
        // vector data never lands on the image-space BDM, image presets do.
        let vec_mix = cli_key_mix("gddim:q=2", "gmm2d", 10).unwrap();
        assert_eq!(vec_mix.len(), 2, "gmm2d serves on vpsde + cld only");
        assert!(vec_mix.iter().all(|k| k.process != "bdm"));
        let img_mix = cli_key_mix("gddim:q=2+ancestral", "blobs16", 10).unwrap();
        assert_eq!(img_mix.len(), 6, "blobs16 serves 2 specs on all 3 processes");
        assert!(img_mix.iter().any(|k| k.process == "bdm"));
        for k in &img_mix {
            assert!(k.validate().is_ok(), "{:?}", k);
        }
    }

    #[test]
    fn engine_throughput_reports_positive_rate() {
        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        use crate::data::presets;
        use crate::diffusion::process::KtKind;
        use crate::diffusion::{Cld, Process, TimeGrid};
        use crate::samplers::GddimDet;
        use crate::score::oracle::GmmOracle;
        use std::sync::Arc;
        let spec = presets::gmm2d();
        let proc = Arc::new(Cld::standard(spec.d));
        let oracle = GmmOracle::new(proc.clone(), spec, KtKind::R);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 5);
        let plan =
            SamplerPlan::build(proc.as_ref(), &grid, &PlanConfig::deterministic(1, KtKind::R));
        let engine = Engine::new(2);
        let sampler = GddimDet { plan: &plan };
        let job = Job {
            proc: proc.as_ref(),
            model: &oracle,
            sampler: &sampler,
            n: 128,
            seed: 1,
        };
        assert!(engine_throughput(&engine, &job, 2) > 0.0);
        assert!(engine_throughput(&engine, &job, 1) > 0.0, "repeats=1 must not divide by zero");
    }

    /// An ε-model that counts invocations (and optionally sleeps a fixed
    /// time per call): the instrument behind the warm-up-exclusion and
    /// open-loop accounting tests.
    struct CountingModel {
        d: usize,
        calls: std::sync::atomic::AtomicUsize,
        pause: Duration,
    }

    impl CountingModel {
        fn new(d: usize, pause: Duration) -> Self {
            CountingModel { d, calls: std::sync::atomic::AtomicUsize::new(0), pause }
        }

        fn calls(&self) -> usize {
            self.calls.load(std::sync::atomic::Ordering::SeqCst)
        }
    }

    impl crate::score::model::ScoreModel for CountingModel {
        fn dim_u(&self) -> usize {
            self.d
        }

        fn kt_kind(&self) -> crate::diffusion::process::KtKind {
            crate::diffusion::process::KtKind::R
        }

        fn eps_batch(&self, _t: f64, _us: &[f64], out: &mut [f64]) {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
            if !self.pause.is_zero() {
                std::thread::sleep(self.pause);
            }
            out.fill(0.0);
        }
    }

    #[test]
    fn engine_throughput_runs_exactly_repeats_jobs() {
        use crate::diffusion::{Process, TimeGrid, Vpsde};
        use crate::samplers::Ancestral;
        let proc = Vpsde::standard(2);
        let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), 4);
        let model = CountingModel::new(2, Duration::ZERO);
        let engine = Engine::new(1);
        let sampler = Ancestral { grid: &grid };
        let job = Job {
            proc: &proc,
            model: &model,
            sampler: &sampler,
            n: 16,
            seed: 2,
        };
        // Calibrate ε-calls per run, then check the driver adds none.
        let _ = engine.run(&job);
        let per_run = model.calls();
        assert!(per_run > 0);
        let before = model.calls();
        let _ = engine_throughput(&engine, &job, 3);
        assert_eq!(
            model.calls() - before,
            3 * per_run,
            "engine_throughput must execute exactly `repeats` jobs (warm-up \
             is the first repeat, not an extra run)"
        );
    }

    #[test]
    fn open_loop_schedule_is_deterministic_and_rate_true() {
        let spec = WorkloadSpec {
            n_requests: 100,
            samples_per_request: 1,
            rate_per_sec: 50.0,
            keys: vec![PlanKey::gddim("vpsde", "gmm2d", 5, 1)],
            seed: 11,
        };
        let uniform = OpenLoop::new(spec.clone());
        assert_eq!(uniform.schedule(), uniform.schedule());
        let sched = uniform.schedule();
        assert_eq!(sched[0], 0.0);
        assert!((sched[99] - 99.0 / 50.0).abs() < 1e-12, "uniform spacing at the rate");

        let poisson = OpenLoop::poisson(spec.clone());
        assert_eq!(poisson.schedule(), poisson.schedule(), "poisson schedule is seeded");
        let p = poisson.schedule();
        assert!(p.windows(2).all(|w| w[1] > w[0]), "arrival times increase");
        // Mean inter-arrival ≈ 1/rate (100 draws: generous band).
        let mean_gap = p[99] / 99.0;
        assert!((mean_gap - 0.02).abs() < 0.01, "mean gap {mean_gap}");

        let burst = OpenLoop::new(WorkloadSpec { rate_per_sec: f64::INFINITY, ..spec });
        assert!(burst.schedule().iter().all(|&t| t == 0.0));
    }

    #[test]
    fn open_loop_accounting_on_fixed_cost_engine() {
        use crate::coeffs::plan::{PlanConfig, SamplerPlan};
        use crate::diffusion::process::KtKind;
        use crate::diffusion::{Process, TimeGrid, Vpsde};
        use crate::server::router::Prepared;
        use std::sync::Arc;

        // A synthetic fixed-cost backend: every ε call sleeps PAUSE, so a
        // request's service latency is ≈ NFE × PAUSE and the open-loop
        // accounting can be checked against a known floor.
        const NFE: usize = 4;
        const PAUSE: Duration = Duration::from_millis(2);
        let factory: Box<crate::server::router::PreparedFactory> =
            Box::new(move |key: &PlanKey, _preloaded| {
                let proc = Arc::new(Vpsde::standard(2));
                let grid = TimeGrid::uniform(proc.t_min(), proc.t_max(), key.nfe);
                let cfg = key.spec.plan_config().expect("gddim key carries a plan config");
                let plan = SamplerPlan::build(proc.as_ref(), &grid, &cfg);
                Ok(Arc::new(Prepared {
                    dim_x: proc.dim_x(),
                    model: Arc::new(CountingModel::new(proc.dim_u(), PAUSE)),
                    plan: Some(Arc::new(plan)),
                    grid,
                    proc,
                }))
            });
        let router = Router::new(
            1,
            BatcherConfig { max_batch: 4096, max_wait: Duration::from_millis(1) },
            factory,
        );
        let spec = WorkloadSpec {
            n_requests: 12,
            samples_per_request: 4,
            rate_per_sec: 500.0,
            keys: vec![PlanKey::gddim("vpsde", "gmm2d", NFE, 1)],
            seed: 5,
        };
        let run = OpenLoop::new(spec).drive(&router, |id, key, n, seed| GenRequest {
            id,
            n,
            key: key.clone(),
            seed,
        });
        assert_eq!(run.issued, 12);
        assert_eq!(run.responses.len(), 12, "open loop must collect every response");
        assert_eq!(run.dropped, 0);
        assert!(run.max_inject_lag >= 0.0 && run.max_inject_lag.is_finite());
        for r in &run.responses {
            assert!(r.queue_latency >= 0.0 && r.service_latency > 0.0);
            assert!(
                (r.queue_latency + r.service_latency - r.latency).abs() < 1e-9,
                "latency split must add up exactly"
            );
        }
        let report = run.report();
        let service = report.service.as_ref().unwrap();
        let floor = (NFE as f64) * PAUSE.as_secs_f64();
        assert!(
            service.p50 >= 0.5 * floor,
            "service p50 {} below the fixed-cost floor {}",
            service.p50,
            floor
        );
        let (q, t) = (report.queueing.as_ref().unwrap(), report.total.as_ref().unwrap());
        assert!(t.p50 >= service.p50, "total dominates service pointwise");
        assert!(q.p50 <= t.p50);
        for s in [q, service, t] {
            assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        }
        router.shutdown();
    }

    #[test]
    fn open_loop_tcp_probe_completes_and_the_split_adds_up() {
        use crate::engine::EngineConfig;
        use crate::server::net::NetConfig;
        use crate::server::router::RouterConfig;
        let spec = WorkloadSpec {
            n_requests: 6,
            samples_per_request: 2,
            rate_per_sec: 200.0,
            keys: vec![
                PlanKey::gddim("vpsde", "gmm2d", 5, 1),
                PlanKey::gddim("cld", "gmm2d", 5, 2),
            ],
            seed: 4,
        };
        let (report, metrics) = open_loop_tcp_probe(
            RouterConfig { dispatchers: 1, ..RouterConfig::default() },
            EngineConfig { workers: 2, ..EngineConfig::default() },
            BatcherConfig::default(),
            NetConfig { conn_threads: 2, ..NetConfig::default() },
            2,
            spec,
            false,
        );
        assert_eq!(report.issued, 6);
        assert_eq!(report.completed, 6, "every wire request must come back");
        assert_eq!(report.dropped, 0);
        let (q, s, t) = (
            report.queueing.as_ref().unwrap(),
            report.service.as_ref().unwrap(),
            report.total.as_ref().unwrap(),
        );
        assert!(q.p50 >= 0.0 && s.p50 > 0.0 && t.p50 >= s.p50);
        let edge = metrics.edge.expect("TCP probe report carries edge counters");
        // 2 warm requests + 6 measured ones, all admitted, none shed.
        assert_eq!(edge.requests_admitted, 8);
        assert_eq!(edge.requests_completed, 8);
        assert_eq!(edge.requests_shed, 0);
        assert_eq!(edge.connections_accepted, 3, "1 warm + 2 client connections");
    }

    #[test]
    fn max_rate_under_slo_picks_the_highest_passing_rate() {
        // Synthetic reports: p99 grows linearly with rate, so an SLO of
        // 0.1s passes 10/20/40 and fails 80.
        let fake = |rate: f64| {
            let p99 = rate / 400.0; // 0.025, 0.05, 0.1 → pass; 0.2 → fail
            let lat = Summary::from(&[p99; 4]);
            OpenLoopReport {
                offered_rate: rate,
                issued: 8,
                completed: 8,
                dropped: 0,
                max_inject_lag: 0.0,
                achieved_rate: rate,
                elapsed: 1.0,
                queueing: Some(lat.clone()),
                service: Some(lat.clone()),
                total: Some(lat),
            }
        };
        let sweep = max_rate_under_slo(&[10.0, 20.0, 40.0, 80.0], 0.1, fake);
        assert_eq!(sweep.max_rate, Some(40.0));
        assert_eq!(sweep.points.len(), 4);
        assert!(sweep.points[2].meets_slo && !sweep.points[3].meets_slo);

        // A dropped request disqualifies a rate even with a good p99.
        let dropping = |rate: f64| OpenLoopReport {
            dropped: 1,
            completed: 7,
            ..fake(rate)
        };
        let sweep = max_rate_under_slo(&[10.0], 0.1, dropping);
        assert_eq!(sweep.max_rate, None);
    }

    #[test]
    fn poisson_interarrivals_have_expected_mean() {
        let mut rng = Rng::seed_from(9);
        let n = 50_000;
        let rate = 40.0;
        let mean: f64 = (0..n).map(|_| rng.exponential(rate)).sum::<f64>() / n as f64;
        assert!((mean - 1.0 / rate).abs() < 0.002, "mean={mean}");
    }
}
